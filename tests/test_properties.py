"""Hypothesis property tests on system invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
st = pytest.importorskip("hypothesis.strategies")
from hypothesis import given, settings

from repro.configs import get_smoke_config
from repro.core import masks, memory
from repro.data import SyntheticCorpus
from repro.kernels import ref
from repro.optim import adamw

CFG = get_smoke_config("llama2-7b").replace(n_layers=4)
MM = memory.build_memory_model(CFG)
L = CFG.n_layers

mask_strategy = st.lists(st.booleans(), min_size=2 * L, max_size=2 * L)


@settings(max_examples=50, deadline=None)
@given(mask=mask_strategy, bs=st.integers(1, 64), sql=st.integers(1, 8192))
def test_memory_model_monotone(mask, bs, sql):
    """Peak memory is monotone: removing any block never increases it, and
    every peak is ≥ the embedding floor."""
    m = np.asarray(mask, bool)
    peak = MM.peak_bytes(m, bs, sql)
    assert peak >= MM.embed_bytes - 1e-6
    live = np.nonzero(m)[0]
    if len(live):
        m2 = masks.remove_block(m, int(live[0]))
        assert MM.peak_bytes(m2, bs, sql) <= peak + 1e-6


@settings(max_examples=30, deadline=None)
@given(bs=st.integers(1, 32), s1=st.integers(1, 2048), s2=st.integers(1, 2048))
def test_kv_linear_in_seq(bs, s1, s2):
    """Eq. (1): KV state is linear in seq_len (dense full mask)."""
    full = masks.full_mask(L)
    a = MM.state_bytes(full, bs, s1)
    b = MM.state_bytes(full, bs, s2)
    c = MM.state_bytes(full, bs, s1 + s2)
    assert abs((a + b) - c) < 1e-3


@settings(max_examples=25, deadline=None)
@given(mask=mask_strategy)
def test_compact_layout_consistent(mask):
    """Compacted layout has exactly the retained blocks, in order."""
    m = np.asarray(mask, bool)
    layout, gather = masks.compact_layout(CFG, m)
    n_mixers = sum(1 for s in layout if s.mixer is not None)
    n_ffns = sum(1 for s in layout if s.ffn is not None)
    assert n_mixers == int(m[:L].sum())
    assert n_ffns == int(m[L:].sum())
    # gather indices are strictly increasing per kind (order preserved)
    for kind, idxs in gather.items():
        assert idxs == sorted(idxs)


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), batch=st.integers(1, 4),
       seq=st.integers(2, 64))
def test_corpus_deterministic_and_in_range(seed, batch, seq):
    c = SyntheticCorpus(128, seed=seed)
    b1 = c.batch(batch, seq)
    b2 = c.batch(batch, seq)
    assert np.array_equal(b1["tokens"], b2["tokens"])
    assert b1["tokens"].min() >= 0 and b1["tokens"].max() < 128


@settings(max_examples=20, deadline=None)
@given(t=st.integers(1, 40), w=st.integers(1, 8))
def test_rglru_ref_contraction(t, w):
    """|h_t| stays bounded when |a|<1 and |b| bounded (stability)."""
    rng = np.random.default_rng(t * 100 + w)
    a = jnp.asarray(rng.uniform(0.0, 0.99, (1, t, w)).astype(np.float32))
    b = jnp.asarray(rng.uniform(-1, 1, (1, t, w)).astype(np.float32))
    h = ref.rglru_ref(a, b)
    assert np.abs(np.asarray(h)).max() <= 1.0 / (1 - 0.99) + 1e-3


@settings(max_examples=10, deadline=None)
@given(steps=st.integers(1, 5))
def test_adamw_descends_quadratic(steps):
    """AdamW reduces a convex quadratic within a few steps."""
    cfg = adamw.AdamWConfig(lr=0.1, weight_decay=0.0, warmup_steps=0,
                            schedule="constant", clip_norm=0.0)
    params = {"w": jnp.asarray([3.0, -2.0])}
    state = adamw.init(params)

    def loss(p):
        return jnp.sum(jnp.square(p["w"]))

    l0 = float(loss(params))
    for _ in range(steps):
        g = jax.grad(loss)(params)
        params, state, _ = adamw.apply(cfg, params, g, state)
    assert float(loss(params)) < l0


@settings(max_examples=15, deadline=None)
@given(frac=st.floats(0.3, 1.0), bs=st.integers(1, 16),
       sql=st.integers(64, 4096))
def test_budget_fraction_semantics(frac, bs, sql):
    b = memory.budget_bytes(MM, bs, sql, frac)
    assert abs(b - frac * MM.dense_peak(bs, sql)) < 1e-6


@settings(max_examples=20, deadline=None)
@given(x=st.lists(st.floats(-50, 50), min_size=4, max_size=64))
def test_int8_kv_quant_roundtrip(x):
    """Quantize→dequantize error bounded by scale/2 per element."""
    from repro.models.attention import kv_quant
    arr = jnp.asarray(np.asarray(x, np.float32).reshape(1, -1))
    q, scale = kv_quant(arr)
    deq = q.astype(jnp.float32) * scale
    err = np.abs(np.asarray(deq - arr))
    assert err.max() <= float(scale.max()) * 0.51 + 1e-6
