"""Hypothesis property tests on system invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
st = pytest.importorskip("hypothesis.strategies")
from hypothesis import given, settings

from repro.configs import get_smoke_config
from repro.core import masks, memory
from repro.data import SyntheticCorpus
from repro.kernels import ref
from repro.optim import adamw
from repro.runtime import KVPool, PoolExhausted

CFG = get_smoke_config("llama2-7b").replace(n_layers=4)
MM = memory.build_memory_model(CFG)
L = CFG.n_layers

mask_strategy = st.lists(st.booleans(), min_size=2 * L, max_size=2 * L)


@settings(max_examples=50, deadline=None)
@given(mask=mask_strategy, bs=st.integers(1, 64), sql=st.integers(1, 8192))
def test_memory_model_monotone(mask, bs, sql):
    """Peak memory is monotone: removing any block never increases it, and
    every peak is ≥ the embedding floor."""
    m = np.asarray(mask, bool)
    peak = MM.peak_bytes(m, bs, sql)
    assert peak >= MM.embed_bytes - 1e-6
    live = np.nonzero(m)[0]
    if len(live):
        m2 = masks.remove_block(m, int(live[0]))
        assert MM.peak_bytes(m2, bs, sql) <= peak + 1e-6


@settings(max_examples=30, deadline=None)
@given(bs=st.integers(1, 32), s1=st.integers(1, 2048), s2=st.integers(1, 2048))
def test_kv_linear_in_seq(bs, s1, s2):
    """Eq. (1): KV state is linear in seq_len (dense full mask)."""
    full = masks.full_mask(L)
    a = MM.state_bytes(full, bs, s1)
    b = MM.state_bytes(full, bs, s2)
    c = MM.state_bytes(full, bs, s1 + s2)
    assert abs((a + b) - c) < 1e-3


@settings(max_examples=25, deadline=None)
@given(mask=mask_strategy)
def test_compact_layout_consistent(mask):
    """Compacted layout has exactly the retained blocks, in order."""
    m = np.asarray(mask, bool)
    layout, gather = masks.compact_layout(CFG, m)
    n_mixers = sum(1 for s in layout if s.mixer is not None)
    n_ffns = sum(1 for s in layout if s.ffn is not None)
    assert n_mixers == int(m[:L].sum())
    assert n_ffns == int(m[L:].sum())
    # gather indices are strictly increasing per kind (order preserved)
    for kind, idxs in gather.items():
        assert idxs == sorted(idxs)


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), batch=st.integers(1, 4),
       seq=st.integers(2, 64))
def test_corpus_deterministic_and_in_range(seed, batch, seq):
    c = SyntheticCorpus(128, seed=seed)
    b1 = c.batch(batch, seq)
    b2 = c.batch(batch, seq)
    assert np.array_equal(b1["tokens"], b2["tokens"])
    assert b1["tokens"].min() >= 0 and b1["tokens"].max() < 128


@settings(max_examples=20, deadline=None)
@given(t=st.integers(1, 40), w=st.integers(1, 8))
def test_rglru_ref_contraction(t, w):
    """|h_t| stays bounded when |a|<1 and |b| bounded (stability)."""
    rng = np.random.default_rng(t * 100 + w)
    a = jnp.asarray(rng.uniform(0.0, 0.99, (1, t, w)).astype(np.float32))
    b = jnp.asarray(rng.uniform(-1, 1, (1, t, w)).astype(np.float32))
    h = ref.rglru_ref(a, b)
    assert np.abs(np.asarray(h)).max() <= 1.0 / (1 - 0.99) + 1e-3


@settings(max_examples=10, deadline=None)
@given(steps=st.integers(1, 5))
def test_adamw_descends_quadratic(steps):
    """AdamW reduces a convex quadratic within a few steps."""
    cfg = adamw.AdamWConfig(lr=0.1, weight_decay=0.0, warmup_steps=0,
                            schedule="constant", clip_norm=0.0)
    params = {"w": jnp.asarray([3.0, -2.0])}
    state = adamw.init(params)

    def loss(p):
        return jnp.sum(jnp.square(p["w"]))

    l0 = float(loss(params))
    for _ in range(steps):
        g = jax.grad(loss)(params)
        params, state, _ = adamw.apply(cfg, params, g, state)
    assert float(loss(params)) < l0


@settings(max_examples=15, deadline=None)
@given(frac=st.floats(0.3, 1.0), bs=st.integers(1, 16),
       sql=st.integers(64, 4096))
def test_budget_fraction_semantics(frac, bs, sql):
    b = memory.budget_bytes(MM, bs, sql, frac)
    assert abs(b - frac * MM.dense_peak(bs, sql)) < 1e-6


# ----------------------------------------------------------------- KV pool
def _pool_invariants(pool, n_pages, overcommits_seen):
    """Structural invariants that must hold after EVERY pool operation."""
    held_byte = [p for a in pool._live.values() for p in a.pages
                 if p < n_pages]
    held_tok = [p for a in pool._tok.values() for row in a.rows for p in row]
    held = held_byte + held_tok
    # page conservation: free ∪ held partitions [0, n_pages), no duplicates
    assert sorted(pool._free + held) == sorted(set(pool._free + held))
    # overflow ids are excluded above, so real pages always partition
    assert sorted(pool._free + held) == list(range(n_pages))
    # ledger: reserved tracks pages exactly; in_use never exceeds it
    # (within fp eps) unless a byte alloc overcommitted past capacity
    n_reserved = (sum(len(a.pages) for a in pool._live.values())
                  + len(held_tok))
    assert pool.bytes_reserved == pytest.approx(n_reserved * pool.page_bytes)
    assert pool.acct.overcommit_events >= overcommits_seen[0]
    overcommits_seen[0] = pool.acct.overcommit_events
    # commitments: never negative, always rebuildable from live allocs
    commit = sum(a.committed_pages - a.held_pages for a in pool._tok.values())
    assert pool.committed_pages == commit >= 0
    # peaks are monotone cumulative maxima
    assert pool.acct.peak_reserved_bytes >= pool.bytes_reserved
    assert pool.acct.peak_in_use_bytes >= pool.bytes_in_use - 1e-6


@settings(max_examples=60, deadline=None)
@given(data=st.data())
def test_kv_pool_byte_ops_never_leak(data):
    """Random alloc/free (± overcommit) sequences: pages are conserved,
    the ledger mirrors the free list, overcommit count is monotone."""
    n_pages = data.draw(st.integers(2, 12), label="n_pages")
    pool = KVPool(n_pages * 100, page_bytes=100)
    seen = [0]
    rids = [f"r{i}" for i in range(6)]
    for step in range(data.draw(st.integers(1, 25), label="n_ops")):
        rid = data.draw(st.sampled_from(rids), label=f"rid{step}")
        if rid in pool._live:
            pool.free(rid)
        else:
            nbytes = data.draw(st.integers(1, n_pages * 150),
                               label=f"bytes{step}")
            over = data.draw(st.booleans(), label=f"over{step}")
            try:
                pool.alloc(rid, nbytes, allow_overcommit=over)
            except PoolExhausted:
                # strict-only, and for a real shortage: either the free
                # list or the ledger (held over capacity by an earlier
                # overcommit) lacked headroom
                need = pool.pages_needed(nbytes)
                assert not over and (
                    not pool.can_alloc(nbytes)
                    or not pool.acct.can_reserve(need * pool.page_bytes))
        _pool_invariants(pool, n_pages, seen)
    for rid in pool.live_requests():
        pool.free(rid)
    assert sorted(pool._free) == list(range(n_pages))
    assert pool.bytes_reserved == 0 and pool.bytes_in_use == 0


@settings(max_examples=60, deadline=None)
@given(data=st.data())
def test_kv_pool_token_ops_never_leak(data):
    """Random alloc_tokens/extend/free sequences: pages conserved, the
    reserved ≥ in-use invariant holds, commitments guarantee that every
    extend within max_tokens succeeds."""
    n_pages = data.draw(st.integers(2, 16), label="n_pages")
    pt = data.draw(st.integers(1, 6), label="tokens_per_page")
    pool = KVPool(n_pages * 64, page_bytes=64, tokens_per_page=pt)
    seen = [0]
    rids = [f"t{i}" for i in range(5)]
    for step in range(data.draw(st.integers(1, 25), label="n_ops")):
        rid = data.draw(st.sampled_from(rids), label=f"rid{step}")
        if rid in pool._tok:
            st_alloc = pool._tok[rid]
            if (st_alloc.seq_tokens < st_alloc.max_tokens
                    and data.draw(st.booleans(), label=f"ext{step}")):
                pool.extend(rid, 1)      # within commitment: must not raise
            else:
                pool.free(rid)
        else:
            batch = data.draw(st.integers(1, 3), label=f"b{step}")
            n_tok = data.draw(st.integers(1, 4 * pt), label=f"n{step}")
            max_tok = data.draw(st.integers(n_tok, 6 * pt),
                                label=f"m{step}")
            # in-use rate chosen ≤ the physical per-token rate so the
            # analytical cross-check can never outrun the reservation
            rate = data.draw(st.floats(0.0, 64.0 / pt), label=f"rate{step}")
            try:
                pool.alloc_tokens(rid, batch, n_tok, max_tokens=max_tok,
                                  in_use_bytes=rate * n_tok * batch,
                                  in_use_per_token=rate * batch)
            except PoolExhausted:
                assert not pool.can_alloc_tokens(batch, max_tok)
        _pool_invariants(pool, n_pages, seen)
        assert pool.bytes_in_use <= pool.bytes_reserved + 1e-6
    for rid in pool.live_requests():
        pool.free(rid)
    assert sorted(pool._free) == list(range(n_pages))
    assert pool.committed_pages == 0
    assert pool.bytes_reserved == 0
    assert pool.bytes_in_use == pytest.approx(0.0, abs=1e-6)


@settings(max_examples=40, deadline=None)
@given(data=st.data())
def test_quantized_kv_pool_token_ops_conserve(data):
    """Quantized-pool variant of the token-ops suite: alloc/extend/free on
    an int8 physical pool conserves pages AND scale rows (the scale arrays
    never reshape, drop rows, or go non-finite across any op sequence),
    and the ledger's in-use side is charged at the physical byte width
    (``in_use_scale`` < 1 for narrow pages under a wide model dtype)."""
    n_pages = data.draw(st.integers(2, 10), label="n_pages")
    pt = data.draw(st.integers(1, 4), label="tokens_per_page")
    K, D, layers = 2, 4, 2
    # physical int8 page: elements (1 byte) + per-(layer, page, head) scales
    page_bytes = 2 * layers * pt * K * D * 1 + 2 * layers * K * 4
    pool = KVPool(n_pages * page_bytes, page_bytes=page_bytes,
                  tokens_per_page=pt)
    pool.allocate_physical(n_layers=layers, n_kv_heads=K, head_dim=D,
                           dtype=jnp.float32, kv_dtype="int8")
    assert pool.kv_dtype == "int8"
    assert pool.k_pages.dtype == jnp.int8
    sshape = (layers, pool.n_pages + 1, K)
    model_tok = 2 * K * D * 4 * layers
    assert pool.acct.in_use_scale == pytest.approx(
        (page_bytes / pt) / model_tok)
    seen = [0]
    rids = [f"q{i}" for i in range(4)]
    for step in range(data.draw(st.integers(1, 20), label="n_ops")):
        rid = data.draw(st.sampled_from(rids), label=f"rid{step}")
        if rid in pool._tok:
            st_alloc = pool._tok[rid]
            if (st_alloc.seq_tokens < st_alloc.max_tokens
                    and data.draw(st.booleans(), label=f"ext{step}")):
                pool.extend(rid, 1)
            else:
                pool.free(rid)
        else:
            batch = data.draw(st.integers(1, 2), label=f"b{step}")
            n_tok = data.draw(st.integers(1, 3 * pt), label=f"n{step}")
            max_tok = data.draw(st.integers(n_tok, 4 * pt), label=f"m{step}")
            rate = data.draw(st.floats(0.0, float(model_tok)),
                             label=f"rate{step}")
            try:
                pool.alloc_tokens(rid, batch, n_tok, max_tokens=max_tok,
                                  in_use_bytes=rate * n_tok * batch,
                                  in_use_per_token=rate * batch,
                                  kv_dtype="int8")
            except PoolExhausted:
                assert not pool.can_alloc_tokens(batch, max_tok)
        _pool_invariants(pool, n_pages, seen)
        assert pool.bytes_in_use <= pool.bytes_reserved + 1e-6
        # scale-row conservation: every op leaves the scale pools intact
        for s in (pool.k_scales, pool.v_scales):
            assert s.shape == sshape and s.dtype == jnp.float32
            assert bool(jnp.isfinite(s).all())
    for rid in pool.live_requests():
        pool.free(rid)
    assert sorted(pool._free) == list(range(n_pages))
    assert pool.committed_pages == 0
    assert pool.bytes_reserved == 0
    assert pool.bytes_in_use == pytest.approx(0.0, abs=1e-6)


@settings(max_examples=25, deadline=None)
@given(x=st.lists(st.floats(-50, 50), min_size=8, max_size=64))
def test_page_quant_roundtrip_bound(x):
    """Whole-page quantize→dequant error bounds, pinned: int8 error ≤
    scale/2 per element (symmetric rounding); fp8-e4m3 error ≤ 1/16
    relative (3 mantissa bits) plus the scale floor. Requantizing a
    page's own dequantized values with its scale as the floor reproduces
    the stored codes exactly (the monotone-scale append invariant)."""
    from repro.models.attention import page_dequant, page_quant
    arr = np.zeros((max(len(x) // 8, 1) * 8,), np.float32)
    arr[: len(x)] = np.asarray(x[: arr.size], np.float32)
    page = jnp.asarray(arr.reshape(1, -1, 2, 4))      # [1, pt, K=2, D=4]
    q, s = page_quant(page, jnp.int8)
    err = np.abs(np.asarray(page_dequant(q, s) - page))
    per_head = np.asarray(s)[..., None, :, None]
    assert (err <= per_head * 0.51 + 1e-6).all()
    q2, s2 = page_quant(page_dequant(q, s), jnp.int8, scale_floor=s)
    assert np.array_equal(np.asarray(q), np.asarray(q2))
    fp8 = getattr(jnp, "float8_e4m3fn", None)
    if fp8 is not None:
        q8, s8 = page_quant(page, fp8)
        err8 = np.abs(np.asarray(page_dequant(q8, s8) - page))
        bound = (np.abs(np.asarray(page)) * 0.0625
                 + np.asarray(s8)[..., None, :, None] + 1e-6)
        assert (err8 <= bound).all()


@settings(max_examples=20, deadline=None)
@given(x=st.lists(st.floats(-50, 50), min_size=4, max_size=64))
def test_int8_kv_quant_roundtrip(x):
    """Quantize→dequantize error bounded by scale/2 per element."""
    from repro.models.attention import kv_quant
    arr = jnp.asarray(np.asarray(x, np.float32).reshape(1, -1))
    q, scale = kv_quant(arr)
    deq = q.astype(jnp.float32) * scale
    err = np.abs(np.asarray(deq - arr))
    assert err.max() <= float(scale.max()) * 0.51 + 1e-6


@settings(max_examples=40, deadline=None)
@given(data=st.data())
def test_kv_pool_spill_restore_interleave_conserves(data):
    """Spill/restore (DESIGN.md §11) interleaved with alloc/extend/free on
    an int8 physical pool: pages and scale rows are conserved after EVERY
    op, a spill releases exactly its reservation, ``can_restore`` is an
    accurate oracle (True ⇒ restore succeeds, token-kind False ⇒ restore
    raises PoolExhausted), and draining live + spilled ends with the full
    free list — no page can leak through any preempt/resume/cancel
    interleaving."""
    n_pages = data.draw(st.integers(2, 10), label="n_pages")
    pt = data.draw(st.integers(1, 4), label="tokens_per_page")
    K, D, layers = 2, 4, 2
    page_bytes = 2 * layers * pt * K * D * 1 + 2 * layers * K * 4
    pool = KVPool(n_pages * page_bytes, page_bytes=page_bytes,
                  tokens_per_page=pt)
    pool.allocate_physical(n_layers=layers, n_kv_heads=K, head_dim=D,
                           dtype=jnp.float32, kv_dtype="int8")
    sshape = (layers, pool.n_pages + 1, K)
    model_tok = 2 * K * D * 4 * layers
    seen = [0]
    rids = [f"s{i}" for i in range(4)]
    for step in range(data.draw(st.integers(1, 22), label="n_ops")):
        rid = data.draw(st.sampled_from(rids), label=f"rid{step}")
        if rid in pool._tok:
            op = data.draw(st.sampled_from(["extend", "spill", "free"]),
                           label=f"op{step}")
            st_alloc = pool._tok[rid]
            if op == "extend" and st_alloc.seq_tokens < st_alloc.max_tokens:
                pool.extend(rid, 1)
            elif op == "spill":
                before = pool.bytes_reserved
                released = pool.spill(rid)
                # a spill releases exactly the reservation it held
                assert released == pytest.approx(st_alloc.reserved_bytes)
                assert pool.bytes_reserved == pytest.approx(
                    before - released)
                assert rid in pool.spilled_requests()
            else:
                pool.free(rid)
        elif rid in pool._spilled:
            op = data.draw(st.sampled_from(["restore", "drop"]),
                           label=f"op{step}")
            if op == "drop":
                assert pool.drop_spilled(rid) is True
                assert pool.drop_spilled(rid, missing_ok=True) is False
            elif pool.can_restore(rid):
                rows = pool.restore(rid)
                assert rid in pool._tok and rows is not None
            else:
                with pytest.raises(PoolExhausted):
                    pool.restore(rid)
                assert rid in pool._spilled   # still restorable later
        else:
            batch = data.draw(st.integers(1, 2), label=f"b{step}")
            n_tok = data.draw(st.integers(1, 3 * pt), label=f"n{step}")
            max_tok = data.draw(st.integers(n_tok, 4 * pt),
                                label=f"m{step}")
            rate = data.draw(st.floats(0.0, float(model_tok)),
                             label=f"rate{step}")
            try:
                pool.alloc_tokens(rid, batch, n_tok, max_tokens=max_tok,
                                  in_use_bytes=rate * n_tok * batch,
                                  in_use_per_token=rate * batch,
                                  kv_dtype="int8")
            except PoolExhausted:
                assert not pool.can_alloc_tokens(batch, max_tok)
        _pool_invariants(pool, n_pages, seen)
        assert pool.bytes_in_use <= pool.bytes_reserved + 1e-6
        # scale-row conservation across spill/restore scatter-gather
        for s in (pool.k_scales, pool.v_scales):
            assert s.shape == sshape and s.dtype == jnp.float32
            assert bool(jnp.isfinite(s).all())
    for rid in pool.live_requests():
        pool.free(rid)
    for rid in pool.spilled_requests():
        pool.drop_spilled(rid)
    assert sorted(pool._free) == list(range(n_pages))
    assert pool.committed_pages == 0
    assert pool.bytes_reserved == 0
    assert pool.bytes_in_use == pytest.approx(0.0, abs=1e-6)
    assert pool.stats()["spilled_requests"] == 0
