"""RAP core behaviour: memory model, GSI, masks/compaction, DQN, controller."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.core import (baselines, controller as ctl, dqn, env as env_lib,
                        gsi, masks, memory, workload)
from repro.models import decoder, registry


# ------------------------------------------------------------ memory model
def test_memory_model_matches_pytree(tiny_model):
    model, params, _ = tiny_model
    cfg = model.cfg
    mm = memory.build_memory_model(cfg, param_bytes_per=4)  # f32 smoke
    L = cfg.n_layers
    full = masks.full_mask(L)
    analytic = mm.param_bytes(full)
    real = sum(int(np.prod(l.shape)) * l.dtype.itemsize
               for l in jax.tree.leaves(params))
    assert abs(analytic - real) / real < 0.05


def test_memory_model_kv_scaling(tiny_model):
    model, _, _ = tiny_model
    mm = memory.build_memory_model(model.cfg)
    full = masks.full_mask(model.cfg.n_layers)
    s1 = mm.state_bytes(full, 2, 128)
    s2 = mm.state_bytes(full, 4, 128)
    s3 = mm.state_bytes(full, 2, 256)
    assert abs(s2 - 2 * s1) < 1e-6 and abs(s3 - 2 * s1) < 1e-6  # Eq. (1)
    # removing an MHA block reduces KV; removing FFN does not
    m = masks.remove_block(full, 0)
    assert mm.state_bytes(m, 2, 128) < s1
    m = masks.remove_block(full, model.cfg.n_layers)
    assert mm.state_bytes(m, 2, 128) == s1


def test_memory_model_matches_real_cache(tiny_model):
    """Analytical Eq.(4) state bytes == the actual allocated cache bytes."""
    model, params, batch = tiny_model
    cfg = model.cfg
    mm = memory.build_memory_model(cfg)
    B, S = 2, 64
    cache = model.init_cache(B, S)
    real = sum(int(np.prod(l.shape)) * l.dtype.itemsize
               for l in jax.tree.leaves(cache))
    analytic = mm.state_bytes(masks.full_mask(cfg.n_layers), B, S)
    # cfg dtype is f32 in smoke; kv bytes default = dtype bytes
    assert abs(real - analytic) / real < 0.05


# -------------------------------------------------------------------- GSI
def test_gsi_removal_order_and_trace(tiny_model):
    model, params, batch = tiny_model
    res = gsi.gsi_rank(model, params, batch, max_removals=3)
    assert len(res.order) == 3
    assert len(set(res.order)) == 3
    # scores snapshots: removed blocks become inf-masked in later snapshots
    s0, s1 = res.score_snapshots[0], res.score_snapshots[1]
    assert np.isfinite(s0[res.order[0]])
    assert not np.isfinite(s1[res.order[0]])


def test_gsi_vs_oneshot_divergence(tiny_model):
    """After removals, re-evaluated scores differ from one-shot scores —
    the paper's inter-layer dependence claim (Fig. 6)."""
    model, params, batch = tiny_model
    oneshot = gsi.oneshot_rank(model, params, batch)
    res = gsi.gsi_rank(model, params, batch, max_removals=2)
    later = res.score_snapshots[1]
    live = np.isfinite(later) & np.isfinite(oneshot)
    assert not np.allclose(later[live], oneshot[live], rtol=1e-3)


def test_gsi_scorer_masks_inactive(tiny_model):
    model, params, batch = tiny_model
    L = model.cfg.n_layers
    scorer = gsi.make_candidate_scorer(model, batch)
    m = np.ones(2 * L, np.float32)
    m[1] = 0.0
    scores = np.asarray(scorer(params, jnp.asarray(m)))
    assert not np.isfinite(scores[1])
    assert np.isfinite(np.delete(scores, 1)).all()


# ----------------------------------------------------- masks / compaction
def test_masked_equals_structural(tiny_model):
    model, params, batch = tiny_model
    cfg = model.cfg
    L = cfg.n_layers
    mask = masks.full_mask(L)
    mask[1] = False          # drop one mixer
    mask[L + 2] = False      # drop one ffn
    gates = masks.mask_to_gates(mask)
    full_logits = model.logits(params, batch, gates=gates)
    small, layout = masks.compact_params(params, cfg, mask)
    small_logits, _ = decoder.forward(small, cfg, batch["tokens"],
                                      layout=layout)
    np.testing.assert_allclose(np.asarray(full_logits),
                               np.asarray(small_logits), atol=1e-4,
                               rtol=1e-4)


def test_compaction_shrinks_params(tiny_model):
    model, params, _ = tiny_model
    cfg = model.cfg
    L = cfg.n_layers
    mask = masks.full_mask(L)
    mask[0] = mask[L] = False    # drop layer 0 entirely
    small, layout = masks.compact_params(params, cfg, mask)
    n_full = sum(int(np.prod(l.shape)) for l in jax.tree.leaves(params))
    n_small = sum(int(np.prod(l.shape)) for l in jax.tree.leaves(small))
    assert n_small < n_full
    assert len(layout) == L - 1


def test_bucket_key_collapses_uniform(tiny_model):
    model, _, _ = tiny_model
    cfg = model.cfg
    L = cfg.n_layers
    # whole-layer drops collapse by count (the vLLM-bucket-like case)
    m1 = masks.full_mask(L); m1[1] = m1[L + 1] = False
    m2 = masks.full_mask(L); m2[2] = m2[L + 2] = False
    assert masks.bucket_key(cfg, m1) == masks.bucket_key(cfg, m2)
    # half-layer drops keep their position in the signature
    m3 = masks.full_mask(L); m3[L] = False   # ffn-only drop
    assert masks.bucket_key(cfg, m1) != masks.bucket_key(cfg, m3)


# ------------------------------------------------------------ env + DQN
def make_env(tiny):
    model, params, batch = tiny
    mm = memory.build_memory_model(model.cfg)
    return env_lib.PruneEnv(model, params, batch, mm), mm


def test_env_episode_semantics(tiny_model):
    env, mm = make_env(tiny_model)
    budget = 0.7 * mm.dense_peak(4, 256)
    s = env.reset(4, 256, budget)
    assert s.shape == (env.state_dim,)
    valid = env.valid_actions()
    assert valid[1:].all()
    # STOP masked while over budget (memory-aware action mask)
    assert valid[0] == env.fits()
    s2, r, done, info = env.step(1)   # remove block 0
    assert not env.mask[0]
    assert np.isfinite(r)


def test_env_reward_decreases_with_removal(tiny_model):
    """Removing a block lowers Σ kept·(α·imp − β·mem) memory penalty."""
    env, mm = make_env(tiny_model)
    env.reset(4, 256, 0.5 * mm.dense_peak(4, 256))
    r_full = env._reward()
    env.step(1)
    # reward changes and stays finite
    assert np.isfinite(env._reward())


def test_dqn_training_runs_and_fits(tiny_model):
    env, mm = make_env(tiny_model)

    def sampler(rng):
        bs = int(rng.integers(1, 8))
        sql = int(rng.integers(64, 512))
        return bs, sql, 0.75 * mm.dense_peak(bs, sql)

    tr = dqn.train(lambda: env, episodes=4,
                   cfg=dqn.DQNConfig(eps_decay_episodes=2, batch_size=16),
                   request_sampler=sampler, seed=0)
    assert len(tr.episode_rewards) == 4
    assert all(tr.episode_fits)    # mask_stop_until_fit guarantees this
    assert dqn.n_params(tr.q_params) < 50_000   # paper: ~18K controller


def test_controller_meets_budget(tiny_model):
    model, params, batch = tiny_model
    mm = memory.build_memory_model(model.cfg)
    qp = dqn.init_qnet(jax.random.key(0), 2 * model.cfg.n_layers + 4,
                       2 * model.cfg.n_layers + 1, 32)
    c = ctl.RAPController(model, params, batch, mm, qp)
    budget = 0.6 * mm.dense_peak(4, 256)
    d = c.decide(4, 256, budget)
    assert d.fits and d.peak_bytes <= budget
    # abundant memory → keep everything (paper: "leaves model intact")
    d2 = c.decide(1, 32, 1.1 * mm.dense_peak(1, 32))
    assert d2.mask.all() and d2.steps == 0


# ------------------------------------------------------------- baselines
def test_baseline_masks_fit_budget(tiny_model):
    model, params, batch = tiny_model
    mm = memory.build_memory_model(model.cfg)
    bs, sql = 4, 256
    budget = 0.75 * mm.dense_peak(bs, sql)
    for name, fn in [
        ("shortgpt", lambda: baselines.shortgpt_mask(model, params, batch,
                                                     mm, bs, sql, budget)),
        ("random", lambda: baselines.random_drop_mask(model, mm, bs, sql,
                                                      budget)),
        ("oneshot", lambda: baselines.oneshot_ppl_mask(model, params, batch,
                                                       mm, bs, sql, budget)),
        ("llmpruner", lambda: baselines.llmpruner_mask(model, params, batch,
                                                       mm, bs, sql, budget)),
    ]:
        m = fn()
        assert mm.peak_bytes(m, bs, sql) <= budget, name


def test_mha_ffn_only_baselines_target_right_blocks(tiny_model):
    model, params, batch = tiny_model
    mm = memory.build_memory_model(model.cfg)
    L = model.cfg.n_layers
    budget = 0.8 * mm.dense_peak(4, 256)
    m_mha = baselines.mha_drop_mask(model, params, batch, mm, 4, 256, budget)
    assert m_mha[L:].all()          # FFN untouched
    m_ffn = baselines.ffn_skip_mask(model, params, batch, mm, 4, 256, budget)
    assert m_ffn[:L].all()          # MHA untouched


def test_slicegpt_slices_and_runs(tiny_model):
    model, params, batch = tiny_model
    mm = memory.build_memory_model(model.cfg)
    ratio = baselines.slicegpt_fit_ratio(model.cfg, mm, 4, 256,
                                         0.8 * mm.dense_peak(4, 256))
    assert 0.0 < ratio < 1.0
    p2, cfg2 = baselines.slicegpt_slice(model, params, ratio)
    assert cfg2.d_ff < model.cfg.d_ff
    m2 = registry.build(cfg2)
    loss, _ = m2.loss(p2, batch)
    assert np.isfinite(float(loss))


# -------------------------------------------------------------- workload
def test_workload_deterministic():
    cfg = workload.WorkloadConfig(seed=3, horizon_s=120)
    a, b = workload.generate(cfg), workload.generate(cfg)
    assert [(r.t, r.batch, r.seq_len) for r in a] == \
        [(r.t, r.batch, r.seq_len) for r in b]
    assert all(cfg.mem_floor <= r.budget_frac <= 1.0 for r in a)
