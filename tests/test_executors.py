"""Cross-executor conformance suite (DESIGN.md §7).

Every ``ModelExecutor`` backend must be observationally identical on the
engine's serve path: the SAME trace yields bitwise-identical per-request
token streams and keep-masks, the engine-report invariants hold, and the
decode horizon is unobservable (H ∈ {1, 4, 8} bitwise-equal, including a
``max_new`` that lands mid-horizon). A new executor only registers a
factory in ``EXECUTORS`` plus a param in ``EXECUTOR_PARAMS`` — every test
here then runs against it.

The sharded factory builds a DP-majority mesh (model axis 1): tensor
parallelism re-associates the matmul reductions (partial sums per shard),
so TP meshes are numerically close but not contractually bitwise — DP
sharding keeps per-slot compute identical, which is the contract this
suite pins. On one device that is the degenerate (1, 1) mesh; the
multi-device CI job re-runs the ``multi_device``-marked tests under
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` where the data
axis really shards (plus the 8-way end-to-end and transfer-guard tests
below).
"""
import jax
import numpy as np
import pytest

from repro.core import masks
from repro.core.policy import Decision, DensePolicy, RLPolicy
from repro.launch.mesh import make_host_mesh, make_serve_mesh
from repro.runtime import (EngineConfig, EngineRequest, LocalExecutor,
                           PagedExecutor, RAPEngine, ShardedExecutor,
                           TickStaircase)

EXECUTORS = {
    "local": lambda model, params, slots, kv_dtype=None: None,  # engine default
    "paged": lambda model, params, slots, kv_dtype=None: PagedExecutor(
        model, params, max_active=slots, kv_dtype=kv_dtype),
    "sharded": lambda model, params, slots, kv_dtype=None: ShardedExecutor(
        model, make_serve_mesh(slots), params=params, max_active=slots,
        kv_dtype=kv_dtype),
}

# sharded runs in the multi-device CI job (8 fake CPU devices); tier-1
# covers its single-device smoke path via tests/test_engine.py
EXECUTOR_PARAMS = ["local", "paged",
                   pytest.param("sharded", marks=pytest.mark.multi_device)]


# `served` (tiny model + memory model + random-Q controller) comes from
# tests/conftest.py — shared with the engine and horizon suites.


def _reqs(prompts, max_new=None, rate=1000.0, seed=0):
    rng = np.random.default_rng(seed)
    t, out = 0.0, []
    for i, p in enumerate(prompts):
        t += float(rng.exponential(1.0 / rate))
        out.append(EngineRequest(rid=f"r{i}", prompt=np.asarray(p, np.int32),
                                 arrival_t=t, max_new=max_new))
    return out


def _engine(model, params, c, kind, *, budget, max_new, slots=4, max_len=32,
            horizon=8, chunk=0, kv_dtype=None, policy=None):
    return RAPEngine(model, params, policy or RLPolicy(c), EngineConfig(
        mode="masked", max_new_tokens=max_new, max_active=slots,
        max_len=max_len, budget_bytes=budget, tokens_per_page=8,
        kv_dtype=kv_dtype, decode_horizon=horizon,
        max_prefill_tokens=chunk),
        executor=EXECUTORS[kind](model, params, slots, kv_dtype))


# ------------------------------------------------------- canonical trace
# 8 requests, alternating 16/24-token prompts, a pool of ~2.5 dense
# requests (admission must queue under load) — the PR 3 paged-vs-local
# acceptance trace, now the conformance trace every executor serves.
def _trace(batch, mm, cfg):
    toks = np.asarray(batch["tokens"])
    full = masks.full_mask(cfg.n_layers)
    prompts = [toks[:1, : (16 if i % 2 else 24)] for i in range(8)]
    budget = mm.param_bytes(full) + 2.5 * mm.state_bytes(full, 1, 26)
    return prompts, budget


@pytest.fixture(scope="module")
def reference_run(served):
    """The LocalExecutor report on the canonical trace — the oracle every
    backend is compared against bitwise."""
    model, params, batch, mm, c = served
    prompts, budget = _trace(batch, mm, model.cfg)
    eng = _engine(model, params, c, "local", budget=budget, max_new=2)
    return eng.run(_reqs(prompts))


@pytest.mark.parametrize("kind", EXECUTOR_PARAMS)
def test_trace_tokens_match_local_reference(served, reference_run, kind):
    """Bitwise token/mask equality on the canonical trace. For 'local'
    this degenerates to a run-to-run determinism check (same oracle
    trace, fresh engine)."""
    model, params, batch, mm, c = served
    prompts, budget = _trace(batch, mm, model.cfg)
    eng = _engine(model, params, c, kind, budget=budget, max_new=2)
    rep = eng.run(_reqs(prompts))
    done_ref = {r.rid: r for r in reference_run.results
                if r.status == "done"}
    done = {r.rid: r for r in rep.results if r.status == "done"}
    assert len(done) == len(done_ref) == 8 and rep.rejected == 0
    for rid, r in done_ref.items():
        np.testing.assert_array_equal(
            r.tokens, done[rid].tokens,
            err_msg=f"{kind} diverged from local on {rid}")
        np.testing.assert_array_equal(r.mask, done[rid].mask)


@pytest.mark.parametrize("kind", EXECUTOR_PARAMS)
def test_report_invariants(served, kind):
    """Engine-report invariants every backend must uphold: all served,
    accounting consistent, pool fully drained, budget never exceeded."""
    model, params, batch, mm, c = served
    prompts, budget = _trace(batch, mm, model.cfg)
    eng = _engine(model, params, c, kind, budget=budget, max_new=2)
    rep = eng.run(_reqs(prompts))
    done = [r for r in rep.results if r.status == "done"]
    assert len(done) == 8 and rep.rejected == 0
    assert rep.generated_tokens == sum(r.tokens.size for r in done)
    assert rep.tokens_per_s > 0.0 and rep.decode_iters > 0
    assert 0.0 <= rep.launch_s <= rep.wall_s + 1e-9
    for r in done:
        assert r.admitted_t >= r.arrival_t - 1e-9
        assert r.queue_delay_s >= 0.0
        assert r.finished_t >= r.admitted_t
        assert r.tokens.shape == (1, 2)       # truncated, never padded
        # TTFT is measured from arrival, so queue delay is a lower bound
        assert r.ttft_s >= r.queue_delay_s - 1e-9
    # latency summaries: one TTFT per served request, ordered percentiles
    assert rep.ttft["count"] == 8.0
    assert rep.ttft["p50"] <= rep.ttft["p90"] + 1e-12 <= rep.ttft["p99"] + 2e-12
    assert rep.itl["count"] >= 8.0            # ≥1 decode token per request
    assert rep.itl["p50"] <= rep.itl["p90"] + 1e-12 <= rep.itl["p99"] + 2e-12
    # stats() decomposes TTFT into queueing + prefill per request
    per_req = eng.stats()["requests"]
    assert set(per_req) == {r.rid for r in done}
    for rid, d in per_req.items():
        r = rep.result(rid)
        assert d["ttft_s"] == r.ttft_s
        np.testing.assert_allclose(
            d["queue_delay_s"] + d["prefill_s"], r.ttft_s, atol=1e-9)
    pool = rep.pool
    assert pool["peak_in_use_bytes"] <= pool["peak_reserved_bytes"] + 1e-6
    assert pool["peak_reserved_bytes"] <= pool["capacity_bytes"] + 1e-6
    assert pool["capacity_bytes"] + eng.resident_param_bytes <= budget + 1e-6
    assert pool["overcommit_events"] == 0
    assert pool["reserved_bytes"] == 0 and pool["in_use_bytes"] == 0


@pytest.mark.parametrize("kind", EXECUTOR_PARAMS)
def test_horizon_token_equivalence(served, kind):
    """decode_horizon ∈ {1, 4, 8} must emit bitwise-identical per-request
    token streams — max_new=6 deliberately lands mid-horizon for H=4 and
    H=8, exercising boundary truncation."""
    model, params, batch, mm, c = served
    toks = np.asarray(batch["tokens"])
    full = masks.full_mask(model.cfg.n_layers)
    budget = mm.param_bytes(full) + 4 * mm.state_bytes(full, 1, 32)
    prompts = [toks[:1, :16], toks[:1, :24], toks[:1, :16]]
    outs = {}
    for horizon in (1, 4, 8):
        eng = _engine(model, params, c, kind, budget=budget, max_new=6,
                      horizon=horizon)
        rep = eng.run(_reqs(prompts))
        assert all(r.status == "done" for r in rep.results)
        outs[horizon] = {r.rid: r.tokens for r in rep.results}
        for r in rep.results:
            assert r.tokens.shape == (1, 6)    # truncated, never padded
    for horizon in (4, 8):
        for rid, t in outs[1].items():
            np.testing.assert_array_equal(
                t, outs[horizon][rid],
                err_msg=f"{kind}: H={horizon} diverged from H=1 on {rid}")


@pytest.mark.parametrize("chunk", [1, 8, 64],
                         ids=["slice1", "horizon8", "whole"])
@pytest.mark.parametrize("kind", EXECUTOR_PARAMS)
def test_chunked_prefill_bitwise_conformance(served, reference_run, kind,
                                             chunk):
    """Chunked prefill is unobservable in results: the canonical trace
    served with ``max_prefill_tokens`` ∈ {1 (single-token slices), 8
    (horizon-sized), 64 (≥ whole prompt)} emits token streams and masks
    bitwise-identical to the monolithic reference, on every backend.
    Pow2 chunk decomposition never pads, so no garbage K/V can perturb
    the attention math."""
    model, params, batch, mm, c = served
    prompts, budget = _trace(batch, mm, model.cfg)
    eng = _engine(model, params, c, kind, budget=budget, max_new=2,
                  chunk=chunk)
    rep = eng.run(_reqs(prompts))
    done_ref = {r.rid: r for r in reference_run.results
                if r.status == "done"}
    done = {r.rid: r for r in rep.results if r.status == "done"}
    assert len(done) == len(done_ref) == 8 and rep.rejected == 0
    for rid, r in done_ref.items():
        np.testing.assert_array_equal(
            r.tokens, done[rid].tokens,
            err_msg=f"{kind} chunk={chunk} diverged from monolithic "
                    f"on {rid}")
        np.testing.assert_array_equal(r.mask, done[rid].mask)


@pytest.mark.parametrize("kind", EXECUTOR_PARAMS)
def test_chunked_horizon_equivalence(served, kind):
    """Chunked prefill composed with every decode horizon H ∈ {1, 4, 8}
    matches the monolithic H=1 stream bitwise — chunking and horizon are
    independently and jointly unobservable."""
    model, params, batch, mm, c = served
    toks = np.asarray(batch["tokens"])
    full = masks.full_mask(model.cfg.n_layers)
    budget = mm.param_bytes(full) + 4 * mm.state_bytes(full, 1, 32)
    prompts = [toks[:1, :16], toks[:1, :24], toks[:1, :16]]
    base = _engine(model, params, c, kind, budget=budget, max_new=6,
                   horizon=1).run(_reqs(prompts))
    ref = {r.rid: r.tokens for r in base.results}
    assert all(r.status == "done" for r in base.results)
    for horizon in (1, 4, 8):
        eng = _engine(model, params, c, kind, budget=budget, max_new=6,
                      horizon=horizon, chunk=8)
        rep = eng.run(_reqs(prompts))
        assert all(r.status == "done" for r in rep.results)
        for r in rep.results:
            np.testing.assert_array_equal(
                ref[r.rid], r.tokens,
                err_msg=f"{kind}: chunked H={horizon} diverged from "
                        f"monolithic H=1 on {r.rid}")


def test_paged_fragmentation_below_slot(served, reference_run):
    """Paged-specific conformance extra: measured physical fragmentation
    must be strictly below the slot-cache baseline (pages grow per token;
    slot caches pin max_len per occupant)."""
    model, params, batch, mm, c = served
    prompts, budget = _trace(batch, mm, model.cfg)
    eng = _engine(model, params, c, "paged", budget=budget, max_new=2)
    rep = eng.run(_reqs(prompts))
    assert 0.0 < rep.measured_frag < reference_run.measured_frag
    assert rep.pool["committed_pages"] == 0


# ------------------------------------------------------ quantized KV rows
# int8 KV is not bitwise vs the fp32 reference (quantization perturbs the
# attention values), so quantized rows get their own contracts: a tolerance
# gate against fp32, an EXACT gate on the greedy-stability trace, and full
# bitwise invariance of horizon/chunking WITHIN the quantized path.
QUANT_PARAMS = ["local", "paged"]


@pytest.mark.parametrize("kind", QUANT_PARAMS)
def test_quantized_trace_matches_fp32_within_tolerance(served, kind):
    """int8 vs model-width KV on the canonical trace under the tolerance
    gate: every request's FIRST token is exact (prefill logits are computed
    at model width before quantize-on-write), and at least 6 of 8 full
    streams are token-exact. The quantized pool must also buy ≥ 1.8× the
    pages of the fp32 pool at the same byte budget — the admission headroom
    the precision action exists for."""
    model, params, batch, mm, c = served
    prompts, budget = _trace(batch, mm, model.cfg)
    eng_f = _engine(model, params, c, kind, budget=budget, max_new=4)
    ref = {r.rid: r for r in eng_f.run(_reqs(prompts, max_new=4)).results
           if r.status == "done"}
    eng_q = _engine(model, params, c, kind, budget=budget, max_new=4,
                    kv_dtype="int8")
    rep = eng_q.run(_reqs(prompts, max_new=4))
    done = {r.rid: r for r in rep.results if r.status == "done"}
    assert len(done) == len(ref) == 8 and rep.rejected == 0
    # int8 reservations are ~4× smaller, so the policy's effective-budget
    # cell can drift for a request or two — compare decodes only where the
    # decision agreed (a mask flip changes the compute, not the precision)
    agree = [rid for rid in ref
             if np.array_equal(ref[rid].mask, done[rid].mask)]
    assert len(agree) >= 6, f"{kind}: masks diverged on {8 - len(agree)}/8"
    exact = 0
    for rid in agree:
        assert done[rid].tokens[0, 0] == ref[rid].tokens[0, 0], \
            f"{kind}: int8 perturbed the model-width prefill logits on {rid}"
        exact += np.array_equal(ref[rid].tokens, done[rid].tokens)
    assert exact >= len(agree) - 1, \
        f"{kind}: only {exact}/{len(agree)} int8 streams token-exact"
    # pool ledger: drained, physical-width accounting engaged
    assert rep.pool["reserved_bytes"] == 0 and rep.pool["in_use_bytes"] == 0
    if kind == "paged":
        assert eng_q.pool.kv_dtype == "int8"
        assert rep.pool["in_use_scale"] < 1.0
        assert eng_q.pool.n_pages >= 1.8 * eng_f.pool.n_pages


@pytest.mark.parametrize("kind", QUANT_PARAMS)
def test_quantized_greedy_stability_exact(served, kind):
    """The dedicated greedy-stability trace: ``max_new=1`` serves every
    request as prefill-only next-token prediction, whose logits never read
    quantized KV back — int8 serving MUST match fp32 exactly here, pinning
    that quantize-on-write cannot corrupt the prefill compute path."""
    model, params, batch, mm, c = served
    prompts, budget = _trace(batch, mm, model.cfg)
    ref = _engine(model, params, c, kind, budget=budget,
                  max_new=1).run(_reqs(prompts, max_new=1))
    rep = _engine(model, params, c, kind, budget=budget, max_new=1,
                  kv_dtype="int8").run(_reqs(prompts, max_new=1))
    done_ref = {r.rid: r for r in ref.results if r.status == "done"}
    done = {r.rid: r for r in rep.results if r.status == "done"}
    assert len(done) == len(done_ref) == 8
    agree = [rid for rid in done_ref
             if np.array_equal(done_ref[rid].mask, done[rid].mask)]
    assert len(agree) >= 6, f"{kind}: masks diverged on {8 - len(agree)}/8"
    for rid in agree:
        np.testing.assert_array_equal(
            done_ref[rid].tokens, done[rid].tokens,
            err_msg=f"{kind}: int8 diverged on the greedy-stability trace "
                    f"({rid})")


@pytest.mark.parametrize("kind", QUANT_PARAMS)
def test_quantized_horizon_unobservable(served, kind):
    """WITHIN the int8 path, horizon decode stays bitwise unobservable:
    H ∈ {1, 4, 8} emit identical streams. Decode reads quantized KV
    identically at every horizon, so this pins the quantized decode write
    seam (per-token masked page requantization, horizon pre-grant extends,
    scratch-page routing) against the H=1 quantized reference."""
    model, params, batch, mm, c = served
    prompts, budget = _trace(batch, mm, model.cfg)
    ref = None
    for horizon in (1, 4, 8):
        eng = _engine(model, params, c, kind, budget=budget, max_new=4,
                      horizon=horizon, kv_dtype="int8")
        rep = eng.run(_reqs(prompts, max_new=4))
        done = {r.rid: r.tokens for r in rep.results if r.status == "done"}
        assert len(done) == 8 and rep.rejected == 0
        if ref is None:
            ref = done
            continue
        for rid, t in ref.items():
            np.testing.assert_array_equal(
                t, done[rid],
                err_msg=f"{kind}: int8 H={horizon} diverged from H=1 "
                        f"on {rid}")


@pytest.mark.parametrize("kind", QUANT_PARAMS)
def test_quantized_chunked_prefill_tolerance(served, kind):
    """Chunked prefill under int8 is NOT bitwise vs monolithic — a later
    chunk attends to earlier chunks' *dequantized* KV, where monolithic
    prefill attends at model width — so it gets the tolerance gate:
    all 8 requests served, masks identical, ≥ 6/8 streams token-exact
    against the monolithic quantized run."""
    model, params, batch, mm, c = served
    prompts, budget = _trace(batch, mm, model.cfg)
    ref = _engine(model, params, c, kind, budget=budget, max_new=4,
                  kv_dtype="int8").run(_reqs(prompts, max_new=4))
    done_ref = {r.rid: r for r in ref.results if r.status == "done"}
    for chunk in (8, 64):
        eng = _engine(model, params, c, kind, budget=budget, max_new=4,
                      chunk=chunk, kv_dtype="int8")
        rep = eng.run(_reqs(prompts, max_new=4))
        done = {r.rid: r for r in rep.results if r.status == "done"}
        assert len(done) == len(done_ref) == 8 and rep.rejected == 0
        agree = [rid for rid in done_ref
                 if np.array_equal(done_ref[rid].mask, done[rid].mask)]
        assert len(agree) >= 6, \
            f"{kind}: masks diverged on {8 - len(agree)}/8 (chunk={chunk})"
        exact = sum(np.array_equal(done_ref[rid].tokens, done[rid].tokens)
                    for rid in agree)
        assert exact >= len(agree) - 1, \
            (f"{kind}: only {exact}/{len(agree)} int8 chunked "
             f"(chunk={chunk}) streams token-exact")


# --------------------------------------------------- sharded: multi-device
@pytest.mark.multi_device
def test_sharded_eight_way_mesh_end_to_end(served):
    """Acceptance: a full trace served on an 8-way host-platform mesh
    (one slot per device — the data axis REALLY shards) emits token
    streams bitwise-identical to LocalExecutor."""
    if len(jax.devices()) < 8:
        pytest.skip("needs 8 devices (multi-device CI job sets "
                    "XLA_FLAGS=--xla_force_host_platform_device_count=8)")
    model, params, batch, mm, c = served
    prompts, budget = _trace(batch, mm, model.cfg)
    local = _engine(model, params, c, "local", budget=budget, max_new=2,
                    slots=8)
    rep_l = local.run(_reqs(prompts))
    mesh = make_host_mesh((8, 1), ("data", "model"))
    eng = RAPEngine(model, params, RLPolicy(c), EngineConfig(
        mode="masked", max_new_tokens=2, max_active=8, max_len=32,
        budget_bytes=budget, tokens_per_page=8),
        executor=ShardedExecutor(model, mesh, params=params, max_active=8))
    rep_s = eng.run(_reqs(prompts))
    group = eng.executor.groups()[0]
    spec = group.cache["attn"]["k"].sharding.spec
    assert "data" in jax.tree.leaves(tuple(spec)), spec   # DP engaged
    done_l = {r.rid: r for r in rep_l.results if r.status == "done"}
    done_s = {r.rid: r for r in rep_s.results if r.status == "done"}
    assert len(done_l) == len(done_s) == 8
    for rid, r in done_l.items():
        np.testing.assert_array_equal(r.tokens, done_s[rid].tokens)
        np.testing.assert_array_equal(r.mask, done_s[rid].mask)
    assert eng.executor.stats()["mesh_devices"] == 8


@pytest.mark.multi_device
def test_sharded_tp_mesh_serves_and_is_deterministic(served):
    """A mesh with a real TP axis serves the trace end-to-end and is
    run-to-run deterministic. TP partial-sum re-association means bitwise
    equality with local is NOT contractual here — the bitwise conformance
    contract is pinned on DP meshes above."""
    if len(jax.devices()) < 4:
        pytest.skip("needs 4 devices for a (2, 2) mesh")
    model, params, batch, mm, c = served
    prompts, budget = _trace(batch, mm, model.cfg)
    mesh = make_host_mesh((2, 2), ("data", "model"))

    def run():
        eng = RAPEngine(model, params, RLPolicy(c), EngineConfig(
            mode="masked", max_new_tokens=2, max_active=4, max_len=32,
            budget_bytes=budget, tokens_per_page=8),
            executor=ShardedExecutor(model, mesh, params=params,
                                     max_active=4))
        return eng.run(_reqs(prompts))

    a, b = run(), run()
    done_a = {r.rid: r for r in a.results if r.status == "done"}
    done_b = {r.rid: r for r in b.results if r.status == "done"}
    assert len(done_a) == len(done_b) == 8
    for rid, r in done_a.items():
        np.testing.assert_array_equal(r.tokens, done_b[rid].tokens)


@pytest.mark.multi_device
def test_sharded_horizon_zero_transfers_when_warm(tiny_model):
    """After one warming call, a sharded horizon launch moves no bytes
    between host and device: the mesh-resident cache, positions, seed
    tokens, and gates are all committed device arrays and the horizon
    executable's shardings are pinned. The only sync is the single
    [n_slots, H] token read-back after the launch (placement columns stay
    exempt, as on the local path)."""
    model, params, batch = tiny_model
    full = masks.full_mask(model.cfg.n_layers)
    prompt = np.asarray(batch["tokens"])[:1, :16]
    mesh = make_serve_mesh(4)
    ex = ShardedExecutor(model, mesh, params=params, max_active=4)
    group = ex.group_for(full, 32)
    ex.prefill_into(group, [0], "r0", prompt, full)
    ex.decode_horizon(group, 4)                     # warm (compiles)
    with jax.transfer_guard("disallow"):
        toks_dev, idx, new = group.launch_horizon(4, ex.decode_buckets)
    assert not new                                  # warmed executable
    assert idx is None                              # full width, always
    toks = np.asarray(toks_dev)                     # the one read-back
    assert toks.shape == (4, 4)


# ------------------------------------------- elastic-budget preemption
# (DESIGN.md §11): a mid-serve budget shock forces KV spill to host and
# later resume; the token streams must be BITWISE identical to the
# unshocked run on every backend — preemption must be unobservable in
# the output, exactly like the decode horizon above.

def _kv_staircase(eng, budget, down, up, frac=0.45):
    """Tick staircase cutting ``frac`` of the KV headroom (budget minus
    resident params) between ticks ``down`` and ``up``; see
    run_budget_shock for why the cut targets the KV share."""
    params_b = float(eng.resident_param_bytes)
    kv = max(budget - params_b, 0.0)
    shocked = (params_b + (1.0 - frac) * kv) / budget
    return TickStaircase(budget, [(down, 1.0), (up - down, shocked),
                                  (0, 1.0)])


@pytest.mark.parametrize("kind", EXECUTOR_PARAMS)
def test_preemption_spill_restore_bitwise(served, kind):
    """Spill→restore round-trip under a mid-serve KV budget shock is
    bitwise: every request completes with the SAME tokens and mask as the
    unshocked oracle, at least one request was actually preempted, and
    the pool drains clean.

    Both runs use DensePolicy so the keep-mask cannot depend on the live
    budget: an adaptive policy legitimately prunes differently for
    requests ADMITTED during the shock window (that is the paper's
    point), which would flip tokens without any spill-path bug. Pinning
    the decision isolates exactly what this test owns — preemption must
    be unobservable in the output."""
    model, params, batch, mm, c = served
    prompts, budget = _trace(batch, mm, model.cfg)
    ref_eng = _engine(model, params, c, kind, budget=budget, max_new=6,
                      horizon=2, policy=DensePolicy(mm))
    ref = {r.rid: r for r in ref_eng.run(_reqs(prompts, max_new=6)).results
           if r.status == "done"}
    eng = _engine(model, params, c, kind, budget=budget, max_new=6,
                  horizon=2, policy=DensePolicy(mm))
    rep = eng.run(_reqs(prompts, max_new=6),
                  budget_trace=_kv_staircase(eng, budget, down=4, up=14))
    done = {r.rid: r for r in rep.results if r.status == "done"}
    assert rep.preempted_count > 0, f"{kind}: shock never preempted"
    assert rep.spilled_mb > 0
    assert set(done) == set(ref) == {f"r{i}" for i in range(8)}
    for rid, r in ref.items():
        np.testing.assert_array_equal(
            r.tokens, done[rid].tokens,
            err_msg=f"{kind}: preemption changed tokens on {rid}")
        np.testing.assert_array_equal(r.mask, done[rid].mask)
    assert rep.pool["reserved_bytes"] == 0
    assert rep.pool["spilled_requests"] == 0
    assert rep.pool["free_pages"] == rep.pool["n_pages"]


@pytest.mark.parametrize("kv_dtype", [None, "int8"])
def test_paged_preemption_bitwise_fp32_and_int8(served, kv_dtype):
    """The paged pool's PHYSICAL spill path (page gather → host → page
    scatter, including int8 quantization scale rows) round-trips bitwise:
    the shocked run reproduces the same-precision unshocked oracle
    token-for-token. fp32 and int8 pools are separate oracles — int8 is
    compared against int8, so any scale-row corruption on the spill path
    shows up as a token flip. DensePolicy pins the keep-mask (see
    test_preemption_spill_restore_bitwise) so only the spill path can
    flip a token."""
    model, params, batch, mm, c = served
    prompts, budget = _trace(batch, mm, model.cfg)
    ref_eng = _engine(model, params, c, "paged", budget=budget, max_new=6,
                      horizon=2, kv_dtype=kv_dtype, policy=DensePolicy(mm))
    ref = {r.rid: r for r in ref_eng.run(_reqs(prompts, max_new=6)).results
           if r.status == "done"}
    eng = _engine(model, params, c, "paged", budget=budget, max_new=6,
                  horizon=2, kv_dtype=kv_dtype, policy=DensePolicy(mm))
    # int8 pages reserve ~4x less, so the shock must cut deeper to evict
    frac = 0.45 if kv_dtype is None else 0.8
    rep = eng.run(_reqs(prompts, max_new=6),
                  budget_trace=_kv_staircase(eng, budget, down=4, up=14,
                                             frac=frac))
    done = {r.rid: r for r in rep.results if r.status == "done"}
    assert rep.preempted_count > 0
    assert set(done) == set(ref)
    for rid, r in ref.items():
        np.testing.assert_array_equal(
            r.tokens, done[rid].tokens,
            err_msg=f"kv_dtype={kv_dtype}: spill path changed tokens "
                    f"on {rid}")
    assert rep.pool["reserved_bytes"] == 0
    assert rep.pool["free_pages"] == rep.pool["n_pages"]


# ------------------------------------------------------ structural serving
# (DESIGN.md §9): structural buckets on the paged backend, the bucket
# aliasing regression, bucket-shape quantization, the bounded group set,
# and the persistent compilation cache.

class FixedMaskPolicy(DensePolicy):
    """Deterministic mask sequence keyed by observe() call index: call i
    returns ``seq[min(i, len(seq)-1)]``. Pins exactly which keep-mask each
    admission sees, independent of budget drift — the structural
    conformance tests need the mask stream itself to be the controlled
    variable."""

    name = "fixed"

    def __init__(self, mm, seq):
        super().__init__(mm)
        self._seq = [np.array(m, copy=True) for m in seq]
        self._i = 0

    def observe(self, state):
        mask = self._seq[min(self._i, len(self._seq) - 1)]
        self._i += 1
        peak = self.mm.peak_bytes(mask, state.batch, state.total_len)
        return self._stamp(Decision(mask=mask.copy(), steps=0,
                                    peak_bytes=peak,
                                    fits=peak <= state.budget_bytes,
                                    latency_s=0.0))


STRUCT_PARAMS = ["local", "paged"]


def _struct_engine(model, params, policy, kind, *, budget, max_new, slots=4,
                   max_len=32, horizon=8, kv_dtype=None, bucket_quant="none",
                   max_groups=0, cache_dir=""):
    ex = None
    if kind == "paged":
        ex = PagedExecutor(model, params, mode="structural", max_active=slots,
                           kv_dtype=kv_dtype, bucket_quant=bucket_quant)
    return RAPEngine(model, params, policy, EngineConfig(
        mode="structural", max_new_tokens=max_new, max_active=slots,
        max_len=max_len, budget_bytes=budget, tokens_per_page=8,
        kv_dtype=kv_dtype, decode_horizon=horizon,
        bucket_quant=bucket_quant, max_structural_groups=max_groups,
        compile_cache_dir=cache_dir), executor=ex)


def _drop_layer(cfg, *layers):
    m = masks.full_mask(cfg.n_layers)
    for i in layers:
        m[i] = m[cfg.n_layers + i] = False
    return m


@pytest.mark.parametrize("kind", STRUCT_PARAMS)
def test_structural_bucket_aliasing_serves_own_weights(served, kind):
    """THE aliasing regression (DESIGN.md §9): masks dropping DIFFERENT
    layers share a bucket signature (``bucket_key`` collapses k whole-layer
    drops by count), but must never share compacted params. Two
    same-signature requests served concurrently — A drops layer 0, B drops
    layer 1, one slot per group so neither can join the other's group —
    must each emit the stream their own single-request serve emits. The
    pre-fix executor cached the first mask's ``compact_params`` under the
    shared signature, so B decoded with A's weights (deferred behind A,
    then seated on A's gather)."""
    model, params, batch, mm, c = served
    toks = np.asarray(batch["tokens"])
    full = masks.full_mask(model.cfg.n_layers)
    mA, mB = _drop_layer(model.cfg, 0), _drop_layer(model.cfg, 1)
    assert masks.bucket_key(model.cfg, mA) == masks.bucket_key(model.cfg, mB)
    assert masks.gather_key(model.cfg, mA) != masks.gather_key(model.cfg, mB)
    budget = mm.param_bytes(full) + 4 * mm.state_bytes(full, 1, 32)
    pA, pB = toks[:1, :16], toks[:1, :24]

    def solo(mask, prompt):
        eng = _struct_engine(model, params, FixedMaskPolicy(mm, [mask]),
                             kind, budget=budget, max_new=4, slots=1)
        rep = eng.run([EngineRequest(rid="x", prompt=prompt, arrival_t=0.0,
                                     max_new=4)])
        return rep.result("x")

    ref_a, ref_b = solo(mA, pA), solo(mB, pB)
    eng = _struct_engine(model, params, FixedMaskPolicy(mm, [mA, mB]),
                         kind, budget=budget, max_new=4, slots=1)
    rep = eng.run([
        EngineRequest(rid="a", prompt=pA, arrival_t=0.0, max_new=4),
        EngineRequest(rid="b", prompt=pB, arrival_t=0.0, max_new=4)])
    ra, rb = rep.result("a"), rep.result("b")
    assert ra.status == rb.status == "done"
    np.testing.assert_array_equal(ra.mask, mA)
    np.testing.assert_array_equal(rb.mask, mB)
    np.testing.assert_array_equal(
        ra.tokens, ref_a.tokens,
        err_msg=f"{kind}: request A diverged from its solo reference")
    np.testing.assert_array_equal(
        rb.tokens, ref_b.tokens,
        err_msg=f"{kind}: same-signature request B was served with the "
                f"wrong compacted weights (bucket aliasing)")
    # one compiled family, two resident parameter gathers
    s = eng.executor.stats()
    assert s["bucket_signatures"] == 1
    assert s["groups"] == 2


def test_structural_paged_matches_local_bitwise(served):
    """Structural paged serves the canonical trace bitwise-identically to
    structural local: compacted per-bucket layer stacks decoding over the
    shared page pool reproduce the slot-cache reference token for token.
    One fixed whole-layer mask for every request, so backend-dependent
    policy call order cannot flip a mask."""
    model, params, batch, mm, c = served
    prompts, budget = _trace(batch, mm, model.cfg)
    mask = _drop_layer(model.cfg, 1)
    outs = {}
    for kind in STRUCT_PARAMS:
        eng = _struct_engine(model, params, FixedMaskPolicy(mm, [mask]),
                             kind, budget=budget, max_new=4)
        rep = eng.run(_reqs(prompts, max_new=4))
        done = {r.rid: r for r in rep.results if r.status == "done"}
        assert len(done) == 8 and rep.rejected == 0, kind
        for r in done.values():
            np.testing.assert_array_equal(r.mask, mask)
        outs[kind] = done
    for rid, r in outs["local"].items():
        np.testing.assert_array_equal(
            r.tokens, outs["paged"][rid].tokens,
            err_msg=f"structural paged diverged from local on {rid}")


@pytest.mark.parametrize("kind", STRUCT_PARAMS)
def test_structural_horizon_token_equivalence(served, kind):
    """Horizon decode stays unobservable in structural mode: H ∈ {1, 4, 8}
    emit bitwise-identical streams through the compacted layer stacks
    (max_new=6 lands mid-horizon for H=4 and H=8)."""
    model, params, batch, mm, c = served
    toks = np.asarray(batch["tokens"])
    full = masks.full_mask(model.cfg.n_layers)
    mask = _drop_layer(model.cfg, 2)
    budget = mm.param_bytes(full) + 4 * mm.state_bytes(full, 1, 32)
    prompts = [toks[:1, :16], toks[:1, :24], toks[:1, :16]]
    outs = {}
    for horizon in (1, 4, 8):
        eng = _struct_engine(model, params, FixedMaskPolicy(mm, [mask]),
                             kind, budget=budget, max_new=6, horizon=horizon)
        rep = eng.run(_reqs(prompts, max_new=6))
        assert all(r.status == "done" for r in rep.results)
        outs[horizon] = {r.rid: r.tokens for r in rep.results}
    for horizon in (4, 8):
        for rid, t in outs[1].items():
            np.testing.assert_array_equal(
                t, outs[horizon][rid],
                err_msg=f"structural {kind}: H={horizon} diverged from "
                        f"H=1 on {rid}")


@pytest.mark.parametrize("kind,kv_dtype", [("local", None), ("paged", None),
                                           ("paged", "int8")],
                         ids=["local-fp32", "paged-fp32", "paged-int8"])
def test_structural_spill_restore_bitwise(served, kind, kv_dtype):
    """Preemption is unobservable in structural mode too: a mid-serve KV
    budget shock spills compacted-bucket residents (paged: physical page
    gather → host → scatter, including int8 scale rows) and the resumed
    streams match the unshocked same-precision oracle bitwise. The resume
    path re-resolves the group by gather key, so a restored request can
    never land on another bucket's weights."""
    model, params, batch, mm, c = served
    prompts, budget = _trace(batch, mm, model.cfg)
    mask = _drop_layer(model.cfg, 1)
    ref_eng = _struct_engine(model, params, FixedMaskPolicy(mm, [mask]),
                             kind, budget=budget, max_new=6, horizon=2,
                             kv_dtype=kv_dtype)
    ref = {r.rid: r for r in ref_eng.run(_reqs(prompts, max_new=6)).results
           if r.status == "done"}
    eng = _struct_engine(model, params, FixedMaskPolicy(mm, [mask]),
                         kind, budget=budget, max_new=6, horizon=2,
                         kv_dtype=kv_dtype)
    frac = 0.45 if kv_dtype is None else 0.8
    rep = eng.run(_reqs(prompts, max_new=6),
                  budget_trace=_kv_staircase(eng, budget, down=4, up=14,
                                             frac=frac))
    done = {r.rid: r for r in rep.results if r.status == "done"}
    assert rep.preempted_count > 0, f"{kind}/{kv_dtype}: shock never " \
                                    f"preempted"
    assert set(done) == set(ref)
    for rid, r in ref.items():
        np.testing.assert_array_equal(
            r.tokens, done[rid].tokens,
            err_msg=f"structural {kind}/{kv_dtype}: spill/resume changed "
                    f"tokens on {rid}")
    assert rep.pool["reserved_bytes"] == 0
    assert rep.pool["spilled_requests"] == 0


def test_bucket_quantization_bitwise_and_bounded(tiny_model):
    """Bucket-shape quantization is invisible in the tokens and bounds the
    compiled set: every trial mask served through a pow2-quantized bucket
    (exact mask realized as 0/1 gates inside it) emits the stream the
    exact structural compaction emits — gating a block off multiplies by
    literal 0.0/1.0, bitwise-identical to dropping it — while the
    signature count collapses onto the pow2 ladder (≤ ceil(log2 L)+1
    families; here {4, 2}-layer buckets for 5 distinct masks)."""
    model, params, batch = tiny_model
    L = model.cfg.n_layers
    prompt = np.asarray(batch["tokens"])[:1, :16]
    trial = [_drop_layer(model.cfg, 0), _drop_layer(model.cfg, 1),
             _drop_layer(model.cfg, 3), _drop_layer(model.cfg, 0, 1)]
    half = masks.full_mask(L)
    half[L + 2] = False                      # ffn-only drop: gated in both
    trial.append(half)
    streams, stats = {}, {}
    for quant in ("none", "pow2"):
        ex = LocalExecutor(model, params, mode="structural", max_active=2,
                           bucket_quant=quant)
        out = []
        for i, m in enumerate(trial):
            g = ex.group_for(m, 32)
            first = ex.prefill_into(g, [0], f"r{i}", prompt, m)
            toks, _ = ex.decode_horizon(g, 4)
            g.evict([0])
            out.append(np.concatenate([first, toks[0]]))
        streams[quant] = out
        stats[quant] = ex.stats()
    for i, m in enumerate(trial):
        np.testing.assert_array_equal(
            streams["none"][i], streams["pow2"][i],
            err_msg=f"pow2 bucket changed tokens for trial mask {i}")
    bound = int(np.ceil(np.log2(L))) + 1
    assert stats["pow2"]["bucket_signatures"] <= bound
    assert stats["pow2"]["bucket_signatures"] == 2      # {4, 2}-layer
    assert stats["pow2"]["groups"] == 2                 # gathers collapsed
    assert stats["none"]["groups"] == len(trial)        # one per exact mask
    assert (stats["pow2"]["prefill_executables"]
            < stats["none"]["prefill_executables"])


def test_structural_group_cap_evicts_idle(tiny_model):
    """The ``max_groups`` cap bounds ``_groups``/``_prefill_fns``/resident
    param growth under an adaptive mask stream: idle structural groups are
    evicted LRU at mint time, releasing their prefill executables and —
    when last of their signature — the resident compacted stack. Occupied
    groups are never evicted (the cap may overshoot while all are busy)."""
    model, params, batch = tiny_model
    L = model.cfg.n_layers
    prompt = np.asarray(batch["tokens"])[:1, :16]
    ex = LocalExecutor(model, params, mode="structural", max_active=2,
                       max_groups=2)
    for k in range(L):                      # 4 distinct single-layer drops
        m = _drop_layer(model.cfg, k)
        g = ex.group_for(m, 32)
        ex.prefill_into(g, [0], f"r{k}", prompt, m)
        ex.decode_horizon(g, 2)
        g.evict([0])
    s = ex.stats()
    assert s["groups"] <= 2
    assert s["resident_param_stacks"] <= 2
    # all four masks share one 3-layer signature: one prefill family
    assert s["prefill_executables"] == 1
    # occupied groups are exempt: with both cap slots busy, a third mask
    # overshoots instead of evicting a resident
    g0 = ex.group_for(_drop_layer(model.cfg, 0), 32)
    ex.prefill_into(g0, [0], "busy0", prompt, _drop_layer(model.cfg, 0))
    g1 = ex.group_for(_drop_layer(model.cfg, 1), 32)
    ex.prefill_into(g1, [0], "busy1", prompt, _drop_layer(model.cfg, 1))
    g2 = ex.group_for(_drop_layer(model.cfg, 2), 32)
    assert g0.occupied() and g1.occupied()
    assert ex.stats()["groups"] == 3
    # …and the overshoot drains at the next mint once they idle
    g0.evict([0])
    g1.evict([0])
    ex.group_for(_drop_layer(model.cfg, 3), 32)
    assert ex.stats()["groups"] <= 2


def test_invalidation_unified(tiny_model):
    """``set_max_active`` and ``drop_groups`` share one invalidation path:
    both clear groups, prefill executables, and resident compacted params
    — stale (signature, slots) keys must not pin dead XLA executables
    after a capacity reshape."""
    model, params, batch = tiny_model
    prompt = np.asarray(batch["tokens"])[:1, :16]
    for invalidate in (lambda e: e.set_max_active(4),
                       lambda e: e.drop_groups()):
        ex = LocalExecutor(model, params, mode="structural", max_active=2)
        m = _drop_layer(model.cfg, 0)
        g = ex.group_for(m, 32)
        ex.prefill_into(g, [0], "r0", prompt, m)
        g.evict([0])
        s = ex.stats()
        assert s["groups"] == 1 and s["prefill_executables"] == 1
        assert s["resident_param_stacks"] == 1
        invalidate(ex)
        s = ex.stats()
        assert s["groups"] == 0
        assert s["prefill_executables"] == 0
        assert s["resident_param_stacks"] == 0


def test_persistent_compile_cache_hits(served, tmp_path):
    """With ``EngineConfig.compile_cache_dir`` set, a second engine serving
    the same config after ``jax.clear_caches()`` re-traces its executables
    but loads the XLA binaries from disk: the report shows cache hits,
    near-zero misses, and the replayed streams are bitwise-identical."""
    model, params, batch, mm, c = served
    toks = np.asarray(batch["tokens"])
    full = masks.full_mask(model.cfg.n_layers)
    mask = _drop_layer(model.cfg, 1)
    budget = mm.param_bytes(full) + 4 * mm.state_bytes(full, 1, 32)
    prompts = [toks[:1, :16], toks[:1, :16]]
    names = ("jax_compilation_cache_dir",
             "jax_persistent_cache_min_entry_size_bytes",
             "jax_persistent_cache_min_compile_time_secs")
    prev = {n: getattr(jax.config, n) for n in names}
    try:
        def serve():
            eng = _struct_engine(model, params, FixedMaskPolicy(mm, [mask]),
                                 "local", budget=budget, max_new=4,
                                 cache_dir=str(tmp_path))
            return eng.run(_reqs(prompts, max_new=4))

        rep1 = serve()
        assert rep1.compile_events > 0
        jax.clear_caches()                  # drop in-memory executables
        # first replay: executables compiled BEFORE the cache was enabled
        # (session fixtures, earlier tests) are written — not hit — so
        # only the second replay has a history-independent miss count
        rep2 = serve()
        assert rep2.compile_cache_hits > 0, \
            "warmed replay never hit the persistent cache"
        jax.clear_caches()
        rep3 = serve()
        assert rep3.compile_cache_hits > 0
        assert rep3.compile_cache_misses == 0, \
            "fully-warmed replay still recompiled"
        done1 = {r.rid: r.tokens for r in rep1.results}
        for rep in (rep2, rep3):
            for r in rep.results:
                np.testing.assert_array_equal(done1[r.rid], r.tokens)
    finally:
        for n, v in prev.items():
            jax.config.update(n, v)
        from jax._src import compilation_cache as _cc
        _cc.reset_cache()               # re-latch: later tests cache-free
        from repro.runtime.engine import _CACHE_LISTENER
        _CACHE_LISTENER.pop("dir", None)
