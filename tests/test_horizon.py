"""Horizon decode (DESIGN.md §5): device-resident state + fused H-token
decode loops.

Pins the two properties the horizon refactor exists for:

  * a *warmed* horizon launch performs ZERO host↔device transfers between
    the launch and the single ``[B, H]`` token read-back — all decode
    state (cache/pos/tokens/gates/page tables) is device-resident and the
    bucket index vectors are cached (``jax.transfer_guard``);
  * ``decoder.decode_horizon`` is bitwise-equal to H separate decode
    steps. (The engine-level H ∈ {1, 4, 8} token-equivalence pins moved
    into the cross-executor conformance suite, ``tests/test_executors.py``,
    which runs them on every backend — local, paged, sharded.)
"""
import jax
import numpy as np
import pytest

from repro.core import masks
from repro.models import decoder
from repro.runtime import (EngineConfig, EngineRequest, FIFOScheduler,
                           KVPool, LocalExecutor, PagedExecutor)

# `served` comes from tests/conftest.py


def test_horizon_matches_reference_rollout(served):
    """decoder.decode_horizon == H separate decode_step calls, bitwise."""
    model, params, batch, mm, c = served
    cfg = model.cfg
    import jax.numpy as jnp
    prompt = jnp.asarray(np.asarray(batch["tokens"])[:2, :12], jnp.int32)
    logits, cache = decoder.prefill(params, cfg, prompt, 24)
    tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    ref_cache = jax.tree.map(lambda x: x, cache)
    ref, rtok = [], tok
    for _ in range(5):
        lg, ref_cache = decoder.decode_step(params, cfg, ref_cache, rtok)
        rtok = jnp.argmax(lg[:, -1], -1)[:, None].astype(jnp.int32)
        ref.append(np.asarray(rtok)[:, 0])
    hor, _ = decoder.decode_horizon(params, cfg, cache, tok, 5)
    np.testing.assert_array_equal(np.asarray(hor), np.stack(ref, axis=1))


# --------------------------------------------------------- transfer guard
def test_local_horizon_zero_transfers_when_warm(tiny_model):
    """After one warming call, a LocalExecutor horizon launch moves no
    bytes between host and device: cache, positions, seed tokens, gates,
    and the bucket index vector are all device-resident. The only sync is
    the single [B, H] token read-back after the launch."""
    model, params, batch = tiny_model
    full = masks.full_mask(model.cfg.n_layers)
    prompt = np.asarray(batch["tokens"])[:1, :16]
    ex = LocalExecutor(model, params, mode="masked", max_active=4)
    group = ex.group_for(full, 32)
    ex.prefill_into(group, [0], "r0", prompt, full)
    ex.decode_horizon(group, 4)                     # warm (compiles)
    with jax.transfer_guard("disallow"):
        toks_dev, idx, new = group.launch_horizon(4, ex.decode_buckets)
    assert not new                                  # warmed executable
    toks = np.asarray(toks_dev)                     # the one read-back
    assert toks.shape == (1, 4)                     # bucket width 1
    assert idx == [0]


def test_paged_horizon_zero_transfers_when_warm(tiny_model):
    """Paged sibling: page table, positions, tokens, and gates are
    device-resident; the bulk page pre-grant runs host-side before the
    launch (here sized so no page boundary is crossed) and the launch
    itself moves nothing."""
    model, params, batch = tiny_model
    full = masks.full_mask(model.cfg.n_layers)
    prompt = np.asarray(batch["tokens"])[:1, :16]
    ex = PagedExecutor(model, params, max_active=4)
    pt = 64                       # horizon stays inside the prompt's page
    page_bytes = ex.page_phys_bytes(pt)
    pool = KVPool(16 * page_bytes, page_bytes=page_bytes,
                  tokens_per_page=pt)
    ex.bind_pool(pool, max_len=64)
    pool.alloc_tokens("r0", 1, 16, max_tokens=64)
    group = ex.group_for(full, 0)
    ex.prefill_into(group, [0], "r0", prompt, full)
    ex.decode_horizon(group, 4)                     # warm (compiles)
    with jax.transfer_guard("disallow"):
        granted = ex.pre_extend_horizon(group, 4)   # host-only bookkeeping
        toks_dev, idx, new = ex.launch_horizon(group, 4)
    assert granted == 0 and not new
    toks = np.asarray(toks_dev)                     # the one read-back
    assert toks.shape == (1, 4)
    assert idx == [0]
    pool.free("r0")


def test_paged_horizon_bulk_pre_grant(tiny_model):
    """A horizon crossing a page boundary pre-grants ALL its pages in one
    bulk extend before the launch, and the grant lands in both the host
    mirror and the device page table."""
    model, params, batch = tiny_model
    full = masks.full_mask(model.cfg.n_layers)
    prompt = np.asarray(batch["tokens"])[:1, :16]
    ex = PagedExecutor(model, params, max_active=4)
    pt = 8
    page_bytes = ex.page_phys_bytes(pt)
    pool = KVPool(16 * page_bytes, page_bytes=page_bytes,
                  tokens_per_page=pt)
    ex.bind_pool(pool, max_len=64)
    pool.alloc_tokens("r0", 1, 16, max_tokens=32)   # 2 pages, commits 4
    group = ex.group_for(full, 0)
    ex.prefill_into(group, [0], "r0", prompt, full)
    # 16 tokens backed; an 8-token horizon needs page 3 (tokens 17–24)
    granted = ex.pre_extend_horizon(group, 8)
    assert granted == 1
    assert pool.seq_tokens("r0") == 24
    assert group.table[0, 2] != group.scratch_page
    np.testing.assert_array_equal(np.asarray(group.table_dev), group.table)
    # beyond the commitment the pre-grant clamps instead of raising
    ex.pre_extend_horizon(group, 8)
    assert pool.seq_tokens("r0") == 32
    ex.pre_extend_horizon(group, 8)                 # fully committed: no-op
    assert pool.seq_tokens("r0") == 32
    pool.free("r0")


# ------------------------------------------------- host/device overlap
def _host_phase_work(now=0.0):
    """Representative host-side scheduling work the async engine runs
    while a launched scan is in flight: waiting-set bookkeeping and plan
    construction. Must perform zero host↔device transfers."""
    sched = FIFOScheduler()
    sched.add(EngineRequest(rid="w0", prompt=np.zeros((1, 8), np.int32),
                            arrival_t=now), cost=16.0)
    plan = sched.schedule(now, running=["r0"])
    assert [r.rid for r in plan.admit] == ["w0"]
    assert plan.decode == ["r0"]


def test_local_decode_launch_overlaps_host_work(tiny_model):
    """The async-tick contract on the local backend: ``decode_launch``
    dispatches the fused scan and returns without syncing, host
    scheduling work runs with the scan in flight, and the only transfer
    of the whole sequence is ``decode_finish``'s token read-back — the
    launch + host phase execute under ``jax.transfer_guard``."""
    model, params, batch = tiny_model
    full = masks.full_mask(model.cfg.n_layers)
    prompt = np.asarray(batch["tokens"])[:1, :16]
    ex = LocalExecutor(model, params, mode="masked", max_active=4)
    group = ex.group_for(full, 32)
    ex.prefill_into(group, [0], "r0", prompt, full)
    ex.decode_horizon(group, 4)                     # warm (compiles)
    with jax.transfer_guard("disallow"):
        launch = ex.decode_launch(group, 4)         # scan in flight
        _host_phase_work()                          # overlapped host phase
    toks, new = ex.decode_finish(launch)            # the one sync point
    assert not new
    assert toks.shape == (4, 4)                     # [n_slots, H]


def test_paged_decode_launch_overlaps_host_work(tiny_model):
    """Paged sibling: the bulk page pre-grant inside ``decode_launch`` is
    host-only bookkeeping (sized here so no boundary is crossed), the
    launch moves nothing, and admission-style pool queries run while the
    scan is in flight."""
    model, params, batch = tiny_model
    full = masks.full_mask(model.cfg.n_layers)
    prompt = np.asarray(batch["tokens"])[:1, :16]
    ex = PagedExecutor(model, params, max_active=4)
    pt = 64                       # horizon stays inside the prompt's page
    page_bytes = ex.page_phys_bytes(pt)
    pool = KVPool(16 * page_bytes, page_bytes=page_bytes,
                  tokens_per_page=pt)
    ex.bind_pool(pool, max_len=64)
    pool.alloc_tokens("r0", 1, 16, max_tokens=64)
    group = ex.group_for(full, 0)
    ex.prefill_into(group, [0], "r0", prompt, full)
    ex.decode_horizon(group, 4)                     # warm (compiles)
    with jax.transfer_guard("disallow"):
        launch = ex.decode_launch(group, 4)         # pre-grant + dispatch
        _host_phase_work()                          # overlapped host phase
        assert pool.can_alloc_tokens(1, 64)         # admission-style query
    toks, new = ex.decode_finish(launch)            # the one sync point
    assert not new
    assert toks.shape == (4, 4)
    assert np.asarray(toks[0]).any()
    pool.free("r0")


def test_engine_chunked_prefill_interleaves_with_decode(served):
    """The async tick really interleaves: while a long prompt prefills
    chunk-by-chunk, the running request's decode horizons keep launching
    between chunks (instead of stalling for the whole prompt)."""
    from repro.core.policy import RLPolicy
    from repro.runtime import RAPEngine

    model, params, batch, mm, c = served

    events = []

    class Recorder(LocalExecutor):
        def decode_launch(self, group, horizon):
            events.append("launch")
            return super().decode_launch(group, horizon)

        def prefill_step(self, task):
            events.append("chunk")
            return super().prefill_step(task)

    toks = np.asarray(batch["tokens"])
    full = masks.full_mask(model.cfg.n_layers)
    budget = mm.param_bytes(full) + 4 * mm.state_bytes(full, 1, 48)
    eng = RAPEngine(model, params, RLPolicy(c), EngineConfig(
        mode="masked", max_new_tokens=16, max_active=4, max_len=48,
        budget_bytes=budget, tokens_per_page=8, decode_horizon=2,
        max_prefill_tokens=4),
        executor=Recorder(model, params, mode="masked", max_active=4))
    short = EngineRequest(rid="short", prompt=toks[:1, :8], arrival_t=0.0)
    long_r = EngineRequest(rid="long", prompt=toks[:1, :24], arrival_t=0.0,
                           max_new=2)
    rep = eng.run([short, long_r])
    assert all(r.status == "done" for r in rep.results)
    # 24/4 = 6 chunks for the long prompt + 8/4 = 2 for the short one...
    assert events.count("chunk") == 8
    # ...and decode horizons launched BETWEEN its chunks
    first, last = events.index("chunk"), len(events) - 1 - \
        events[::-1].index("chunk")
    assert events[first:last].count("launch") >= 2, events


# ------------------------------------------------------------- validation
def test_decode_horizon_validation(served):
    model, params, batch, mm, c = served
    with pytest.raises(ValueError, match="decode_horizon"):
        EngineConfig(decode_horizon=0)
    with pytest.raises(ValueError, match="horizon"):
        decoder.decode_horizon(params, model.cfg, {}, np.zeros((1, 1)), 0)
