"""Continuous-batching engine: KV-pool invariants, admission control,
FIFO trace completion, and token equivalence against one-shot serving."""
import jax
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.core import masks, memory
from repro.core.policy import RLPolicy
from repro.core.workload import PoissonConfig, poisson_requests
from repro.models import decoder
from repro.runtime import (EngineConfig, EngineRequest, KVPool, PagedExecutor,
                           PoolExhausted, RAPEngine, RAPServer)


# ------------------------------------------------------------------ KV pool
def test_pool_alloc_free_occupancy_invariants():
    pool = KVPool(1000, page_bytes=100)           # 10 pages
    a = pool.alloc("r1", 250)                     # 3 pages (ceil)
    assert len(a.pages) == 3 and pool.free_pages == 7
    assert pool.bytes_in_use == 250 and pool.bytes_reserved == 300
    frag = pool.stats()["fragmentation"]
    assert 0.0 < frag < 1.0                       # 50B of internal frag
    pool.alloc("r2", 700)                         # 7 pages → pool full
    assert pool.free_pages == 0 and not pool.can_alloc(1)
    with pytest.raises(PoolExhausted):
        pool.alloc("r3", 1)
    with pytest.raises(ValueError):               # double alloc is a bug
        pool.alloc("r1", 10)
    pool.free("r1")
    assert pool.free_pages == 3 and pool.can_alloc(300)
    pool.free("r2")
    st = pool.stats()
    assert pool.free_pages == 10
    assert st["reserved_bytes"] == 0 and st["in_use_bytes"] == 0
    assert st["peak_reserved_bytes"] == 1000      # never exceeded capacity
    assert st["peak_in_use_bytes"] == 950
    assert st["peak_reserved_bytes"] <= st["capacity_bytes"]


def test_pool_overcommit_is_tracked_not_silent():
    pool = KVPool(200, page_bytes=100)
    pool.alloc("a", 150)
    with pytest.raises(PoolExhausted):
        pool.alloc("b", 150)
    pool.alloc("b", 150, allow_overcommit=True)
    assert pool.stats()["overcommit_events"] == 1
    pool.free("b")
    pool.free("a")
    assert pool.free_pages == 2                   # overflow pages evaporate


def test_pool_partial_tail_page_unusable():
    pool = KVPool(250, page_bytes=100)            # 2 whole pages only
    assert pool.n_pages == 2
    assert not pool.fits_capacity(201)
    assert pool.fits_capacity(200)


def test_pool_free_unknown_rid_and_idempotent():
    """free() of an unknown rid names the rid and the live set (a bare
    KeyError used to escape); missing_ok=True makes the cancel path
    idempotent without corrupting the free list."""
    pool = KVPool(1000, page_bytes=100)
    pool.alloc("alive", 150)
    with pytest.raises(ValueError, match=r"ghost.*alive"):
        pool.free("ghost")
    assert pool.free("ghost", missing_ok=True) == 0.0
    pool.free("alive")
    assert pool.free("alive", missing_ok=True) == 0.0   # double free is safe
    assert pool.free_pages == 10
    st = pool.stats()
    assert st["reserved_bytes"] == 0 and st["in_use_bytes"] == 0


def test_pool_overflow_pages_never_backfilled():
    """Pins the overcommit contract: synthesized overflow pages are
    bookkeeping fictions — a later free() of a DIFFERENT request returns
    its real pages to the free list but cannot backfill the overflowed
    allocation, which stays over-budget until itself freed."""
    pool = KVPool(300, page_bytes=100)            # 3 real pages
    pool.alloc("a", 200)                          # 2 real pages
    over = pool.alloc("b", 300, allow_overcommit=True)  # 1 real + 2 overflow
    assert sum(1 for p in over.pages if p >= pool.n_pages) == 2
    assert pool.stats()["overcommit_events"] == 1
    before = tuple(pool._live["b"].pages)
    pool.free("a")                                # real pages come back...
    assert pool.free_pages == 2
    assert tuple(pool._live["b"].pages) == before  # ...but b keeps overflow
    assert pool.bytes_reserved == 300              # still charged page-full
    pool.free("b")
    assert pool.free_pages == 3                    # overflow ids evaporated
    assert pool.bytes_reserved == 0


# -------------------------------------------------------- token allocations
def test_pool_token_alloc_extend_free():
    """The physically paged contract: admission commits worst-case pages,
    extend() grants a page only on boundary crossings, and within the
    commitment a strict-mode extend can never fail."""
    pool = KVPool(8 * 64, page_bytes=64, tokens_per_page=4)   # 8 pages
    a = pool.alloc_tokens("r1", 1, 6, max_tokens=12,
                          in_use_bytes=60.0, in_use_per_token=10.0)
    assert a.held_pages == 2 and a.committed_pages == 3       # ceil(12/4)
    assert pool.free_pages == 6 and pool.committed_pages == 1
    assert pool.bytes_reserved == 2 * 64 and pool.bytes_in_use == 60.0
    # tokens 7, 8 fill page 2; token 9 crosses into a fresh page
    assert pool.extend("r1") == [[]]
    assert pool.extend("r1") == [[]]
    grants = pool.extend("r1")
    assert len(grants[0]) == 1 and pool.committed_pages == 0
    assert pool.bytes_reserved == 3 * 64
    assert pool.bytes_in_use == pytest.approx(90.0)
    pool.extend("r1", 3)                                      # up to 12
    with pytest.raises(ValueError, match="commitment"):
        pool.extend("r1")                                     # 13 > 12
    assert pool.free("r1") == 3 * 64
    assert pool.free_pages == 8 and pool.committed_pages == 0
    st = pool.stats()
    assert st["reserved_bytes"] == 0 and st["in_use_bytes"] == 0


def test_pool_token_commitments_gate_admission():
    """can_alloc_tokens discounts OUTSTANDING commitments, not just free
    pages — otherwise a mid-decode extend could find the free list empty
    and deadlock the engine."""
    pool = KVPool(6 * 64, page_bytes=64, tokens_per_page=4)   # 6 pages
    pool.alloc_tokens("a", 1, 4, max_tokens=16)   # holds 1, commits 4
    assert pool.free_pages == 5
    assert pool.can_alloc_tokens(1, 8)            # 2 ≤ 5 − 3
    assert not pool.can_alloc_tokens(1, 12)       # 3 > 5 − 3
    with pytest.raises(PoolExhausted, match="commit"):
        pool.alloc_tokens("b", 1, 4, max_tokens=12)
    pool.alloc_tokens("b", 1, 4, max_tokens=8)
    # a's committed extends succeed even while b holds pages
    for _ in range(12):
        pool.extend("a")
    assert pool.free_pages == 1
    # b still has one committed page outstanding → a 2-row request that
    # would need both remaining pages is not admissible
    assert not pool.can_alloc_tokens(2, 2)
    pool.free("a")
    pool.free("b")
    assert pool.free_pages == 6
    multi = pool.alloc_tokens("c", 2, 6, max_tokens=8)
    assert [len(r) for r in multi.rows] == [2, 2]   # per-row page lists
    assert pool.extend("c", 2) == [[], []]          # 6→8 fills page 2 exactly
    pool.free("c")
    assert sorted(pool._free) == list(range(6))     # no leaks


# ----------------------------------------------- memory-model pool plumbing
def test_block_bytes_seq_zero_guard():
    cfg = get_smoke_config("recurrentgemma-9b")   # has fixed (seq-indep) state
    mm = memory.build_memory_model(cfg)
    L = mm.n_layers
    bb = mm.block_bytes(2, 0)
    # per-token term vanishes at seq=0; seq-independent recurrent/window
    # state is still charged per batch element
    np.testing.assert_allclose(
        bb[:L], mm.mixer_param_bytes + mm.mixer_state_fixed * 2)
    np.testing.assert_array_equal(bb, mm.block_bytes(2, -5))  # clamped
    full = masks.full_mask(L)
    assert mm.state_bytes(full, 2, 0) == pytest.approx(
        2 * float(np.sum(mm.mixer_state_fixed)))
    assert mm.state_bytes(full, 2, -3) == mm.state_bytes(full, 2, 0)


def test_pool_accounting_ledger():
    acct = memory.PoolAccounting(capacity_bytes=100.0)
    acct.reserve(60.0, 50.0)
    assert acct.available_bytes == 40.0
    assert acct.fragmentation() == pytest.approx(1 / 6)
    with pytest.raises(memory.PoolExhausted):
        acct.reserve(50.0, 50.0)
    acct.reserve(50.0, 50.0, allow_overcommit=True)
    assert acct.overcommit_events == 1
    acct.release(50.0, 50.0)
    acct.release(60.0, 50.0)
    assert acct.reserved_bytes == 0 and acct.in_use_bytes == 0
    assert acct.peak_reserved_bytes == 110.0


def test_pool_accounting_in_use_scale_reports_physical_bytes():
    """Mixed-precision accounting: with ``in_use_scale=0.25`` (int8 pages
    under an fp32 model) analytical charges land at quarter width through
    reserve/grow/release, so ``pool_peak_mb``/``pool_frag`` report TRUE
    bytes and fragmentation cannot go negative."""
    acct = memory.PoolAccounting(capacity_bytes=1000.0, in_use_scale=0.25)
    acct.reserve(400.0, 400.0)            # analytical 400B → physical 100B
    assert acct.in_use_bytes == pytest.approx(100.0)
    assert acct.peak_in_use_bytes == pytest.approx(100.0)
    acct.grow(0.0, 200.0)                 # append charges scale too
    assert acct.in_use_bytes == pytest.approx(150.0)
    assert acct.fragmentation() == pytest.approx(1.0 - 150.0 / 400.0)
    assert acct.fragmentation() >= 0.0    # unscaled would report -0.5
    acct.release(400.0, 600.0)
    assert acct.in_use_bytes == pytest.approx(0.0)
    assert acct.reserved_bytes == pytest.approx(0.0)
    # default pools are unscaled: analytical bytes pass through unchanged
    plain = memory.PoolAccounting(capacity_bytes=1000.0)
    plain.reserve(400.0, 300.0)
    assert plain.in_use_bytes == pytest.approx(300.0)


def test_pool_rejects_mismatched_kv_dtype():
    """A request whose Decision.kv_dtype disagrees with the pool's
    allocated precision fails loudly at admission, naming both dtypes —
    never silently writing mis-scaled pages."""
    import jax.numpy as jnp
    pool = KVPool(8 * 64, page_bytes=64, tokens_per_page=4)
    pool.allocate_physical(n_layers=1, n_kv_heads=2, head_dim=4,
                           dtype=jnp.float32, kv_dtype="int8")
    with pytest.raises(ValueError, match=r"'fp32'.*'int8'"):
        pool.alloc_tokens("r0", 1, 4, max_tokens=8, kv_dtype="fp32")
    assert "r0" not in pool._tok          # rejected before taking pages
    # a matching ask and a None ask (pool-native precision) both pass
    pool.alloc_tokens("r1", 1, 4, max_tokens=8, kv_dtype="int8")
    pool.alloc_tokens("r2", 1, 4, max_tokens=8)
    pool.free("r1")
    pool.free("r2")
    assert pool.bytes_reserved == 0


# ------------------------------------------------------------------- engine
# `served` (tiny model + memory model + random-Q controller) comes from
# tests/conftest.py — shared with the horizon and executor suites.


def _engine(model, params, c, mm, *, mode="masked", budget, max_new=4,
            slots=4, max_len=32, admission="strict", scheduler=None):
    return RAPEngine(model, params, RLPolicy(c), EngineConfig(
        mode=mode, max_new_tokens=max_new, max_active=slots, max_len=max_len,
        budget_bytes=budget, admission=admission), scheduler=scheduler)


def _reqs(prompts, rate=1000.0, seed=0):
    rng = np.random.default_rng(seed)
    t, out = 0.0, []
    for i, p in enumerate(prompts):
        t += float(rng.exponential(1.0 / rate))
        out.append(EngineRequest(rid=f"r{i}", prompt=np.asarray(p, np.int32),
                                 arrival_t=t))
    return out


def test_engine_single_request_matches_reference_decode(served):
    """Engine greedy tokens == a raw prefill/decode_step greedy rollout."""
    model, params, batch, mm, c = served
    cfg = model.cfg
    prompt = np.asarray(batch["tokens"])[:1, :16]
    total = 16 + 4
    state = mm.state_bytes(masks.full_mask(cfg.n_layers), 1, total)
    budget = mm.param_bytes(masks.full_mask(cfg.n_layers)) + 4 * state
    eng = _engine(model, params, c, mm, budget=budget)
    rep = eng.run(_reqs([prompt]))
    r = rep.results[0]
    assert r.status == "done" and r.fits
    assert bool(r.mask.all())                     # budget was generous

    import jax.numpy as jnp
    tokens = jnp.asarray(prompt, jnp.int32)
    logits, cache = decoder.prefill(params, cfg, tokens, total)
    ref = [np.asarray(jnp.argmax(logits, -1))[:, None]]
    tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    for _ in range(3):
        lg, cache = decoder.decode_step(params, cfg, cache, tok)
        tok = jnp.argmax(lg[:, -1], -1)[:, None].astype(jnp.int32)
        ref.append(np.asarray(tok))
    np.testing.assert_array_equal(r.tokens, np.concatenate(ref, axis=1))


def test_engine_matches_oneshot_server(served):
    """Shared-pool engine == force-mode RAPServer wrapper, token for token."""
    model, params, batch, mm, c = served
    cfg = model.cfg
    prompt = np.asarray(batch["tokens"])[:1, :16]
    full = masks.full_mask(cfg.n_layers)
    budget = mm.param_bytes(full) + 4 * mm.state_bytes(full, 1, 20)
    srv = RAPServer(model, params, RLPolicy(c), mode="masked",
                    max_new_tokens=4)
    sres = srv.serve(prompt, budget)
    eng = _engine(model, params, c, mm, budget=budget)
    rep = eng.run(_reqs([prompt]))
    r = rep.results[0]
    np.testing.assert_array_equal(r.tokens, sres.tokens)
    np.testing.assert_array_equal(r.mask, sres.mask)


def test_engine_masked_structural_equivalent_under_pruning(served):
    """A budget that forces pruning: both modes pick the same mask and emit
    identical greedy tokens from the slot-batched decode paths."""
    model, params, batch, mm, c = served
    cfg = model.cfg
    prompt = np.asarray(batch["tokens"])[:1, :16]
    full = masks.full_mask(cfg.n_layers)
    # below dense peak → controller must prune
    budget = 0.8 * mm.dense_peak(1, 20)
    reps = {}
    for mode in ("masked", "structural"):
        eng = _engine(model, params, c, mm, mode=mode, budget=budget,
                      admission="force")
        reps[mode] = eng.run(_reqs([prompt])).results[0]
    m, s = reps["masked"], reps["structural"]
    assert not m.mask.all()                       # pruning actually happened
    np.testing.assert_array_equal(m.mask, s.mask)
    np.testing.assert_array_equal(m.tokens, s.tokens)
    assert s.bucket != () and m.bucket == ()


def test_engine_fifo_trace_and_budget_invariant(served):
    """≥16-request Poisson trace: FIFO completion, every request served,
    pool bytes never exceed the configured shared budget."""
    model, params, batch, mm, c = served
    cfg = model.cfg
    toks = np.asarray(batch["tokens"])
    full = masks.full_mask(cfg.n_layers)
    prompts = [toks[:1, : (16 if i % 2 else 24)] for i in range(16)]
    total = 24 + 2
    state1 = mm.state_bytes(full, 1, total)
    # pool fits ~2.5 dense requests → admission must queue under load
    budget = mm.param_bytes(full) + 2.5 * state1
    eng = _engine(model, params, c, mm, budget=budget, max_new=2,
                  slots=4, max_len=32)
    reqs = _reqs(prompts, rate=1000.0)
    rep = eng.run(reqs)

    done = [r for r in rep.results if r.status == "done"]
    assert len(done) == 16 and rep.rejected == 0
    # FIFO: completion order == arrival order (equal decode lengths)
    assert [r.rid for r in done] == [q.rid for q in reqs]
    for r in done:
        assert r.admitted_t >= r.arrival_t - 1e-9
        assert r.queue_delay_s >= 0.0
    assert rep.generated_tokens == 16 * 2
    assert rep.tokens_per_s > 0.0
    # the acceptance invariant: in-use ≤ reserved ≤ pool capacity, and
    # capacity + resident params ≤ the configured global budget
    pool = rep.pool
    assert pool["peak_in_use_bytes"] <= pool["peak_reserved_bytes"] + 1e-6
    assert pool["peak_reserved_bytes"] <= pool["capacity_bytes"] + 1e-6
    assert (pool["capacity_bytes"] + eng.resident_param_bytes
            <= budget + 1e-6)
    assert pool["overcommit_events"] == 0
    # pool fully drained after the run
    assert pool["reserved_bytes"] == 0 and pool["in_use_bytes"] == 0


def test_engine_rejects_oversized_request(served):
    model, params, batch, mm, c = served
    cfg = model.cfg
    full = masks.full_mask(cfg.n_layers)
    budget = mm.param_bytes(full) + 4 * mm.state_bytes(full, 1, 64)
    eng = _engine(model, params, c, mm, budget=budget, slots=2, max_len=24)
    toks = np.asarray(batch["tokens"])
    reqs = _reqs([toks[:1, :30], toks[:1, :16]])  # 30+4 > max_len=24
    rep = eng.run(reqs)
    by = {r.rid: r for r in rep.results}
    assert by["r0"].status == "rejected" and "capacity" in by["r0"].reason
    assert by["r1"].status == "done"
    assert rep.rejected == 1


def test_engine_strict_requires_headroom(served):
    """A global budget below resident parameter bytes cannot host a strict
    pool — admission control refuses to start rather than thrash."""
    model, params, batch, mm, c = served
    eng = _engine(model, params, c, mm, budget=1.0)
    with pytest.raises(ValueError):
        eng.run(_reqs([np.asarray(batch["tokens"])[:1, :8]]))


def test_controller_batch_aware_decide_and_memo(served):
    """reserved_bytes shrinks the effective budget; identical effective
    budgets hit the memo table."""
    model, params, batch, mm, c = served
    L = model.cfg.n_layers
    dense = mm.dense_peak(1, 32)
    a = c.decide(1, 32, dense, reserved_bytes=0.35 * dense)
    b = c.decide(1, 32, 0.65 * dense)
    np.testing.assert_array_equal(a.mask, b.mask)
    assert b.cached                       # same (bucket, shape) memo key
    assert b.latency_s < a.latency_s or a.cached
    full_budget = c.decide(1, 32, 2 * dense)
    assert full_budget.mask.sum() >= a.mask.sum()


def test_poisson_trace_deterministic_and_ordered():
    cfg = PoissonConfig(seed=3, n_requests=20, rate=8.0)
    a, b = poisson_requests(cfg), poisson_requests(cfg)
    assert [r.t for r in a] == [r.t for r in b]
    ts = [r.t for r in a]
    assert all(t2 > t1 for t1, t2 in zip(ts, ts[1:]))
    assert all(r.seq_len % cfg.round_len_to == 0 for r in a)
    assert len(a) == 20


# ------------------------------------------------------- serving-API split
def test_old_constructor_raises_migration_hint(served):
    """Pre-split callers passed a RAPController (positionally or via the
    controller= kwarg); both must fail loudly with the wrapping recipe."""
    model, params, batch, mm, c = served
    with pytest.raises(TypeError, match="RLPolicy"):
        RAPEngine(model, params, c, EngineConfig())
    with pytest.raises(TypeError, match="RLPolicy"):
        RAPEngine(model, params, controller=c)
    with pytest.raises(TypeError, match="RLPolicy"):
        RAPServer(model, params, c)
    with pytest.raises(TypeError, match="RLPolicy"):
        RAPServer(model, params, controller=c)


def test_engine_config_validation():
    """Numeric misconfigurations fail at construction with actionable
    messages, not deep inside a serve loop."""
    with pytest.raises(ValueError, match="budget_quantum_frac"):
        EngineConfig(budget_quantum_frac=1.5)
    with pytest.raises(ValueError, match="budget_quantum_frac"):
        EngineConfig(budget_quantum_frac=-0.1)
    with pytest.raises(ValueError, match="max_active"):
        EngineConfig(max_active=0)
    with pytest.raises(ValueError, match="tokens_per_page"):
        EngineConfig(tokens_per_page=0)
    with pytest.raises(ValueError, match="max_len"):
        EngineConfig(max_len=0)
    with pytest.raises(ValueError, match="max_new_tokens"):
        EngineConfig(max_new_tokens=-1)
    with pytest.raises(ValueError, match="budget_bytes"):
        EngineConfig(budget_bytes=-1.0)
    with pytest.raises(ValueError, match="decode_buckets"):
        EngineConfig(decode_buckets=(0, 2))
    with pytest.raises(ValueError, match="len_buckets"):
        EngineConfig(len_buckets="linear")
    with pytest.raises(ValueError, match="preemption_enabled"):
        EngineConfig(preemption_enabled=1)
    with pytest.raises(ValueError, match="spill_headroom_frac"):
        EngineConfig(spill_headroom_frac=1.0)
    with pytest.raises(ValueError, match="spill_headroom_frac"):
        EngineConfig(spill_headroom_frac=-0.1)
    with pytest.raises(ValueError, match="victim_policy"):
        EngineConfig(victim_policy="coinflip")
    EngineConfig(budget_quantum_frac=0.0, max_active=1, tokens_per_page=1,
                 preemption_enabled=False, spill_headroom_frac=0.0,
                 victim_policy="arrival")


def _two_prompts(batch):
    toks = np.asarray(batch["tokens"])
    return toks[:1, :24], toks[:1, :8]   # long, short


def test_scheduler_fifo_vs_sjf_completion_order(served):
    """One slot, long request first: FIFO serves arrival order, SJF runs
    the short job first."""
    model, params, batch, mm, c = served
    cfg = model.cfg
    long_p, short_p = _two_prompts(batch)
    full = masks.full_mask(cfg.n_layers)
    budget = mm.param_bytes(full) + 4 * mm.state_bytes(full, 1, 32)
    orders = {}
    for sched in ("fifo", "sjf"):
        eng = _engine(model, params, c, mm, budget=budget, max_new=2,
                      slots=1, max_len=32, scheduler=sched)
        reqs = [EngineRequest(rid="long", prompt=long_p, arrival_t=0.0),
                EngineRequest(rid="short", prompt=short_p, arrival_t=0.0)]
        rep = eng.run(reqs)
        orders[sched] = [r.rid for r in rep.results if r.status == "done"]
    assert orders["fifo"] == ["long", "short"]
    assert orders["sjf"] == ["short", "long"]


def test_engine_duplicate_rid_rejected_not_crashed(served):
    """Two same-rid requests in one tick: the second is rejected as a
    result, not raised as a ValueError that loses the whole run."""
    model, params, batch, mm, c = served
    toks = np.asarray(batch["tokens"])
    full = masks.full_mask(model.cfg.n_layers)
    budget = mm.param_bytes(full) + 4 * mm.state_bytes(full, 1, 32)
    eng = _engine(model, params, c, mm, budget=budget, max_new=2)
    reqs = [EngineRequest(rid="dup", prompt=toks[:1, :16], arrival_t=0.0),
            EngineRequest(rid="dup", prompt=toks[:1, :16], arrival_t=0.0)]
    rep = eng.run(reqs)
    statuses = sorted(r.status for r in rep.results)
    assert statuses == ["done", "rejected"]
    rej = [r for r in rep.results if r.status == "rejected"][0]
    assert "duplicate" in rej.reason


def test_sjf_cost_scales_with_batch(served):
    """SJF orders by total KV demand (batch × tokens), not per-row prompt
    length: a 2-row short request is a LARGER job than a 1-row longer
    one."""
    model, params, batch, mm, c = served
    toks = np.asarray(batch["tokens"])
    full = masks.full_mask(model.cfg.n_layers)
    budget = mm.param_bytes(full) + 6 * mm.state_bytes(full, 1, 32)
    eng = _engine(model, params, c, mm, budget=budget, max_new=2,
                  slots=2, max_len=32, scheduler="sjf")
    reqs = [EngineRequest(rid="wide", prompt=toks[:2, :16], arrival_t=0.0),
            EngineRequest(rid="narrow", prompt=toks[:1, :24],
                          arrival_t=0.0)]
    rep = eng.run(reqs)
    # narrow: 1×26 tokens < wide: 2×18 tokens → narrow first
    assert [r.rid for r in rep.results if r.status == "done"] == \
        ["narrow", "wide"]


def test_scheduler_priority_overrides_arrival(served):
    model, params, batch, mm, c = served
    cfg = model.cfg
    long_p, short_p = _two_prompts(batch)
    full = masks.full_mask(cfg.n_layers)
    budget = mm.param_bytes(full) + 4 * mm.state_bytes(full, 1, 32)
    eng = _engine(model, params, c, mm, budget=budget, max_new=2,
                  slots=1, max_len=32, scheduler="priority")
    reqs = [EngineRequest(rid="steerage", prompt=short_p, arrival_t=0.0,
                          priority=5),
            EngineRequest(rid="vip", prompt=long_p, arrival_t=0.0,
                          priority=-1)]
    rep = eng.run(reqs)
    assert [r.rid for r in rep.results if r.status == "done"] == \
        ["vip", "steerage"]


def test_priority_scheduler_aging_prevents_starvation():
    """Aging bounds starvation: a low-priority request behind a steady
    high-priority stream sorts ahead once it has waited
    ``aging_s × Δpriority`` seconds — instead of being deferred forever.
    Pure scheduler-level pin (no engine) so the ordering math is exact."""
    from repro.runtime import PriorityScheduler

    sched = PriorityScheduler(aging_s=1.0)
    low = EngineRequest(rid="low", prompt=np.zeros((1, 4), np.int32),
                        arrival_t=0.0, priority=5)
    sched.add(low, cost=8.0)
    # steady stream: one fresh high-priority arrival per second, and the
    # head of each tick's plan is admitted (removed) — the scenario that
    # starves `low` forever without aging
    admitted = []
    for t in range(10):
        sched.add(EngineRequest(rid=f"hi{t}",
                                prompt=np.zeros((1, 4), np.int32),
                                arrival_t=float(t), priority=0), cost=8.0)
        head = sched.schedule(float(t)).admit[0]
        admitted.append(head.rid)
        sched.remove(head.rid)
    # the stream wins while effective(low) = 5 - t exceeds a fresh hi's 0
    assert admitted[:5] == [f"hi{t}" for t in range(5)]
    # ...then low overtakes, exactly at aging_s × Δpriority = 5 s
    assert admitted[5] == "low"
    # aging disabled → starvation returns, no matter how long it waits
    frozen = PriorityScheduler(aging_s=float("inf"))
    frozen.add(low, cost=8.0)
    frozen.add(EngineRequest(rid="hi", prompt=np.zeros((1, 4), np.int32),
                             arrival_t=1e6, priority=0), cost=8.0)
    assert frozen.schedule(1e9).admit[0].rid == "hi"
    # validation: aging_s must be a positive duration
    for bad in (0.0, -1.0):
        with pytest.raises(ValueError, match="aging_s"):
            PriorityScheduler(aging_s=bad)


def test_decode_buckets_token_equivalent(served):
    """Dynamic decode-batch buckets must not change greedy tokens."""
    model, params, batch, mm, c = served
    cfg = model.cfg
    toks = np.asarray(batch["tokens"])
    full = masks.full_mask(cfg.n_layers)
    budget = mm.param_bytes(full) + 6 * mm.state_bytes(full, 1, 32)
    prompts = [toks[:1, :16], toks[:1, :24], toks[:1, :16]]
    outs = {}
    for buckets in ((1, 2, 4, 8), ()):
        eng = RAPEngine(model, params, RLPolicy(c), EngineConfig(
            mode="masked", max_new_tokens=4, max_active=8, max_len=32,
            budget_bytes=budget, decode_buckets=buckets))
        rep = eng.run(_reqs(prompts))
        outs[buckets] = {r.rid: r.tokens for r in rep.results}
    for rid, t in outs[(1, 2, 4, 8)].items():
        np.testing.assert_array_equal(t, outs[()][rid])


def test_server_pow2_len_buckets_fix_recompile_trap(served):
    """A long serve mints its own long-cache group; re-serving the short
    shape afterwards hits the already-compiled short group (the historical
    shim dropped every group on max_len growth)."""
    model, params, batch, mm, c = served
    toks = np.asarray(batch["tokens"])
    srv = RAPServer(model, params, RLPolicy(c), mode="masked",
                    max_new_tokens=2)
    full = masks.full_mask(model.cfg.n_layers)
    budget = mm.param_bytes(full) + 4 * mm.state_bytes(full, 1, 64)
    r1 = srv.serve(toks[:1, :8], budget)      # short → 16-token bucket
    assert r1.compiled_new
    r2 = srv.serve(toks[:1, :30], budget)     # long → 32-token bucket
    assert r2.compiled_new
    r3 = srv.serve(toks[:1, :8], budget)      # short again: no recompile
    assert not r3.compiled_new
    np.testing.assert_array_equal(r1.tokens, r3.tokens)


# ------------------------------------------------------------ paged executor
def _paged_engine(model, params, c, mm, *, budget, max_new=2, slots=4,
                  max_len=32, tokens_per_page=8, scheduler=None):
    ex = PagedExecutor(model, params, max_active=slots)
    return RAPEngine(model, params, RLPolicy(c), EngineConfig(
        mode="masked", max_new_tokens=max_new, max_active=slots,
        max_len=max_len, budget_bytes=budget,
        tokens_per_page=tokens_per_page), scheduler=scheduler, executor=ex)


# NOTE: the paged-vs-local token-equivalence acceptance test moved into
# the cross-executor conformance suite (tests/test_executors.py), which
# runs EVERY backend — local, paged, sharded — through the same trace.


def test_engine_paged_mixed_lengths_one_group(served):
    """Heterogeneous cache lengths share ONE paged group (the pow2
    cache-length machinery is gone on this path) and heterogeneous
    per-slot masks decode together."""
    model, params, batch, mm, c = served
    toks = np.asarray(batch["tokens"])
    full = masks.full_mask(model.cfg.n_layers)
    budget = mm.param_bytes(full) + 3 * mm.state_bytes(full, 1, 30)
    eng = _paged_engine(model, params, c, mm, budget=budget, max_new=4,
                        slots=4, max_len=32, tokens_per_page=4)
    prompts = [toks[:1, :8], toks[:1, :24], toks[:1, :16]]
    rep = eng.run(_reqs(prompts))
    assert all(r.status == "done" for r in rep.results)
    assert eng.executor.stats()["groups"] == 1
    # every request decoded against its own page-table row: cross-check
    # token equality against the local reference path
    ref = _engine(model, params, c, mm, budget=budget, max_new=4,
                  slots=4, max_len=32)
    rep_ref = ref.run(_reqs(prompts))
    for r in rep_ref.results:
        np.testing.assert_array_equal(
            r.tokens, next(p.tokens for p in rep.results if p.rid == r.rid))


def test_engine_paged_queues_under_page_pressure(served):
    """A pool sized below the trace's concurrent demand must queue (defer)
    paged admissions — commitments, not optimism — and still finish."""
    model, params, batch, mm, c = served
    toks = np.asarray(batch["tokens"])
    full = masks.full_mask(model.cfg.n_layers)
    # room for roughly one dense request's page commitment at a time
    # (a 26-token request commits ceil(26/8)=4 pages; 1.7 × analytical
    # bytes quantizes to 5 physical pages)
    budget = mm.param_bytes(full) + 1.7 * mm.state_bytes(full, 1, 26)
    eng = _paged_engine(model, params, c, mm, budget=budget, max_new=2,
                        slots=4, max_len=32)
    prompts = [toks[:1, :24] for _ in range(4)]
    rep = eng.run(_reqs(prompts))
    assert all(r.status == "done" for r in rep.results)
    assert rep.pool["overcommit_events"] == 0
    assert rep.pool["peak_reserved_bytes"] <= rep.pool["capacity_bytes"] + 1e-6
    # with ~1 request of headroom, later arrivals must have waited
    assert max(r.queue_delay_s for r in rep.results) > 0.0


def test_paged_executor_validation(served):
    """Misconfigurations fail loudly at construction, not mid-serve."""
    model, params, batch, mm, c = served
    # structural paged buckets are now a supported mode (DESIGN.md §9);
    # unknown modes still fail loudly at construction
    with pytest.raises(ValueError, match="mode"):
        PagedExecutor(model, params, mode="gated")
    # int8 paged pools are now a supported precision: the executor
    # resolves the canonical name and allocates quantized pages + scales
    import jax.numpy as jnp
    ex8 = PagedExecutor(model, params, kv_dtype=jnp.int8)
    assert ex8.kv_dtype_name == "int8" and ex8.kv_quantized
    with pytest.raises(ValueError, match="kv_dtype"):
        PagedExecutor(model, params, kv_dtype="int4")
    ex = PagedExecutor(model, params)
    with pytest.raises(ValueError, match="masked"):
        RAPEngine(model, params, RLPolicy(c),
                  EngineConfig(mode="structural"), executor=ex)
    with pytest.raises(ValueError, match="strict"):
        RAPEngine(model, params, RLPolicy(c),
                  EngineConfig(admission="force"), executor=ex)
    with pytest.raises(RuntimeError, match="bind_pool"):
        ex.group_for(masks.full_mask(model.cfg.n_layers), 32)


def test_sharded_executor_places_params_and_serves(served):
    """Single-device smoke of the sharded serve path (the mesh-sharded
    variants run in the multi-device CI job — tests/test_executors.py):
    params placed under the production rules, a degenerate (1, 1) mesh
    serves a trace bitwise-identical to LocalExecutor, and the
    still-unimplemented corners point at the ROADMAP."""
    import jax
    from repro.launch.mesh import make_host_mesh
    from repro.runtime import ShardedExecutor

    model, params, batch, mm, c = served
    mesh = make_host_mesh((1, 1), ("data", "model"))
    ex = ShardedExecutor(model, mesh, params=params)
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(ex.params)):
        assert a.shape == b.shape
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert ex.groups() == []

    toks = np.asarray(batch["tokens"])
    full = masks.full_mask(model.cfg.n_layers)
    budget = mm.param_bytes(full) + 4 * mm.state_bytes(full, 1, 32)
    prompts = [toks[:1, :16], toks[:1, :24]]
    rep_l = _engine(model, params, c, mm, budget=budget,
                    max_new=2).run(_reqs(prompts))
    eng = RAPEngine(model, params, RLPolicy(c), EngineConfig(
        mode="masked", max_new_tokens=2, max_active=4, max_len=32,
        budget_bytes=budget),
        executor=ShardedExecutor(model, mesh, params=params, max_active=4))
    rep_s = eng.run(_reqs(prompts))
    for r in rep_l.results:
        s = next(x for x in rep_s.results if x.rid == r.rid)
        assert r.status == s.status == "done"
        np.testing.assert_array_equal(r.tokens, s.tokens)
    assert eng.stats()["mesh_devices"] == 1

    # unimplemented corners fail loudly with the ROADMAP pointer
    with pytest.raises(NotImplementedError, match="ROADMAP"):
        ShardedExecutor(model, mesh, params=params, mode="structural")
    with pytest.raises(RuntimeError, match="params"):
        ShardedExecutor(model, mesh).group_for(full, 32)


# ------------------------------------- elastic budgets / spill / cancel
# (DESIGN.md §11). Budget shocks in tests are TICK-counting staircases
# (repro.runtime.scenarios.TickStaircase): the engine evaluates callable
# traces once per tick, so the shock hits after a deterministic number of
# ticks regardless of how long a tick takes on the host running the test.


def _shock_engine(served, *, kind="paged", max_new=6, horizon=2, chunk=0,
                  scheduler=None, victim_policy="scheduler",
                  preemption_enabled=True):
    model, params, batch, mm, c = served
    full = masks.full_mask(model.cfg.n_layers)
    budget = mm.param_bytes(full) + 2.5 * mm.state_bytes(full, 1, 30)
    ex = (PagedExecutor(model, params, max_active=4) if kind == "paged"
          else None)
    eng = RAPEngine(model, params, RLPolicy(c), EngineConfig(
        mode="masked", max_new_tokens=max_new, max_active=4, max_len=32,
        budget_bytes=budget, tokens_per_page=8, decode_horizon=horizon,
        max_prefill_tokens=chunk, victim_policy=victim_policy,
        preemption_enabled=preemption_enabled),
        executor=ex, scheduler=scheduler)
    toks = np.asarray(batch["tokens"])
    prompts = [toks[:1, : (16 if i % 2 else 24)] for i in range(6)]
    return eng, _reqs(prompts), budget


def _kv_staircase(eng, budget, down, up, frac=0.5):
    """Tick staircase cutting FRAC of the KV headroom (params stay
    resident; cutting the total would zero the pool at smoke scale)."""
    from repro.runtime import TickStaircase
    kv = budget - eng.resident_param_bytes
    shocked = (eng.resident_param_bytes + (1.0 - frac) * kv) / budget
    return TickStaircase(budget, [(down, 1.0), (up - down, shocked),
                                  (0, 1.0)])


def test_select_victims_priority_and_aging():
    """SLO-tier victim order: lowest effective priority (largest numeric
    rank, aged by waiting time) first, most-remaining-work tiebreak, then
    newest arrival — and the base scheduler (no priority notion) falls
    through to the tiebreaks."""
    from repro.runtime import FIFOScheduler, PriorityScheduler
    from repro.runtime.scheduler import VictimCandidate

    def cand(rid, prio, arr, rem):
        return VictimCandidate(rid=rid, priority=prio, arrival_t=arr,
                               remaining_tokens=rem, reserved_bytes=100.0)

    pr = PriorityScheduler(aging_s=10.0)
    # low tier (rank 2) yields before high tier (rank 0)
    order = pr.select_victims([cand("hi", 0, 0.0, 4),
                               cand("lo", 2, 0.0, 4)], now=1.0)
    assert [c.rid for c in order] == ["lo", "hi"]
    # aging: a low-tier request that waited 3 levels' worth outranks a
    # fresh mid-tier one (preempted later), same contract admission has
    order = pr.select_victims([cand("old-lo", 2, 0.0, 4),
                               cand("new-mid", 1, 29.0, 4)], now=30.0)
    assert [c.rid for c in order] == ["new-mid", "old-lo"]
    # ties: most remaining work yields first, then newest arrival
    fifo = FIFOScheduler()
    order = fifo.select_victims([cand("short", 0, 0.0, 1),
                                 cand("long", 0, 0.0, 9)], now=0.0)
    assert [c.rid for c in order] == ["long", "short"]
    order = fifo.select_victims([cand("early", 0, 0.0, 4),
                                 cand("late", 0, 5.0, 4)], now=9.0)
    assert [c.rid for c in order] == ["late", "early"]


def test_engine_preempts_and_drains_under_shock(served):
    """A mid-serve KV-budget cut preempts victims (pages spilled to host)
    and the run still completes every request, token-identical to an
    unshocked run; the pool ends fully drained and the report carries the
    preemption accounting."""
    eng, reqs, budget = _shock_engine(served)
    ref = eng.run(reqs)
    assert all(r.status == "done" for r in ref.results)
    eng2, reqs2, _ = _shock_engine(served)
    rep = eng2.run(reqs2, budget_trace=_kv_staircase(eng2, budget, 4, 12,
                                                     frac=0.6))
    assert rep.preempted_count > 0 and rep.spilled_mb > 0.0
    assert rep.resume_latency["count"] >= 1
    assert len(rep.budget_events) >= 3       # full → shocked → recovered
    done = {r.rid: r for r in rep.results if r.status == "done"}
    assert len(done) == len(reqs2)
    for r in ref.results:
        np.testing.assert_array_equal(r.tokens, done[r.rid].tokens)
    st = eng2.pool.stats()
    assert st["live_requests"] == 0 and st["spilled_requests"] == 0
    assert st["free_pages"] == st["n_pages"]
    # preempted requests' ITL pooled separately from untouched ones
    assert rep.itl_preempted["count"] > 0
    assert rep.itl["count"] > 0


def test_engine_preemption_disabled_still_gates_admission(served):
    """preemption_enabled=False: a shock never evicts running requests
    (preempted_count == 0) but the shrunken budget still defers NEW
    admissions; the run drains once the budget recovers."""
    eng, reqs, budget = _shock_engine(served, preemption_enabled=False)
    rep = eng.run(reqs, budget_trace=_kv_staircase(eng, budget, 4, 12,
                                                   frac=0.6))
    assert rep.preempted_count == 0
    assert all(r.status == "done" for r in rep.results)


def test_engine_force_resume_drains_without_recovery(served):
    """A trace that never recovers must not deadlock: the idle-engine
    backstop force-resumes preempted requests (physical capacity checks
    only) and the run drains."""
    from repro.runtime import TickStaircase
    eng, reqs, budget = _shock_engine(served)
    kv = budget - eng.resident_param_bytes
    never_up = TickStaircase(budget, [
        (4, 1.0), (0, (eng.resident_param_bytes + 0.3 * kv) / budget)])
    rep = eng.run(reqs, budget_trace=never_up)
    assert rep.preempted_count > 0
    # every ADMITTED request drains to completion (force-resumed victims
    # included); requests the shocked budget can never admit are rejected
    # loudly rather than spun on forever
    by_status = {}
    for r in rep.results:
        by_status.setdefault(r.status, []).append(r)
    assert by_status.get("done"), "nothing drained"
    assert set(by_status) <= {"done", "rejected"}
    for r in by_status.get("rejected", []):
        assert "budget" in (r.reason or "") or "deferred" in (r.reason or "")
    st = eng.pool.stats()
    assert st["live_requests"] == 0 and st["spilled_requests"] == 0


def test_engine_cancel_every_lifecycle_stage(served):
    """cancel(rid) is safe at every stage: pending (not yet arrived),
    queued, prefilling, decoding mid-horizon, and preempted — plus
    double-cancel and unknown-rid no-ops. Pool drains to zero live rids
    and zero leaked pages."""
    model, params, batch, mm, c = served
    full = masks.full_mask(model.cfg.n_layers)
    toks = np.asarray(batch["tokens"])
    budget = mm.param_bytes(full) + 2.0 * mm.state_bytes(full, 1, 30)
    eng = RAPEngine(model, params, RLPolicy(c), EngineConfig(
        mode="masked", max_new_tokens=8, max_active=2, max_len=32,
        budget_bytes=budget, tokens_per_page=8, decode_horizon=2,
        max_prefill_tokens=8),
        executor=PagedExecutor(model, params, max_active=2))
    # r5 arrives far in the future → stays pending; 2 slots force a queue
    reqs = [EngineRequest(rid=f"r{i}", prompt=toks[:1, :24],
                          arrival_t=0.001 * i, max_new=8) for i in range(5)]
    reqs.append(EngineRequest(rid="r5", prompt=toks[:1, :16],
                              arrival_t=120.0, max_new=8))
    staircase = _kv_staircase(eng, budget, 6, 10 ** 9, frac=0.7)
    state = {"tick": 0, "hit": set()}

    def on_tick(e):
        state["tick"] += 1
        assert e.cancel("nonexistent") is False
        if "pending" not in state["hit"] and any(
                r.rid == "r5" for r in e._pending):
            assert e.cancel("r5") is True
            assert e.cancel("r5") is False          # double-cancel no-op
            state["hit"].add("pending")
        if "queued" not in state["hit"] and "r4" in e.scheduler:
            assert e.cancel("r4") is True
            state["hit"].add("queued")
        if "prefilling" not in state["hit"] and e._prefilling:
            rid = next(iter(e._prefilling))
            assert e.cancel(rid) is True
            state["hit"].add("prefilling")
        elif "running" not in state["hit"] and e._running:
            rid = next(iter(e._running))
            assert e.cancel(rid) is True            # mid-horizon: scan in
            assert e.cancel(rid) is False           # flight right now
            state["hit"].add("running")
        if "preempted" not in state["hit"] and e._preempted:
            rid = next(iter(e._preempted))
            assert e.cancel(rid) is True
            state["hit"].add("preempted")

    rep = eng.run(reqs, budget_trace=staircase, on_tick=on_tick)
    assert {"pending", "queued", "prefilling", "running",
            "preempted"} <= state["hit"]
    by = {r.rid: r for r in rep.results}
    assert by["r5"].status == "cancelled" and by["r4"].status == "cancelled"
    assert rep.cancelled == sum(1 for r in rep.results
                                if r.status == "cancelled") >= 5
    st = eng.pool.stats()
    assert st["live_requests"] == 0 and st["spilled_requests"] == 0
    assert st["free_pages"] == st["n_pages"]


def test_engine_cancel_races_completion_safely(served):
    """The missing_ok seam from the engine API: cancelling a rid that
    completed earlier in the same run is a no-op (False), and a cancelled
    request's tokens are truncated to what it had generated — fold-back
    never resurrects it."""
    eng, reqs, budget = _shock_engine(served, max_new=4)
    finished = {}
    did_cancel = []

    def on_tick(e):
        for r in e._results:
            if r.status == "done" and r.rid not in finished:
                finished[r.rid] = True
                assert e.cancel(r.rid) is False     # racing a completion
        if finished and not did_cancel and e._running:
            did_cancel.append(True)
            rid = next(iter(e._running))
            run = e._running[rid]
            n_before = len(run.out)
            assert e.cancel(rid) is True
            res = next(x for x in e._results if x.rid == rid)
            n_tokens = 0 if res.tokens is None else res.tokens.shape[1]
            assert n_tokens == n_before < run.max_new

    rep = eng.run(reqs, on_tick=on_tick)
    assert rep.cancelled == 1
    assert sum(1 for r in rep.results if r.status == "done") == len(reqs) - 1
    st = eng.pool.stats()
    assert st["live_requests"] == 0 and st["free_pages"] == st["n_pages"]


def test_engine_cancellation_storm_no_leaks(served):
    """Deterministic tier-1 cancellation storm (the bench hard-gates the
    same invariants): ≥25% of requests cancelled at random lifecycle
    stages under a concurrent budget shock — zero live rids, zero leaked
    pages, zero spilled leftovers, no deadlock."""
    from repro.runtime import run_cancellation_storm
    eng, reqs, budget = _shock_engine(served, max_new=6)
    res = run_cancellation_storm(
        eng, reqs, cancel_frac=0.34, seed=5,
        budget_trace=_kv_staircase(eng, budget, 4, 14, frac=0.6))
    assert res["cancelled"] >= res["cancel_quota"] >= 2
    assert res["live_requests"] == 0
    assert res["leaked_pages"] == 0
    assert res["spilled_requests"] == 0
    assert res["done"] + res["cancelled"] == len(reqs)
    assert not res["deadlock"]


def test_run_exception_releases_pool(served):
    """A run that raises mid-serve releases pages, commitments, spilled
    copies, and seated slots — the next run() on the same engine starts
    from a clean ledger (the cross-run rid-leak fix)."""
    eng, reqs, budget = _shock_engine(served)

    class Boom(RuntimeError):
        pass

    def bomb(e):
        if e._running and e._preempted:
            raise Boom("fault injection")

    with pytest.raises(Boom):
        eng.run(reqs, budget_trace=_kv_staircase(eng, budget, 3, 10 ** 9,
                                                 frac=0.7), on_tick=bomb)
    st = eng.pool.stats()
    assert st["live_requests"] == 0 and st["spilled_requests"] == 0
    assert st["free_pages"] == st["n_pages"]
    assert not eng._running and not eng._preempted and not eng._prefilling
    # the engine is reusable: a fresh run serves normally
    rep = eng.run(reqs)
    assert all(r.status == "done" for r in rep.results)
    st = eng.pool.stats()
    assert st["live_requests"] == 0 and st["free_pages"] == st["n_pages"]


def test_kv_pool_spill_restore_roundtrip_bitwise():
    """Unit-level spill→restore on a physical int8 pool: page contents
    and scale rows written back bitwise into freshly granted pages, the
    free list and commitments restored exactly."""
    import jax.numpy as jnp
    pt, K, D, layers = 2, 2, 4, 2
    page_bytes = 2 * layers * pt * K * D * 1 + 2 * layers * K * 4
    pool = KVPool(8 * page_bytes, page_bytes=page_bytes, tokens_per_page=pt)
    pool.allocate_physical(n_layers=layers, n_kv_heads=K, head_dim=D,
                           dtype=jnp.float32, kv_dtype="int8")
    pool.alloc_tokens("a", 2, 3, max_tokens=6, in_use_bytes=6.0,
                      in_use_per_token=1.0, kv_dtype="int8")
    rows = pool.row_pages("a")
    rng = np.random.default_rng(0)
    ids = [p for row in rows for p in row]
    k_ref = rng.integers(-127, 127, (layers, len(ids), pt, K, D),
                         dtype=np.int8)
    s_ref = rng.uniform(0.1, 2.0, (layers, len(ids), K)).astype(np.float32)
    idx = jnp.asarray(np.asarray(ids, np.int32))
    pool.k_pages = pool.k_pages.at[:, idx].set(jnp.asarray(k_ref))
    pool.v_pages = pool.v_pages.at[:, idx].set(jnp.asarray(k_ref))
    pool.k_scales = pool.k_scales.at[:, idx].set(jnp.asarray(s_ref))
    pool.v_scales = pool.v_scales.at[:, idx].set(jnp.asarray(s_ref))
    reserved_before = pool.bytes_reserved
    freed = pool.spill("a")
    assert freed == reserved_before
    assert pool.bytes_reserved == 0 and pool.committed_pages == 0
    assert sorted(pool._free) == list(range(pool.n_pages))
    assert pool.spilled_requests() == ["a"]
    # clobber the old pages: restore must not depend on them
    pool.k_pages = pool.k_pages.at[:, idx].set(0)
    pool.k_scales = pool.k_scales.at[:, idx].set(0.0)
    # occupy some pages so the restore lands on a DIFFERENT layout
    pool.alloc_tokens("b", 1, 2 * pt, max_tokens=2 * pt,
                      in_use_bytes=1.0, in_use_per_token=0.5,
                      kv_dtype="int8")
    assert pool.can_restore("a")
    new_rows = pool.restore("a")
    assert pool.bytes_reserved == reserved_before + pool.page_bytes * 2
    new_ids = [p for row in new_rows for p in row]
    nidx = jnp.asarray(np.asarray(new_ids, np.int32))
    np.testing.assert_array_equal(np.asarray(pool.k_pages[:, nidx]), k_ref)
    np.testing.assert_array_equal(np.asarray(pool.v_pages[:, nidx]), k_ref)
    np.testing.assert_array_equal(np.asarray(pool.k_scales[:, nidx]), s_ref)
    np.testing.assert_array_equal(np.asarray(pool.v_scales[:, nidx]), s_ref)
    # token extension works after restore exactly as before the spill
    pool.extend("a", 3)
    pool.free("a")
    pool.free("b")
    assert pool.bytes_reserved == 0
    assert sorted(pool._free) == list(range(pool.n_pages))
    # drop_spilled is idempotent like free(missing_ok=True)
    assert pool.drop_spilled("a", missing_ok=True) is False
    with pytest.raises(ValueError, match="drop_spilled"):
        pool.drop_spilled("a")


def test_kv_pool_spill_guards():
    """Spill/restore edge contracts: unknown rids raise with the spilled
    set named, double-spill is impossible (rid leaves the live set), and
    a rid cannot be re-allocated while spilled."""
    pool = KVPool(800, page_bytes=100, tokens_per_page=2)
    pool.alloc_tokens("a", 1, 2, max_tokens=4, in_use_bytes=2.0,
                      in_use_per_token=1.0)
    pool.spill("a")
    with pytest.raises(ValueError, match="spill"):
        pool.spill("a")                    # no longer live
    with pytest.raises(ValueError, match="already"):
        pool.alloc_tokens("a", 1, 2, max_tokens=4, in_use_bytes=2.0,
                          in_use_per_token=1.0)
    with pytest.raises(ValueError, match="restore"):
        pool.restore("zzz")
    pool.restore("a")
    assert pool.spilled_requests() == []
    pool.free("a")
