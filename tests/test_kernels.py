"""Per-kernel shape/dtype sweeps: Pallas (interpret=True) vs ref.py oracles."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from numpy.testing import assert_allclose

from repro.kernels import ops, ref

R = np.random.default_rng(42)


def rnd(*shape, dtype=np.float32, scale=1.0):
    return jnp.asarray(R.standard_normal(shape).astype(dtype) * scale)


FLASH_CASES = [
    # B, Sq, H, K, D, window, softcap, dtype
    (2, 128, 4, 2, 64, 0, 0.0, jnp.float32),
    (1, 100, 4, 1, 64, 0, 0.0, jnp.float32),     # padding path
    (2, 64, 8, 8, 32, 16, 0.0, jnp.float32),     # banded / MHA
    (1, 128, 4, 2, 64, 0, 30.0, jnp.float32),    # softcap
    (1, 96, 6, 3, 128, 0, 0.0, jnp.float32),     # non-pow2 heads
    (2, 64, 4, 2, 64, 0, 0.0, jnp.bfloat16),     # bf16 io
]


@pytest.mark.parametrize("B,Sq,H,K,D,window,cap,dt", FLASH_CASES)
def test_flash_attention(B, Sq, H, K, D, window, cap, dt):
    q, k, v = (rnd(B, Sq, H, D).astype(dt), rnd(B, Sq, K, D).astype(dt),
               rnd(B, Sq, K, D).astype(dt))
    out = ops.flash_attention(q, k, v, causal=True, window=window,
                              softcap=cap, block_q=32, block_k=32)
    want = ref.attention_ref(q, k, v, causal=True, window=window, softcap=cap)
    tol = 2e-5 if dt == jnp.float32 else 2e-2
    assert_allclose(np.asarray(out, np.float32), np.asarray(want, np.float32),
                    atol=tol, rtol=tol)


DECODE_CASES = [
    (2, 8, 2, 64, 256, 100), (1, 4, 4, 32, 130, 130), (2, 8, 1, 128, 512, 1),
    (1, 16, 2, 64, 96, 33),
]


@pytest.mark.parametrize("B,H,K,D,S,nvalid", DECODE_CASES)
def test_decode_attention(B, H, K, D, S, nvalid):
    q, k, v = rnd(B, 1, H, D), rnd(B, S, K, D), rnd(B, S, K, D)
    valid = jnp.arange(S) < nvalid
    out = ops.decode_attention(q, k, v, valid, block_k=64)
    want = ref.decode_attention_ref(q, k, v, valid)
    assert_allclose(np.asarray(out), np.asarray(want), atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("T,F,act,dt", [
    (64, 256, "swiglu", jnp.float32), (100, 128, "geglu", jnp.float32),
    (7, 96, "swiglu", jnp.float32), (64, 256, "swiglu", jnp.bfloat16)])
def test_fused_glu(T, F, act, dt):
    h = rnd(T, 2 * F).astype(dt)
    out = ops.fused_glu(h, act, block_t=32, block_f=64)
    want = ref.glu_ref(h, act)
    tol = 2e-5 if dt == jnp.float32 else 2e-2
    assert_allclose(np.asarray(out, np.float32), np.asarray(want, np.float32),
                    atol=tol, rtol=tol)


@pytest.mark.parametrize("B,T,H,P,N,Q", [
    (1, 64, 2, 16, 16, 16), (2, 100, 4, 32, 64, 32), (1, 48, 3, 16, 32, 16)])
def test_ssd_kernel(B, T, H, P, N, Q):
    xh = rnd(B, T, H, P, scale=0.5)
    log_a = -jnp.abs(rnd(B, T, H, scale=0.1))
    Bm, Cm = rnd(B, T, N, scale=0.3), rnd(B, T, N, scale=0.3)
    y, fin = ops.ssd(xh, log_a, Bm, Cm, chunk=Q)
    yr, finr = ref.ssd_ref(xh, log_a, Bm, Cm)
    assert_allclose(np.asarray(y), np.asarray(yr), atol=3e-4, rtol=3e-4)
    assert_allclose(np.asarray(fin), np.asarray(finr), atol=3e-4, rtol=3e-4)


def test_ssd_kernel_matches_model_scan():
    from repro.models.ssm import _ssd_scan
    xh = rnd(2, 96, 4, 16, scale=0.5)
    log_a = -jnp.abs(rnd(2, 96, 4, scale=0.1))
    Bm, Cm = rnd(2, 96, 32, scale=0.3), rnd(2, 96, 32, scale=0.3)
    y_k, f_k = ops.ssd(xh, log_a, Bm, Cm, chunk=32)
    y_s, f_s = _ssd_scan(xh, log_a, Bm, Cm, 32)
    assert_allclose(np.asarray(y_k), np.asarray(y_s), atol=3e-4, rtol=3e-4)
    assert_allclose(np.asarray(f_k), np.asarray(f_s), atol=3e-4, rtol=3e-4)


@pytest.mark.parametrize("B,T,W,bt", [(2, 64, 128, 16), (1, 100, 64, 32),
                                      (3, 33, 96, 8)])
def test_rglru_kernel(B, T, W, bt):
    a = jnp.exp(-jnp.abs(rnd(B, T, W, scale=0.5)))
    b = rnd(B, T, W, scale=0.5)
    h = ops.rglru(a, b, block_t=bt, block_w=64)
    assert_allclose(np.asarray(h), np.asarray(ref.rglru_ref(a, b)),
                    atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("arch", ["llama2-7b", "gemma-2b", "mamba2-370m",
                                  "recurrentgemma-9b"])
def test_model_pallas_path_matches_xla(arch):
    from repro.configs import get_smoke_config
    from repro.models import registry
    cfg = get_smoke_config(arch)
    model = registry.build(cfg)
    params = model.init(jax.random.key(0))
    batch = {"tokens": jax.random.randint(jax.random.key(1), (2, 32), 0,
                                          cfg.vocab_size)}
    lx = model.logits(params, batch, impl="xla")
    lp = model.logits(params, batch, impl="pallas")
    assert np.abs(np.asarray(lx) - np.asarray(lp)).max() < 5e-4


def test_chunked_attention_matches_plain():
    """The XLA memory-efficient chunked path == plain masked softmax."""
    from repro.configs import get_smoke_config
    from repro.models import attention
    cfg = get_smoke_config("llama2-7b")
    q, k, v = rnd(2, 8192, 4, 16), rnd(2, 8192, 2, 16), rnd(2, 8192, 2, 16)
    out_c = attention._sdpa_chunked(cfg, q, k, v)
    mask = attention._causal_mask(8192, 8192, 0)
    out_p = attention._sdpa(cfg, q, k, v, mask)
    assert_allclose(np.asarray(out_c), np.asarray(out_p), atol=2e-5,
                    rtol=2e-5)


def test_chunked_attention_banded():
    from repro.configs import get_smoke_config
    from repro.models import attention
    cfg = get_smoke_config("recurrentgemma-9b")
    S, w = 8192, 512
    q, k, v = rnd(1, S, 2, 16), rnd(1, S, 1, 16), rnd(1, S, 1, 16)
    out_c = attention._sdpa_chunked(cfg, q, k, v, window=w)
    mask = attention._causal_mask(S, S, w)
    out_p = attention._sdpa(cfg, q, k, v, mask)
    assert_allclose(np.asarray(out_c), np.asarray(out_p), atol=2e-5,
                    rtol=2e-5)
