"""Per-kernel shape/dtype sweeps: Pallas (interpret=True) vs ref.py oracles."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from numpy.testing import assert_allclose

from repro.kernels import ops, ref

# every test here exercises Pallas kernels in interpret mode — the
# `pallas-interpret` CI job runs this module under JAX_PLATFORMS=cpu so
# paged/flash kernel regressions fail without a TPU in the loop
pytestmark = pytest.mark.pallas_interpret

R = np.random.default_rng(42)


def rnd(*shape, dtype=np.float32, scale=1.0):
    return jnp.asarray(R.standard_normal(shape).astype(dtype) * scale)


FLASH_CASES = [
    # B, Sq, H, K, D, window, softcap, dtype
    (2, 128, 4, 2, 64, 0, 0.0, jnp.float32),
    (1, 100, 4, 1, 64, 0, 0.0, jnp.float32),     # padding path
    (2, 64, 8, 8, 32, 16, 0.0, jnp.float32),     # banded / MHA
    (1, 128, 4, 2, 64, 0, 30.0, jnp.float32),    # softcap
    (1, 96, 6, 3, 128, 0, 0.0, jnp.float32),     # non-pow2 heads
    (2, 64, 4, 2, 64, 0, 0.0, jnp.bfloat16),     # bf16 io
]


@pytest.mark.parametrize("B,Sq,H,K,D,window,cap,dt", FLASH_CASES)
def test_flash_attention(B, Sq, H, K, D, window, cap, dt):
    q, k, v = (rnd(B, Sq, H, D).astype(dt), rnd(B, Sq, K, D).astype(dt),
               rnd(B, Sq, K, D).astype(dt))
    out = ops.flash_attention(q, k, v, causal=True, window=window,
                              softcap=cap, block_q=32, block_k=32)
    want = ref.attention_ref(q, k, v, causal=True, window=window, softcap=cap)
    tol = 2e-5 if dt == jnp.float32 else 2e-2
    assert_allclose(np.asarray(out, np.float32), np.asarray(want, np.float32),
                    atol=tol, rtol=tol)


DECODE_CASES = [
    (2, 8, 2, 64, 256, 100), (1, 4, 4, 32, 130, 130), (2, 8, 1, 128, 512, 1),
    (1, 16, 2, 64, 96, 33),
]


@pytest.mark.parametrize("B,H,K,D,S,nvalid", DECODE_CASES)
def test_decode_attention(B, H, K, D, S, nvalid):
    q, k, v = rnd(B, 1, H, D), rnd(B, S, K, D), rnd(B, S, K, D)
    valid = jnp.arange(S) < nvalid
    out = ops.decode_attention(q, k, v, valid, block_k=64)
    want = ref.decode_attention_ref(q, k, v, valid)
    assert_allclose(np.asarray(out), np.asarray(want), atol=2e-5, rtol=2e-5)


PAGED_CASES = [
    # B, H, K, D, page_tokens, max_len, softcap
    (3, 8, 2, 64, 16, 80, 0.0),
    (2, 4, 4, 32, 8, 64, 0.0),       # MHA, small pages
    (1, 16, 2, 64, 32, 96, 0.0),     # wide GQA group
    (2, 6, 3, 16, 16, 48, 30.0),     # non-pow2 heads + softcap
]


@pytest.mark.parametrize("B,H,K,D,pt,S,cap", PAGED_CASES)
def test_paged_decode_matches_dense_bitwise(B, H, K, D, pt, S, cap):
    """Paged kernel == dense decode kernel, BITWISE, on random GQA shapes.

    With ``page_tokens == block_k`` and pages holding the same tokens in
    order, both kernels run the identical f32 online-softmax op sequence —
    page indirection must not change a single ulp. Rows get random lengths
    (ragged batch) and pages are scattered randomly through the pool."""
    rng = np.random.default_rng(B * 1000 + S)
    P = -(-S // pt)                       # pages per row
    n_pages = B * P + 3                   # spare pages stay garbage
    lengths = rng.integers(1, S + 1, size=B).astype(np.int32)
    q = jnp.asarray(rng.standard_normal((B, 1, H, D)).astype(np.float32))
    kd = rng.standard_normal((B, S, K, D)).astype(np.float32)
    vd = rng.standard_normal((B, S, K, D)).astype(np.float32)
    table = rng.permutation(n_pages)[: B * P].reshape(B, P).astype(np.int32)
    k_pages = rng.standard_normal((n_pages, pt, K, D)).astype(np.float32)
    v_pages = rng.standard_normal((n_pages, pt, K, D)).astype(np.float32)
    for b in range(B):
        for p in range(P):
            k_pages[table[b, p]] = kd[b, p * pt:(p + 1) * pt]
            v_pages[table[b, p]] = vd[b, p * pt:(p + 1) * pt]

    out = ops.paged_decode_attention(q, jnp.asarray(k_pages),
                                     jnp.asarray(v_pages),
                                     jnp.asarray(table),
                                     jnp.asarray(lengths), softcap=cap)
    for b in range(B):
        valid = jnp.arange(S) < lengths[b]
        want = ops.decode_attention(q[b:b + 1], jnp.asarray(kd[b:b + 1]),
                                    jnp.asarray(vd[b:b + 1]), valid,
                                    softcap=cap, block_k=pt)
        np.testing.assert_array_equal(np.asarray(out[b]), np.asarray(want[0]))


@pytest.mark.parametrize("B,H,K,D,pt,S,cap", PAGED_CASES)
def test_paged_decode_quantized_matches_dequant_bitwise(B, H, K, D, pt, S,
                                                        cap):
    """Fused-dequant kernel == fp32 kernel on externally dequantized pages,
    BITWISE. The quantized kernel widens each int8 page to f32 and applies
    the per-(page, kv-head) scale BEFORE the shared flash step, so it must
    reproduce the exact op sequence of the fp32 kernel fed
    ``page_dequant``-ed pages — which in turn is bitwise vs the dense
    decode kernel (pinned above). This is the pin that lets the XLA gather
    fallback and the Pallas path share one numeric contract."""
    from repro.models.attention import page_dequant, page_quant
    rng = np.random.default_rng(B * 777 + S)
    P = -(-S // pt)
    n_pages = B * P + 3
    lengths = rng.integers(1, S + 1, size=B).astype(np.int32)
    q = jnp.asarray(rng.standard_normal((B, 1, H, D)).astype(np.float32))
    table = rng.permutation(n_pages)[: B * P].reshape(B, P).astype(np.int32)
    k_pages = rng.standard_normal((n_pages, pt, K, D)).astype(np.float32)
    v_pages = rng.standard_normal((n_pages, pt, K, D)).astype(np.float32)
    kq, ks = page_quant(jnp.asarray(k_pages), jnp.int8)
    vq, vs = page_quant(jnp.asarray(v_pages), jnp.int8)

    out = ops.paged_decode_attention(q, kq, vq, jnp.asarray(table),
                                     jnp.asarray(lengths), softcap=cap,
                                     k_scales=ks, v_scales=vs)
    want = ops.paged_decode_attention(q, page_dequant(kq, ks),
                                      page_dequant(vq, vs),
                                      jnp.asarray(table),
                                      jnp.asarray(lengths), softcap=cap)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(want))


def test_paged_decode_row_isolation():
    """A row's output depends only on ITS pages: rewriting another row's
    pages (and the never-referenced spares) must not change it."""
    rng = np.random.default_rng(7)
    B, H, K, D, pt, S = 2, 4, 2, 32, 8, 32
    P = S // pt
    n_pages = B * P + 2
    lengths = np.asarray([S, S - 3], np.int32)
    q = jnp.asarray(rng.standard_normal((B, 1, H, D)).astype(np.float32))
    table = np.arange(B * P).reshape(B, P).astype(np.int32)
    k_pages = rng.standard_normal((n_pages, pt, K, D)).astype(np.float32)
    v_pages = rng.standard_normal((n_pages, pt, K, D)).astype(np.float32)
    a = ops.paged_decode_attention(q, jnp.asarray(k_pages),
                                   jnp.asarray(v_pages), jnp.asarray(table),
                                   jnp.asarray(lengths))
    k2, v2 = k_pages.copy(), v_pages.copy()
    k2[P:] = rng.standard_normal(k2[P:].shape)  # row 1's + spare pages
    v2[P:] = rng.standard_normal(v2[P:].shape)
    b = ops.paged_decode_attention(q, jnp.asarray(k2), jnp.asarray(v2),
                                   jnp.asarray(table), jnp.asarray(lengths))
    np.testing.assert_array_equal(np.asarray(a[0]), np.asarray(b[0]))
    assert not np.array_equal(np.asarray(a[1]), np.asarray(b[1]))


@pytest.mark.parametrize("T,F,act,dt", [
    (64, 256, "swiglu", jnp.float32), (100, 128, "geglu", jnp.float32),
    (7, 96, "swiglu", jnp.float32), (64, 256, "swiglu", jnp.bfloat16)])
def test_fused_glu(T, F, act, dt):
    h = rnd(T, 2 * F).astype(dt)
    out = ops.fused_glu(h, act, block_t=32, block_f=64)
    want = ref.glu_ref(h, act)
    tol = 2e-5 if dt == jnp.float32 else 2e-2
    assert_allclose(np.asarray(out, np.float32), np.asarray(want, np.float32),
                    atol=tol, rtol=tol)


@pytest.mark.parametrize("B,T,H,P,N,Q", [
    (1, 64, 2, 16, 16, 16), (2, 100, 4, 32, 64, 32), (1, 48, 3, 16, 32, 16)])
def test_ssd_kernel(B, T, H, P, N, Q):
    xh = rnd(B, T, H, P, scale=0.5)
    log_a = -jnp.abs(rnd(B, T, H, scale=0.1))
    Bm, Cm = rnd(B, T, N, scale=0.3), rnd(B, T, N, scale=0.3)
    y, fin = ops.ssd(xh, log_a, Bm, Cm, chunk=Q)
    yr, finr = ref.ssd_ref(xh, log_a, Bm, Cm)
    assert_allclose(np.asarray(y), np.asarray(yr), atol=3e-4, rtol=3e-4)
    assert_allclose(np.asarray(fin), np.asarray(finr), atol=3e-4, rtol=3e-4)


def test_ssd_kernel_matches_model_scan():
    from repro.models.ssm import _ssd_scan
    xh = rnd(2, 96, 4, 16, scale=0.5)
    log_a = -jnp.abs(rnd(2, 96, 4, scale=0.1))
    Bm, Cm = rnd(2, 96, 32, scale=0.3), rnd(2, 96, 32, scale=0.3)
    y_k, f_k = ops.ssd(xh, log_a, Bm, Cm, chunk=32)
    y_s, f_s = _ssd_scan(xh, log_a, Bm, Cm, 32)
    assert_allclose(np.asarray(y_k), np.asarray(y_s), atol=3e-4, rtol=3e-4)
    assert_allclose(np.asarray(f_k), np.asarray(f_s), atol=3e-4, rtol=3e-4)


@pytest.mark.parametrize("B,T,W,bt", [(2, 64, 128, 16), (1, 100, 64, 32),
                                      (3, 33, 96, 8)])
def test_rglru_kernel(B, T, W, bt):
    a = jnp.exp(-jnp.abs(rnd(B, T, W, scale=0.5)))
    b = rnd(B, T, W, scale=0.5)
    h = ops.rglru(a, b, block_t=bt, block_w=64)
    assert_allclose(np.asarray(h), np.asarray(ref.rglru_ref(a, b)),
                    atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("arch", ["llama2-7b", "gemma-2b", "mamba2-370m",
                                  "recurrentgemma-9b"])
def test_model_pallas_path_matches_xla(arch):
    from repro.configs import get_smoke_config
    from repro.models import registry
    cfg = get_smoke_config(arch)
    model = registry.build(cfg)
    params = model.init(jax.random.key(0))
    batch = {"tokens": jax.random.randint(jax.random.key(1), (2, 32), 0,
                                          cfg.vocab_size)}
    lx = model.logits(params, batch, impl="xla")
    lp = model.logits(params, batch, impl="pallas")
    assert np.abs(np.asarray(lx) - np.asarray(lp)).max() < 5e-4


def test_chunked_attention_matches_plain():
    """The XLA memory-efficient chunked path == plain masked softmax."""
    from repro.configs import get_smoke_config
    from repro.models import attention
    cfg = get_smoke_config("llama2-7b")
    q, k, v = rnd(2, 8192, 4, 16), rnd(2, 8192, 2, 16), rnd(2, 8192, 2, 16)
    out_c = attention._sdpa_chunked(cfg, q, k, v)
    mask = attention._causal_mask(8192, 8192, 0)
    out_p = attention._sdpa(cfg, q, k, v, mask)
    assert_allclose(np.asarray(out_c), np.asarray(out_p), atol=2e-5,
                    rtol=2e-5)


def test_chunked_attention_banded():
    from repro.configs import get_smoke_config
    from repro.models import attention
    cfg = get_smoke_config("recurrentgemma-9b")
    S, w = 8192, 512
    q, k, v = rnd(1, S, 2, 16), rnd(1, S, 1, 16), rnd(1, S, 1, 16)
    out_c = attention._sdpa_chunked(cfg, q, k, v, window=w)
    mask = attention._causal_mask(S, S, w)
    out_p = attention._sdpa(cfg, q, k, v, mask)
    assert_allclose(np.asarray(out_c), np.asarray(out_p), atol=2e-5,
                    rtol=2e-5)
