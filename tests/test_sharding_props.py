"""Partition-rule consistency properties (DESIGN.md §7).

Every PartitionSpec the sharding rules emit must *fit*: each sharded dim
divides the product of its mesh axes. `_pick` enforces this inside
`repro.parallel.sharding`, so these hypothesis sweeps over every
registered model config × mesh shape exist to catch a rule that bypasses
the fallback (a hand-written P() on a new param kind, a rank pattern the
rules misread) before it manifests as a GSPMD error mid-serve.

Marked ``multi_device``: the (2, 1)/(1, 2)/(2, 2) meshes need real
devices, which only the multi-device CI job provides
(``XLA_FLAGS=--xla_force_host_platform_device_count=8``).
"""
import pytest

hypothesis = pytest.importorskip("hypothesis")
st = pytest.importorskip("hypothesis.strategies")
from hypothesis import given, settings  # noqa: E402

import jax  # noqa: E402
import numpy as np  # noqa: E402

from repro.configs import all_configs, get_smoke_config  # noqa: E402
from repro.launch.mesh import make_host_mesh  # noqa: E402
from repro.models import registry  # noqa: E402
from repro.parallel import (cache_pspecs, param_pspecs,  # noqa: E402
                            serve_slot_pspec, serve_state_pspecs)

pytestmark = pytest.mark.multi_device

ARCHS = sorted(all_configs())
MESH_SHAPES = [(1, 1), (2, 1), (1, 2), (2, 2)]

_SHAPES_CACHE = {}


def _model_shapes(arch):
    """eval_shape of init params + a decode cache, once per arch."""
    if arch not in _SHAPES_CACHE:
        cfg = get_smoke_config(arch)
        model = registry.build(cfg)
        params = jax.eval_shape(lambda m=model: m.init(jax.random.key(0)))
        _SHAPES_CACHE[arch] = (cfg, model, params)
    return _SHAPES_CACHE[arch]


def _axis_size(mesh, axis):
    if axis is None:
        return 1
    if isinstance(axis, (tuple, list)):
        return int(np.prod([mesh.shape[a] for a in axis]))
    return mesh.shape[axis]


def _assert_specs_fit(shape_tree, spec_tree, mesh, what):
    leaves, _ = jax.tree_util.tree_flatten_with_path(shape_tree)
    specs = jax.tree_util.tree_leaves(
        spec_tree, is_leaf=lambda x: isinstance(x, jax.sharding.PartitionSpec))
    assert len(leaves) == len(specs)
    for (path, leaf), spec in zip(leaves, specs):
        assert len(spec) <= len(leaf.shape), (what, path, spec, leaf.shape)
        for dim, axis in zip(leaf.shape, spec):
            n = _axis_size(mesh, axis)
            assert dim % n == 0, (
                f"{what}: {jax.tree_util.keystr(path)} dim {dim} does not "
                f"divide mesh axis {axis!r} (size {n}) under "
                f"{dict(mesh.shape)}")


@pytest.fixture(scope="module", autouse=True)
def _needs_devices():
    if len(jax.devices()) < 4:
        pytest.skip("needs ≥4 devices for the (2, 2) mesh (multi-device "
                    "CI job)")


@settings(max_examples=30, deadline=None)
@given(data=st.data())
def test_param_and_state_shardings_always_fit(data):
    arch = data.draw(st.sampled_from(ARCHS), label="arch")
    mesh_shape = data.draw(st.sampled_from(MESH_SHAPES), label="mesh")
    fsdp = data.draw(st.booleans(), label="fsdp")
    batch = data.draw(st.sampled_from([1, 2, 4, 8]), label="batch")
    seq = data.draw(st.sampled_from([16, 64, 256]), label="seq")
    mesh = make_host_mesh(mesh_shape, ("data", "model"))
    cfg, model, params_shape = _model_shapes(arch)

    specs = param_pspecs(params_shape, mesh, fsdp=fsdp)
    _assert_specs_fit(params_shape, specs, mesh, f"{arch} params")

    cache_shape = jax.eval_shape(lambda: model.init_cache(batch, seq))
    _assert_specs_fit(cache_shape,
                      cache_pspecs(cache_shape, mesh, batch=batch),
                      mesh, f"{arch} cache")

    # serve-path slot-group state: the decoder cache keyed by slots, plus
    # the seed-token companion (encoder–decoder archs are not served by
    # the engine, so the slot-state rules do not apply to them)
    if not getattr(cfg, "is_encoder_decoder", False):
        from repro.models import decoder
        slot_shape = jax.eval_shape(
            lambda: decoder.init_cache(cfg, batch, seq))
        slot_shape["pos"] = jax.ShapeDtypeStruct((batch,), np.int32)
        _assert_specs_fit(
            slot_shape,
            serve_state_pspecs(slot_shape, mesh, n_slots=batch),
            mesh, f"{arch} serve state")
        tok_spec = serve_slot_pspec((batch, 1), mesh)
        for dim, axis in zip((batch, 1), tok_spec):
            assert dim % _axis_size(mesh, axis) == 0
