"""Per-architecture smoke tests: every assigned config instantiates at a
reduced size of the same family and runs forward/train/prefill/decode on CPU
with finite outputs and correct shapes."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import (ASSIGNED_ARCHS, SHAPES, get_config,
                           get_smoke_config, shape_applicable)
from repro.models import registry
from repro.optim import adamw
from repro.runtime import steps as steps_lib

ALL = ASSIGNED_ARCHS + ["llama2-7b"]


def make_batch(cfg, B=2, S=16, key=0):
    batch = {
        "tokens": jax.random.randint(jax.random.key(key), (B, S), 0,
                                     cfg.vocab_size),
        "labels": jax.random.randint(jax.random.key(key + 1), (B, S), 0,
                                     cfg.vocab_size),
    }
    if cfg.family == "vlm":
        batch["vision_embeds"] = jnp.full(
            (B, cfg.n_vision_tokens, cfg.d_model), 0.01, cfg.jnp_dtype())
    if cfg.is_encoder_decoder:
        batch["frames"] = jnp.full((B, cfg.n_audio_frames, cfg.d_model),
                                   0.01, cfg.jnp_dtype())
    return batch


@pytest.mark.parametrize("arch", ALL)
def test_forward_loss_finite(arch):
    cfg = get_smoke_config(arch)
    model = registry.build(cfg)
    params = model.init(jax.random.key(0))
    batch = make_batch(cfg)
    loss, aux = model.loss(params, batch)
    assert np.isfinite(float(loss))
    lg = model.logits(params, batch)
    nv = cfg.n_vision_tokens if cfg.family == "vlm" else 0
    assert lg.shape == (2, 16 + nv, cfg.vocab_padded)
    assert np.all(np.isfinite(np.asarray(lg, np.float32)))


@pytest.mark.parametrize("arch", ALL)
def test_train_step(arch):
    cfg = get_smoke_config(arch)
    model = registry.build(cfg)
    params = model.init(jax.random.key(0))
    batch = make_batch(cfg)
    step = jax.jit(steps_lib.make_train_step(
        model, adamw.AdamWConfig(lr=1e-3), remat=True))
    opt = adamw.init(params)
    p1, o1, m1 = step(params, opt, batch)
    p2, o2, m2 = step(p1, o1, batch)
    assert np.isfinite(float(m2["loss"]))
    # params actually moved
    moved = any(
        not np.allclose(np.asarray(a, np.float32), np.asarray(b, np.float32))
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(p2)))
    assert moved


@pytest.mark.parametrize("arch", ALL)
def test_prefill_decode_consistency(arch):
    """Greedy decode from cache must match teacher-forced argmax."""
    cfg = get_smoke_config(arch)
    model = registry.build(cfg)
    params = model.init(jax.random.key(0))
    B, S = 2, 16
    batch = make_batch(cfg, B, S)
    nv = cfg.n_vision_tokens if cfg.family == "vlm" else 0
    last, cache = model.prefill(params, batch, max_len=S + nv + 4)
    assert np.all(np.isfinite(np.asarray(last)))
    # teacher-forced logits at the last prompt position
    full = model.logits(params, batch)
    np.testing.assert_allclose(np.asarray(last), np.asarray(full[:, -1]),
                               atol=5e-2, rtol=5e-2)
    tok = jnp.argmax(last, -1)[:, None].astype(jnp.int32)
    lg, cache = model.decode(params, cache, tok)
    assert lg.shape[0] == B and lg.shape[-1] == cfg.vocab_padded
    assert np.all(np.isfinite(np.asarray(lg)))
    # decode once more to exercise cache advance
    tok2 = jnp.argmax(lg[:, -1], -1)[:, None].astype(jnp.int32)
    lg2, cache = model.decode(params, cache, tok2)
    assert int(cache["pos"]) == S + nv + 2


@pytest.mark.parametrize("arch", ALL)
def test_full_config_is_exact(arch):
    """The full (production) config matches the assignment numbers."""
    spec = {
        "internvl2-1b": (24, 896, 14, 2, 4864, 151655),
        "recurrentgemma-9b": (38, 4096, 16, 1, 12288, 256000),
        "glm4-9b": (40, 4096, 32, 2, 13696, 151552),
        "qwen1.5-32b": (64, 5120, 40, 40, 27392, 152064),
        "gemma-2b": (18, 2048, 8, 1, 16384, 256000),
        "qwen3-14b": (40, 5120, 40, 8, 17408, 151936),
        "mamba2-370m": (48, 1024, 0, 0, 0, 50280),
        "olmoe-1b-7b": (16, 2048, 16, 16, 1024, 50304),
        "dbrx-132b": (40, 6144, 48, 8, 10752, 100352),
        "whisper-medium": (24, 1024, 16, 16, 4096, 51865),
        "llama2-7b": (32, 4096, 32, 32, 11008, 32000),
    }[arch]
    cfg = get_config(arch)
    got = (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.d_ff,
           cfg.vocab_size)
    assert got == spec, (got, spec)


def test_moe_configs():
    olmoe, dbrx = get_config("olmoe-1b-7b"), get_config("dbrx-132b")
    assert (olmoe.n_experts, olmoe.moe_top_k) == (64, 8)
    assert (dbrx.n_experts, dbrx.moe_top_k) == (16, 4)


def test_param_count_sanity():
    """Analytical total_params ≈ known production sizes (±15%)."""
    approx = {"llama2-7b": 6.7e9, "gemma-2b": 2.5e9, "dbrx-132b": 132e9,
              "olmoe-1b-7b": 6.9e9, "qwen1.5-32b": 32e9,
              "mamba2-370m": 0.37e9}
    for arch, want in approx.items():
        got = get_config(arch).total_params()
        assert abs(got - want) / want < 0.18, (arch, got, want)


def test_shape_applicability():
    for arch in ASSIGNED_ARCHS:
        cfg = get_config(arch)
        long_ok = shape_applicable(cfg, SHAPES[3])
        assert long_ok == (arch in ("mamba2-370m", "recurrentgemma-9b"))


def test_analytic_params_match_pytree():
    """config.total_params() equals the real initialized pytree size."""
    for arch in ("llama2-7b", "olmoe-1b-7b", "mamba2-370m",
                 "recurrentgemma-9b", "whisper-medium"):
        cfg = get_smoke_config(arch)
        model = registry.build(cfg)
        params = model.init(jax.random.key(0))
        real = sum(int(np.prod(l.shape)) for l in jax.tree.leaves(params))
        analytic = cfg.total_params()
        assert abs(real - analytic) / real < 0.05, (arch, real, analytic)
