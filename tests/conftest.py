"""Shared fixtures. NOTE: no XLA_FLAGS here — tests run on the single real
CPU device; only the dry-run uses placeholder devices."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.data import SyntheticCorpus
from repro.models import registry


@pytest.fixture(scope="session")
def tiny_model():
    """4-layer llama-family model + params + calibration batch."""
    cfg = get_smoke_config("llama2-7b").replace(n_layers=4)
    model = registry.build(cfg)
    params = model.init(jax.random.key(0))
    corpus = SyntheticCorpus(cfg.vocab_size, seed=7)
    batch = {k: jnp.asarray(v) for k, v in corpus.batch(2, 32,
                                                        split="calib").items()}
    return model, params, batch


@pytest.fixture(scope="module")
def served(tiny_model):
    """tiny_model plus its memory model and a random-Q RAPController —
    the shared substrate of the engine/horizon/executor suites.
    Module-scoped: the controller memoizes decisions per (bucket, shape),
    and cross-module sharing would let one suite's memo warm another's."""
    from repro.core import controller as ctl, dqn, memory

    model, params, batch = tiny_model
    mm = memory.build_memory_model(model.cfg)
    qp = dqn.init_qnet(jax.random.key(0), 2 * model.cfg.n_layers + 4,
                       2 * model.cfg.n_layers + 1, 32)
    c = ctl.RAPController(model, params, batch, mm, qp)
    return model, params, batch, mm, c


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)
