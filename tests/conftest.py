"""Shared fixtures. NOTE: no XLA_FLAGS here — tests run on the single real
CPU device; only the dry-run uses placeholder devices."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.data import SyntheticCorpus
from repro.models import registry


@pytest.fixture(scope="session")
def tiny_model():
    """4-layer llama-family model + params + calibration batch."""
    cfg = get_smoke_config("llama2-7b").replace(n_layers=4)
    model = registry.build(cfg)
    params = model.init(jax.random.key(0))
    corpus = SyntheticCorpus(cfg.vocab_size, seed=7)
    batch = {k: jnp.asarray(v) for k, v in corpus.batch(2, 32,
                                                        split="calib").items()}
    return model, params, batch


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)
