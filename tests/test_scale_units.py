"""Units behind the scale path: collective parsing, pattern-group scan,
roofline math, microbatched training, sharding helpers."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, get_smoke_config
from repro.launch.dryrun import _shape_bytes, cell_policy, parse_collectives
from repro.models import decoder, registry
from repro.optim import adamw
from repro.runtime import steps as steps_lib


def test_shape_bytes():
    assert _shape_bytes("bf16[2,3,4]") == 48
    assert _shape_bytes("f32[10]") == 40
    assert _shape_bytes("s8[5,5]") == 25
    assert _shape_bytes("pred[8]") == 8


def test_parse_collectives_synthetic():
    hlo = """
  %ag = bf16[4,128] all-gather(%x), replica_groups=[32,16]<=[512], dimensions={1}
  %ar = f32[64] all-reduce(%y), replica_groups={{0,1,2,3},{4,5,6,7}}, to_apply=%add
  %rs = f32[16] reduce-scatter(%z), replica_groups=[2,256]<=[512], dimensions={0}
  %cp = bf16[8,8] collective-permute(%w), source_target_pairs={{0,1}}
  %other = f32[4] add(%a, %b)
"""
    out = parse_collectives(hlo)
    assert out["all-gather"]["count"] == 1
    assert out["all-gather"]["bytes"] == 4 * 128 * 2
    # ring factor (g-1)/g with g=16
    assert abs(out["all-gather"]["wire_bytes"]
               - 4 * 128 * 2 * 15 / 16) < 1e-6
    assert out["all-reduce"]["count"] == 1
    assert abs(out["all-reduce"]["wire_bytes"] - 64 * 4 * 2 * 3 / 4) < 1e-6
    assert out["reduce-scatter"]["count"] == 1
    assert out["reduce-scatter"]["wire_bytes"] == 16 * 4 * 255
    assert out["collective-permute"]["wire_bytes"] == 8 * 8 * 2
    assert out["total_wire_bytes"] > 0


def test_cell_policies():
    from repro.configs import get_shape
    p = cell_policy("qwen1.5-32b", get_shape("decode_32k"))
    assert p["kv_int8"] and p["fsdp"]
    p = cell_policy("dbrx-132b", get_shape("train_4k"))
    assert p["microbatches"] >= 4
    p = cell_policy("mamba2-370m", get_shape("long_500k"))
    assert p["shard_seq"]


def test_pattern_group_scan_matches_unrolled():
    import os
    cfg = get_smoke_config("recurrentgemma-9b").replace(n_layers=8)
    model = registry.build(cfg)
    params = model.init(jax.random.key(0))
    t = jax.random.randint(jax.random.key(1), (2, 32), 0, cfg.vocab_size)
    lg_group, _ = decoder.forward(params, cfg, t)
    os.environ["REPRO_UNROLL"] = "1"
    try:
        lg_unroll, _ = decoder.forward(params, cfg, t)
    finally:
        os.environ.pop("REPRO_UNROLL")
    np.testing.assert_allclose(np.asarray(lg_group), np.asarray(lg_unroll),
                               atol=1e-4, rtol=1e-4)


def test_pattern_group_respects_gates():
    cfg = get_smoke_config("recurrentgemma-9b").replace(n_layers=6)
    model = registry.build(cfg)
    params = model.init(jax.random.key(0))
    t = jax.random.randint(jax.random.key(1), (2, 16), 0, cfg.vocab_size)
    L = cfg.n_layers
    gates = {"mixer": jnp.ones((L,), jnp.float32).at[1].set(0.0),
             "ffn": jnp.ones((L,), jnp.float32).at[4].set(0.0)}
    lg_gated = model.logits(params, {"tokens": t}, gates=gates)
    lg_full = model.logits(params, {"tokens": t})
    assert np.abs(np.asarray(lg_gated) - np.asarray(lg_full)).max() > 1e-3


def test_microbatched_train_step_matches_full():
    cfg = get_smoke_config("llama2-7b").replace(n_layers=2)
    model = registry.build(cfg)
    params = model.init(jax.random.key(0))
    t = jax.random.randint(jax.random.key(1), (4, 32), 0, cfg.vocab_size)
    batch = {"tokens": t, "labels": t}
    opt_cfg = adamw.AdamWConfig(lr=1e-3, warmup_steps=0, schedule="constant")
    s1 = jax.jit(steps_lib.make_train_step(model, opt_cfg, remat=False))
    s2 = jax.jit(steps_lib.make_train_step(model, opt_cfg, remat=False,
                                           microbatches=2))
    p1, _, m1 = s1(params, adamw.init(params), batch)
    p2, _, m2 = s2(params, adamw.init(params), batch)
    # same data, same total gradient (mean over microbatches == full-batch
    # mean since microbatches are equal-sized)
    assert abs(float(m1["loss"]) - float(m2["loss"])) < 1e-5
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), atol=5e-4)


def test_roofline_model_flops():
    from repro.roofline import model_flops_per_device
    # dense train: ≥ 6·N·T/devices
    f = model_flops_per_device("gemma-2b", "train_4k")
    cfg = get_config("gemma-2b")
    floor = 6.0 * cfg.active_params() * 4096 * 256 / 256
    assert f >= floor
    # decode ≪ prefill
    assert (model_flops_per_device("gemma-2b", "decode_32k")
            < model_flops_per_device("gemma-2b", "prefill_32k") / 100)


def test_roofline_analyze_cell_from_disk():
    import os
    from repro.roofline import analyze_cell
    if not os.path.exists(
            "experiments/dryrun/gemma-2b_train_4k_pod1.json"):
        pytest.skip("dry-run artifacts not generated yet")
    r = analyze_cell("gemma-2b", "train_4k")
    assert r is not None
    assert r["dominant"] in ("compute", "memory", "collective")
    assert r["compute_s"] > 0 and r["fit_gb"] > 0
