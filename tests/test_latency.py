"""Property tests for the pure latency-percentile helpers
(``repro.runtime.latency``, DESIGN.md §12 "Measurement").

These pin the arithmetic the engine's TTFT/ITL summaries and the
BENCH_engine.json schema rely on — no JAX, no engine, tier-1 fast. The
hypothesis sweeps follow the repo convention (``importorskip``, as in
``tests/test_properties.py``) and widen the search when hypothesis is
installed; the seeded deterministic sweeps below always run, so the
invariants stay pinned even without it.
"""
import math

import numpy as np
import pytest

from repro.runtime.latency import percentile, summarize


def _check_bounded(xs, q):
    p = percentile(xs, q)
    assert min(xs) - 1e-9 <= p <= max(xs) + 1e-9
    assert math.isfinite(p)


def _check_monotone(xs, q_lo, q_hi):
    assert percentile(xs, q_lo) <= percentile(xs, q_hi) + 1e-9


def _check_numpy_linear(xs):
    for q in (0.0, 50.0, 90.0, 99.0, 100.0):
        np.testing.assert_allclose(
            percentile(xs, q), np.percentile(np.asarray(xs), q),
            rtol=1e-9, atol=1e-9)


def _check_summary(xs):
    s = summarize(xs)
    assert set(s) == {"p50", "p90", "p99", "mean", "count"}
    assert s["count"] == float(len(xs))
    assert s["p50"] <= s["p90"] + 1e-9 <= s["p99"] + 2e-9
    assert min(xs) - 1e-9 <= s["mean"] <= max(xs) + 1e-9


# ------------------------------------------------ deterministic sweeps
def test_percentile_properties_seeded_sweep():
    """Bounded-by-extremes, monotone-in-q, numpy-equivalent, and summary
    ordering over seeded random streams of varied size and scale."""
    rng = np.random.default_rng(7)
    for n in (1, 2, 3, 7, 50, 200):
        for scale in (1e-3, 1.0, 1e6):
            xs = list((rng.standard_normal(n) * scale).round(6))
            for q in (0.0, 13.7, 50.0, 90.0, 99.0, 100.0):
                _check_bounded(xs, q)
            q_pairs = rng.uniform(0.0, 100.0, size=(8, 2))
            for a, b in q_pairs:
                _check_monotone(xs, min(a, b), max(a, b))
            _check_numpy_linear(xs)
            _check_summary(xs)


def test_percentile_edge_cases():
    assert percentile([], 50.0) == 0.0
    assert percentile([3.5], 99.0) == 3.5
    assert percentile([1.0, 2.0], 50.0) == 1.5   # linear interpolation
    for bad in (-0.1, 100.1, float("nan")):
        with pytest.raises(ValueError, match="percentile q"):
            percentile([1.0], bad)


def test_summarize_empty_stream_is_total():
    s = summarize([])
    assert s == {"p50": 0.0, "p90": 0.0, "p99": 0.0,
                 "mean": 0.0, "count": 0.0}


def test_summarize_custom_quantiles_key_rendering():
    s = summarize([1.0, 2.0], qs=(25.0, 99.9))
    assert set(s) == {"p25", "p99.9", "mean", "count"}


# --------------------------------------------------- hypothesis sweeps
# A plain try/except (not importorskip, which would skip the whole module
# and the always-on sweeps above with it) gates the wider random search.
try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:
    given = None

if given is not None:
    finite = st.floats(min_value=-1e9, max_value=1e9,
                       allow_nan=False, allow_infinity=False)
    streams = st.lists(finite, min_size=1, max_size=200)

    @given(xs=streams, q=st.floats(min_value=0.0, max_value=100.0))
    @settings(max_examples=200, deadline=None)
    def test_percentile_bounded_by_extremes(xs, q):
        _check_bounded(xs, q)

    @given(xs=streams,
           qs=st.tuples(st.floats(min_value=0.0, max_value=100.0),
                        st.floats(min_value=0.0, max_value=100.0)))
    @settings(max_examples=200, deadline=None)
    def test_percentile_monotone_in_q(xs, qs):
        lo, hi = sorted(qs)
        _check_monotone(xs, lo, hi)

    @given(xs=streams)
    @settings(max_examples=100, deadline=None)
    def test_percentile_matches_numpy_linear(xs):
        _check_numpy_linear(xs)

    @given(xs=streams)
    @settings(max_examples=100, deadline=None)
    def test_summarize_shape_and_ordering(xs):
        _check_summary(xs)
