"""Policy-conformance suite: every registered PruningPolicy (RL + all
static baselines + random + dense) runs through the SAME engine trace and
must satisfy the serving contract — budget safety (pool never exceeds the
shared budget), mask shape, and bitwise determinism under a fixed seed."""
import jax
import numpy as np
import pytest

from repro.core import dqn, masks, memory
from repro.core.controller import RAPController
from repro.core.policy import (PolicyState, PruningPolicy, RLPolicy,
                               StaticOrderPolicy, available_policies,
                               make_policy)
from repro.runtime import EngineConfig, EngineRequest, RAPEngine

MAX_NEW = 2
N_REQ = 5


@pytest.fixture(scope="module")
def ctx(tiny_model):
    model, params, batch = tiny_model
    mm = memory.build_memory_model(model.cfg)
    qp = dqn.init_qnet(jax.random.key(0), 2 * model.cfg.n_layers + 4,
                       2 * model.cfg.n_layers + 1, 32)
    controller = RAPController(model, params, batch, mm, qp)
    return model, params, batch, mm, controller


@pytest.fixture(scope="module")
def policies(ctx):
    """Every registered policy, built from one serving context."""
    model, params, batch, mm, controller = ctx
    return {name: make_policy(name, model=model, params=params, calib=batch,
                              mm=mm, controller=controller, seed=0)
            for name in available_policies()}


def _trace(batch):
    toks = np.asarray(batch["tokens"])
    prompts = [toks[:1, : (16 if i % 2 else 24)] for i in range(N_REQ)]
    return [EngineRequest(rid=f"r{i}", prompt=np.asarray(p, np.int32),
                          arrival_t=0.001 * i)
            for i, p in enumerate(prompts)]


def _run(model, params, mm, policy, batch, *, budget_frac=0.9):
    full = masks.full_mask(model.cfg.n_layers)
    # pool ≈ 2.5 dense requests with a sub-dense budget → contention AND
    # pruning pressure for every policy
    budget = (mm.param_bytes(full)
              + 2.5 * budget_frac * mm.state_bytes(full, 1, 26))
    eng = RAPEngine(model, params, policy, EngineConfig(
        mode="masked", max_new_tokens=MAX_NEW, max_active=4, max_len=32,
        budget_bytes=budget))
    return eng.run(_trace(batch)), budget, eng


def test_registry_covers_paper_baselines(policies):
    """The §5.1 comparison set is servable: RL + the static baselines."""
    for name in ("rl", "shortgpt", "llmpruner", "random", "mha_drop",
                 "ffn_skip", "oneshot", "dense"):
        assert name in policies
        assert isinstance(policies[name], PruningPolicy)
        assert policies[name].name == name
        assert policies[name].mm is not None


@pytest.mark.parametrize("name", ["rl", "shortgpt", "llmpruner", "random",
                                  "mha_drop", "ffn_skip", "oneshot",
                                  "dense"])
def test_policy_conformance_through_engine(ctx, policies, name):
    """Same Poisson-ish trace through every policy: all requests served,
    budget never exceeded, masks well-formed, replay deterministic."""
    model, params, batch, mm, _ = ctx
    policy = policies[name]
    L = model.cfg.n_layers
    rep, budget, eng = _run(model, params, mm, policy, batch)

    done = [r for r in rep.results if r.status == "done"]
    assert len(done) == N_REQ and rep.rejected == 0

    # --- budget safety: the pool (strict admission) never exceeds the
    # shared budget net of resident params
    pool = rep.pool
    assert pool["peak_reserved_bytes"] <= pool["capacity_bytes"] + 1e-6
    assert (pool["capacity_bytes"] + eng.resident_param_bytes
            <= budget + 1e-6)
    assert pool["overcommit_events"] == 0
    assert pool["reserved_bytes"] == 0 and pool["in_use_bytes"] == 0

    # --- mask contract: boolean [2L], analytically consistent state bytes
    for r in done:
        assert r.mask.shape == (2 * L,) and r.mask.dtype == np.bool_
        assert r.tokens.shape == (1, MAX_NEW)
        i = int(r.rid[1:])
        total = (16 if i % 2 else 24) + MAX_NEW
        assert r.kv_bytes == pytest.approx(
            mm.state_bytes(r.mask, 1, total))

    # --- determinism: bitwise-identical replay under the fixed seed
    rep2, _, _ = _run(model, params, mm, policy, batch)
    for a, b in zip(rep.results, rep2.results):
        assert a.rid == b.rid and a.status == b.status
        np.testing.assert_array_equal(a.mask, b.mask)
        np.testing.assert_array_equal(a.tokens, b.tokens)


def test_static_policy_observes_budget(ctx, policies):
    """StaticOrderPolicy prunes until the analytical peak fits (when the
    order allows) and reports fits honestly when it cannot."""
    model, params, batch, mm, _ = ctx
    L = model.cfg.n_layers
    for name in ("shortgpt", "llmpruner", "random"):
        pol = policies[name]
        dense = mm.dense_peak(1, 32)
        d = pol.observe(PolicyState(batch=1, total_len=32,
                                    budget_bytes=0.8 * dense))
        assert d.mask.shape == (2 * L,)
        if d.fits:
            assert d.peak_bytes <= 0.8 * dense
        assert not d.mask.all()          # 80% of dense forces pruning
        # generous budget → no pruning
        d2 = pol.observe(PolicyState(batch=1, total_len=32,
                                     budget_bytes=2.0 * dense))
        assert d2.mask.all() and d2.fits


def test_static_policy_memoizes(ctx):
    model, params, batch, mm, _ = ctx
    pol = make_policy("random", model=model, mm=mm, seed=0)
    dense = mm.dense_peak(1, 32)
    d1 = pol.observe(PolicyState(batch=1, total_len=32,
                                 budget_bytes=0.8 * dense))
    d2 = pol.observe(PolicyState(batch=1, total_len=32,
                                 budget_bytes=0.8 * dense))
    assert not d1.cached and d2.cached
    np.testing.assert_array_equal(d1.mask, d2.mask)
    # memoized masks are private copies
    d2.mask[0] = not d2.mask[0]
    d3 = pol.observe(PolicyState(batch=1, total_len=32,
                                 budget_bytes=0.8 * dense))
    np.testing.assert_array_equal(d1.mask, d3.mask)


def test_policy_feedback_hook_called(ctx):
    """The engine reports every completion back to the policy."""
    model, params, batch, mm, _ = ctx

    class Recorder(StaticOrderPolicy):
        def __init__(self, mm):
            super().__init__(mm, [], "recorder")
            self.seen = []

        def feedback(self, result):
            self.seen.append(result.rid)

    pol = Recorder(mm)
    rep, _, _ = _run(model, params, mm, pol, batch)
    assert pol.seen == [r.rid for r in rep.results if r.status == "done"]


def test_make_policy_errors():
    with pytest.raises(KeyError, match="unknown policy"):
        make_policy("nope")
    with pytest.raises(ValueError, match="requires"):
        make_policy("rl")                 # no controller
    with pytest.raises(ValueError, match="requires"):
        make_policy("shortgpt")           # no model/params/calib/mm


def test_rl_policy_wraps_controller(ctx):
    model, params, batch, mm, controller = ctx
    pol = RLPolicy(controller)
    dense = mm.dense_peak(1, 32)
    d = pol.observe(PolicyState(batch=1, total_len=32,
                                budget_bytes=0.7 * dense))
    ref = controller.decide(1, 32, 0.7 * dense)
    np.testing.assert_array_equal(d.mask, ref.mask)
    assert pol.mm is controller.mm
