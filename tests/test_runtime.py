"""Runtime behaviour: fault-tolerant trainer, checkpoint manager, server,
gradient compression, sharding rules, int8 KV."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager, latest_step, save_pytree
from repro.configs import get_smoke_config
from repro.core import controller as ctl, dqn, memory
from repro.core.policy import RLPolicy
from repro.data import SyntheticCorpus, batch_iterator
from repro.models import registry
from repro.optim import adamw
from repro.parallel import compression, param_pspecs
from repro.runtime import RAPServer, Trainer, TrainerConfig


# --------------------------------------------------------------- checkpoint
def test_checkpoint_atomic_and_keep_n(tmp_path, tiny_model):
    _, params, _ = tiny_model
    cm = CheckpointManager(str(tmp_path), keep=2)
    for s in (1, 2, 3):
        cm.save(params, s)
    steps = sorted(n for n in os.listdir(tmp_path) if n.startswith("step_"))
    assert len(steps) == 2 and cm.latest_step() == 3


def test_checkpoint_roundtrip_async(tmp_path, tiny_model):
    _, params, _ = tiny_model
    cm = CheckpointManager(str(tmp_path))
    cm.save(params, 7, blocking=False)
    cm.wait()
    restored, manifest = cm.restore(jax.eval_shape(lambda: params))
    assert manifest["step"] == 7
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_ignores_partial_writes(tmp_path, tiny_model):
    """A .tmp directory (simulated crash mid-save) is never visible."""
    _, params, _ = tiny_model
    save_pytree(params, str(tmp_path), 5)
    os.makedirs(tmp_path / "step_0000000009.tmp")
    assert latest_step(str(tmp_path)) == 5


# ------------------------------------------------------------------ trainer
def _small_trainer(tmp_path, steps=12, ckpt_every=4):
    cfg = get_smoke_config("llama2-7b").replace(n_layers=2)
    model = registry.build(cfg)
    return model, Trainer(
        model, adamw.AdamWConfig(lr=1e-3, total_steps=steps),
        TrainerConfig(total_steps=steps, ckpt_dir=str(tmp_path),
                      ckpt_every=ckpt_every, log_every=4, ckpt_async=False,
                      remat=False))


def test_trainer_checkpoint_restart_resumes_exactly(tmp_path):
    model, tr = _small_trainer(tmp_path)
    corpus = SyntheticCorpus(model.cfg.vocab_size, seed=1)
    tr.run(batch_iterator(corpus, 2, 32), steps=8)
    assert tr.ckpt.latest_step() == 8
    # fresh trainer = simulated restart after node failure
    model2, tr2 = _small_trainer(tmp_path)
    assert tr2.maybe_restore()
    assert tr2.step == 8
    batches = batch_iterator(corpus, 2, 32, start=tr2.step)
    out = tr2.run(batches)
    assert out["final_step"] == 12


def test_trainer_emergency_checkpoint_on_crash(tmp_path):
    model, tr = _small_trainer(tmp_path, steps=100, ckpt_every=1000)
    corpus = SyntheticCorpus(model.cfg.vocab_size, seed=1)
    base = batch_iterator(corpus, 2, 32)

    def crashing():
        for i, b in enumerate(base):
            if i == 5:
                raise RuntimeError("simulated node failure")
            yield b

    with pytest.raises(RuntimeError):
        tr.run(crashing())
    assert tr.ckpt.latest_step() == 5   # emergency save happened


def test_trainer_straggler_detection(tmp_path):
    import time
    model, tr = _small_trainer(tmp_path, steps=10, ckpt_every=1000)
    corpus = SyntheticCorpus(model.cfg.vocab_size, seed=1)
    events = []
    tr.on_straggler = lambda s, dt: events.append(s)
    base = batch_iterator(corpus, 2, 32)

    def slow():
        for i, b in enumerate(base):
            if i == 6:
                time.sleep(1.2)   # inject a straggler step
            yield b

    tr.run(slow())
    assert len(tr.straggler_events) >= 1
    assert events == [s for s, _, _ in tr.straggler_events]


def test_trainer_elastic_remesh(tmp_path):
    """Shrink/grow the device mesh mid-run; training continues."""
    from repro.launch.mesh import make_host_mesh
    model, tr = _small_trainer(tmp_path, steps=8, ckpt_every=100)
    corpus = SyntheticCorpus(model.cfg.vocab_size, seed=1)
    tr.run(batch_iterator(corpus, 2, 32), steps=3)
    tr.remesh(make_host_mesh((1, 1), ("data", "model")))
    out = tr.run(batch_iterator(corpus, 2, 32, start=tr.step), steps=3)
    assert out["final_step"] == 6
    assert np.isfinite(out["history"][-1]["loss"])


# ------------------------------------------------------------------- server
def test_server_structural_vs_masked_equivalent(tiny_model):
    model, params, batch = tiny_model
    mm = memory.build_memory_model(model.cfg)
    qp = dqn.init_qnet(jax.random.key(0), 2 * model.cfg.n_layers + 4,
                       2 * model.cfg.n_layers + 1, 32)
    c = ctl.RAPController(model, params, batch, mm, qp)
    prompt = np.asarray(batch["tokens"])[:, :16]
    budget = 0.8 * mm.dense_peak(prompt.shape[0], 24)
    s1 = RAPServer(model, params, RLPolicy(c), mode="structural",
                   max_new_tokens=4)
    s2 = RAPServer(model, params, RLPolicy(c), mode="masked",
                   max_new_tokens=4)
    r1 = s1.serve(prompt, budget)
    r2 = s2.serve(prompt, budget)
    assert np.array_equal(r1.mask, r2.mask)
    np.testing.assert_array_equal(r1.tokens, r2.tokens)
    assert r1.fits and r1.peak_bytes <= budget


def test_server_bucket_cache_reuse(tiny_model):
    model, params, batch = tiny_model
    mm = memory.build_memory_model(model.cfg)
    qp = dqn.init_qnet(jax.random.key(1), 2 * model.cfg.n_layers + 4,
                       2 * model.cfg.n_layers + 1, 32)
    c = ctl.RAPController(model, params, batch, mm, qp)
    srv = RAPServer(model, params, RLPolicy(c), mode="structural",
                    max_new_tokens=2)
    prompt = np.asarray(batch["tokens"])[:, :16]
    budget = 0.85 * mm.dense_peak(2, 18)
    r1 = srv.serve(prompt, budget)
    r2 = srv.serve(prompt, budget)
    assert r1.compiled_new and not r2.compiled_new


# ------------------------------------------------------------- compression
def test_int8_error_feedback_allreduce():
    """Inside shard_map on a 1-device mesh: quantized mean ≈ true mean and
    the residual carries the quantization error."""
    from repro.compat import shard_map
    from jax.sharding import PartitionSpec as P
    from repro.launch.mesh import make_host_mesh

    mesh = make_host_mesh((1,), ("data",))
    g = {"w": jnp.asarray(np.random.default_rng(0)
                          .standard_normal((64,)).astype(np.float32))}
    r = compression.init_residuals(g)

    def f(g, r):
        return compression.compress_allreduce(g, r, ("data",))

    mean, new_r = shard_map(f, mesh=mesh, in_specs=(P(), P()),
                            out_specs=(P(), P()), check_vma=False)(g, r)
    err = np.abs(np.asarray(mean["w"]) - np.asarray(g["w"]))
    scale = np.abs(np.asarray(g["w"])).max() / 127.0
    assert err.max() <= scale * 0.51 + 1e-6
    np.testing.assert_allclose(np.asarray(new_r["w"]),
                               np.asarray(g["w"] - mean["w"]), atol=1e-6)
    # second round with residual: cumulative error shrinks (error feedback)
    mean2, _ = shard_map(f, mesh=mesh, in_specs=(P(), P()),
                         out_specs=(P(), P()), check_vma=False)(g, new_r)
    total = np.asarray(mean["w"] + mean2["w"])
    np.testing.assert_allclose(total, 2 * np.asarray(g["w"]),
                               atol=2 * scale)


# ---------------------------------------------------------------- sharding
def test_param_pspecs_divisibility_fallback(tiny_model):
    """Rules never emit a spec whose sharded dim does not divide the mesh."""
    from repro.configs import get_config
    from repro.launch.mesh import make_host_mesh
    import jax.sharding as jsh

    mesh = make_host_mesh((1, 1), ("data", "model"))
    for arch in ("dbrx-132b", "recurrentgemma-9b", "whisper-medium"):
        cfg = get_config(arch)
        model = registry.build(cfg)
        shapes = jax.eval_shape(lambda m=model: m.init(jax.random.key(0)))
        specs = param_pspecs(shapes, mesh, fsdp=True)
        flat_shapes = jax.tree.leaves(shapes)
        flat_specs = jax.tree.leaves(
            specs, is_leaf=lambda x: isinstance(x, jsh.PartitionSpec))
        assert len(flat_shapes) == len(flat_specs)
        for sh, sp in zip(flat_shapes, flat_specs):
            for dim, axis in zip(sh.shape, sp):
                if axis is not None:
                    n = np.prod([mesh.shape[a] for a in
                                 (axis if isinstance(axis, tuple)
                                  else (axis,))])
                    assert dim % n == 0


# ----------------------------------------------------------------- int8 KV
def test_int8_kv_decode_close_to_bf16():
    cfg = get_smoke_config("qwen3-14b")
    model = registry.build(cfg)
    params = model.init(jax.random.key(0))
    B, S = 2, 24
    batch = {"tokens": jax.random.randint(jax.random.key(1), (B, S), 0,
                                          cfg.vocab_size)}
    _, c16 = model.prefill(params, batch, max_len=S + 4)
    _, c8 = model.prefill(params, batch, max_len=S + 4, kv_dtype=jnp.int8)
    assert c8["attn"]["k"].dtype == jnp.int8 and "ks" in c8["attn"]
    tok = jnp.zeros((B, 1), jnp.int32)
    d16, _ = model.decode(params, c16, tok)
    d8, _ = model.decode(params, c8, tok)
    # int8 KV shifts logits only slightly; argmax agrees
    assert np.abs(np.asarray(d16) - np.asarray(d8)).max() < 0.5
    assert np.array_equal(np.argmax(np.asarray(d16), -1),
                          np.argmax(np.asarray(d8), -1))
