"""Fault-tolerant checkpointing: atomic, sharded, async, keep-N.

Layout:  <dir>/step_<n>/
            manifest.json      # treedef, leaf paths, shapes, dtypes, step
            <leaf-key>.npy     # one file per pytree leaf

Atomicity: leaves are written into ``step_<n>.tmp`` and the directory is
``os.rename``d into place — a crash mid-save never corrupts the latest
checkpoint, and ``latest_step`` only trusts directories with a manifest
(rename is the commit point). Restore reshards onto the *current* mesh via
``jax.device_put(leaf, sharding)``, which is what makes elastic re-mesh
(device count changed between runs) work: the checkpoint stores plain host
arrays, placement is decided at load time.

Async: ``save(..., blocking=False)`` snapshots leaves to host memory
synchronously (cheap) and writes files on a background thread, overlapping
I/O with the next training steps — the standard production trick for
large-model checkpointing cadence.
"""
from __future__ import annotations

import json
import os
import re
import shutil
import threading
from typing import Any, Dict, List, Optional, Sequence

import jax
import numpy as np


def _flatten(tree) -> Dict[str, np.ndarray]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        key = "/".join(_path_str(p) for p in path)
        out[key] = np.asarray(leaf)
    return out


def _path_str(entry) -> str:
    if hasattr(entry, "key"):
        return str(entry.key)
    if hasattr(entry, "idx"):
        return str(entry.idx)
    if hasattr(entry, "name"):
        return str(entry.name)
    return str(entry)


def save_pytree(tree, directory: str, step: int, *,
                extra: Optional[Dict[str, Any]] = None) -> str:
    """Atomic synchronous save. Returns the committed path."""
    final = os.path.join(directory, f"step_{step:010d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp, exist_ok=True)
    leaves = _flatten(tree)
    manifest = {"step": step, "extra": extra or {}, "leaves": {}}
    for key, arr in leaves.items():
        fname = key.replace("/", "__") + ".npy"
        np.save(os.path.join(tmp, fname), arr)
        manifest["leaves"][key] = {"file": fname,
                                   "shape": list(arr.shape),
                                   "dtype": str(arr.dtype)}
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)   # commit point
    return final


def latest_step(directory: str) -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    best = None
    for name in os.listdir(directory):
        m = re.fullmatch(r"step_(\d+)", name)
        if m and os.path.exists(os.path.join(directory, name,
                                             "manifest.json")):
            s = int(m.group(1))
            best = s if best is None else max(best, s)
    return best


def restore_pytree(template, directory: str, step: Optional[int] = None, *,
                   shardings=None):
    """Restore into ``template``'s structure. ``shardings`` (same structure,
    or None) controls device placement — pass mesh-specific shardings to
    reshard onto a different device count than the one that saved."""
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoint under {directory}")
    path = os.path.join(directory, f"step_{step:010d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)

    flat, treedef = jax.tree_util.tree_flatten_with_path(template)
    shard_flat = (jax.tree_util.tree_leaves(shardings)
                  if shardings is not None else [None] * len(flat))
    new_leaves = []
    for (keypath, leaf), shard in zip(flat, shard_flat):
        key = "/".join(_path_str(p) for p in keypath)
        meta = manifest["leaves"][key]
        arr = np.load(os.path.join(path, meta["file"]))
        if list(arr.shape) != list(leaf.shape):
            raise ValueError(f"shape mismatch for {key}: "
                             f"{arr.shape} vs {leaf.shape}")
        arr = arr.astype(leaf.dtype)
        new_leaves.append(jax.device_put(arr, shard) if shard is not None
                          else jax.device_put(arr))
    return treedef.unflatten(new_leaves), manifest


class CheckpointManager:
    """keep-N rotation + async background writes."""

    def __init__(self, directory: str, *, keep: int = 3):
        self.directory = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    def save(self, tree, step: int, *, extra=None, blocking: bool = True):
        self.wait()
        host_tree = jax.tree.map(np.asarray, tree)   # snapshot now
        if blocking:
            self._write(host_tree, step, extra)
        else:
            self._thread = threading.Thread(
                target=self._write_guarded, args=(host_tree, step, extra),
                daemon=True)
            self._thread.start()

    def _write_guarded(self, tree, step, extra):
        try:
            self._write(tree, step, extra)
        except BaseException as e:  # surfaced on next wait()
            self._error = e

    def _write(self, tree, step, extra):
        save_pytree(tree, self.directory, step, extra=extra)
        self._gc()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    def _gc(self):
        steps = sorted(
            int(m.group(1)) for m in
            (re.fullmatch(r"step_(\d+)", n)
             for n in os.listdir(self.directory)) if m)
        for s in steps[: max(0, len(steps) - self.keep)]:
            shutil.rmtree(os.path.join(self.directory, f"step_{s:010d}"),
                          ignore_errors=True)

    def restore(self, template, *, step=None, shardings=None):
        self.wait()
        return restore_pytree(template, self.directory, step,
                              shardings=shardings)

    def latest_step(self) -> Optional[int]:
        return latest_step(self.directory)
