"""Three-term roofline from the dry-run artifacts.

Per (arch × shape) on the single-pod mesh:

    compute_s    = HLO_FLOPs/device ÷ 197 TF/s   (bf16 MXU peak)
    memory_s     = HLO_bytes/device ÷ 819 GB/s   (HBM)
    collective_s = wire_bytes/device ÷ 50 GB/s   (ICI link)

Sources: the *unrolled* dry-run JSON supplies FLOPs / bytes / collective
wire bytes (XLA's cost_analysis counts a ``scan`` body once regardless of
trip count, so the roofline lowering unrolls the layer loop — exact per-op
accounting); the scan-mode JSON supplies the per-device memory fit (its
buffer assignment reflects the production double-buffered loop).

MODEL_FLOPS uses the standard accounting: train 6·N·T (fwd 2 + bwd 4),
prefill 2·N·T, decode 2·N·B — with N_active for MoE — plus attention
O(S²·H·Dh) terms. The ratio MODEL_FLOPS/HLO_FLOPs exposes remat recompute
and dispatch waste.
"""
from __future__ import annotations

import glob
import json
import os
from typing import Dict, List, Optional

from repro.configs import (ASSIGNED_ARCHS, SHAPES, get_config, get_shape,
                           shape_applicable)
from repro.launch.mesh import HBM_BW, ICI_BW, PEAK_FLOPS_BF16

DRYRUN_DIR = "experiments/dryrun"
HBM_PER_CHIP = 16e9   # v5e


def _load(tag: str) -> Optional[Dict]:
    p = os.path.join(DRYRUN_DIR, tag + ".json")
    if not os.path.exists(p):
        return None
    with open(p) as f:
        d = json.load(f)
    return None if d.get("error") or d.get("skipped") else d


def model_flops_per_device(arch: str, shape_name: str,
                           n_devices: int = 256) -> float:
    """Analytic MODEL_FLOPS per device (the 'useful compute' yardstick)."""
    cfg = get_config(arch)
    shape = get_shape(shape_name)
    n_active = cfg.active_params()
    B, S = shape.global_batch, shape.seq_len
    window = cfg.attn_window
    if shape.kind == "train":
        tokens = B * S
        flops = 6.0 * n_active * tokens
        mult = 3.0   # fwd + 2×bwd for the attention term too
    elif shape.kind == "prefill":
        tokens = B * S
        flops = 2.0 * n_active * tokens
        mult = 1.0
    else:  # decode: one token per sequence
        tokens = B
        flops = 2.0 * n_active * tokens
        mult = 1.0
    # attention score+combine FLOPs (not in the 6ND param accounting)
    if cfg.n_heads > 0 and shape.kind != "decode":
        for mixer in cfg.mixer_kinds():
            if mixer == "attn":
                eff = S
            elif mixer == "local_attn":
                eff = min(window, S) if window else S
            else:
                continue
            # causal: ~S·eff/2 scores; 2 matmuls (QK^T and PV), 2 FLOP/MAC
            flops += mult * B * cfg.n_heads * cfg.dh * S * eff * 2.0
    if shape.kind == "decode" and cfg.n_heads > 0:
        for mixer in cfg.mixer_kinds():
            if mixer == "attn":
                eff = S
            elif mixer == "local_attn":
                eff = min(window, S) if window else S
            else:
                continue
            flops += B * cfg.n_heads * cfg.dh * eff * 2.0 * 2.0
    return flops / n_devices


def analyze_cell(arch: str, shape_name: str) -> Optional[Dict]:
    scan = _load(f"{arch}_{shape_name}_pod1")
    unroll = _load(f"{arch}_{shape_name}_pod1_unroll") or scan
    if scan is None and unroll is None:
        return None
    src = unroll
    exact = bool(src.get("unroll", False))
    flops = src["cost"]["flops"]
    bytes_acc = src["cost"]["bytes_accessed"]
    wire = src["collectives"]["total_wire_bytes"]
    mf = model_flops_per_device(arch, shape_name,
                                src.get("n_devices", 256))
    # scan-lowered artifacts undercount loop bodies (counted once): fall
    # back to the analytic compute term and flag memory/collective as
    # lower bounds until the unrolled artifact exists.
    compute_s = (flops if exact else max(flops, mf)) / PEAK_FLOPS_BF16
    memory_s = bytes_acc / HBM_BW
    coll_s = wire / ICI_BW
    terms = {"compute": compute_s, "memory": memory_s, "collective": coll_s}
    dominant = max(terms, key=terms.get)
    bound = max(terms.values())
    mem_fit = (scan or src)["memory"].get(
        "real_bytes", (scan or src)["memory"]["argument_bytes"])
    return {
        "arch": arch, "shape": shape_name,
        "kind": src["kind"],
        "compute_s": compute_s, "memory_s": memory_s,
        "collective_s": coll_s, "dominant": dominant,
        "hlo_flops": flops, "model_flops": mf,
        "useful_ratio": (mf / flops) if (flops and exact) else None,
        "roofline_frac": min((mf / PEAK_FLOPS_BF16) / bound, 1.0)
            if bound else 0.0,
        "fit_gb": mem_fit / 1e9, "fits_hbm": mem_fit <= HBM_PER_CHIP,
        "unrolled": exact,
        "policy": src.get("policy", {}),
    }


_SUGGEST = {
    "compute": ("dominant term is MXU compute — already near the useful "
                "work floor; gains come from cutting remat recompute "
                "(useful_ratio < 1) or int8 matmuls"),
    "memory": ("dominant term is HBM traffic — fuse/eliminate materialized "
               "intermediates (attention probs, MoE dispatch buffers), "
               "shrink KV via int8, or re-block kernels"),
    "collective": ("dominant term is ICI wire — reduce per-layer "
                   "all-gathers (better weight/activation sharding "
                   "alignment), fold reduce-scatter into matmul consumers, "
                   "or compress gradients"),
}


def suggestion(row: Dict) -> str:
    return _SUGGEST[row["dominant"]]


def full_table() -> List[Dict]:
    rows = []
    for arch in ASSIGNED_ARCHS:
        cfg = get_config(arch)
        for shape in SHAPES:
            if not shape_applicable(cfg, shape):
                rows.append({"arch": arch, "shape": shape.name,
                             "skipped": True})
                continue
            r = analyze_cell(arch, shape.name)
            if r:
                rows.append(r)
    return rows


def render_table(rows: List[Dict]) -> str:
    out = ["| arch | shape | compute_s | memory_s | collective_s | dominant "
           "| MODEL/HLO | roofline_frac | fit GB |",
           "|---|---|---|---|---|---|---|---|---|"]
    for r in rows:
        if r.get("skipped"):
            out.append(f"| {r['arch']} | {r['shape']} | — | — | — | n/a "
                       "(full-attn skips long_500k) | — | — | — |")
            continue
        ge = "" if r["unrolled"] else "≥"
        ur = (f"{r['useful_ratio']:.2f}" if r["useful_ratio"] is not None
              else "—")
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['compute_s']:.4f} "
            f"| {ge}{r['memory_s']:.4f} | {ge}{r['collective_s']:.4f} "
            f"| {r['dominant']} | {ur} "
            f"| {r['roofline_frac']:.3f} | {r['fit_gb']:.2f} |")
    return "\n".join(out)
