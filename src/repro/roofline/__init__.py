from repro.roofline.analysis import (analyze_cell, full_table,
                                     model_flops_per_device, render_table)

__all__ = ["analyze_cell", "full_table", "model_flops_per_device",
           "render_table"]
