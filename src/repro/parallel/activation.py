"""Activation sharding constraints (``with_sharding_constraint`` hints).

GSPMD propagates parameter shardings into matmuls, but propagation through
``lax.scan`` carries and gathers is weak: without hints the hidden state —
and everything downstream — silently replicates across the batch axes,
inflating per-device activation memory by the full DP factor (observed:
489 GB/device on internvl2-1b × train_4k before these constraints).

Model code calls the helpers below at layer boundaries. They no-op unless a
policy is installed (tests and single-device runs are untouched); the
dry-run / trainer installs one via ``use(mesh)``. Constraints are
best-effort: any dim that does not divide its axis is left unsharded.
"""
from __future__ import annotations

import contextlib
from typing import Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_POLICY: Optional["Policy"] = None


class Policy:
    def __init__(self, mesh: Mesh, *, shard_seq: bool = False,
                 fsdp: bool = False):
        self.mesh = mesh
        self.dp: Tuple[str, ...] = tuple(a for a in ("pod", "data")
                                         if a in mesh.shape)
        self.ndp = int(np.prod([mesh.shape[a] for a in self.dp])) \
            if self.dp else 1
        self.nmdl = mesh.shape.get("model", 1)
        self.shard_seq = shard_seq
        self.fsdp = fsdp


@contextlib.contextmanager
def use(mesh: Optional[Mesh], *, shard_seq: bool = False,
        fsdp: bool = False):
    global _POLICY
    prev = _POLICY
    _POLICY = (Policy(mesh, shard_seq=shard_seq, fsdp=fsdp)
               if mesh is not None else None)
    try:
        yield
    finally:
        _POLICY = prev


def policy() -> Optional[Policy]:
    return _POLICY


def _constrain(x, spec: P):
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(_POLICY.mesh, spec))


_SEQ_SHARD_MIN = 2048


def hidden(x):
    """[B, S, D] (or [B, S, ...]): batch over dp; long sequences also shard
    the seq axis over "model" — Megatron sequence parallelism. The residual
    stream (and the layer-boundary activations saved for backward) then
    live 1/tp per device; GSPMD inserts the all-gather before column-
    parallel matmuls and the reduce-scatter after row-parallel ones, which
    is wire-equivalent to the TP all-reduce it replaces. Long-context
    batch=1 falls back to sequence-over-dp sharding."""
    if _POLICY is None or x.ndim < 2:
        return x
    p = _POLICY
    if x.shape[0] % p.ndp == 0 and x.shape[0] >= p.ndp:
        spec = [p.dp] + [None] * (x.ndim - 1)
        if (x.ndim >= 3 and x.shape[1] >= _SEQ_SHARD_MIN
                and x.shape[1] % p.nmdl == 0):
            spec[1] = "model"
        return _constrain(x, P(*spec))
    if p.shard_seq and x.shape[1] % p.ndp == 0:
        return _constrain(x, P(None, p.dp, *([None] * (x.ndim - 2))))
    return x


def logits(x):
    """[B, S, V] / [B, V]: batch over dp, vocab over model."""
    if _POLICY is None:
        return x
    p = _POLICY
    spec = [None] * x.ndim
    if x.shape[0] % p.ndp == 0 and x.shape[0] >= p.ndp:
        spec[0] = p.dp
    if x.shape[-1] % p.nmdl == 0 and x.shape[-1] >= p.nmdl:
        spec[-1] = "model"
    return _constrain(x, P(*spec))


def width(x):
    """Recurrence-internal activations [B, T, W]: the time axis cannot
    shard (sequential dependency) but the width axis is elementwise — shard
    W over "model" (f32 gate/state tensors at RG-LRU width 4096 × seq 4096
    are 0.5 GB each unsharded; dozens are live through the backward)."""
    if _POLICY is None or x.ndim < 2:
        return x
    p = _POLICY
    spec = [None] * x.ndim
    if x.shape[0] % p.ndp == 0 and x.shape[0] >= p.ndp:
        spec[0] = p.dp
    if x.shape[-1] % p.nmdl == 0 and x.shape[-1] >= p.nmdl:
        spec[-1] = "model"
    return _constrain(x, P(*spec))


def gather_seq(x):
    """Constrain [B, S, D] to batch-only sharding (seq gathered) — placed
    once before the QKV projections so GSPMD gathers the residual stream a
    single time per attention block instead of gathering q, k and v
    separately after projection (3× the wire at q_dim == kv_dim)."""
    if _POLICY is None or x.ndim < 3:
        return x
    p = _POLICY
    if x.shape[0] % p.ndp == 0 and x.shape[0] >= p.ndp:
        return _constrain(x, P(p.dp, *([None] * (x.ndim - 1))))
    return x


def expert_buffer(x):
    """MoE dispatch buffer [E, C, D]: experts over model (EP)."""
    if _POLICY is None:
        return x
    p = _POLICY
    if x.shape[0] % p.nmdl == 0:
        return _constrain(x, P("model", *([None] * (x.ndim - 1))))
    return x


def heads(x, head_dim_idx: int = 2):
    """Attention activations [B, S, H, Dh]: batch over dp + heads over
    "model" — but ONLY when the head count divides the axis. When it does
    not (qwen1.5-32b's 40 heads on a 16-way mesh), constraining to a
    batch-only spec forces full-tensor reshards that GSPMD's free
    propagation avoids (measured: 371 → 210 GB prefill wire on
    qwen1.5-32b × prefill_32k by leaving these unconstrained)."""
    if _POLICY is None:
        return x
    p = _POLICY
    if x.shape[head_dim_idx] % p.nmdl != 0:
        return x            # let GSPMD choose (see docstring)
    spec = [None] * x.ndim
    if x.shape[0] % p.ndp == 0 and x.shape[0] >= p.ndp:
        spec[0] = p.dp
    elif p.shard_seq and x.ndim > 1 and x.shape[1] % p.ndp == 0:
        spec[1] = p.dp
    spec[head_dim_idx] = "model"
    return _constrain(x, P(*spec))
