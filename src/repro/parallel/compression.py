"""int8 error-feedback gradient compression for the DP all-reduce.

At 1000+ nodes the data-parallel gradient all-reduce dominates step time
for parameter-heavy models. This implements the standard error-feedback
scheme: each step quantizes (grad + residual) to int8 with a per-leaf
scale, all-reduces the int8 payload (4× less ICI traffic than f32, 2× less
than bf16), dequantizes the mean, and keeps the quantization error as next
step's residual — which makes the compression *unbiased over time* (the
error-feedback theorem: SGD with EF-compression converges at the
uncompressed rate).

Mechanically: inside ``shard_map`` over the DP axes the all-reduce is an
explicit ``jax.lax.psum``, so the quantize→psum→dequantize pipeline is
visible to the scheduler and the int8 payload is what crosses ICI.
"""
from __future__ import annotations

import functools
from typing import Any, Tuple

import jax
import jax.numpy as jnp


def init_residuals(grads):
    return jax.tree.map(lambda g: jnp.zeros_like(g, dtype=jnp.float32),
                        grads)


def _quantize(x) -> Tuple[jnp.ndarray, jnp.ndarray]:
    x = x.astype(jnp.float32)
    scale = jnp.max(jnp.abs(x)) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def compress_allreduce(grads, residuals, axis_names) -> Tuple[Any, Any]:
    """Inside shard_map: EF-int8 all-reduce-mean over ``axis_names``.

    Returns (mean_grads f32, new_residuals). Scales are all-reduduced in
    f32 (a scalar per leaf — negligible traffic).
    """
    def one(g, r):
        v = g.astype(jnp.float32) + r
        q, scale = _quantize(v)
        deq = q.astype(jnp.float32) * scale
        new_r = v - deq                                   # error feedback
        total = jax.lax.psum(q.astype(jnp.float32) * scale, axis_names)
        # axis size via psum(1): works on every jax release (lax.axis_size
        # is a recent addition) and folds to a constant under shard_map
        n = jax.lax.psum(jnp.ones((), jnp.float32), axis_names)
        return total / n, new_r

    flat_g, treedef = jax.tree_util.tree_flatten(grads)
    flat_r = treedef.flatten_up_to(residuals)
    out = [one(g, r) for g, r in zip(flat_g, flat_r)]
    return (treedef.unflatten([o[0] for o in out]),
            treedef.unflatten([o[1] for o in out]))


def plain_allreduce(grads, axis_names):
    def one(g):
        total = jax.lax.psum(g.astype(jnp.float32), axis_names)
        n = 1
        for a in (axis_names if isinstance(axis_names, tuple)
                  else (axis_names,)):
            n *= jax.lax.axis_size(a)
        return total / n
    return jax.tree.map(one, grads)
