from repro.parallel.sharding import (batch_pspecs, cache_pspecs,
                                     param_pspecs, serve_slot_pspec,
                                     serve_state_pspecs, shardings_for)

__all__ = ["param_pspecs", "batch_pspecs", "cache_pspecs",
           "serve_state_pspecs", "serve_slot_pspec", "shardings_for"]
