from repro.parallel.sharding import (batch_pspecs, cache_pspecs,
                                     param_pspecs, shardings_for)

__all__ = ["param_pspecs", "batch_pspecs", "cache_pspecs", "shardings_for"]
