"""Sharding rules: parameter / batch / cache PartitionSpecs for any mesh.

Axes: ``"data"`` (+ ``"pod"`` when multi-pod) carry the batch; ``"model"``
carries tensor parallelism (feature dims), expert parallelism (MoE expert
dim) and vocab sharding. Rules are *name+rank* patterns over the pytree and
every rule checks divisibility — a dim that does not divide its mesh axis
falls back to replicated instead of producing a GSPMD error, so the same
rules serve full production configs and tiny smoke configs.

TP placement summary (16-way "model"):
  embed [V,D]            → (model, ∅)      vocab-sharded; V padded to 512·k
  lm_head [D,V]          → (∅, model)
  attn  wq/wk/wv [L,D,E] → (∅, ∅, model)   feature out-dim (n_heads·d_head)
        wo [L,E,D]       → (∅, model, ∅)   contracting in-dim → one AR/layer
  ffn   wi [L,D,2F]      → (∅, ∅, model)   gate|up halves stay shard-aligned
        wo [L,F,D]       → (∅, model, ∅)
  moe   wi/wo [L,E,..]   → (∅, model, ∅, ∅) expert-parallel
  rglru wx/w_gate/wa/wi  → width / block axis over model
  ssd                    → replicated (370M params; TP overhead ≫ gain)
  norms, biases, scalars → replicated
"""
from __future__ import annotations

import re
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def _axis_size(mesh: Mesh, axis) -> int:
    if axis is None:
        return 1
    if isinstance(axis, (tuple, list)):
        n = 1
        for a in axis:
            n *= mesh.shape[a]
        return n
    return mesh.shape.get(axis, 1)


def _fits(shape, spec: P, mesh: Mesh) -> bool:
    for dim, axis in zip(shape, spec):
        if axis is not None and dim % _axis_size(mesh, axis) != 0:
            return False
    return True


def _pick(shape, mesh: Mesh, *candidates: P) -> P:
    """First candidate whose sharded dims divide evenly; else replicated."""
    for spec in candidates:
        if _fits(shape, spec, mesh):
            return spec
    return P()


# ------------------------------------------------------------------ params
def _param_rule(path: str, shape: Tuple[int, ...], mesh: Mesh) -> P:
    r = len(shape)
    mdl = "model"

    if re.search(r"(^|/)embed$", path):
        return _pick(shape, mesh, P(mdl, None))
    if re.search(r"(^|/)lm_head$", path):
        return _pick(shape, mesh, P(None, mdl))
    if re.search(r"(^|/)enc_pos$", path):
        return P()

    # ssd mixer: replicated wholesale (see module docstring)
    if "/ssd/" in path:
        return P()

    # rglru: width dims over model
    if "/rglru/" in path:
        if re.search(r"/(wx|w_gate)$", path) and r == 3:
            return _pick(shape, mesh, P(None, None, mdl))
        if re.search(r"/wo$", path) and r == 3:
            return _pick(shape, mesh, P(None, mdl, None))
        if re.search(r"/(wa|wi)$", path) and r == 4:   # block-diag [L,nb,bw,bw]
            return _pick(shape, mesh, P(None, mdl, None, None))
        if re.search(r"/(conv_w)$", path) and r == 3:
            return _pick(shape, mesh, P(None, None, mdl))
        if re.search(r"/(conv_b|ba|bi|lam)$", path) and r == 2:
            return _pick(shape, mesh, P(None, mdl))
        return P()

    # MoE: expert-parallel over model
    if "/moe/" in path:
        if re.search(r"/(wi|wo)$", path) and r == 4:
            return _pick(shape, mesh, P(None, mdl, None, None))
        return P()   # router replicated (tiny, read by every token)

    # attention (incl. enc_attn / cross): [L, D, E] out-features over model
    if re.search(r"/(wq|wk|wv)$", path) and r == 3:
        return _pick(shape, mesh, P(None, None, mdl))
    if re.search(r"/wo$", path) and r == 3:
        return _pick(shape, mesh, P(None, mdl, None))
    if re.search(r"/(bq|bk|bv)$", path) and r == 2:
        return _pick(shape, mesh, P(None, mdl))

    # dense FFN: [L, D, 2F] / [L, F, D]
    if re.search(r"/wi$", path) and r == 3:
        return _pick(shape, mesh, P(None, None, mdl))

    return P()   # norms, scalar gates, etc.


def _leaf_paths(tree) -> Dict[str, Any]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        out[key] = leaf
    return out


def _add_fsdp(spec: P, path: str, shape, mesh: Mesh) -> P:
    """Layer a ZeRO-3/FSDP shard over the "data" axis onto an unsharded dim.

    Skips the leading stack axis of per-layer stacks (sharding layers breaks
    the scan) and any dim that does not divide. Picks the largest eligible
    dim — for weight matrices that is the feature-in dim, reproducing the
    MaxText fsdp axis placement."""
    nd = _axis_size(mesh, "data")
    if nd <= 1:
        return spec
    dims = list(spec) + [None] * (len(shape) - len(spec))
    start = 1 if ("stacks" in path and len(shape) >= 2) else 0
    best, best_dim = -1, None
    for i in range(start, len(shape)):
        if dims[i] is None and shape[i] % nd == 0 and shape[i] > best:
            best, best_dim = shape[i], i
    if best_dim is None or best < nd * 8:   # too small to matter
        return spec
    dims[best_dim] = "data"
    return P(*dims)


def param_pspecs(params_shape_tree, mesh: Mesh, *, fsdp: bool = False):
    """Same-structure tree of PartitionSpec for a params pytree (arrays or
    ShapeDtypeStructs). ``fsdp=True`` additionally shards each leaf over the
    "data" axis (weights gathered on use — ZeRO-3), which is what lets
    132B-param configs and f32 optimizer moments fit per-device HBM."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(params_shape_tree)
    specs = []
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        spec = _param_rule(key, tuple(leaf.shape), mesh)
        if fsdp:
            spec = _add_fsdp(spec, key, tuple(leaf.shape), mesh)
        specs.append(spec)
    return jax.tree_util.tree_unflatten(treedef, specs)


# ------------------------------------------------------------------- batch
def _dp_axes(mesh: Mesh):
    return tuple(a for a in ("pod", "data") if a in mesh.shape) or None


def batch_pspecs(batch_tree, mesh: Mesh, *, shard_seq: bool = False):
    """Batch dict → PartitionSpecs. Batch axis over (pod, data); if the
    batch does not divide (e.g. long_500k batch=1) and ``shard_seq``, the
    sequence axis shards instead (sequence parallelism)."""
    dp = _dp_axes(mesh)
    ndp = _axis_size(mesh, dp)

    def rule(leaf):
        shape = tuple(leaf.shape)
        if len(shape) == 0:
            return P()
        if shape[0] % ndp == 0 and shape[0] >= ndp:
            return P(dp, *([None] * (len(shape) - 1)))
        if shard_seq and len(shape) >= 2 and shape[1] % ndp == 0:
            return P(None, dp, *([None] * (len(shape) - 2)))
        return P()

    return jax.tree.map(rule, batch_tree)


# ------------------------------------------------------------------- cache
def cache_pspecs(cache_tree, mesh: Mesh, *, batch: int,
                 shard_seq: bool = False):
    """Decode-state shardings. Attention KV [L,B,S,K,Dh]: batch over
    (pod,data) and — for rank-5 KV leaves — sequence over "model"
    (flash-decode's split-KV dimension; KV heads stay replicated since
    tp > n_kv_heads for every assigned arch). When the batch cannot shard
    (long_500k), the sequence / state axes shard over (pod,data) instead."""
    dp = _dp_axes(mesh)
    ndp = _axis_size(mesh, dp)
    nm = _axis_size(mesh, "model")

    def rule(path_key: str, leaf):
        shape = tuple(leaf.shape)
        if len(shape) <= 1:
            return P()
        if len(shape) >= 2 and shape[1] == batch and batch % ndp == 0:
            rest = [None] * (len(shape) - 2)
            # rank-5 KV (+scale) leaves: also split the seq axis over model
            if len(shape) == 5 and shape[2] % nm == 0 and shape[2] >= nm * 64:
                rest[0] = "model"
            return P(None, dp, *rest)
        if shard_seq and len(shape) >= 3:
            # [L, B, S, ...] or [L, B, H, ...]: shard the 3rd axis
            if shape[2] % ndp == 0:
                return P(None, None, dp, *([None] * (len(shape) - 3)))
        return P()

    flat, treedef = jax.tree_util.tree_flatten_with_path(cache_tree)
    specs = []
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        specs.append(rule(key, leaf))
    return jax.tree_util.tree_unflatten(treedef, specs)


# ------------------------------------------------------------- serve state
def serve_state_pspecs(state_tree, mesh: Mesh, *, n_slots: int):
    """Slot-group decode-state shardings for the sharded serve path
    (DESIGN.md §7 "Sharded serving").

    The engine's slot axis is the data-parallel dimension: every leaf with
    ``n_slots`` in position 1 (attention KV ``[L, B, S, K, Dh]``, recurrent
    state ``[L, B, ...]``) shards its slot axis over ("pod", "data"), and
    rank-5 KV leaves additionally shard the KV-head axis over "model" —
    the tensor-parallel split matching ``param_pspecs``' wq/wk/wv
    out-feature sharding, so the per-head KV a TP shard writes lives on
    the shard that computed it. Per-slot positions (rank-1 ``[n_slots]``)
    follow the slot axis. Every rule falls back per-axis on divisibility
    (`_pick`), so smoke meshes and odd head counts degrade to replication
    instead of GSPMD errors.
    """
    dp = _dp_axes(mesh)

    def rule(leaf):
        shape = tuple(leaf.shape)
        if len(shape) == 1:
            return _pick(shape, mesh, P(dp)) if shape[0] == n_slots else P()
        if len(shape) >= 2 and shape[1] == n_slots:
            rest = [None] * (len(shape) - 2)
            if len(shape) == 5:      # attn KV (+ int8 scales): heads on TP
                return _pick(shape, mesh,
                             P(None, dp, None, "model", None),
                             P(None, dp, None, None, None),
                             P(None, None, None, "model", None))
            return _pick(shape, mesh, P(None, dp, *rest))
        return P()

    return jax.tree.map(rule, state_tree)


def serve_slot_pspec(shape, mesh: Mesh) -> P:
    """Leading-axis (slot) DP spec with divisibility fallback — the
    per-slot seed-token ``[n_slots, 1]`` companion of
    :func:`serve_state_pspecs`."""
    shape = tuple(shape)
    return _pick(shape, mesh, P(_dp_axes(mesh), *([None] * (len(shape) - 1))))


# ------------------------------------------------------------------ helper
def shardings_for(tree_of_pspecs, mesh: Mesh):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), tree_of_pspecs,
                        is_leaf=lambda x: isinstance(x, P))
