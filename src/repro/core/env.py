"""Pruning MDP environment — paper Appendix A.1/A.2.

State  s_t = (R_bs, R_sql) ⧺ GSI importance of every MHA/FFN block on the
current contracted model ⧺ (Sys_avail, predicted Sys_req) → ℝ^{2L+4}.
Action 0 = STOP; action 1+b removes block b. Episode ends on STOP or when
the analytical peak memory fits the budget. Reward is Eq. (2).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax.numpy as jnp
import numpy as np

from repro.core import gsi as gsi_lib
from repro.core import masks as masks_lib
from repro.core.memory import MemoryModel


@dataclasses.dataclass
class EnvConfig:
    alpha: float = 1.0        # R_ppl weight (paper: 1.0)
    beta: float = 0.3         # R_mem weight (paper: 0.3)
    gamma: float = 0.99
    bs_norm: float = 32.0     # state normalizers
    sql_norm: float = 4096.0
    imp_norm: float = 1.0     # importance scores are Δlog-ppl; O(1) already
    fast_scores: bool = False # True → skip per-step GSI recompute (RAP^-GSI-ish
                              # env used only for speed-insensitive tests)
    mask_stop_until_fit: bool = True  # the paper's memory-aware action mask:
                              # STOP is invalid while peak memory > budget


class PruneEnv:
    """One episode = prune-to-budget for a sampled (batch, seq, budget)."""

    def __init__(self, model, params, calib_batch, mm: MemoryModel,
                 cfg: EnvConfig = EnvConfig(), chunk: int = 8):
        self.model = model
        self.params = params
        self.mm = mm
        self.cfg = cfg
        self.L = model.cfg.n_layers
        self.n_actions = 2 * self.L + 1
        self.state_dim = 2 * self.L + 4
        self._scorer = gsi_lib.make_candidate_scorer(model, calib_batch,
                                                     chunk=chunk)
        self._ppl = gsi_lib.make_ppl_fn(model, calib_batch)
        self._dense_scores: Optional[np.ndarray] = None

    # ------------------------------------------------------------------ state
    def _scores(self, mask: np.ndarray) -> Tuple[np.ndarray, float]:
        cur = float(self._ppl(self.params, jnp.asarray(mask, jnp.float32)))
        if self.cfg.fast_scores and self._dense_scores is not None:
            raw = self._dense_scores
        else:
            raw = np.asarray(self._scorer(self.params,
                                          jnp.asarray(mask, jnp.float32)))
            if self._dense_scores is None:
                self._dense_scores = raw
        return gsi_lib.importance_scores(raw, cur), cur

    def _obs(self) -> np.ndarray:
        imp = self._imp / self.cfg.imp_norm
        peak = self.mm.peak_bytes(self.mask, self.bs, self.sql)
        dense = self.mm.dense_peak(self.bs, self.sql)
        return np.concatenate([
            [self.bs / self.cfg.bs_norm, self.sql / self.cfg.sql_norm],
            imp[: self.L], imp[self.L:],
            [self.budget / dense, peak / dense],
        ]).astype(np.float32)

    def valid_actions(self) -> np.ndarray:
        v = np.zeros(self.n_actions, bool)
        v[0] = self.fits() if self.cfg.mask_stop_until_fit else True
        v[1:] = self.mask
        if not v.any():
            v[0] = True   # nothing left to prune — STOP must be legal
        return v

    # --------------------------------------------------------------- episode
    def reset(self, bs: int, sql: int, budget_bytes: float) -> np.ndarray:
        self.bs, self.sql, self.budget = bs, sql, float(budget_bytes)
        self.mask = masks_lib.full_mask(self.L)
        self._imp, self._cur_logppl = self._scores(self.mask)
        self.t = 0
        self._prev_pot = self._potential()
        return self._obs()

    def _potential(self) -> float:
        """Eq. (2): Σ_i kept_i (α·R_ppl_i − β·R_mem_i), normalized terms."""
        imp = self._imp / self.cfg.imp_norm
        memb = self.mm.block_bytes(self.bs, self.sql)
        dense = self.mm.dense_peak(self.bs, self.sql)
        r = self.mask @ (self.cfg.alpha * imp - self.cfg.beta * memb / dense * len(memb))
        return float(r) / len(memb)

    def _reward(self) -> float:
        """Potential-based shaping of Eq. (2): the step reward is the DELTA
        of the kept-set utility, telescoping to the terminal value. The raw
        per-step form rewards episode length — at our scale the agent learns
        to remove cheap low-memory blocks to stay over budget longer and
        farm positive steps (observed exploit; documented in
        EXPERIMENTS.md). The delta form makes 'remove high-memory,
        low-importance blocks' the locally-rewarded action, which is the
        paper's intent."""
        pot = self._potential()
        prev = getattr(self, "_prev_pot", pot)
        self._prev_pot = pot
        return pot - prev

    def fits(self) -> bool:
        return self.mm.peak_bytes(self.mask, self.bs, self.sql) <= self.budget

    def step(self, action: int):
        """Returns (obs, reward, done, info)."""
        self.t += 1
        if action == 0:
            done = True
        else:
            b = action - 1
            assert self.mask[b], f"block {b} already pruned"
            self.mask = masks_lib.remove_block(self.mask, b)
            self._imp, self._cur_logppl = self._scores(self.mask)
            done = self.fits() or self.t >= 2 * self.L
        reward = self._reward()
        info = {"mask": self.mask.copy(), "log_ppl": self._cur_logppl,
                "peak": self.mm.peak_bytes(self.mask, self.bs, self.sql),
                "fits": self.fits()}
        return self._obs(), reward, done, info
