"""Block masks ↔ gates ↔ structural compaction.

A *mask* is a boolean [2L] vector (True = keep), indexed per
``repro.core.memory``. Two execution forms:

* masked mode   — ``mask_to_gates`` produces the runtime 0/1 gate inputs for
                  the single compiled executable (no memory savings);
* structural    — ``compact_params`` gathers the per-kind parameter stacks
                  along the layer axis, yielding genuinely smaller params, a
                  new layout, and a smaller KV cache. Executables are cached
                  per ``bucket_key`` (the retained-layout signature), vLLM
                  shape-bucket style.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.decoder import LayerSlot, default_layout, layout_counts


def full_mask(n_layers: int) -> np.ndarray:
    return np.ones(2 * n_layers, bool)


def mask_to_gates(mask) -> Dict[str, jnp.ndarray]:
    m = jnp.asarray(mask)
    L = m.shape[0] // 2
    return {"mixer": m[:L].astype(jnp.float32),
            "ffn": m[L:].astype(jnp.float32)}


def remove_block(mask: np.ndarray, block: int) -> np.ndarray:
    out = np.array(mask, copy=True)
    out[block] = False
    return out


def active_blocks(mask: np.ndarray) -> np.ndarray:
    return np.nonzero(np.asarray(mask))[0]


def compact_layout(cfg, mask: np.ndarray) -> Tuple[Tuple[LayerSlot, ...], Dict]:
    """Retained layout: drop layers where both blocks are pruned; keep gate
    info for half-pruned layers. Returns (layout, per-kind gather indices)."""
    base = default_layout(cfg)
    L = len(base)
    m = np.asarray(mask)
    keep_rows = [i for i in range(L) if m[i] or m[L + i]]
    gather: Dict[str, list] = {}
    slots = []
    counters: Dict[str, int] = {}
    for i in keep_rows:
        s = base[i]
        mixer = s.mixer if m[i] else None
        f = s.ffn if m[L + i] else None
        mi = fi = 0
        if mixer is not None:
            mk = "attn" if mixer == "local_attn" else mixer
            gather.setdefault(mk, []).append(s.mixer_idx)
            mi = counters.get(mk, 0)
            counters[mk] = mi + 1
        if f is not None:
            gather.setdefault(f, []).append(s.ffn_idx)
            fi = counters.get(f, 0)
            counters[f] = fi + 1
        slots.append(LayerSlot(mixer, mi, f, fi))
    return tuple(slots), gather


def compact_params(params: dict, cfg, mask: np.ndarray):
    """Gather stacks per the mask. Returns (small_params, layout, gates).

    ``gates`` are all-ones over the compacted layout (masking became
    structure); callers pass them (or None) to forward/decode.
    """
    layout, gather = compact_layout(cfg, mask)
    new_stacks = {}
    for kind, idxs in gather.items():
        idx = jnp.asarray(idxs, jnp.int32)
        new_stacks[kind] = jax.tree.map(lambda x: jnp.take(x, idx, axis=0),
                                        params["stacks"][kind])
    small = dict(params)
    small["stacks"] = new_stacks
    return small, layout


def bucket_key(cfg, mask: np.ndarray) -> Tuple:
    """Executable-cache key: the retained layout signature (kinds sequence).

    Whole-layer drops on uniform architectures collapse by count — any mask
    removing k full layers maps to the same (L-k)-layer signature, so those
    masks share one compiled program (vLLM-shape-bucket-style). Half-layer
    drops keep their position (the block sequence differs structurally).
    """
    layout, _ = compact_layout(cfg, mask)
    return tuple((s.mixer, s.ffn) for s in layout)


def mask_param_fraction(cfg, mask: np.ndarray) -> float:
    """Fraction of block params retained (excludes embeddings) — Table 4."""
    mix, ffn = cfg.block_param_counts()
    L = cfg.n_layers
    m = np.asarray(mask)
    tot = float(np.sum(mix) + np.sum(ffn))
    kept = float(np.asarray(mix) @ m[:L] + np.asarray(ffn) @ m[L:])
    return kept / max(tot, 1.0)
