"""Block masks ↔ gates ↔ structural compaction.

A *mask* is a boolean [2L] vector (True = keep), indexed per
``repro.core.memory``. Two execution forms:

* masked mode   — ``mask_to_gates`` produces the runtime 0/1 gate inputs for
                  the single compiled executable (no memory savings);
* structural    — ``compact_params`` gathers the per-kind parameter stacks
                  along the layer axis, yielding genuinely smaller params, a
                  new layout, and a smaller KV cache. Executables are cached
                  per ``bucket_key`` (the retained-layout signature), vLLM
                  shape-bucket style.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.decoder import LayerSlot, default_layout, layout_counts


def full_mask(n_layers: int) -> np.ndarray:
    return np.ones(2 * n_layers, bool)


def mask_to_gates(mask) -> Dict[str, jnp.ndarray]:
    m = jnp.asarray(mask)
    L = m.shape[0] // 2
    return {"mixer": m[:L].astype(jnp.float32),
            "ffn": m[L:].astype(jnp.float32)}


def remove_block(mask: np.ndarray, block: int) -> np.ndarray:
    out = np.array(mask, copy=True)
    out[block] = False
    return out


def active_blocks(mask: np.ndarray) -> np.ndarray:
    return np.nonzero(np.asarray(mask))[0]


def compact_layout(cfg, mask: np.ndarray) -> Tuple[Tuple[LayerSlot, ...], Dict]:
    """Retained layout: drop layers where both blocks are pruned; keep gate
    info for half-pruned layers. Returns (layout, per-kind gather indices)."""
    base = default_layout(cfg)
    L = len(base)
    m = np.asarray(mask)
    keep_rows = [i for i in range(L) if m[i] or m[L + i]]
    gather: Dict[str, list] = {}
    slots = []
    counters: Dict[str, int] = {}
    for i in keep_rows:
        s = base[i]
        mixer = s.mixer if m[i] else None
        f = s.ffn if m[L + i] else None
        mi = fi = 0
        if mixer is not None:
            mk = "attn" if mixer == "local_attn" else mixer
            gather.setdefault(mk, []).append(s.mixer_idx)
            mi = counters.get(mk, 0)
            counters[mk] = mi + 1
        if f is not None:
            gather.setdefault(f, []).append(s.ffn_idx)
            fi = counters.get(f, 0)
            counters[f] = fi + 1
        slots.append(LayerSlot(mixer, mi, f, fi))
    return tuple(slots), gather


def compact_params(params: dict, cfg, mask: np.ndarray):
    """Gather stacks per the mask. Returns (small_params, layout).

    Masking became structure: the compacted stacks hold only retained
    blocks, so callers run forward/decode with ``layout`` and no gates
    (or all-ones gates over the compacted layout).
    """
    layout, gather = compact_layout(cfg, mask)
    new_stacks = {}
    for kind, idxs in gather.items():
        idx = jnp.asarray(idxs, jnp.int32)
        new_stacks[kind] = jax.tree.map(lambda x: jnp.take(x, idx, axis=0),
                                        params["stacks"][kind])
    small = dict(params)
    small["stacks"] = new_stacks
    return small, layout


def bucket_key(cfg, mask: np.ndarray) -> Tuple:
    """Executable-cache key: the retained layout signature (kinds sequence).

    Whole-layer drops on uniform architectures collapse by count — any mask
    removing k full layers maps to the same (L-k)-layer signature, so those
    masks share one compiled program (vLLM-shape-bucket-style). Half-layer
    drops keep their position (the block sequence differs structurally).
    """
    layout, _ = compact_layout(cfg, mask)
    return tuple((s.mixer, s.ffn) for s in layout)


def gather_key(cfg, mask: np.ndarray) -> Tuple:
    """Identity key for the *exact* compacted parameter stack.

    ``bucket_key`` deliberately collapses any k whole-layer drops to one
    (L-k)-layer signature so those masks share a compiled executable —
    but masks dropping *different* layers gather *different* rows of the
    parameter stacks. Resident compacted params (and the slot groups
    holding them) must therefore be keyed on the gather indices, never on
    the signature alone (see DESIGN.md §9 on the aliasing bug this fixes).
    """
    _, gather = compact_layout(cfg, mask)
    return tuple(sorted((kind, tuple(idxs)) for kind, idxs in gather.items()))


def keep_rows(cfg, mask: np.ndarray) -> np.ndarray:
    """Original layer indices retained by ``mask`` (either block kept)."""
    L = cfg.n_layers
    m = np.asarray(mask)
    return np.asarray([i for i in range(L) if m[i] or m[L + i]], np.int64)


def quantize_mask(cfg, mask: np.ndarray, mode: str) -> np.ndarray:
    """Snap a mask onto a bucket-shape ladder; returns the *bucket* mask.

    An adaptive policy emits a stream of distinct masks; compiling one
    structural executable per mask is unbounded. Quantization rounds the
    retained-layer count UP onto a small ladder and keeps *whole layers*
    (both blocks) at every retained row, so the request's exact mask is
    realized as per-slot 0/1 gates inside the bucket. Gating a block off
    is bitwise-identical to dropping it structurally (``h + 0*out == h``
    for finite outputs, and ``1.0*out == out`` exactly), so bucket streams
    match pure-structural streams token for token.

    Modes:
      * ``none``  — identity; each exact mask compiles its own bucket.
      * ``layer`` — whole-layer bucket over the exact retained-row set
                    (half-layer drops become gates; row sets still vary).
      * ``pow2``  — like ``layer`` but the row count is rounded up to the
                    next power of two (extra rows realized from the
                    lowest-indexed fully-dropped layers, gated off), so at
                    most ceil(log2 L)+1 compiled families exist.
    """
    if mode == "none":
        return np.array(mask, copy=True)
    if mode not in ("layer", "pow2"):
        raise ValueError(f"unknown bucket_quant mode {mode!r}; "
                         "expected none|layer|pow2")
    L = cfg.n_layers
    m = np.asarray(mask)
    rows = [i for i in range(L) if m[i] or m[L + i]]
    k = max(len(rows), 1)
    if mode == "pow2":
        target = min(1 << (k - 1).bit_length(), L)
        extras = [i for i in range(L) if not (m[i] or m[L + i])]
        rows = sorted(rows + extras[: target - len(rows)])
    elif not rows:
        rows = [0]
    out = np.zeros(2 * L, bool)
    for i in rows:
        out[i] = out[L + i] = True
    return out


def mask_param_fraction(cfg, mask: np.ndarray) -> float:
    """Fraction of block params retained (excludes embeddings) — Table 4."""
    mix, ffn = cfg.block_param_counts()
    L = cfg.n_layers
    m = np.asarray(mask)
    tot = float(np.sum(mix) + np.sum(ffn))
    kept = float(np.asarray(mix) @ m[:L] + np.asarray(ffn) @ m[L:])
    return kept / max(tot, 1.0)
