"""Static structured-pruning baselines the paper compares against (§5.1).

All baselines emit a boolean keep-mask over the 2L blocks (mixer blocks
first, FFN blocks second — the convention of ``repro.core.memory``) and are
evaluated under the paper's protocol: prune until the *unified memory
budget* (params + KV cache for the request shape) is met, then measure
perplexity / task accuracy. ``SliceGPT`` is width-slicing rather than
block-dropping, so it returns modified (params, cfg) instead of a mask.

Fidelity notes (recorded per DESIGN.md §15):
 * ShortGPT  — Block-Influence score = 1 − cos(h_in, h_out) per *layer*;
   lowest-influence layers removed first.            [Men et al. 2024]
 * MHA-Drop  — same cosine criterion per *attention block* only.
                                                     [He et al. 2024]
 * FFN-Skip  — cosine criterion per *FFN block* only. [Jaiswal et al. 2024]
 * LLMPruner — first-order Taylor saliency |g ⊙ w| summed per block (the
   gradient-based criterion; coupled-structure bookkeeping is subsumed by
   our block granularity).                           [Ma et al. 2023]
 * SliceGPT  — our TPU-native stand-in slices the lowest-L2 d_ff channels
   and attention heads to a uniform width ratio (PCA rotation replaced by
   magnitude ranking — the *width-reduction* mechanism is faithful, the
   rotation is not; noted honestly in EXPERIMENTS.md).
 * Random-Drop — uniform random blocks (the paper's RAP^-RL ablation).
 * One-shot PPL — dense-model Δppl scores without re-evaluation (RAP^-GSI).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import gsi as gsi_lib
from repro.core import masks as masks_lib
from repro.core.memory import MemoryModel
from repro.models import decoder, layers


# ----------------------------------------------------------- cosine probes
def block_cosines(model, params, batch) -> Tuple[np.ndarray, np.ndarray]:
    """Per-block residual influence: 1 − cos(h, h + out).

    Returns (mixer_scores [L], ffn_scores [L]); low score = redundant.
    """
    cfg = model.cfg
    layout = decoder.default_layout(cfg)
    h = decoder._embed(params, cfg, jnp.asarray(batch["tokens"]), None)
    positions = jnp.arange(h.shape[1])[None, :]

    def cos(a, b):
        a = a.astype(jnp.float32).reshape(-1, a.shape[-1])
        b = b.astype(jnp.float32).reshape(-1, b.shape[-1])
        num = jnp.sum(a * b, -1)
        den = jnp.linalg.norm(a, axis=-1) * jnp.linalg.norm(b, axis=-1) + 1e-9
        return jnp.mean(num / den)

    mix_s, ffn_s = [], []
    for slot in layout:
        if slot.mixer is not None:
            mk = "attn" if slot.mixer == "local_attn" else slot.mixer
            pm = decoder.tree_slice(params["stacks"][mk], slot.mixer_idx)
            out, _ = decoder._apply_mixer(slot.mixer, pm, cfg, h, positions,
                                          impl="xla")
            h2 = h + out
            mix_s.append(1.0 - float(cos(h, h2)))
            h = h2
        else:
            mix_s.append(np.inf)
        if slot.ffn is not None:
            pf = decoder.tree_slice(params["stacks"][slot.ffn], slot.ffn_idx)
            out = decoder._apply_ffn(slot.ffn, pf, cfg, h, impl="xla")
            h2 = h + out
            ffn_s.append(1.0 - float(cos(h, h2)))
            h = h2
        else:
            ffn_s.append(np.inf)
    return np.asarray(mix_s), np.asarray(ffn_s)


def taylor_saliency(model, params, batch) -> np.ndarray:
    """LLMPruner-style |g ⊙ w| per block → [2L] (∞ where block missing)."""
    cfg = model.cfg
    L = cfg.n_layers

    def loss_fn(p):
        l, _ = model.loss(p, batch)
        return l

    grads = jax.grad(loss_fn)(params)
    layout = decoder.default_layout(cfg)
    sal = np.full(2 * L, np.inf)
    for i, slot in enumerate(layout):
        if slot.mixer is not None:
            mk = "attn" if slot.mixer == "local_attn" else slot.mixer
            gw = jax.tree.map(
                lambda g, w: jnp.sum(jnp.abs(g[slot.mixer_idx].astype(jnp.float32)
                                             * w[slot.mixer_idx].astype(jnp.float32))),
                grads["stacks"][mk], params["stacks"][mk])
            sal[i] = float(sum(jax.tree.leaves(gw)))
        if slot.ffn is not None:
            gw = jax.tree.map(
                lambda g, w: jnp.sum(jnp.abs(g[slot.ffn_idx].astype(jnp.float32)
                                             * w[slot.ffn_idx].astype(jnp.float32))),
                grads["stacks"][slot.ffn], params["stacks"][slot.ffn])
            sal[L + i] = float(sum(jax.tree.leaves(gw)))
    return sal


# ----------------------------------------------------- mask-based baselines
def prune_by_order(order, mm: MemoryModel, bs, sql, budget,
                   allowed: Optional[np.ndarray] = None) -> np.ndarray:
    """Remove blocks in ``order`` (most-redundant first) until budget fits."""
    L = mm.n_layers
    mask = masks_lib.full_mask(L)
    for b in order:
        if mm.peak_bytes(mask, bs, sql) <= budget:
            break
        if allowed is not None and not allowed[b]:
            continue
        mask[b] = False
    return mask


_prune_by_order = prune_by_order   # historical (pre-policy-API) name


# Each baseline factors into a *removal order* (scored once per model —
# the expensive probe) and the shared budget-fitting loop above. The order
# functions are what ``repro.core.policy`` wraps into PruningPolicy
# implementations; the ``*_mask`` forms keep the one-call offline protocol.
def shortgpt_order(model, params, batch, mm) -> list:
    """Layer-level removal order: (mixer, ffn) pairs by combined cosine
    influence, most-redundant layer first."""
    mix_s, ffn_s = block_cosines(model, params, batch)
    L = mm.n_layers
    layer_score = np.where(np.isfinite(mix_s), mix_s, 0) + \
        np.where(np.isfinite(ffn_s), ffn_s, 0)
    order = []
    for i in np.argsort(layer_score):    # drop the whole layer (both blocks)
        order += [int(i), int(L + i)]
    return order


def mha_drop_order(model, params, batch, mm) -> list:
    mix_s, _ = block_cosines(model, params, batch)
    return [int(i) for i in np.argsort(mix_s) if np.isfinite(mix_s[i])]


def ffn_skip_order(model, params, batch, mm) -> list:
    _, ffn_s = block_cosines(model, params, batch)
    L = mm.n_layers
    return [int(L + i) for i in np.argsort(ffn_s) if np.isfinite(ffn_s[i])]


def random_drop_order(model, mm, seed=0) -> list:
    rng = np.random.default_rng(seed)
    layout = decoder.default_layout(model.cfg)
    present = np.array([s.mixer is not None for s in layout]
                       + [s.ffn is not None for s in layout])
    return [int(i) for i in rng.permutation(np.nonzero(present)[0])]


def oneshot_ppl_order(model, params, batch, chunk: int = 8) -> list:
    """RAP^-GSI: dense-model one-shot Δppl scores, no re-evaluation."""
    scores = gsi_lib.oneshot_rank(model, params, batch, chunk=chunk)
    return [int(i) for i in np.argsort(scores) if np.isfinite(scores[i])]


def llmpruner_order(model, params, batch, mm) -> list:
    sal = taylor_saliency(model, params, batch)
    return [int(i) for i in np.argsort(sal) if np.isfinite(sal[i])]


def shortgpt_mask(model, params, batch, mm, bs, sql, budget) -> np.ndarray:
    """Layer-level: removes (mixer, ffn) pairs by combined cosine influence."""
    return prune_by_order(shortgpt_order(model, params, batch, mm),
                          mm, bs, sql, budget)


def mha_drop_mask(model, params, batch, mm, bs, sql, budget) -> np.ndarray:
    return prune_by_order(mha_drop_order(model, params, batch, mm),
                          mm, bs, sql, budget)


def ffn_skip_mask(model, params, batch, mm, bs, sql, budget) -> np.ndarray:
    return prune_by_order(ffn_skip_order(model, params, batch, mm),
                          mm, bs, sql, budget)


def random_drop_mask(model, mm, bs, sql, budget, seed=0) -> np.ndarray:
    return prune_by_order(random_drop_order(model, mm, seed=seed),
                          mm, bs, sql, budget)


def oneshot_ppl_mask(model, params, batch, mm, bs, sql, budget,
                     chunk: int = 8) -> np.ndarray:
    """RAP^-GSI: dense-model one-shot Δppl scores, no re-evaluation."""
    return prune_by_order(oneshot_ppl_order(model, params, batch, chunk=chunk),
                          mm, bs, sql, budget)


def llmpruner_mask(model, params, batch, mm, bs, sql, budget) -> np.ndarray:
    return prune_by_order(llmpruner_order(model, params, batch, mm),
                          mm, bs, sql, budget)


# ------------------------------------------------------- SliceGPT stand-in
def slicegpt_slice(model, params, ratio: float):
    """Uniform width slicing to ``ratio``: keeps the top-|L2| d_ff channels
    and the top-|L2| whole query-head groups (KV heads and their G query
    heads slice together so GQA stays consistent). Returns (params', cfg')
    evaluable exactly like any other model."""
    cfg = model.cfg
    keep_f = max(8, int(round(cfg.d_ff * ratio)))
    kv_keep = max(1, int(round(cfg.n_kv_heads * ratio)))
    G = cfg.n_heads // max(cfg.n_kv_heads, 1)
    new_cfg = cfg.replace(d_ff=keep_f, n_kv_heads=kv_keep,
                          n_heads=kv_keep * G, head_dim=cfg.dh)

    p = jax.tree.map(lambda x: x, params)  # shallow copy
    st = dict(p["stacks"])

    if "dense" in st:
        def slice_ffn(tree):
            wi, wo = tree["wi"], tree["wo"]          # [L,D,2F], [L,F,D]
            F = cfg.d_ff
            gate, up = wi[..., :F], wi[..., F:]
            norm = (jnp.linalg.norm(gate.astype(jnp.float32), axis=1)
                    + jnp.linalg.norm(up.astype(jnp.float32), axis=1)
                    + jnp.linalg.norm(wo.astype(jnp.float32), axis=2))  # [L,F]
            idx = jnp.argsort(-norm, axis=1)[:, :keep_f]                # [L,f]
            take = jax.vmap(lambda m, i: m[:, i], in_axes=(0, 0))
            new = dict(tree)
            if cfg.activation in ("swiglu", "geglu"):
                new["wi"] = jnp.concatenate(
                    [take(gate, idx), take(up, idx)], axis=-1)
            else:
                new["wi"] = take(wi, idx)
            new["wo"] = jax.vmap(lambda m, i: m[i, :], in_axes=(0, 0))(wo, idx)
            return new
        st["dense"] = slice_ffn(st["dense"])

    if "attn" in st and cfg.n_kv_heads > 0:
        def slice_attn(tree):
            dh, K = cfg.dh, cfg.n_kv_heads
            wk = tree["wk"].reshape(cfg.n_layers, cfg.d_model, K, dh)
            norm = jnp.linalg.norm(
                wk.astype(jnp.float32), axis=(1, 3))                    # [L,K]
            kidx = jnp.argsort(-norm, axis=1)[:, :kv_keep]              # [L,k]
            def take_kv(m):
                mr = m.reshape(cfg.n_layers, cfg.d_model, K, dh)
                return jax.vmap(lambda x, i: x[:, i], in_axes=(0, 0))(
                    mr, kidx).reshape(cfg.n_layers, cfg.d_model, kv_keep * dh)
            def take_q(m):
                mr = m.reshape(cfg.n_layers, cfg.d_model, K, G, dh)
                return jax.vmap(lambda x, i: x[:, i], in_axes=(0, 0))(
                    mr, kidx).reshape(cfg.n_layers, cfg.d_model,
                                      kv_keep * G * dh)
            def take_o(m):
                mr = m.reshape(cfg.n_layers, K, G, dh, cfg.d_model)
                return jax.vmap(lambda x, i: x[i], in_axes=(0, 0))(
                    mr, kidx).reshape(cfg.n_layers, kv_keep * G * dh,
                                      cfg.d_model)
            new = dict(tree)
            new["wq"] = take_q(tree["wq"])
            new["wk"] = take_kv(tree["wk"])
            new["wv"] = take_kv(tree["wv"])
            new["wo"] = take_o(tree["wo"])
            if cfg.qkv_bias:
                def take_bkv(b):
                    br = b.reshape(cfg.n_layers, K, dh)
                    return jax.vmap(lambda x, i: x[i], in_axes=(0, 0))(
                        br, kidx).reshape(cfg.n_layers, kv_keep * dh)
                def take_bq(b):
                    br = b.reshape(cfg.n_layers, K, G, dh)
                    return jax.vmap(lambda x, i: x[i], in_axes=(0, 0))(
                        br, kidx).reshape(cfg.n_layers, kv_keep * G * dh)
                new["bq"] = take_bq(tree["bq"])
                new["bk"] = take_bkv(tree["bk"])
                new["bv"] = take_bkv(tree["bv"])
            return new
        st["attn"] = slice_attn(st["attn"])

    p = dict(p)
    p["stacks"] = st
    return p, new_cfg


def slicegpt_fit_ratio(cfg, mm: MemoryModel, bs, sql, budget,
                       tol: float = 1e-3) -> float:
    """Bisect the width ratio whose (params+KV) footprint meets the budget.
    Width slicing scales block params ~ratio and KV cache ~ratio."""
    lo, hi = 0.05, 1.0
    L = cfg.n_layers
    full = masks_lib.full_mask(L)
    embed = mm.embed_bytes
    blocks = mm.param_bytes(full) - embed
    state = mm.state_bytes(full, bs, sql)
    while hi - lo > tol:
        mid = 0.5 * (lo + hi)
        peak = embed + blocks * mid + state * mid
        if peak <= budget:
            lo = mid
        else:
            hi = mid
    return lo


BASELINES = ("shortgpt", "mha_drop", "ffn_skip", "random", "oneshot",
             "llmpruner", "slicegpt")
