"""Runtime memory model — paper Eq. (3)–(4), Appendix A.2.

Peak inference memory = static parameter bytes + dynamic state bytes
(KV cache for attention; recurrent/conv state for RG-LRU; SSD state for
Mamba-2; window cache for local attention). Block indexing convention used
across the RAP core:

    block b ∈ [0, 2L):  b <  L → mixer (MHA-class) block of layer b
                        b >= L → FFN-class block of layer b - L

Masks are boolean [2L] arrays (True = keep). All byte counts are analytical
and are validated against actual pytree sizes in tests.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import numpy as np

_DTYPE_BYTES = {"bfloat16": 2, "float16": 2, "float32": 4, "int8": 1}


def dtype_bytes(name: str) -> int:
    return _DTYPE_BYTES[name]


@dataclasses.dataclass(frozen=True)
class MemoryModel:
    """Per-block byte tables for one (cfg, request-shape) pair."""
    n_layers: int
    mixer_param_bytes: np.ndarray   # [L]
    ffn_param_bytes: np.ndarray     # [L]
    mixer_state_unit: np.ndarray    # [L] state bytes per (batch · token) — see note
    mixer_state_fixed: np.ndarray   # [L] state bytes per batch element (seq-independent)
    embed_bytes: int

    def param_bytes(self, mask: np.ndarray) -> float:
        m = np.asarray(mask)
        L = self.n_layers
        return (float(self.mixer_param_bytes @ m[:L])
                + float(self.ffn_param_bytes @ m[L:]) + self.embed_bytes)

    def state_bytes(self, mask: np.ndarray, batch: int, seq: int) -> float:
        m = np.asarray(mask)[: self.n_layers]
        batch, seq = max(int(batch), 0), max(int(seq), 0)
        per_tok = float(self.mixer_state_unit @ m) * batch * seq
        fixed = float(self.mixer_state_fixed @ m) * batch
        return per_tok + fixed

    def peak_bytes(self, mask: np.ndarray, batch: int, seq: int) -> float:
        """Eq. (3) + (4): Mem_param + Mem_state."""
        return self.param_bytes(mask) + self.state_bytes(mask, batch, seq)

    def dense_peak(self, batch: int, seq: int) -> float:
        return self.peak_bytes(np.ones(2 * self.n_layers, bool), batch, seq)

    def block_bytes(self, batch: int, seq: int) -> np.ndarray:
        """Per-block total footprint [2L] (params + state) for the reward.

        Guarded against degenerate request shapes: callers occasionally pass
        seq=0 (decode-only accounting) or negative deltas; the per-token term
        must vanish then while the seq-independent ``mixer_state_fixed``
        component (recurrent/conv/window state) is still charged per batch
        element.
        """
        L = self.n_layers
        batch, seq = max(int(batch), 0), max(int(seq), 0)
        out = np.zeros(2 * L)
        out[:L] = (self.mixer_param_bytes
                   + self.mixer_state_unit * batch * seq
                   + self.mixer_state_fixed * batch)
        out[L:] = self.ffn_param_bytes
        return out


def build_memory_model(cfg, *, param_bytes_per: Optional[int] = None,
                       kv_bytes_per: Optional[int] = None) -> MemoryModel:
    pb = param_bytes_per or dtype_bytes(cfg.param_dtype)
    kb = kv_bytes_per or dtype_bytes(cfg.dtype)
    L = cfg.n_layers
    mix_counts, ffn_counts = cfg.block_param_counts()
    mixer_pb = np.asarray(mix_counts, np.float64) * pb
    ffn_pb = np.asarray(ffn_counts, np.float64) * pb
    if cfg.is_encoder_decoder:
        # decoder cross-attn params ride with the mixer block
        cross = (cfg.d_model * (cfg.q_dim + 2 * cfg.kv_dim)
                 + cfg.q_dim * cfg.d_model + cfg.d_model) * pb
        mixer_pb = mixer_pb + cross

    unit = np.zeros(L)
    fixed = np.zeros(L)
    for i, (mixer, _) in enumerate(cfg.layer_specs()):
        if mixer == "attn":
            unit[i] = 2 * cfg.n_kv_heads * cfg.dh * kb       # K and V per token
        elif mixer == "local_attn":
            fixed[i] = 2 * cfg.attn_window * cfg.n_kv_heads * cfg.dh * kb
        elif mixer == "rglru":
            W = cfg.rnn_width or cfg.d_model
            fixed[i] = W * 4 + 3 * W * kb                    # f32 state + conv buf
        elif mixer == "ssd":
            fixed[i] = (cfg.ssm_heads * cfg.ssm_head_dim * cfg.ssm_state * 4
                        + (cfg.ssm_conv_width - 1)
                        * (cfg.ssm_inner + 2 * cfg.ssm_state) * kb)
    if cfg.is_encoder_decoder:
        # cross-attn KV is fixed-size (encoder length), rides with mixer block
        fixed += 2 * cfg.n_audio_frames * cfg.n_kv_heads * cfg.dh * kb

    return MemoryModel(
        n_layers=L,
        mixer_param_bytes=mixer_pb,
        ffn_param_bytes=ffn_pb,
        mixer_state_unit=unit,
        mixer_state_fixed=fixed,
        embed_bytes=cfg.embed_params() * pb,
    )


def budget_bytes(mm: MemoryModel, batch: int, seq: int, fraction: float) -> float:
    """`fraction` of the dense model's peak (the paper's 80%/60% budgets)."""
    return fraction * mm.dense_peak(batch, seq)


# ------------------------------------------------------------ pool accounting
class PoolExhausted(RuntimeError):
    """Raised when a reservation cannot fit the shared pool budget."""


@dataclasses.dataclass
class PoolAccounting:
    """Reserved-vs-in-use byte ledger for a shared device pool.

    The KV pool grants memory at *page* granularity, so two numbers
    describe its pressure at any instant:

      * ``reserved_bytes`` — bytes granted to live allocations (page-rounded;
        this is what actually occupies the device budget);
      * ``in_use_bytes``   — exact bytes the requests asked for (the
        analytical Eq. (3)–(4) state footprint).

    ``reserved - in_use`` is internal fragmentation. The ledger enforces the
    hard invariant ``reserved_bytes <= capacity_bytes`` unless the caller
    explicitly overcommits (legacy one-shot serving executes regardless of
    fit; the engine's strict admission path never does).

    ``in_use_scale`` is the pool's byte width relative to the analytical
    memory model: admission charges arrive in model-dtype bytes (Eq. (3)–(4)
    at the model's KV width), but a quantized pool stores each element
    narrower (plus per-page scales). Every in-use charge is multiplied by
    this ratio on entry so ``in_use_bytes`` / ``peak_in_use_bytes`` /
    ``fragmentation()`` report *physical* bytes — without it, an int8 pool's
    ledger would claim 4× its true occupancy and fragmentation would go
    negative. Reserved bytes are already physical (page-granular) and are
    never scaled.
    """
    capacity_bytes: float
    reserved_bytes: float = 0.0
    in_use_bytes: float = 0.0
    peak_reserved_bytes: float = 0.0
    peak_in_use_bytes: float = 0.0
    overcommit_events: int = 0
    in_use_scale: float = 1.0

    @property
    def available_bytes(self) -> float:
        return max(self.capacity_bytes - self.reserved_bytes, 0.0)

    def can_reserve(self, reserved: float) -> bool:
        return self.reserved_bytes + reserved <= self.capacity_bytes

    def reserve(self, reserved: float, in_use: float, *,
                allow_overcommit: bool = False) -> None:
        in_use = in_use * self.in_use_scale
        if in_use > reserved + 1e-6:
            raise ValueError(f"in_use {in_use} exceeds reservation {reserved}")
        if not self.can_reserve(reserved):
            if not allow_overcommit:
                raise PoolExhausted(
                    f"reserve {reserved:.0f}B > available "
                    f"{self.available_bytes:.0f}B "
                    f"(capacity {self.capacity_bytes:.0f}B)")
            self.overcommit_events += 1
        self.reserved_bytes += reserved
        self.in_use_bytes += in_use
        self.peak_reserved_bytes = max(self.peak_reserved_bytes,
                                       self.reserved_bytes)
        self.peak_in_use_bytes = max(self.peak_in_use_bytes,
                                     self.in_use_bytes)

    def grow(self, reserved_delta: float, in_use_delta: float) -> None:
        """Incremental variant of :meth:`reserve` for allocations that grow
        over time (per-token page appends): unlike ``reserve``, the
        ``in_use <= reserved`` consistency is an invariant of the *totals*,
        not of each call — an append may raise in-use bytes without granting
        a new page (the token lands in a partially filled page). Strict
        only: the token-granular pool path never overcommits (overflow
        pages would have no physical backing)."""
        if not self.can_reserve(reserved_delta):
            raise PoolExhausted(
                f"grow {reserved_delta:.0f}B > available "
                f"{self.available_bytes:.0f}B "
                f"(capacity {self.capacity_bytes:.0f}B)")
        self.reserved_bytes += reserved_delta
        self.in_use_bytes += in_use_delta * self.in_use_scale
        self.peak_reserved_bytes = max(self.peak_reserved_bytes,
                                       self.reserved_bytes)
        self.peak_in_use_bytes = max(self.peak_in_use_bytes,
                                     self.in_use_bytes)

    def release(self, reserved: float, in_use: float) -> None:
        self.reserved_bytes = max(self.reserved_bytes - reserved, 0.0)
        self.in_use_bytes = max(
            self.in_use_bytes - in_use * self.in_use_scale, 0.0)

    def fragmentation(self) -> float:
        """Internal fragmentation: wasted fraction of reserved bytes."""
        if self.reserved_bytes <= 0:
            return 0.0
        return 1.0 - self.in_use_bytes / self.reserved_bytes

    def occupancy(self) -> float:
        if self.capacity_bytes <= 0:
            return 0.0
        return self.reserved_bytes / self.capacity_bytes
