"""Masked DQN controller — paper Appendix A.3/A.4 (Algorithm 2).

Pure-JAX Q-network (the paper's compact 2-layer MLP, ~18K params at
Llama2-7B scale), masked ε-greedy behaviour policy, uniform replay, soft
target updates, Adam. The jitted pieces are the Q forward and the TD update;
the environment loop stays in Python (it calls the GSI scorer, itself a
jitted batched forward).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Callable, List, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.optim import adamw

NEG = -1e9


@dataclasses.dataclass(frozen=True)
class DQNConfig:
    hidden: int = 64
    lr: float = 1e-3
    gamma: float = 0.99
    tau: float = 0.01            # soft target update
    eps_start: float = 1.0
    eps_end: float = 0.05
    eps_decay_episodes: int = 60
    buffer_size: int = 20000
    batch_size: int = 64
    train_iters_per_step: int = 1


def init_qnet(rng, state_dim: int, n_actions: int, hidden: int):
    k1, k2 = jax.random.split(rng)
    s1 = 1.0 / np.sqrt(state_dim)
    s2 = 1.0 / np.sqrt(hidden)
    return {
        "w1": jax.random.normal(k1, (state_dim, hidden), jnp.float32) * s1,
        "b1": jnp.zeros((hidden,), jnp.float32),
        "w2": jax.random.normal(k2, (hidden, n_actions), jnp.float32) * s2,
        "b2": jnp.zeros((n_actions,), jnp.float32),
    }


def q_apply(params, s):
    h = jnp.tanh(s @ params["w1"] + params["b1"])
    return h @ params["w2"] + params["b2"]


def n_params(params) -> int:
    return sum(int(np.prod(l.shape)) for l in jax.tree.leaves(params))


class Replay:
    def __init__(self, size: int, state_dim: int, n_actions: int):
        self.size, self.ptr, self.full = size, 0, False
        self.s = np.zeros((size, state_dim), np.float32)
        self.a = np.zeros((size,), np.int32)
        self.r = np.zeros((size,), np.float32)
        self.s2 = np.zeros((size, state_dim), np.float32)
        self.d = np.zeros((size,), np.float32)
        self.valid2 = np.zeros((size, n_actions), bool)

    def add(self, s, a, r, s2, d, valid2):
        i = self.ptr
        self.s[i], self.a[i], self.r[i] = s, a, r
        self.s2[i], self.d[i], self.valid2[i] = s2, d, valid2
        self.ptr = (i + 1) % self.size
        self.full = self.full or self.ptr == 0

    def __len__(self):
        return self.size if self.full else self.ptr

    def sample(self, rng: np.random.Generator, n: int):
        idx = rng.integers(0, len(self), size=n)
        return (self.s[idx], self.a[idx], self.r[idx], self.s2[idx],
                self.d[idx], self.valid2[idx])


@functools.partial(jax.jit, static_argnames=("gamma",))
def td_update(qp, tp, opt_state, batch, gamma: float, opt_cfg_lr: float):
    s, a, r, s2, d, valid2 = batch

    def loss_fn(qp):
        q = q_apply(qp, s)
        qa = jnp.take_along_axis(q, a[:, None], axis=1)[:, 0]
        q2 = q_apply(tp, s2)
        q2 = jnp.where(valid2, q2, NEG)
        target = r + gamma * (1.0 - d) * jnp.max(q2, axis=1)
        return jnp.mean(jnp.square(qa - jax.lax.stop_gradient(target)))

    loss, grads = jax.value_and_grad(loss_fn)(qp)
    cfg = adamw.AdamWConfig(lr=opt_cfg_lr, weight_decay=0.0, clip_norm=1.0,
                            warmup_steps=0, schedule="constant")
    qp, opt_state, _ = adamw.apply(cfg, qp, grads, opt_state)
    return qp, opt_state, loss


@jax.jit
def soft_update(tp, qp, tau: float):
    return jax.tree.map(lambda t, q: (1 - tau) * t + tau * q, tp, qp)


def select_action(qp, s, valid: np.ndarray, eps: float,
                  rng: np.random.Generator) -> int:
    if rng.random() < eps:
        return int(rng.choice(np.nonzero(valid)[0]))
    q = np.array(q_apply(qp, jnp.asarray(s)))
    q[~valid] = NEG
    return int(np.argmax(q))


@dataclasses.dataclass
class TrainResult:
    q_params: dict
    episode_rewards: List[float]
    episode_fits: List[bool]
    losses: List[float]


def train(env_factory: Callable[[], tuple], *, episodes: int,
          cfg: DQNConfig = DQNConfig(), seed: int = 0,
          request_sampler: Optional[Callable] = None) -> TrainResult:
    """Algorithm 2. ``env_factory() → env``; ``request_sampler(rng) →
    (bs, sql, budget_bytes)`` samples the per-episode workload."""
    rng = np.random.default_rng(seed)
    env = env_factory()
    qp = init_qnet(jax.random.key(seed), env.state_dim, env.n_actions,
                   cfg.hidden)
    tp = jax.tree.map(jnp.copy, qp)
    opt_state = adamw.init(qp)
    buf = Replay(cfg.buffer_size, env.state_dim, env.n_actions)

    rewards, fits, losses = [], [], []
    for ep in range(episodes):
        eps = max(cfg.eps_end,
                  cfg.eps_start - (cfg.eps_start - cfg.eps_end)
                  * ep / max(cfg.eps_decay_episodes, 1))
        bs, sql, budget = request_sampler(rng)
        s = env.reset(bs, sql, budget)
        total, done = 0.0, False
        while not done:
            valid = env.valid_actions()
            a = select_action(qp, s, valid, eps, rng)
            s2, r, done, info = env.step(a)
            buf.add(s, a, r, s2, float(done), env.valid_actions())
            s = s2
            total += r
            if len(buf) >= cfg.batch_size:
                for _ in range(cfg.train_iters_per_step):
                    batch = buf.sample(rng, cfg.batch_size)
                    qp, opt_state, loss = td_update(
                        qp, tp, opt_state,
                        tuple(jnp.asarray(x) for x in batch),
                        cfg.gamma, cfg.lr)
                    losses.append(float(loss))
                tp = soft_update(tp, qp, cfg.tau)
        rewards.append(total)
        fits.append(bool(info["fits"]))
    return TrainResult(qp, rewards, fits, losses)
