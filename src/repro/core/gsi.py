"""Greedy Sequential Importance (paper §4.1, Algorithm 1).

The paper scores each candidate block removal by evaluating perplexity on a
calibration corpus, removes the least-damaging block, then *re-scores every
remaining block on the contracted model* — capturing inter-layer dependence
that one-shot scoring misses.

Beyond-paper optimization (recorded in EXPERIMENTS.md §Perf): the paper
evaluates the candidates serially (one forward per candidate). Here all
candidates are scored in a single batched forward — candidate gate vectors
are mapped over with ``vmap``/``lax.map`` on the *gates* input of the shared
masked executable, so one jit-compiled program scores every block.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Callable, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import masks as masks_lib


def make_ppl_fn(model, batch) -> Callable[[dict, jnp.ndarray], jnp.ndarray]:
    """Returns jitted fn(params, mask_f32[2L]) → log-perplexity (scalar f32)."""
    L = model.cfg.n_layers

    @jax.jit
    def log_ppl(params, mask):
        gates = {"mixer": mask[:L], "ffn": mask[L:]}
        loss, _ = model.loss(params, batch, gates=gates)
        return loss  # mean NLL == log(ppl)

    return log_ppl


def make_candidate_scorer(model, batch, *, chunk: int = 8):
    """Returns jitted fn(params, mask) → scores[2L]:

    scores[b] = log-ppl of the model with block b additionally removed
                (+inf where b is already inactive).
    """
    L = model.cfg.n_layers
    n = 2 * L

    def score(params, mask):
        eye = jnp.eye(n, dtype=mask.dtype)
        cand = jnp.clip(mask[None, :] - eye, 0.0, 1.0)  # [2L, 2L]

        def one(m):
            gates = {"mixer": m[:L], "ffn": m[L:]}
            loss, _ = model.loss(params, batch, gates=gates)
            return loss

        if chunk >= n:
            scores = jax.vmap(one)(cand)
        else:
            pad = (-n) % chunk
            cand_p = jnp.pad(cand, ((0, pad), (0, 0)))
            scores = jax.lax.map(jax.vmap(one),
                                 cand_p.reshape(-1, chunk, n)).reshape(-1)[:n]
        return jnp.where(mask > 0.5, scores, jnp.inf)

    return jax.jit(score)


@dataclasses.dataclass
class GSIResult:
    order: list            # blocks in removal order
    ppl_trace: list        # log-ppl after each removal
    score_snapshots: list  # [step][2L] candidate scores at each state
    final_mask: np.ndarray


def importance_scores(scores: np.ndarray, current_log_ppl: float) -> np.ndarray:
    """RL-state importance: Δlog-ppl caused by removing each block (≥ 0);
    inactive blocks get 0."""
    imp = np.asarray(scores, np.float64) - float(current_log_ppl)
    imp = np.where(np.isfinite(imp), np.maximum(imp, 0.0), 0.0)
    return imp


def gsi_rank(model, params, batch, *, stop: Optional[Callable] = None,
             max_removals: Optional[int] = None, chunk: int = 8,
             mask: Optional[np.ndarray] = None) -> GSIResult:
    """Algorithm 1. ``stop(mask) → bool`` ends early (e.g. memory target met);
    default runs until ``max_removals`` (or 2L-2) blocks are gone."""
    L = model.cfg.n_layers
    scorer = make_candidate_scorer(model, batch, chunk=chunk)
    ppl_fn = make_ppl_fn(model, batch)
    mask = masks_lib.full_mask(L) if mask is None else np.array(mask, copy=True)
    max_removals = max_removals if max_removals is not None else 2 * L - 2

    order, trace, snaps = [], [], []
    for _ in range(max_removals):
        if stop is not None and stop(mask):
            break
        scores = np.asarray(scorer(params, jnp.asarray(mask, jnp.float32)))
        snaps.append(scores)
        k = int(np.argmin(scores))
        if not np.isfinite(scores[k]):
            break
        mask[k] = False
        order.append(k)
        trace.append(float(scores[k]))
    return GSIResult(order, trace, snaps, mask)


def oneshot_rank(model, params, batch, *, chunk: int = 8) -> np.ndarray:
    """One-shot scores on the dense model (the RAP^-GSI ablation):
    scores[b] = log-ppl with only block b removed; no re-evaluation."""
    L = model.cfg.n_layers
    scorer = make_candidate_scorer(model, batch, chunk=chunk)
    return np.asarray(scorer(params, jnp.ones(2 * L, jnp.float32)))
