"""RAP online controller — paper Algorithm 3.

Given the trained Q-network, an incoming request (batch, seq_len) and the
measured memory budget, greedily removes blocks (masked argmax over Q) until
the analytical peak fits. Produces a block mask; the serving runtime turns
it into gates (masked mode) or a compacted executable (structural mode,
cached per bucket).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, Optional, Tuple

import jax.numpy as jnp
import numpy as np

from repro.core import dqn as dqn_lib
from repro.core import gsi as gsi_lib
from repro.core import masks as masks_lib
from repro.core.env import EnvConfig
from repro.core.memory import MemoryModel


@dataclasses.dataclass
class Decision:
    mask: np.ndarray
    steps: int
    peak_bytes: float
    fits: bool
    latency_s: float
    cached: bool = False      # served from the (bucket, shape) memo table
    # per-request KV storage precision the policy asks for (canonical name
    # "fp32"/"bf16"/"int8"/"fp8", or None = the serving pool's precision);
    # the engine charges admission at this width and KVPool.alloc_tokens
    # rejects a request whose precision disagrees with the bound pool
    kv_dtype: Optional[str] = None


class RAPController:
    """Holds (Q-params, GSI scorer, memory model) for one served model."""

    def __init__(self, model, params, calib_batch, mm: MemoryModel,
                 q_params: dict, env_cfg: EnvConfig = EnvConfig(),
                 chunk: int = 8, recompute_scores: bool = True):
        self.model = model
        self.params = params
        self.mm = mm
        self.q_params = q_params
        self.env_cfg = env_cfg
        self.L = model.cfg.n_layers
        self.recompute = recompute_scores
        self._scorer = gsi_lib.make_candidate_scorer(model, calib_batch,
                                                     chunk=chunk)
        self._ppl = gsi_lib.make_ppl_fn(model, calib_batch)
        self._dense_cache: Optional[np.ndarray] = None
        self._memo: Dict[Tuple, Decision] = {}

    def _importance(self, mask: np.ndarray) -> np.ndarray:
        if not self.recompute and self._dense_cache is not None:
            return self._dense_cache
        cur = float(self._ppl(self.params, jnp.asarray(mask, jnp.float32)))
        raw = np.asarray(self._scorer(self.params,
                                      jnp.asarray(mask, jnp.float32)))
        imp = gsi_lib.importance_scores(raw, cur)
        if self._dense_cache is None:
            self._dense_cache = imp
        return imp

    def _obs(self, mask, imp, bs, sql, budget) -> np.ndarray:
        peak = self.mm.peak_bytes(mask, bs, sql)
        dense = self.mm.dense_peak(bs, sql)
        c = self.env_cfg
        return np.concatenate([
            [bs / c.bs_norm, sql / c.sql_norm],
            imp[: self.L] / c.imp_norm, imp[self.L:] / c.imp_norm,
            [budget / dense, peak / dense],
        ]).astype(np.float32)

    def decide(self, bs: int, sql: int, budget_bytes: float, *,
               reserved_bytes: float = 0.0, memo: bool = True) -> Decision:
        """Algorithm 3: prune until Mem_peak ≤ B (or STOP / exhaustion).

        Batch-aware form for the continuous-batching engine:
        ``reserved_bytes`` is the dynamic state already resident for other
        in-flight requests (the KV pool's reserved bytes) — this request must
        fit in what remains of the shared device budget, so the effective
        budget is ``budget_bytes - reserved_bytes``.

        Decisions are memoized by (bucket, shape): the key quantizes the
        effective-budget/dense-peak ratio to 0.1% so the engine's
        continuously drifting pool level collapses onto a small table and
        steady-state admission skips the greedy Q-rollout entirely.
        """
        t0 = time.perf_counter()
        budget_bytes = budget_bytes - reserved_bytes
        key = (int(bs), int(sql),
               round(budget_bytes / max(self.mm.dense_peak(bs, sql), 1.0), 3))
        if memo and key in self._memo:
            d = self._memo[key]
            # fits is re-derived against THIS call's budget: the memo cell
            # quantizes to 0.1% of dense, so a cached fits could straddle
            # the boundary for a slightly smaller budget in the same cell
            return dataclasses.replace(
                d, mask=d.mask.copy(), cached=True,
                fits=d.peak_bytes <= budget_bytes,
                latency_s=time.perf_counter() - t0)
        mask = masks_lib.full_mask(self.L)
        imp = self._importance(mask)
        steps = 0
        while (self.mm.peak_bytes(mask, bs, sql) > budget_bytes
               and steps < 2 * self.L):
            s = self._obs(mask, imp, bs, sql, budget_bytes)
            q = np.array(dqn_lib.q_apply(self.q_params, jnp.asarray(s)))
            # memory-aware action mask: while over budget, STOP is invalid
            stop_ok = (not self.env_cfg.mask_stop_until_fit) or not mask.any()
            valid = np.concatenate([[stop_ok], mask])
            if not valid.any():
                break
            q[~valid] = dqn_lib.NEG
            a = int(np.argmax(q))
            if a == 0:
                break
            mask = masks_lib.remove_block(mask, a - 1)
            steps += 1
            if self.recompute:
                imp = self._importance(mask)
        peak = self.mm.peak_bytes(mask, bs, sql)
        d = Decision(mask=mask, steps=steps, peak_bytes=peak,
                     fits=peak <= budget_bytes,
                     latency_s=time.perf_counter() - t0)
        if memo:
            self._memo[key] = dataclasses.replace(d, mask=mask.copy())
        return d
