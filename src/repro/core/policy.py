"""Pruning policies — the decision seam of the serving stack.

The engine used to hard-code ``RAPController``; this module makes the
decision step a protocol so *any* pruning strategy can serve against the
live shared budget:

    PolicyState (what the engine observes at admission time)
        │
        ▼
    PruningPolicy.observe(state) ──► Decision (block keep-mask + peak)
        ▲                                  │
        └── PruningPolicy.feedback(result) ┘  (after the request completes)

Implementations:
  * :class:`RLPolicy` — the paper's DQN controller (Algorithm 3), wrapping
    :class:`repro.core.controller.RAPController`;
  * :class:`StaticOrderPolicy` — every static baseline in
    ``repro.core.baselines`` (ShortGPT, LLMPruner, MHA-drop, FFN-skip,
    one-shot PPL, random drop): a fixed removal order is scored ONCE per
    served model, then each observation greedily removes blocks in that
    order until the analytical peak fits the instantaneous budget —
    exactly the paper's §5.1 protocol, but now against the engine's live
    pool level instead of an offline budget sweep;
  * :class:`DensePolicy` — never prunes (the no-op lower bound).

Policies register under a name in :data:`POLICIES`; ``make_policy()``
builds one from the same (model, params, calib, mm) tuple the engine
already has, so launchers and benchmarks select policies by flag.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, Optional, Tuple

import numpy as np

from repro.core import baselines as baselines_lib
from repro.core import masks as masks_lib
from repro.core.controller import Decision, RAPController
from repro.core.memory import MemoryModel

__all__ = ["Decision", "PolicyState", "PruningPolicy", "RLPolicy",
           "StaticOrderPolicy", "DensePolicy", "POLICIES",
           "available_policies", "make_policy", "register_policy"]


@dataclasses.dataclass(frozen=True)
class PolicyState:
    """The engine's observation at admission time.

    ``budget_bytes`` is the *effective* budget this request must fit —
    for a pooled engine that is total budget minus bytes reserved by
    in-flight requests (already quantized by the engine's admission
    grid); for one-shot serving it is the request's instantaneous budget.
    The pool context fields let richer policies condition on contention.
    """
    batch: int
    total_len: int                 # prompt + generated tokens
    budget_bytes: float
    reserved_bytes: float = 0.0    # pool bytes held by in-flight requests
    capacity_bytes: float = 0.0    # pool capacity (0 when unpooled)
    n_running: int = 0
    now: float = 0.0               # engine virtual-clock timestamp


class PruningPolicy:
    """Protocol: map a :class:`PolicyState` to a keep-mask Decision.

    Subclasses must set ``name`` and ``mm`` (the analytical
    :class:`~repro.core.memory.MemoryModel` the engine shares for
    admission accounting) and implement :meth:`observe`. The
    :meth:`feedback` hook closes the loop after a request finishes —
    online policies can learn from outcomes; the default is a no-op.
    """

    name: str = "base"
    mm: MemoryModel
    # KV storage precision this policy asks the engine to serve requests
    # at ("fp32"/"bf16"/"int8"/"fp8", or None = the pool's native width).
    # Launchers set it once (``--kv-dtype``); every Decision carries it so
    # admission charges quantized bytes and the pool can reject mismatches.
    kv_dtype: Optional[str] = None

    def observe(self, state: PolicyState) -> Decision:
        raise NotImplementedError

    def _stamp(self, d: Decision) -> Decision:
        """Attach this policy's requested KV precision to a Decision."""
        if self.kv_dtype is None or d.kv_dtype == self.kv_dtype:
            return d
        return dataclasses.replace(d, kv_dtype=self.kv_dtype)

    def feedback(self, result) -> None:
        """Called with the completed request's ``RequestResult``."""
        return None


class RLPolicy(PruningPolicy):
    """The paper's RL agent: greedy masked-argmax over Q until the peak
    fits (Algorithm 3), memoized by (bucket, shape) inside the
    controller."""

    name = "rl"

    def __init__(self, controller: RAPController):
        self.controller = controller
        self.mm = controller.mm

    def observe(self, state: PolicyState) -> Decision:
        return self._stamp(self.controller.decide(state.batch,
                                                  state.total_len,
                                                  state.budget_bytes))


class DensePolicy(PruningPolicy):
    """Never prunes — the dense upper bound (and worst-case admission)."""

    name = "dense"

    def __init__(self, mm: MemoryModel):
        self.mm = mm

    def observe(self, state: PolicyState) -> Decision:
        mask = masks_lib.full_mask(self.mm.n_layers)
        peak = self.mm.peak_bytes(mask, state.batch, state.total_len)
        return self._stamp(Decision(mask=mask, steps=0, peak_bytes=peak,
                                    fits=peak <= state.budget_bytes,
                                    latency_s=0.0))


class StaticOrderPolicy(PruningPolicy):
    """Prune blocks in a fixed precomputed order until the peak fits.

    The order (the expensive model probe: cosine influence, Taylor
    saliency, Δppl rank, …) is computed once at construction; each
    ``observe`` is then a cheap analytical loop, memoized on the same
    (batch, total, budget/dense-ratio) grid the RL controller uses so
    steady-state admissions are O(1).
    """

    def __init__(self, mm: MemoryModel, order, name: str):
        self.mm = mm
        self.order = [int(b) for b in order]
        self.name = name
        self._memo: Dict[Tuple, Decision] = {}

    def observe(self, state: PolicyState) -> Decision:
        t0 = time.perf_counter()
        bs, sql, budget = state.batch, state.total_len, state.budget_bytes
        key = (int(bs), int(sql),
               round(budget / max(self.mm.dense_peak(bs, sql), 1.0), 3))
        if key in self._memo:
            d = self._memo[key]
            return self._stamp(dataclasses.replace(
                d, mask=d.mask.copy(), cached=True,
                fits=d.peak_bytes <= budget,
                latency_s=time.perf_counter() - t0))
        mask = baselines_lib.prune_by_order(self.order, self.mm, bs, sql,
                                            budget)
        peak = self.mm.peak_bytes(mask, bs, sql)
        d = Decision(mask=mask, steps=int(2 * self.mm.n_layers - mask.sum()),
                     peak_bytes=peak, fits=peak <= budget,
                     latency_s=time.perf_counter() - t0)
        self._memo[key] = dataclasses.replace(d, mask=mask.copy())
        return self._stamp(d)


# ---------------------------------------------------------------- registry
PolicyBuilder = Callable[..., PruningPolicy]
POLICIES: Dict[str, PolicyBuilder] = {}


def register_policy(name: str):
    """Decorator: register a builder under ``name`` for ``make_policy``."""
    def deco(builder: PolicyBuilder) -> PolicyBuilder:
        POLICIES[name] = builder
        return builder
    return deco


def available_policies() -> Tuple[str, ...]:
    return tuple(sorted(POLICIES))


def make_policy(name: str, *, model=None, params=None, calib=None,
                mm: Optional[MemoryModel] = None,
                controller: Optional[RAPController] = None,
                seed: int = 0) -> PruningPolicy:
    """Build a registered policy from the serving context.

    ``rl`` needs a trained ``controller``; the static baselines need
    (model, params, calib, mm) to score their removal order; ``random``
    and ``dense`` need only (model,) mm.
    """
    if name not in POLICIES:
        raise KeyError(f"unknown policy {name!r}; available: "
                       f"{', '.join(available_policies())}")
    return POLICIES[name](model=model, params=params, calib=calib, mm=mm,
                          controller=controller, seed=seed)


def _require(name, **kwargs):
    missing = [k for k, v in kwargs.items() if v is None]
    if missing:
        raise ValueError(f"policy {name!r} requires {', '.join(missing)}")


@register_policy("rl")
def _build_rl(*, controller=None, **_):
    _require("rl", controller=controller)
    return RLPolicy(controller)


@register_policy("dense")
def _build_dense(*, mm=None, **_):
    _require("dense", mm=mm)
    return DensePolicy(mm)


@register_policy("random")
def _build_random(*, model=None, mm=None, seed=0, **_):
    _require("random", model=model, mm=mm)
    order = baselines_lib.random_drop_order(model, mm, seed=seed)
    return StaticOrderPolicy(mm, order, "random")


def _static_builder(name: str, order_fn):
    @register_policy(name)
    def build(*, model=None, params=None, calib=None, mm=None, **_):
        _require(name, model=model, params=params, calib=calib, mm=mm)
        return StaticOrderPolicy(mm, order_fn(model, params, calib, mm), name)
    return build


_static_builder("shortgpt", baselines_lib.shortgpt_order)
_static_builder("mha_drop", baselines_lib.mha_drop_order)
_static_builder("ffn_skip", baselines_lib.ffn_skip_order)
_static_builder("llmpruner", baselines_lib.llmpruner_order)
_static_builder("oneshot",
                lambda model, params, calib, mm:
                baselines_lib.oneshot_ppl_order(model, params, calib))
