"""Synthetic serving workload + memory-availability traces (paper Fig. 2/5).

Models the two runtime-variance sources the paper identifies:
 * input-driven — request mix: bursty arrivals, bimodal prompt lengths
   (short conversational turns + long-form documents), diurnal modulation,
   batch sizes from queue depth  (Azure LLM-trace-like, Stojkovic 2025);
 * system-level — available-memory trace: base capacity minus co-running
   application interference (OU random walk + occasional spikes).

Everything is deterministic in the seed so experiments replay exactly.
"""
from __future__ import annotations

import dataclasses
from typing import Iterator, List, Tuple

import numpy as np


@dataclasses.dataclass(frozen=True)
class Request:
    t: float                 # arrival time (s)
    batch: int
    seq_len: int
    budget_frac: float       # available memory / dense peak at this instant


@dataclasses.dataclass
class WorkloadConfig:
    seed: int = 0
    horizon_s: float = 600.0
    base_rate: float = 0.5           # requests/s baseline
    burst_rate: float = 4.0          # requests/s during bursts
    burst_prob: float = 0.08
    short_len: Tuple[int, int] = (64, 512)
    long_len: Tuple[int, int] = (1024, 4096)
    long_frac: float = 0.25
    max_batch: int = 32
    mem_base: float = 1.0            # fraction of dense peak available
    mem_walk_sigma: float = 0.04
    mem_spike_prob: float = 0.03
    mem_spike_depth: Tuple[float, float] = (0.2, 0.5)
    mem_floor: float = 0.45
    round_len_to: int = 64


def generate(cfg: WorkloadConfig) -> List[Request]:
    rng = np.random.default_rng(cfg.seed)
    out: List[Request] = []
    t = 0.0
    mem = cfg.mem_base
    while t < cfg.horizon_s:
        diurnal = 1.0 + 0.5 * np.sin(2 * np.pi * t / cfg.horizon_s)
        rate = (cfg.burst_rate if rng.random() < cfg.burst_prob
                else cfg.base_rate) * diurnal
        t += float(rng.exponential(1.0 / max(rate, 1e-6)))
        # memory availability: mean-reverting walk + interference spikes
        mem += (rng.normal(0.0, cfg.mem_walk_sigma)
                + 0.1 * (cfg.mem_base - mem))
        if rng.random() < cfg.mem_spike_prob:
            mem -= rng.uniform(*cfg.mem_spike_depth)
        mem = float(np.clip(mem, cfg.mem_floor, 1.0))
        if rng.random() < cfg.long_frac:
            sql = int(rng.integers(*cfg.long_len))
        else:
            sql = int(rng.integers(*cfg.short_len))
        sql = max(cfg.round_len_to,
                  (sql // cfg.round_len_to) * cfg.round_len_to)
        bs = int(2 ** rng.integers(0, int(np.log2(cfg.max_batch)) + 1))
        out.append(Request(t=t, batch=bs, seq_len=sql, budget_frac=mem))
    return out


# ------------------------------------------------- engine arrival processes
@dataclasses.dataclass
class PoissonConfig:
    """Homogeneous-Poisson request stream for the continuous-batching engine.

    Unlike :func:`generate` (which models the paper's diurnal/bursty traffic
    over a long horizon), this produces a fixed-count trace with exponential
    interarrivals — the standard benchmark arrival process for serving
    engines — plus the same bimodal prompt-length mix. Budget here is the
    *shared* pool fraction, not a per-request instantaneous budget.
    """
    seed: int = 0
    n_requests: int = 16
    rate: float = 4.0                    # mean arrivals per second
    short_len: Tuple[int, int] = (32, 128)
    long_len: Tuple[int, int] = (128, 512)
    long_frac: float = 0.25
    round_len_to: int = 16
    budget_frac: float = 0.8             # recorded per request for replay
    batch: int = 1                       # sequences per request


def poisson_requests(cfg: PoissonConfig) -> List[Request]:
    """Fixed-count Poisson trace with arrival timestamps (t strictly
    increasing). Deterministic in ``cfg.seed``."""
    rng = np.random.default_rng(cfg.seed)
    out: List[Request] = []
    t = 0.0
    for _ in range(cfg.n_requests):
        t += float(rng.exponential(1.0 / max(cfg.rate, 1e-9)))
        if rng.random() < cfg.long_frac:
            sql = int(rng.integers(*cfg.long_len))
        else:
            sql = int(rng.integers(*cfg.short_len))
        sql = max(cfg.round_len_to,
                  (sql // cfg.round_len_to) * cfg.round_len_to)
        out.append(Request(t=t, batch=cfg.batch, seq_len=sql,
                           budget_frac=cfg.budget_frac))
    return out


def trace_requests(arrivals, seq_lens, *, batch: int = 1,
                   budget_frac: float = 0.8) -> List[Request]:
    """Replay an externally supplied (arrival_time, prompt_len) trace —
    e.g. Azure LLM-trace timestamps — as engine requests."""
    if len(arrivals) != len(seq_lens):
        raise ValueError("arrivals and seq_lens must be the same length")
    return [Request(t=float(t), batch=batch, seq_len=int(s),
                    budget_frac=budget_frac)
            for t, s in zip(arrivals, seq_lens)]


def request_sampler(cfg: WorkloadConfig, mm, *,
                    budget_range: Tuple[float, float] = (0.55, 0.95)):
    """Adapter for ``repro.core.dqn.train``: samples (bs, sql, budget_bytes)
    per episode from the workload distributions."""
    wl_rng = np.random.default_rng(cfg.seed + 77)
    reqs = generate(cfg)

    def sample(rng: np.random.Generator):
        r = reqs[int(rng.integers(0, len(reqs)))]
        frac = float(np.clip(r.budget_frac, *budget_range))
        budget = frac * mm.dense_peak(r.batch, r.seq_len)
        return r.batch, r.seq_len, budget

    del wl_rng
    return sample
