"""Synthetic serving workload + memory-availability traces (paper Fig. 2/5).

Models the two runtime-variance sources the paper identifies:
 * input-driven — request mix: bursty arrivals, bimodal prompt lengths
   (short conversational turns + long-form documents), diurnal modulation,
   batch sizes from queue depth  (Azure LLM-trace-like, Stojkovic 2025);
 * system-level — available-memory trace: base capacity minus co-running
   application interference (OU random walk + occasional spikes).

Everything is deterministic in the seed so experiments replay exactly.
"""
from __future__ import annotations

import dataclasses
from typing import Iterator, List, Tuple

import numpy as np


@dataclasses.dataclass(frozen=True)
class Request:
    t: float                 # arrival time (s)
    batch: int
    seq_len: int
    budget_frac: float       # available memory / dense peak at this instant


@dataclasses.dataclass
class WorkloadConfig:
    seed: int = 0
    horizon_s: float = 600.0
    base_rate: float = 0.5           # requests/s baseline
    burst_rate: float = 4.0          # requests/s during bursts
    burst_prob: float = 0.08
    short_len: Tuple[int, int] = (64, 512)
    long_len: Tuple[int, int] = (1024, 4096)
    long_frac: float = 0.25
    max_batch: int = 32
    mem_base: float = 1.0            # fraction of dense peak available
    mem_walk_sigma: float = 0.04
    mem_spike_prob: float = 0.03
    mem_spike_depth: Tuple[float, float] = (0.2, 0.5)
    mem_floor: float = 0.45
    round_len_to: int = 64


def generate(cfg: WorkloadConfig) -> List[Request]:
    rng = np.random.default_rng(cfg.seed)
    out: List[Request] = []
    t = 0.0
    mem = cfg.mem_base
    while t < cfg.horizon_s:
        diurnal = 1.0 + 0.5 * np.sin(2 * np.pi * t / cfg.horizon_s)
        rate = (cfg.burst_rate if rng.random() < cfg.burst_prob
                else cfg.base_rate) * diurnal
        t += float(rng.exponential(1.0 / max(rate, 1e-6)))
        # memory availability: mean-reverting walk + interference spikes
        mem += (rng.normal(0.0, cfg.mem_walk_sigma)
                + 0.1 * (cfg.mem_base - mem))
        if rng.random() < cfg.mem_spike_prob:
            mem -= rng.uniform(*cfg.mem_spike_depth)
        mem = float(np.clip(mem, cfg.mem_floor, 1.0))
        if rng.random() < cfg.long_frac:
            sql = int(rng.integers(*cfg.long_len))
        else:
            sql = int(rng.integers(*cfg.short_len))
        sql = max(cfg.round_len_to,
                  (sql // cfg.round_len_to) * cfg.round_len_to)
        bs = int(2 ** rng.integers(0, int(np.log2(cfg.max_batch)) + 1))
        out.append(Request(t=t, batch=bs, seq_len=sql, budget_frac=mem))
    return out


def request_sampler(cfg: WorkloadConfig, mm, *,
                    budget_range: Tuple[float, float] = (0.55, 0.95)):
    """Adapter for ``repro.core.dqn.train``: samples (bs, sql, budget_bytes)
    per episode from the workload distributions."""
    wl_rng = np.random.default_rng(cfg.seed + 77)
    reqs = generate(cfg)

    def sample(rng: np.random.Generator):
        r = reqs[int(rng.integers(0, len(reqs)))]
        frac = float(np.clip(r.budget_frac, *budget_range))
        budget = frac * mm.dense_peak(r.batch, r.seq_len)
        return r.batch, r.seq_len, budget

    del wl_rng
    return sample
