from repro.data.synthetic import SyntheticCorpus, batch_iterator

__all__ = ["SyntheticCorpus", "batch_iterator"]
