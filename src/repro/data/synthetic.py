"""Deterministic synthetic corpus with learnable structure.

No WikiText/Alpaca exists offline, so every experiment that needs text runs
on a Zipf-Markov language: Zipfian unigram marginals (natural-language-like
token frequencies) + a sparse first-order Markov transition structure
(k likely successors per token) + an in-context copy process (spans repeat
later in the sequence, giving attention something only context can solve).
A model that learns the transitions and the copy rule drops well below the
unigram-entropy floor, so pruning-quality differences show up exactly as
they would on real text perplexity.

Fully deterministic given (vocab, seed): corpus regeneration is exact across
hosts — the data-parallel pipeline shards by slicing the batch axis, no
files needed.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional

import numpy as np


@dataclasses.dataclass
class SyntheticCorpus:
    vocab_size: int
    seed: int = 0
    n_successors: int = 8          # sparse Markov out-degree
    zipf_a: float = 1.2
    copy_prob: float = 0.15        # per-position chance to start a copy span
    copy_len: int = 8
    smoothing: float = 0.05        # uniform mixture (keeps support full)

    def __post_init__(self):
        rng = np.random.default_rng(self.seed)
        V = self.vocab_size
        ranks = np.arange(1, V + 1, dtype=np.float64)
        self.unigram = ranks ** (-self.zipf_a)
        self.unigram /= self.unigram.sum()
        # each token's successor set + Zipf-weighted transition probs
        self.succ = rng.integers(0, V, size=(V, self.n_successors))
        w = np.arange(1, self.n_successors + 1, dtype=np.float64) ** (-1.0)
        self.succ_p = w / w.sum()

    def sample_tokens(self, rng: np.random.Generator, batch: int,
                      seq: int) -> np.ndarray:
        V = self.vocab_size
        out = np.empty((batch, seq), np.int64)
        out[:, 0] = rng.choice(V, size=batch, p=self.unigram)
        # vectorized Markov walk with uniform smoothing
        for t in range(1, seq):
            prev = out[:, t - 1]
            pick = rng.choice(self.n_successors, size=batch, p=self.succ_p)
            nxt = self.succ[prev, pick]
            smooth = rng.random(batch) < self.smoothing
            nxt[smooth] = rng.choice(V, size=smooth.sum(), p=self.unigram)
            out[:, t] = nxt
        # overlay copy spans: out[:, t:t+L] = out[:, s:s+L] for earlier s
        n_spans = int(self.copy_prob * seq / self.copy_len)
        for b in range(batch):
            for _ in range(n_spans):
                L = self.copy_len
                if seq < 3 * L:
                    break
                dst = rng.integers(2 * L, seq - L)
                src = rng.integers(0, dst - L)
                out[b, dst:dst + L] = out[b, src:src + L]
        return out.astype(np.int32)

    def batch(self, batch: int, seq: int, *, split: str = "train",
              index: int = 0) -> Dict[str, np.ndarray]:
        """Deterministic batch #`index` of a named split."""
        salt = {"train": 1, "eval": 2, "calib": 3}[split]
        rng = np.random.default_rng((self.seed, salt, index))
        toks = self.sample_tokens(rng, batch, seq)
        return {"tokens": toks, "labels": toks.copy()}


def batch_iterator(corpus: SyntheticCorpus, batch: int, seq: int, *,
                   split: str = "train", start: int = 0,
                   extra: Optional[Dict] = None) -> Iterator[Dict]:
    """Stateless infinite iterator — step-indexed so a restarted trainer
    resumes at the exact batch it crashed on."""
    i = start
    while True:
        b = corpus.batch(batch, seq, split=split, index=i)
        if extra:
            b.update(extra)
        yield b
        i += 1
