"""Version-skew shims for the jax APIs this codebase spells the modern way.

The code targets current jax, but containers may carry an older release
(0.4.x/0.5.x) where a few names live elsewhere or take different kwargs:

  * ``jax.shard_map``            → ``jax.experimental.shard_map.shard_map``
    (and ``check_vma=`` was called ``check_rep=``);
  * ``jax.sharding.AxisType``    → absent (explicit-sharding meshes landed
    later; plain meshes behave identically for our uses);
  * ``pallas.tpu.CompilerParams`` → named ``TPUCompilerParams`` before the
    rename.

Call sites keep the modern spelling through these shims.
"""
from __future__ import annotations


def tpu_compiler_params():
    """The pallas-TPU CompilerParams class under either of its names."""
    from jax.experimental.pallas import tpu as pltpu

    return getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams


def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool = False):
    try:
        from jax import shard_map as _sm               # jax >= 0.6
        return _sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                   check_vma=check_vma)
    except ImportError:
        from jax.experimental.shard_map import shard_map as _sm
        return _sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                   check_rep=check_vma)


def make_mesh(shape, axes, devices=None):
    import jax

    try:
        from jax.sharding import AxisType
        return jax.make_mesh(shape, axes,
                             axis_types=(AxisType.Auto,) * len(axes),
                             devices=devices)
    except ImportError:
        return jax.make_mesh(shape, axes, devices=devices)
