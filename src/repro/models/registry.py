"""Uniform model API over every architecture family.

``build(cfg)`` returns a ``Model`` namespace with:
  init(rng)                          → params
  loss(params, batch, gates=None)    → (scalar loss, aux)  [teacher-forced LM]
  logits(params, batch, gates=None)  → [B, S, Vp] f32
  prefill(params, batch, max_len)    → (last_logits, cache)
  decode(params, cache, tokens)      → (logits [B,1,Vp], cache)
  input_specs(shape_cfg)             → dict of ShapeDtypeStructs per step kind

Batches are dicts: tokens/labels always; ``vision_embeds`` for vlm;
``frames`` for audio.
"""
from __future__ import annotations

import functools
from typing import Any, Callable, Dict, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.models import decoder, encdec


class Model(NamedTuple):
    cfg: Any
    init: Callable
    loss: Callable
    logits: Callable
    prefill: Callable
    decode: Callable
    init_cache: Callable
    input_specs: Callable


CHUNKED_CE_MIN_SEQ = 2048


def _nll_terms(logits, labels, vocab_size: int):
    viota = jax.lax.broadcasted_iota(jnp.int32, (logits.shape[-1],), 0)
    if logits.shape[-1] > vocab_size:
        logits = jnp.where(viota >= vocab_size, jnp.float32(-1e30), logits)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.sum(jnp.where(viota == labels[..., None], logits, 0.0), -1)
    return logz - gold


def chunked_cross_entropy(unembed_fn, h, labels, vocab_size: int,
                          mask=None, chunk: int = 512):
    """CE without materializing [B,S,V] logits: ``lax.map`` over seq
    chunks, each rematerialized — peak holds one [B,chunk,V] block. At
    train_4k × 152k-vocab shapes the full-logit tensor plus its gradient is
    ~5 GB/device; this caps it at ~0.3 GB.

    h: [B,S,D] pre-final-norm hidden; unembed_fn(h_chunk) → logits chunk.
    Positions predict labels shifted by one; the final position is masked.
    """
    B, S, D = h.shape
    labels_next = jnp.concatenate(
        [labels[:, 1:], jnp.zeros((B, 1), labels.dtype)], axis=1)
    w = jnp.ones((B, S), jnp.float32).at[:, -1].set(0.0)
    if mask is not None:
        m = jnp.concatenate(
            [mask[:, 1:].astype(jnp.float32), jnp.zeros((B, 1))], axis=1)
        w = w * m
    cs = chunk
    while cs > 1 and S % cs:
        cs //= 2

    def one(ci):
        c0 = ci * cs
        h_c = jax.lax.dynamic_slice(h, (0, c0, 0), (B, cs, D))
        l_c = jax.lax.dynamic_slice(labels_next, (0, c0), (B, cs))
        w_c = jax.lax.dynamic_slice(w, (0, c0), (B, cs))
        nll = _nll_terms(unembed_fn(h_c), l_c, vocab_size)
        return jnp.sum(nll * w_c), jnp.sum(w_c)

    nlls, cnts = jax.lax.map(jax.checkpoint(one), jnp.arange(S // cs))
    return jnp.sum(nlls) / jnp.maximum(jnp.sum(cnts), 1.0)


def cross_entropy(logits, labels, vocab_size: int, mask=None):
    """Mean next-token CE; padded-vocab entries are excluded from Z.

    Sharding-aware formulation: the vocab axis is model-sharded at scale, so
    everything here is elementwise + reductions — no take_along_axis /
    scatter, whose GSPMD partitioning would all-gather the full [B,S,V]
    logits (hundreds of GB at train_4k shapes)."""
    nll = _nll_terms(logits, labels, vocab_size)
    if mask is None:
        mask = jnp.ones_like(nll)
    mask = mask.astype(nll.dtype)
    return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)


def _lm_build(cfg) -> Model:
    is_vlm = cfg.family == "vlm"

    def init(rng):
        return decoder.init_params(rng, cfg)

    def logits(params, batch, gates=None, impl="xla", remat=False, layout=None):
        extra = batch.get("vision_embeds") if is_vlm else None
        out, _ = decoder.forward(params, cfg, batch["tokens"], gates=gates,
                                 extra_embeds=extra, impl=impl, remat=remat,
                                 layout=layout)
        return out

    def loss(params, batch, gates=None, impl="xla", remat=False, layout=None):
        labels = batch["labels"]
        mask = batch.get("loss_mask")
        if labels.shape[1] >= CHUNKED_CE_MIN_SEQ:
            extra = batch.get("vision_embeds") if is_vlm else None
            h, _ = decoder.forward(params, cfg, batch["tokens"], gates=gates,
                                   extra_embeds=extra, impl=impl,
                                   remat=remat, layout=layout, unembed=False)
            if is_vlm:
                h = h[:, -labels.shape[1]:, :]
            l = chunked_cross_entropy(
                lambda hc: decoder._unembed(params, cfg, hc), h, labels,
                cfg.vocab_size, mask)
            return l, {"loss": l, "ppl": jnp.exp(l)}
        lg = logits(params, batch, gates, impl, remat, layout)
        if is_vlm:  # loss only on text positions
            lg = lg[:, -labels.shape[1]:, :]
        lg, labels = lg[:, :-1], labels[:, 1:]
        if mask is not None:
            mask = mask[:, 1:]
        l = cross_entropy(lg, labels, cfg.vocab_size, mask)
        return l, {"loss": l, "ppl": jnp.exp(l)}

    def prefill(params, batch, max_len, gates=None, impl="xla", layout=None,
                kv_dtype=None):
        extra = batch.get("vision_embeds") if is_vlm else None
        return decoder.prefill(params, cfg, batch["tokens"], max_len,
                               gates=gates, extra_embeds=extra, impl=impl,
                               layout=layout, kv_dtype=kv_dtype)

    def decode(params, cache, tokens, gates=None, impl="xla", layout=None):
        return decoder.decode_step(params, cfg, cache, tokens, gates=gates,
                                   impl=impl, layout=layout)

    def init_cache(batch_size, max_len, layout=None, kv_dtype=None):
        return decoder.init_cache(cfg, batch_size, max_len, layout, kv_dtype)

    def input_specs(shape_cfg) -> Dict[str, Any]:
        B, S = shape_cfg.global_batch, shape_cfg.seq_len
        i32 = jnp.int32
        specs: Dict[str, Any] = {}
        nv = cfg.n_vision_tokens if is_vlm else 0
        tok_len = S - nv
        if shape_cfg.kind == "train":
            specs["tokens"] = jax.ShapeDtypeStruct((B, tok_len), i32)
            specs["labels"] = jax.ShapeDtypeStruct((B, tok_len), i32)
        elif shape_cfg.kind == "prefill":
            specs["tokens"] = jax.ShapeDtypeStruct((B, tok_len), i32)
        else:  # decode: one new token against a seq_len cache
            specs["tokens"] = jax.ShapeDtypeStruct((B, 1), i32)
        if is_vlm and shape_cfg.kind != "decode":
            specs["vision_embeds"] = jax.ShapeDtypeStruct(
                (B, nv, cfg.d_model), cfg.jnp_dtype())
        return specs

    return Model(cfg, init, loss, logits, prefill, decode, init_cache,
                 input_specs)


def _encdec_build(cfg) -> Model:
    def init(rng):
        return encdec.init_params(rng, cfg)

    def logits(params, batch, gates=None, impl="xla", remat=False, layout=None):
        return encdec.forward(params, cfg, batch["tokens"], batch["frames"],
                              gates=gates, impl=impl, remat=remat)

    def loss(params, batch, gates=None, impl="xla", remat=False, layout=None):
        labels = batch["labels"]
        mask = batch.get("loss_mask")
        if labels.shape[1] >= CHUNKED_CE_MIN_SEQ:
            h = encdec.forward(params, cfg, batch["tokens"], batch["frames"],
                               gates=gates, impl=impl, remat=remat,
                               unembed=False)
            l = chunked_cross_entropy(
                lambda hc: encdec.unembed(params, cfg, hc), h, labels,
                cfg.vocab_size, mask)
            return l, {"loss": l, "ppl": jnp.exp(l)}
        lg = logits(params, batch, gates, impl, remat)
        lg, labels = lg[:, :-1], labels[:, 1:]
        if mask is not None:
            mask = mask[:, 1:]
        l = cross_entropy(lg, labels, cfg.vocab_size, mask)
        return l, {"loss": l, "ppl": jnp.exp(l)}

    def prefill(params, batch, max_len, gates=None, impl="xla", layout=None,
                kv_dtype=None):
        return encdec.prefill(params, cfg, batch["tokens"], batch["frames"],
                              max_len, gates=gates, impl=impl,
                              kv_dtype=kv_dtype)

    def decode(params, cache, tokens, gates=None, impl="xla", layout=None):
        return encdec.decode_step(params, cfg, cache, tokens, gates=gates,
                                  impl=impl)

    def init_cache(batch_size, max_len, layout=None, kv_dtype=None):
        return encdec.init_cache(cfg, batch_size, max_len, kv_dtype)

    def input_specs(shape_cfg) -> Dict[str, Any]:
        B, S = shape_cfg.global_batch, shape_cfg.seq_len
        i32 = jnp.int32
        specs: Dict[str, Any] = {}
        if shape_cfg.kind == "train":
            specs["tokens"] = jax.ShapeDtypeStruct((B, S), i32)
            specs["labels"] = jax.ShapeDtypeStruct((B, S), i32)
        elif shape_cfg.kind == "prefill":
            specs["tokens"] = jax.ShapeDtypeStruct((B, S), i32)
        else:  # decode: the encoder ran at prefill; cache holds cross-KV
            specs["tokens"] = jax.ShapeDtypeStruct((B, 1), i32)
        if shape_cfg.kind in ("train", "prefill"):
            specs["frames"] = jax.ShapeDtypeStruct(
                (B, cfg.n_audio_frames, cfg.d_model), cfg.jnp_dtype())
        return specs

    return Model(cfg, init, loss, logits, prefill, decode, init_cache,
                 input_specs)


def build(cfg) -> Model:
    if cfg.is_encoder_decoder:
        return _encdec_build(cfg)
    return _lm_build(cfg)
