"""GQA/MQA/MHA attention with RoPE, qk-norm, qkv-bias, local windows, caching.

Two data paths:
  * prefill/train — full-sequence causal (optionally banded) attention;
  * decode       — one query token against a pre-allocated KV cache.

The XLA path is the default (and the dry-run path); ``impl='pallas'`` routes
through the Pallas flash-attention kernels in ``repro.kernels``.
"""
from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import layers

NEG_INF = -2.0e38


def init_attn_params(rng, cfg) -> dict:
    """Separate wq/wk/wv (not fused): the fused [D, q+2kv] layout puts the
    q|k|v split boundaries off the 16-way TP shard grid for most assigned
    head counts, forcing per-layer reshards. Separate projections shard
    their own feature dims cleanly (MaxText-style)."""
    k1, k2, k3, k4 = jax.random.split(rng, 4)
    pd = cfg.jnp_param_dtype()
    p = {
        "wq": layers.dense_init(k1, cfg.d_model, cfg.q_dim, pd),
        "wk": layers.dense_init(k2, cfg.d_model, cfg.kv_dim, pd),
        "wv": layers.dense_init(k3, cfg.d_model, cfg.kv_dim, pd),
        "wo": layers.dense_init(k4, cfg.q_dim, cfg.d_model, pd,
                                scale=1.0 / math.sqrt(2 * max(cfg.n_layers, 1))),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((cfg.q_dim,), pd)
        p["bk"] = jnp.zeros((cfg.kv_dim,), pd)
        p["bv"] = jnp.zeros((cfg.kv_dim,), pd)
    if cfg.qk_norm:
        p["q_norm"] = jnp.zeros((cfg.dh,), pd)
        p["k_norm"] = jnp.zeros((cfg.dh,), pd)
    return p


def _project_qkv(params, cfg, x):
    """x: [B, S, D] → q [B,S,H,Dh], k/v [B,S,K,Dh]."""
    q = jnp.einsum("bsd,de->bse", x, params["wq"].astype(x.dtype))
    k = jnp.einsum("bsd,de->bse", x, params["wk"].astype(x.dtype))
    v = jnp.einsum("bsd,de->bse", x, params["wv"].astype(x.dtype))
    if cfg.qkv_bias:
        q = q + params["bq"].astype(x.dtype)
        k = k + params["bk"].astype(x.dtype)
        v = v + params["bv"].astype(x.dtype)
    B, S = x.shape[:2]
    q = q.reshape(B, S, cfg.n_heads, cfg.dh)
    k = k.reshape(B, S, cfg.n_kv_heads, cfg.dh)
    v = v.reshape(B, S, cfg.n_kv_heads, cfg.dh)
    if cfg.qk_norm:
        q = layers.rms_norm(q, params["q_norm"], cfg.norm_eps)
        k = layers.rms_norm(k, params["k_norm"], cfg.norm_eps)
    return q, k, v


def _sdpa(cfg, q, k, v, mask):
    """q [B,Sq,H,Dh], k/v [B,Skv,K,Dh], mask broadcastable [B,1,Sq,Skv]."""
    B, Sq, H, Dh = q.shape
    K = k.shape[2]
    G = H // K  # queries per kv head
    q = q.reshape(B, Sq, K, G, Dh)
    scale = 1.0 / math.sqrt(Dh)
    logits = jnp.einsum("bqkgd,bskd->bkgqs", q, k,
                        preferred_element_type=jnp.float32) * scale
    logits = layers.softcap(logits, cfg.logit_softcap)
    logits = jnp.where(mask[:, :, None, :, :], logits, NEG_INF)
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    out = jnp.einsum("bkgqs,bskd->bqkgd", probs.astype(v.dtype), v)
    return out.reshape(B, Sq, H, Dh)


def _causal_mask(Sq: int, Skv: int, window: int, q_offset: int = 0):
    """[1, 1, Sq, Skv] causal (banded if window>0) mask."""
    qpos = jnp.arange(Sq)[:, None] + q_offset
    kpos = jnp.arange(Skv)[None, :]
    m = kpos <= qpos
    if window > 0:
        m = m & (kpos > qpos - window)
    return m[None, None, :, :]


# --------------------------------------------------- chunked (long-context)
_CHUNK_MIN_SEQ = 4096        # plain path below this — probs fit comfortably
_CHUNK_BYTE_BUDGET = 16e9    # global bytes for one chunk's f32 probs


def _pick_chunk(B, K, G, Skv, Sq):
    cq = 1024
    while cq > 64 and B * K * G * cq * Skv * 4 > _CHUNK_BYTE_BUDGET:
        cq //= 2
    while cq > 1 and Sq % cq:
        cq //= 2
    return cq


def _sdpa_chunked(cfg, q, k, v, *, window: int = 0, q_offset: int = 0,
                  causal: bool = True):
    """Memory-efficient exact causal attention: ``lax.map`` over query
    chunks, each chunk rematerialized (`jax.checkpoint`) so neither forward
    nor backward ever holds more than one chunk's [B,K,G,cq,Skv] probs —
    the XLA-native flash-attention dataflow (the Pallas kernel is the
    TPU-tiled version of the same thing). Banded (local) attention
    additionally slices KV to the ``window+cq`` live band, making local
    layers O(S·w) instead of O(S²)."""
    B, Sq, H, Dh = q.shape
    Skv, K = k.shape[1], k.shape[2]
    G = H // K
    banded = window > 0 and window + 1024 <= Skv
    eff_kv = (window + 1024) if banded else Skv
    cq = _pick_chunk(B, K, G, eff_kv, Sq)
    if cq < 16:
        return _sdpa(cfg, q, k, v, _causal_mask(Sq, Skv, window, q_offset))
    nq = Sq // cq
    Wk = min(Skv, window + cq) if banded else Skv

    def chunk(qi):
        q_start = qi * cq
        qc = jax.lax.dynamic_slice_in_dim(q, q_start, cq, axis=1)
        if banded:
            k_start = jnp.clip(q_start + q_offset - window + 1, 0, Skv - Wk)
        else:
            k_start = 0
        kc = jax.lax.dynamic_slice_in_dim(k, k_start, Wk, axis=1)
        vc = jax.lax.dynamic_slice_in_dim(v, k_start, Wk, axis=1)
        qpos = q_start + q_offset + jnp.arange(cq)[:, None]
        kpos = k_start + jnp.arange(Wk)[None, :]
        m = (kpos <= qpos) if causal else \
            jnp.ones((cq, Wk), bool) & (kpos >= 0)
        if window > 0:
            m = m & (kpos > qpos - window)
        return _sdpa(cfg, qc, kc, vc, m[None, None])

    out = jax.lax.map(jax.checkpoint(chunk), jnp.arange(nq))  # [nq,B,cq,H,Dh]
    return jnp.moveaxis(out, 0, 1).reshape(B, Sq, H, Dh)


def attention(params, cfg, x, positions, *, window: int = 0,
              impl: str = "xla") -> Tuple[jnp.ndarray, dict]:
    """Full-sequence causal attention. Returns (out [B,S,D], kv dict)."""
    from repro.parallel import activation as act

    q, k, v = _project_qkv(params, cfg, x)
    if cfg.use_rope:
        q = layers.apply_rope(q, positions, cfg.rope_theta)
        k = layers.apply_rope(k, positions, cfg.rope_theta)
    q, k, v = act.heads(q), act.heads(k), act.heads(v)
    if impl == "pallas":
        from repro.kernels import ops as kops
        out = kops.flash_attention(q, k, v, causal=True, window=window,
                                   softcap=cfg.logit_softcap)
    elif q.shape[1] >= _CHUNK_MIN_SEQ:
        out = _sdpa_chunked(cfg, q, k, v, window=window)
    else:
        mask = _causal_mask(q.shape[1], k.shape[1], window)
        out = _sdpa(cfg, q, k, v, mask)
    y = jnp.einsum("bsq,qm->bsm", out.reshape(*out.shape[:2], -1),
                   params["wo"].astype(x.dtype))
    return y, {"k": k, "v": v}


def cross_attention(params, cfg, x, kv: dict) -> jnp.ndarray:
    """Decoder cross-attention against precomputed encoder K/V (no mask)."""
    q, _, _ = _project_qkv(params, cfg, x)  # k,v projections unused on this path
    k, v = kv["k"], kv["v"]
    B, Sq = q.shape[:2]
    mask = jnp.ones((1, 1, Sq, k.shape[1]), dtype=bool)
    out = _sdpa(cfg, q, k, v, mask)
    return jnp.einsum("bsq,qm->bsm", out.reshape(B, Sq, -1),
                      params["wo"].astype(x.dtype))


def kv_quant(x):
    """Per-(token, head) symmetric int8 quantization. x: [..., Dh]."""
    xf = x.astype(jnp.float32)
    amax = jnp.max(jnp.abs(xf), axis=-1, keepdims=True)
    scale = amax / 127.0 + 1e-8
    q = jnp.clip(jnp.round(xf / scale), -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


def page_qmax(dtype) -> float:
    """Symmetric quantization ceiling of a paged storage dtype: 127 for
    int8, 448 for float8_e4m3fn (its largest finite value)."""
    return 127.0 if jnp.dtype(dtype) == jnp.int8 else 448.0


def page_quant(xf, dtype, scale_floor=None):
    """Quantize whole pages ``[..., page_tokens, K, Dh]`` (f32) into
    ``dtype`` with ONE symmetric scale per (page, kv-head): returns
    ``(q, scales[..., K])``.

    ``scale_floor`` (same shape as the scales) makes the scale monotone
    within a page's lifetime: when an append does not raise the page's
    amax, the scale is unchanged and requantizing the page's existing
    tokens reproduces their stored codes exactly (``round(s·q/s) == q``),
    so repeated appends drift only when the scale actually grows."""
    amax = jnp.max(jnp.abs(xf), axis=(-3, -1))            # [..., K]
    qmax = page_qmax(dtype)
    scale = amax / qmax
    if scale_floor is not None:
        scale = jnp.maximum(scale, scale_floor)
    # epsilon as a FLOOR, not an addend: adding it after the max would
    # grow a stable page's scale every requantization
    scale = jnp.maximum(scale, 1e-8)
    y = xf / scale[..., None, :, None]
    if jnp.dtype(dtype) == jnp.int8:
        q = jnp.clip(jnp.round(y), -qmax, qmax).astype(jnp.int8)
    else:
        q = jnp.clip(y, -qmax, qmax).astype(dtype)
    return q, scale.astype(jnp.float32)


def page_dequant(q, scales):
    """Dequantize pages ``[..., page_tokens, K, Dh]`` with per-(page, head)
    scales ``[..., K]`` to f32 — the reference the fused kernel is pinned
    bitwise against (``q.astype(f32) * scale`` per element, nothing else)."""
    return q.astype(jnp.float32) * scales[..., None, :, None]


def init_kv_cache(cfg, batch: int, max_len: int, n_layers: int, dtype=None):
    """Cache entry dict. bf16/f32 mode: {k, v}. int8 mode adds per-(token,
    head) scales {ks, vs} — the production KV-quantization that halves the
    decode-cache HBM footprint (e.g. qwen1.5-32b × decode_32k does not fit
    a 256-chip pod at bf16)."""
    dt = dtype or cfg.jnp_dtype()
    shape = (n_layers, batch, max_len, cfg.n_kv_heads, cfg.dh)
    cache = {"k": jnp.zeros(shape, dt), "v": jnp.zeros(shape, dt)}
    if jnp.dtype(dt) == jnp.int8:
        sshape = shape[:-1] + (1,)
        cache["ks"] = jnp.zeros(sshape, jnp.float32)
        cache["vs"] = jnp.zeros(sshape, jnp.float32)
    return cache


def store_kv(entry: dict, k, v) -> dict:
    """Encode (k, v) [..., K, Dh] into the entry's storage dtype. Returns the
    leaf dict matching ``init_kv_cache`` structure (no layer axis)."""
    if "ks" in entry:
        kq, ks = kv_quant(k)
        vq, vs = kv_quant(v)
        return {"k": kq, "v": vq, "ks": ks, "vs": vs}
    return {"k": k.astype(entry["k"].dtype), "v": v.astype(entry["v"].dtype)}


def load_kv(entry: dict, dtype):
    if "ks" in entry:
        k = (entry["k"].astype(jnp.float32) * entry["ks"]).astype(dtype)
        v = (entry["v"].astype(jnp.float32) * entry["vs"]).astype(dtype)
        return k, v
    return entry["k"].astype(dtype), entry["v"].astype(dtype)


def paged_decode_attention(params, cfg, x, kv: dict, page_table, pos, *,
                           impl: str = "xla") -> Tuple[jnp.ndarray, dict]:
    """One-token decode against a *paged* KV pool (one layer's slice).

    x: [B,1,D]; kv: {"k","v"} page pools [n_pages, page_tokens, K, Dh]
    shared by every in-flight request; page_table: int32 [B, max_pages]
    mapping row b's token t to page ``page_table[b, t // page_tokens]``;
    pos: int32 [B] per-row write positions. Returns (out [B,1,D], kv').

    The new token's K/V is scattered into its owning page (rows own
    disjoint pages, so the scatter is conflict-free), then attention runs
    either through the Pallas paged flash-decode kernel (``impl='pallas'``,
    the TPU path — BlockSpec index maps chase the page table, no gather)
    or an XLA gather fallback that materializes ``[B, max_pages ×
    page_tokens]`` and reuses the dense softmax (the CPU serving path).

    Quantized pools carry per-(page, kv-head) scales ``{"ks","vs"}``
    ``[n_pages, K]``: the append is a code-space rewrite of the row's
    page — the monotone scale grows to ``max(old, token_amax/qmax)``,
    existing codes rescale by ``old/new`` (exactly 1.0 while the scale
    is stable, so they round-trip bitwise), the token quantizes into its
    slot, and stale slots past the write frontier stay zero. The read
    path dequantizes — fused into the Pallas kernel via scalar-prefetched
    scales, or mirrored exactly in the XLA gather (``q.astype(f32) *
    scale``) so both paths see identical f32 values.
    """
    B = x.shape[0]
    pos = jnp.asarray(pos, jnp.int32)
    page_table = jnp.asarray(page_table, jnp.int32)
    page_tokens = kv["k"].shape[1]
    quantized = "ks" in kv
    q, k, v = _project_qkv(params, cfg, x)
    positions = jnp.broadcast_to(pos.reshape(-1, 1), (B, 1))
    if cfg.use_rope:
        q = layers.apply_rope(q, positions, cfg.rope_theta)
        k = layers.apply_rope(k, positions, cfg.rope_theta)
    # scatter the new token's KV into its page slot
    rows = jnp.arange(B)
    page_ids = page_table[rows, pos // page_tokens]
    offs = pos % page_tokens
    kv = dict(kv)
    if quantized:
        # code-space append (rows own disjoint pages; only padded rows
        # collide on the scratch page, which is never read). The monotone
        # page scale means existing codes never exceed old_scale*qmax, so
        # the new scale is just max(token amax / qmax, old scale) — no
        # page-wide amax reduction — and existing codes rescale by
        # old/new, which is exactly 1.0 while the scale is stable: the
        # common-case append rewrites the page bitwise-unchanged plus the
        # one inserted slot, at a fraction of a dequant→requant pass.
        slot = jnp.arange(page_tokens)[None, :, None, None]   # [1, pt, 1, 1]
        off_b = offs[:, None, None, None]                     # [B, 1, 1, 1]
        fresh = (offs == 0)[:, None]                          # [B, 1]
        for pk, sk, new in (("k", "ks", k), ("v", "vs", v)):
            qmax = page_qmax(kv[pk].dtype)
            int_codes = jnp.dtype(kv[pk].dtype) == jnp.int8
            tok = new[:, 0].astype(jnp.float32)               # [B, K, Dh]
            old_s = kv[sk][page_ids]                          # [B, K]
            # a freshly started page must not inherit the previous
            # occupant's content or scale
            floor = jnp.where(fresh, 0.0, old_s)
            new_s = jnp.maximum(jnp.maximum(
                jnp.max(jnp.abs(tok), axis=-1) / qmax, floor), 1e-8)
            r = jnp.where(fresh, 0.0, old_s / new_s)          # [B, K] <= 1
            pg = kv[pk][page_ids].astype(jnp.float32) * r[:, None, :, None]
            tok_q = tok / new_s[..., None]
            if int_codes:
                pg, tok_q = jnp.round(pg), jnp.round(tok_q)
            pg = jnp.where(slot == off_b, tok_q[:, None], pg)
            pg = jnp.where(slot <= off_b, pg, 0.0)  # stale slots → 0
            kv[pk] = kv[pk].at[page_ids].set(
                jnp.clip(pg, -qmax, qmax).astype(kv[pk].dtype))
            kv[sk] = kv[sk].at[page_ids].set(new_s)
    else:
        kv["k"] = kv["k"].at[page_ids, offs].set(
            k[:, 0].astype(kv["k"].dtype))
        kv["v"] = kv["v"].at[page_ids, offs].set(
            v[:, 0].astype(kv["v"].dtype))
    lengths = pos + 1
    if impl == "pallas":
        from repro.kernels import ops as kops
        out = kops.paged_decode_attention(
            q, kv["k"], kv["v"], page_table, lengths,
            k_scales=kv.get("ks"), v_scales=kv.get("vs"),
            softcap=cfg.logit_softcap)
    else:
        # gather fallback: page_table indexes the pool back into a
        # contiguous per-row view [B, max_pages*page_tokens, K, Dh]
        S = page_table.shape[1] * page_tokens
        if quantized:
            ck = page_dequant(kv["k"][page_table], kv["ks"][page_table])
            cv = page_dequant(kv["v"][page_table], kv["vs"][page_table])
            ck = ck.reshape(B, S, *ck.shape[3:])
            cv = cv.reshape(B, S, *cv.shape[3:])
        else:
            ck = kv["k"][page_table].reshape(B, S, *kv["k"].shape[2:])
            cv = kv["v"][page_table].reshape(B, S, *kv["v"].shape[2:])
        valid = jnp.arange(S)[None, :] < lengths[:, None]      # [B, S]
        out = _sdpa(cfg, q, ck.astype(q.dtype), cv.astype(q.dtype),
                    valid[:, None, None, :])
    y = jnp.einsum("bsq,qm->bsm", out.reshape(B, 1, -1),
                   params["wo"].astype(x.dtype))
    return y, kv


def chunk_attention(params, cfg, x, kv: dict, start, *,
                    impl: str = "xla") -> Tuple[jnp.ndarray, dict]:
    """Prefill one prompt *chunk* against a partially filled KV cache.

    x: [B, C, D] — C consecutive prompt tokens starting at absolute
    position ``start`` (an int32 scalar, traced: executables key on the
    chunk width C, never on the offset); kv: one layer's cache entry with
    leaves [B, S_max, K, Dh]. The chunk's K/V is RoPE'd at its absolute
    positions and written contiguously at ``[start, start+C)``, then the C
    queries attend the full cache width under the causal mask
    ``kpos <= start + qi`` — positions beyond the write frontier are
    masked to exactly-zero probability, so chunk-by-chunk prefill is
    bitwise-identical to the monolithic pass (DESIGN.md §6). Returns
    (out [B, C, D], kv').
    """
    B, C = x.shape[:2]
    start = jnp.asarray(start, jnp.int32)
    q, k, v = _project_qkv(params, cfg, x)
    positions = start + jnp.arange(C)[None, :]
    if cfg.use_rope:
        q = layers.apply_rope(q, positions, cfg.rope_theta)
        k = layers.apply_rope(k, positions, cfg.rope_theta)
    new = store_kv(kv, k, v)
    kv = dict(kv)
    for key, val in new.items():
        kv[key] = jax.lax.dynamic_update_slice(
            kv[key], val, (0, start) + (0,) * (kv[key].ndim - 2))
    S = kv["k"].shape[1]
    ck, cv = load_kv(kv, q.dtype)
    mask = _causal_mask(C, S, 0, q_offset=start)
    out = _sdpa(cfg, q, ck, cv, mask)
    y = jnp.einsum("bsq,qm->bsm", out.reshape(B, C, -1),
                   params["wo"].astype(x.dtype))
    return y, kv


def paged_chunk_attention(params, cfg, x, kv: dict, page_table, start, *,
                          scratch_page: int,
                          impl: str = "xla") -> Tuple[jnp.ndarray, dict]:
    """Paged sibling of :func:`chunk_attention`: prefill C prompt tokens
    straight into granted pages.

    x: [B, C, D]; kv: {"k","v"} page pools [n_pages, page_tokens, K, Dh];
    page_table: int32 [B, max_pages]; start: int32 scalar — the chunk's
    first absolute position (every row of a chunked-prefill request sits
    at the same offset). Tokens whose position falls past the table width
    are routed to the scratch page (a write sink) instead of letting the
    gather clamp onto a live page. Attention runs through the same
    gather fallback as ``paged_decode_attention``'s XLA path. Quantized
    pools requantize every page the chunk touches (monotone scales;
    straddled leading pages keep their scale floor, pages starting at or
    after ``start`` reset it) and never rewrite settled earlier pages —
    their untouched write-back is routed to the scratch sink. Returns
    (out [B, C, D], kv').
    """
    B, C = x.shape[:2]
    start = jnp.asarray(start, jnp.int32)
    page_table = jnp.asarray(page_table, jnp.int32)
    page_tokens = kv["k"].shape[1]
    max_pages = page_table.shape[1]
    quantized = "ks" in kv
    q, k, v = _project_qkv(params, cfg, x)
    tok_pos = start + jnp.arange(C)                        # [C]
    positions = jnp.broadcast_to(tok_pos[None, :], (B, C))
    if cfg.use_rope:
        q = layers.apply_rope(q, positions, cfg.rope_theta)
        k = layers.apply_rope(k, positions, cfg.rope_theta)
    cols = tok_pos // page_tokens                          # [C]
    in_range = cols < max_pages
    rows = jnp.arange(B)[:, None]                          # [B, 1]
    page_ids = page_table[rows, jnp.minimum(cols, max_pages - 1)[None, :]]
    page_ids = jnp.where(in_range[None, :], page_ids, scratch_page)  # [B, C]
    offs = jnp.broadcast_to((tok_pos % page_tokens)[None, :], (B, C))
    kv = dict(kv)
    if quantized:
        S = max_pages * page_tokens
        col_ids = jnp.arange(max_pages)                     # [maxp]
        # which table columns this chunk writes into (same for all rows:
        # chunked rows share one offset); everything else is settled or
        # empty and must NOT be requantized — route its write-back to the
        # scratch sink instead
        touched = (((col_ids + 1) * page_tokens > start)
                   & (col_ids * page_tokens < start + C))
        write_ids = jnp.where(touched[None, :], page_table, scratch_page)
        frontier = (start + C)
        kpos = jnp.arange(S)
        live = (kpos < frontier)[None, :, None, None]       # [1, S, 1, 1]
        fresh_col = (col_ids * page_tokens >= start)[None, :, None]
        for pk, sk, new in (("k", "ks", k), ("v", "vs", v)):
            view = page_dequant(kv[pk][page_table], kv[sk][page_table])
            view = view.reshape(B, S, *view.shape[3:])      # [B, S, K, Dh]
            # pad by C so an over-the-table chunk spills off the end
            # instead of letting dynamic_update_slice clamp onto live data
            view = jnp.concatenate(
                [view, jnp.zeros((B, C) + view.shape[2:], view.dtype)], 1)
            view = jax.lax.dynamic_update_slice(
                view, new.astype(jnp.float32), (0, start, 0, 0))[:, :S]
            view = jnp.where(live, view, 0.0)               # stale slots → 0
            pages = view.reshape(B, max_pages, page_tokens, *view.shape[2:])
            floor = jnp.where(fresh_col, 0.0, kv[sk][page_table])
            qp, sp = page_quant(pages, kv[pk].dtype, scale_floor=floor)
            kv[pk] = kv[pk].at[write_ids].set(qp)
            kv[sk] = kv[sk].at[write_ids].set(sp)
    else:
        kv["k"] = kv["k"].at[page_ids, offs].set(k.astype(kv["k"].dtype))
        kv["v"] = kv["v"].at[page_ids, offs].set(v.astype(kv["v"].dtype))
    # gather fallback view [B, max_pages*page_tokens, K, Dh] + causal mask
    S = max_pages * page_tokens
    if quantized:
        ck = page_dequant(kv["k"][page_table], kv["ks"][page_table])
        cv = page_dequant(kv["v"][page_table], kv["vs"][page_table])
        ck = ck.reshape(B, S, *ck.shape[3:])
        cv = cv.reshape(B, S, *cv.shape[3:])
    else:
        ck = kv["k"][page_table].reshape(B, S, *kv["k"].shape[2:])
        cv = kv["v"][page_table].reshape(B, S, *kv["v"].shape[2:])
    mask = _causal_mask(C, S, 0, q_offset=start)
    out = _sdpa(cfg, q, ck.astype(q.dtype), cv.astype(q.dtype), mask)
    y = jnp.einsum("bsq,qm->bsm", out.reshape(B, C, -1),
                   params["wo"].astype(x.dtype))
    return y, kv


def decode_attention(params, cfg, x, kv: dict, pos, *, window: int = 0,
                     impl: str = "xla") -> Tuple[jnp.ndarray, dict]:
    """One-token decode. x: [B,1,D]; kv: cache entry (no layer axis), leaves
    [B, S_max, K, Dh] (+ scales). Returns (out [B,1,D], kv').

    ``pos`` is a scalar (whole batch at one position — the one-shot server
    path) or an int32 [B] vector (continuous batching: each cache slot holds
    a different request at its own decode offset). The vector path scatters
    each row's KV at its own slot and builds a per-row validity mask.
    """
    B = x.shape[0]
    pos = jnp.asarray(pos, jnp.int32)
    batched_pos = pos.ndim > 0
    q, k, v = _project_qkv(params, cfg, x)
    positions = jnp.broadcast_to(pos.reshape(-1, 1), (B, 1))
    if cfg.use_rope:
        q = layers.apply_rope(q, positions, cfg.rope_theta)
        k = layers.apply_rope(k, positions, cfg.rope_theta)
    S = kv["k"].shape[1]
    if window > 0:
        # ring-buffer write for banded caches
        slot = jnp.mod(pos, S)
    else:
        slot = pos
    new = store_kv(kv, k, v)
    kv = dict(kv)
    for key, val in new.items():
        if batched_pos:
            # per-row scatter: row b writes at its own slot[b]
            kv[key] = kv[key].at[jnp.arange(B), slot].set(val[:, 0])
        else:
            kv[key] = jax.lax.dynamic_update_slice(
                kv[key], val, (0, slot) + (0,) * (kv[key].ndim - 2))
    kpos = jnp.arange(S)[None, :]
    posc = pos.reshape(-1, 1)
    if window > 0:
        # valid = within the last `window` tokens (ring semantics)
        age = jnp.mod(posc - kpos, S)
        valid = (age < jnp.minimum(posc + 1, window))      # [B or 1, S]
    else:
        valid = kpos <= posc                               # [B or 1, S]
    ck, cv = load_kv(kv, q.dtype)
    if impl == "pallas" and not batched_pos:
        from repro.kernels import ops as kops
        out = kops.decode_attention(q, ck, cv, valid[0],
                                    softcap=cfg.logit_softcap)
    else:
        mask = valid[:, None, None, :]
        out = _sdpa(cfg, q, ck, cv, mask)
    y = jnp.einsum("bsq,qm->bsm", out.reshape(B, 1, -1),
                   params["wo"].astype(x.dtype))
    return y, kv
