"""Primitive layers: initializers, norms, embeddings, RoPE.

Everything is pure-functional: ``init_*`` returns a pytree of arrays,
``apply``-style functions take (params, inputs). No module framework — params
flow through ``jax.jit``/``pjit`` directly.
"""
from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp


# ----------------------------------------------------------------- initializers
def dense_init(rng, in_dim: int, out_dim: int, dtype, scale: float = 1.0):
    """Truncated-normal fan-in init (production default)."""
    std = scale / math.sqrt(in_dim)
    w = jax.random.truncated_normal(rng, -2.0, 2.0, (in_dim, out_dim), jnp.float32) * std
    return w.astype(dtype)


def embed_init(rng, vocab: int, dim: int, dtype):
    w = jax.random.normal(rng, (vocab, dim), jnp.float32) * 0.02
    return w.astype(dtype)


# ----------------------------------------------------------------------- norms
def rms_norm(x, scale, eps: float = 1e-6):
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps)
    return (y * (1.0 + scale.astype(jnp.float32))).astype(dt)


def layer_norm(x, scale, bias, eps: float = 1e-6):
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
    y = (x - mu) * jax.lax.rsqrt(var + eps)
    return (y * (1.0 + scale.astype(jnp.float32)) + bias.astype(jnp.float32)).astype(dt)


def init_norm(cfg, rng=None) -> dict:
    if cfg.norm == "layernorm":
        return {"scale": jnp.zeros((cfg.d_model,), cfg.jnp_param_dtype()),
                "bias": jnp.zeros((cfg.d_model,), cfg.jnp_param_dtype())}
    return {"scale": jnp.zeros((cfg.d_model,), cfg.jnp_param_dtype())}


def apply_norm(cfg, params: dict, x):
    if cfg.norm == "layernorm":
        return layer_norm(x, params["scale"], params["bias"], cfg.norm_eps)
    return rms_norm(x, params["scale"], cfg.norm_eps)


# ------------------------------------------------------------------------ RoPE
def rope_freqs(head_dim: int, theta: float):
    exponent = jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim
    return 1.0 / (theta ** exponent)  # [head_dim//2]


def apply_rope(x, positions, theta: float):
    """x: [..., S, H, Dh]; positions: broadcastable to [..., S]."""
    dh = x.shape[-1]
    freqs = rope_freqs(dh, theta)                       # [dh/2]
    angles = positions[..., :, None].astype(jnp.float32) * freqs  # [..., S, dh/2]
    angles = angles[..., None, :]                       # [..., S, 1, dh/2]
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ----------------------------------------------------------------- activations
def gelu(x):
    return jax.nn.gelu(x, approximate=True)


def silu(x):
    return jax.nn.silu(x)


def softcap(x, cap: float):
    if cap <= 0.0:
        return x
    return cap * jnp.tanh(x / cap)
