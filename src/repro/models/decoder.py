"""Generic decoder-only LM supporting every assigned family.

Parameters live in per-kind *stacks* (leading axis = number of layers of that
kind, MaxText-style). Uniform architectures run as ``lax.scan`` over the
stack; heterogeneous ones (Griffin's rglru/rglru/attn pattern) unroll a
Python loop with static per-layer indices into the stacks.

RAP hooks:
  * ``gates`` — dict {'mixer': f32[L], 'ffn': f32[L]} of 0/1 runtime gates.
    Masked-mode pruning multiplies each residual branch; one executable serves
    every pruning pattern (no memory savings — used by GSI scoring).
  * structural compaction (see ``repro.core.masks``) gathers the stacks along
    the layer axis, producing genuinely smaller params + KV cache.
"""
from __future__ import annotations

import math
import os
from typing import Any, Dict, NamedTuple, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.models import attention, ffn as ffn_mod, layers, moe as moe_mod
from repro.models import rglru as rglru_mod, ssm as ssm_mod
from repro.parallel import activation as act


class LayerSlot(NamedTuple):
    mixer: Optional[str]   # attn|local_attn|rglru|ssd|None
    mixer_idx: int         # index into the kind's stack
    ffn: Optional[str]     # dense|moe|None
    ffn_idx: int


def default_layout(cfg) -> Tuple[LayerSlot, ...]:
    slots = []
    counts: Dict[str, int] = {}
    for mixer, f in cfg.layer_specs():
        mk = "attn" if mixer == "local_attn" else mixer  # shared param stack
        mi = counts.get(mk, 0)
        counts[mk] = mi + 1
        if f == "none":
            fk, fi = None, 0
        else:
            fi = counts.get(f, 0)
            counts[f] = fi + 1
            fk = f
        slots.append(LayerSlot(mixer, mi, fk, fi))
    return tuple(slots)


def layout_counts(layout) -> Dict[str, int]:
    counts: Dict[str, int] = {}
    for s in layout:
        if s.mixer is not None:
            mk = "attn" if s.mixer == "local_attn" else s.mixer
            counts[mk] = max(counts.get(mk, 0), s.mixer_idx + 1)
        if s.ffn is not None:
            counts[s.ffn] = max(counts.get(s.ffn, 0), s.ffn_idx + 1)
    return counts


# --------------------------------------------------------------------- params
_MIXER_INIT = {
    "attn": attention.init_attn_params,
    "rglru": rglru_mod.init_rglru_params,
    "ssd": ssm_mod.init_ssd_params,
}
_FFN_INIT = {
    "dense": ffn_mod.init_ffn_params,
    "moe": moe_mod.init_moe_params,
}


def _stack_init(rng, n: int, init_fn, cfg):
    keys = jax.random.split(rng, n)
    trees = [dict(norm=layers.init_norm(cfg), **init_fn(keys[i], cfg))
             for i in range(n)]
    return jax.tree.map(lambda *xs: jnp.stack(xs), *trees)


def init_params(rng, cfg) -> dict:
    layout = default_layout(cfg)
    counts = layout_counts(layout)
    k_embed, k_head, k_rest = jax.random.split(rng, 3)
    params: dict = {
        "embed": layers.embed_init(k_embed, cfg.vocab_padded, cfg.d_model,
                                   cfg.jnp_param_dtype()),
        "final_norm": layers.init_norm(cfg),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = layers.dense_init(k_head, cfg.d_model,
                                              cfg.vocab_padded,
                                              cfg.jnp_param_dtype())
    stacks = {}
    kinds = sorted(counts)
    keys = jax.random.split(k_rest, max(len(kinds), 1))
    for key, kind in zip(keys, kinds):
        init_fn = _MIXER_INIT.get(kind) or _FFN_INIT[kind]
        stacks[kind] = _stack_init(key, counts[kind], init_fn, cfg)
    params["stacks"] = stacks
    return params


def tree_slice(tree, idx: int):
    return jax.tree.map(lambda x: x[idx], tree)


# --------------------------------------------------------------- mixer apply
def _apply_mixer(kind: str, p, cfg, h, positions, *, impl: str):
    hn = layers.apply_norm(cfg, p["norm"], h)
    if kind in ("attn", "local_attn"):
        window = cfg.attn_window if kind == "local_attn" else 0
        out, kv = attention.attention(p, cfg, hn, positions, window=window,
                                      impl=impl)
        return out, kv
    if kind == "rglru":
        return rglru_mod.rglru_mixer(p, cfg, hn, impl=impl), None
    if kind == "ssd":
        return ssm_mod.ssd_mixer(p, cfg, hn, impl=impl), None
    raise ValueError(kind)


def _apply_ffn(kind: str, p, cfg, h, *, impl: str):
    hn = layers.apply_norm(cfg, p["norm"], h)
    if kind == "dense":
        return ffn_mod.ffn(p, cfg, hn, impl=impl)
    if kind == "moe":
        return moe_mod.moe_ffn(p, cfg, hn,
                               impl="dense" if impl == "oracle" else "scatter")
    raise ValueError(kind)


def _embed(params, cfg, tokens, extra_embeds):
    h = params["embed"][tokens].astype(cfg.jnp_dtype())
    if cfg.embed_scale:
        h = h * jnp.asarray(math.sqrt(cfg.d_model), h.dtype)
    if extra_embeds is not None:
        h = jnp.concatenate([extra_embeds.astype(h.dtype), h], axis=1)
    return act.hidden(h)


def _unembed(params, cfg, h):
    h = layers.apply_norm(cfg, params["final_norm"], h)
    if cfg.tie_embeddings:
        lg = jnp.einsum("bsd,vd->bsv", h, params["embed"].astype(h.dtype),
                        preferred_element_type=jnp.float32)
    else:
        lg = jnp.einsum("bsd,dv->bsv", h, params["lm_head"].astype(h.dtype),
                        preferred_element_type=jnp.float32)
    return act.logits(lg)


def force_unroll() -> bool:
    """REPRO_UNROLL=1 lowers layer loops unrolled instead of lax.scan —
    XLA's cost_analysis counts a while-loop body once regardless of trip
    count, so the roofline dry-run unrolls to get exact per-op FLOPs /
    bytes / collective counts in the HLO."""
    return os.environ.get("REPRO_UNROLL", "0") == "1"


def _ones_gates(n_layers: int):
    return {"mixer": jnp.ones((n_layers,), jnp.float32),
            "ffn": jnp.ones((n_layers,), jnp.float32)}


def _bgate(g, ref):
    """Broadcast one layer's gate against an activation [B, S, D].

    Gates are scalars in one-shot serving ([L] per-layer vectors) and
    per-request rows in the continuous-batching engine ([L, B]: each cache
    slot runs its own keep-mask). Scalars broadcast as before; [B] rows gain
    trailing axes so slot b's residual branch is scaled by its own gate.
    """
    g = g.astype(ref.dtype)
    if g.ndim == 0:
        return g
    return g.reshape(g.shape + (1,) * (ref.ndim - g.ndim))


# -------------------------------------------------------------------- forward
def forward(params, cfg, tokens, *, gates=None, extra_embeds=None,
            impl: str = "xla", remat: bool = False, layout=None,
            collect_kv: bool = False, unembed: bool = True):
    """Full-sequence forward. Returns (logits f32 [B,S,Vp], kv or None);
    ``unembed=False`` returns the pre-final-norm hidden state instead (the
    chunked-CE path computes logits blockwise to avoid materializing the
    [B,S,V] f32 tensor)."""
    use_groups = (layout is None and bool(cfg.block_pattern)
                  and not force_unroll() and not collect_kv
                  and cfg.n_layers >= 2 * len(cfg.block_pattern))
    layout = layout or default_layout(cfg)
    L = len(layout)
    gates = gates or _ones_gates(L)
    h = _embed(params, cfg, tokens, extra_embeds)
    positions = jnp.arange(h.shape[1])[None, :]

    uniform = (all(s.mixer == layout[0].mixer and s.ffn == layout[0].ffn
                   for s in layout) and L > 0 and not force_unroll())
    kvs = None
    if use_groups:
        return _forward_pattern_groups(params, cfg, h, positions, gates,
                                       impl=impl, remat=remat,
                                       unembed=unembed)
    if uniform and not collect_kv:
        mk = "attn" if layout[0].mixer == "local_attn" else layout[0].mixer
        mixer_stack = params["stacks"][mk]
        ffn_stack = params["stacks"][layout[0].ffn] if layout[0].ffn else None

        def body(carry, xs):
            h = act.hidden(carry)
            pm, pf, gm, gf = xs
            out, _ = _apply_mixer(layout[0].mixer, pm, cfg, h, positions,
                                  impl=impl)
            h = h + gm.astype(h.dtype) * out
            if pf is not None:
                h = h + gf.astype(h.dtype) * _apply_ffn(layout[0].ffn, pf, cfg,
                                                        h, impl=impl)
            return h, None

        if remat:
            body = jax.checkpoint(body, prevent_cse=False)
        h, _ = jax.lax.scan(body, h,
                            (mixer_stack, ffn_stack, gates["mixer"],
                             gates["ffn"]))
    else:
        if collect_kv:
            kvs = []
        for i, slot in enumerate(layout):
            # NB: prevent_cse stays True here — in UNROLLED code,
            # prevent_cse=False lets XLA CSE re-merge the rematerialized
            # values with the forward ones, silently disabling remat
            # (observed: 294 GB/device on recurrentgemma × train_4k).
            # Inside lax.scan bodies the loop boundary blocks CSE, so the
            # scan paths keep prevent_cse=False for cheaper HLO.
            if slot.mixer is not None:
                mk = "attn" if slot.mixer == "local_attn" else slot.mixer
                pm = tree_slice(params["stacks"][mk], slot.mixer_idx)
                step = lambda h, pm=pm, slot=slot: _apply_mixer(
                    slot.mixer, pm, cfg, h, positions, impl=impl)
                if remat:
                    step = jax.checkpoint(step)
                out, kv = step(h)
                h = act.hidden(h + gates["mixer"][i].astype(h.dtype) * out)
                if collect_kv and kv is not None:
                    kvs.append(kv)
            if slot.ffn is not None:
                pf = tree_slice(params["stacks"][slot.ffn], slot.ffn_idx)
                fstep = lambda h, pf=pf, slot=slot: _apply_ffn(
                    slot.ffn, pf, cfg, h, impl=impl)
                if remat:
                    fstep = jax.checkpoint(fstep)
                h = act.hidden(h + gates["ffn"][i].astype(h.dtype) * fstep(h))
    if not unembed:
        return h, kvs
    logits = _unembed(params, cfg, h)
    return logits, kvs


def _forward_pattern_groups(params, cfg, h, positions, gates, *, impl,
                            remat, unembed):
    """Patterned architectures (Griffin's rglru/rglru/local_attn) as a
    ``lax.scan`` over repeating GROUPS of stacked params — the MaxText
    "repeat block" trick. A fully unrolled 38-layer train graph keeps every
    layer's backward residuals live simultaneously (86–294 GB/device on
    recurrentgemma × train_4k depending on remat details) and compiles for
    minutes; the group scan restores while-loop double-buffering and
    O(pattern) HLO. Trailing layers that do not complete a group unroll.
    """
    pattern = cfg.layer_specs()[0:len(cfg.block_pattern)]
    pattern = [m for m, _ in cfg.layer_specs()][:len(cfg.block_pattern)]
    plen = len(pattern)
    L = cfg.n_layers
    n_groups = L // plen
    rem = L - n_groups * plen

    # per-kind count inside one pattern repetition
    c_kind: Dict[str, int] = {}
    for m in pattern:
        mk = "attn" if m == "local_attn" else m
        c_kind[mk] = c_kind.get(mk, 0) + 1

    # grouped param stacks: position j of every group, stacked over groups
    grouped = []
    occ: Dict[str, int] = {}
    for j, m in enumerate(pattern):
        mk = "attn" if m == "local_attn" else m
        off = occ.get(mk, 0)
        occ[mk] = off + 1
        idx = off + c_kind[mk] * jnp.arange(n_groups)
        mix_j = jax.tree.map(lambda x, i=idx: x[i], params["stacks"][mk])
        ffn_idx = j + plen * jnp.arange(n_groups)
        ffn_j = jax.tree.map(lambda x, i=ffn_idx: x[i],
                             params["stacks"]["dense"])
        grouped.append((mix_j, ffn_j))

    gm = gates["mixer"][: n_groups * plen].reshape(n_groups, plen)
    gf = gates["ffn"][: n_groups * plen].reshape(n_groups, plen)

    def body(carry, xs):
        h = act.hidden(carry)
        trees, gm_g, gf_g = xs
        for j, m in enumerate(pattern):
            mix_j, ffn_j = trees[j]
            out, _ = _apply_mixer(m, mix_j, cfg, h, positions, impl=impl)
            h = h + gm_g[j].astype(h.dtype) * out
            h = h + gf_g[j].astype(h.dtype) * _apply_ffn(
                "dense", ffn_j, cfg, h, impl=impl)
        return h, None

    if remat:
        body = jax.checkpoint(body, prevent_cse=False)
    h, _ = jax.lax.scan(body, h, (tuple(grouped), gm, gf))

    # remainder layers (pattern prefix), unrolled with safe remat
    occ = {}
    for r in range(rem):
        m = pattern[r]
        mk = "attn" if m == "local_attn" else m
        off = occ.get(mk, 0)
        occ[mk] = off + 1
        mix_r = tree_slice(params["stacks"][mk],
                           c_kind.get(mk, 0) * n_groups + off)
        ffn_r = tree_slice(params["stacks"]["dense"], n_groups * plen + r)
        i = n_groups * plen + r

        def step(h, mix_r=mix_r, m=m):
            return _apply_mixer(m, mix_r, cfg, h, positions, impl=impl)[0]

        def fstep(h, ffn_r=ffn_r):
            return _apply_ffn("dense", ffn_r, cfg, h, impl=impl)

        if remat:
            step, fstep = jax.checkpoint(step), jax.checkpoint(fstep)
        h = act.hidden(h + gates["mixer"][i].astype(h.dtype) * step(h))
        h = h + gates["ffn"][i].astype(h.dtype) * fstep(h)

    if not unembed:
        return h, None
    return _unembed(params, cfg, h), None


# ---------------------------------------------------------------------- cache
def init_cache(cfg, batch: int, max_len: int, layout=None,
               kv_dtype=None) -> dict:
    """Pre-allocated decode state for every stateful kind in the layout."""
    layout = layout or default_layout(cfg)
    kv_dtype = kv_dtype or cfg.jnp_dtype()
    n_global = sum(1 for s in layout if s.mixer == "attn")
    n_local = sum(1 for s in layout if s.mixer == "local_attn")
    n_rglru = sum(1 for s in layout if s.mixer == "rglru")
    n_ssd = sum(1 for s in layout if s.mixer == "ssd")
    cache: dict = {"pos": jnp.zeros((), jnp.int32)}
    if n_global:
        cache["attn"] = attention.init_kv_cache(cfg, batch, max_len,
                                                n_global, kv_dtype)
    if n_local:
        w = min(cfg.attn_window, max_len)
        cache["local_attn"] = attention.init_kv_cache(cfg, batch, w,
                                                      n_local, kv_dtype)
    if n_rglru:
        cache["rglru"] = rglru_mod.init_rglru_cache(cfg, batch, n_rglru)
    if n_ssd:
        cache["ssd"] = ssm_mod.init_ssd_cache(cfg, batch, n_ssd)
    return cache


def _cache_indices(layout):
    """Per-layer index into each kind's cache stack."""
    counters: Dict[str, int] = {}
    idx = []
    for s in layout:
        if s.mixer is None:
            idx.append(-1)
            continue
        i = counters.get(s.mixer, 0)
        counters[s.mixer] = i + 1
        idx.append(i)
    return idx


def _is_uniform(layout) -> bool:
    if force_unroll():
        return False
    return len(layout) > 0 and all(
        s.mixer == layout[0].mixer and s.ffn == layout[0].ffn for s in layout)


# -------------------------------------------------------------------- prefill
def prefill(params, cfg, tokens, max_len: int, *, gates=None,
            extra_embeds=None, impl: str = "xla", layout=None,
            kv_dtype=None) -> Tuple[jnp.ndarray, dict]:
    """Process the prompt; return (last-position logits [B,Vp], filled cache).

    Stateful mixers run their sequence form and we extract final state; the
    attention KV collected during the pass is written into the cache.
    Uniform architectures run as one ``lax.scan`` (small HLO, fast compiles
    at 512-device GSPMD); heterogeneous ones unroll.
    """
    layout = layout or default_layout(cfg)
    B, S = tokens.shape
    if extra_embeds is not None:
        S = S + extra_embeds.shape[1]
    L = len(layout)
    gates = gates or _ones_gates(L)
    h = _embed(params, cfg, tokens, extra_embeds)
    positions = jnp.arange(S)[None, :]
    cidx = _cache_indices(layout)

    if _is_uniform(layout) and layout[0].mixer == "attn":
        mixer_stack = params["stacks"]["attn"]
        ffn_stack = params["stacks"][layout[0].ffn] if layout[0].ffn else None

        def body(h, xs):
            h = act.hidden(h)
            pm, pf, gm, gf = xs
            hn = layers.apply_norm(cfg, pm["norm"], h)
            out, kv = attention.attention(pm, cfg, hn, positions, impl=impl)
            h = h + gm.astype(h.dtype) * out
            if pf is not None:
                h = h + gf.astype(h.dtype) * _apply_ffn(layout[0].ffn, pf,
                                                        cfg, h, impl=impl)
            return h, kv

        h, kvs = jax.lax.scan(body, h, (mixer_stack, ffn_stack,
                                        gates["mixer"], gates["ffn"]))
        cache = init_cache(cfg, B, max_len, layout, kv_dtype)
        stored = attention.store_kv(cache["attn"], kvs["k"], kvs["v"])
        for key, val in stored.items():
            cache["attn"][key] = jax.lax.dynamic_update_slice(
                cache["attn"][key], val, (0,) * cache["attn"][key].ndim)
        logits = _unembed(params, cfg, h[:, -1:, :])[:, 0]
        cache["pos"] = jnp.asarray(S, jnp.int32)
        return logits, cache

    if _is_uniform(layout) and layout[0].mixer == "ssd":
        mixer_stack = params["stacks"]["ssd"]
        ffn_stack = params["stacks"][layout[0].ffn] if layout[0].ffn else None

        def body(h, xs):
            h = act.hidden(h)
            pm, pf, gm, gf = xs
            hn = layers.apply_norm(cfg, pm["norm"], h)
            out, sstate, conv = _ssd_prefill(pm, cfg, hn)
            h = h + gm.astype(h.dtype) * out
            if pf is not None:
                h = h + gf.astype(h.dtype) * _apply_ffn(layout[0].ffn, pf,
                                                        cfg, h, impl=impl)
            return h, (sstate, conv)

        h, (states, convs) = jax.lax.scan(
            body, h, (mixer_stack, ffn_stack, gates["mixer"], gates["ffn"]))
        cache = init_cache(cfg, B, max_len, layout, kv_dtype)
        cache["ssd"]["state"] = states
        cache["ssd"]["conv"] = convs.astype(cache["ssd"]["conv"].dtype)
        logits = _unembed(params, cfg, h[:, -1:, :])[:, 0]
        cache["pos"] = jnp.asarray(S, jnp.int32)
        return logits, cache

    cache = init_cache(cfg, B, max_len, layout, kv_dtype)

    for i, slot in enumerate(layout):
        if slot.mixer is not None:
            mk = "attn" if slot.mixer == "local_attn" else slot.mixer
            pm = tree_slice(params["stacks"][mk], slot.mixer_idx)
            hn = layers.apply_norm(cfg, pm["norm"], h)
            if slot.mixer in ("attn", "local_attn"):
                window = cfg.attn_window if slot.mixer == "local_attn" else 0
                out, kv = attention.attention(pm, cfg, hn, positions,
                                              window=window, impl=impl)
                ci = cidx[i]
                k, v = kv["k"], kv["v"]
                if slot.mixer == "local_attn":
                    w = cache["local_attn"]["k"].shape[2]
                    if S >= w:
                        # keep last `w` positions; element i holds position
                        # (S-w+i) whose ring slot is (S-w+i) % w → roll by
                        # (S-w) % w so slot = pos % w stays valid.
                        k, v = k[:, S - w:], v[:, S - w:]
                        roll = (S - w) % w
                        k = jnp.roll(k, roll, axis=1)
                        v = jnp.roll(v, roll, axis=1)
                    else:
                        pad = ((0, 0), (0, w - S), (0, 0), (0, 0))
                        k, v = jnp.pad(k, pad), jnp.pad(v, pad)
                stored = attention.store_kv(cache[slot.mixer], k, v)
                for key, val in stored.items():
                    arr = cache[slot.mixer][key]
                    cache[slot.mixer][key] = jax.lax.dynamic_update_slice(
                        arr, val[None], (ci,) + (0,) * (arr.ndim - 1))
            elif slot.mixer == "rglru":
                out, hstate, conv = _rglru_prefill(pm, cfg, hn)
                ci = cidx[i]
                cache["rglru"]["h"] = cache["rglru"]["h"].at[ci].set(hstate)
                cache["rglru"]["conv"] = cache["rglru"]["conv"].at[ci].set(conv)
            else:  # ssd
                out, sstate, conv = _ssd_prefill(pm, cfg, hn)
                ci = cidx[i]
                cache["ssd"]["state"] = cache["ssd"]["state"].at[ci].set(sstate)
                cache["ssd"]["conv"] = cache["ssd"]["conv"].at[ci].set(conv)
            h = act.hidden(h + gates["mixer"][i].astype(h.dtype) * out)
        if slot.ffn is not None:
            pf = tree_slice(params["stacks"][slot.ffn], slot.ffn_idx)
            h = h + gates["ffn"][i].astype(h.dtype) * _apply_ffn(
                slot.ffn, pf, cfg, h, impl=impl)

    logits = _unembed(params, cfg, h[:, -1:, :])[:, 0]
    cache["pos"] = jnp.asarray(S, jnp.int32)
    return logits, cache


def _rglru_prefill(pm, cfg, hn):
    """Run sequence rglru and recover final recurrent + conv state."""
    out = rglru_mod.rglru_mixer(pm, cfg, hn)
    # recompute final state cheaply: redo gate path on the last CONV window
    u = act.width(jnp.einsum("btd,dw->btw", hn, pm["wx"].astype(hn.dtype)))
    K = pm["conv_w"].shape[0]
    up = jnp.pad(u, ((0, 0), (K - 1, 0), (0, 0)))
    uc = act.width(
        sum(up[:, i:i + u.shape[1], :] * pm["conv_w"].astype(u.dtype)[i][None, None]
            for i in range(K)) + pm["conv_b"].astype(u.dtype))
    a, b = rglru_mod._gates(pm, uc)
    hseq = rglru_mod.blocked_scan(a, b)
    return out, hseq[:, -1], u[:, -(K - 1):, :]


def _ssd_prefill(pm, cfg, hn):
    out = ssm_mod.ssd_mixer(pm, cfg, hn)
    # recover final state by rerunning the scan's state path
    DI, N, H, P = cfg.ssm_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim
    z, xBC, dt = ssm_mod._split_proj(pm, cfg, hn)
    xBC_conv = layers.silu(ssm_mod._causal_conv(
        xBC, pm["conv_w"].astype(hn.dtype), pm["conv_b"].astype(hn.dtype)))
    xc, Bm, Cm = jnp.split(xBC_conv, [DI, DI + N], axis=-1)
    dtf = jax.nn.softplus(dt.astype(jnp.float32) + pm["dt_bias"])
    A = -jnp.exp(pm["A_log"])
    log_a = dtf * A
    xh = xc.reshape(*xc.shape[:2], H, P).astype(jnp.float32) * dtf[..., None]
    _, final = ssm_mod._ssd_scan(xh, log_a, Bm.astype(jnp.float32),
                                 Cm.astype(jnp.float32), cfg.ssm_chunk)
    K = pm["conv_w"].shape[0]
    return out, final, xBC[:, -(K - 1):, :]


def prefill_chunk(params, cfg, cache, tokens, start, *, gates=None,
                  impl: str = "xla",
                  layout=None) -> Tuple[jnp.ndarray, dict]:
    """Process one prompt chunk against a partially filled slot cache.

    The chunked-prefill hot path (DESIGN.md §6): ``tokens`` [B, C] are C
    consecutive prompt tokens at absolute offset ``start`` (int32 scalar,
    traced — executables key on the chunk width, never the offset). Layers
    scan with the KV cache riding the carry exactly like
    :func:`decode_step`; each layer's chunk K/V lands at ``[start,
    start+C)`` and the chunk's queries attend everything written so far.
    Running a prompt chunk-by-chunk (any split) then reading the final
    chunk's last-position logits is bitwise-identical to :func:`prefill`.
    Returns (last-position logits [B, Vp], cache).

    Uniform all-attention layouts only — recurrent/SSD state has no
    positional write frontier to resume from; heterogeneous models stay
    on the monolithic prefill.
    """
    layout = layout or default_layout(cfg)
    if not (_is_uniform(layout) and layout[0].mixer == "attn"):
        raise NotImplementedError(
            "prefill_chunk serves uniform all-attention layouts; "
            f"got mixers {sorted({str(s.mixer) for s in layout})} — use "
            "prefill (monolithic) for heterogeneous models")
    L = len(layout)
    gates = gates or _ones_gates(L)
    start = jnp.asarray(start, jnp.int32)
    h = _embed(params, cfg, tokens, None)
    mixer_stack = params["stacks"]["attn"]
    ffn_stack = params["stacks"][layout[0].ffn] if layout[0].ffn else None
    state0 = cache["attn"]

    def body(carry, xs):
        h, state = carry
        pm, pf, gm, gf, i = xs
        hn = layers.apply_norm(cfg, pm["norm"], h)
        kv = jax.tree.map(
            lambda x: jax.lax.dynamic_index_in_dim(x, i, 0, keepdims=False),
            state)
        out, kv = attention.chunk_attention(pm, cfg, hn, kv, start, impl=impl)
        state = jax.tree.map(
            lambda s, n: jax.lax.dynamic_update_index_in_dim(s, n, i, 0),
            state, kv)
        h = h + _bgate(gm, h) * out
        if pf is not None:
            h = h + _bgate(gf, h) * _apply_ffn(layout[0].ffn, pf, cfg, h,
                                               impl=impl)
        return (h, state), None

    xs = (mixer_stack, ffn_stack, gates["mixer"], gates["ffn"],
          jnp.arange(L, dtype=jnp.int32))
    (h, state), _ = jax.lax.scan(body, (h, state0), xs)
    cache["attn"] = state
    logits = _unembed(params, cfg, h[:, -1:, :])[:, 0]
    cache["pos"] = start + tokens.shape[1]
    return logits, cache


def _pool_layer(pools: dict, i) -> dict:
    """Layer ``i``'s slice of every pool leaf (pages and, when the pool is
    quantized, the per-page scales)."""
    return {name: jax.lax.dynamic_index_in_dim(leaf, i, 0, keepdims=False)
            for name, leaf in pools.items()}


def _pool_store(pools: dict, kv: dict, i) -> dict:
    """Write a layer's updated slices back into the stacked pools."""
    return {name: jax.lax.dynamic_update_index_in_dim(pools[name], kv[name],
                                                      i, 0)
            for name in pools}


def paged_prefill_chunk(params, cfg, pools: dict, page_table, tokens, start,
                        *, scratch_page: int, gates=None, impl: str = "xla",
                        layout=None) -> Tuple[jnp.ndarray, dict]:
    """Paged sibling of :func:`prefill_chunk`: one prompt chunk appended
    straight into granted pages.

    pools: {"k","v"} [L, n_pages, page_tokens, K, Dh] — quantized pools
    add per-page scale leaves {"ks","vs"} [L, n_pages, K]; page_table:
    int32 [B, max_pages]; tokens [B, C] at absolute offset ``start``. The
    pool arrays ride the layer scan's carry (donated, in-place) exactly
    like :func:`paged_decode_step`; the same uniform all-attention
    restriction applies. Returns (last-position logits [B, Vp], pools').
    """
    layout = layout or default_layout(cfg)
    if not (len(layout) > 0
            and all(s.mixer == "attn" and s.ffn == layout[0].ffn
                    for s in layout)):
        raise NotImplementedError(
            "paged prefill serves uniform all-attention layouts; "
            f"got mixers {sorted({str(s.mixer) for s in layout})} — use "
            "prefill (slot caches) for heterogeneous models")
    L = len(layout)
    gates = gates or _ones_gates(L)
    start = jnp.asarray(start, jnp.int32)
    page_table = jnp.asarray(page_table, jnp.int32)
    h = _embed(params, cfg, tokens, None)
    mixer_stack = params["stacks"]["attn"]
    ffn_stack = params["stacks"][layout[0].ffn] if layout[0].ffn else None

    def body(carry, xs):
        h, pools = carry
        pm, pf, gm, gf, i = xs
        hn = layers.apply_norm(cfg, pm["norm"], h)
        kv = _pool_layer(pools, i)
        out, kv = attention.paged_chunk_attention(
            pm, cfg, hn, kv, page_table, start, scratch_page=scratch_page,
            impl=impl)
        pools = _pool_store(pools, kv, i)
        h = h + _bgate(gm, h) * out
        if pf is not None:
            h = h + _bgate(gf, h) * _apply_ffn(layout[0].ffn, pf, cfg, h,
                                               impl=impl)
        return (h, pools), None

    xs = (mixer_stack, ffn_stack, gates["mixer"], gates["ffn"],
          jnp.arange(L, dtype=jnp.int32))
    (h, pools), _ = jax.lax.scan(body, (h, dict(pools)), xs)
    logits = _unembed(params, cfg, h[:, -1:, :])[:, 0]
    return logits, pools


# --------------------------------------------------------------------- decode
def decode_step(params, cfg, cache, tokens, *, gates=None, impl: str = "xla",
                layout=None) -> Tuple[jnp.ndarray, dict]:
    """One autoregressive step. tokens: [B,1]. Returns (logits [B,1,Vp], cache).

    Continuous-batching form: ``cache["pos"]`` may be an int32 [B] vector
    (per-slot decode offsets) and ``gates`` entries may be [L, B] (per-slot
    keep-masks) — every slot of the engine's shared cache advances one token
    in a single fused step. Scalar pos / [L] gates remain the one-shot path.
    """
    layout = layout or default_layout(cfg)
    L = len(layout)
    gates = gates or _ones_gates(L)
    pos = cache["pos"]
    h = _embed(params, cfg, tokens, None)
    cidx = _cache_indices(layout)

    if _is_uniform(layout) and layout[0].mixer in ("attn", "ssd"):
        kind = layout[0].mixer
        mixer_stack = params["stacks"][kind]
        ffn_stack = params["stacks"][layout[0].ffn] if layout[0].ffn else None

        # The layer-state buffer rides the scan CARRY with per-layer
        # dynamic(-update)-slice — in-place while-loop updates that alias
        # the donated input cache. (Passing it as scan xs/ys doubles the
        # live cache: the stacked ys staging buffer costs a full extra
        # copy — 11 GB/device on qwen1.5-32b × decode_32k.)
        state0 = cache["attn"] if kind == "attn" else cache["ssd"]

        def body(carry, xs):
            h, state = carry
            pm, pf, gm, gf, i = xs
            hn = layers.apply_norm(cfg, pm["norm"], h)
            if kind == "attn":
                kv = jax.tree.map(
                    lambda x: jax.lax.dynamic_index_in_dim(
                        x, i, 0, keepdims=False), state)
                out, kv = attention.decode_attention(pm, cfg, hn, kv, pos,
                                                     impl=impl)
                state = jax.tree.map(
                    lambda s, n: jax.lax.dynamic_update_index_in_dim(
                        s, n, i, 0), state, kv)
            else:
                ss = jax.lax.dynamic_index_in_dim(state["state"], i, 0,
                                                  keepdims=False)
                cb = jax.lax.dynamic_index_in_dim(state["conv"], i, 0,
                                                  keepdims=False)
                out, ss, cb = ssm_mod.ssd_decode_step(pm, cfg, hn, ss, cb)
                state = {
                    "state": jax.lax.dynamic_update_index_in_dim(
                        state["state"], ss, i, 0),
                    "conv": jax.lax.dynamic_update_index_in_dim(
                        state["conv"], cb, i, 0)}
            h = h + _bgate(gm, h) * out
            if pf is not None:
                h = h + _bgate(gf, h) * _apply_ffn(layout[0].ffn, pf,
                                                   cfg, h, impl=impl)
            return (h, state), None

        L_kind = len(layout)
        xs = (mixer_stack, ffn_stack, gates["mixer"], gates["ffn"],
              jnp.arange(L_kind, dtype=jnp.int32))
        (h, state), _ = jax.lax.scan(body, (h, state0), xs)
        if kind == "attn":
            cache["attn"] = state
        else:
            cache["ssd"] = state
        logits = _unembed(params, cfg, h)
        cache["pos"] = pos + 1
        return logits, cache

    for i, slot in enumerate(layout):
        if slot.mixer is not None:
            mk = "attn" if slot.mixer == "local_attn" else slot.mixer
            pm = tree_slice(params["stacks"][mk], slot.mixer_idx)
            hn = layers.apply_norm(cfg, pm["norm"], h)
            ci = cidx[i]
            if slot.mixer in ("attn", "local_attn"):
                kind = slot.mixer
                window = cfg.attn_window if kind == "local_attn" else 0
                kv = jax.tree.map(lambda x: x[ci], cache[kind])
                out, kv = attention.decode_attention(pm, cfg, hn, kv, pos,
                                                     window=window, impl=impl)
                cache[kind] = jax.tree.map(lambda c, n: c.at[ci].set(n),
                                           cache[kind], kv)
            elif slot.mixer == "rglru":
                out, hs, cb = rglru_mod.rglru_decode_step(
                    pm, cfg, hn, cache["rglru"]["h"][ci],
                    cache["rglru"]["conv"][ci])
                cache["rglru"]["h"] = cache["rglru"]["h"].at[ci].set(hs)
                cache["rglru"]["conv"] = cache["rglru"]["conv"].at[ci].set(cb)
            else:
                out, ss, cb = ssm_mod.ssd_decode_step(
                    pm, cfg, hn, cache["ssd"]["state"][ci],
                    cache["ssd"]["conv"][ci])
                cache["ssd"]["state"] = cache["ssd"]["state"].at[ci].set(ss)
                cache["ssd"]["conv"] = cache["ssd"]["conv"].at[ci].set(cb)
            h = h + _bgate(gates["mixer"][i], h) * out
        if slot.ffn is not None:
            pf = tree_slice(params["stacks"][slot.ffn], slot.ffn_idx)
            h = h + _bgate(gates["ffn"][i], h) * _apply_ffn(
                slot.ffn, pf, cfg, h, impl=impl)

    logits = _unembed(params, cfg, h)
    cache["pos"] = pos + 1
    return logits, cache


def decode_horizon(params, cfg, cache, tokens, horizon: int, *, gates=None,
                   impl: str = "xla",
                   layout=None) -> Tuple[jnp.ndarray, dict]:
    """Fuse ``horizon`` greedy decode ticks into one on-device loop.

    ``lax.scan`` over :func:`decode_step`: each iteration feeds the argmax
    token of the previous step back in, so a whole *horizon* of tokens is
    produced by ONE dispatched executable with ONE device→host read-back
    (the ``[B, horizon]`` token matrix) instead of ``horizon`` round trips.
    ``tokens`` is the int32 [B, 1] seed (the last emitted token per row);
    ``gates``/``pos`` semantics are exactly :func:`decode_step`'s — per-slot
    [L, B] gates and int32 [B] positions ride the scan unchanged/incremented.
    Returns (toks int32 [B, horizon], cache after ``horizon`` steps).
    Greedy only: the scan carries the argmax token, not logits.
    """
    horizon = int(horizon)
    if horizon < 1:
        raise ValueError(f"horizon must be >= 1, got {horizon}")

    def body(carry, _):
        cache, tok = carry
        logits, cache = decode_step(params, cfg, cache, tok, gates=gates,
                                    impl=impl, layout=layout)
        nxt = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
        return (cache, nxt[:, None]), nxt

    seed = jnp.asarray(tokens, jnp.int32)
    (cache, _), toks = jax.lax.scan(body, (cache, seed), None, length=horizon)
    return jnp.moveaxis(toks, 0, 1), cache


def paged_decode_horizon(params, cfg, pools: dict, page_table, pos, tokens,
                         horizon: int, *, gates=None, impl: str = "xla",
                         layout=None) -> Tuple[jnp.ndarray, dict, jnp.ndarray]:
    """Fuse ``horizon`` paged decode ticks into one on-device loop.

    The paged sibling of :func:`decode_horizon`: scans
    :func:`paged_decode_step` with the page pools, per-row positions, and
    the fed-back argmax token riding the carry. The page table is
    *constant* across the horizon — callers pre-grant every page the
    horizon can touch (``KVPool.extend(rid, horizon)``) before launching,
    which the admission-time worst-case commitment guarantees can't fail.
    Returns (toks int32 [B, horizon], pools', pos + horizon).
    """
    horizon = int(horizon)
    if horizon < 1:
        raise ValueError(f"horizon must be >= 1, got {horizon}")
    pos = jnp.asarray(pos, jnp.int32)
    page_table = jnp.asarray(page_table, jnp.int32)

    def body(carry, _):
        pools, pos, tok = carry
        logits, pools = paged_decode_step(params, cfg, pools, page_table,
                                          pos, tok, gates=gates, impl=impl,
                                          layout=layout)
        nxt = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
        return (pools, pos + 1, nxt[:, None]), nxt

    seed = jnp.asarray(tokens, jnp.int32)
    (pools, pos, _), toks = jax.lax.scan(body, (pools, pos, seed), None,
                                         length=horizon)
    return jnp.moveaxis(toks, 0, 1), pools, pos


def paged_decode_step(params, cfg, pools: dict, page_table, pos, tokens, *,
                      gates=None, impl: str = "xla",
                      layout=None) -> Tuple[jnp.ndarray, dict]:
    """One autoregressive step against a *paged* KV pool.

    pools: {"k","v"} global page arrays [L, n_pages, page_tokens, K, Dh]
    (one pool slice per attention layer, stacked — a page id is valid at
    every layer; quantized pools add {"ks","vs"} [L, n_pages, K] scales);
    page_table: int32 [B, max_pages]; pos: int32 [B] per-row
    write positions; tokens: [B, 1]. Returns (logits [B,1,Vp], pools').

    Only uniform all-attention layouts are supported (the llama/gemma/qwen
    families the paper evaluates): heterogeneous mixers keep their state in
    per-request slot caches and stay on :func:`decode_step` — paging
    recurrent/SSD state is a different (fixed-size) problem. Gates may be
    [L] (one-shot) or [L, B] (per-slot keep-masks), as in ``decode_step``.
    The pool arrays ride the layer scan's carry with per-layer
    dynamic(-update)-slice, aliasing the donated inputs exactly like the
    dense decode path.
    """
    layout = layout or default_layout(cfg)
    if not (len(layout) > 0
            and all(s.mixer == "attn" and s.ffn == layout[0].ffn
                    for s in layout)):
        raise NotImplementedError(
            "paged decode serves uniform all-attention layouts; "
            f"got mixers {sorted({str(s.mixer) for s in layout})} — use "
            "decode_step (slot caches) for heterogeneous models")
    L = len(layout)
    gates = gates or _ones_gates(L)
    pos = jnp.asarray(pos, jnp.int32)
    page_table = jnp.asarray(page_table, jnp.int32)
    h = _embed(params, cfg, tokens, None)
    mixer_stack = params["stacks"]["attn"]
    ffn_stack = params["stacks"][layout[0].ffn] if layout[0].ffn else None

    def body(carry, xs):
        h, pools = carry
        pm, pf, gm, gf, i = xs
        hn = layers.apply_norm(cfg, pm["norm"], h)
        kv = _pool_layer(pools, i)
        out, kv = attention.paged_decode_attention(pm, cfg, hn, kv,
                                                   page_table, pos,
                                                   impl=impl)
        pools = _pool_store(pools, kv, i)
        h = h + _bgate(gm, h) * out
        if pf is not None:
            h = h + _bgate(gf, h) * _apply_ffn(layout[0].ffn, pf, cfg, h,
                                               impl=impl)
        return (h, pools), None

    xs = (mixer_stack, ffn_stack, gates["mixer"], gates["ffn"],
          jnp.arange(L, dtype=jnp.int32))
    (h, pools), _ = jax.lax.scan(body, (h, dict(pools)), xs)
    logits = _unembed(params, cfg, h)
    return logits, pools
