"""Dense feed-forward blocks: SwiGLU / GeGLU / GELU."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.models import layers


def init_ffn_params(rng, cfg) -> dict:
    k1, k2 = jax.random.split(rng)
    pd = cfg.jnp_param_dtype()
    if cfg.activation in ("swiglu", "geglu"):
        wi = layers.dense_init(k1, cfg.d_model, 2 * cfg.d_ff, pd)
    else:
        wi = layers.dense_init(k1, cfg.d_model, cfg.d_ff, pd)
    wo = layers.dense_init(k2, cfg.d_ff, cfg.d_model, pd,
                           scale=1.0 / math.sqrt(2 * max(cfg.n_layers, 1)))
    return {"wi": wi, "wo": wo}


def glu_activate(h, activation: str, impl: str = "xla"):
    """h: [..., 2F] fused (gate, up) → [..., F]."""
    if impl == "pallas":
        from repro.kernels import ops as kops
        return kops.fused_glu(h, activation)
    gate, up = jnp.split(h, 2, axis=-1)
    act = layers.silu(gate) if activation == "swiglu" else layers.gelu(gate)
    return act * up


def ffn(params, cfg, x, *, impl: str = "xla"):
    h = jnp.einsum("bsd,df->bsf", x, params["wi"].astype(x.dtype))
    if cfg.activation in ("swiglu", "geglu"):
        h = glu_activate(h, cfg.activation, impl)
    else:
        h = layers.gelu(h)
    return jnp.einsum("bsf,fd->bsd", h, params["wo"].astype(x.dtype))
