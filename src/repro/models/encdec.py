"""Whisper-style encoder-decoder [arXiv:2212.04356].

The conv frontend is a STUB per the assignment: the encoder consumes
precomputed mel-frame embeddings ``frames [B, n_audio_frames, d_model]``
(provided by ``input_specs``), adds learned positions, and runs bidirectional
attention. The decoder is causal with per-layer self-attn KV cache plus
cross-attn KV computed once at prefill.

Adaptation note (recorded in DESIGN.md): Whisper's learned decoder positions
are replaced with sinusoidal ones so parameters stay independent of the
assigned decode lengths (up to 32k ≫ Whisper's native 448).

RAP mapping: the (self-attn + cross-attn) pair is the prunable "MHA" unit —
it owns the growing self-KV cache; FFN is the parameter unit. Encoder layers
run once per request and are not pruned online.
"""
from __future__ import annotations

import math
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.models import attention, ffn as ffn_mod, layers
from repro.models.decoder import _ones_gates, force_unroll, tree_slice
from repro.parallel import activation as act


def _sinusoid(positions, d_model: int):
    half = d_model // 2
    freqs = jnp.exp(-jnp.arange(half, dtype=jnp.float32)
                    * (math.log(10000.0) / max(half - 1, 1)))
    ang = positions[..., None].astype(jnp.float32) * freqs
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


def init_params(rng, cfg) -> dict:
    ks = jax.random.split(rng, 8)
    pd = cfg.jnp_param_dtype()

    def stack(key, n, init_fn):
        keys = jax.random.split(key, n)
        trees = [dict(norm=layers.init_norm(cfg), **init_fn(keys[i], cfg))
                 for i in range(n)]
        return jax.tree.map(lambda *xs: jnp.stack(xs), *trees)

    return {
        "embed": layers.embed_init(ks[0], cfg.vocab_padded, cfg.d_model, pd),
        "enc_pos": (jax.random.normal(ks[1], (cfg.n_audio_frames, cfg.d_model),
                                      jnp.float32) * 0.02).astype(pd),
        "final_norm": layers.init_norm(cfg),
        "enc_final_norm": layers.init_norm(cfg),
        "stacks": {
            "enc_attn": stack(ks[2], cfg.n_encoder_layers,
                              attention.init_attn_params),
            "enc_ffn": stack(ks[3], cfg.n_encoder_layers,
                             ffn_mod.init_ffn_params),
            "attn": stack(ks[4], cfg.n_layers, attention.init_attn_params),
            "cross": stack(ks[5], cfg.n_layers, attention.init_attn_params),
            "ffn": stack(ks[6], cfg.n_layers, ffn_mod.init_ffn_params),
        },
    }


def _bidir_attend(cfg, q, k, v):
    """Unmasked attention, chunked over queries when long (memory bound)."""
    if q.shape[1] >= 2048:
        return attention._sdpa_chunked(cfg, q, k, v, causal=False)
    mask = jnp.ones((1, 1, q.shape[1], k.shape[1]), bool)
    return attention._sdpa(cfg, q, k, v, mask)


def encode(params, cfg, frames, *, impl: str = "xla", remat: bool = False):
    """frames: [B, T_enc, D] (stub frontend output) → [B, T_enc, D]."""
    h = frames.astype(cfg.jnp_dtype()) + params["enc_pos"][None].astype(cfg.jnp_dtype())

    def body(h, xs):
        pa, pf = xs
        h = act.hidden(h)
        hn = layers.apply_norm(cfg, pa["norm"], h)
        q, k, v = attention._project_qkv(pa, cfg, hn)
        out = _bidir_attend(cfg, q, k, v)
        out = jnp.einsum("bsq,qm->bsm", out.reshape(*out.shape[:2], -1),
                         pa["wo"].astype(h.dtype))
        h = h + out
        hn = layers.apply_norm(cfg, pf["norm"], h)
        h = h + ffn_mod.ffn(pf, cfg, hn, impl=impl)
        return h, None

    if remat:
        # prevent_cse=False is only safe inside scan bodies (see decoder)
        body = (jax.checkpoint(body) if force_unroll()
                else jax.checkpoint(body, prevent_cse=False))
    if force_unroll():
        for i in range(cfg.n_encoder_layers):
            h, _ = body(h, (tree_slice(params["stacks"]["enc_attn"], i),
                            tree_slice(params["stacks"]["enc_ffn"], i)))
    else:
        h, _ = jax.lax.scan(body, h, (params["stacks"]["enc_attn"],
                                      params["stacks"]["enc_ffn"]))
    return layers.apply_norm(cfg, params["enc_final_norm"], h)


def _cross_kv(params_cross_stack, cfg, enc_h):
    """Precompute per-decoder-layer cross K/V: [Ld, B, T_enc, K, Dh]."""
    def body(_, pc):
        _, k, v = attention._project_qkv(pc, cfg, enc_h)
        return None, (k, v)

    _, (ks, vs) = jax.lax.scan(body, None, params_cross_stack)
    return ks, vs


def _decoder_pass(params, cfg, h, positions, enc_h, gates, *, impl,
                  remat: bool = False):
    """Teacher-forced decoder over a full sequence (train / scoring)."""
    def body(h, xs):
        pa, pc, pf, gm, gf = xs
        h = act.hidden(h)
        hn = layers.apply_norm(cfg, pa["norm"], h)
        out, _ = attention.attention(pa, cfg, hn, positions, impl=impl)
        h = h + gm.astype(h.dtype) * out
        hn = layers.apply_norm(cfg, pc["norm"], h)
        _, ck, cv = attention._project_qkv(pc, cfg, enc_h)
        B, Sq = hn.shape[:2]
        q, _, _ = attention._project_qkv(pc, cfg, hn)
        xout = _bidir_attend(cfg, q, ck, cv)
        xout = jnp.einsum("bsq,qm->bsm", xout.reshape(B, Sq, -1),
                          pc["wo"].astype(h.dtype))
        h = h + gm.astype(h.dtype) * xout
        hn = layers.apply_norm(cfg, pf["norm"], h)
        h = h + gf.astype(h.dtype) * ffn_mod.ffn(pf, cfg, hn, impl=impl)
        return h, None

    if remat:
        # prevent_cse=False is only safe inside scan bodies (see decoder)
        body = (jax.checkpoint(body) if force_unroll()
                else jax.checkpoint(body, prevent_cse=False))
    if force_unroll():
        for i in range(cfg.n_layers):
            h, _ = body(h, (tree_slice(params["stacks"]["attn"], i),
                            tree_slice(params["stacks"]["cross"], i),
                            tree_slice(params["stacks"]["ffn"], i),
                            gates["mixer"][i], gates["ffn"][i]))
    else:
        h, _ = jax.lax.scan(body, h, (params["stacks"]["attn"],
                                      params["stacks"]["cross"],
                                      params["stacks"]["ffn"],
                                      gates["mixer"], gates["ffn"]))
    return h


def _embed_tokens(params, cfg, tokens, offset):
    h = params["embed"][tokens].astype(cfg.jnp_dtype())
    pos = jnp.arange(tokens.shape[1]) + offset
    return h + _sinusoid(pos, cfg.d_model)[None].astype(h.dtype), pos[None]


def forward(params, cfg, tokens, frames, *, gates=None, impl: str = "xla",
            remat: bool = False, unembed: bool = True):
    """Teacher-forced logits [B, S, Vp] (f32); ``unembed=False`` returns the
    pre-final-norm hidden state (chunked-CE path)."""
    gates = gates or _ones_gates(cfg.n_layers)
    enc_h = encode(params, cfg, frames, impl=impl, remat=remat)
    h, positions = _embed_tokens(params, cfg, tokens, 0)
    h = _decoder_pass(params, cfg, h, positions, enc_h, gates, impl=impl,
                      remat=remat)
    if not unembed:
        return h
    h = layers.apply_norm(cfg, params["final_norm"], h)
    return act.logits(
        jnp.einsum("bsd,vd->bsv", h, params["embed"].astype(h.dtype),
                   preferred_element_type=jnp.float32))


def unembed(params, cfg, h):
    h = layers.apply_norm(cfg, params["final_norm"], h)
    return act.logits(
        jnp.einsum("bsd,vd->bsv", h, params["embed"].astype(h.dtype),
                   preferred_element_type=jnp.float32))


def init_cache(cfg, batch: int, max_len: int, kv_dtype=None) -> dict:
    """Self-attn cache honours kv_dtype (incl. int8 quantized); cross-attn
    KV is fixed-size (encoder length) and stays in activation dtype."""
    dt = cfg.jnp_dtype()
    Ld = cfg.n_layers
    return {
        "pos": jnp.zeros((), jnp.int32),
        "attn": attention.init_kv_cache(cfg, batch, max_len, Ld, kv_dtype),
        "cross": {"k": jnp.zeros((Ld, batch, cfg.n_audio_frames,
                                  cfg.n_kv_heads, cfg.dh), dt),
                  "v": jnp.zeros((Ld, batch, cfg.n_audio_frames,
                                  cfg.n_kv_heads, cfg.dh), dt)},
    }


def prefill(params, cfg, tokens, frames, max_len: int, *, gates=None,
            impl: str = "xla", kv_dtype=None) -> Tuple[jnp.ndarray, dict]:
    """Encode audio + consume the decoder prompt. Returns (last logits, cache)."""
    gates = gates or _ones_gates(cfg.n_layers)
    B, S = tokens.shape
    enc_h = encode(params, cfg, frames, impl=impl)
    cache = init_cache(cfg, B, max_len, kv_dtype)
    ck, cv = _cross_kv(params["stacks"]["cross"], cfg, enc_h)
    cache["cross"]["k"] = ck.astype(cache["cross"]["k"].dtype)
    cache["cross"]["v"] = cv.astype(cache["cross"]["v"].dtype)

    h, positions = _embed_tokens(params, cfg, tokens, 0)

    def body(h, xs):
        pa, pc, pf, gm, gf, xk, xv = xs
        hn = layers.apply_norm(cfg, pa["norm"], h)
        out, kv = attention.attention(pa, cfg, hn, positions, impl=impl)
        h = h + gm.astype(h.dtype) * out
        hn = layers.apply_norm(cfg, pc["norm"], h)
        q, _, _ = attention._project_qkv(pc, cfg, hn)
        mask = jnp.ones((1, 1, h.shape[1], xk.shape[1]), bool)
        xout = attention._sdpa(cfg, q, xk.astype(h.dtype),
                               xv.astype(h.dtype), mask)
        xout = jnp.einsum("bsq,qm->bsm", xout.reshape(*h.shape[:2], -1),
                          pc["wo"].astype(h.dtype))
        h = h + gm.astype(h.dtype) * xout
        hn = layers.apply_norm(cfg, pf["norm"], h)
        h = h + gf.astype(h.dtype) * ffn_mod.ffn(pf, cfg, hn, impl=impl)
        return h, kv

    if force_unroll():
        kv_list = []
        for i in range(cfg.n_layers):
            h, kv_i = body(h, (tree_slice(params["stacks"]["attn"], i),
                               tree_slice(params["stacks"]["cross"], i),
                               tree_slice(params["stacks"]["ffn"], i),
                               gates["mixer"][i], gates["ffn"][i],
                               cache["cross"]["k"][i], cache["cross"]["v"][i]))
            kv_list.append(kv_i)
        kvs = jax.tree.map(lambda *xs: jnp.stack(xs), *kv_list)
    else:
        h, kvs = jax.lax.scan(body, h, (params["stacks"]["attn"],
                                        params["stacks"]["cross"],
                                        params["stacks"]["ffn"],
                                        gates["mixer"], gates["ffn"],
                                        cache["cross"]["k"],
                                        cache["cross"]["v"]))
    stored = attention.store_kv(cache["attn"], kvs["k"], kvs["v"])
    for key, val in stored.items():
        cache["attn"][key] = jax.lax.dynamic_update_slice(
            cache["attn"][key], val, (0,) * cache["attn"][key].ndim)
    cache["pos"] = jnp.asarray(S, jnp.int32)
    h = layers.apply_norm(cfg, params["final_norm"], h[:, -1:, :])
    logits = jnp.einsum("bsd,vd->bsv", h, params["embed"].astype(h.dtype),
                        preferred_element_type=jnp.float32)
    return logits[:, 0], cache


def decode_step(params, cfg, cache, tokens, *, gates=None,
                impl: str = "xla") -> Tuple[jnp.ndarray, dict]:
    gates = gates or _ones_gates(cfg.n_layers)
    pos = cache["pos"]
    h, _ = _embed_tokens(params, cfg, tokens, pos)

    def body(h, xs):
        pa, pc, pf, gm, gf, kv, xk, xv = xs
        hn = layers.apply_norm(cfg, pa["norm"], h)
        out, kv = attention.decode_attention(pa, cfg, hn, kv, pos, impl=impl)
        h = h + gm.astype(h.dtype) * out
        hn = layers.apply_norm(cfg, pc["norm"], h)
        q, _, _ = attention._project_qkv(pc, cfg, hn)
        mask = jnp.ones((1, 1, 1, xk.shape[1]), bool)
        xout = attention._sdpa(cfg, q, xk.astype(h.dtype),
                               xv.astype(h.dtype), mask)
        xout = jnp.einsum("bsq,qm->bsm", xout.reshape(h.shape[0], 1, -1),
                          pc["wo"].astype(h.dtype))
        h = h + gm.astype(h.dtype) * xout
        hn = layers.apply_norm(cfg, pf["norm"], h)
        h = h + gf.astype(h.dtype) * ffn_mod.ffn(pf, cfg, hn, impl=impl)
        return h, kv

    if force_unroll():
        kv_list = []
        for i in range(cfg.n_layers):
            h, kv_i = body(h, (tree_slice(params["stacks"]["attn"], i),
                               tree_slice(params["stacks"]["cross"], i),
                               tree_slice(params["stacks"]["ffn"], i),
                               gates["mixer"][i], gates["ffn"][i],
                               jax.tree.map(lambda x: x[i], cache["attn"]),
                               cache["cross"]["k"][i], cache["cross"]["v"][i]))
            kv_list.append(kv_i)
        kv_new = jax.tree.map(lambda *xs: jnp.stack(xs), *kv_list)
    else:
        h, kv_new = jax.lax.scan(body, h, (params["stacks"]["attn"],
                                           params["stacks"]["cross"],
                                           params["stacks"]["ffn"],
                                           gates["mixer"], gates["ffn"],
                                           cache["attn"],
                                           cache["cross"]["k"],
                                           cache["cross"]["v"]))
    cache["attn"] = kv_new
    cache["pos"] = pos + 1
    h = layers.apply_norm(cfg, params["final_norm"], h)
    logits = jnp.einsum("bsd,vd->bsv", h, params["embed"].astype(h.dtype),
                        preferred_element_type=jnp.float32)
    return logits, cache
