"""Mixture-of-Experts FFN with top-k routing.

Two dispatch implementations:

* ``dense``   — every expert runs on every token, one-hot combine. Exact
                (dropless), O(E/k) FLOP waste. Correctness oracle + smoke tests.
* ``scatter`` — MegaBlocks-style sort-free capacity dispatch: tokens are
                scattered into a per-expert ``[E, C, D]`` buffer, all experts
                run as one grouped einsum (MXU-friendly), results gathered
                back with routing weights. Tokens beyond capacity drop (GShard
                semantics). This is the production / dry-run path; the expert
                axis shards over the "model" mesh axis (EP).
"""
from __future__ import annotations

import math
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.models import layers
from repro.models.ffn import glu_activate
from repro.parallel import activation as act


def init_moe_params(rng, cfg) -> dict:
    k1, k2, k3 = jax.random.split(rng, 3)
    pd = cfg.jnp_param_dtype()
    E, D, F = cfg.n_experts, cfg.d_model, cfg.d_ff
    std_i = 1.0 / math.sqrt(D)
    std_o = 1.0 / math.sqrt(F) / math.sqrt(2 * max(cfg.n_layers, 1))
    wi = jax.random.truncated_normal(k1, -2, 2, (E, D, 2 * F), jnp.float32) * std_i
    wo = jax.random.truncated_normal(k2, -2, 2, (E, F, D), jnp.float32) * std_o
    router = layers.dense_init(k3, D, E, jnp.float32)  # router kept in f32
    return {"wi": wi.astype(pd), "wo": wo.astype(pd), "router": router}


def _route(params, cfg, x):
    """x: [T, D] → (weights [T, k], expert_idx [T, k]) with renormalized top-k."""
    logits = jnp.einsum("td,de->te", x.astype(jnp.float32), params["router"])
    probs = jax.nn.softmax(logits, axis=-1)
    weights, idx = jax.lax.top_k(probs, cfg.moe_top_k)
    weights = weights / jnp.sum(weights, axis=-1, keepdims=True)
    return weights.astype(x.dtype), idx


def moe_ffn_dense(params, cfg, x):
    """Oracle path. x: [B, S, D]."""
    B, S, D = x.shape
    xt = x.reshape(-1, D)
    weights, idx = _route(params, cfg, xt)                     # [T,k]
    onehot = jax.nn.one_hot(idx, cfg.n_experts, dtype=x.dtype)  # [T,k,E]
    combine = jnp.einsum("tk,tke->te", weights, onehot)         # [T,E]
    h = jnp.einsum("td,edf->tef", xt, params["wi"].astype(x.dtype))
    h = glu_activate(h, cfg.activation)
    y = jnp.einsum("tef,efd->ted", h, params["wo"].astype(x.dtype))
    out = jnp.einsum("ted,te->td", y, combine)
    return out.reshape(B, S, D)


def _capacity(cfg, T: int) -> int:
    c = int(math.ceil(cfg.moe_capacity_factor * T * cfg.moe_top_k / cfg.n_experts))
    return max(8, -(-c // 8) * 8)  # round up to 8 for TPU lanes


def moe_ffn_scatter(params, cfg, x):
    """Production path. x: [B, S, D]."""
    B, S, D = x.shape
    E, k = cfg.n_experts, cfg.moe_top_k
    xt = x.reshape(-1, D)
    T = xt.shape[0]
    C = _capacity(cfg, T)

    weights, idx = _route(params, cfg, xt)                 # [T,k]
    flat_e = idx.reshape(-1)                               # [T*k] expert ids
    # position of each assignment within its expert, via stable sort:
    # rank among same-expert assignments == cumulative count.
    order = jnp.argsort(flat_e, stable=True)               # [T*k]
    ranks = jnp.zeros((T * k,), jnp.int32)
    # within sorted order, rank = index - start_of_expert_segment
    sorted_e = flat_e[order]
    seg_start = jnp.searchsorted(sorted_e, jnp.arange(E))  # [E]
    pos_in_sorted = jnp.arange(T * k, dtype=jnp.int32)
    sorted_rank = pos_in_sorted - seg_start[sorted_e]
    ranks = ranks.at[order].set(sorted_rank)               # [T*k]

    keep = ranks < C                                       # capacity drop mask
    slot = jnp.where(keep, ranks, C)                       # overflow → trash slot
    tok = jnp.repeat(jnp.arange(T, dtype=jnp.int32), k)

    # scatter tokens → [E, C+1, D] buffer (last slot is trash)
    buf = jnp.zeros((E, C + 1, D), x.dtype)
    buf = buf.at[flat_e, slot].set(xt[tok], mode="drop")
    buf = act.expert_buffer(buf)          # EP: experts over "model"

    h = jnp.einsum("ecd,edf->ecf", buf, params["wi"].astype(x.dtype))
    h = glu_activate(h, cfg.activation)
    y = jnp.einsum("ecf,efd->ecd", h, params["wo"].astype(x.dtype))
    y = act.expert_buffer(y)

    # gather back + weighted combine over the k assignments
    gathered = y[flat_e, slot]                             # [T*k, D]
    gathered = gathered * (keep[:, None].astype(x.dtype))
    wflat = weights.reshape(-1, 1).astype(x.dtype)
    out = jax.ops.segment_sum(gathered * wflat, tok, num_segments=T)
    return out.reshape(B, S, D)


def _local_dispatch(cfg, xt, weights, idx, wi, wo, e_lo, E_loc):
    """Capacity dispatch restricted to experts [e_lo, e_lo+E_loc).

    xt [T, D]; weights/idx [T, k]; wi [E_loc, D, 2F]; wo [E_loc, F, D].
    Returns the partial combine ([T, D]) of the local experts only.
    """
    T, D = xt.shape
    k = idx.shape[1]
    C = _capacity(cfg, T)
    flat_e = idx.reshape(-1) - e_lo                        # local ids
    inside = (flat_e >= 0) & (flat_e < E_loc)
    flat_e = jnp.where(inside, flat_e, E_loc)              # sentinel bin
    order = jnp.argsort(flat_e, stable=True)
    sorted_e = flat_e[order]
    seg_start = jnp.searchsorted(sorted_e, jnp.arange(E_loc + 1))
    pos = jnp.arange(T * k, dtype=jnp.int32)
    sorted_rank = pos - seg_start[sorted_e]
    ranks = jnp.zeros((T * k,), jnp.int32).at[order].set(sorted_rank)
    keep = inside & (ranks < C)
    slot = jnp.where(keep, ranks, C)
    tok = jnp.repeat(jnp.arange(T, dtype=jnp.int32), k)

    buf = jnp.zeros((E_loc, C + 1, D), xt.dtype)
    buf = buf.at[jnp.minimum(flat_e, E_loc - 1), slot].set(
        jnp.where(keep[:, None], xt[tok], 0), mode="drop")
    h = jnp.einsum("ecd,edf->ecf", buf, wi.astype(xt.dtype))
    h = glu_activate(h, cfg.activation)
    y = jnp.einsum("ecf,efd->ecd", h, wo.astype(xt.dtype))
    gathered = y[jnp.minimum(flat_e, E_loc - 1), slot]
    gathered = gathered * keep[:, None].astype(xt.dtype)
    wflat = weights.reshape(-1, 1).astype(xt.dtype)
    return jax.ops.segment_sum(gathered * wflat, tok, num_segments=T)


def moe_ffn_ep(params, cfg, x, pol):
    """Expert-parallel dispatch under ``shard_map``.

    Exploits the Megatron-style activation layout — x is batch-sharded over
    (pod, data) and *replicated* across "model" — so no token all-to-all is
    needed at all: each model shard routes the full local token set, runs
    only its E/n_model experts, and the partial combines are summed with
    one psum over "model" (the same wire cost as a dense-FFN wo
    all-reduce). GSPMD's scatter partitioner would instead replicate the
    [E, C, D] dispatch buffers and gathered updates (observed: 190 GB/dev
    on olmoe × train_4k); this path keeps them shard-local.

    FSDP composition: when weights carry an extra "data" shard, the body
    all-gathers them before use (explicit ZeRO-3 gather, visible in HLO).
    """
    from repro.compat import shard_map
    from jax.sharding import PartitionSpec as P

    from repro.parallel.sharding import _add_fsdp, _param_rule

    mesh = pol.mesh
    E = cfg.n_experts
    E_loc = E // pol.nmdl
    L = cfg.n_layers

    def spec_for(name, arr):
        full = (L,) + arr.shape
        sp = _param_rule(f"stacks/moe/{name}", full, mesh)
        if pol.fsdp:
            sp = _add_fsdp(sp, f"stacks/moe/{name}", full, mesh)
        return P(*tuple(sp)[1:])   # drop the layer axis

    wi_spec = spec_for("wi", params["wi"])
    wo_spec = spec_for("wo", params["wo"])
    x_spec = P(pol.dp, None, None)

    def gather_fsdp(w, spec):
        for axis, ax_name in enumerate(tuple(spec)):
            if ax_name == "data":
                w = jax.lax.all_gather(w, "data", axis=axis, tiled=True)
        return w

    def body(x_loc, wi, wo, router):
        wi = gather_fsdp(wi, wi_spec)
        wo = gather_fsdp(wo, wo_spec)
        xt = x_loc.reshape(-1, x_loc.shape[-1])
        weights, idx = _route({"router": router}, cfg, xt)
        e_lo = jax.lax.axis_index("model") * E_loc
        T, D_ = xt.shape
        # token-group chunking (GShard group capacity): bounds the [T·k, D]
        # gather/scatter transients that otherwise dominate backward temps
        cs = 16384
        while cs > 1 and T % cs:
            cs //= 2
        if T > cs >= 1024:
            k = idx.shape[1]

            def disp(args):
                xt_c, w_c, i_c = args
                return _local_dispatch(cfg, xt_c, w_c, i_c, wi, wo, e_lo,
                                       E_loc)

            out = jax.lax.map(jax.checkpoint(disp),
                              (xt.reshape(-1, cs, D_),
                               weights.reshape(-1, cs, k),
                               idx.reshape(-1, cs, k))).reshape(T, D_)
        else:
            out = _local_dispatch(cfg, xt, weights, idx, wi, wo, e_lo, E_loc)
        out = jax.lax.psum(out, "model")
        return out.reshape(x_loc.shape)

    ep_call = shard_map(body, mesh=mesh,
                        in_specs=(x_spec, wi_spec, wo_spec, P()),
                        out_specs=x_spec, check_vma=False)

    # Outer sequence chunking: the shard_map boundary materializes x (and
    # its f32 cotangent) at full sequence length per data shard; mapping
    # seq chunks through it bounds those transients (observed 25 GB of
    # temps on dbrx × train_4k without this).
    B, S, D = x.shape
    cs = 1024
    while cs > 1 and S % cs:
        cs //= 2
    if S > cs >= 256:
        xc = jnp.swapaxes(x.reshape(B, S // cs, cs, D), 0, 1)

        def one(xb):
            return ep_call(xb, params["wi"], params["wo"], params["router"])

        out = jax.lax.map(jax.checkpoint(one), xc)
        return jnp.swapaxes(out, 0, 1).reshape(B, S, D)
    return ep_call(x, params["wi"], params["wo"], params["router"])


def moe_ffn(params, cfg, x, *, impl: str = "scatter"):
    if impl == "dense":
        return moe_ffn_dense(params, cfg, x)
    pol = act.policy()
    if (pol is not None and pol.nmdl > 1
            and cfg.n_experts % pol.nmdl == 0):
        return moe_ffn_ep(params, cfg, x, pol)
    return moe_ffn_scatter(params, cfg, x)
