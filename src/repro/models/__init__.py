from repro.models.registry import Model, build, cross_entropy  # noqa: F401
