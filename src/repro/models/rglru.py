"""Griffin RG-LRU recurrent block [arXiv:2402.19427] (RecurrentGemma).

Block:  y = W_out( GeLU(W_gate x) ⊙ RG-LRU( conv1d_4(W_x x) ) )
RG-LRU: r_t = σ(W_a u_t + b_a);  i_t = σ(W_i u_t + b_i)
        log a_t = -c · softplus(Λ) · r_t            (c = 8)
        h_t = a_t · h_{t-1} + sqrt(1 - a_t²) · (i_t ⊙ u_t)

W_a / W_i are block-diagonal (n_blocks = n_heads) per the paper. The sequence
pass uses ``lax.associative_scan`` over (a, b) pairs — the TPU-native form of
the recurrence; the Pallas kernel in ``repro.kernels.rglru`` provides the
blocked fused alternative.
"""
from __future__ import annotations

import math
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.models import layers

_C = 8.0
_CONV_W = 4


def _n_blocks(cfg) -> int:
    nb = max(cfg.n_heads, 1)
    w = cfg.rnn_width or cfg.d_model
    while w % nb != 0:
        nb //= 2
    return max(nb, 1)


def init_rglru_params(rng, cfg) -> dict:
    ks = jax.random.split(rng, 7)
    pd = cfg.jnp_param_dtype()
    D = cfg.d_model
    W = cfg.rnn_width or cfg.d_model
    nb = _n_blocks(cfg)
    bw = W // nb
    blk = lambda k: (jax.random.normal(k, (nb, bw, bw), jnp.float32)
                     / math.sqrt(bw)).astype(pd)
    # Λ init so that a^c ∈ (0.9, 0.999) roughly (paper's stable range)
    lam = jax.random.uniform(ks[4], (W,), jnp.float32, 0.9 ** 2, 0.999 ** 2)
    lam = jnp.log(jnp.expm1(-jnp.log(lam) / _C))  # softplus^-1(-log(a_max)/c)
    return {
        "wx": layers.dense_init(ks[0], D, W, pd),
        "w_gate": layers.dense_init(ks[1], D, W, pd),
        "conv_w": (jax.random.normal(ks[2], (_CONV_W, W), jnp.float32)
                   / math.sqrt(_CONV_W)).astype(pd),
        "conv_b": jnp.zeros((W,), pd),
        "wa": blk(ks[3]), "ba": jnp.zeros((W,), pd),
        "wi": blk(ks[5]), "bi": jnp.zeros((W,), pd),
        "lam": lam,
        "wo": layers.dense_init(ks[6], W, D, pd,
                                scale=1.0 / math.sqrt(2 * max(cfg.n_layers, 1))),
    }


def _block_diag_proj(u, w, b):
    """u: [..., W]; w: [nb, bw, bw] → [..., W]."""
    nb, bw, _ = w.shape
    ub = u.reshape(*u.shape[:-1], nb, bw)
    out = jnp.einsum("...nb,nbc->...nc", ub, w.astype(u.dtype))
    return out.reshape(*u.shape) + b.astype(u.dtype)


def _gates(params, u):
    r = jax.nn.sigmoid(_block_diag_proj(u, params["wa"], params["ba"]).astype(jnp.float32))
    i = jax.nn.sigmoid(_block_diag_proj(u, params["wi"], params["bi"]).astype(jnp.float32))
    log_a = -_C * jax.nn.softplus(params["lam"]) * r        # [..., W] f32
    a = jnp.exp(log_a)
    # sqrt(1 - a^2) computed stably via expm1
    b_scale = jnp.sqrt(-jnp.expm1(2.0 * log_a))
    return a, b_scale * (i * u.astype(jnp.float32))


def rglru_mixer(params, cfg, x, *, impl: str = "xla") -> jnp.ndarray:
    """Full-sequence Griffin block. x: [B,T,D] → [B,T,D]."""
    from repro.parallel import activation as act
    u = act.width(jnp.einsum("btd,dw->btw", x, params["wx"].astype(x.dtype)))
    g = act.width(jnp.einsum("btd,dw->btw", x,
                             params["w_gate"].astype(x.dtype)))
    # causal depthwise conv width 4
    K = params["conv_w"].shape[0]
    up = jnp.pad(u, ((0, 0), (K - 1, 0), (0, 0)))
    u = sum(up[:, i:i + u.shape[1], :] * params["conv_w"].astype(u.dtype)[i][None, None]
            for i in range(K)) + params["conv_b"].astype(u.dtype)
    a, b = _gates(params, u)                                # [B,T,W] f32
    if impl == "pallas":
        from repro.kernels import ops as kops
        h = kops.rglru(a, b)
    else:
        h = blocked_scan(a, b)
    y = (h.astype(x.dtype) * layers.gelu(g))
    return jnp.einsum("btw,wd->btd", y, params["wo"].astype(x.dtype))


def _combine(p, q):
    a1, b1 = p
    a2, b2 = q
    return a1 * a2, a2 * b1 + b2


def blocked_scan(a, b, block: int = 256):
    """h_t = a_t·h_{t-1} + b_t via lax.scan over time blocks with an
    in-block associative scan — the XLA mirror of the Pallas kernel's
    carry-stitch. O(T) residual memory (a full-sequence associative_scan
    keeps O(T·log T) tree levels alive through the backward pass, which at
    [B,32k,4096] f32 is tens of GB/device)."""
    B, T, W = a.shape
    bt = min(block, T)
    while bt > 1 and T % bt:
        bt //= 2
    if bt < 8:
        _, h = jax.lax.associative_scan(_combine, (a, b), axis=1)
        return h
    nT = T // bt
    ar = jnp.moveaxis(a.reshape(B, nT, bt, W), 1, 0)
    br = jnp.moveaxis(b.reshape(B, nT, bt, W), 1, 0)

    def step(h, ab):
        a_blk, b_blk = ab                       # [B, bt, W]
        A, Bs = jax.lax.associative_scan(_combine, (a_blk, b_blk), axis=1)
        out = Bs + A * h[:, None, :]
        return out[:, -1], out

    _, outs = jax.lax.scan(step, jnp.zeros((B, W), a.dtype), (ar, br))
    return jnp.moveaxis(outs, 0, 1).reshape(B, T, W)


def init_rglru_cache(cfg, batch: int, n_layers: int, dtype=jnp.float32) -> dict:
    W = cfg.rnn_width or cfg.d_model
    return {
        "h": jnp.zeros((n_layers, batch, W), jnp.float32),
        "conv": jnp.zeros((n_layers, batch, _CONV_W - 1, W), dtype),
    }


def rglru_decode_step(params, cfg, x, h_prev, conv_buf):
    """One token. x: [B,1,D]; h_prev: [B,W]; conv_buf: [B,3,W]."""
    u = jnp.einsum("btd,dw->btw", x, params["wx"].astype(x.dtype))
    g = jnp.einsum("btd,dw->btw", x, params["w_gate"].astype(x.dtype))
    full = jnp.concatenate([conv_buf.astype(u.dtype), u], axis=1)  # [B,4,W]
    u_t = jnp.einsum("bkw,kw->bw", full, params["conv_w"].astype(u.dtype))
    u_t = u_t + params["conv_b"].astype(u.dtype)
    conv_buf = full[:, 1:, :]
    a, b = _gates(params, u_t)                              # [B,W]
    h = a * h_prev + b
    y = (h.astype(x.dtype) * layers.gelu(g[:, 0]))[:, None, :]
    return (jnp.einsum("btw,wd->btd", y, params["wo"].astype(x.dtype)),
            h, conv_buf)
