"""Mamba-2 SSD (state-space duality) mixer [arXiv:2405.21060].

TPU adaptation: the chunked SSD algorithm is already matmul-dominant (MXU
friendly). We keep the chunk-local quadratic term as einsums and run the
inter-chunk recurrence as a ``lax.scan`` (linear in chunks) instead of the
paper listing's quadratic chunk-decay matmul. The Pallas kernel in
``repro.kernels.ssd`` fuses the chunk-local part into VMEM tiles.

Layout (n_groups=1):
  in_proj:  x [B,T,D] → z (gate, d_inner) | xc (d_inner) | B (N) | C (N) | dt (H)
  conv1d:   causal depthwise width-4 over (xc|B|C) channels
  SSD:      heads H = d_inner / P, scalar decay per head
  out:      gated RMSNorm → out_proj
"""
from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import layers


def init_ssd_params(rng, cfg) -> dict:
    ks = jax.random.split(rng, 5)
    pd = cfg.jnp_param_dtype()
    D, DI, N, H = cfg.d_model, cfg.ssm_inner, cfg.ssm_state, cfg.ssm_heads
    conv_ch = DI + 2 * N
    p = {
        "in_proj": layers.dense_init(ks[0], D, 2 * DI + 2 * N + H, pd),
        "conv_w": (jax.random.normal(ks[1], (cfg.ssm_conv_width, conv_ch), jnp.float32)
                   * (1.0 / math.sqrt(cfg.ssm_conv_width))).astype(pd),
        "conv_b": jnp.zeros((conv_ch,), pd),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, H)).astype(jnp.float32),
        "D": jnp.ones((H,), jnp.float32),
        "dt_bias": jnp.log(jnp.expm1(jnp.exp(jax.random.uniform(
            ks[2], (H,), jnp.float32,
            math.log(1e-3), math.log(1e-1))))).astype(jnp.float32),
        "norm_scale": jnp.zeros((DI,), pd),
        "out_proj": layers.dense_init(ks[3], DI, D, pd,
                                      scale=1.0 / math.sqrt(2 * max(cfg.n_layers, 1))),
    }
    return p


def _causal_conv(x, w, b):
    """Depthwise causal conv. x: [B,T,C]; w: [K,C]."""
    K = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    out = sum(xp[:, i:i + x.shape[1], :] * w[i][None, None, :] for i in range(K))
    return out + b[None, None, :]


def _split_proj(params, cfg, x):
    DI, N, H = cfg.ssm_inner, cfg.ssm_state, cfg.ssm_heads
    zxbcdt = jnp.einsum("btd,de->bte", x, params["in_proj"].astype(x.dtype))
    z, xBC, dt = jnp.split(zxbcdt, [DI, 2 * DI + 2 * N], axis=-1)
    return z, xBC, dt


def _ssd_scan(xh, log_a, Bm, Cm, chunk: int, initial_state=None):
    """Chunked SSD. xh:[B,T,H,P] (dt-folded), log_a:[B,T,H], Bm/Cm:[B,T,N].

    Returns (y [B,T,H,P], final_state [B,H,P,N]).
    """
    B, T, H, P = xh.shape
    N = Bm.shape[-1]
    Q = min(chunk, T)
    T_orig = T
    if T % Q != 0:
        # pad with (x=0, log_a=0): decay 1, zero contribution → state-neutral
        pad = Q - T % Q
        xh = jnp.pad(xh, ((0, 0), (0, pad), (0, 0), (0, 0)))
        log_a = jnp.pad(log_a, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0)))
        T = T + pad
    NC = T // Q
    csh = lambda t, tail: t.reshape(B, NC, Q, *tail)
    xh, log_a = csh(xh, (H, P)), csh(log_a, (H,))
    Bm, Cm = csh(Bm, (N,)), csh(Cm, (N,))

    la = log_a.astype(jnp.float32)
    a_cum = jnp.cumsum(la, axis=2)                         # [B,NC,Q,H]
    causal = (jnp.arange(Q)[:, None] >= jnp.arange(Q)[None, :])[None, None]
    scores = jnp.einsum("bcqn,bcsn->bcqs", Cm, Bm,
                        preferred_element_type=jnp.float32)  # [B,NC,Q,Q]

    # Intra-chunk decay L[q,s,h] = exp(a_cum[q,h]-a_cum[s,h]) (s<=q) is
    # [B,NC,Q,Q,H] — at production shapes that intermediate is GBs. Process
    # heads in groups of ≤4 under lax.map so only [B,NC,Q,Q,g] is ever live
    # (the Pallas `ssd` kernel removes the intermediate entirely on TPU).
    hg = 4
    pad_h = (-H) % hg
    a_cum_p = jnp.pad(a_cum, ((0, 0),) * 3 + ((0, pad_h),))
    xh_p = jnp.pad(xh.astype(jnp.float32),
                   ((0, 0),) * 3 + ((0, pad_h), (0, 0)))

    def diag_group(args):
        ac_g, xh_g = args                                  # [B,NC,Q,g], [B,NC,Q,g,P]
        seg = ac_g[:, :, :, None, :] - ac_g[:, :, None, :, :]
        L = jnp.exp(jnp.where(causal[..., None], seg, -jnp.inf))
        return jnp.einsum("bcqs,bcqsh,bcshp->bcqhp", scores, L, xh_g)

    n_g = (H + pad_h) // hg
    ac_g = jnp.moveaxis(a_cum_p.reshape(*a_cum_p.shape[:3], n_g, hg), 3, 0)
    xh_g = jnp.moveaxis(xh_p.reshape(*xh_p.shape[:3], n_g, hg, P), 3, 0)
    y_diag = jax.lax.map(diag_group, (ac_g, xh_g))         # [n_g,B,NC,Q,hg,P]
    y_diag = jnp.moveaxis(y_diag, 0, 3).reshape(
        B, NC, Q, n_g * hg, P)[:, :, :, :H]

    # right factors: per-chunk input→state contribution
    decay_states = jnp.exp(a_cum[:, :, -1:, :] - a_cum)     # [B,NC,Q,H]
    chunk_states = jnp.einsum("bcqn,bcqh,bcqhp->bchpn",
                              Bm.astype(jnp.float32), decay_states,
                              xh.astype(jnp.float32))       # [B,NC,H,P,N]
    chunk_decay = jnp.exp(a_cum[:, :, -1, :])               # [B,NC,H]

    init = (jnp.zeros((B, H, P, N), jnp.float32) if initial_state is None
            else initial_state.astype(jnp.float32))

    def step(state, inputs):
        c_state, c_decay = inputs                           # [B,H,P,N], [B,H]
        prev = state
        state = state * c_decay[:, :, None, None] + c_state
        return state, prev

    final_state, prev_states = jax.lax.scan(
        step, init,
        (jnp.moveaxis(chunk_states, 1, 0), jnp.moveaxis(chunk_decay, 1, 0)))
    prev_states = jnp.moveaxis(prev_states, 0, 1)           # [B,NC,H,P,N]

    state_decay = jnp.exp(a_cum)                            # [B,NC,Q,H]
    y_off = jnp.einsum("bcqn,bchpn,bcqh->bcqhp",
                       Cm.astype(jnp.float32), prev_states, state_decay)
    y = (y_diag + y_off).reshape(B, T, H, P)[:, :T_orig]
    return y, final_state


def ssd_mixer(params, cfg, x, *, impl: str = "xla") -> jnp.ndarray:
    """Full-sequence Mamba-2 mixer. x: [B,T,D] → [B,T,D]."""
    DI, N, H, P = cfg.ssm_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim
    z, xBC, dt = _split_proj(params, cfg, x)
    xBC = layers.silu(_causal_conv(xBC, params["conv_w"].astype(x.dtype),
                                   params["conv_b"].astype(x.dtype)))
    xc, Bm, Cm = jnp.split(xBC, [DI, DI + N], axis=-1)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])  # [B,T,H]
    A = -jnp.exp(params["A_log"])                                     # [H]
    log_a = dt * A                                                    # [B,T,H]
    xh = xc.reshape(*xc.shape[:2], H, P)
    xh_dt = xh.astype(jnp.float32) * dt[..., None]
    if impl == "pallas":
        from repro.kernels import ops as kops
        y, _ = kops.ssd(xh_dt, log_a, Bm.astype(jnp.float32),
                        Cm.astype(jnp.float32), cfg.ssm_chunk)
    else:
        y, _ = _ssd_scan(xh_dt, log_a, Bm.astype(jnp.float32),
                         Cm.astype(jnp.float32), cfg.ssm_chunk)
    y = y + params["D"][None, None, :, None] * xh.astype(jnp.float32)
    y = y.reshape(*x.shape[:2], DI).astype(x.dtype)
    # gated RMSNorm then out projection
    y = layers.rms_norm(y * layers.silu(z), params["norm_scale"], cfg.norm_eps)
    return jnp.einsum("btf,fd->btd", y, params["out_proj"].astype(x.dtype))


def init_ssd_cache(cfg, batch: int, n_layers: int, dtype=jnp.float32) -> dict:
    DI, N = cfg.ssm_inner, cfg.ssm_state
    return {
        "state": jnp.zeros((n_layers, batch, cfg.ssm_heads, cfg.ssm_head_dim, N),
                           jnp.float32),
        "conv": jnp.zeros((n_layers, batch, cfg.ssm_conv_width - 1, DI + 2 * N),
                          dtype),
    }


def ssd_decode_step(params, cfg, x, state, conv_buf):
    """One token. x: [B,1,D]; state: [B,H,P,N]; conv_buf: [B,K-1,C].

    Returns (y [B,1,D], state, conv_buf).
    """
    DI, N, H, P = cfg.ssm_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim
    z, xBC, dt = _split_proj(params, cfg, x)                # [B,1,*]
    full = jnp.concatenate([conv_buf, xBC.astype(conv_buf.dtype)], axis=1)  # [B,K,C]
    w = params["conv_w"].astype(x.dtype)
    conv_out = jnp.einsum("bkc,kc->bc", full.astype(x.dtype), w) + params["conv_b"].astype(x.dtype)
    xBC_t = layers.silu(conv_out)[:, None, :]               # [B,1,C]
    conv_buf = full[:, 1:, :]
    xc, Bm, Cm = jnp.split(xBC_t, [DI, DI + N], axis=-1)
    dt = jax.nn.softplus(dt[:, 0].astype(jnp.float32) + params["dt_bias"])  # [B,H]
    A = -jnp.exp(params["A_log"])
    a = jnp.exp(dt * A)                                     # [B,H]
    xh = xc[:, 0].reshape(-1, H, P).astype(jnp.float32)
    dBx = jnp.einsum("bn,bhp,bh->bhpn", Bm[:, 0].astype(jnp.float32), xh, dt)
    state = state * a[:, :, None, None] + dBx
    y = jnp.einsum("bn,bhpn->bhp", Cm[:, 0].astype(jnp.float32), state)
    y = y + params["D"][None, :, None] * xh
    y = y.reshape(-1, 1, DI).astype(x.dtype)
    y = layers.rms_norm(y * layers.silu(z), params["norm_scale"], cfg.norm_eps)
    return (jnp.einsum("btf,fd->btd", y, params["out_proj"].astype(x.dtype)),
            state, conv_buf)
