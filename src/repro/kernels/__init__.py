"""Pallas TPU kernels for RAP's data-plane hot spots.

Each kernel ships three pieces: ``<name>.py`` (``pl.pallas_call`` +
BlockSpec tiling), a jitted wrapper in ``ops.py``, and a pure-jnp oracle in
``ref.py``. Kernels cover exactly the two block families RAP prunes —
attention (KV-dominated: flash prefill + flash decode) and FFN
(parameter-dominated: fused GLU) — plus the SSM/hybrid mixers of the
assigned architectures (SSD chunk scan, RG-LRU blocked recurrence).
"""
