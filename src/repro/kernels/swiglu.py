"""Fused GLU-gate Pallas TPU kernel (SwiGLU / GeGLU).

The FFN hot spot: ``wi`` produces a fused ``[T, 2F]`` (gate|up) activation.
Materializing silu(gate) and the product separately costs three HBM
round-trips of a ``[B,S,d_ff]`` tensor; this kernel reads each element once
and writes the ``[T, F]`` product once — both halves of the fused tensor are
addressed by ``index_map`` offsets into the *same* input array, so the gate
half (block column j) and the up half (block column j + F/bf) stream
together through VMEM tiles.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.compat import tpu_compiler_params

_CompilerParams = tpu_compiler_params()      # pallas API rename (jax<=0.4.x)


def _kernel(gate_ref, up_ref, o_ref, *, activation: str):
    g = gate_ref[...].astype(jnp.float32)
    u = up_ref[...].astype(jnp.float32)
    if activation == "swiglu":
        a = g * jax.nn.sigmoid(g)
    else:  # geglu
        a = jax.nn.gelu(g, approximate=True)
    o_ref[...] = (a * u).astype(o_ref.dtype)


def fused_glu(h, activation: str = "swiglu", *, block_t: int = 256,
              block_f: int = 512, interpret: bool = False):
    """h: [..., 2F] fused (gate, up) → [..., F] (h.dtype)."""
    orig_shape = h.shape
    F = orig_shape[-1] // 2
    x = h.reshape(-1, 2 * F)
    T = x.shape[0]
    block_t = min(block_t, max(T, 8))
    block_f = min(block_f, F)
    while F % block_f != 0:          # F is 128-aligned for every real config
        block_f //= 2
    block_f = max(block_f, 1)
    pad_t = (-T) % block_t
    if pad_t:
        x = jnp.pad(x, ((0, pad_t), (0, 0)))
    nt, nf = x.shape[0] // block_t, F // block_f
    off = F // block_f               # up half starts nf block-columns later

    out = pl.pallas_call(
        functools.partial(_kernel, activation=activation),
        grid=(nt, nf),
        in_specs=[
            pl.BlockSpec((block_t, block_f), lambda i, j: (i, j)),
            pl.BlockSpec((block_t, block_f),
                         lambda i, j, off=off: (i, j + off)),
        ],
        out_specs=pl.BlockSpec((block_t, block_f), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((x.shape[0], F), h.dtype),
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel")),
        interpret=interpret,
        name="rap_fused_glu",
    )(x, x)
    if pad_t:
        out = out[:T]
    return out.reshape(*orig_shape[:-1], F)
