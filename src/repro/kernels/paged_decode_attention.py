"""Paged flash-decode Pallas TPU kernel: one query token vs a paged KV pool.

The dense decode kernel (``decode_attention.py``) streams a *contiguous*
``[B, S, K, D]`` cache — which forces the serving engine to materialize
``max_len × max_active`` slot caches and eat their internal fragmentation.
This kernel's KV operands are instead a **global page pool**
``[n_pages, page_tokens, K, D]`` shared by every in-flight request, plus an
int32 per-request **page table** ``[B, max_pages]``: request ``b``'s tokens
``[ip·page_tokens, (ip+1)·page_tokens)`` live in physical page
``page_table[b, ip]`` (vLLM-block style, one level of indirection).

Grid ``(B, K_kv, max_pages)`` with the page dimension innermost
(sequential). The page table and per-request lengths ride
``PrefetchScalarGridSpec`` scalar prefetch, so the K/V BlockSpec *index
maps* chase the table — ``(page_table[b, ip], 0, g, 0)`` — and the pages
DMA straight from wherever they physically sit; no gather materializes a
contiguous cache. The (m, l, acc) online-softmax scratch carry is identical
to the dense kernel's split-KV reduction, so with
``page_tokens == block_k`` and an in-order page table the two kernels
execute the *same* f32 op sequence and agree **bitwise** (pinned in
``tests/test_kernels.py``).

Rows needing fewer than ``max_pages`` pages pad their table row with any
valid page id (0 by convention); the ``kpos < length[b]`` mask turns those
blocks into exact no-ops (``acc·1 + 0``) without branching.

On CPU/tests the kernel runs in ``interpret=True`` mode (the
``pallas-interpret`` CI job); the XLA fallback for production CPU serving
lives in ``repro.models.attention.paged_decode_attention``.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.compat import tpu_compiler_params

_CompilerParams = tpu_compiler_params()      # pallas API rename (jax<=0.4.x)

NEG_INF = -2.0e38
_LANES = 128


def _flash_step(b, ip, n_ip, q, k, v, len_ref, o_ref, m_sc, l_sc, acc_sc,
                *, scale: float, softcap: float, page_tokens: int):
    """One page's online-softmax update — shared verbatim by the plain and
    quantized kernels so dequantization cannot perturb the (m, l, acc)
    op sequence the bitwise conformance pins."""
    @pl.when(ip == 0)
    def _init():
        m_sc[...] = jnp.full_like(m_sc, NEG_INF)
        l_sc[...] = jnp.zeros_like(l_sc)
        acc_sc[...] = jnp.zeros_like(acc_sc)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    if softcap > 0.0:
        s = softcap * jnp.tanh(s / softcap)

    kpos = ip * page_tokens + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    mask = kpos < len_ref[b]
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_sc[:, :1]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
    p = jnp.where(mask, jnp.exp(s - m_new), 0.0)
    alpha = jnp.exp(m_prev - m_new)
    l_sc[...] = jnp.broadcast_to(
        alpha * l_sc[:, :1] + jnp.sum(p, axis=1, keepdims=True), l_sc.shape)
    acc_sc[...] = acc_sc[...] * alpha + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_sc[...] = jnp.broadcast_to(m_new, m_sc.shape)

    @pl.when(ip == n_ip - 1)
    def _finalize():
        l = jnp.maximum(l_sc[:, :1], 1e-30)
        o_ref[0, 0] = (acc_sc[...] / l).astype(o_ref.dtype)


def _kernel(pt_ref, len_ref, q_ref, k_ref, v_ref, o_ref, m_sc, l_sc, acc_sc,
            *, scale: float, softcap: float, page_tokens: int):
    b = pl.program_id(0)
    ip = pl.program_id(2)
    n_ip = pl.num_programs(2)
    q = q_ref[0, 0].astype(jnp.float32)              # [G, D]
    k = k_ref[0, :, 0].astype(jnp.float32)           # [page_tokens, D]
    v = v_ref[0, :, 0].astype(jnp.float32)
    _flash_step(b, ip, n_ip, q, k, v, len_ref, o_ref, m_sc, l_sc, acc_sc,
                scale=scale, softcap=softcap, page_tokens=page_tokens)


def _kernel_quant(pt_ref, len_ref, ks_ref, vs_ref, q_ref, k_ref, v_ref,
                  o_ref, m_sc, l_sc, acc_sc, *, scale: float, softcap: float,
                  page_tokens: int):
    """Fused-dequant variant: pages arrive int8/fp8; per-(page, kv-head)
    scales ride the scalar-prefetch path next to the page table, so the
    dequant is one scalar multiply per tile — ``q.astype(f32) * scale`` —
    exactly mirroring ``models.attention.page_dequant``. The (m, l, acc)
    scratch stays fp32 via the shared ``_flash_step``."""
    b = pl.program_id(0)
    g = pl.program_id(1)
    ip = pl.program_id(2)
    n_ip = pl.num_programs(2)
    page = pt_ref[b, ip]
    q = q_ref[0, 0].astype(jnp.float32)              # [G, D]
    k = k_ref[0, :, 0].astype(jnp.float32) * ks_ref[page, g]
    v = v_ref[0, :, 0].astype(jnp.float32) * vs_ref[page, g]
    _flash_step(b, ip, n_ip, q, k, v, len_ref, o_ref, m_sc, l_sc, acc_sc,
                scale=scale, softcap=softcap, page_tokens=page_tokens)


def paged_decode_attention(q, k_pages, v_pages, page_table, lengths, *,
                           k_scales=None, v_scales=None,
                           softcap: float = 0.0, interpret: bool = False):
    """q: [B,1,H,D]; k_pages/v_pages: [n_pages, page_tokens, K, D];
    page_table: int32 [B, max_pages]; lengths: int32 [B]. → [B,1,H,D].

    Row ``b`` attends its first ``lengths[b]`` tokens, token ``t`` living at
    ``(page_table[b, t // page_tokens], t % page_tokens)``. Unused table
    entries must still be valid page ids (they are fetched, then masked).

    ``k_scales``/``v_scales`` (f32 ``[n_pages, K]``, both or neither)
    switch on the fused-dequant path for int8/fp8 page pools: scales are
    scalar-prefetched alongside the page table and each K/V tile is
    multiplied by its page's per-head scale before the fp32 online softmax.
    """
    B, _, H, D = q.shape
    page_tokens, K = k_pages.shape[1], k_pages.shape[2]
    max_pages = page_table.shape[1]
    assert H % K == 0
    G = H // K
    quantized = k_scales is not None
    assert quantized == (v_scales is not None), \
        "k_scales and v_scales must be given together"

    qg = q[:, 0].reshape(B, K, G, D)                 # grouped query heads
    page_table = jnp.asarray(page_table, jnp.int32)
    lengths = jnp.asarray(lengths, jnp.int32)

    # scalar-prefetch operands lead the positional args; BlockSpec index
    # maps receive them after the grid ids, so the two layouts need their
    # own lambdas (the quantized maps take the two trailing scale refs)
    if quantized:
        kernel = functools.partial(_kernel_quant, scale=1.0 / math.sqrt(D),
                                   softcap=softcap, page_tokens=page_tokens)
        num_prefetch = 4                 # page_table, lengths, ks, vs
        q_map = lambda b, g, ip, tab, ln, ks, vs: (b, g, 0, 0)
        kv_map = lambda b, g, ip, tab, ln, ks, vs: (tab[b, ip], 0, g, 0)
        prefetch = (page_table, lengths, jnp.asarray(k_scales, jnp.float32),
                    jnp.asarray(v_scales, jnp.float32))
    else:
        kernel = functools.partial(_kernel, scale=1.0 / math.sqrt(D),
                                   softcap=softcap, page_tokens=page_tokens)
        num_prefetch = 2                 # page_table, lengths
        q_map = lambda b, g, ip, tab, ln: (b, g, 0, 0)
        kv_map = lambda b, g, ip, tab, ln: (tab[b, ip], 0, g, 0)
        prefetch = (page_table, lengths)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=num_prefetch,
        grid=(B, K, max_pages),
        in_specs=[
            pl.BlockSpec((1, 1, G, D), q_map),
            pl.BlockSpec((1, page_tokens, 1, D), kv_map),
            pl.BlockSpec((1, page_tokens, 1, D), kv_map),
        ],
        out_specs=pl.BlockSpec((1, 1, G, D), q_map),
        scratch_shapes=[
            pltpu.VMEM((G, _LANES), jnp.float32),
            pltpu.VMEM((G, _LANES), jnp.float32),
            pltpu.VMEM((G, D), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, K, G, D), q.dtype),
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
        name=("rap_paged_decode_attention_quant" if quantized
              else "rap_paged_decode_attention"),
    )(*prefetch, qg, k_pages, v_pages)
    return out.reshape(B, 1, H, D)
