"""Flash attention (prefill/train) Pallas TPU kernel.

Streaming-softmax tiling: grid ``(B, H, num_q_blocks, num_kv_blocks)`` with
the KV dimension innermost — TPU grids execute the last dimension
sequentially, so the (m, l, acc) accumulators live in VMEM scratch and carry
across KV steps. Block sizes default to 128×128 (MXU-aligned); the working
set per grid cell is

    q (bq·D) + k,v (2·bk·D) + acc (bq·D f32) + s/p (bq·bk f32)  ≈ 0.4 MB

well inside a v5e core's VMEM. GQA is handled in the k/v ``index_map``
(query head h reads kv head ``h // G``) so no KV replication is ever
materialized. Causal masking is iota-based inside the block; fully-masked
blocks above the diagonal skip their matmuls via ``pl.when``.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.compat import tpu_compiler_params

_CompilerParams = tpu_compiler_params()      # pallas API rename (jax<=0.4.x)

NEG_INF = -2.0e38
_LANES = 128


def _kernel(q_ref, k_ref, v_ref, o_ref, m_sc, l_sc, acc_sc, *,
            scale: float, causal: bool, window: int, softcap: float,
            sq: int, skv: int, block_q: int, block_k: int):
    iq = pl.program_id(2)
    ik = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(ik == 0)
    def _init():
        m_sc[...] = jnp.full_like(m_sc, NEG_INF)
        l_sc[...] = jnp.zeros_like(l_sc)
        acc_sc[...] = jnp.zeros_like(acc_sc)

    q_start = iq * block_q
    k_start = ik * block_k

    # Skip blocks strictly above the causal diagonal (or left of the band).
    run = jnp.asarray(True)
    if causal:
        run = run & (k_start <= q_start + block_q - 1)
    if window > 0:
        run = run & (k_start + block_k - 1 > q_start - window)

    @pl.when(run)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)          # [bq, D]
        k = k_ref[0, 0].astype(jnp.float32)          # [bk, D]
        v = v_ref[0, 0].astype(jnp.float32)          # [bk, D]
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        if softcap > 0.0:
            s = softcap * jnp.tanh(s / softcap)

        qpos = q_start + jax.lax.broadcasted_iota(jnp.int32,
                                                  (block_q, block_k), 0)
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32,
                                                  (block_q, block_k), 1)
        mask = kpos < skv                            # KV padding
        mask = mask & (qpos < sq)                    # Q padding
        if causal:
            mask = mask & (kpos <= qpos)
        if window > 0:
            mask = mask & (kpos > qpos - window)
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_sc[:, :1]                         # [bq, 1]
        m_cur = jnp.max(s, axis=1, keepdims=True)    # [bq, 1]
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new)                       # [bq, bk]
        p = jnp.where(mask, p, 0.0)
        alpha = jnp.exp(m_prev - m_new)              # [bq, 1]
        l_new = alpha * l_sc[:, :1] + jnp.sum(p, axis=1, keepdims=True)
        acc_sc[...] = acc_sc[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
        m_sc[...] = jnp.broadcast_to(m_new, m_sc.shape)
        l_sc[...] = jnp.broadcast_to(l_new, l_sc.shape)

    @pl.when(ik == nk - 1)
    def _finalize():
        l = l_sc[:, :1]
        o_ref[0, 0] = (acc_sc[...] / jnp.maximum(l, 1e-30)).astype(o_ref.dtype)


def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    softcap: float = 0.0, block_q: int = 128,
                    block_k: int = 128, interpret: bool = False):
    """q: [B,Sq,H,D]; k,v: [B,Skv,K,D]. Returns [B,Sq,H,D] (q.dtype)."""
    B, Sq, H, D = q.shape
    Skv, K = k.shape[1], k.shape[2]
    assert H % K == 0, (H, K)
    G = H // K
    block_q = min(block_q, max(8, Sq))
    block_k = min(block_k, max(_LANES, 8))

    qt = jnp.swapaxes(q, 1, 2)                       # [B,H,Sq,D]
    kt = jnp.swapaxes(k, 1, 2)                       # [B,K,Skv,D]
    vt = jnp.swapaxes(v, 1, 2)

    pad_q = (-Sq) % block_q
    pad_k = (-Skv) % block_k
    if pad_q:
        qt = jnp.pad(qt, ((0, 0), (0, 0), (0, pad_q), (0, 0)))
    if pad_k:
        kt = jnp.pad(kt, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
        vt = jnp.pad(vt, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
    nq = qt.shape[2] // block_q
    nk = kt.shape[2] // block_k

    kernel = functools.partial(
        _kernel, scale=1.0 / math.sqrt(D), causal=causal, window=window,
        softcap=softcap, sq=Sq, skv=Skv, block_q=block_q, block_k=block_k)

    out = pl.pallas_call(
        kernel,
        grid=(B, H, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, block_q, D), lambda b, h, iq, ik: (b, h, iq, 0)),
            pl.BlockSpec((1, 1, block_k, D),
                         lambda b, h, iq, ik, G=G: (b, h // G, ik, 0)),
            pl.BlockSpec((1, 1, block_k, D),
                         lambda b, h, iq, ik, G=G: (b, h // G, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, D),
                               lambda b, h, iq, ik: (b, h, iq, 0)),
        out_shape=jax.ShapeDtypeStruct(qt.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, _LANES), jnp.float32),
            pltpu.VMEM((block_q, _LANES), jnp.float32),
            pltpu.VMEM((block_q, D), jnp.float32),
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
        interpret=interpret,
        name="rap_flash_attention",
    )(qt, kt, vt)
    out = out[:, :, :Sq, :] if pad_q else out
    return jnp.swapaxes(out, 1, 2)
