"""Mamba-2 SSD chunk-scan Pallas TPU kernel.

State-space duality splits the sequence into chunks of Q tokens: inside a
chunk the recurrence is the quadratic masked form (three MXU matmuls —
C·Bᵀ, the decay-weighted combine, and the input→state projection); across
chunks a rank-preserving [P,N] state carries. Grid ``(B, H, num_chunks)``
with chunks innermost: the state lives in VMEM scratch across the
sequential chunk walk, so HBM sees each token exactly once (the GPU
implementation's shared-memory chunk buffer maps onto the VMEM-resident
block; the warp-level parallel scan maps onto the sequential-grid carry,
which is the TPU-native form of the same dataflow).

VMEM per cell at (Q=256, N=128, P=64): xh 64K + B/C 2·128K + L 256K +
state 32K  ≈ 0.6 MB f32.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.compat import tpu_compiler_params

_CompilerParams = tpu_compiler_params()      # pallas API rename (jax<=0.4.x)


def _kernel(xh_ref, la_ref, b_ref, c_ref, y_ref, fin_ref, st_ref, *,
            block_q: int):
    c_idx = pl.program_id(2)
    nc = pl.num_programs(2)

    @pl.when(c_idx == 0)
    def _init():
        st_ref[...] = jnp.zeros_like(st_ref)

    xh = xh_ref[0, 0].astype(jnp.float32)            # [Q, P]
    la = la_ref[0, 0].astype(jnp.float32)            # [1, Q]
    Bm = b_ref[0].astype(jnp.float32)                # [Q, N]
    Cm = c_ref[0].astype(jnp.float32)                # [Q, N]

    a_cum = jnp.cumsum(la[0])                        # [Q]
    # intra-chunk decay L[q,s] = exp(a_cum[q]-a_cum[s]) for s<=q
    seg = a_cum[:, None] - a_cum[None, :]
    causal = (jax.lax.broadcasted_iota(jnp.int32, (block_q, block_q), 0)
              >= jax.lax.broadcasted_iota(jnp.int32, (block_q, block_q), 1))
    L = jnp.where(causal, jnp.exp(seg), 0.0)

    scores = jax.lax.dot_general(Cm, Bm, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)  # [Q,Q]
    y_diag = jax.lax.dot_general(scores * L, xh, (((1,), (0,)), ((), ())),
                                 preferred_element_type=jnp.float32)  # [Q,P]

    # off-diagonal: contribution of the carried state
    state = st_ref[...]                              # [P, N]
    y_off = jax.lax.dot_general(Cm, state, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)   # [Q,P]
    y_off = y_off * jnp.exp(a_cum)[:, None]
    y_ref[0, 0] = (y_diag + y_off).astype(y_ref.dtype)

    # state update: decay full chunk + input→state projection
    total = a_cum[block_q - 1]
    decay_in = jnp.exp(total - a_cum)                # [Q]
    bx = jax.lax.dot_general(xh * decay_in[:, None], Bm,
                             (((0,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)      # [P,N]
    st_ref[...] = state * jnp.exp(total) + bx

    @pl.when(c_idx == nc - 1)
    def _emit_final():
        fin_ref[0, 0] = st_ref[...]


def ssd(xh, log_a, Bm, Cm, chunk: int = 256, *, interpret: bool = False):
    """Chunked SSD. xh: [B,T,H,P]; log_a: [B,T,H]; Bm/Cm: [B,T,N].

    Returns (y [B,T,H,P] f32, final_state [B,H,P,N] f32) — matches
    ``repro.models.ssm._ssd_scan`` and the ``ssd_ref`` oracle.
    """
    B, T, H, P = xh.shape
    N = Bm.shape[-1]
    Q = min(chunk, T)
    pad = (-T) % Q
    if pad:  # decay-1 / zero-input padding is state-neutral
        xh = jnp.pad(xh, ((0, 0), (0, pad), (0, 0), (0, 0)))
        log_a = jnp.pad(log_a, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0)))
    Tp = T + pad
    nc = Tp // Q

    xh_t = jnp.transpose(xh, (0, 2, 1, 3))           # [B,H,T,P]
    la_t = jnp.transpose(log_a, (0, 2, 1))[:, :, None, :]  # [B,H,1,T]

    y, fin = pl.pallas_call(
        functools.partial(_kernel, block_q=Q),
        grid=(B, H, nc),
        in_specs=[
            pl.BlockSpec((1, 1, Q, P), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((1, 1, 1, Q), lambda b, h, c: (b, h, 0, c)),
            pl.BlockSpec((1, Q, N), lambda b, h, c: (b, c, 0)),
            pl.BlockSpec((1, Q, N), lambda b, h, c: (b, c, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, Q, P), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((1, 1, P, N), lambda b, h, c: (b, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, H, Tp, P), jnp.float32),
            jax.ShapeDtypeStruct((B, H, P, N), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((P, N), jnp.float32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
        name="rap_ssd",
    )(xh_t, la_t, Bm, Cm)
    y = jnp.transpose(y, (0, 2, 1, 3))[:, :T]
    return y, fin
