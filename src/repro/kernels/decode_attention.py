"""Flash-decode Pallas TPU kernel: one query token vs a long KV cache.

The decode hot spot (``decode_32k`` / ``long_500k`` shapes) is memory-bound:
arithmetic intensity ≈ 1 FLOP/byte, so the kernel's job is to stream KV from
HBM exactly once at full bandwidth. Layout choice: queries are grouped
``[B, K_kv, G, D]`` (G = H/K query heads per kv head) so one streamed KV
block serves all G query rows — the GQA group rides the MXU's sublane
dimension instead of replicating KV reads G times.

Grid ``(B, K_kv, num_kv_blocks)`` with the KV dimension innermost
(sequential); (m, l, acc) accumulators carry in VMEM scratch — the split-KV
reduction of flash-decode expressed as a sequential grid walk. A ``valid``
f32 vector masks ring-buffer slots / unwritten cache tail.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.compat import tpu_compiler_params

_CompilerParams = tpu_compiler_params()      # pallas API rename (jax<=0.4.x)

NEG_INF = -2.0e38
_LANES = 128


def _kernel(q_ref, k_ref, v_ref, valid_ref, o_ref, m_sc, l_sc, acc_sc, *,
            scale: float, softcap: float, skv: int, block_k: int):
    ik = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ik == 0)
    def _init():
        m_sc[...] = jnp.full_like(m_sc, NEG_INF)
        l_sc[...] = jnp.zeros_like(l_sc)
        acc_sc[...] = jnp.zeros_like(acc_sc)

    q = q_ref[0, 0].astype(jnp.float32)              # [G, D]
    k = k_ref[0, 0].astype(jnp.float32)              # [bk, D]
    v = v_ref[0, 0].astype(jnp.float32)              # [bk, D]
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    if softcap > 0.0:
        s = softcap * jnp.tanh(s / softcap)

    kpos = ik * block_k + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    mask = (valid_ref[0] > 0.5)[None, :] & (kpos < skv)
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_sc[:, :1]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
    p = jnp.where(mask, jnp.exp(s - m_new), 0.0)
    alpha = jnp.exp(m_prev - m_new)
    l_sc[...] = jnp.broadcast_to(
        alpha * l_sc[:, :1] + jnp.sum(p, axis=1, keepdims=True), l_sc.shape)
    acc_sc[...] = acc_sc[...] * alpha + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_sc[...] = jnp.broadcast_to(m_new, m_sc.shape)

    @pl.when(ik == nk - 1)
    def _finalize():
        l = jnp.maximum(l_sc[:, :1], 1e-30)
        o_ref[0, 0] = (acc_sc[...] / l).astype(o_ref.dtype)


def decode_attention(q, k, v, valid, *, softcap: float = 0.0,
                     block_k: int = 512, interpret: bool = False):
    """q: [B,1,H,D]; k,v: [B,S,K,D]; valid: [S] (bool/num). → [B,1,H,D]."""
    B, _, H, D = q.shape
    S, K = k.shape[1], k.shape[2]
    assert H % K == 0
    G = H // K
    block_k = min(block_k, max(_LANES, 8))

    qg = q[:, 0].reshape(B, K, G, D)                 # grouped query heads
    kt = jnp.swapaxes(k, 1, 2)                       # [B,K,S,D]
    vt = jnp.swapaxes(v, 1, 2)
    vf = valid.astype(jnp.float32)[None, :]          # [1,S]

    pad = (-S) % block_k
    if pad:
        kt = jnp.pad(kt, ((0, 0), (0, 0), (0, pad), (0, 0)))
        vt = jnp.pad(vt, ((0, 0), (0, 0), (0, pad), (0, 0)))
        vf = jnp.pad(vf, ((0, 0), (0, pad)))
    nk = kt.shape[2] // block_k

    kernel = functools.partial(_kernel, scale=1.0 / math.sqrt(D),
                               softcap=softcap, skv=S, block_k=block_k)

    out = pl.pallas_call(
        kernel,
        grid=(B, K, nk),
        in_specs=[
            pl.BlockSpec((1, 1, G, D), lambda b, g, ik: (b, g, 0, 0)),
            pl.BlockSpec((1, 1, block_k, D), lambda b, g, ik: (b, g, ik, 0)),
            pl.BlockSpec((1, 1, block_k, D), lambda b, g, ik: (b, g, ik, 0)),
            pl.BlockSpec((1, block_k), lambda b, g, ik: (0, ik)),
        ],
        out_specs=pl.BlockSpec((1, 1, G, D), lambda b, g, ik: (b, g, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, K, G, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((G, _LANES), jnp.float32),
            pltpu.VMEM((G, _LANES), jnp.float32),
            pltpu.VMEM((G, D), jnp.float32),
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
        name="rap_decode_attention",
    )(qg, kt, vt, vf)
    return out.reshape(B, 1, H, D)
