"""Pure-jnp oracles for every Pallas kernel.

Each function is an *independent*, simple implementation of the kernel's
contract (naive masked softmax, naive sequential recurrences). Tests sweep
shapes/dtypes and ``assert_allclose`` kernel-vs-oracle.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -2.0e38


def _maybe_softcap(x, cap: float):
    return cap * jnp.tanh(x / cap) if cap > 0.0 else x


def attention_ref(q, k, v, *, causal: bool = True, window: int = 0,
                  softcap: float = 0.0):
    """q: [B,Sq,H,D]; k,v: [B,Skv,K,D] (H % K == 0). Returns [B,Sq,H,D]."""
    B, Sq, H, D = q.shape
    K = k.shape[2]
    G = H // K
    kr = jnp.repeat(k, G, axis=2)
    vr = jnp.repeat(v, G, axis=2)
    s = jnp.einsum("bqhd,bkhd->bhqk", q, kr,
                   preferred_element_type=jnp.float32) / jnp.sqrt(float(D))
    s = _maybe_softcap(s, softcap)
    qpos = jnp.arange(Sq)[:, None]
    kpos = jnp.arange(k.shape[1])[None, :]
    mask = jnp.ones((Sq, k.shape[1]), bool)
    if causal:
        mask = mask & (kpos <= qpos)
    if window > 0:
        mask = mask & (kpos > qpos - window)
    s = jnp.where(mask[None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", p.astype(vr.dtype), vr)
    return out.astype(q.dtype)


def decode_attention_ref(q, k, v, valid, *, softcap: float = 0.0):
    """q: [B,1,H,D]; k,v: [B,S,K,D]; valid: [S] bool. Returns [B,1,H,D]."""
    B, _, H, D = q.shape
    K = k.shape[2]
    G = H // K
    kr = jnp.repeat(k, G, axis=2)
    vr = jnp.repeat(v, G, axis=2)
    s = jnp.einsum("bqhd,bkhd->bhqk", q, kr,
                   preferred_element_type=jnp.float32) / jnp.sqrt(float(D))
    s = _maybe_softcap(s, softcap)
    s = jnp.where(valid[None, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", p.astype(vr.dtype), vr)
    return out.astype(q.dtype)


def glu_ref(h, activation: str = "swiglu"):
    """h: [..., 2F] fused (gate, up) → [..., F]."""
    gate, up = jnp.split(h, 2, axis=-1)
    act = jax.nn.silu(gate) if activation == "swiglu" else \
        jax.nn.gelu(gate, approximate=True)
    return (act * up).astype(h.dtype)


def ssd_ref(xh, log_a, Bm, Cm):
    """Naive sequential SSD recurrence (exact linear form).

    xh: [B,T,H,P] (dt already folded in); log_a: [B,T,H]; Bm/Cm: [B,T,N].
    state_t = exp(log_a_t)·state_{t-1} + xh_t ⊗ B_t;  y_t = state_t · C_t.
    Returns (y [B,T,H,P] f32, final_state [B,H,P,N] f32).
    """
    B, T, H, P = xh.shape
    N = Bm.shape[-1]
    xh = xh.astype(jnp.float32)
    log_a = log_a.astype(jnp.float32)
    Bm = Bm.astype(jnp.float32)
    Cm = Cm.astype(jnp.float32)

    def step(state, inputs):
        x_t, la_t, b_t, c_t = inputs      # [B,H,P], [B,H], [B,N], [B,N]
        a = jnp.exp(la_t)[:, :, None, None]
        state = state * a + x_t[..., None] * b_t[:, None, None, :]
        y = jnp.einsum("bhpn,bn->bhp", state, c_t)
        return state, y

    init = jnp.zeros((B, H, P, N), jnp.float32)
    final, ys = jax.lax.scan(
        step, init,
        (jnp.moveaxis(xh, 1, 0), jnp.moveaxis(log_a, 1, 0),
         jnp.moveaxis(Bm, 1, 0), jnp.moveaxis(Cm, 1, 0)))
    return jnp.moveaxis(ys, 0, 1), final


def rglru_ref(a, b):
    """Naive linear recurrence h_t = a_t·h_{t-1} + b_t, h_0 = b_0 (zero init).

    a, b: [B,T,W] f32. Returns h [B,T,W] f32.
    """
    def step(h, inputs):
        a_t, b_t = inputs
        h = a_t * h + b_t
        return h, h

    init = jnp.zeros_like(a[:, 0])
    _, hs = jax.lax.scan(step, init, (jnp.moveaxis(a, 1, 0),
                                      jnp.moveaxis(b, 1, 0)))
    return jnp.moveaxis(hs, 0, 1)
