"""Jitted public wrappers over the Pallas kernels.

On TPU the kernels compile natively; everywhere else (this CPU container)
they execute in ``interpret=True`` mode, which runs the kernel body in
Python on CPU — bit-accurate validation of the same tiling/control flow
the TPU lowers. ``impl='pallas'`` paths throughout ``repro.models`` land
here.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import decode_attention as _dec
from repro.kernels import flash_attention as _fa
from repro.kernels import paged_decode_attention as _pdec
from repro.kernels import rglru as _rg
from repro.kernels import ssd as _ssd
from repro.kernels import swiglu as _glu


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=("causal", "window", "softcap",
                                             "block_q", "block_k"))
def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    softcap: float = 0.0, block_q: int = 128,
                    block_k: int = 128):
    return _fa.flash_attention(q, k, v, causal=causal, window=window,
                               softcap=softcap, block_q=block_q,
                               block_k=block_k, interpret=_interpret())


@functools.partial(jax.jit, static_argnames=("softcap", "block_k"))
def decode_attention(q, k, v, valid, *, softcap: float = 0.0,
                     block_k: int = 512):
    return _dec.decode_attention(q, k, v, valid, softcap=softcap,
                                 block_k=block_k, interpret=_interpret())


@functools.partial(jax.jit, static_argnames=("softcap",))
def paged_decode_attention(q, k_pages, v_pages, page_table, lengths, *,
                           k_scales=None, v_scales=None,
                           softcap: float = 0.0):
    return _pdec.paged_decode_attention(q, k_pages, v_pages, page_table,
                                        lengths, k_scales=k_scales,
                                        v_scales=v_scales, softcap=softcap,
                                        interpret=_interpret())


@functools.partial(jax.jit, static_argnames=("activation", "block_t",
                                             "block_f"))
def fused_glu(h, activation: str = "swiglu", *, block_t: int = 256,
              block_f: int = 512):
    return _glu.fused_glu(h, activation, block_t=block_t, block_f=block_f,
                          interpret=_interpret())


@functools.partial(jax.jit, static_argnames=("chunk",))
def ssd(xh, log_a, Bm, Cm, chunk: int = 256):
    return _ssd.ssd(xh, log_a, Bm, Cm, chunk, interpret=_interpret())


@functools.partial(jax.jit, static_argnames=("block_t", "block_w"))
def rglru(a, b, *, block_t: int = 256, block_w: int = 512):
    return _rg.rglru(a, b, block_t=block_t, block_w=block_w,
                     interpret=_interpret())
