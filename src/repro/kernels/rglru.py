"""RG-LRU blocked linear-recurrence Pallas TPU kernel (Griffin).

h_t = a_t ⊙ h_{t-1} + b_t over T, diagonal per channel. The recurrence is
bandwidth-bound; the kernel tiles the channel axis (width blocks ride the
VPU lanes) and walks the time axis in blocks of ``block_t``: inside a block
an associative scan does log₂(block_t) vectorized passes in VMEM, and the
carried hidden state h stitches consecutive blocks:

    h_t = Bscan_t + Ascan_t · h_carry      (Ascan = running ∏a, Bscan = scan of b)

Grid ``(B, W/bw, T/bt)`` with time innermost (sequential) so the [1, bw]
carry lives in scratch.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.compat import tpu_compiler_params

_CompilerParams = tpu_compiler_params()      # pallas API rename (jax<=0.4.x)


def _kernel(a_ref, b_ref, h_ref, carry_ref):
    it = pl.program_id(2)

    @pl.when(it == 0)
    def _init():
        carry_ref[...] = jnp.zeros_like(carry_ref)

    a = a_ref[0].astype(jnp.float32)                 # [bt, bw]
    b = b_ref[0].astype(jnp.float32)

    def combine(p, q):
        a1, b1 = p
        a2, b2 = q
        return a1 * a2, a2 * b1 + b2

    A, Bs = jax.lax.associative_scan(combine, (a, b), axis=0)
    h = Bs + A * carry_ref[...]                      # [bt, bw]
    h_ref[0] = h.astype(h_ref.dtype)
    carry_ref[...] = h[-1:, :]


def rglru(a, b, *, block_t: int = 256, block_w: int = 512,
          interpret: bool = False):
    """a, b: [B,T,W] f32 → h [B,T,W] f32 (matches ``rglru_ref``)."""
    B, T, W = a.shape
    block_t = min(block_t, T)
    block_w = min(block_w, W)
    while W % block_w != 0:
        block_w //= 2
    block_w = max(block_w, 1)
    pad_t = (-T) % block_t
    if pad_t:  # a=1,b=0 padding is state-neutral; padded rows sliced off
        a = jnp.pad(a, ((0, 0), (0, pad_t), (0, 0)), constant_values=1.0)
        b = jnp.pad(b, ((0, 0), (0, pad_t), (0, 0)))
    nt = a.shape[1] // block_t
    nw = W // block_w

    h = pl.pallas_call(
        _kernel,
        grid=(B, nw, nt),
        in_specs=[
            pl.BlockSpec((1, block_t, block_w), lambda bb, iw, it: (bb, it, iw)),
            pl.BlockSpec((1, block_t, block_w), lambda bb, iw, it: (bb, it, iw)),
        ],
        out_specs=pl.BlockSpec((1, block_t, block_w),
                               lambda bb, iw, it: (bb, it, iw)),
        out_shape=jax.ShapeDtypeStruct(a.shape, jnp.float32),
        scratch_shapes=[pltpu.VMEM((1, block_w), jnp.float32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
        name="rap_rglru",
    )(a, b)
    return h[:, :T] if pad_t else h
