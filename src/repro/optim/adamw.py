"""AdamW + global-norm clipping + schedules, as pure pytree transforms.

(optax is not available in this environment; this is the minimal production
subset: bias-corrected Adam moments, decoupled weight decay, cosine/linear
warmup schedules, global-norm clipping, all jit/pjit-friendly.)
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jnp.ndarray
    mu: Any
    nu: Any


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 1000
    schedule: str = "cosine"  # cosine|linear|constant
    min_lr_ratio: float = 0.1


def schedule_lr(cfg: AdamWConfig, step):
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    if cfg.schedule == "constant":
        decay = 1.0
    else:
        t = jnp.clip((step - cfg.warmup_steps)
                     / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
        if cfg.schedule == "cosine":
            decay = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * 0.5 * (1 + jnp.cos(jnp.pi * t))
        else:
            decay = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * (1 - t)
    return cfg.lr * warm * decay


def init(params) -> AdamWState:
    zeros = jax.tree.map(lambda p: jnp.zeros_like(p, dtype=jnp.float32), params)
    return AdamWState(step=jnp.zeros((), jnp.int32), mu=zeros,
                      nu=jax.tree.map(jnp.copy, zeros))


def global_norm(tree) -> jnp.ndarray:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


def apply(cfg: AdamWConfig, params, grads, state: AdamWState):
    """Returns (new_params, new_state, metrics)."""
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / (gnorm + 1e-9)) if cfg.clip_norm > 0 else 1.0
    step = state.step + 1
    lr = schedule_lr(cfg, step)
    b1c = 1 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, mu, nu):
        g = g.astype(jnp.float32) * scale
        mu = cfg.b1 * mu + (1 - cfg.b1) * g
        nu = cfg.b2 * nu + (1 - cfg.b2) * jnp.square(g)
        mhat = mu / b1c
        nhat = nu / b2c
        delta = mhat / (jnp.sqrt(nhat) + cfg.eps)
        if cfg.weight_decay > 0:
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), mu, nu

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_mu = treedef.flatten_up_to(state.mu)
    flat_nu = treedef.flatten_up_to(state.nu)
    out = [upd(p, g, m, n) for p, g, m, n in zip(flat_p, flat_g, flat_mu, flat_nu)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_mu = treedef.unflatten([o[1] for o in out])
    new_nu = treedef.unflatten([o[2] for o in out])
    return new_p, AdamWState(step, new_mu, new_nu), {"grad_norm": gnorm, "lr": lr}
