from repro.optim.adamw import AdamWConfig, AdamWState, apply, init, global_norm, schedule_lr  # noqa: F401
