import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod AOT dry-run: ``lower().compile()`` every (arch × shape × mesh).

This is the scale proof for the whole framework: 512 placeholder host
devices stand in for 2 TPU v5e pods, GSPMD partitions every step function
under the production sharding rules, and the compiled artifact yields
  * ``memory_analysis()``  — per-device bytes (proves the cell fits HBM),
  * ``cost_analysis()``    — HLO FLOPs / bytes for the roofline,
  * the optimized HLO      — parsed for per-device collective wire bytes.

One JSON per cell lands in ``experiments/dryrun/`` and feeds
``repro.roofline`` / ``benchmarks.roofline``.

Usage:
  python -m repro.launch.dryrun --arch gemma-2b --shape train_4k
  python -m repro.launch.dryrun --all [--multi-pod] [--jobs 4]
"""
import argparse
import json
import sys
import time
import traceback
from typing import Any, Dict, Optional

import numpy as np


# --------------------------------------------------------------- policies
def cell_policy(arch: str, shape) -> Dict[str, Any]:
    """Per-cell sharding/numerics choices (recorded in the cell JSON).

    * fsdp      — ZeRO-3 weight sharding over "data"; required where params
                  + optimizer exceed per-device HBM (all train shapes, and
                  the 132B/32B archs everywhere).
    * kv_int8   — quantized KV cache; required where the bf16 cache exceeds
                  pod HBM (qwen1.5-32b decode_32k: 5.5 TB bf16 > 4 TB pod).
    * shard_seq — batch=1 long-context: shard sequence/state dims over the
                  batch axes instead (sequence parallelism).
    """
    fsdp = (shape.kind == "train") or arch in ("dbrx-132b", "qwen1.5-32b")
    kv_int8 = arch == "qwen1.5-32b" and shape.kind == "decode"
    shard_seq = shape.global_batch == 1
    micro = {"dbrx-132b": 4, "qwen1.5-32b": 4, "qwen3-14b": 2,
             "glm4-9b": 2, "recurrentgemma-9b": 2}.get(arch, 1) \
        if shape.kind == "train" else 1
    return {"fsdp": fsdp, "kv_int8": kv_int8, "shard_seq": shard_seq,
            "microbatches": micro}


# ------------------------------------------------------------- collectives
_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")


def _shape_bytes(shape_str: str) -> int:
    """'bf16[2,16,128]' → bytes. Tuple shapes handled by caller."""
    import re as _re
    m = _re.match(r"([a-z0-9]+)\[([\d,]*)\]", shape_str)
    if not m:
        return 0
    dt, dims = m.group(1), m.group(2)
    nbytes = {"pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2,
              "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
              "f64": 8, "c64": 8, "u1": 1, "s1": 1}.get(dt)
    if nbytes is None:
        return 0
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * nbytes


def parse_collectives(hlo: str) -> Dict[str, Any]:
    """Per-device collective wire bytes from optimized (post-SPMD) HLO.

    Shapes in partitioned HLO are per-device. Ring-algorithm wire factors:
      all-gather        (g-1)/g × output
      all-reduce        2(g-1)/g × operand
      reduce-scatter    (g-1) × output      (input = g × output)
      all-to-all        (g-1)/g × operand
      collective-permute  1 × operand
    """
    import re as _re
    out = {k: {"count": 0, "bytes": 0.0, "wire_bytes": 0.0}
           for k in _COLLECTIVES}
    group_re = _re.compile(r"replica_groups=\{\{([\d,]+)\}")
    iota_re = _re.compile(r"replica_groups=\[(\d+),(\d+)\]")
    for line in hlo.splitlines():
        ls = line.lstrip()
        m = _re.match(r"(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(\(?[a-z0-9]+\[)", ls)
        if m is None:
            continue
        op = None
        for k in _COLLECTIVES:
            if f"= {k}" in ls.replace("(", " (") or f" {k}(" in ls \
                    or _re.search(rf"=\s*\(?\s*[a-z0-9]+\[[^\]]*\][^=]*\s{k}\(", ls):
                op = k
                break
        if op is None:
            # robust fallback: opcode appears right after the shape
            mm = _re.search(rf"\)?\s({'|'.join(_COLLECTIVES)})(-start|-done)?\(",
                            ls)
            if mm is None:
                continue
            op = mm.group(1)
            if mm.group(2) == "-done":
                continue  # count -start only, not its completion
        if f"{op}-done" in ls:
            continue
        # result shape(s): everything before the opcode
        shapes = _re.findall(r"[a-z0-9]+\[[\d,]*\]", ls.split("(")[0])
        nbytes = sum(_shape_bytes(s) for s in shapes)
        g = 1
        mg = group_re.search(ls)
        if mg:
            g = len(mg.group(1).split(","))
        else:
            mi = iota_re.search(ls)
            if mi:
                g = int(mi.group(2))
        if op == "collective-permute":
            factor = 1.0          # point-to-point: sends its whole tensor
        elif g <= 1:
            factor = 0.0
        elif op == "all-gather":
            factor = (g - 1) / g
        elif op == "all-reduce":
            factor = 2 * (g - 1) / g
        elif op == "reduce-scatter":
            factor = float(g - 1)
        else:  # all-to-all
            factor = (g - 1) / g
        out[op]["count"] += 1
        out[op]["bytes"] += float(nbytes)
        out[op]["wire_bytes"] += float(nbytes) * factor
    out["total_wire_bytes"] = sum(v["wire_bytes"] for v in out.values()
                                  if isinstance(v, dict))
    return out


# ------------------------------------------------------------------ lower
def lower_cell(arch: str, shape_name: str, *, multi_pod: bool = False,
               policy_override: Optional[Dict] = None,
               unroll: bool = False) -> Dict[str, Any]:
    if unroll:   # exact per-op HLO accounting (see decoder.force_unroll)
        os.environ["REPRO_UNROLL"] = "1"
    else:
        os.environ.pop("REPRO_UNROLL", None)
    import jax
    import jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.configs import get_config, get_shape, shape_applicable
    from repro.launch.mesh import make_production_mesh
    from repro.models import registry
    from repro.optim import adamw
    from repro.parallel import (batch_pspecs, cache_pspecs, param_pspecs,
                                shardings_for)
    from repro.parallel import activation as act
    from repro.runtime import steps as steps_lib

    cfg = get_config(arch)
    shape = get_shape(shape_name)
    if not shape_applicable(cfg, shape):
        return {"arch": arch, "shape": shape_name, "skipped": True,
                "reason": "full-attention arch skips long_500k"}

    policy = cell_policy(arch, shape)
    if policy_override:
        policy.update(policy_override)
    mesh = make_production_mesh(multi_pod=multi_pod)
    model = registry.build(cfg)
    t0 = time.time()

    act_ctx = act.use(mesh, shard_seq=policy["shard_seq"],
                      fsdp=policy["fsdp"])
    act_ctx.__enter__()
    try:
        return _lower_cell_inner(arch, shape_name, multi_pod, policy, mesh,
                                 model, shape, cfg, unroll, t0)
    finally:
        act_ctx.__exit__(None, None, None)


def _lower_cell_inner(arch, shape_name, multi_pod, policy, mesh, model,
                      shape, cfg, unroll, t0):
    import jax
    import jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.optim import adamw
    from repro.parallel import (batch_pspecs, cache_pspecs, param_pspecs,
                                shardings_for)
    from repro.runtime import steps as steps_lib

    params_shape = jax.eval_shape(lambda: model.init(jax.random.key(0)))
    pspec = param_pspecs(params_shape, mesh, fsdp=policy["fsdp"])
    psh = shardings_for(pspec, mesh)
    specs = model.input_specs(shape)
    bsh = shardings_for(
        batch_pspecs(specs, mesh, shard_seq=policy["shard_seq"]), mesh)
    kv_dtype = jnp.int8 if policy["kv_int8"] else None

    if shape.kind == "train":
        opt_cfg = adamw.AdamWConfig()
        opt_shape = jax.eval_shape(adamw.init, params_shape)
        osh = adamw.AdamWState(
            step=NamedSharding(mesh, P()), mu=psh,
            nu=jax.tree.map(lambda s: s, psh))
        fn = steps_lib.make_train_step(
            model, opt_cfg, remat=True,
            microbatches=policy.get("microbatches", 1))
        jfn = jax.jit(fn, in_shardings=(psh, osh, bsh),
                      out_shardings=(psh, osh, None),
                      donate_argnums=(0, 1))
        lowered = jfn.lower(params_shape, opt_shape, specs)
    elif shape.kind == "prefill":
        fn = steps_lib.make_prefill_step(model, shape.seq_len,
                                         kv_dtype=kv_dtype)
        out_shape = jax.eval_shape(fn, params_shape, specs)
        csh = shardings_for(
            cache_pspecs(out_shape[1], mesh, batch=shape.global_batch,
                         shard_seq=policy["shard_seq"]), mesh)
        jfn = jax.jit(fn, in_shardings=(psh, bsh),
                      out_shardings=(None, csh))
        lowered = jfn.lower(params_shape, specs)
    else:  # decode
        nv = cfg.n_vision_tokens if cfg.family == "vlm" else 0
        cache_shape = jax.eval_shape(
            lambda: model.init_cache(shape.global_batch,
                                     shape.seq_len + nv,
                                     kv_dtype=kv_dtype))
        csh = shardings_for(
            cache_pspecs(cache_shape, mesh, batch=shape.global_batch,
                         shard_seq=policy["shard_seq"]), mesh)
        fn = steps_lib.make_decode_step(model)
        jfn = jax.jit(fn, in_shardings=(psh, csh, bsh["tokens"]),
                      out_shardings=(None, csh), donate_argnums=(1,))
        lowered = jfn.lower(params_shape, cache_shape, specs["tokens"])

    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    coll = parse_collectives(compiled.as_text())
    n_dev = int(np.prod(list(mesh.shape.values())))
    result = {
        "arch": arch, "shape": shape_name, "kind": shape.kind,
        "unroll": unroll,
        "multi_pod": multi_pod, "n_devices": n_dev,
        "mesh": dict(mesh.shape), "policy": policy, "skipped": False,
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        "memory": {
            "argument_bytes": int(getattr(mem, "argument_size_in_bytes", 0)),
            "output_bytes": int(getattr(mem, "output_size_in_bytes", 0)),
            "temp_bytes": int(getattr(mem, "temp_size_in_bytes", 0)),
            "generated_code_bytes":
                int(getattr(mem, "generated_code_size_in_bytes", 0)),
            "alias_bytes": int(getattr(mem, "alias_size_in_bytes", 0)),
            "real_bytes": int(getattr(mem, "argument_size_in_bytes", 0)
                              + getattr(mem, "temp_size_in_bytes", 0)
                              + getattr(mem, "output_size_in_bytes", 0)
                              - getattr(mem, "alias_size_in_bytes", 0)),
        },
        "cost": {"flops": float(cost.get("flops", 0.0)),
                 "bytes_accessed": float(cost.get("bytes accessed", 0.0))},
        "collectives": coll,
        "model_params": int(cfg.total_params()),
        "model_params_active": int(cfg.active_params()),
    }
    return result


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             out_dir: str, unroll: bool = False) -> Dict[str, Any]:
    tag = f"{arch}_{shape_name}_{'pod2' if multi_pod else 'pod1'}"
    if unroll:
        tag += "_unroll"
    os.makedirs(out_dir, exist_ok=True)
    path = os.path.join(out_dir, tag + ".json")
    try:
        result = lower_cell(arch, shape_name, multi_pod=multi_pod,
                            unroll=unroll)
    except Exception as e:
        result = {"arch": arch, "shape": shape_name, "multi_pod": multi_pod,
                  "skipped": False, "error": f"{type(e).__name__}: {e}",
                  "traceback": traceback.format_exc()[-3000:]}
    with open(path, "w") as f:
        json.dump(result, f, indent=1)
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--unroll", action="store_true")
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args()

    from repro.configs import (ASSIGNED_ARCHS, SHAPES, get_config,
                               shape_applicable)

    if args.all:
        cells = [(a, s.name) for a in ASSIGNED_ARCHS for s in SHAPES
                 if shape_applicable(get_config(a), s)]
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        cells = [(args.arch, args.shape)]
    meshes = [args.multi_pod] if not args.both_meshes else [False, True]

    failures = 0
    for arch, shp in cells:
        for mp in meshes:
            tag = f"{arch}_{shp}_{'pod2' if mp else 'pod1'}"
            if args.unroll:
                tag += "_unroll"
            path = os.path.join(args.out, tag + ".json")
            if args.skip_existing and os.path.exists(path):
                with open(path) as f:
                    prev = json.load(f)
                if "error" not in prev:
                    print(f"SKIP {tag} (cached)")
                    continue
            r = run_cell(arch, shp, mp, args.out, unroll=args.unroll)
            if r.get("error"):
                failures += 1
                print(f"FAIL {tag}: {r['error']}", flush=True)
            elif r.get("skipped"):
                print(f"N/A  {tag}: {r['reason']}", flush=True)
            else:
                mem_gb = r["memory"]["real_bytes"] / 1e9
                print(f"OK   {tag}: compile={r['compile_s']}s "
                      f"mem/dev={mem_gb:.2f}GB "
                      f"GFLOP={r['cost']['flops']/1e9:.1f} "
                      f"wire={r['collectives']['total_wire_bytes']/1e6:.1f}MB",
                      flush=True)
    sys.exit(1 if failures else 0)


if __name__ == "__main__":
    main()
