"""Serving launcher: RAP-managed inference over a synthetic workload trace.

  PYTHONPATH=src python -m repro.launch.serve --arch llama2-7b --smoke \
      --requests 10 --mode structural --policy rl --scheduler fifo

Boots the reduced model, builds the requested pruning policy — for
``--policy rl`` that means briefly training the RAP controller (paper
Algorithm 2); static baselines (shortgpt, llmpruner, random, …) score
their removal order once and need no RL training — then serves an
Azure-like workload trace of (batch, seq_len, memory-budget) requests:
the full online loop of paper Algorithm 3, now policy-agnostic.

Two serving paths (DESIGN.md §10):
  * default — continuous batching through ``RAPEngine``: one shared KV pool
    with admission control; all in-flight requests decode together under
    the chosen scheduler (fifo | sjf | priority);
  * ``--serial`` — the historical one-shot ``RAPServer`` replay, each
    request against its own instantaneous budget.
"""
from __future__ import annotations

import argparse


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama2-7b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--mode", choices=("structural", "masked"),
                    default="structural")
    ap.add_argument("--policy", default="rl",
                    help="pruning policy (rl | shortgpt | llmpruner | "
                         "random | mha_drop | ffn_skip | oneshot | dense)")
    ap.add_argument("--scheduler", choices=("fifo", "sjf", "priority"),
                    default="fifo", help="engine admission ordering")
    ap.add_argument("--executor", choices=("local", "paged", "sharded"),
                    default="local",
                    help="execution backend: 'local' = slot-batched caches "
                         "(reference, any mode/arch); 'paged' = physically "
                         "paged KV pool with per-request page tables "
                         "(masked or structural mode, uniform-attention "
                         "archs); 'sharded' = mesh-resident slot groups, TP/DP "
                         "horizon decode (masked mode; see --mesh — works "
                         "on CPU via XLA_FLAGS="
                         "--xla_force_host_platform_device_count=8)")
    ap.add_argument("--mesh", default="auto",
                    help="sharded executor mesh as DATAxMODEL (e.g. 4x2); "
                         "'auto' picks a DP-majority mesh over the host's "
                         "devices whose data axis divides --slots")
    ap.add_argument("--serial", action="store_true",
                    help="one-shot RAPServer replay instead of the engine")
    ap.add_argument("--episodes", type=int, default=20)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4,
                    help="engine decode slots (concurrent requests)")
    ap.add_argument("--decode-horizon", type=int, default=8,
                    help="decode tokens fused per engine macro-tick: one "
                         "compiled on-device loop emits H tokens per "
                         "running request with ONE device→host sync "
                         "(results are bitwise-identical to H=1; see "
                         "DESIGN.md §5)")
    ap.add_argument("--chunked-prefill", action="store_true",
                    help="prefill prompts in pow2-bucketed chunks "
                         "interleaved with decode macro-ticks (async "
                         "engine, DESIGN.md §6) so a long prompt cannot "
                         "stall running decodes; chunk cap defaults to 64 "
                         "tokens unless --max-prefill-tokens is given")
    ap.add_argument("--max-prefill-tokens", type=int, default=0,
                    help="cap on prompt tokens prefilled per engine tick "
                         "(implies --chunked-prefill; 0 = monolithic "
                         "prefill unless --chunked-prefill is set)")
    ap.add_argument("--kv-dtype", default="model",
                    choices=("model", "fp32", "bf16", "int8", "fp8", "auto"),
                    help="KV cache storage precision: 'model' (default) "
                         "stores at the model dtype; int8/fp8 quantize "
                         "pages (paged executor: per-(page, head) scales "
                         "with dequant fused into the decode kernel; slot "
                         "executors: per-(token, head) scales) — admission "
                         "charges quantized bytes, so int8 admits ~2× the "
                         "sequence under the same budget; 'auto' lets the "
                         "policy choose once at startup: quantize when the "
                         "pool cannot host the full decode batch densely")
    ap.add_argument("--pool-requests", type=float, default=2.5,
                    help="KV pool sized for this many concurrent dense "
                         "requests")
    ap.add_argument("--budget-trace", choices=("none", "workload",
                                               "staircase"),
                    default="none",
                    help="time-varying device budget (DESIGN.md §11): "
                         "'workload' replays the trace's OU memory-"
                         "availability walk (each request's budget_frac "
                         "becomes a breakpoint); 'staircase' cuts half "
                         "the KV headroom for the middle half of the "
                         "trace and restores it; 'none' serves the "
                         "static budget. Under a trace the engine "
                         "preempts victims (KV spilled to host, resumed "
                         "bitwise when the budget recovers) unless "
                         "--no-enable-preemption")
    ap.add_argument("--enable-preemption", default=True,
                    action=argparse.BooleanOptionalAction,
                    help="preempt running requests when the budget trace "
                         "drops (--no-enable-preemption: shrink by "
                         "admission-gating new work only; in-flight "
                         "requests keep their pages)")
    ap.add_argument("--bucket-quant", choices=("none", "layer", "pow2"),
                    default="none",
                    help="structural bucket-shape quantization (DESIGN.md "
                         "§9): snap decision masks onto a ladder of whole-"
                         "layer keep-sets before minting a bucket — the "
                         "exact mask runs as 0/1 gates inside it (bitwise-"
                         "identical tokens) — so adaptive policies compile "
                         "a bounded executable set; 'pow2' bounds it at "
                         "ceil(log2 L)+1 families. The paged executor "
                         "floors 'none' at 'layer'")
    ap.add_argument("--compile-cache-dir", default="",
                    help="enable JAX's persistent compilation cache rooted "
                         "here: a second serve of the same config re-traces "
                         "but loads XLA binaries from disk instead of "
                         "recompiling (near-zero warm-start compiles; "
                         "DESIGN.md §9)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    if args.chunked_prefill and args.max_prefill_tokens <= 0:
        args.max_prefill_tokens = 64

    import jax
    import numpy as np

    from repro.configs import get_config, get_smoke_config
    from repro.core import dqn, env as env_lib, masks, memory, workload
    from repro.core.controller import RAPController
    from repro.core.policy import available_policies, make_policy
    from repro.data import SyntheticCorpus
    from repro.models import registry
    from repro.runtime import (EngineConfig, EngineRequest, PagedExecutor,
                               RAPEngine, RAPServer)

    if args.executor != "local" and args.serial:
        ap.error(f"--executor {args.executor} drives the batching engine; "
                 f"drop --serial")
    if args.executor == "sharded" and args.mode != "masked":
        ap.error("--executor sharded serves masked mode (structural sharded "
                 "buckets are a ROADMAP item); add --mode masked")

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    model = registry.build(cfg)
    params = model.init(jax.random.key(args.seed))
    corpus = SyntheticCorpus(cfg.vocab_size, seed=args.seed)
    calib = {k: jax.numpy.asarray(v)
             for k, v in corpus.batch(2, 64, split="calib").items()}
    mm = memory.build_memory_model(cfg)

    wl = workload.WorkloadConfig(seed=args.seed, max_batch=8,
                                 short_len=(32, 128), long_len=(128, 512),
                                 long_frac=0.3)
    sampler = workload.request_sampler(wl, mm)

    if args.policy == "rl":
        print(f"training RAP controller ({args.episodes} episodes)...")
        e = env_lib.PruneEnv(model, params, calib, mm)
        tr = dqn.train(lambda: e, episodes=args.episodes,
                       request_sampler=sampler, seed=args.seed)
        print(f"  reward: first={tr.episode_rewards[0]:.3f} "
              f"last={tr.episode_rewards[-1]:.3f} "
              f"fit-rate={np.mean(tr.episode_fits):.2f}")
        controller = RAPController(model, params, calib, mm, tr.q_params)
        policy = make_policy("rl", controller=controller)
    else:
        print(f"building static policy {args.policy!r} "
              f"(available: {', '.join(available_policies())})")
        policy = make_policy(args.policy, model=model, params=params,
                             calib=calib, mm=mm, seed=args.seed)

    reqs = workload.generate(wl)[: args.requests]
    rng = np.random.default_rng(args.seed)

    if args.serial:
        server = RAPServer(model, params, policy, mode=args.mode,
                           max_new_tokens=args.max_new)
        for i, r in enumerate(reqs):
            sql = min(r.seq_len, 256)
            prompt = corpus.sample_tokens(rng, r.batch, sql)
            budget = r.budget_frac * mm.dense_peak(r.batch, sql + args.max_new)
            res = server.serve(prompt, budget)
            kept = int(res.mask.sum())
            print(f"req {i}: bs={r.batch} sql={sql} "
                  f"budget={r.budget_frac:.2f} "
                  f"→ kept {kept}/{len(res.mask)} blocks, "
                  f"peak {res.peak_bytes/1e6:.1f}MB fits={res.fits} "
                  f"decide {res.decide_s*1e3:.0f}ms infer {res.infer_s:.2f}s "
                  f"{'(new compile)' if res.compiled_new else '(cached)'}")
        print("bucket stats:", server.stats())
        return

    # ------------------------------------------------- continuous batching
    max_total = 256 + args.max_new
    full = masks.full_mask(cfg.n_layers)
    # same workload the serial path serves: requests keep their trace batch
    # size (each occupies that many cache slots)
    slots = max(args.slots, *(r.batch for r in reqs))
    max_b = max(r.batch for r in reqs)
    budget = (mm.param_bytes(full)
              + args.pool_requests * mm.state_bytes(full, max_b, max_total))
    kv_dtype = None if args.kv_dtype == "model" else args.kv_dtype
    if kv_dtype == "auto":
        # precision as a policy action, resolved ONCE at startup (one pool
        # holds one precision): quantize when the pool cannot host the
        # full decode batch densely at model width, else keep model width
        kv_cap = budget - mm.param_bytes(full)
        dense_req = mm.state_bytes(full, 1, max_total)
        kv_dtype = "int8" if kv_cap < slots * dense_req else None
        print(f"--kv-dtype auto → {kv_dtype or 'model precision'} "
              f"(pool {kv_cap / 1e6:.1f}MB vs {slots} dense requests "
              f"{slots * dense_req / 1e6:.1f}MB)")
    executor = None
    if args.executor == "paged":
        executor = PagedExecutor(model, params, mode=args.mode,
                                 max_active=slots, kv_dtype=kv_dtype,
                                 bucket_quant=args.bucket_quant)
    elif args.executor == "sharded":
        from repro.launch.mesh import make_host_mesh, make_serve_mesh
        from repro.runtime import ShardedExecutor
        if args.mesh == "auto":
            mesh = make_serve_mesh(slots)
        else:
            try:
                d, m = (int(x) for x in args.mesh.lower().split("x"))
            except ValueError:
                ap.error(f"--mesh must be DATAxMODEL (e.g. 4x2), got "
                         f"{args.mesh!r}")
            if d * m > len(jax.devices()):
                ap.error(f"--mesh {args.mesh} needs {d * m} devices, host "
                         f"has {len(jax.devices())} (on CPU set XLA_FLAGS="
                         f"--xla_force_host_platform_device_count=N)")
            if slots % d != 0:
                # serve_state_pspecs would silently fall back to full
                # replication — N-way dispatch overhead, zero DP sharding
                print(f"WARNING: data axis {d} does not divide {slots} "
                      f"slots — the slot axis will replicate instead of "
                      f"sharding (pick --slots a multiple of {d}, or "
                      f"--mesh auto)")
            mesh = make_host_mesh((d, m), ("data", "model"))
        print(f"sharded mesh: {dict(mesh.shape)} over {mesh.size} of "
              f"{len(jax.devices())} devices")
        executor = ShardedExecutor(model, mesh, params=params,
                                   max_active=slots, kv_dtype=kv_dtype)
    engine = RAPEngine(model, params, policy, EngineConfig(
        mode=args.mode, max_new_tokens=args.max_new, max_active=slots,
        max_len=max_total, budget_bytes=budget, kv_dtype=kv_dtype,
        decode_horizon=args.decode_horizon,
        max_prefill_tokens=args.max_prefill_tokens,
        preemption_enabled=args.enable_preemption,
        bucket_quant=args.bucket_quant,
        compile_cache_dir=args.compile_cache_dir),
        scheduler=args.scheduler, executor=executor)
    ereqs = []
    for i, r in enumerate(reqs):
        sql = min(r.seq_len, 256)
        prompt = corpus.sample_tokens(rng, r.batch, sql)
        # interactive tier: short conversational turns outrank long-form
        # documents (only consulted under --scheduler priority)
        ereqs.append(EngineRequest(rid=f"req{i}", prompt=prompt,
                                   arrival_t=r.t - reqs[0].t,
                                   priority=0 if sql <= 128 else 1))
    # time-varying budget (DESIGN.md §11): breakpoint lists on the
    # engine's virtual clock, derived from the workload or a synthetic
    # mid-serve staircase shock
    trace = None
    if args.budget_trace == "workload":
        from repro.runtime import workload_budget_trace
        t0 = reqs[0].t
        trace = [(t - t0, b) for t, b in
                 workload_budget_trace(reqs, budget)]
    elif args.budget_trace == "staircase":
        from repro.runtime import staircase_trace
        span = max(ereqs[-1].arrival_t, 0.2)
        # cut half the KV headroom (params stay resident — a 50% TOTAL
        # cut would zero the pool at smoke scale) for the middle half
        kv = budget - mm.param_bytes(full)
        shocked = (mm.param_bytes(full) + 0.5 * kv) / budget
        trace = staircase_trace(budget, 0.25 * span, 0.75 * span,
                                frac=shocked)
    if trace is not None:
        print(f"budget trace: {args.budget_trace} "
              f"({len(trace)} breakpoints, "
              f"{min(b for _, b in trace)/1e6:.1f}–"
              f"{max(b for _, b in trace)/1e6:.1f}MB), preemption "
              f"{'on' if args.enable_preemption else 'off'}")
    print(f"engine[{policy.name}/{args.scheduler}/{args.executor}]: "
          f"{len(ereqs)} requests "
          f"(batch {min(r.batch for r in reqs)}–{max(r.batch for r in reqs)}),"
          f" {slots} slots, shared pool {budget/1e6:.1f}MB total budget")
    rep = engine.run(ereqs, budget_trace=trace)
    for r in rep.results:
        if r.status == "done":
            kept = int(r.mask.sum())
            print(f"{r.rid}: kept {kept}/{len(r.mask)} blocks  "
                  f"queue {r.queue_delay_s*1e3:.0f}ms  "
                  f"decide {r.decide_s*1e3:.0f}ms"
                  f"{' (memo)' if r.cached_decision else ''}  "
                  f"fits={r.fits}")
        else:
            print(f"{r.rid}: {r.status.upper()} ({r.reason})")
    print(f"engine: {rep.tokens_per_s:.1f} tok/s, "
          f"{rep.decode_iters} decode iters, "
          f"mean queue {rep.mean_queue_delay_s*1e3:.0f}ms, "
          f"fit-rate {rep.budget_fit_rate:.2f}")
    if args.compile_cache_dir:
        print(f"compile cache: {rep.compile_events} traces, "
              f"{rep.compile_cache_hits} disk hits, "
              f"{rep.compile_cache_misses} misses "
              f"({args.compile_cache_dir})")
    if rep.preempted_count:
        print(f"preemption: {rep.preempted_count} preempted, "
              f"{rep.spilled_mb:.2f}MB spilled, resume p50/p99 "
              f"{rep.resume_latency.get('p50', 0.0)*1e3:.0f}/"
              f"{rep.resume_latency.get('p99', 0.0)*1e3:.0f}ms, "
              f"preempted-request itl p99 "
              f"{rep.itl_preempted.get('p99', 0.0)*1e3:.2f}ms")
    if rep.ttft.get("count"):
        print(f"latency: ttft p50/p99 {rep.ttft['p50']*1e3:.0f}/"
              f"{rep.ttft['p99']*1e3:.0f}ms, itl p50/p99 "
              f"{rep.itl['p50']*1e3:.2f}/{rep.itl['p99']*1e3:.2f}ms")
    print(f"pool: peak {rep.pool['peak_reserved_bytes']/1e6:.2f}MB "
          f"of {rep.pool['capacity_bytes']/1e6:.2f}MB, "
          f"frag {rep.pool['fragmentation']:.2f}, "
          f"measured frag {rep.measured_frag:.2f}, "
          f"overcommits {int(rep.pool['overcommit_events'])}")
    print("engine stats:", engine.stats())


if __name__ == "__main__":
    main()
