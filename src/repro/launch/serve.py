"""Serving launcher: RAP-managed inference over a synthetic workload trace.

  PYTHONPATH=src python -m repro.launch.serve --arch llama2-7b --smoke \
      --requests 10 --mode structural

Boots the reduced model, trains the RAP controller briefly (or loads
``--qnet`` from a checkpoint), then replays an Azure-like workload trace of
(batch, seq_len, memory-budget) requests through ``RAPServer`` — the full
online loop of paper Algorithm 3.
"""
from __future__ import annotations

import argparse


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama2-7b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--mode", choices=("structural", "masked"),
                    default="structural")
    ap.add_argument("--episodes", type=int, default=20)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    import jax
    import numpy as np

    from repro.configs import get_config, get_smoke_config
    from repro.core import dqn, env as env_lib, memory, workload
    from repro.core.controller import RAPController
    from repro.data import SyntheticCorpus
    from repro.models import registry
    from repro.runtime import RAPServer

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    model = registry.build(cfg)
    params = model.init(jax.random.key(args.seed))
    corpus = SyntheticCorpus(cfg.vocab_size, seed=args.seed)
    calib = {k: jax.numpy.asarray(v)
             for k, v in corpus.batch(2, 64, split="calib").items()}
    mm = memory.build_memory_model(cfg)

    wl = workload.WorkloadConfig(seed=args.seed, max_batch=8,
                                 short_len=(32, 128), long_len=(128, 512),
                                 long_frac=0.3)
    sampler = workload.request_sampler(wl, mm)

    print(f"training RAP controller ({args.episodes} episodes)...")
    e = env_lib.PruneEnv(model, params, calib, mm)
    tr = dqn.train(lambda: e, episodes=args.episodes,
                   request_sampler=sampler, seed=args.seed)
    print(f"  reward: first={tr.episode_rewards[0]:.3f} "
          f"last={tr.episode_rewards[-1]:.3f} "
          f"fit-rate={np.mean(tr.episode_fits):.2f}")

    controller = RAPController(model, params, calib, mm, tr.q_params)
    server = RAPServer(model, params, controller, mode=args.mode,
                       max_new_tokens=args.max_new)

    reqs = workload.generate(wl)[: args.requests]
    rng = np.random.default_rng(args.seed)
    for i, r in enumerate(reqs):
        sql = min(r.seq_len, 256)
        prompt = corpus.sample_tokens(rng, r.batch, sql)
        budget = r.budget_frac * mm.dense_peak(r.batch, sql + args.max_new)
        res = server.serve(prompt, budget)
        kept = int(res.mask.sum())
        print(f"req {i}: bs={r.batch} sql={sql} budget={r.budget_frac:.2f} "
              f"→ kept {kept}/{len(res.mask)} blocks, "
              f"peak {res.peak_bytes/1e6:.1f}MB fits={res.fits} "
              f"decide {res.decide_s*1e3:.0f}ms infer {res.infer_s:.2f}s "
              f"{'(new compile)' if res.compiled_new else '(cached)'}")
    print("bucket stats:", server.stats())


if __name__ == "__main__":
    main()
