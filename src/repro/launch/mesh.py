"""Production mesh construction.

Importing this module never touches jax device state — meshes are built
inside functions only, so the 512-placeholder-device XLA flag (set by
``dryrun.py`` before any jax import) and real-TPU runs both work.

Topology: one v5e pod = 16×16 = 256 chips → mesh ("data", "model").
Multi-pod adds a leading "pod" axis (DCN-connected): batch shards over
("pod", "data"); "model" (TP/EP) stays inside a pod where ICI is fast.
"""
from __future__ import annotations

from typing import Optional, Tuple

import numpy as np


def make_production_mesh(*, multi_pod: bool = False):
    import jax

    from repro.compat import make_mesh

    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes,
                     devices=jax.devices()[: int(np.prod(shape))])


def make_host_mesh(shape: Tuple[int, ...] = None, axes=None):
    """Small mesh over whatever devices exist (tests / local runs)."""
    import jax

    from repro.compat import make_mesh

    n = len(jax.devices())
    if shape is None:
        shape, axes = (n, 1), ("data", "model")
    return make_mesh(shape, axes,
                     devices=jax.devices()[: int(np.prod(shape))])


# Hardware constants for the roofline (TPU v5e per chip).
PEAK_FLOPS_BF16 = 197e12        # FLOP/s
HBM_BW = 819e9                  # bytes/s
ICI_BW = 50e9                   # bytes/s per link
