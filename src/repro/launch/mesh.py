"""Production mesh construction.

Importing this module never touches jax device state — meshes are built
inside functions only, so the 512-placeholder-device XLA flag (set by
``dryrun.py`` before any jax import) and real-TPU runs both work.

Topology: one v5e pod = 16×16 = 256 chips → mesh ("data", "model").
Multi-pod adds a leading "pod" axis (DCN-connected): batch shards over
("pod", "data"); "model" (TP/EP) stays inside a pod where ICI is fast.
"""
from __future__ import annotations

from typing import Optional, Tuple

import numpy as np


def make_production_mesh(*, multi_pod: bool = False):
    import jax

    from repro.compat import make_mesh

    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes,
                     devices=jax.devices()[: int(np.prod(shape))])


def make_host_mesh(shape: Tuple[int, ...] = None, axes=None):
    """Small mesh over whatever devices exist (tests / local runs)."""
    import jax

    from repro.compat import make_mesh

    n = len(jax.devices())
    if shape is None:
        shape, axes = (n, 1), ("data", "model")
    return make_mesh(shape, axes,
                     devices=jax.devices()[: int(np.prod(shape))])


def make_serve_mesh(n_slots: Optional[int] = None, *, model: int = 1):
    """DP-majority serve mesh over the host's devices (DESIGN.md §7).

    The engine's slot axis is the data-parallel dimension, so the "data"
    axis is the largest power of two that (a) fits the devices left after
    the requested "model" (TP) axis and (b) divides ``n_slots`` — a data
    axis that does not divide the slot count would make
    ``serve_state_pspecs`` fall back to replication. One device yields
    the degenerate (1, 1) mesh; the 8-fake-device CI host
    (``XLA_FLAGS=--xla_force_host_platform_device_count=8``) with 8
    slots yields (8, 1)."""
    import jax

    n = len(jax.devices()) // max(int(model), 1)
    d = 1
    while d * 2 <= n and (n_slots is None or int(n_slots) % (d * 2) == 0):
        d *= 2
    return make_host_mesh((d, int(model)), ("data", "model"))


# Hardware constants for the roofline (TPU v5e per chip).
PEAK_FLOPS_BF16 = 197e12        # FLOP/s
HBM_BW = 819e9                  # bytes/s
ICI_BW = 50e9                   # bytes/s per link
