"""Training launcher.

  PYTHONPATH=src python -m repro.launch.train --arch llama2-7b --smoke \
      --steps 200 --ckpt-dir /tmp/ckpt

``--smoke`` selects the reduced config of the same family (CPU-trainable);
omit it on real hardware for the full config. The trainer resumes from the
latest checkpoint automatically — rerunning the same command after a crash
continues the run (fault-tolerance path exercised by tests).
"""
from __future__ import annotations

import argparse


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama2-7b")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced same-family config (CPU-trainable)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--mesh", action="store_true",
                    help="build a mesh over available devices")
    args = ap.parse_args()

    import jax

    from repro.configs import get_config, get_smoke_config
    from repro.data import SyntheticCorpus, batch_iterator
    from repro.launch.mesh import make_host_mesh
    from repro.models import registry
    from repro.optim import adamw
    from repro.runtime import Trainer, TrainerConfig

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    model = registry.build(cfg)
    corpus = SyntheticCorpus(cfg.vocab_size, seed=args.seed)

    extra = None
    if cfg.family == "vlm":
        import jax.numpy as jnp
        extra = {"vision_embeds": jnp.zeros(
            (args.batch, cfg.n_vision_tokens, cfg.d_model), cfg.jnp_dtype())}
    if cfg.is_encoder_decoder:
        import jax.numpy as jnp
        extra = {"frames": jnp.zeros(
            (args.batch, cfg.n_audio_frames, cfg.d_model), cfg.jnp_dtype())}

    trainer = Trainer(
        model,
        adamw.AdamWConfig(lr=args.lr, total_steps=args.steps),
        TrainerConfig(total_steps=args.steps, ckpt_dir=args.ckpt_dir,
                      ckpt_every=args.ckpt_every, seed=args.seed,
                      log_every=max(args.steps // 20, 1)),
        mesh=make_host_mesh() if args.mesh else None,
        on_log=lambda s, m: print(
            f"step {s:5d}  loss {m['loss']:.4f}  ppl {m['ppl']:.2f}  "
            f"gnorm {m['grad_norm']:.3f}", flush=True),
    )
    start = trainer.step if trainer.maybe_restore() else 0
    if start:
        print(f"resumed from checkpoint at step {start}")
    batches = batch_iterator(corpus, args.batch, args.seq, start=start,
                             extra=extra)
    summary = trainer.run(batches)
    print(f"done at step {summary['final_step']}; "
          f"stragglers observed: {len(summary['straggler_events'])}")


if __name__ == "__main__":
    main()
