import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""RAP-at-scale sweep: lower the decode step for structurally pruned
variants of an architecture and report how the roofline terms move.

Decode is memory-bound (params + KV cache streamed once per token), so the
paper's co-pruning of MHA blocks (KV bytes) and FFN blocks (param bytes) is
*directly* a roofline lever: the dominant memory term scales with the
retained blocks. This script quantifies that at production scale — the
systems-level counterpart of the paper's Table 1.

  python -m repro.launch.rap_sweep --arch qwen3-14b --shape decode_32k
"""
import argparse
import json


def lower_pruned_decode(arch: str, shape_name: str, keep_frac: float,
                        out_dir: str):
    """Lower decode for a layer-bucket pruned variant (keep_frac of layer
    pairs — the dominant structural-compaction bucket) through the
    ``ShardedExecutor``'s mesh-placement path (serving-API split)."""
    from repro.configs import get_config, get_shape
    from repro.launch.dryrun import cell_policy, parse_collectives
    from repro.launch.mesh import make_production_mesh
    from repro.models import registry
    from repro.runtime import ShardedExecutor

    base = get_config(arch)
    L = max(2, int(round(base.n_layers * keep_frac)))
    cfg = base.replace(n_layers=L)
    shape = get_shape(shape_name)
    policy = cell_policy(arch, shape)
    mesh = make_production_mesh()
    model = registry.build(cfg)

    executor = ShardedExecutor(model, mesh, fsdp=policy["fsdp"],
                               shard_seq=policy["shard_seq"],
                               kv_int8=policy["kv_int8"])
    compiled = executor.lower_decode(shape).compile()
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):   # pre-0.4.30 jax: one dict/device
        cost = cost[0] if cost else {}
    mem = compiled.memory_analysis()
    coll = parse_collectives(compiled.as_text())
    from repro.launch.mesh import HBM_BW, ICI_BW, PEAK_FLOPS_BF16
    flops, byts = float(cost.get("flops", 0)), float(
        cost.get("bytes accessed", 0))
    result = {
        "arch": arch, "shape": shape_name, "keep_frac": keep_frac,
        "n_layers": L,
        "compute_s": flops / PEAK_FLOPS_BF16,
        "memory_s": byts / HBM_BW,
        "collective_s": coll["total_wire_bytes"] / ICI_BW,
        "hlo_flops": flops, "hlo_bytes": byts,
        "real_gb": (mem.argument_size_in_bytes + mem.temp_size_in_bytes
                    + mem.output_size_in_bytes
                    - mem.alias_size_in_bytes) / 1e9,
    }
    os.makedirs(out_dir, exist_ok=True)
    tag = f"rap_{arch}_{shape_name}_keep{int(keep_frac*100)}"
    with open(os.path.join(out_dir, tag + ".json"), "w") as f:
        json.dump(result, f, indent=1)
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-14b")
    ap.add_argument("--shape", default="decode_32k")
    ap.add_argument("--fracs", default="1.0,0.8,0.6")
    ap.add_argument("--out", default="experiments/rap_sweep")
    args = ap.parse_args()

    print(f"{'keep':>5} {'layers':>6} {'mem_s':>9} {'comp_s':>9} "
          f"{'coll_s':>9} {'fit_GB':>7}")
    rows = []
    for frac in [float(x) for x in args.fracs.split(",")]:
        r = lower_pruned_decode(args.arch, args.shape, frac, args.out)
        rows.append(r)
        print(f"{frac:5.2f} {r['n_layers']:6d} {r['memory_s']:9.5f} "
              f"{r['compute_s']:9.5f} {r['collective_s']:9.5f} "
              f"{r['real_gb']:7.2f}", flush=True)
    base = rows[0]
    for r in rows[1:]:
        print(f"# keep={r['keep_frac']}: step-time bound "
              f"{max(r['memory_s'], r['compute_s'], r['collective_s'])/max(base['memory_s'], base['compute_s'], base['collective_s']):.3f}×"
              f" of dense")


if __name__ == "__main__":
    main()
