"""olmoe-1b-7b — 64 experts top-8 [arXiv:2409.02060; hf:allenai/OLMoE-1B-7B].

[moe] 16L d_model=2048 16H (GQA kv=16) d_ff=1024 (per expert) vocab=50304.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="olmoe-1b-7b",
    family="moe",
    source="arXiv:2409.02060; hf:allenai/OLMoE-1B-7B-0924",
    n_layers=16,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=1024,
    vocab_size=50304,
    activation="swiglu",
    n_experts=64,
    moe_top_k=8,
    qk_norm=True,
    rope_theta=10000.0,
    tie_embeddings=False,
)

SMOKE = CONFIG.replace(
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16, d_ff=64,
    n_experts=8, moe_top_k=2, vocab_size=512, vocab_round_to=64,
    param_dtype="float32", dtype="float32",
)
