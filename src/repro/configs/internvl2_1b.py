"""internvl2-1b — InternViT + InternLM2 backbone [arXiv:2404.16821; hf].

[vlm] 24L d_model=896 14H (GQA kv=2) d_ff=4864 vocab=151655.
The ViT frontend is a STUB per the assignment: ``input_specs()`` provides
precomputed patch embeddings prepended to the token stream.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-1b",
    family="vlm",
    source="arXiv:2404.16821; hf:OpenGVLab/InternVL2-1B",
    n_layers=24,
    d_model=896,
    n_heads=14,
    n_kv_heads=2,
    d_ff=4864,
    vocab_size=151655,
    activation="swiglu",
    rope_theta=1_000_000.0,
    n_vision_tokens=256,
    tie_embeddings=True,
)

SMOKE = CONFIG.replace(
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16, d_ff=128,
    vocab_size=512, vocab_round_to=64, n_vision_tokens=8,
    param_dtype="float32", dtype="float32",
)
