"""qwen1.5-32b — QKV bias [hf:Qwen/Qwen1.5-0.5B family scaling].

[dense] 64L d_model=5120 40H (GQA kv=40 → full MHA KV) d_ff=27392 vocab=152064.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen1.5-32b",
    family="dense",
    source="hf:Qwen/Qwen1.5-32B",
    n_layers=64,
    d_model=5120,
    n_heads=40,
    n_kv_heads=40,
    d_ff=27392,
    vocab_size=152064,
    activation="swiglu",
    qkv_bias=True,
    rope_theta=1_000_000.0,
    tie_embeddings=False,
)

SMOKE = CONFIG.replace(
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16, d_ff=160,
    vocab_size=512, vocab_round_to=64,
    param_dtype="float32", dtype="float32",
)
