"""dbrx-132b — 16 experts top-4, fine-grained [hf:databricks/dbrx-base].

[moe] 40L d_model=6144 48H (GQA kv=8) d_ff=10752 (per expert) vocab=100352.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="dbrx-132b",
    family="moe",
    source="hf:databricks/dbrx-base",
    n_layers=40,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_ff=10752,
    vocab_size=100352,
    activation="swiglu",
    n_experts=16,
    moe_top_k=4,
    rope_theta=500_000.0,
    tie_embeddings=False,
)

SMOKE = CONFIG.replace(
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16, d_ff=64,
    n_experts=4, moe_top_k=2, vocab_size=512, vocab_round_to=64,
    param_dtype="float32", dtype="float32",
)
