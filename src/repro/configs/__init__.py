"""Config registry: ``get_config(name)``, ``get_smoke_config(name)``.

The ten assigned architectures plus the paper's own subject model. Every
config is selectable from launchers via ``--arch <id>``.
"""
from __future__ import annotations

import importlib
from typing import Dict, List

from repro.configs.base import ModelConfig, ShapeConfig, SHAPES, get_shape

_MODULES = {
    "internvl2-1b": "repro.configs.internvl2_1b",
    "recurrentgemma-9b": "repro.configs.recurrentgemma_9b",
    "glm4-9b": "repro.configs.glm4_9b",
    "qwen1.5-32b": "repro.configs.qwen15_32b",
    "gemma-2b": "repro.configs.gemma_2b",
    "qwen3-14b": "repro.configs.qwen3_14b",
    "mamba2-370m": "repro.configs.mamba2_370m",
    "olmoe-1b-7b": "repro.configs.olmoe_1b_7b",
    "dbrx-132b": "repro.configs.dbrx_132b",
    "whisper-medium": "repro.configs.whisper_medium",
    "llama2-7b": "repro.configs.llama2_7b",
}

ASSIGNED_ARCHS: List[str] = [k for k in _MODULES if k != "llama2-7b"]


def get_config(name: str) -> ModelConfig:
    if name not in _MODULES:
        raise KeyError(f"unknown arch {name!r}; available: {sorted(_MODULES)}")
    return importlib.import_module(_MODULES[name]).CONFIG


def get_smoke_config(name: str) -> ModelConfig:
    if name not in _MODULES:
        raise KeyError(f"unknown arch {name!r}; available: {sorted(_MODULES)}")
    return importlib.import_module(_MODULES[name]).SMOKE


def all_configs() -> Dict[str, ModelConfig]:
    return {k: get_config(k) for k in _MODULES}


def shape_applicable(cfg: ModelConfig, shape: ShapeConfig) -> bool:
    """Cell-applicability rules from the assignment."""
    if shape.name == "long_500k" and not cfg.sub_quadratic():
        return False  # pure full-attention archs skip long-context decode
    return True


__all__ = [
    "ModelConfig", "ShapeConfig", "SHAPES", "get_shape", "get_config",
    "get_smoke_config", "all_configs", "ASSIGNED_ARCHS", "shape_applicable",
]
