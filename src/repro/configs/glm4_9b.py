"""glm4-9b — RoPE, GQA [hf:THUDM/glm-4-9b].

[dense] 40L d_model=4096 32H (GQA kv=2) d_ff=13696 vocab=151552.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="glm4-9b",
    family="dense",
    source="hf:THUDM/glm-4-9b",
    n_layers=40,
    d_model=4096,
    n_heads=32,
    n_kv_heads=2,
    d_ff=13696,
    vocab_size=151552,
    activation="swiglu",
    rope_theta=10000.0,
    tie_embeddings=False,
)

SMOKE = CONFIG.replace(
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16, d_ff=160,
    vocab_size=512, vocab_round_to=64,
    param_dtype="float32", dtype="float32",
)
