"""qwen3-14b — qk_norm, GQA [hf:Qwen/Qwen3-14B family].

[dense] 40L d_model=5120 40H (GQA kv=8) d_ff=17408 vocab=151936.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-14b",
    family="dense",
    source="hf:Qwen/Qwen3-14B",
    n_layers=40,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    d_ff=17408,
    vocab_size=151936,
    activation="swiglu",
    qk_norm=True,
    rope_theta=1_000_000.0,
    tie_embeddings=False,
)

SMOKE = CONFIG.replace(
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16, d_ff=160,
    vocab_size=512, vocab_round_to=64,
    param_dtype="float32", dtype="float32",
)
