"""whisper-medium — enc-dec, conv frontend STUB [arXiv:2212.04356].

[audio] 24L(dec)+24L(enc) d_model=1024 16H (kv=16) d_ff=4096 vocab=51865.
``input_specs()`` supplies precomputed mel-frame embeddings (the conv stem is
a stub per the assignment); learned positional embeddings over 1500 frames.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-medium",
    family="audio",
    source="arXiv:2212.04356; hf:openai/whisper-medium",
    n_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    d_ff=4096,
    vocab_size=51865,
    activation="gelu",
    use_rope=False,
    norm="layernorm",
    is_encoder_decoder=True,
    n_encoder_layers=24,
    n_audio_frames=1500,
    tie_embeddings=True,
)

SMOKE = CONFIG.replace(
    n_layers=2, n_encoder_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
    head_dim=16, d_ff=128, vocab_size=512, vocab_round_to=64, n_audio_frames=16,
    param_dtype="float32", dtype="float32",
)
