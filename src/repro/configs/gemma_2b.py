"""gemma-2b — GeGLU, head_dim=256, MQA [arXiv:2403.08295; hf:google/gemma-2b].

[dense] 18L d_model=2048 8H (GQA kv=1) d_ff=16384 vocab=256000.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="gemma-2b",
    family="dense",
    source="arXiv:2403.08295; hf:google/gemma-2b",
    n_layers=18,
    d_model=2048,
    n_heads=8,
    n_kv_heads=1,
    head_dim=256,
    d_ff=16384,
    vocab_size=256000,
    activation="geglu",
    rope_theta=10000.0,
    embed_scale=True,
    tie_embeddings=True,
)

SMOKE = CONFIG.replace(
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=1, head_dim=32, d_ff=128,
    vocab_size=512, vocab_round_to=64,
    param_dtype="float32", dtype="float32",
)
