"""recurrentgemma-9b — Griffin: RG-LRU + local attention, 1 attn : 2 recurrent
[arXiv:2402.19427; unverified].

[hybrid] 38L d_model=4096 16H (GQA kv=1) d_ff=12288 vocab=256000.
Pattern: (rglru, rglru, local_attn) repeating; local window 2048.
Sub-quadratic → runs the long_500k shape.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-9b",
    family="hybrid",
    source="arXiv:2402.19427 (Griffin); hf:google/recurrentgemma-9b",
    n_layers=38,
    d_model=4096,
    n_heads=16,
    n_kv_heads=1,
    head_dim=256,
    d_ff=12288,
    vocab_size=256000,
    activation="geglu",
    attn_window=2048,
    block_pattern=("rglru", "rglru", "local_attn"),
    rnn_width=4096,
    use_rope=True,
    embed_scale=True,
    tie_embeddings=True,
)

SMOKE = CONFIG.replace(
    n_layers=3, d_model=64, n_heads=2, n_kv_heads=1, head_dim=32, d_ff=128,
    vocab_size=512, vocab_round_to=64, attn_window=16, rnn_width=64,
    param_dtype="float32", dtype="float32",
)
