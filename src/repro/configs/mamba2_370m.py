"""mamba2-370m — SSD (state-space duality) [arXiv:2405.21060].

[ssm] 48L d_model=1024 attn-free vocab=50280, ssm_state=128, d_ff=0
(mamba2 has no separate FFN; the SSD mixer is the whole layer).
Sub-quadratic → runs the long_500k shape.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-370m",
    family="ssm",
    source="arXiv:2405.21060; hf:state-spaces/mamba2-370m",
    n_layers=48,
    d_model=1024,
    n_heads=0,
    n_kv_heads=0,
    head_dim=1,
    d_ff=0,
    vocab_size=50280,
    ssm_state=128,
    ssm_expand=2,
    ssm_head_dim=64,
    ssm_conv_width=4,
    ssm_chunk=256,
    use_rope=False,
    tie_embeddings=True,
)

SMOKE = CONFIG.replace(
    n_layers=2, d_model=64, ssm_state=16, ssm_head_dim=16, ssm_chunk=8,
    vocab_size=512, vocab_round_to=64,
    param_dtype="float32", dtype="float32",
)
