"""llama2-7b — the paper's own primary subject [arXiv:2307.09288].

32L d_model=4096 32H (MHA kv=32) d_ff=11008 vocab=32000. Used by the
paper-faithful reproduction experiments (Table 1/2/4, Figs 3-11 analogues).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llama2-7b",
    family="dense",
    source="arXiv:2307.09288 (paper's subject model)",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=32,
    d_ff=11008,
    vocab_size=32000,
    activation="swiglu",
    rope_theta=10000.0,
    tie_embeddings=False,
)

# The in-repo trainable stand-in for the paper's experiments (same family:
# RMSNorm + SwiGLU + RoPE decoder) — small enough to train on CPU.
RAP_SUBJECT = CONFIG.replace(
    name="llama2-7b-subject",
    n_layers=8, d_model=256, n_heads=8, n_kv_heads=8, head_dim=32, d_ff=688,
    vocab_size=512, vocab_round_to=64,
    param_dtype="float32", dtype="float32",
)

SMOKE = CONFIG.replace(
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16, d_ff=176,
    vocab_size=512, vocab_round_to=64,
    param_dtype="float32", dtype="float32",
)
