"""Model / run configuration dataclasses.

Every assigned architecture is expressed as a ``ModelConfig``. The decoder
substrate reads ``layer_specs()`` — a per-layer (mixer_kind, ffn_kind) list —
so dense, MoE, SSM, hybrid and enc-dec families all flow through one model
implementation.

Mixer kinds:   'attn' | 'local_attn' | 'rglru' | 'ssd'
FFN kinds:     'dense' | 'moe' | 'none'
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Optional, Sequence, Tuple

import jax.numpy as jnp


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    # identity -------------------------------------------------------------
    name: str = "model"
    family: str = "dense"  # dense|moe|ssm|hybrid|vlm|audio
    source: str = ""       # citation tag

    # trunk ----------------------------------------------------------------
    n_layers: int = 2
    d_model: int = 128
    n_heads: int = 2
    n_kv_heads: int = 2
    head_dim: Optional[int] = None       # default d_model // n_heads
    d_ff: int = 256
    vocab_size: int = 256
    vocab_round_to: int = 512            # production vocab padding (TP-friendly)

    # attention flavour ------------------------------------------------------
    qkv_bias: bool = False               # qwen1.5
    qk_norm: bool = False                # qwen3
    logit_softcap: float = 0.0           # gemma-2 style (0 = off)
    attn_window: int = 0                 # local attention window (0 = global)
    rope_theta: float = 10000.0
    use_rope: bool = True

    # ffn flavour -----------------------------------------------------------
    activation: str = "swiglu"           # swiglu|geglu|gelu
    # MoE
    n_experts: int = 0
    moe_top_k: int = 0
    # capacity factor for dense dispatch (tokens per expert = cf * T * top_k / E)
    moe_capacity_factor: float = 1.25

    # ssm (mamba-2 / SSD) ----------------------------------------------------
    ssm_state: int = 0                   # d_state (mamba2: 128)
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_conv_width: int = 4
    ssm_chunk: int = 256

    # hybrid (recurrentgemma / griffin) ---------------------------------------
    block_pattern: Tuple[str, ...] = ()  # e.g. ('rglru','rglru','local_attn')
    rnn_width: int = 0                   # RG-LRU recurrence width (griffin: ~d_model)

    # enc-dec (whisper) --------------------------------------------------------
    is_encoder_decoder: bool = False
    n_encoder_layers: int = 0
    n_audio_frames: int = 1500           # whisper stub frontend output length

    # multimodal stub ----------------------------------------------------------
    n_vision_tokens: int = 0             # vlm stub: prepended patch embeddings

    # norms / embeddings --------------------------------------------------------
    norm: str = "rmsnorm"                # rmsnorm|layernorm
    norm_eps: float = 1e-6
    tie_embeddings: bool = True
    embed_scale: bool = False            # gemma multiplies embeddings by sqrt(d)

    # numerics -------------------------------------------------------------------
    param_dtype: str = "bfloat16"
    dtype: str = "bfloat16"              # activation dtype

    # ------------------------------------------------------------------ derived
    @property
    def dh(self) -> int:
        return self.head_dim if self.head_dim is not None else self.d_model // self.n_heads

    @property
    def vocab_padded(self) -> int:
        return _round_up(self.vocab_size, self.vocab_round_to)

    @property
    def q_dim(self) -> int:
        return self.n_heads * self.dh

    @property
    def kv_dim(self) -> int:
        return self.n_kv_heads * self.dh

    @property
    def ssm_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.ssm_inner // self.ssm_head_dim

    def layer_specs(self) -> Tuple[Tuple[str, str], ...]:
        """Per-decoder-layer (mixer_kind, ffn_kind)."""
        if self.family == "ssm":
            # mamba2-370m interleaves SSD mixers only (d_ff=0 → no FFN block)
            ffn = "dense" if self.d_ff > 0 else "none"
            return tuple(("ssd", ffn) for _ in range(self.n_layers))
        if self.block_pattern:
            pat = self.block_pattern
            mix = [pat[i % len(pat)] for i in range(self.n_layers)]
            return tuple((m, "dense") for m in mix)
        ffn = "moe" if self.n_experts > 0 else "dense"
        mixer = "local_attn" if self.attn_window > 0 else "attn"
        return tuple((mixer, ffn) for _ in range(self.n_layers))

    def is_uniform(self) -> bool:
        specs = self.layer_specs()
        return all(s == specs[0] for s in specs)

    def mixer_kinds(self) -> Tuple[str, ...]:
        return tuple(m for m, _ in self.layer_specs())

    def n_attn_layers(self) -> int:
        return sum(1 for m in self.mixer_kinds() if m in ("attn", "local_attn"))

    def sub_quadratic(self) -> bool:
        """True if decode-time cache is bounded independent of seq_len."""
        kinds = set(self.mixer_kinds())
        return kinds <= {"ssd", "rglru", "local_attn"}

    # parameter counting (used by the memory model & roofline) ------------------
    def block_param_counts(self) -> Tuple[Tuple[int, ...], Tuple[int, ...]]:
        """(per-layer mixer params, per-layer ffn params), embeddings excluded."""
        mixers, ffns = [], []
        for mixer, ffn in self.layer_specs():
            if mixer in ("attn", "local_attn"):
                p = self.d_model * (self.q_dim + 2 * self.kv_dim)  # wqkv
                p += self.q_dim * self.d_model                       # wo
                if self.qkv_bias:
                    p += self.q_dim + 2 * self.kv_dim
                if self.qk_norm:
                    p += 2 * self.dh
            elif mixer == "rglru":
                w = self.rnn_width or self.d_model
                # in-proj (x,gate) + conv4 + RG-LRU gates (a, input gate) + out
                p = self.d_model * (2 * w) + 4 * w + 2 * w * w // 8 + w + w * self.d_model
            elif mixer == "ssd":
                di, hn = self.ssm_inner, self.ssm_heads
                p = self.d_model * (2 * di + 2 * self.ssm_state + hn)  # in_proj(zx) + B,C, dt
                p += self.ssm_conv_width * (di + 2 * self.ssm_state)   # conv
                p += hn + hn                                           # A_log, D
                p += di * self.d_model                                 # out
            else:
                p = 0
            p += self.d_model  # pre-norm scale
            mixers.append(p)

            if ffn == "dense":
                if self.activation in ("swiglu", "geglu"):
                    f = self.d_model * 2 * self.d_ff + self.d_ff * self.d_model
                else:
                    f = 2 * self.d_model * self.d_ff
                f += self.d_model
            elif ffn == "moe":
                f = self.n_experts * (self.d_model * 2 * self.d_ff + self.d_ff * self.d_model)
                f += self.d_model * self.n_experts  # router
                f += self.d_model
            else:
                f = 0
            ffns.append(f)
        return tuple(mixers), tuple(ffns)

    def embed_params(self) -> int:
        p = self.vocab_padded * self.d_model
        if not self.tie_embeddings:
            p += self.vocab_padded * self.d_model
        p += self.d_model  # final norm
        if self.is_encoder_decoder:
            # encoder stack params counted as mixer/ffn of the encoder
            m, f = self._encoder_block_params()
            p += self.n_encoder_layers * (m + f)
            p += self.n_audio_frames * self.d_model  # learned positions (stub frontend)
        return p

    def _encoder_block_params(self) -> Tuple[int, int]:
        m = self.d_model * (self.q_dim + 2 * self.kv_dim) + self.q_dim * self.d_model + self.d_model
        # whisper decoder also carries cross-attn per layer; counted in mixer below
        f = 2 * self.d_model * self.d_ff + self.d_model
        return m, f

    def total_params(self) -> int:
        m, f = self.block_param_counts()
        total = sum(m) + sum(f) + self.embed_params()
        if self.is_encoder_decoder:
            # decoder cross-attention (one per decoder layer)
            total += self.n_layers * (self.d_model * (self.q_dim + 2 * self.kv_dim)
                                      + self.q_dim * self.d_model + self.d_model)
        return total

    def active_params(self) -> int:
        """MoE: experts actually used per token (for MODEL_FLOPS = 6·N_active·D)."""
        if self.n_experts == 0:
            return self.total_params()
        m, _ = self.block_param_counts()
        act_ffn = self.n_layers * (self.moe_top_k *
                                   (self.d_model * 2 * self.d_ff + self.d_ff * self.d_model)
                                   + self.d_model * self.n_experts + self.d_model)
        return sum(m) + act_ffn + self.embed_params()

    def jnp_dtype(self):
        return jnp.dtype(self.dtype)

    def jnp_param_dtype(self):
        return jnp.dtype(self.param_dtype)

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    """One assigned input-shape cell."""
    name: str
    seq_len: int
    global_batch: int
    kind: str  # 'train' | 'prefill' | 'decode'


SHAPES: Tuple[ShapeConfig, ...] = (
    ShapeConfig("train_4k", 4096, 256, "train"),
    ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    ShapeConfig("decode_32k", 32768, 128, "decode"),
    ShapeConfig("long_500k", 524288, 1, "decode"),
)


def get_shape(name: str) -> ShapeConfig:
    for s in SHAPES:
        if s.name == name:
            return s
    raise KeyError(name)
