"""Model executors — the execution seam of the serving engine.

The engine decides *who* runs (scheduler) and *what shape* they run in
(pruning policy); a :class:`ModelExecutor` owns *how* the chosen masks
execute: slot-batched caches, compiled executable families, prefill
scattering, and the fused decode loop. PR 1 inlined all of this into
``RAPEngine``; extracting it means sharded serving is "swap the
executor", not "rewrite the engine".

Decode state is **device-resident** (DESIGN.md §5 "Horizon decode"):
groups keep tokens, positions, gates, and (paged) page-table rows as
device arrays that are updated *incrementally* at placement, eviction,
and page grants — never re-uploaded per step — and decode advances in
fused **horizons** of H tokens: one compiled ``lax.scan`` launch, one
``[B, H]`` token read-back. A warmed horizon performs zero host↔device
transfers between the launch and that read-back (pinned in
``tests/test_horizon.py`` under ``jax.transfer_guard``).

Executors:
  * :class:`LocalExecutor` — today's single-process path. Groups (one per
    structural bucket, or one gated group in masked mode) are additionally
    keyed by a power-of-two *cache length*, so a long request mints a new
    long-cache group instead of invalidating every compiled short one.
    Decode runs in dynamic batch buckets B ∈ {1, 2, 4, 8} (ROADMAP): the
    occupied slots are gathered into the smallest bucket that holds them,
    stepped H tokens, and scattered back, so a lightly loaded engine does
    not pay full-slot-count compute per token.
  * :class:`PagedExecutor` — physically paged KV execution (DESIGN.md §3
    "Paged KV"): requests own *pages* of a global KV pool
    (``repro.runtime.kv_pool.KVPool`` holds the page arrays), prefill
    writes KV straight into granted pages, and one fused horizon launch
    advances any mix of cache lengths through a per-request page table —
    no ``max_len × max_active`` slot caches, no pow2 cache-length groups,
    and page-granular (not slot-granular) internal fragmentation. Pages
    for the whole horizon are pre-granted in ONE bulk ``KVPool.extend``
    before the launch (the admission-time worst-case commitment
    guarantees it cannot fail), so no paging happens mid-loop. Serves
    both pruning modes: structural mode runs per-bucket compacted layer
    stacks over the SAME shared pool (a bucket with L' retained layers
    touches pool layers 0..L'-1 of its pages; see DESIGN.md §9).
  * :class:`ShardedExecutor` — mesh-resident serving (DESIGN.md §7
    "Sharded serving"): parameters placed with the production partition
    rules of ``repro.parallel.sharding`` (and a sharded decode-step
    lowering for cost analysis, ``launch/rap_sweep.py``), groups are
    :class:`ShardedSlotGroup` whose decode state lives sharded on the
    mesh — KV over slots (DP) and KV heads (TP), gates replicated — and
    whose horizon scan is ONE mesh-lowered executable per macro-tick,
    paying collectives once per H tokens. Masked mode only; structural
    sharded buckets are a ROADMAP item.

``LocalExecutor`` remains the reference backend: it serves every layout
(heterogeneous mixers keep per-request slot state) and both pruning modes,
and every other backend's token-equivalence is pinned against it by the
cross-executor conformance suite (``tests/test_executors.py``) — a new
executor only registers a fixture there.
"""
from __future__ import annotations

import dataclasses
import functools
import time
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import masks as masks_lib
from repro.models import attention, decoder
from repro.runtime.kv_pool import resolve_kv_dtype

__all__ = ["ModelExecutor", "SlotGroup", "LocalExecutor", "PagedExecutor",
           "PagedGroup", "ShardedExecutor", "ShardedSlotGroup",
           "chunk_widths"]


def chunk_widths(n_tokens: int, max_chunk: int) -> List[int]:
    """Split a prompt into power-of-two chunk widths for chunked prefill.

    Greedy largest-power-of-two-first: 13 tokens under an 8-token cap
    chunk as [8, 4, 1]. Every width is an exact power of two ≤ the cap,
    so the chunked-prefill executable set is bounded at log2(cap)+1
    widths per (batch, group) — and chunks are never padded, which is
    what keeps chunked prefill bitwise-identical to the monolithic pass
    (no garbage K/V ever lands in the cache)."""
    n = int(n_tokens)
    cap = int(max_chunk)
    if n < 1:
        raise ValueError(f"n_tokens must be >= 1, got {n_tokens!r}")
    if cap < 1:
        raise ValueError(f"max_chunk must be >= 1, got {max_chunk!r}")
    cap = 1 << (cap.bit_length() - 1)          # pow2 floor of the cap
    widths: List[int] = []
    while n > 0:
        c = min(cap, 1 << (n.bit_length() - 1))
        widths.append(c)
        n -= c
    return widths


@dataclasses.dataclass
class _PrefillTask:
    """One in-flight chunked prefill (``prefill_begin``/``prefill_step``).

    The request's slots are *reserved* in its group for the task's
    lifetime (they pad no decode bucket and admit no other request) and
    seated only when the final chunk completes. ``state`` is the
    backend's partial cache (Local: the request-sized attn cache the
    chunks accumulate into; Paged: None — chunks write straight into the
    pool's granted pages)."""
    group: Any
    slots: List[int]
    rid: str
    prompt: np.ndarray                # int32 [b, S]
    mask: Optional[np.ndarray]
    gates: Optional[dict]             # mask_to_gates(mask) for gated groups
    widths: List[int]                 # pow2 chunk widths, sum == S
    pos: int = 0                      # prompt tokens processed so far
    step: int = 0                     # chunks processed so far
    state: Any = None

    @property
    def done(self) -> bool:
        return self.pos >= self.prompt.shape[1]


@dataclasses.dataclass
class _InFlightHorizon:
    """A launched-but-unsynced fused decode horizon.

    ``decode_launch`` returns one; ``decode_finish`` performs the single
    device→host read-back. Occupancy is captured at launch so host work
    overlapped with the in-flight scan (admission may seat new requests
    into slots that were free/padding when the scan launched) cannot
    corrupt the finish-side bookkeeping."""
    group: Any
    horizon: int
    toks_dev: Any                     # device [width, horizon] tokens
    idx: Optional[List[int]]          # stepped slots (None = full width)
    occupants: List[Optional[str]]    # per stepped slot, at launch time
    new: bool                         # compiled a new executable


# Fused device-state updates. Placement/eviction touch four resident
# tensors each; issuing the column updates as eager ``.at[].set`` chains
# costs one dispatch (plus index-normalization work) per tensor per call,
# which the admission/completion profile is dominated by. One shared
# jitted executable per update kind replaces the chain with a single
# launch; donation makes the updates in-place.
@functools.partial(jax.jit, donate_argnums=(0, 1, 2, 3))
def _paged_place_upd(table, pos, tok, gates, sidx, rows, plen, first, cols):
    return (table.at[sidx].set(rows),
            pos.at[sidx].set(plen),
            tok.at[sidx].set(first),
            gates.at[:, :, sidx].set(cols[:, :, None]))


@functools.partial(jax.jit, donate_argnums=(0, 1, 2, 3))
def _paged_evict_upd(table, pos, tok, gates, sidx, scratch):
    return (table.at[sidx].set(scratch),
            pos.at[sidx].set(0),
            tok.at[sidx].set(0),
            gates.at[:, :, sidx].set(1.0))


@functools.partial(jax.jit, donate_argnums=(0,))
def _paged_grant_upd(table, rows, cols, vals):
    return table.at[rows, cols].set(vals)


def _slot_place_body(cache, tokens, req_cache, sidx, plen, first, cols,
                     gates):
    out = {}
    for k, v in cache.items():
        if k == "pos":
            out[k] = v.at[sidx].set(plen)
        else:
            out[k] = jax.tree.map(
                lambda big, small: big.at[:, sidx].set(small), v,
                req_cache[k])
    tokens = tokens.at[sidx, 0].set(first)
    if gates is not None:
        gates = gates.at[:, :, sidx].set(cols[:, :, None])
    return out, tokens, gates


# undecorated body kept separate: ShardedSlotGroup re-jits it with explicit
# output shardings so placement cannot silently re-shard the resident state
_slot_place_upd = jax.jit(_slot_place_body, donate_argnums=(0, 1, 7))


# distinct occupancy patterns a group may cache device index vectors for;
# a long adaptive serve cycles through unboundedly many patterns, so the
# cache evicts FIFO past the cap (each entry is a tiny int32 vector, but
# "tiny and immortal" is still a leak)
_IIDX_CACHE_CAP = 256


def _cached_iidx(cache: Dict[Tuple[int, ...], Any], idx: List[int]):
    """Device copy of a slot-index vector, cached by its pattern — the
    hot paths (horizon launches, placement, eviction) re-use the resident
    array instead of re-uploading the index list every call."""
    key = tuple(idx)
    dev = cache.get(key)
    if dev is None:
        if len(cache) >= _IIDX_CACHE_CAP:
            cache.pop(next(iter(cache)))
        dev = jnp.asarray(idx, jnp.int32)
        cache[key] = dev
    return dev


def _gate_cols(mask, gate_rows: Optional[np.ndarray]) -> np.ndarray:
    """A request's gate columns [2, Lg] for its host group: the keep-mask
    split into mixer/ffn rows and, for gated *compacted* buckets,
    restricted to the bucket's retained layers (``gate_rows`` — gates are
    indexed by compacted layout position, not original layer)."""
    m = np.asarray(mask, np.float32)
    L = m.shape[0] // 2
    gm, gf = m[:L], m[L:]
    if gate_rows is not None:
        gm, gf = gm[gate_rows], gf[gate_rows]
    return np.stack([gm, gf])


def _bucket_batch(occ: List[int], free: List[int], n_slots: int,
                  buckets: Sequence[int]) -> Optional[List[int]]:
    """Slot indices to step this iteration: the occupied slots padded with
    free ones up to the smallest bucket that holds them, or None for the
    full-width path. Padding uses *distinct free* slots so a scatter-back
    never writes one index twice; their compute is garbage but unobservable
    (slot rows are independent and re-seeded on placement)."""
    n = len(occ)
    for b in sorted(set(buckets)):
        if n <= b < n_slots:
            return occ + free[: b - n]
    return None


# ------------------------------------------------------------------- groups
class SlotGroup:
    """One slot-batched executable family sharing a cache.

    masked mode: a single group over the full params with per-slot gates.
    structural mode: one group per bucket (compacted params, gates absorbed
    into structure). Groups are minted per (bucket, cache_len).

    All decode state — the cache (including int32 [n_slots] positions),
    the per-slot seed tokens, and the [2, L, n_slots] gate tensor — lives
    on device. Placement and eviction touch only the affected columns via
    ``.at[...]`` updates; a horizon launch reads the resident arrays
    directly, so the per-token hot path performs no host→device uploads.
    """

    def __init__(self, key, params, layout, cfg_model, n_slots: int,
                 cache_len: int, kv_dtype, gated: bool,
                 mask: Optional[np.ndarray] = None,
                 gate_rows: Optional[np.ndarray] = None):
        self.key = key                # logical bucket key ("masked" | tuple)
        self.params = params
        self.layout = layout
        self.mask = mask              # the keep-mask that minted this bucket
        # gated compacted buckets (bucket quantization): the original
        # layer index behind each layout row — request masks restrict to
        # these rows before becoming per-slot gate columns
        self.gate_rows = gate_rows
        self.n_slots = n_slots
        self.cache_len = cache_len
        self.gated = gated
        self.occupants: List[Optional[str]] = [None] * n_slots
        # slots held by an in-flight chunked prefill: not yet occupied
        # (no decode steps them) but not free either (no other admission
        # may claim them). Cleared by place()/evict().
        self.reserved: set = set()
        self.cache = decoder.init_cache(cfg_model, n_slots, cache_len,
                                        layout, kv_dtype)
        self.cache["pos"] = jnp.zeros((n_slots,), jnp.int32)
        self.tokens = jnp.zeros((n_slots, 1), jnp.int32)
        if gated:
            # gates are indexed by layout position: a compacted gated
            # bucket carries len(layout) gate rows, not n_layers
            Lg = len(layout) if layout is not None else cfg_model.n_layers
            self._gates_dev = jnp.ones((2, Lg, n_slots), jnp.float32)
        self._mcfg = cfg_model
        # fused horizon executables, one jit per horizon length (batch
        # widths retrace inside jit); compile accounting per (width, H)
        self._hfns: Dict[int, Any] = {}
        self._compiled_batches: set = set()
        # device copies of the bucket gather/scatter index vectors, keyed
        # by the occupancy pattern — steady-state horizons re-use them
        # instead of re-uploading the index list every launch
        self._iidx_cache: Dict[Tuple[int, ...], Any] = {}

    # ----------------------------------------------------------- occupancy
    def free_slots(self) -> List[int]:
        return [i for i, o in enumerate(self.occupants)
                if o is None and i not in self.reserved]

    def occupied_slots(self) -> List[int]:
        return [i for i, o in enumerate(self.occupants) if o is not None]

    def occupied(self) -> bool:
        return any(o is not None for o in self.occupants)

    def place(self, rid: str, slots: List[int], req_cache: dict,
              mask: Optional[np.ndarray], prompt_len: int,
              first: np.ndarray) -> None:
        """Write a freshly prefilled request cache into ``slots`` — cache
        rows, positions, seed tokens, and (masked mode) ONLY the placed
        gate columns, all in one fused jitted update. Re-uploading the
        full [2, L, n_slots] gate tensor per placement would scale
        placement cost with slot count, not request size."""
        self.reserved.difference_update(slots)
        for s in slots:
            self.occupants[s] = rid
        cols = None
        if self.gated and mask is not None:
            cols = _gate_cols(mask, self.gate_rows)
        # mask=None on a gated group skips the gate write (the historical
        # contract): the fused update traces a no-gate variant rather
        # than scattering a None
        gates = self._gates_dev if cols is not None else None
        self.cache, self.tokens, gates = self._place_fn(cols is not None)(
            self.cache, self.tokens, req_cache, self._iidx(slots),
            int(prompt_len), np.asarray(first, np.int32), cols, gates)
        if cols is not None:
            self._gates_dev = gates

    def _place_fn(self, with_gates: bool):
        """The fused placement executable — mesh-resident subclasses
        override to pin output shardings to the group's layout."""
        return _slot_place_upd

    def evict(self, slots: List[int]) -> None:
        self.reserved.difference_update(slots)
        for s in slots:
            self.occupants[s] = None

    # -------------------------------------------------------------- decode
    def _decode_batch(self, buckets: Sequence[int]) -> Optional[List[int]]:
        return _bucket_batch(self.occupied_slots(), self.free_slots(),
                             self.n_slots, buckets)

    def _full_width_horizon(self, horizon: int):
        """Un-jitted full-width fused horizon:
        ``(p, cache, tok[, gates]) → (toks [B, H], cache', last [B, 1])``.
        Shared between the local jit and the sharded re-jit
        (:class:`ShardedSlotGroup` pins ``out_shardings`` on it), so the
        horizon step itself is defined exactly once."""
        h = int(horizon)
        cfg, layout_c, gated = self._mcfg, self.layout, self.gated
        if gated:
            def fn(p, cache, tok, gates):
                toks, cache = decoder.decode_horizon(
                    p, cfg, cache, tok, h,
                    gates={"mixer": gates[0], "ffn": gates[1]},
                    layout=layout_c)
                return toks, cache, toks[:, -1:]
        else:
            def fn(p, cache, tok):
                toks, cache = decoder.decode_horizon(p, cfg, cache, tok, h,
                                                     layout=layout_c)
                return toks, cache, toks[:, -1:]
        return fn

    def _horizon_fn(self, horizon: int, bucketed: bool):
        """Jitted fused horizon, one executable family per (H, bucketed).
        The bucketed variant takes the *full-width* resident state plus a
        device index vector and performs the gather → H-step scan →
        scatter-back entirely inside the compiled call — eager indexing
        would smuggle a scalar host→device upload per launch (the index
        normalization constant), which the transfer-guard test forbids."""
        h = int(horizon)
        key = (h, bool(bucketed))
        if key not in self._hfns:
            cfg, layout_c, gated = self._mcfg, self.layout, self.gated

            if not bucketed:
                fn = jax.jit(self._full_width_horizon(h),
                             donate_argnums=(1, 2))
            else:
                def scan_h(p, cache, tok, gates):
                    g = ({"mixer": gates[0], "ffn": gates[1]} if gated
                         else None)
                    return decoder.decode_horizon(p, cfg, cache, tok, h,
                                                  gates=g, layout=layout_c)

                def gather_scan_scatter(p, cache, tok, gates, iidx):
                    sub = {k: (v[iidx] if k == "pos"
                               else jax.tree.map(lambda a: a[:, iidx], v))
                           for k, v in cache.items()}
                    toks, sub = scan_h(p, sub, tok[iidx],
                                       gates[:, :, iidx]
                                       if gates is not None else None)
                    out = {}
                    for k, v in sub.items():
                        if k == "pos":
                            out[k] = cache[k].at[iidx].set(v)
                        else:
                            out[k] = jax.tree.map(
                                lambda full, small, _i=iidx:
                                full.at[:, _i].set(small), cache[k], v)
                    tok = tok.at[iidx].set(toks[:, -1:])
                    return toks, out, tok

                if gated:
                    @functools.partial(jax.jit, donate_argnums=(1, 2))
                    def fn(p, cache, tok, gates, iidx):
                        return gather_scan_scatter(p, cache, tok, gates,
                                                   iidx)
                else:
                    @functools.partial(jax.jit, donate_argnums=(1, 2))
                    def fn(p, cache, tok, iidx):
                        return gather_scan_scatter(p, cache, tok, None,
                                                   iidx)
            self._hfns[key] = fn
        return self._hfns[key]

    def _iidx(self, idx: List[int]):
        return _cached_iidx(self._iidx_cache, idx)

    def launch_horizon(self, horizon: int,
                       buckets: Sequence[int] = ()) -> Tuple[Any,
                                                             Optional[List[int]],
                                                             bool]:
        """Device phase of a fused H-token decode: pick the batch bucket,
        gather the stepped slots' state (on device), launch ONE compiled
        ``lax.scan`` executable that advances them ``horizon`` tokens, and
        fold the updated state back into the resident arrays. Returns
        (device toks [width, horizon], stepped slot ids or None for full
        width, new-compile flag). Once an occupancy pattern and executable
        are warm this performs zero host↔device transfers — the caller's
        single ``np.asarray`` on the returned tokens is the only sync."""
        idx = self._decode_batch(buckets) if buckets else None
        width = self.n_slots if idx is None else len(idx)
        key = (width, int(horizon))
        new = key not in self._compiled_batches
        self._compiled_batches.add(key)
        fn = self._horizon_fn(horizon, bucketed=idx is not None)
        args = (self.params, self.cache, self.tokens)
        if self.gated:
            args += (self._gates_dev,)
        if idx is not None:
            args += (self._iidx(idx),)
        toks, cache, last = fn(*args)
        self.cache = cache
        self.tokens = last
        return toks, idx, new

    def decode_horizon(self, horizon: int,
                       buckets: Sequence[int] = ()) -> Tuple[np.ndarray,
                                                             bool]:
        """Advance every occupied slot ``horizon`` tokens; returns
        ([n_slots, horizon] tokens — unstepped rows are zero/garbage — and
        whether this call compiled a new executable)."""
        toks_dev, idx, new = self.launch_horizon(horizon, buckets)
        if idx is None:
            return np.asarray(toks_dev), new
        out = np.zeros((self.n_slots, int(horizon)), np.int32)
        out[np.asarray(idx)] = np.asarray(toks_dev)
        return out, new

    def decode_once(self, buckets: Sequence[int] = ()) -> Tuple[np.ndarray,
                                                                bool]:
        """Single-token compatibility wrapper over :meth:`decode_horizon`."""
        toks, new = self.decode_horizon(1, buckets)
        return toks[:, 0], new


# ---------------------------------------------------------------- protocol
class ModelExecutor:
    """Execution backend protocol for the engine.

    ``group_for`` resolves a keep-mask (+ cache length) to the slot group
    that will host the request; ``prefill_into`` seats a prefilled request;
    ``decode_horizon`` advances one group H tokens in one fused launch
    (``decode`` is the H=1 compatibility form). ``compile_events`` counts
    new executables (prefill shapes + decode (batch, horizon) buckets);
    ``launch_s`` accumulates wall time spent inside compiled-executable
    launches and their read-backs, so benchmarks can separate host
    orchestration overhead from device compute.

    ``paged`` marks backends whose KV lives in a :class:`KVPool`'s physical
    page arrays — the engine switches admission to the token-granular pool
    API and calls ``bind_pool`` per run. ``kv_utilization`` reports
    (used_bytes, physical_bytes) of the live KV storage so benchmarks can
    measure *physical* internal fragmentation, not just the ledger's."""

    compile_events: int = 0
    launch_s: float = 0.0
    paged: bool = False

    def group_for(self, mask: np.ndarray, cache_len: int) -> SlotGroup:
        raise NotImplementedError

    def prefill_into(self, group: SlotGroup, slots: List[int], rid: str,
                     prompt: np.ndarray, mask: np.ndarray) -> np.ndarray:
        raise NotImplementedError

    # ------------------------------------------------- chunked prefill seam
    def supports_chunked_prefill(self, group: SlotGroup) -> bool:
        """Whether ``prefill_begin``/``prefill_step`` work for this group.
        Default False: backends without a chunked path fall back to
        monolithic ``prefill_into`` transparently."""
        return False

    def prefill_begin(self, group: SlotGroup, slots: List[int], rid: str,
                      prompt: np.ndarray, mask: np.ndarray, *,
                      max_chunk: int) -> _PrefillTask:
        """Reserve ``slots`` and open a chunked prefill over ``prompt``
        (pow2 widths per :func:`chunk_widths`). Advance it one chunk at a
        time with :meth:`prefill_step`."""
        raise NotImplementedError

    def prefill_step(self, task: _PrefillTask) -> Optional[np.ndarray]:
        """Process the task's next chunk. Returns None while the prompt is
        incomplete; on the final chunk, seats the request into its slots
        (``place``) and returns the first sampled tokens ``[b]`` — the
        same contract as monolithic ``prefill_into``'s return."""
        raise NotImplementedError

    # ------------------------------------------------- decode launch/finish
    def decode_launch(self, group: SlotGroup,
                      horizon: int) -> "_InFlightHorizon":
        """Dispatch one fused H-token decode for ``group`` and return
        WITHOUT syncing — JAX async dispatch means the host is free to do
        scheduling/admission work while the scan runs on device. Pair with
        :meth:`decode_finish` for the read-back."""
        raise NotImplementedError

    def decode_finish(self,
                      launch: "_InFlightHorizon") -> Tuple[np.ndarray, bool]:
        """Block on ``launch``'s device tokens (the tick's single sync) and
        fold them back to host form: ([n_slots, horizon] tokens,
        new-compile flag). Slots whose occupant changed since launch (host
        work seated a new request into a then-free padding slot) are left
        untouched."""
        raise NotImplementedError

    def decode_horizon(self, group: SlotGroup,
                       horizon: int) -> Tuple[np.ndarray, bool]:
        """Advance every occupied slot of ``group`` by ``horizon`` tokens;
        returns ([n_slots, horizon] next tokens, new-compile flag).
        Equivalent to ``decode_finish(decode_launch(...))`` with no host
        work in between."""
        raise NotImplementedError

    def decode(self, group: SlotGroup) -> Tuple[np.ndarray, bool]:
        toks, new = self.decode_horizon(group, 1)
        return toks[:, 0], new

    # ---------------------------------------------------- preemption seam
    def spill_state(self, group: SlotGroup, slots: List[int]) -> dict:
        """Host-side copy of everything the executor holds for the request
        resident in ``slots`` — enough for :meth:`restore_state` to reseat
        it bitwise after its device memory was reclaimed. Called BEFORE the
        group eviction / pool spill; pairs with ``KVPool.spill`` (which
        carries the physical page contents on paged backends)."""
        raise NotImplementedError

    def restore_state(self, group: SlotGroup, slots: List[int], rid: str,
                      state: dict, mask: Optional[np.ndarray],
                      rows: Optional[List[List[int]]] = None) -> None:
        """Reseat a previously spilled request into ``slots`` of ``group``
        from its :meth:`spill_state` snapshot. ``rows`` carries the
        re-granted page ids on paged backends (``KVPool.restore``'s
        return); slot backends reconstruct from the snapshot alone. The
        reseated decode state is exactly what an unpreempted run would
        hold, so the continued token stream is bitwise-identical."""
        raise NotImplementedError

    def groups(self) -> List[SlotGroup]:
        raise NotImplementedError

    def set_max_active(self, n_slots: int) -> None:
        raise NotImplementedError

    def drop_groups(self) -> None:
        """Invalidate every compiled group (capacity reshape)."""
        raise NotImplementedError

    def evict_all(self) -> None:
        for g in self.groups():
            g.evict(list(range(g.n_slots)))

    def kv_utilization(self) -> Tuple[float, float]:
        """(used_bytes, physical_bytes) of live KV storage; (0, 0) when the
        backend does not track it. ``used`` counts tokens actually written
        by resident requests; ``physical`` counts the allocated arrays
        backing them — their ratio is the *measured* (not analytical)
        internal fragmentation."""
        return 0.0, 0.0

    def stats(self) -> Dict[str, int]:
        return {"compile_events": self.compile_events}


# ------------------------------------------------------------------- local
class LocalExecutor(ModelExecutor):
    """Single-process slot-batched execution (the PR 1 path, extracted),
    plus dynamic decode-batch buckets, per-cache-length groups, and fused
    horizon decode."""

    def __init__(self, model, params, *, mode: str = "masked",
                 max_active: int = 8, kv_dtype=None,
                 decode_buckets: Sequence[int] = (1, 2, 4, 8),
                 bucket_quant: str = "none", max_groups: int = 0):
        if mode not in ("masked", "structural"):
            raise ValueError(f"unknown mode {mode!r}")
        if bucket_quant not in ("none", "layer", "pow2"):
            raise ValueError(f"unknown bucket_quant {bucket_quant!r}; "
                             "expected none|layer|pow2")
        self.model = model
        self.mcfg = model.cfg
        self.params = params
        self.mode = mode
        self.bucket_quant = bucket_quant
        self.max_groups = int(max_groups)   # structural group cap, 0 = ∞
        self.max_active = int(max_active)
        # canonical precision names ("fp32"/"bf16"/"int8"/"fp8") resolve to
        # their storage dtype so --kv-dtype works on the slot path too; raw
        # dtype objects (the historical API) pass through unchanged
        _, _store, _, _ = resolve_kv_dtype(kv_dtype)
        self.kv_dtype = _store if _store is not None else kv_dtype
        self.decode_buckets = tuple(int(b) for b in decode_buckets or ())
        self.compile_events = 0
        self.launch_s = 0.0
        # structural groups are keyed by (gather_key, cache_len) — the
        # EXACT parameter rows they decode with — never by bucket_key
        # alone, which aliases different-layer drops onto one signature
        self._groups: Dict[Tuple, SlotGroup] = {}
        self._prefill_fns: Dict[Tuple, Any] = {}
        # one device-resident compacted stack per gather signature, shared
        # by every cache-length group of that bucket and refcounted so it
        # frees when its last group drops: gather_key -> [params, layout,
        # refs]
        self._resident: Dict[Tuple, list] = {}

    # ------------------------------------------------------------ capacity
    def _invalidate(self) -> None:
        """THE invalidation path: groups, their prefill executables, and
        the resident compacted stacks drop together. Any key kept behind a
        cleared group dict would pin dead XLA executables (or device
        params) for the executor's lifetime — capacity reshapes and bucket
        churn must not be able to strand them."""
        self._groups.clear()
        self._prefill_fns.clear()
        self._resident.clear()

    def set_max_active(self, n_slots: int) -> None:
        """Changing the slot count changes every cache's slot axis — the
        full compiled state drops (one unified invalidation path with
        :meth:`drop_groups`; re-minting a handful of prefill executables
        on the next admission is cheaper than auditing which stale keys
        are still reachable)."""
        if int(n_slots) == self.max_active:
            return
        self.max_active = int(n_slots)
        self._invalidate()

    def drop_groups(self) -> None:
        self._invalidate()

    # -------------------------------------------------------------- groups
    def groups(self) -> List[SlotGroup]:
        return list(self._groups.values())

    def _resident_acquire(self, rkey: Tuple, qmask: np.ndarray):
        """(params, layout) for a gather signature, minting the compacted
        device stack on first use and bumping its refcount."""
        ent = self._resident.get(rkey)
        if ent is None:
            small, layout = masks_lib.compact_params(self.params, self.mcfg,
                                                     qmask)
            ent = self._resident[rkey] = [small, layout, 0]
        ent[2] += 1
        return ent[0], ent[1]

    def _resident_release(self, rkey: Tuple) -> None:
        ent = self._resident.get(rkey)
        if ent is None:
            return
        ent[2] -= 1
        if ent[2] <= 0:
            del self._resident[rkey]

    def _drop_group(self, gkey: Tuple) -> None:
        """Drop one structural group: release its resident-params ref and,
        when it was the last group of its (signature, cache_len), the
        prefill executables compiled for that family."""
        g = self._groups.pop(gkey)
        self._resident_release(gkey[0])
        if not any(og.key == g.key and og.cache_len == g.cache_len
                   for og in self._groups.values()):
            dead = [k for k in self._prefill_fns
                    if (k[0] == g.key and k[1] == g.cache_len)
                    or (k[0] == "chunk" and k[1] == g.key
                        and k[2] == g.cache_len)]
            for k in dead:
                del self._prefill_fns[k]

    def _maybe_evict_structural(self) -> None:
        """Enforce the structural-group cap before minting a new group:
        evict idle (unoccupied, unreserved) structural groups in LRU
        order. Busy groups are never evicted — under a cap smaller than
        the working set the dict temporarily overshoots instead."""
        if self.max_groups <= 0:
            return
        n_struct = sum(1 for k in self._groups if k[0] != "masked")
        while n_struct >= self.max_groups:
            idle = [k for k, g in self._groups.items()
                    if k[0] != "masked" and not g.occupied()
                    and not g.reserved]
            if not idle:
                break
            self._drop_group(idle[0])
            n_struct -= 1

    def group_for(self, mask: np.ndarray, cache_len: int) -> SlotGroup:
        if self.mode == "masked":
            key = "masked"
            gkey = (key, cache_len)
            if gkey not in self._groups:
                self._groups[gkey] = SlotGroup(
                    key, self.params, None, self.mcfg, self.max_active,
                    cache_len, self.kv_dtype, gated=True)
            return self._groups[gkey]
        # bucket quantization first (identity under "none"), then key the
        # group by the exact gather indices: two masks dropping DIFFERENT
        # layers share a bucket_key (by design — one compiled family) but
        # must never share compacted params
        qmask = masks_lib.quantize_mask(self.mcfg, mask, self.bucket_quant)
        rkey = masks_lib.gather_key(self.mcfg, qmask)
        gkey = (rkey, cache_len)
        group = self._groups.get(gkey)
        if group is not None:
            self._groups[gkey] = self._groups.pop(gkey)   # LRU touch
            return group
        self._maybe_evict_structural()
        small, layout = self._resident_acquire(rkey, qmask)
        gated = self.bucket_quant != "none"
        # group.mask is engine-facing sticky-affinity metadata: store the
        # exact MINTING mask, not qmask — a rounded-up bucket mask would
        # make bucket affinity adopt a less-pruned (up to dense) decision,
        # diverging quantized runs from unquantized ones. Per-request
        # masks ride the slot gates, so correctness never reads this.
        group = SlotGroup(
            masks_lib.bucket_key(self.mcfg, qmask), small, layout,
            self.mcfg, self.max_active, cache_len, self.kv_dtype,
            gated=gated, mask=np.array(mask, copy=True),
            gate_rows=(masks_lib.keep_rows(self.mcfg, qmask) if gated
                       else None))
        self._groups[gkey] = group
        return group

    # ------------------------------------------------------------- prefill
    def _prefill_fn(self, group: SlotGroup, b: int, S: int):
        key = (group.key, group.cache_len, b, S)
        if key not in self._prefill_fns:
            cfg, max_len = self.mcfg, group.cache_len
            kv_dtype, layout = self.kv_dtype, group.layout
            if group.gated:
                # same-signature buckets share this executable: their
                # (compacted) layouts are identical tuples and the params
                # arrive as jit arguments, never closure constants
                @jax.jit
                def fn(p, tokens, gm, gf):
                    return decoder.prefill(p, cfg, tokens, max_len,
                                           gates={"mixer": gm, "ffn": gf},
                                           layout=layout, kv_dtype=kv_dtype)
            else:
                @jax.jit
                def fn(p, tokens):
                    return decoder.prefill(p, cfg, tokens, max_len,
                                           layout=layout, kv_dtype=kv_dtype)
            self._prefill_fns[key] = fn
            self.compile_events += 1
        return self._prefill_fns[key]

    def prefill_into(self, group: SlotGroup, slots: List[int], rid: str,
                     prompt: np.ndarray, mask: np.ndarray) -> np.ndarray:
        """Prefill the request and seat it; returns token #1 per row [b]."""
        b, S = prompt.shape
        tokens = jnp.asarray(prompt, jnp.int32)
        fn = self._prefill_fn(group, b, S)
        t0 = time.perf_counter()
        if group.gated:
            cols = _gate_cols(mask, group.gate_rows)
            logits, cache = fn(group.params, tokens, cols[0], cols[1])
        else:
            logits, cache = fn(group.params, tokens)
        first = np.asarray(jnp.argmax(logits, axis=-1).astype(jnp.int32))
        self.launch_s += time.perf_counter() - t0
        cache.pop("pos")
        group.place(rid, slots, cache, mask if group.gated else None, S,
                    first)
        return first

    # ----------------------------------------------------- chunked prefill
    def supports_chunked_prefill(self, group: SlotGroup) -> bool:
        """Chunked prefill resumes a positional KV write frontier — only
        uniform all-attention layouts have one (recurrent/SSD state can't
        be re-entered mid-prompt)."""
        layout = group.layout or decoder.default_layout(self.mcfg)
        return bool(layout) and decoder._is_uniform(layout) \
            and layout[0].mixer == "attn"

    def _chunk_fn(self, group: SlotGroup, b: int, C: int):
        """Jitted one-chunk prefill step, keyed by chunk *width* only (the
        chunk's absolute offset is a traced int32 scalar): a prompt split
        into pow2 widths reuses log2(cap)+1 executables per (group, b)
        regardless of prompt length or how far along the chunk sits."""
        key = ("chunk", group.key, group.cache_len, b, C)
        if key not in self._prefill_fns:
            cfg, layout = self.mcfg, group.layout
            if group.gated:
                @functools.partial(jax.jit, donate_argnums=(1,))
                def fn(p, attn, tokens, start, gm, gf):
                    logits, cache = decoder.prefill_chunk(
                        p, cfg, {"attn": attn}, tokens, start,
                        gates={"mixer": gm, "ffn": gf}, layout=layout)
                    return logits, cache["attn"]
            else:
                @functools.partial(jax.jit, donate_argnums=(1,))
                def fn(p, attn, tokens, start):
                    logits, cache = decoder.prefill_chunk(
                        p, cfg, {"attn": attn}, tokens, start,
                        layout=layout)
                    return logits, cache["attn"]
            self._prefill_fns[key] = fn
            self.compile_events += 1
        return self._prefill_fns[key]

    def prefill_begin(self, group: SlotGroup, slots: List[int], rid: str,
                      prompt: np.ndarray, mask: np.ndarray, *,
                      max_chunk: int) -> _PrefillTask:
        """Open a chunked prefill: reserve the slots and mint the
        request-sized partial cache the chunks accumulate into (placed
        into the group only when the last chunk lands)."""
        prompt = np.asarray(prompt, np.int32)
        b, S = prompt.shape
        attn = decoder.init_cache(self.mcfg, b, group.cache_len,
                                  group.layout, self.kv_dtype)["attn"]
        group.reserved.update(slots)
        gates = None
        if group.gated:
            cols = _gate_cols(mask, group.gate_rows)
            gates = {"mixer": cols[0], "ffn": cols[1]}
        return _PrefillTask(group=group, slots=list(slots), rid=rid,
                            prompt=prompt, mask=mask, gates=gates,
                            widths=chunk_widths(S, max_chunk), state=attn)

    def prefill_step(self, task: _PrefillTask) -> Optional[np.ndarray]:
        group = task.group
        b, S = task.prompt.shape
        c = task.widths[task.step]
        tokens = jnp.asarray(task.prompt[:, task.pos:task.pos + c],
                             jnp.int32)
        fn = self._chunk_fn(group, b, c)
        t0 = time.perf_counter()
        if group.gated:
            logits, task.state = fn(group.params, task.state, tokens,
                                    np.int32(task.pos),
                                    task.gates["mixer"], task.gates["ffn"])
        else:
            logits, task.state = fn(group.params, task.state, tokens,
                                    np.int32(task.pos))
        task.pos += c
        task.step += 1
        if not task.done:
            self.launch_s += time.perf_counter() - t0
            return None
        first = np.asarray(jnp.argmax(logits, axis=-1).astype(jnp.int32))
        self.launch_s += time.perf_counter() - t0
        group.place(task.rid, task.slots, {"attn": task.state},
                    task.mask if group.gated else None, S, first)
        task.state = None
        return first

    # ---------------------------------------------------- preemption seam
    def spill_state(self, group: SlotGroup, slots: List[int]) -> dict:
        """Gather the request's slot-cache rows (every cache leaf,
        positions, seed tokens) to host arrays. The gather reuses the
        group's cached device index vector; ``np.asarray`` round-trips
        f32/bf16/int8 exactly, so reseating is bitwise. Works unchanged on
        mesh-resident groups — the host copy implicitly gathers shards."""
        iidx = group._iidx(list(slots))
        cache = {}
        for k, v in group.cache.items():
            if k == "pos":
                continue
            cache[k] = jax.tree.map(lambda a: np.asarray(a[:, iidx]), v)
        pos = np.asarray(group.cache["pos"])
        return {"cache": cache,
                # one request's rows share one position (placed together,
                # stepped together)
                "pos": int(pos[slots[0]]),
                "first": np.asarray(group.tokens)[np.asarray(slots), 0]}

    def restore_state(self, group: SlotGroup, slots: List[int], rid: str,
                      state: dict, mask: Optional[np.ndarray],
                      rows: Optional[List[List[int]]] = None) -> None:
        """Reseat via the ordinary fused placement update: the snapshot's
        cache rows have the same shapes a monolithic prefill produces, so
        this reuses the compiled placement executable (and, on sharded
        groups, its pinned output shardings)."""
        group.place(rid, list(slots), state["cache"],
                    mask if group.gated else None, state["pos"],
                    state["first"])

    # -------------------------------------------------------------- decode
    def decode_launch(self, group: SlotGroup,
                      horizon: int) -> _InFlightHorizon:
        t0 = time.perf_counter()
        toks_dev, idx, new = group.launch_horizon(horizon,
                                                  self.decode_buckets)
        self.launch_s += time.perf_counter() - t0
        if new:
            self.compile_events += 1
        occ = (list(group.occupants) if idx is None
               else [group.occupants[s] for s in idx])
        return _InFlightHorizon(group=group, horizon=int(horizon),
                                toks_dev=toks_dev, idx=idx, occupants=occ,
                                new=new)

    def decode_finish(self,
                      launch: _InFlightHorizon) -> Tuple[np.ndarray, bool]:
        t0 = time.perf_counter()
        nxt = np.asarray(launch.toks_dev)  # the single device→host sync
        self.launch_s += time.perf_counter() - t0
        if launch.idx is None:
            return nxt, launch.new
        out = np.zeros((launch.group.n_slots, launch.horizon), np.int32)
        out[np.asarray(launch.idx)] = nxt
        return out, launch.new

    def decode_horizon(self, group: SlotGroup,
                       horizon: int) -> Tuple[np.ndarray, bool]:
        return self.decode_finish(self.decode_launch(group, horizon))

    # ---------------------------------------------------------- utilization
    def kv_utilization(self) -> Tuple[float, float]:
        """Slot caches are dense ``[n_slots, cache_len]`` arrays: physical
        bytes exist for every minted group whether or not its slots are
        occupied, and an occupied slot pins ``cache_len`` tokens while using
        only its current position. Only attention KV (the per-token state)
        is counted; fixed-size recurrent state is excluded from both
        sides."""
        used = phys = 0.0
        for g in self.groups():
            entry = g.cache.get("attn")
            if entry is None:     # windowed/recurrent state is fixed-size
                continue
            attn_bytes = sum(int(v.size) * v.dtype.itemsize
                             for v in entry.values())
            if attn_bytes == 0:
                continue
            phys += attn_bytes
            occ = g.occupied_slots()
            if occ:
                per_tok = attn_bytes / (g.n_slots * g.cache_len)
                pos = np.asarray(g.cache["pos"])[np.asarray(occ)]
                # a just-finished slot may have over-advanced inside its
                # final horizon (truncated tokens); its cache writes past
                # cache_len were dropped, so clamp the used-token count
                pos = np.minimum(pos, g.cache_len)
                used += float(pos.sum()) * per_tok
        return used, phys

    # --------------------------------------------------------------- stats
    def stats(self) -> Dict[str, int]:
        return {
            "groups": len(self._groups),
            # distinct parameter gathers resident — NOT (gather, cache_len)
            # entries, which pow2 length bucketing would overcount
            "structural_buckets": len({k for k, _ in self._groups
                                       if k != "masked"}),
            # distinct compiled families (bucket signatures): what bucket
            # quantization bounds — many gathers may share one signature
            "bucket_signatures": len({g.key for g in self._groups.values()
                                      if g.key != "masked"}),
            "resident_param_stacks": len(self._resident),
            "prefill_executables": len(self._prefill_fns),
            "masked_prefill_executables": sum(
                1 for k in self._prefill_fns if k[0] == "masked"),
            "compile_events": self.compile_events,
        }


# ------------------------------------------------------------------- paged
class PagedGroup:
    """One paged executable family: occupancy + page tables, no slot cache.

    Satisfies the slice of the ``SlotGroup`` surface the engine touches
    (``free_slots`` / ``occupied_slots`` / ``occupied`` / ``evict`` /
    ``n_slots`` / ``key`` / ``mask``). KV lives in the bound pool's page
    arrays; this object owns the per-slot decode state around them —
    int32 page-table rows, write positions, next tokens, and gates — as
    **device-resident** arrays (``table_dev``/``pos_dev``/``tokens_dev``/
    ``gates_dev``) updated incrementally at placement, eviction, and page
    grants, plus host numpy mirrors (``table``/``pos``/``tokens``) for
    the engine's occupancy bookkeeping and utilization sampling."""

    def __init__(self, cfg_model, n_slots: int, max_row_pages: int,
                 scratch_page: int, *, key="paged", mask=None, layout=None,
                 params=None, gate_rows: Optional[np.ndarray] = None):
        self.key = key                 # "paged" | structural bucket signature
        self.mask = mask               # structural: the bucket's keep-mask
        self.layout = layout           # structural: compacted LayerSlots
        self.params = params           # structural: compacted param stack
        self.gate_rows = gate_rows     # structural: original rows per slot
        self.cache_len = 0             # no dense cache — pages grow per token
        self.n_slots = n_slots
        self.max_row_pages = max_row_pages
        self.scratch_page = scratch_page
        self.occupants: List[Optional[str]] = [None] * n_slots
        # slots held by an in-flight chunked prefill (see SlotGroup.reserved)
        self.reserved: set = set()
        # padded decode rows write their garbage KV into the scratch page
        self.table = np.full((n_slots, max_row_pages), scratch_page, np.int32)
        self.pos = np.zeros((n_slots,), np.int32)
        self.tokens = np.zeros((n_slots,), np.int32)
        # gates are indexed by layout position (see SlotGroup)
        Lg = len(layout) if layout is not None else cfg_model.n_layers
        self.table_dev = jnp.asarray(self.table)
        self.pos_dev = jnp.asarray(self.pos)
        self.tokens_dev = jnp.asarray(self.tokens)
        self.gates_dev = jnp.ones((2, Lg, n_slots), jnp.float32)
        self._iidx_cache: Dict[Tuple[int, ...], Any] = {}

    def free_slots(self) -> List[int]:
        return [i for i, o in enumerate(self.occupants)
                if o is None and i not in self.reserved]

    def occupied_slots(self) -> List[int]:
        return [i for i, o in enumerate(self.occupants) if o is not None]

    def occupied(self) -> bool:
        return any(o is not None for o in self.occupants)

    def iidx(self, idx: List[int]):
        return _cached_iidx(self._iidx_cache, idx)

    def place(self, rid: str, slots: List[int], rows_np: np.ndarray,
              prompt_len: int, first: np.ndarray, gm: np.ndarray,
              gf: np.ndarray) -> None:
        """Seat a prefilled request: host mirrors plus ONE fused jitted
        update writing the placed rows of every resident tensor (nothing
        is re-uploaded beyond the new rows themselves)."""
        npg = rows_np.shape[1]
        full_rows = np.full((len(slots), self.max_row_pages),
                            self.scratch_page, np.int32)
        full_rows[:, :npg] = rows_np
        self.reserved.difference_update(slots)
        for i, s in enumerate(slots):
            self.occupants[s] = rid
            self.table[s] = full_rows[i]
            self.pos[s] = prompt_len
            self.tokens[s] = first[i]
        cols = np.stack([np.asarray(gm, np.float32),
                         np.asarray(gf, np.float32)])
        (self.table_dev, self.pos_dev, self.tokens_dev,
         self.gates_dev) = _paged_place_upd(
            self.table_dev, self.pos_dev, self.tokens_dev, self.gates_dev,
            self.iidx(slots), full_rows, int(prompt_len),
            np.asarray(first, np.int32), cols)

    def grant_pages(self, entries: List[Tuple[int, int, int]]) -> None:
        """Extend page-table rows with freshly granted pages:
        ``entries`` = (slot, column, page id). One fused scatter updates
        the device table; the host mirror tracks it."""
        if not entries:
            return
        rows = np.asarray([e[0] for e in entries], np.int32)
        cols = np.asarray([e[1] for e in entries], np.int32)
        vals = np.asarray([e[2] for e in entries], np.int32)
        self.table[rows, cols] = vals
        self.table_dev = _paged_grant_upd(self.table_dev, rows, cols, vals)

    def evict(self, slots: List[int]) -> None:
        self.reserved.difference_update(slots)
        for s in slots:
            self.occupants[s] = None
            self.table[s] = self.scratch_page
            self.pos[s] = 0
            self.tokens[s] = 0
        if slots:
            (self.table_dev, self.pos_dev, self.tokens_dev,
             self.gates_dev) = _paged_evict_upd(
                self.table_dev, self.pos_dev, self.tokens_dev,
                self.gates_dev, self.iidx(slots), self.scratch_page)


class PagedExecutor(ModelExecutor):
    """Physically paged KV execution.

    The engine's :class:`~repro.runtime.kv_pool.KVPool` owns the page
    arrays (``bind_pool`` materializes them at pool capacity, once per
    run); this executor owns the executables around them:

      * **prefill** runs the gated full-sequence pass with its cache sized
        to the request's granted pages and scatters the KV *directly into
        those pages* inside the same jitted call (the pool arrays are
        donated through it);
      * **decode** batches any mix of cache lengths through one fused
        paged horizon (``repro.models.decoder.paged_decode_horizon``):
        per-slot page-table rows + write positions replace the pow2
        cache-length group machinery entirely — there is ONE group
        regardless of request length. Pages for the whole horizon are
        pre-granted in one bulk ``KVPool.extend`` *before* the launch
        (:meth:`pre_extend_horizon`); the admission-time worst-case
        commitment guarantees the grant cannot fail, so the fused loop
        never pages mid-flight and the page table is constant across it.

    Dynamic decode-batch buckets work as in ``LocalExecutor``: occupied
    slots are stepped in the smallest bucket that holds them, padded with
    free slots whose page-table rows point at the pool's scratch page (so
    their garbage writes land in a write sink no request reads).

    Structural mode runs per-bucket compacted layer stacks over the SAME
    shared pool: groups are keyed by the exact parameter gather (as in
    ``LocalExecutor`` — bucket signatures share executables, never
    params), a bucket with L' retained layers reads/writes pool layers
    0..L'-1 of its request-exclusive pages (the pool stays full-depth, so
    spill/restore and admission accounting are mode-blind and
    conservative), and per-slot gates realize each request's exact mask
    inside its bucket. Structural buckets are always *gated* whole-layer
    buckets (``bucket_quant`` floors at "layer"): the paged decoder
    serves uniform all-attention layouts, so half-layer drops become
    gates — which is bitwise-identical to dropping them structurally.
    Uniform all-attention models only — ``LocalExecutor`` is the
    reference backend for everything else.

    ``kv_dtype`` accepts the canonical precision names (``fp32``/``bf16``/
    ``int8``/``fp8``) or a jnp dtype: quantized precisions store int8/fp8
    pages plus per-(page, kv-head) scale pools, quantize on every write
    seam (monolithic prefill, chunked prefill, horizon decode) and fuse
    dequant into the Pallas kernel / mirror it in the XLA gather.
    """

    paged = True

    def __init__(self, model, params, *, mode: str = "masked",
                 max_active: int = 8, kv_dtype=None,
                 decode_buckets: Sequence[int] = (1, 2, 4, 8),
                 bucket_quant: str = "none"):
        if mode not in ("masked", "structural"):
            raise ValueError(f"unknown mode {mode!r}")
        if bucket_quant not in ("none", "layer", "pow2"):
            raise ValueError(f"unknown bucket_quant {bucket_quant!r}; "
                             "expected none|layer|pow2")
        layout = decoder.default_layout(model.cfg)
        if not (len(layout) > 0
                and all(s.mixer == "attn" and s.ffn == layout[0].ffn
                        for s in layout)):
            raise NotImplementedError(
                "PagedExecutor serves uniform all-attention layouts; "
                f"{model.cfg.name!r} mixes "
                f"{sorted({str(s.mixer) for s in layout})} — use "
                "LocalExecutor (slot caches) for heterogeneous models")
        self.model = model
        self.mcfg = model.cfg
        self.params = params
        self.mode = mode
        # the paged decoder requires uniform layouts, so structural
        # buckets are always whole-layer gated buckets: "none" floors at
        # "layer" (bitwise-identical — half-layer drops run as 0-gates)
        if mode == "structural" and bucket_quant == "none":
            bucket_quant = "layer"
        self.bucket_quant = bucket_quant
        self.max_active = int(max_active)
        name, store, quantized, _ = resolve_kv_dtype(kv_dtype)
        self.kv_dtype_name = name            # canonical, None = model dtype
        self.kv_quantized = quantized
        self.kv_dtype = (store if store is not None
                         else model.cfg.jnp_dtype())   # page storage dtype
        self.decode_buckets = tuple(int(b) for b in decode_buckets or ())
        self.compile_events = 0
        self.launch_s = 0.0
        self.pool = None               # bound per engine run
        # "masked" -> the single gated group; structural mode keys groups
        # by gather_key (exact parameter rows), as in LocalExecutor
        self._groups: Dict[Any, PagedGroup] = {}
        self._prefill_fns: Dict[Tuple, Any] = {}
        self._hfns: Dict[Tuple, Any] = {}
        self._decode_widths: set = set()    # (width, horizon) pairs
        # "pallas" routes decode through the paged flash-decode kernel on
        # TPU; elsewhere the XLA gather fallback is the fast path (the
        # kernel still runs in CI via interpret-mode equivalence tests)
        self._impl = ("pallas" if jax.default_backend() == "tpu" else "xla")

    # ------------------------------------------------------------- binding
    def page_phys_bytes(self, tokens_per_page: int) -> int:
        """Exact bytes of one physical page across all layers (K and V).

        Quantized pools charge the narrow storage width *plus* the page's
        per-(layer, kv-head) f32 scale rows — admission and the pool
        ledger see true bytes, so an int8 request admits ~2× the sequence
        (not exactly 4×: the scales claw a sliver back) under one budget."""
        cfg = self.mcfg
        itemsize = jnp.dtype(self.kv_dtype).itemsize
        n = (2 * cfg.n_layers * int(tokens_per_page) * cfg.n_kv_heads
             * cfg.dh * itemsize)
        if self.kv_quantized:
            n += 2 * cfg.n_layers * cfg.n_kv_heads * 4    # K + V scale rows
        return n

    def bind_pool(self, pool, max_len: int) -> None:
        """Attach this run's KVPool: materialize its page arrays (and, for
        quantized precisions, the scale pools) and size the page-table
        width for ``max_len``-token requests."""
        pool.allocate_physical(n_layers=self.mcfg.n_layers,
                               n_kv_heads=self.mcfg.n_kv_heads,
                               head_dim=self.mcfg.dh,
                               dtype=self.mcfg.jnp_dtype(),
                               kv_dtype=(self.kv_dtype_name
                                         or self.kv_dtype))
        self.pool = pool
        self.max_row_pages = -(-int(max_len) // pool.tokens_per_page)
        # groups reference the previous pool's scratch page/table geometry;
        # compiled executables stay (keys carry their shapes)
        self._groups.clear()

    def _pool_leaves(self) -> Dict[str, Any]:
        """The pool's device arrays as one pytree (pages + scales when
        quantized) — jitted calls donate and return the whole dict."""
        pools = {"k": self.pool.k_pages, "v": self.pool.v_pages}
        if self.kv_quantized:
            pools["ks"] = self.pool.k_scales
            pools["vs"] = self.pool.v_scales
        return pools

    def _store_leaves(self, pools: Dict[str, Any]) -> None:
        self.pool.k_pages = pools["k"]
        self.pool.v_pages = pools["v"]
        if self.kv_quantized:
            self.pool.k_scales = pools["ks"]
            self.pool.v_scales = pools["vs"]

    # ------------------------------------------------------------ capacity
    def _invalidate(self) -> None:
        """Unified invalidation (see ``LocalExecutor._invalidate``):
        groups and every compiled-executable cache drop together."""
        self._groups.clear()
        self._prefill_fns.clear()
        self._hfns.clear()
        self._decode_widths.clear()

    def set_max_active(self, n_slots: int) -> None:
        if int(n_slots) == self.max_active:
            return
        self.max_active = int(n_slots)
        self._invalidate()

    def drop_groups(self) -> None:
        self._invalidate()

    # -------------------------------------------------------------- groups
    def groups(self) -> List[PagedGroup]:
        return list(self._groups.values())

    def group_for(self, mask: np.ndarray, cache_len: int) -> PagedGroup:
        """Masked mode: ONE group hosts every request — pages make cache
        length a per-slot property, so there is nothing to key groups by.
        Structural mode: one group per parameter gather (quantized bucket),
        all decoding over the same shared pool."""
        if self.pool is None:
            raise RuntimeError("PagedExecutor has no bound pool — the "
                               "engine calls bind_pool() per run")
        if self.mode == "masked":
            group = self._groups.get("masked")
            if group is None:
                group = self._groups["masked"] = PagedGroup(
                    self.mcfg, self.max_active, self.max_row_pages,
                    self.pool.scratch_page)
            return group
        qmask = masks_lib.quantize_mask(self.mcfg, mask, self.bucket_quant)
        rkey = masks_lib.gather_key(self.mcfg, qmask)
        group = self._groups.get(rkey)
        if group is None:
            small, layout = masks_lib.compact_params(self.params, self.mcfg,
                                                     qmask)
            # mask: the exact MINTING mask (sticky-affinity metadata, see
            # LocalExecutor.group_for) — per-request masks ride the gates
            group = self._groups[rkey] = PagedGroup(
                self.mcfg, self.max_active, self.max_row_pages,
                self.pool.scratch_page,
                key=masks_lib.bucket_key(self.mcfg, qmask),
                mask=np.array(mask, copy=True), layout=layout,
                params=small,
                gate_rows=masks_lib.keep_rows(self.mcfg, qmask))
        return group

    def _group_params(self, group: PagedGroup):
        return group.params if group.params is not None else self.params

    # ------------------------------------------------------------- prefill
    def _prefill_fn(self, group: PagedGroup, b: int, S: int, npg: int):
        key = (group.key, b, S, npg)
        if key not in self._prefill_fns:
            cfg = self.mcfg
            pt = self.pool.tokens_per_page
            layout = group.layout
            Lp = len(layout) if layout is not None else cfg.n_layers
            quantized = self.kv_quantized
            # quantized pools prefill at model width inside the jit and
            # page-quantize during the scatter: every granted page is
            # fresh (offset 0), so scales are set, never floored
            cache_dtype = None if quantized else self.kv_dtype

            # a compacted bucket prefills an Lp-layer cache and scatters
            # into pool layers [0, Lp) of its granted pages — pages are
            # request-exclusive, so the untouched upper layers are never
            # read. Same-signature buckets share this executable (params
            # are jit arguments; equal-signature layouts are identical).
            @functools.partial(jax.jit, donate_argnums=(4,))
            def fn(p, tokens, gm, gf, pools, rows):
                logits, cache = decoder.prefill(
                    p, cfg, tokens, npg * pt,
                    gates={"mixer": gm, "ffn": gf}, layout=layout,
                    kv_dtype=cache_dtype)
                kp, vp = pools["k"], pools["v"]
                k = cache["attn"]["k"].reshape(Lp, b, npg, pt, *kp.shape[3:])
                v = cache["attn"]["v"].reshape(Lp, b, npg, pt, *vp.shape[3:])
                pools = dict(pools)
                if quantized:
                    qk, sk = attention.page_quant(
                        k.astype(jnp.float32), kp.dtype)
                    qv, sv = attention.page_quant(
                        v.astype(jnp.float32), vp.dtype)
                    pools["k"] = kp.at[:Lp, rows].set(qk)
                    pools["v"] = vp.at[:Lp, rows].set(qv)
                    pools["ks"] = pools["ks"].at[:Lp, rows].set(sk)
                    pools["vs"] = pools["vs"].at[:Lp, rows].set(sv)
                else:
                    pools["k"] = kp.at[:Lp, rows].set(k.astype(kp.dtype))
                    pools["v"] = vp.at[:Lp, rows].set(v.astype(vp.dtype))
                return logits, pools

            self._prefill_fns[key] = fn
            self.compile_events += 1
        return self._prefill_fns[key]

    def prefill_into(self, group: PagedGroup, slots: List[int], rid: str,
                     prompt: np.ndarray, mask: np.ndarray) -> np.ndarray:
        """Prefill the request, writing its KV straight into the pages the
        pool granted at admission; seat its rows in ``slots``."""
        b, S = prompt.shape
        rows = self.pool.row_pages(rid)            # [b][npg] page ids
        npg = len(rows[0])
        rows_np = np.asarray(rows, np.int32)
        fn = self._prefill_fn(group, b, S, npg)
        # one gate-column stack serves both the jitted call and the
        # group's resident gate columns
        cols = _gate_cols(mask, group.gate_rows)
        t0 = time.perf_counter()
        logits, pools = fn(self._group_params(group),
                           jnp.asarray(prompt, jnp.int32),
                           cols[0], cols[1], self._pool_leaves(),
                           jnp.asarray(rows_np))
        self._store_leaves(pools)
        first = np.asarray(jnp.argmax(logits, axis=-1).astype(jnp.int32))
        self.launch_s += time.perf_counter() - t0
        group.place(rid, slots, rows_np, S, first, cols[0], cols[1])
        return first

    # ----------------------------------------------------- chunked prefill
    def supports_chunked_prefill(self, group: PagedGroup) -> bool:
        # the constructor pins uniform all-attention models, and
        # structural buckets are whole-layer (still uniform) — exactly
        # what the paged chunk path serves (quantized pools requantize
        # the chunk's touched pages in the same call)
        return True

    def _chunk_fn(self, group: PagedGroup, b: int, C: int):
        """Jitted paged one-chunk prefill, keyed by chunk width (offset is
        traced): the chunk's K/V scatter straight into the granted pages
        (pool arrays donated through the call, as in monolithic paged
        prefill)."""
        scratch = self.pool.scratch_page
        key = ("chunk", group.key, b, C, scratch)
        if key not in self._prefill_fns:
            cfg = self.mcfg
            layout = group.layout

            @functools.partial(jax.jit, donate_argnums=(1,))
            def fn(p, pools, table, tokens, start, gm, gf):
                logits, pools = decoder.paged_prefill_chunk(
                    p, cfg, pools, table, tokens, start,
                    scratch_page=scratch,
                    gates={"mixer": gm, "ffn": gf}, layout=layout)
                return logits, pools

            self._prefill_fns[key] = fn
            self.compile_events += 1
        return self._prefill_fns[key]

    def prefill_begin(self, group: PagedGroup, slots: List[int], rid: str,
                      prompt: np.ndarray, mask: np.ndarray, *,
                      max_chunk: int) -> _PrefillTask:
        """Open a chunked paged prefill. The pool allocation (made at
        admission) covers only the first chunk; each later chunk extends
        the request's pages just before it runs, so a long prompt's pages
        materialize incrementally instead of all up front."""
        prompt = np.asarray(prompt, np.int32)
        b, S = prompt.shape
        group.reserved.update(slots)
        cols = _gate_cols(mask, group.gate_rows)
        return _PrefillTask(group=group, slots=list(slots), rid=rid,
                            prompt=prompt, mask=mask,
                            gates={"mixer": cols[0], "ffn": cols[1]},
                            widths=chunk_widths(S, max_chunk))

    def prefill_step(self, task: _PrefillTask) -> Optional[np.ndarray]:
        group, rid = task.group, task.rid
        b, S = task.prompt.shape
        c = task.widths[task.step]
        if task.pos > 0:
            # the admission alloc covered chunk 0; grant this chunk's pages
            self.pool.extend(rid, c)
        rows = self.pool.row_pages(rid)
        table = np.full((b, self.max_row_pages), self.pool.scratch_page,
                        np.int32)
        table[:, :len(rows[0])] = np.asarray(rows, np.int32)
        fn = self._chunk_fn(group, b, c)
        t0 = time.perf_counter()
        logits, pools = fn(
            self._group_params(group), self._pool_leaves(), jnp.asarray(table),
            jnp.asarray(task.prompt[:, task.pos:task.pos + c], jnp.int32),
            np.int32(task.pos), task.gates["mixer"], task.gates["ffn"])
        self._store_leaves(pools)
        task.pos += c
        task.step += 1
        if not task.done:
            self.launch_s += time.perf_counter() - t0
            return None
        first = np.asarray(jnp.argmax(logits, axis=-1).astype(jnp.int32))
        self.launch_s += time.perf_counter() - t0
        rows_np = np.asarray(self.pool.row_pages(rid), np.int32)
        group.place(rid, task.slots, rows_np, S, first,
                    np.asarray(task.gates["mixer"]),
                    np.asarray(task.gates["ffn"]))
        return first

    # ---------------------------------------------------- preemption seam
    def spill_state(self, group: PagedGroup, slots: List[int]) -> dict:
        """Paged decode state outside the pool is tiny: the write position
        and the per-row seed token (the page contents travel with
        ``KVPool.spill``)."""
        return {"pos": int(group.pos[slots[0]]),
                "first": group.tokens[np.asarray(slots)].copy()}

    def restore_state(self, group: PagedGroup, slots: List[int], rid: str,
                      state: dict, mask: Optional[np.ndarray],
                      rows: Optional[List[List[int]]] = None) -> None:
        """Reseat with the re-granted page ids (``KVPool.restore``'s rows
        — same per-row layout, contents written back bitwise): one fused
        placement update rebuilds table/pos/tokens/gates exactly as an
        unpreempted resident would hold them."""
        if rows is None:
            rows = self.pool.row_pages(rid)
        cols = _gate_cols(mask, group.gate_rows)
        group.place(rid, list(slots), np.asarray(rows, np.int32),
                    state["pos"], state["first"], cols[0], cols[1])

    # -------------------------------------------------------------- decode
    def _decode_batch(self, group: PagedGroup) -> List[int]:
        idx = _bucket_batch(group.occupied_slots(), group.free_slots(),
                            group.n_slots, self.decode_buckets)
        # full width: every slot steps (free rows write the scratch page)
        return idx if idx is not None else list(range(group.n_slots))

    def _horizon_fn(self, group: PagedGroup, horizon: int, bucketed: bool):
        """Jitted fused paged horizon per (bucket signature, H, bucketed).
        The bucketed variant gathers the stepped rows from the full-width
        resident state and scatters positions/tokens back *inside* the
        compiled call (eager indexing would upload an index-normalization
        scalar per launch — the transfer-guard test forbids it)."""
        h = int(horizon)
        key = (group.key, h, bool(bucketed))
        if key not in self._hfns:
            cfg, impl = self.mcfg, self._impl
            layout = group.layout

            if not bucketed:
                @functools.partial(jax.jit, donate_argnums=(1, 3, 4))
                def fn(p, pools, table, pos, tok, gates):
                    toks, pools, pos = decoder.paged_decode_horizon(
                        p, cfg, pools, table, pos,
                        tok[:, None], h,
                        gates={"mixer": gates[0], "ffn": gates[1]},
                        impl=impl, layout=layout)
                    return toks, pools, pos, toks[:, -1]
            else:
                @functools.partial(jax.jit, donate_argnums=(1, 3, 4))
                def fn(p, pools, table, pos, tok, gates, iidx):
                    g = gates[:, :, iidx]
                    toks, pools, pos_out = decoder.paged_decode_horizon(
                        p, cfg, pools, table[iidx], pos[iidx],
                        tok[iidx][:, None], h,
                        gates={"mixer": g[0], "ffn": g[1]}, impl=impl,
                        layout=layout)
                    pos = pos.at[iidx].set(pos_out)
                    tok = tok.at[iidx].set(toks[:, -1])
                    return toks, pools, pos, tok

            self._hfns[key] = fn
        return self._hfns[key]

    def pre_extend_horizon(self, group: PagedGroup, horizon: int) -> int:
        """Pre-grant every page the coming horizon can touch: ONE bulk
        ``KVPool.extend`` per resident request (clamped to its admission
        commitment — ``alloc_tokens``' worst-case reservation guarantees
        the grant can't fail), folding any new page ids into the device
        page table in one scatter. Positions past the commitment (a
        request over-generating inside its final horizon) resolve to the
        scratch page / its own last page and are truncated by the engine.
        Returns the number of pages granted (0 in the steady state)."""
        occ = group.occupied_slots()
        entries: List[Tuple[int, int, int]] = []
        seen = set()
        for s in occ:
            rid = group.occupants[s]
            if rid in seen:
                continue
            seen.add(rid)
            n = min(int(horizon), self.pool.remaining_commitment(rid))
            if n <= 0:
                continue
            # pages currently held per row (alloc/extend keep rows at
            # exactly ceil(seq/page) — no need to copy the id lists)
            have = self.pool.pages_per_row(self.pool.seq_tokens(rid))
            new_rows = self.pool.extend(rid, n)    # [batch][granted pages]
            if not any(new_rows):
                continue
            rid_slots = [t for t in occ if group.occupants[t] == rid]
            for i, t in enumerate(rid_slots):
                for j, page in enumerate(new_rows[i]):
                    entries.append((t, have + j, page))
        group.grant_pages(entries)
        return len(entries)

    def launch_horizon(self, group: PagedGroup,
                       horizon: int) -> Tuple[Any, List[int], bool]:
        """Device phase of a fused paged horizon: gather the stepped
        slots' resident state, launch ONE compiled ``lax.scan`` that
        advances them ``horizon`` tokens against the page pools, and fold
        positions/tokens back. Pages must already be granted
        (:meth:`pre_extend_horizon`). Returns (device toks [width, H],
        stepped slot ids, new-compile flag); zero host↔device transfers
        once warm — the caller's single ``np.asarray`` is the only sync."""
        idx = self._decode_batch(group)
        width = len(idx)
        key = (group.key, width, int(horizon))
        new = key not in self._decode_widths
        self._decode_widths.add(key)
        if new:
            self.compile_events += 1
        full = width == group.n_slots
        fn = self._horizon_fn(group, horizon, bucketed=not full)
        args = (self._group_params(group), self._pool_leaves(),
                group.table_dev, group.pos_dev, group.tokens_dev,
                group.gates_dev)
        if not full:
            args += (group.iidx(idx),)
        toks, pools, pos, tok = fn(*args)
        self._store_leaves(pools)
        group.pos_dev = pos
        group.tokens_dev = tok
        return toks, idx, new

    def decode_launch(self, group: PagedGroup,
                      horizon: int) -> _InFlightHorizon:
        """Bulk page pre-grant + one fused launch, no sync: the host is
        free to schedule/admit while the scan runs on device."""
        self.pre_extend_horizon(group, horizon)
        t0 = time.perf_counter()
        toks_dev, idx, new = self.launch_horizon(group, horizon)
        self.launch_s += time.perf_counter() - t0
        return _InFlightHorizon(group=group, horizon=int(horizon),
                                toks_dev=toks_dev, idx=idx,
                                occupants=[group.occupants[s] for s in idx],
                                new=new)

    def decode_finish(self,
                      launch: _InFlightHorizon) -> Tuple[np.ndarray, bool]:
        group, h = launch.group, launch.horizon
        t0 = time.perf_counter()
        nxt = np.asarray(launch.toks_dev)  # the single device→host sync
        self.launch_s += time.perf_counter() - t0
        out = np.zeros((group.n_slots, h), np.int32)
        for j, s in enumerate(launch.idx):
            # fold back only slots whose occupant is unchanged since
            # launch: overlapped host admission may have re-seated a slot
            # that was free padding when the scan dispatched
            if (launch.occupants[j] is not None
                    and group.occupants[s] == launch.occupants[j]):
                out[s] = nxt[j]
                group.tokens[s] = nxt[j, -1]
                group.pos[s] += h
        return out, launch.new

    def decode_horizon(self, group: PagedGroup,
                       horizon: int) -> Tuple[np.ndarray, bool]:
        """Advance every occupied slot ``horizon`` tokens: bulk page
        pre-grant, one fused launch, one [width, horizon] read-back."""
        return self.decode_finish(self.decode_launch(group, horizon))

    # ---------------------------------------------------------- utilization
    def kv_utilization(self) -> Tuple[float, float]:
        """used = tokens actually written by resident requests; physical =
        bytes of the pages they hold. Waste is bounded by one partial page
        per row plus the pre-granted horizon tail — the whole point of
        paging."""
        if self.pool is None or not self._groups:
            return 0.0, 0.0
        pt = self.pool.tokens_per_page
        tok_bytes = self.pool.page_bytes / pt
        used = 0.0
        for group in self._groups.values():
            for s in group.occupied_slots():
                rid = group.occupants[s]
                # clamp to the granted backing: a request over-generating
                # in its final horizon advances pos past its page-backed
                # tokens
                used += min(int(group.pos[s]),
                            self.pool.seq_tokens(rid)) * tok_bytes
        return used, self.pool.bytes_reserved

    # --------------------------------------------------------------- stats
    def stats(self) -> Dict[str, int]:
        return {
            "groups": len(self._groups),
            "structural_buckets": len({k for k in self._groups
                                       if k != "masked"}),
            "bucket_signatures": len({g.key for g in self._groups.values()
                                      if g.key != "paged"}),
            "prefill_executables": len(self._prefill_fns),
            "decode_widths": len(self._decode_widths),
            "compile_events": self.compile_events,
        }


# ----------------------------------------------------------------- sharded
class ShardedSlotGroup(SlotGroup):
    """A :class:`SlotGroup` whose decode state is **mesh-resident**
    (DESIGN.md §7 "Sharded serving").

    The slot axis is the mesh's data-parallel dimension: the KV cache is
    sharded over slots ("data") and KV heads ("model"), positions and
    seed tokens over slots, gates replicated — the partition rules from
    ``repro.parallel.sharding.serve_state_pspecs``, with per-axis
    divisibility fallback so smoke shapes degrade to replication instead
    of GSPMD errors. The fused placement update and the horizon scan are
    re-jitted with explicit ``out_shardings`` pinned to that layout, so
    placement writes only the placed columns of the *sharded* arrays and
    a warmed horizon launch never re-shards (or re-uploads) the resident
    state. Groups always step full width — the slot axis IS the mesh
    axis, so there is no bucketed gather variant (``ShardedExecutor``
    passes ``decode_buckets=()``)."""

    def __init__(self, key, params, layout, cfg_model, n_slots: int,
                 cache_len: int, kv_dtype, gated: bool, mesh,
                 mask: Optional[np.ndarray] = None):
        if not gated:
            raise NotImplementedError(
                "sharded slot groups are gated (masked mode) only — "
                "structural sharded buckets (per-bucket compacted params "
                "re-placed on the mesh) are a ROADMAP item")
        super().__init__(key, params, layout, cfg_model, n_slots, cache_len,
                         kv_dtype, gated, mask=mask)
        from jax.sharding import NamedSharding, PartitionSpec as P

        from repro.parallel import (serve_slot_pspec, serve_state_pspecs,
                                    shardings_for)
        self.mesh = mesh
        self._rep = NamedSharding(mesh, P())
        self._cache_sh = shardings_for(
            serve_state_pspecs(self.cache, mesh, n_slots=n_slots), mesh)
        self.cache = jax.device_put(self.cache, self._cache_sh)
        self._tok_sh = NamedSharding(mesh,
                                     serve_slot_pspec(self.tokens.shape,
                                                      mesh))
        self.tokens = jax.device_put(self.tokens, self._tok_sh)
        # gates are replicated: [2, L, n_slots] is tiny, placement updates
        # single columns, and every TP shard reads every layer's gate row
        self._gates_dev = jax.device_put(self._gates_dev, self._rep)
        self._place_fns: Dict[bool, Any] = {}

    def _iidx(self, idx: List[int]):
        key = tuple(idx)
        dev = self._iidx_cache.get(key)
        if dev is None:
            if len(self._iidx_cache) >= _IIDX_CACHE_CAP:
                self._iidx_cache.pop(next(iter(self._iidx_cache)))
            dev = jax.device_put(np.asarray(idx, np.int32), self._rep)
            self._iidx_cache[key] = dev
        return dev

    def _place_fn(self, with_gates: bool):
        fn = self._place_fns.get(with_gates)
        if fn is None:
            fn = jax.jit(_slot_place_body, donate_argnums=(0, 1, 7),
                         out_shardings=(self._cache_sh, self._tok_sh,
                                        self._rep if with_gates else None))
            self._place_fns[with_gates] = fn
        return fn

    def _horizon_fn(self, horizon: int, bucketed: bool):
        """Fused horizon lowered under the mesh: the SAME full-width
        horizon body as the local path (``_full_width_horizon``), jitted
        with the resident state's shardings pinned on the outputs (the
        inputs carry theirs), so ONE mesh-partitioned ``lax.scan``
        executable advances every slot H tokens and pays its collectives
        once per horizon. Tokens come back replicated — the macro-tick's
        single read-back."""
        if bucketed:
            raise NotImplementedError(
                "sharded slot groups always step full width — the slot "
                "axis is the mesh's DP dimension (ShardedExecutor runs "
                "with decode_buckets=())")
        h = int(horizon)
        key = (h, False)
        if key not in self._hfns:
            self._hfns[key] = jax.jit(
                self._full_width_horizon(h), donate_argnums=(1, 2),
                out_shardings=(self._rep, self._cache_sh, self._tok_sh))
        return self._hfns[key]


class ShardedExecutor(LocalExecutor):
    """Mesh-resident slot-group execution (DESIGN.md §7 "Sharded serving").

    Owns both mesh roles of the serving stack:

      * **placement / lowering** — parameters placed under the production
        partition rules (``repro.parallel.sharding.param_pspecs``: TP over
        feature dims, optional ZeRO-3 over "data") and a sharded decode
        step lowered for HLO cost / memory / collective analysis
        (:meth:`lower_decode`, the path ``launch/rap_sweep.py`` drives);
      * **the slot-batched serve path** — groups are
        :class:`ShardedSlotGroup`: decode state lives sharded on the mesh
        (KV over slots=DP and heads=TP, gates replicated), placement /
        eviction stay fused column updates of the sharded arrays, and
        each engine macro-tick launches ONE mesh-lowered horizon scan, so
        TP collectives are paid once per H tokens instead of per token
        (the PR 4 horizon decode is what makes sharded ticks affordable).

    Masked mode only — one gated group serves every keep-mask, which is
    exactly what keeps the sharded executable set small. Structural
    sharded buckets are a ROADMAP item; use ``LocalExecutor`` for
    structural serving. Works on CPU via
    ``XLA_FLAGS=--xla_force_host_platform_device_count=8`` (the
    multi-device CI job) as well as on real accelerator meshes.
    """

    def __init__(self, model, mesh, *, params=None, fsdp: bool = False,
                 shard_seq: bool = False, kv_int8: bool = False,
                 mode: str = "masked", max_active: int = 8, kv_dtype=None):
        if mode != "masked":
            raise NotImplementedError(
                f"sharded serving is masked-mode only (got {mode!r}); "
                "structural sharded buckets are a ROADMAP item — use "
                "LocalExecutor for structural serving")
        self.mesh = mesh
        self.policy = {"fsdp": bool(fsdp), "shard_seq": bool(shard_seq),
                       "kv_int8": bool(kv_int8)}
        self.model = model          # place_params resolves shapes via model
        placed = self.place_params(params) if params is not None else None
        # decode_buckets=(): sharded groups step full width — the slot
        # axis is the mesh's DP dimension, and a bucketed gather would
        # change the sharded state shape per occupancy pattern
        super().__init__(model, placed, mode="masked",
                         max_active=max_active, kv_dtype=kv_dtype,
                         decode_buckets=())

    # ----------------------------------------------------------- placement
    def param_shardings(self):
        from repro.parallel import param_pspecs, shardings_for
        shapes = jax.eval_shape(lambda: self.model.init(jax.random.key(0)))
        return shardings_for(param_pspecs(shapes, self.mesh,
                                          fsdp=self.policy["fsdp"]),
                             self.mesh)

    def place_params(self, params):
        """Place a params pytree on the mesh under the production rules."""
        return jax.device_put(params, self.param_shardings())

    def lower_decode(self, shape):
        """Lower one sharded fused decode step for ``shape`` (a
        ``repro.configs`` request shape) and return the ``Lowered`` —
        callers compile it for HLO cost / memory / collective analysis."""
        from repro.parallel import (batch_pspecs, cache_pspecs, param_pspecs,
                                    shardings_for)
        from repro.parallel import activation as act
        from repro.runtime import steps as steps_lib
        model, mesh, policy = self.model, self.mesh, self.policy
        with act.use(mesh, shard_seq=policy["shard_seq"],
                     fsdp=policy["fsdp"]):
            params_shape = jax.eval_shape(
                lambda: model.init(jax.random.key(0)))
            psh = shardings_for(param_pspecs(params_shape, mesh,
                                             fsdp=policy["fsdp"]), mesh)
            specs = model.input_specs(shape)
            bsh = shardings_for(batch_pspecs(specs, mesh), mesh)
            kv_dtype = jnp.int8 if policy["kv_int8"] else None
            cache_shape = jax.eval_shape(
                lambda: model.init_cache(shape.global_batch, shape.seq_len,
                                         kv_dtype=kv_dtype))
            csh = shardings_for(
                cache_pspecs(cache_shape, mesh, batch=shape.global_batch,
                             shard_seq=policy["shard_seq"]), mesh)
            fn = steps_lib.make_decode_step(model)
            jfn = jax.jit(fn, in_shardings=(psh, csh, bsh["tokens"]),
                          out_shardings=(None, csh), donate_argnums=(1,))
            return jfn.lower(params_shape, cache_shape, specs["tokens"])

    # ------------------------------------------------------------ serve API
    def group_for(self, mask: np.ndarray, cache_len: int) -> SlotGroup:
        """One gated mesh-resident group per cache length (masked mode:
        every keep-mask shares it, exactly as on the local path)."""
        if self.params is None:
            raise RuntimeError(
                "ShardedExecutor has no params — construct with params= "
                "to serve (mesh cost analysis via lower_decode() does not "
                "need them)")
        gkey = ("masked", cache_len)
        if gkey not in self._groups:
            self._groups[gkey] = ShardedSlotGroup(
                "masked", self.params, None, self.mcfg, self.max_active,
                cache_len, self.kv_dtype, gated=True, mesh=self.mesh)
        return self._groups[gkey]

    # --------------------------------------------------------------- stats
    def stats(self) -> Dict[str, int]:
        s = super().stats()
        s["mesh_devices"] = int(self.mesh.size)
        return s
