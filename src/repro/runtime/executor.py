"""Model executors — the execution seam of the serving engine.

The engine decides *who* runs (scheduler) and *what shape* they run in
(pruning policy); a :class:`ModelExecutor` owns *how* the chosen masks
execute: slot-batched caches, compiled executable families, prefill
scattering, and the fused decode step. PR 1 inlined all of this into
``RAPEngine``; extracting it means sharded serving is "swap the
executor", not "rewrite the engine".

Executors:
  * :class:`LocalExecutor` — today's single-process path. Groups (one per
    structural bucket, or one gated group in masked mode) are additionally
    keyed by a power-of-two *cache length*, so a long request mints a new
    long-cache group instead of invalidating every compiled short one.
    Decode runs in dynamic batch buckets B ∈ {1, 2, 4, 8} (ROADMAP): the
    occupied slots are gathered into the smallest bucket that holds them,
    stepped, and scattered back, so a lightly loaded engine does not pay
    full-slot-count compute per token.
  * :class:`PagedExecutor` — physically paged KV execution (DESIGN.md §3
    "Paged KV"): requests own *pages* of a global KV pool
    (``repro.runtime.kv_pool.KVPool`` holds the page arrays), prefill
    writes KV straight into granted pages, and one fused decode step
    advances any mix of cache lengths through a per-request page table —
    no ``max_len × max_active`` slot caches, no pow2 cache-length groups,
    and page-granular (not slot-granular) internal fragmentation.
  * :class:`ShardedExecutor` — mesh placement via
    ``repro.parallel.sharding``: places parameters with the production
    partition rules and lowers a sharded decode step for cost analysis
    (``launch/rap_sweep.py``). The slot-batched serve path on a mesh is a
    ROADMAP item; serve-path methods raise ``NotImplementedError`` with
    that pointer.

``LocalExecutor`` remains the reference backend: it serves every layout
(heterogeneous mixers keep per-request slot state) and both pruning modes,
and the paged path's token-equivalence is pinned against it in
``tests/test_engine.py``.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import masks as masks_lib
from repro.models import decoder

__all__ = ["ModelExecutor", "SlotGroup", "LocalExecutor", "PagedExecutor",
           "PagedGroup", "ShardedExecutor"]


def _bucket_batch(occ: List[int], free: List[int], n_slots: int,
                  buckets: Sequence[int]) -> Optional[List[int]]:
    """Slot indices to step this iteration: the occupied slots padded with
    free ones up to the smallest bucket that holds them, or None for the
    full-width path. Padding uses *distinct free* slots so a scatter-back
    never writes one index twice; their compute is garbage but unobservable
    (slot rows are independent and re-seeded on placement)."""
    n = len(occ)
    for b in sorted(set(buckets)):
        if n <= b < n_slots:
            return occ + free[: b - n]
    return None


# ------------------------------------------------------------------- groups
class SlotGroup:
    """One slot-batched executable family sharing a cache.

    masked mode: a single group over the full params with per-slot gates.
    structural mode: one group per bucket (compacted params, gates absorbed
    into structure). Groups are minted per (bucket, cache_len)."""

    def __init__(self, key, params, layout, cfg_model, n_slots: int,
                 cache_len: int, kv_dtype, gated: bool,
                 mask: Optional[np.ndarray] = None):
        self.key = key                # logical bucket key ("masked" | tuple)
        self.params = params
        self.layout = layout
        self.mask = mask              # the keep-mask that minted this bucket
        self.n_slots = n_slots
        self.cache_len = cache_len
        self.gated = gated
        self.occupants: List[Optional[str]] = [None] * n_slots
        self.cache = decoder.init_cache(cfg_model, n_slots, cache_len,
                                        layout, kv_dtype)
        self.cache["pos"] = jnp.zeros((n_slots,), jnp.int32)
        self.tokens = jnp.zeros((n_slots, 1), jnp.int32)
        if gated:
            L = cfg_model.n_layers
            self._gates_np = np.ones((2, L, n_slots), np.float32)
            self._gates_dev = jnp.asarray(self._gates_np)
        cfg = cfg_model
        layout_c = layout

        if gated:
            @jax.jit
            def step(p, cache, tok, gm, gf):
                return decoder.decode_step(p, cfg, cache, tok,
                                           gates={"mixer": gm, "ffn": gf})
        else:
            @jax.jit
            def step(p, cache, tok):
                return decoder.decode_step(p, cfg, cache, tok,
                                           layout=layout_c)
        self._step = step
        # decode executables are cached per batch bucket inside the jitted
        # fn (XLA retraces per shape); we track seen buckets for compile
        # accounting
        self._compiled_batches: set = set()

    # ----------------------------------------------------------- occupancy
    def free_slots(self) -> List[int]:
        return [i for i, o in enumerate(self.occupants) if o is None]

    def occupied_slots(self) -> List[int]:
        return [i for i, o in enumerate(self.occupants) if o is not None]

    def occupied(self) -> bool:
        return any(o is not None for o in self.occupants)

    def place(self, rid: str, slots: List[int], req_cache: dict,
              mask: Optional[np.ndarray], prompt_len: int) -> None:
        """Write a freshly prefilled request cache into ``slots``."""
        idx = jnp.asarray(slots, jnp.int32)
        cache = dict(self.cache)
        for k, v in cache.items():
            if k == "pos":
                cache[k] = v.at[idx].set(jnp.asarray(prompt_len, jnp.int32))
            else:
                cache[k] = jax.tree.map(
                    lambda big, small: big.at[:, idx].set(small), v,
                    req_cache[k])
        self.cache = cache
        for s in slots:
            self.occupants[s] = rid
        if self.gated and mask is not None:
            g = masks_lib.mask_to_gates(mask)
            for s in slots:
                self._gates_np[0, :, s] = np.asarray(g["mixer"])
                self._gates_np[1, :, s] = np.asarray(g["ffn"])
            self._gates_dev = jnp.asarray(self._gates_np)

    def set_tokens(self, slots: List[int], toks: np.ndarray) -> None:
        idx = jnp.asarray(slots, jnp.int32)
        self.tokens = self.tokens.at[idx, 0].set(
            jnp.asarray(toks, jnp.int32))

    def evict(self, slots: List[int]) -> None:
        for s in slots:
            self.occupants[s] = None

    # -------------------------------------------------------------- decode
    def _decode_batch(self, buckets: Sequence[int]) -> Optional[List[int]]:
        return _bucket_batch(self.occupied_slots(), self.free_slots(),
                             self.n_slots, buckets)

    def decode_once(self, buckets: Sequence[int] = ()) -> Tuple[np.ndarray,
                                                                bool]:
        """Advance every occupied slot one token; returns ([n_slots] next
        tokens — unoccupied entries are stale/garbage — and whether this
        call compiled a new executable)."""
        idx = self._decode_batch(buckets) if buckets else None
        width = self.n_slots if idx is None else len(idx)
        new = width not in self._compiled_batches
        self._compiled_batches.add(width)
        if idx is None:
            cache, tokens = self.cache, self.tokens
            gates = self._gates_dev if self.gated else None
        else:
            iidx = jnp.asarray(idx, jnp.int32)
            cache = {k: (v[iidx] if k == "pos"
                         else jax.tree.map(lambda a: a[:, iidx], v))
                     for k, v in self.cache.items()}
            tokens = self.tokens[iidx]
            gates = self._gates_dev[:, :, iidx] if self.gated else None
        if self.gated:
            logits, cache = self._step(self.params, cache, tokens,
                                       gates[0], gates[1])
        else:
            logits, cache = self._step(self.params, cache, tokens)
        nxt = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
        if idx is None:
            self.cache = cache
            self.tokens = nxt[:, None]
            return np.asarray(nxt), new
        # scatter the stepped sub-batch back into the full-width state
        iidx = jnp.asarray(idx, jnp.int32)
        big = dict(self.cache)
        for k, v in cache.items():
            if k == "pos":
                big[k] = self.cache[k].at[iidx].set(v)
            else:
                big[k] = jax.tree.map(
                    lambda full, small: full.at[:, iidx].set(small),
                    self.cache[k], v)
        self.cache = big
        self.tokens = self.tokens.at[iidx, 0].set(nxt)
        out = np.zeros((self.n_slots,), np.int32)
        out[np.asarray(idx)] = np.asarray(nxt)
        return out, new


# ---------------------------------------------------------------- protocol
class ModelExecutor:
    """Execution backend protocol for the engine.

    ``group_for`` resolves a keep-mask (+ cache length) to the slot group
    that will host the request; ``prefill_into`` seats a prefilled request;
    ``decode`` advances one group one token. ``compile_events`` counts new
    executables (prefill shapes + decode batch buckets).

    ``paged`` marks backends whose KV lives in a :class:`KVPool`'s physical
    page arrays — the engine switches admission to the token-granular pool
    API and calls ``bind_pool`` per run. ``kv_utilization`` reports
    (used_bytes, physical_bytes) of the live KV storage so benchmarks can
    measure *physical* internal fragmentation, not just the ledger's."""

    compile_events: int = 0
    paged: bool = False

    def group_for(self, mask: np.ndarray, cache_len: int) -> SlotGroup:
        raise NotImplementedError

    def prefill_into(self, group: SlotGroup, slots: List[int], rid: str,
                     prompt: np.ndarray, mask: np.ndarray) -> np.ndarray:
        raise NotImplementedError

    def decode(self, group: SlotGroup) -> Tuple[np.ndarray, bool]:
        raise NotImplementedError

    def groups(self) -> List[SlotGroup]:
        raise NotImplementedError

    def set_max_active(self, n_slots: int) -> None:
        raise NotImplementedError

    def drop_groups(self) -> None:
        """Invalidate every compiled group (capacity reshape)."""
        raise NotImplementedError

    def evict_all(self) -> None:
        for g in self.groups():
            g.evict(list(range(g.n_slots)))

    def kv_utilization(self) -> Tuple[float, float]:
        """(used_bytes, physical_bytes) of live KV storage; (0, 0) when the
        backend does not track it. ``used`` counts tokens actually written
        by resident requests; ``physical`` counts the allocated arrays
        backing them — their ratio is the *measured* (not analytical)
        internal fragmentation."""
        return 0.0, 0.0

    def stats(self) -> Dict[str, int]:
        return {"compile_events": self.compile_events}


# ------------------------------------------------------------------- local
class LocalExecutor(ModelExecutor):
    """Single-process slot-batched execution (the PR 1 path, extracted),
    plus dynamic decode-batch buckets and per-cache-length groups."""

    def __init__(self, model, params, *, mode: str = "masked",
                 max_active: int = 8, kv_dtype=None,
                 decode_buckets: Sequence[int] = (1, 2, 4, 8)):
        if mode not in ("masked", "structural"):
            raise ValueError(f"unknown mode {mode!r}")
        self.model = model
        self.mcfg = model.cfg
        self.params = params
        self.mode = mode
        self.max_active = int(max_active)
        self.kv_dtype = kv_dtype
        self.decode_buckets = tuple(int(b) for b in decode_buckets or ())
        self.compile_events = 0
        self._groups: Dict[Tuple, SlotGroup] = {}
        self._prefill_fns: Dict[Tuple, Any] = {}

    # ------------------------------------------------------------ capacity
    def set_max_active(self, n_slots: int) -> None:
        """Changing the slot count changes every cache's slot axis — all
        compiled groups drop (their prefill executables stay valid: prefill
        shapes depend on (cache_len, batch, seq), not slot count)."""
        if int(n_slots) == self.max_active:
            return
        self.max_active = int(n_slots)
        self._groups.clear()

    def drop_groups(self) -> None:
        # prefill fns are keyed by cache_len: after a capacity reshape the
        # old lengths are unreachable, so keeping them would pin dead XLA
        # executables for the executor's lifetime
        self._groups.clear()
        self._prefill_fns.clear()

    # -------------------------------------------------------------- groups
    def groups(self) -> List[SlotGroup]:
        return list(self._groups.values())

    def group_for(self, mask: np.ndarray, cache_len: int) -> SlotGroup:
        if self.mode == "masked":
            key = "masked"
            gkey = (key, cache_len)
            if gkey not in self._groups:
                self._groups[gkey] = SlotGroup(
                    key, self.params, None, self.mcfg, self.max_active,
                    cache_len, self.kv_dtype, gated=True)
            return self._groups[gkey]
        key = masks_lib.bucket_key(self.mcfg, mask)
        gkey = (key, cache_len)
        if gkey not in self._groups:
            small, layout = masks_lib.compact_params(self.params, self.mcfg,
                                                     mask)
            self._groups[gkey] = SlotGroup(
                key, small, layout, self.mcfg, self.max_active,
                cache_len, self.kv_dtype, gated=False,
                mask=np.array(mask, copy=True))
        return self._groups[gkey]

    # ------------------------------------------------------------- prefill
    def _prefill_fn(self, group: SlotGroup, b: int, S: int):
        key = (group.key, group.cache_len, b, S)
        if key not in self._prefill_fns:
            cfg, max_len = self.mcfg, group.cache_len
            kv_dtype, layout = self.kv_dtype, group.layout
            if group.gated:
                @jax.jit
                def fn(p, tokens, gm, gf):
                    return decoder.prefill(p, cfg, tokens, max_len,
                                           gates={"mixer": gm, "ffn": gf},
                                           kv_dtype=kv_dtype)
            else:
                @jax.jit
                def fn(p, tokens):
                    return decoder.prefill(p, cfg, tokens, max_len,
                                           layout=layout, kv_dtype=kv_dtype)
            self._prefill_fns[key] = fn
            self.compile_events += 1
        return self._prefill_fns[key]

    def prefill_into(self, group: SlotGroup, slots: List[int], rid: str,
                     prompt: np.ndarray, mask: np.ndarray) -> np.ndarray:
        """Prefill the request and seat it; returns token #1 per row [b]."""
        b, S = prompt.shape
        tokens = jnp.asarray(prompt, jnp.int32)
        fn = self._prefill_fn(group, b, S)
        if group.gated:
            g = masks_lib.mask_to_gates(mask)
            logits, cache = fn(self.params, tokens, g["mixer"], g["ffn"])
        else:
            logits, cache = fn(group.params, tokens)
        cache.pop("pos")
        group.place(rid, slots, cache, mask if group.gated else None, S)
        first = np.asarray(jnp.argmax(logits, axis=-1).astype(jnp.int32))
        group.set_tokens(slots, first)
        return first

    # -------------------------------------------------------------- decode
    def decode(self, group: SlotGroup) -> Tuple[np.ndarray, bool]:
        nxt, new = group.decode_once(self.decode_buckets)
        if new:
            self.compile_events += 1
        return nxt, new

    # ---------------------------------------------------------- utilization
    def kv_utilization(self) -> Tuple[float, float]:
        """Slot caches are dense ``[n_slots, cache_len]`` arrays: physical
        bytes exist for every minted group whether or not its slots are
        occupied, and an occupied slot pins ``cache_len`` tokens while using
        only its current position. Only attention KV (the per-token state)
        is counted; fixed-size recurrent state is excluded from both
        sides."""
        used = phys = 0.0
        for g in self.groups():
            entry = g.cache.get("attn")
            if entry is None:     # windowed/recurrent state is fixed-size
                continue
            attn_bytes = sum(int(v.size) * v.dtype.itemsize
                             for v in entry.values())
            if attn_bytes == 0:
                continue
            phys += attn_bytes
            occ = g.occupied_slots()
            if occ:
                per_tok = attn_bytes / (g.n_slots * g.cache_len)
                pos = np.asarray(g.cache["pos"])[np.asarray(occ)]
                used += float(pos.sum()) * per_tok
        return used, phys

    # --------------------------------------------------------------- stats
    def stats(self) -> Dict[str, int]:
        return {
            "groups": len(self._groups),
            # distinct logical mask buckets — NOT (bucket, cache_len)
            # entries, which pow2 length bucketing would overcount
            "structural_buckets": len({k for k, _ in self._groups
                                       if k != "masked"}),
            "prefill_executables": len(self._prefill_fns),
            "masked_prefill_executables": sum(
                1 for k in self._prefill_fns if k[0] == "masked"),
            "compile_events": self.compile_events,
        }


# ------------------------------------------------------------------- paged
class PagedGroup:
    """One paged executable family: occupancy + page tables, no slot cache.

    Satisfies the slice of the ``SlotGroup`` surface the engine touches
    (``free_slots`` / ``occupied_slots`` / ``occupied`` / ``evict`` /
    ``n_slots`` / ``key`` / ``mask``). KV lives in the bound pool's page
    arrays; this object owns only the host-side per-slot metadata: the
    int32 page-table rows, write positions, next tokens, and gates."""

    def __init__(self, cfg_model, n_slots: int, max_row_pages: int,
                 scratch_page: int):
        self.key = "paged"
        self.mask = None
        self.cache_len = 0             # no dense cache — pages grow per token
        self.n_slots = n_slots
        self.max_row_pages = max_row_pages
        self.scratch_page = scratch_page
        self.occupants: List[Optional[str]] = [None] * n_slots
        # padded decode rows write their garbage KV into the scratch page
        self.table = np.full((n_slots, max_row_pages), scratch_page, np.int32)
        self.pos = np.zeros((n_slots,), np.int32)
        self.tokens = np.zeros((n_slots,), np.int32)
        L = cfg_model.n_layers
        self._gates_np = np.ones((2, L, n_slots), np.float32)

    def free_slots(self) -> List[int]:
        return [i for i, o in enumerate(self.occupants) if o is None]

    def occupied_slots(self) -> List[int]:
        return [i for i, o in enumerate(self.occupants) if o is not None]

    def occupied(self) -> bool:
        return any(o is not None for o in self.occupants)

    def evict(self, slots: List[int]) -> None:
        for s in slots:
            self.occupants[s] = None
            self.table[s] = self.scratch_page
            self.pos[s] = 0
            self.tokens[s] = 0
            self._gates_np[:, :, s] = 1.0


class PagedExecutor(ModelExecutor):
    """Physically paged KV execution (masked mode).

    The engine's :class:`~repro.runtime.kv_pool.KVPool` owns the page
    arrays (``bind_pool`` materializes them at pool capacity, once per
    run); this executor owns the executables around them:

      * **prefill** runs the gated full-sequence pass with its cache sized
        to the request's granted pages and scatters the KV *directly into
        those pages* inside the same jitted call (the pool arrays are
        donated through it);
      * **decode** batches any mix of cache lengths through one fused
        paged step (``repro.models.decoder.paged_decode_step``): per-slot
        page-table rows + write positions replace the pow2 cache-length
        group machinery entirely — there is ONE group regardless of
        request length, and a new token appends a page via
        ``KVPool.extend`` only when it crosses a page boundary.

    Dynamic decode-batch buckets work as in ``LocalExecutor``: occupied
    slots are stepped in the smallest bucket that holds them, padded with
    free slots whose page-table rows point at the pool's scratch page (so
    their garbage writes land in a write sink no request reads).

    Masked mode only: structural paged serving (compacted layer stacks
    over a shared pool) is a ROADMAP item. Uniform all-attention layouts
    only, and int8 KV pools are not yet supported — ``LocalExecutor`` is
    the reference backend for everything else.
    """

    paged = True

    def __init__(self, model, params, *, mode: str = "masked",
                 max_active: int = 8, kv_dtype=None,
                 decode_buckets: Sequence[int] = (1, 2, 4, 8)):
        if mode != "masked":
            raise NotImplementedError(
                f"PagedExecutor serves masked mode only (got {mode!r}); "
                "structural paged serving is a ROADMAP item — use "
                "LocalExecutor")
        layout = decoder.default_layout(model.cfg)
        if not (len(layout) > 0
                and all(s.mixer == "attn" and s.ffn == layout[0].ffn
                        for s in layout)):
            raise NotImplementedError(
                "PagedExecutor serves uniform all-attention layouts; "
                f"{model.cfg.name!r} mixes "
                f"{sorted({str(s.mixer) for s in layout})} — use "
                "LocalExecutor (slot caches) for heterogeneous models")
        if kv_dtype is not None and jnp.dtype(kv_dtype) == jnp.int8:
            raise NotImplementedError(
                "int8 KV pools need per-page scale pools (ROADMAP); use "
                "LocalExecutor for kv_dtype=int8")
        self.model = model
        self.mcfg = model.cfg
        self.params = params
        self.mode = "masked"
        self.max_active = int(max_active)
        self.kv_dtype = kv_dtype or model.cfg.jnp_dtype()
        self.decode_buckets = tuple(int(b) for b in decode_buckets or ())
        self.compile_events = 0
        self.pool = None               # bound per engine run
        self._group: Optional[PagedGroup] = None
        self._prefill_fns: Dict[Tuple, Any] = {}
        self._decode_widths: set = set()
        # "pallas" routes decode through the paged flash-decode kernel on
        # TPU; elsewhere the XLA gather fallback is the fast path (the
        # kernel still runs in CI via interpret-mode equivalence tests)
        self._impl = ("pallas" if jax.default_backend() == "tpu" else "xla")
        cfg = self.mcfg

        @functools.partial(jax.jit, donate_argnums=(1, 2))
        def _step(p, kp, vp, table, pos, tok, gm, gf):
            logits, pools = decoder.paged_decode_step(
                p, cfg, {"k": kp, "v": vp}, table, pos, tok,
                gates={"mixer": gm, "ffn": gf}, impl=self._impl)
            nxt = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
            return nxt, pools["k"], pools["v"]

        self._step = _step

    # ------------------------------------------------------------- binding
    def page_phys_bytes(self, tokens_per_page: int) -> int:
        """Exact bytes of one physical page across all layers (K and V)."""
        cfg = self.mcfg
        itemsize = jnp.dtype(self.kv_dtype).itemsize
        return (2 * cfg.n_layers * int(tokens_per_page) * cfg.n_kv_heads
                * cfg.dh * itemsize)

    def bind_pool(self, pool, max_len: int) -> None:
        """Attach this run's KVPool: materialize its page arrays and size
        the page-table width for ``max_len``-token requests."""
        pool.allocate_physical(n_layers=self.mcfg.n_layers,
                               n_kv_heads=self.mcfg.n_kv_heads,
                               head_dim=self.mcfg.dh, dtype=self.kv_dtype)
        self.pool = pool
        self.max_row_pages = -(-int(max_len) // pool.tokens_per_page)
        self._group = None

    # ------------------------------------------------------------ capacity
    def set_max_active(self, n_slots: int) -> None:
        if int(n_slots) == self.max_active:
            return
        self.max_active = int(n_slots)
        self._group = None

    def drop_groups(self) -> None:
        self._group = None

    # -------------------------------------------------------------- groups
    def groups(self) -> List[PagedGroup]:
        return [self._group] if self._group is not None else []

    def group_for(self, mask: np.ndarray, cache_len: int) -> PagedGroup:
        """One group hosts every request: pages make cache length a
        per-slot property, so there is nothing to key groups by."""
        if self.pool is None:
            raise RuntimeError("PagedExecutor has no bound pool — the "
                               "engine calls bind_pool() per run")
        if self._group is None:
            self._group = PagedGroup(self.mcfg, self.max_active,
                                     self.max_row_pages,
                                     self.pool.scratch_page)
        return self._group

    # ------------------------------------------------------------- prefill
    def _prefill_fn(self, b: int, S: int, npg: int):
        key = (b, S, npg)
        if key not in self._prefill_fns:
            cfg = self.mcfg
            pt = self.pool.tokens_per_page
            L = cfg.n_layers

            @functools.partial(jax.jit, donate_argnums=(4, 5))
            def fn(p, tokens, gm, gf, kp, vp, rows):
                logits, cache = decoder.prefill(
                    p, cfg, tokens, npg * pt,
                    gates={"mixer": gm, "ffn": gf}, kv_dtype=self.kv_dtype)
                k = cache["attn"]["k"].reshape(L, b, npg, pt, *kp.shape[3:])
                v = cache["attn"]["v"].reshape(L, b, npg, pt, *vp.shape[3:])
                kp = kp.at[:, rows].set(k.astype(kp.dtype))
                vp = vp.at[:, rows].set(v.astype(vp.dtype))
                return logits, kp, vp

            self._prefill_fns[key] = fn
            self.compile_events += 1
        return self._prefill_fns[key]

    def prefill_into(self, group: PagedGroup, slots: List[int], rid: str,
                     prompt: np.ndarray, mask: np.ndarray) -> np.ndarray:
        """Prefill the request, writing its KV straight into the pages the
        pool granted at admission; seat its rows in ``slots``."""
        b, S = prompt.shape
        rows = self.pool.row_pages(rid)            # [b][npg] page ids
        npg = len(rows[0])
        rows_np = np.asarray(rows, np.int32)
        fn = self._prefill_fn(b, S, npg)
        g = masks_lib.mask_to_gates(mask)
        logits, kp, vp = fn(self.params, jnp.asarray(prompt, jnp.int32),
                            g["mixer"], g["ffn"],
                            self.pool.k_pages, self.pool.v_pages,
                            jnp.asarray(rows_np))
        self.pool.k_pages, self.pool.v_pages = kp, vp
        first = np.asarray(jnp.argmax(logits, axis=-1).astype(jnp.int32))
        gates = masks_lib.mask_to_gates(mask)
        gm, gf = np.asarray(gates["mixer"]), np.asarray(gates["ffn"])
        for i, s in enumerate(slots):
            group.occupants[s] = rid
            group.table[s, :npg] = rows_np[i]
            group.table[s, npg:] = group.scratch_page
            group.pos[s] = S
            group.tokens[s] = first[i]
            group._gates_np[0, :, s] = gm
            group._gates_np[1, :, s] = gf
        return first

    # -------------------------------------------------------------- decode
    def _decode_batch(self, group: PagedGroup) -> List[int]:
        idx = _bucket_batch(group.occupied_slots(), group.free_slots(),
                            group.n_slots, self.decode_buckets)
        # full width: every slot steps (free rows write the scratch page)
        return idx if idx is not None else list(range(group.n_slots))

    def decode(self, group: PagedGroup) -> Tuple[np.ndarray, bool]:
        """Advance every occupied slot one token. Before stepping, each
        resident request appends one token to its pool allocation —
        crossing a page boundary grants fresh pages whose ids extend the
        slot's page-table row (this is where per-token paging happens)."""
        occ = group.occupied_slots()
        seen = set()
        for s in occ:
            rid = group.occupants[s]
            if rid in seen:
                continue
            seen.add(rid)
            rid_slots = [t for t in occ if group.occupants[t] == rid]
            new_rows = self.pool.extend(rid, 1)    # [batch][0 or 1] pages
            if any(new_rows):
                npg_now = len(self.pool.row_pages(rid)[0])
                for i, t in enumerate(rid_slots):
                    for j, page in enumerate(new_rows[i]):
                        group.table[t, npg_now - len(new_rows[i]) + j] = page
        idx = self._decode_batch(group)
        width = len(idx)
        new = width not in self._decode_widths
        self._decode_widths.add(width)
        if new:
            self.compile_events += 1
        iidx = np.asarray(idx)
        nxt, kp, vp = self._step(
            self.params, self.pool.k_pages, self.pool.v_pages,
            jnp.asarray(group.table[iidx]), jnp.asarray(group.pos[iidx]),
            jnp.asarray(group.tokens[iidx])[:, None],
            jnp.asarray(group._gates_np[0][:, iidx]),
            jnp.asarray(group._gates_np[1][:, iidx]))
        self.pool.k_pages, self.pool.v_pages = kp, vp
        nxt = np.asarray(nxt)
        out = np.zeros((group.n_slots,), np.int32)
        for j, s in enumerate(idx):
            if group.occupants[s] is not None:
                out[s] = nxt[j]
                group.tokens[s] = nxt[j]
                group.pos[s] += 1
        return out, new

    # ---------------------------------------------------------- utilization
    def kv_utilization(self) -> Tuple[float, float]:
        """used = tokens actually written by resident requests; physical =
        bytes of the pages they hold. Waste is bounded by one partial page
        per row — the whole point of paging."""
        if self.pool is None or self._group is None:
            return 0.0, 0.0
        pt = self.pool.tokens_per_page
        tok_bytes = self.pool.page_bytes / pt
        occ = self._group.occupied_slots()
        used = float(self._group.pos[np.asarray(occ)].sum()) * tok_bytes \
            if occ else 0.0
        return used, self.pool.bytes_reserved

    # --------------------------------------------------------------- stats
    def stats(self) -> Dict[str, int]:
        return {
            "groups": 1 if self._group is not None else 0,
            "prefill_executables": len(self._prefill_fns),
            "decode_widths": len(self._decode_widths),
            "compile_events": self.compile_events,
        }


# ----------------------------------------------------------------- sharded
class ShardedExecutor(ModelExecutor):
    """Mesh-placed execution (ROADMAP: sharded serving).

    Today this stub owns the *placement* half: parameters are sharded with
    the production partition rules (``repro.parallel.sharding``) and a
    sharded decode step can be lowered for roofline/cost analysis — the
    path ``launch/rap_sweep.py`` drives. The slot-batched serve methods
    raise until per-group mesh execution lands.
    """

    def __init__(self, model, mesh, *, params=None, fsdp: bool = False,
                 shard_seq: bool = False, kv_int8: bool = False):
        self.model = model
        self.mcfg = model.cfg
        self.mesh = mesh
        self.policy = {"fsdp": bool(fsdp), "shard_seq": bool(shard_seq),
                       "kv_int8": bool(kv_int8)}
        self.compile_events = 0
        self.params = self.place_params(params) if params is not None else None

    # ----------------------------------------------------------- placement
    def param_shardings(self):
        from repro.parallel import param_pspecs, shardings_for
        shapes = jax.eval_shape(lambda: self.model.init(jax.random.key(0)))
        return shardings_for(param_pspecs(shapes, self.mesh,
                                          fsdp=self.policy["fsdp"]),
                             self.mesh)

    def place_params(self, params):
        """Place a params pytree on the mesh under the production rules."""
        return jax.device_put(params, self.param_shardings())

    def lower_decode(self, shape):
        """Lower one sharded fused decode step for ``shape`` (a
        ``repro.configs`` request shape) and return the ``Lowered`` —
        callers compile it for HLO cost / memory / collective analysis."""
        from repro.parallel import (batch_pspecs, cache_pspecs, param_pspecs,
                                    shardings_for)
        from repro.parallel import activation as act
        from repro.runtime import steps as steps_lib
        model, mesh, policy = self.model, self.mesh, self.policy
        with act.use(mesh, shard_seq=policy["shard_seq"],
                     fsdp=policy["fsdp"]):
            params_shape = jax.eval_shape(
                lambda: model.init(jax.random.key(0)))
            psh = shardings_for(param_pspecs(params_shape, mesh,
                                             fsdp=policy["fsdp"]), mesh)
            specs = model.input_specs(shape)
            bsh = shardings_for(batch_pspecs(specs, mesh), mesh)
            kv_dtype = jnp.int8 if policy["kv_int8"] else None
            cache_shape = jax.eval_shape(
                lambda: model.init_cache(shape.global_batch, shape.seq_len,
                                         kv_dtype=kv_dtype))
            csh = shardings_for(
                cache_pspecs(cache_shape, mesh, batch=shape.global_batch,
                             shard_seq=policy["shard_seq"]), mesh)
            fn = steps_lib.make_decode_step(model)
            jfn = jax.jit(fn, in_shardings=(psh, csh, bsh["tokens"]),
                          out_shardings=(None, csh), donate_argnums=(1,))
            return jfn.lower(params_shape, cache_shape, specs["tokens"])

    # ------------------------------------------------------------ serve API
    def _todo(self):
        raise NotImplementedError(
            "sharded slot-batched serving is a ROADMAP item ('Sharded "
            "serving'); construct RAPEngine with a LocalExecutor, or use "
            "ShardedExecutor.lower_decode() for mesh cost analysis")

    def group_for(self, mask, cache_len):
        self._todo()

    def prefill_into(self, group, slots, rid, prompt, mask):
        self._todo()

    def decode(self, group):
        self._todo()

    def groups(self) -> List[SlotGroup]:
        return []
