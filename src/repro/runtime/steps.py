"""Step functions shared by the trainer, the server, and the AOT dry-run.

Everything here is a pure function of (params, state, batch) so the same
code path is jitted for real execution and ``.lower().compile()``d against
ShapeDtypeStructs for the 512-device dry-run.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.optim import adamw
from repro.parallel import compression


def make_train_step(model, opt_cfg: adamw.AdamWConfig, *,
                    remat: bool = True, impl: str = "xla",
                    microbatches: int = 1) -> Callable:
    """(params, opt_state, batch) → (params', opt_state', metrics).

    ``microbatches > 1`` scans gradient accumulation over batch slices —
    activation memory scales 1/m at the cost of an f32 gradient
    accumulator. The standard fit lever for the 100B+ configs whose
    backward working set exceeds HBM even with remat + sequence-parallel
    activations (dbrx-132b × train_4k)."""

    def loss_on(p, b):
        loss, aux = model.loss(p, b, impl=impl, remat=remat)
        return loss, aux

    if microbatches <= 1:
        def train_step(params, opt_state, batch):
            (loss, aux), grads = jax.value_and_grad(
                loss_on, has_aux=True)(params, batch)
            params, opt_state, om = adamw.apply(opt_cfg, params, grads,
                                                opt_state)
            return params, opt_state, {**aux, **om}
        return train_step

    m = microbatches

    def train_step(params, opt_state, batch):
        mb = jax.tree.map(
            lambda x: x.reshape(m, x.shape[0] // m, *x.shape[1:]), batch)

        def accum(gsum, one_batch):
            (loss, aux), g = jax.value_and_grad(
                loss_on, has_aux=True)(params, one_batch)
            gsum = jax.tree.map(
                lambda a, b: a + b.astype(jnp.float32), gsum, g)
            return gsum, aux

        gsum0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                             params)
        gsum, auxes = jax.lax.scan(accum, gsum0, mb)
        grads = jax.tree.map(lambda g: g / m, gsum)
        aux = jax.tree.map(lambda a: jnp.mean(a), auxes)
        params, opt_state, om = adamw.apply(opt_cfg, params, grads,
                                            opt_state)
        return params, opt_state, {**aux, **om}

    return train_step


def make_compressed_train_step(model, opt_cfg: adamw.AdamWConfig, mesh, *,
                               pspecs, batch_pspecs_tree,
                               remat: bool = True) -> Callable:
    """Train step with explicit int8 error-feedback DP all-reduce.

    The model runs replicated per DP shard inside ``shard_map`` (TP is not
    composed here — this variant is for parameter-light models where the DP
    gradient all-reduce dominates); gradients cross ICI as int8.

    (params, opt_state, residuals, batch) → (params', opt', residuals', m).
    """
    from repro.compat import shard_map
    from jax.sharding import PartitionSpec as P

    dp_axes = tuple(a for a in ("pod", "data") if a in mesh.shape)

    def step(params, opt_state, residuals, batch):
        def loss_fn(p):
            loss, aux = model.loss(p, batch, remat=remat)
            return loss, aux

        (loss, aux), grads = jax.value_and_grad(loss_fn, has_aux=True)(params)
        grads, residuals = compression.compress_allreduce(
            grads, residuals, dp_axes)
        params, opt_state, om = adamw.apply(opt_cfg, params, grads, opt_state)
        metrics = {k: jax.lax.pmean(v, dp_axes)
                   for k, v in {**aux, **om}.items()}
        return params, opt_state, residuals, metrics

    rep = jax.tree.map(lambda _: P(), jax.eval_shape(lambda: 0))
    del rep
    param_spec = P()          # replicated params (DP-only variant)
    return shard_map(
        step, mesh=mesh,
        in_specs=(param_spec, param_spec, param_spec, batch_pspecs_tree),
        out_specs=(param_spec, param_spec, param_spec, P()),
        check_vma=False)


def make_prefill_step(model, max_len: int, *, impl: str = "xla",
                      kv_dtype=None, gates: bool = False) -> Callable:
    """(params, batch[, gates]) → (last_logits, cache)."""
    if gates:
        def prefill_step(params, batch, gate_vals):
            return model.prefill(params, batch, max_len, gates=gate_vals,
                                 impl=impl, kv_dtype=kv_dtype)
    else:
        def prefill_step(params, batch):
            return model.prefill(params, batch, max_len, impl=impl,
                                 kv_dtype=kv_dtype)
    return prefill_step


def make_decode_step(model, *, impl: str = "xla",
                     gates: bool = False) -> Callable:
    """(params, cache, tokens[, gates]) → (logits, cache)."""
    if gates:
        def decode_step(params, cache, tokens, gate_vals):
            return model.decode(params, cache, tokens, gates=gate_vals,
                                impl=impl)
    else:
        def decode_step(params, cache, tokens):
            return model.decode(params, cache, tokens, impl=impl)
    return decode_step


def make_eval_step(model, *, impl: str = "xla") -> Callable:
    def eval_step(params, batch, gate_vals=None):
        loss, aux = model.loss(params, batch, gates=gate_vals, impl=impl)
        return aux
    return eval_step
