"""Continuous-batching RAP engine — shared-budget serving of concurrent
requests (the production form of paper Algorithm 3).

``RAPServer`` replays requests one at a time, so each request sees a
*private* instantaneous budget and "runtime memory variation" is simulated.
The engine makes the contention real: many in-flight requests compete for
one device budget, and the policy's keep-mask decision is made against
whatever the *pool* has left.

Since the serving-API split (DESIGN.md §2) the engine is a thin
orchestration loop over four seams:

  * :class:`~repro.runtime.scheduler.Scheduler` — who is admitted next
    (FIFO / SJF / priority), emitting explicit ``SchedulerOutput`` plans;
  * :class:`~repro.core.policy.PruningPolicy` — what shape they run in:
    ``observe(PolicyState) → Decision`` against the remaining shared
    budget (the RL controller, any static baseline, or dense);
  * :class:`~repro.runtime.executor.ModelExecutor` — how the mask
    executes: slot groups, prefill, fused bucketed decode;
  * :class:`~repro.runtime.kv_pool.KVPool` — whether the bytes exist:
    page-granular admission against ``budget − resident params``. With a
    paged executor (``PagedExecutor``) the pool additionally OWNS the
    physical page arrays: admission charges the request's worst-case page
    count as a commitment, prefill writes into granted pages, each decoded
    token appends a page when it crosses a boundary (``KVPool.extend``),
    and completion frees the pages.

One iteration of :meth:`RAPEngine._tick` (the async macro-tick,
DESIGN.md §6 — device work is dispatched FIRST so host scheduling
overlaps the in-flight scans):

  1. **launch** — every occupied group in the scheduler's decode plan
     dispatches one fused horizon of up to ``EngineConfig.decode_horizon``
     tokens (DESIGN.md §5). JAX async dispatch returns token futures
     immediately; nothing syncs yet;
  2. **arrivals** — requests become visible at their trace timestamps
     (virtual clock; idle gaps are skipped, compute time is real) and
     enter the scheduler's waiting set;
  3. **admission** — the scheduler orders candidates; for each, the
     policy decides a keep-mask against the *remaining* shared budget and
     the request's analytical KV/state bytes are allocated from the pool.
     A deferral (no pages / no free slots) ends the admission loop, so
     the scheduler's ordering is never overtaken within a tick. ``force``
     mode (the one-shot compatibility path) admits regardless and records
     the overcommit. Prefill is monolithic by default; with
     ``EngineConfig.max_prefill_tokens > 0`` prompts are split into pow2
     chunks advanced one per tick, interleaved with running decodes;
  4. **finish** — the single device→host read-back folds each horizon's
     tokens into the requests that were resident at launch. Completion
     (``max_new`` today; an EOS-style stop condition, when one lands,
     would share the same boundary semantics) is checked once per
     horizon; tokens a request over-generated inside its final horizon
     are truncated, so results are bitwise-identical to H=1.

Completed requests free their pages and slots, unblocking the queue, and
are reported back to the policy via ``feedback()``.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

from repro.core import masks as masks_lib
from repro.core.controller import RAPController
from repro.core.policy import Decision, PolicyState, PruningPolicy
from repro.runtime.executor import (LocalExecutor, ModelExecutor, SlotGroup,
                                    chunk_widths)
from repro.runtime.latency import summarize as _lat_summarize
from repro.runtime.kv_pool import (KVPool, default_page_bytes,
                                   resolve_kv_dtype)
from repro.runtime.scheduler import Scheduler, make_scheduler

__all__ = ["EngineConfig", "EngineRequest", "RequestResult", "EngineReport",
           "RAPEngine"]

_MIGRATION_HINT = (
    "RAPEngine's constructor changed with the serving-API split: it now "
    "takes a PruningPolicy instead of a RAPController. Wrap your "
    "controller — RAPEngine(model, params, "
    "repro.core.policy.RLPolicy(controller), cfg) — or build any "
    "registered policy with repro.core.policy.make_policy(). Schedulers "
    "and executors are injectable via the scheduler=/executor= kwargs."
)


def _next_pow2(n: int) -> int:
    return 1 << max(int(n) - 1, 0).bit_length()


def _kv_byte_ratio(kv_dtype, mcfg) -> float:
    """Quantized-vs-model KV byte ratio for slot-cache admission.

    int8/fp8 slot caches store 1-byte elements plus one f32 scale per
    (token, kv-head) (``attention.kv_quant``), while the analytical memory
    model charges at the model's KV width — the ratio converts an
    Eq. (3)–(4) charge into the bytes the cache actually occupies."""
    _, _, quantized, _ = resolve_kv_dtype(kv_dtype)
    if not quantized:
        return 1.0
    from repro.core.memory import dtype_bytes
    dh = max(int(mcfg.dh), 1)
    return (dh * 1.0 + 4.0) / (dh * dtype_bytes(mcfg.dtype))


# ------------------------------------------------------------------- config
@dataclasses.dataclass
class EngineConfig:
    mode: str = "masked"              # masked | structural
    max_new_tokens: int = 16
    max_active: int = 8               # cache slots per group (decode batch)
    max_len: int = 256                # slot cache length (prompt + generated)
    budget_bytes: float = 0.0         # TOTAL device budget (params + states)
    page_bytes: int = 0               # 0 → derived from the memory model
    tokens_per_page: int = 16
    kv_dtype: Any = None
    admission: str = "strict"         # strict (queue) | force (overcommit)
    # Admission quantizes the effective budget DOWN to this fraction of the
    # request's dense peak before calling the policy. The pool level drifts
    # continuously; without a quantum every admission sees a fresh budget,
    # the policy emits a fresh mask, and structural mode compiles a fresh
    # bucket — quantizing collapses steady-state admissions onto a handful
    # of memoized decisions/buckets. Safety is unaffected: the page
    # allocator, not the decision, enforces the byte budget.
    budget_quantum_frac: float = 0.05
    # "pow2": slot caches are minted per power-of-two length bucket (the
    # group key includes the bucket), so one long prompt mints a long-cache
    # group instead of invalidating every compiled short one, and short
    # requests keep decoding against short caches — the RAPServer shim's
    # setting (sequential serves, heterogeneous lengths). "max" (default):
    # one max_len-sized cache per group family — requests of every length
    # share one decode batch, which is what continuous batching is for;
    # splitting by length would fragment the fused decode step per bucket.
    len_buckets: str = "max"          # max | pow2
    # Decode batch buckets: the executor steps occupied slots in the
    # smallest bucket that holds them instead of always paying
    # max_active-wide compute. () disables (always full width).
    decode_buckets: Tuple[int, ...] = (1, 2, 4, 8)
    # Horizon decode (DESIGN.md §5): each engine macro-tick advances every
    # running request up to this many tokens through ONE fused on-device
    # loop per group, with completion checked at the horizon boundary and
    # over-generated tokens truncated (token streams are bitwise-identical
    # to decode_horizon=1). Clamped per tick to the largest remaining
    # token need in the group, so short tails don't pay full-horizon
    # compute — and, while requests are queued, to the group's SOONEST
    # completion, so a full horizon can't stall admission behind its
    # longest resident. 1 restores per-token ticks.
    decode_horizon: int = 8
    # Chunked prefill (DESIGN.md §6): 0 (default) prefills each prompt in
    # one monolithic pass; >0 caps the prompt tokens prefilled per engine
    # macro-tick — long prompts are split into power-of-two chunks
    # (largest-first, e.g. 13 → 8+4+1 under a cap of 8) interleaved with
    # the running requests' decode horizons, so a long prefill no longer
    # stalls every in-flight decode for its full length. Token streams are
    # bitwise-identical with chunking on or off. Backends without a
    # chunked path (heterogeneous layouts) fall back to monolithic.
    max_prefill_tokens: int = 0

    def __post_init__(self):
        if self.mode not in ("masked", "structural"):
            raise ValueError(f"unknown mode {self.mode!r}")
        if self.admission not in ("strict", "force"):
            raise ValueError(f"unknown admission {self.admission!r}")
        if self.len_buckets not in ("pow2", "max"):
            raise ValueError(f"unknown len_buckets {self.len_buckets!r} "
                             f"(expected 'pow2' or 'max')")
        if not (0.0 <= self.budget_quantum_frac <= 1.0):
            raise ValueError(
                f"budget_quantum_frac must be in [0, 1], got "
                f"{self.budget_quantum_frac!r} — it is a fraction of the "
                f"request's dense peak (0 disables admission quantization)")
        if self.max_active < 1:
            raise ValueError(
                f"max_active must be >= 1, got {self.max_active!r} — the "
                f"engine needs at least one cache slot to host a request")
        if self.max_len < 1:
            raise ValueError(
                f"max_len must be >= 1, got {self.max_len!r} — slot caches "
                f"must hold at least one token (prompt + generated)")
        if self.max_new_tokens < 0:
            raise ValueError(
                f"max_new_tokens must be >= 0, got {self.max_new_tokens!r}")
        if self.tokens_per_page < 1:
            raise ValueError(
                f"tokens_per_page must be >= 1, got "
                f"{self.tokens_per_page!r} — KV pool pages hold at least "
                f"one token of dense per-token state")
        if self.budget_bytes < 0:
            raise ValueError(
                f"budget_bytes must be >= 0, got {self.budget_bytes!r} "
                f"(0 means 'pass the budget per run() call')")
        if self.page_bytes < 0:
            raise ValueError(
                f"page_bytes must be >= 0, got {self.page_bytes!r} "
                f"(0 derives the page size from the memory model)")
        if any(int(b) < 1 for b in self.decode_buckets):
            raise ValueError(
                f"decode_buckets must be positive slot counts, got "
                f"{self.decode_buckets!r}")
        if self.decode_horizon < 1:
            raise ValueError(
                f"decode_horizon must be >= 1, got {self.decode_horizon!r} "
                f"— each macro-tick advances at least one token")
        if self.max_prefill_tokens < 0:
            raise ValueError(
                f"max_prefill_tokens must be >= 0, got "
                f"{self.max_prefill_tokens!r} (0 prefills prompts "
                f"monolithically; >0 caps prompt tokens prefilled per "
                f"engine tick)")


@dataclasses.dataclass
class EngineRequest:
    rid: str                          # unique among in-flight requests
    prompt: np.ndarray                # int32 [b, S]
    arrival_t: float = 0.0
    max_new: Optional[int] = None     # generated tokens (≥1: prefill always
                                      # yields one); None → engine default
    priority: int = 0                 # PriorityScheduler rank (lower=sooner)


@dataclasses.dataclass
class RequestResult:
    rid: str
    status: str                       # done | rejected
    tokens: Optional[np.ndarray]      # [b, generated]
    mask: Optional[np.ndarray]
    bucket: Tuple
    arrival_t: float
    admitted_t: float
    finished_t: float
    queue_delay_s: float
    decide_s: float
    fits: bool
    cached_decision: bool
    peak_bytes: float
    kv_bytes: float
    reason: str = ""
    # time to first token, measured from ARRIVAL (so it decomposes as
    # queue_delay_s + prefill time; -1.0 for rejected requests)
    ttft_s: float = -1.0


@dataclasses.dataclass
class EngineReport:
    results: List[RequestResult]
    wall_s: float                     # real compute wall time
    makespan_s: float                 # virtual: includes skipped arrival gaps
    generated_tokens: int
    tokens_per_s: float               # generated / makespan_s
    mean_queue_delay_s: float
    budget_fit_rate: float            # admitted requests whose peak fit
    rejected: int
    decode_iters: int                 # macro-ticks (horizons), not tokens
    compile_events: int
    pool: Dict[str, float]
    # wall time spent inside compiled-executable launches + read-backs
    # (prefill and decode horizons): wall_s − launch_s is the host-side
    # orchestration share the horizon decode exists to shrink
    launch_s: float = 0.0
    # measured physical KV fragmentation: mean over decode ticks of
    # 1 − used_bytes / physical_bytes from the executor's kv_utilization()
    # (0.0 when the backend does not track it)
    measured_frag: float = 0.0
    # latency percentiles (repro.runtime.latency.summarize dicts, seconds):
    # ttft pools per-request time-to-first-token (arrival → first token);
    # itl pools per-token inter-token latencies across every request's
    # decode stream (a fused H-token horizon contributes H samples of its
    # per-token share)
    ttft: Dict[str, float] = dataclasses.field(default_factory=dict)
    itl: Dict[str, float] = dataclasses.field(default_factory=dict)

    def result(self, rid: str) -> RequestResult:
        for r in self.results:
            if r.rid == rid:
                return r
        raise KeyError(rid)


@dataclasses.dataclass
class _Running:
    req: EngineRequest
    decision: Decision
    group: SlotGroup
    slots: List[int]
    admitted_t: float
    kv_bytes: float
    max_new: int
    out: List[np.ndarray]            # per generated step: [b] tokens
    bucket: Tuple
    # token-emission events (virtual-clock time, tokens appended): the
    # first entry is the prefill's token #1 (TTFT anchor); each decode
    # horizon appends one entry covering its H tokens (ITL samples)
    events: List[Tuple[float, int]] = dataclasses.field(default_factory=list)


@dataclasses.dataclass
class _Prefilling:
    """A request admitted (pool charged, slots reserved) whose prompt is
    still being prefilled chunk-by-chunk across engine ticks."""
    req: EngineRequest
    decision: Decision
    group: SlotGroup
    slots: List[int]
    admitted_t: float
    kv_bytes: float
    max_new: int
    bucket: Tuple
    task: Any                        # executor _PrefillTask


# ------------------------------------------------------------------- engine
class RAPEngine:
    """Thin orchestration loop: Scheduler × PruningPolicy × ModelExecutor
    × KVPool."""

    def __init__(self, model, params, policy: PruningPolicy = None,
                 cfg: EngineConfig = None, *,
                 scheduler: Optional[Scheduler] = None,
                 executor: Optional[ModelExecutor] = None, **legacy):
        if legacy:
            raise TypeError(
                f"RAPEngine got unexpected kwargs {sorted(legacy)}. "
                + _MIGRATION_HINT)
        if isinstance(policy, RAPController):
            raise TypeError(
                "RAPEngine received a RAPController where a PruningPolicy "
                "is expected. " + _MIGRATION_HINT)
        if policy is None or not isinstance(policy, PruningPolicy):
            raise TypeError(
                f"RAPEngine requires a PruningPolicy, got "
                f"{type(policy).__name__}. " + _MIGRATION_HINT)
        self.model = model
        self.mcfg = model.cfg
        if getattr(self.mcfg, "is_encoder_decoder", False):
            raise NotImplementedError("engine serves decoder-only models")
        self.params = params
        self.policy = policy
        # private copy: ensure_capacity mutates max_len/max_active, and a
        # caller-shared config would desync another engine's shape checks
        # from its actual cache sizes
        self.cfg = dataclasses.replace(cfg if cfg is not None
                                       else EngineConfig())
        self.mm = policy.mm
        self.scheduler = make_scheduler(scheduler)
        self.executor = executor if executor is not None else LocalExecutor(
            model, params, mode=self.cfg.mode, max_active=self.cfg.max_active,
            kv_dtype=self.cfg.kv_dtype,
            decode_buckets=self.cfg.decode_buckets)
        self._paged = bool(getattr(self.executor, "paged", False))
        if self._paged:
            if self.cfg.mode != "masked":
                raise ValueError(
                    "a paged executor serves masked mode only (structural "
                    "paged serving is a ROADMAP item); set "
                    "EngineConfig(mode='masked') or use LocalExecutor")
            if self.cfg.admission != "strict":
                raise ValueError(
                    "a paged executor requires strict admission: overflow "
                    "pages have no physical backing to write KV into")
        # precision as a policy action: when the stack was built with a
        # canonical KV precision (cfg.kv_dtype or a quantized executor),
        # stamp it on the policy so every Decision carries it — admission
        # then charges quantized bytes and the pool's dtype check has a
        # request-side precision to validate. Launchers may override
        # policy.kv_dtype afterwards for per-run choices.
        kv_name = getattr(self.executor, "kv_dtype_name", None)
        if kv_name is None:
            kv_name, _, _, _ = resolve_kv_dtype(self.cfg.kv_dtype)
        if kv_name is not None and getattr(policy, "kv_dtype", None) is None:
            policy.kv_dtype = kv_name
        self._full_mask = masks_lib.full_mask(self.mcfg.n_layers)
        self.resident_param_bytes = self.mm.param_bytes(self._full_mask)
        self.pool: Optional[KVPool] = None
        # run state
        self._pending: List[EngineRequest] = []
        self._running: "Dict[str, _Running]" = {}
        self._prefilling: "Dict[str, _Prefilling]" = {}
        self._results: List[RequestResult] = []
        self._ttft_samples: List[float] = []
        self._itl_samples: List[float] = []
        self._decode_iters = 0
        self._compiles_at_run_start = 0
        self._t0 = 0.0
        self._skew = 0.0
        self._budget = self.cfg.budget_bytes
        self._frag_samples: List[float] = []

    # ------------------------------------------------------------ capacity
    def ensure_capacity(self, batch: int, total_len: int) -> None:
        """Grow slot count / cache-length cap. Slot growth drops compiled
        groups (the slot axis changes); length growth is quantized to
        powers of two and — under pow2 length buckets — keeps every
        existing group valid (they own their own shorter caches)."""
        if total_len > self.cfg.max_len:
            self.cfg.max_len = _next_pow2(total_len)
            if self.cfg.len_buckets == "max":
                # legacy single-length groups are sized by cfg.max_len:
                # growth invalidates them
                self.executor.drop_groups()
        if batch > self.cfg.max_active:
            self.cfg.max_active = int(batch)
            self.executor.set_max_active(self.cfg.max_active)

    def _cache_len(self, total: int) -> int:
        """Cache length bucket hosting a (prompt+gen)-token request.

        pow2 buckets deliberately ignore cfg.max_len (admission already
        guaranteed total ≤ max_len): clamping to a non-power-of-two cap
        would remap the same request shape to a different bucket after
        capacity growth, re-introducing the recompile the buckets exist
        to prevent."""
        if self.cfg.len_buckets == "pow2":
            return max(_next_pow2(total), 16)
        return self.cfg.max_len

    # ---------------------------------------------------------------- time
    def _now(self) -> float:
        return (time.perf_counter() - self._t0) + self._skew

    # ---------------------------------------------------------------- pool
    def _make_pool(self, budget_bytes: float) -> KVPool:
        if self._paged:
            # physical page size is dictated by the model's KV geometry
            # (cfg.page_bytes would desync the ledger from the arrays)
            page = self.executor.page_phys_bytes(self.cfg.tokens_per_page)
        else:
            page = self.cfg.page_bytes or default_page_bytes(
                self.mm, self.cfg.tokens_per_page)
        cap = budget_bytes - self.resident_param_bytes
        if cap < page and self.cfg.admission == "strict":
            raise ValueError(
                f"budget {budget_bytes:.0f}B leaves no KV pool after "
                f"resident params ({self.resident_param_bytes:.0f}B)")
        return KVPool(max(cap, 0.0), page_bytes=page, mm=self.mm,
                      tokens_per_page=(self.cfg.tokens_per_page
                                       if self._paged else None))

    # ------------------------------------------------------------- serving
    def run(self, requests: List[EngineRequest], *,
            budget_bytes: Optional[float] = None) -> EngineReport:
        """Serve a trace to completion and report aggregate stats."""
        budget = self.cfg.budget_bytes if budget_bytes is None else budget_bytes
        self.pool = self._make_pool(budget)
        if self._paged:
            self.executor.bind_pool(self.pool, self.cfg.max_len)
        self._budget = budget
        self._frag_samples: List[float] = []
        self._pending = sorted(requests, key=lambda r: r.arrival_t)
        self.scheduler.clear()
        self._running.clear()
        self._prefilling.clear()
        self._results = []
        self._ttft_samples = []
        self._itl_samples = []
        self._decode_iters = 0
        self._compiles_at_run_start = self.executor.compile_events
        self._launch_s_at_run_start = getattr(self.executor, "launch_s", 0.0)
        self._skew = 0.0
        self._t0 = time.perf_counter()
        self.executor.evict_all()             # previous run's occupants
        while (self._pending or len(self.scheduler) or self._running
               or self._prefilling):
            self._tick()
        # makespan is on the VIRTUAL clock (skipped idle gaps included) —
        # the same clock request timestamps live on, so throughput is
        # comparable with any other replay of the same arrival process
        makespan = self._now()
        wall = time.perf_counter() - self._t0
        done = [r for r in self._results if r.status == "done"]
        gen = sum(r.tokens.size for r in done if r.tokens is not None)
        delays = [r.queue_delay_s for r in done]
        return EngineReport(
            results=self._results,
            wall_s=wall,
            makespan_s=makespan,
            generated_tokens=gen,
            tokens_per_s=gen / max(makespan, 1e-9),
            mean_queue_delay_s=float(np.mean(delays)) if delays else 0.0,
            budget_fit_rate=(float(np.mean([r.fits for r in done]))
                             if done else 0.0),
            rejected=sum(1 for r in self._results if r.status == "rejected"),
            decode_iters=self._decode_iters,
            compile_events=(self.executor.compile_events
                            - self._compiles_at_run_start),
            pool=self.pool.stats(),
            launch_s=(getattr(self.executor, "launch_s", 0.0)
                      - self._launch_s_at_run_start),
            measured_frag=(float(np.mean(self._frag_samples))
                           if self._frag_samples else 0.0),
            ttft=_lat_summarize(self._ttft_samples),
            itl=_lat_summarize(self._itl_samples))

    # ------------------------------------------------------------ one tick
    def _tick(self) -> None:
        """One engine macro-tick, host work overlapped with device work:

          1. **launch** — dispatch this tick's fused decode horizons (the
             scheduler's decode plan). JAX async dispatch returns the
             token futures immediately, so the scans run on device while…
          2. **host phase** — arrivals, admission (policy decision, pool
             allocation, page granting), and one chunk of every in-flight
             chunked prefill all execute on the host with the scans still
             in flight (pinned by the transfer-guard overlap tests in
             tests/test_horizon.py);
          3. **finish** — the single device→host read-back folds the
             horizon's tokens into the running requests and completions
             are processed.

        A request admitted during the host phase joins decode from the
        NEXT tick — its slots were free padding (or reserved) when this
        tick's scan launched, so this tick's rows for them are garbage
        and are never read (the launch's captured occupancy pins this)."""
        now = self._now()
        plan = self.scheduler.schedule(now, running=list(self._running))
        backlog = (len(self.scheduler) > 0
                   or bool(self._pending
                           and self._pending[0].arrival_t <= now))
        launches = self._launch_decode(plan.decode, backlog=backlog)
        # ---- host phase (device scans in flight from here to finish) ----
        while self._pending and self._pending[0].arrival_t <= now:
            req = self._pending.pop(0)
            if (req.rid in self.scheduler or req.rid in self._running
                    or req.rid in self._prefilling):
                self._reject(req, f"duplicate request id {req.rid!r} "
                                  f"(already in flight)")
                continue
            max_new = (self.cfg.max_new_tokens if req.max_new is None
                       else req.max_new)
            # total token cost: batch rows each hold prompt+decode tokens
            # (this is what scales the request's KV demand — SJF orders
            # by it)
            cost = req.prompt.shape[0] * (req.prompt.shape[1]
                                          + max(max_new, 1))
            self.scheduler.add(req, cost=cost)
        # admission plan: try candidates in the scheduler's order; a
        # deferral ends the loop so the order is never overtaken in-tick
        deferred = None
        for req in self.scheduler.schedule(now).admit:
            verdict = self._try_admit(req)
            if verdict == "defer":
                deferred = req
                break
            self.scheduler.remove(req.rid)
        # a deferral is "stuck" only if judged NOW, before this tick's
        # in-flight work lands: with nothing launched, running, or
        # prefilling, no completion can ever free the memory it waits on.
        # (Work finishing later this tick frees capacity — the deferred
        # request simply retries next tick.)
        stuck = (deferred is not None and not launches
                 and not self._running and not self._prefilling)
        self._advance_prefills()
        # ---- finish: the tick's one sync point --------------------------
        if launches:
            self._finish_decode(launches)
        if not self._running and not self._prefilling:
            if stuck:
                # deferred head with an idle engine: reject the
                # scheduler's choice instead of spinning (defensive;
                # strict capacity misfits are rejected in _try_admit
                # already)
                self.scheduler.remove(deferred.rid)
                self._reject(deferred, "deferred with idle engine")
            elif deferred is None and self._pending:
                # fast-forward the virtual clock across the idle gap
                self._skew += self._pending[0].arrival_t - self._now() + 1e-9

    # ----------------------------------------------------------- admission
    def _reject(self, req: EngineRequest, reason: str) -> None:
        now = self._now()
        self._results.append(RequestResult(
            rid=req.rid, status="rejected", tokens=None, mask=None,
            bucket=(), arrival_t=req.arrival_t, admitted_t=-1.0,
            finished_t=now, queue_delay_s=now - req.arrival_t,
            decide_s=0.0, fits=False, cached_decision=False,
            peak_bytes=0.0, kv_bytes=0.0, reason=reason))

    def _try_admit(self, req: EngineRequest) -> str:
        """→ 'admitted' | 'defer' | 'rejected' (rejection recorded here)."""
        b, S = req.prompt.shape
        max_new = (self.cfg.max_new_tokens if req.max_new is None
                   else req.max_new)
        # prefill always yields one token, so the floor is 1 (a max_new=0
        # request is served as prefill-only next-token prediction)
        max_new = max(max_new, 1)
        total = S + max_new
        if req.rid in self._running or req.rid in self._prefilling:
            self._reject(req, f"duplicate request id {req.rid!r} "
                              f"(already in flight)")
            return "rejected"
        if total > self.cfg.max_len or b > self.cfg.max_active:
            if self.cfg.admission != "force":
                self._reject(req, f"shape (b={b}, prompt+gen={total}) "
                                  f"exceeds engine capacity "
                                  f"({self.cfg.max_active} slots × "
                                  f"{self.cfg.max_len})")
                return "rejected"
            if self._running:
                return "defer"   # growth drops live caches; wait for drain
            self.ensure_capacity(b, total)

        # keep-mask against the REMAINING shared budget (quantized down so
        # steady-state admissions hit the policy's memo table)
        eff = self._budget - self.pool.bytes_reserved
        quantum = self.cfg.budget_quantum_frac * self.mm.dense_peak(b, total)
        if quantum > 0 and self.cfg.admission == "strict":
            # (force mode is the one-shot compatibility path: budgets pass
            # through exactly so decisions match the historical contract)
            eff = np.floor(eff / quantum + 1e-9) * quantum
        cache_len = self._cache_len(total)
        d = self._sticky_decision(b, total, eff, cache_len)
        if d is None:
            d = self.policy.observe(PolicyState(
                batch=b, total_len=total, budget_bytes=eff,
                reserved_bytes=self.pool.bytes_reserved,
                capacity_bytes=self.pool.acct.capacity_bytes,
                n_running=len(self._running), now=self._now()))
        kv_bytes = self.mm.state_bytes(d.mask, b, total)
        if not self._paged:
            # slot-path admission charges QUANTIZED bytes: the analytical
            # model speaks model-width bytes, but an int8/fp8 slot cache
            # stores 1-byte elements (+ one f32 scale per token·head), so
            # a quantized request admits ~width× the sequence under the
            # same budget. (The paged path gets this for free: its pages
            # are physically narrower, so page counts already shrank, and
            # the pool's in_use_scale converts the analytical charge.)
            kv_bytes *= _kv_byte_ratio(d.kv_dtype, self.mcfg)
        force = self.cfg.admission == "force"
        if self._paged:
            # page-granular admission: the paged path physically stores
            # every layer's KV whatever the mask says (masked-mode gates
            # save compute, not memory), so the charge is the request's
            # worst-case PAGE commitment, not its analytical byte count —
            # the honest signal the policy's budget observation reflects
            if not self.pool.fits_capacity_tokens(b, total):
                self._reject(
                    req, f"{self.pool.pages_for_tokens(b, total)} pages "
                         f"({b}×{total} tokens) can never fit pool "
                         f"capacity of {self.pool.n_pages} pages")
                return "rejected"
            if not self.pool.can_alloc_tokens(b, total):
                return "defer"
        elif not force:
            if not self.pool.fits_capacity(kv_bytes):
                self._reject(req, f"state {kv_bytes:.0f}B can never fit "
                                  f"pool capacity "
                                  f"{self.pool.acct.capacity_bytes:.0f}B")
                return "rejected"
            if not self.pool.can_alloc(kv_bytes):
                return "defer"

        group = self.executor.group_for(d.mask, cache_len)
        free = group.free_slots()
        if len(free) < b:
            return "defer"
        slots = free[:b]
        # admission ends HERE: admitted_t (and so queue_delay_s) measures
        # time spent queued, not queueing + prefill — TTFT decomposes as
        # queue_delay_s + prefill time
        admitted_t = self._now()
        bucket = group.key if self.cfg.mode == "structural" else ()
        chunked = (self.cfg.max_prefill_tokens > 0 and S >= 1
                   and self.executor.supports_chunked_prefill(group))
        if self._paged:
            # grant pages backing the prompt now; commit the decode tail.
            # The ledger's in-use side stays analytical (the Eq. (3)–(4)
            # bytes) as a cross-check against the physical reservation.
            # Chunked prefill grants only the FIRST chunk's pages here —
            # each later chunk extends the allocation just before it runs
            # (the commitment above covers them, so the grants can't fail).
            if chunked:
                c1 = chunk_widths(S, self.cfg.max_prefill_tokens)[0]
                rate = kv_bytes / max(total, 1)
                self.pool.alloc_tokens(req.rid, b, c1, max_tokens=total,
                                       in_use_bytes=rate * c1,
                                       in_use_per_token=rate,
                                       kv_dtype=d.kv_dtype)
            else:
                prompt_bytes = self.mm.state_bytes(d.mask, b, S)
                rate = max(kv_bytes - prompt_bytes, 0.0) / max(total - S, 1)
                self.pool.alloc_tokens(req.rid, b, S, max_tokens=total,
                                       in_use_bytes=prompt_bytes,
                                       in_use_per_token=rate,
                                       kv_dtype=d.kv_dtype)
        else:
            self.pool.alloc(req.rid, kv_bytes, allow_overcommit=force)
        prompt = np.asarray(req.prompt, np.int32)
        if chunked:
            task = self.executor.prefill_begin(
                group, slots, req.rid, prompt, d.mask,
                max_chunk=self.cfg.max_prefill_tokens)
            self._prefilling[req.rid] = _Prefilling(
                req=req, decision=d, group=group, slots=slots,
                admitted_t=admitted_t, kv_bytes=kv_bytes, max_new=max_new,
                bucket=bucket, task=task)
            return "admitted"
        first = self.executor.prefill_into(group, slots, req.rid, prompt,
                                           d.mask)
        run = _Running(req=req, decision=d, group=group, slots=slots,
                       admitted_t=admitted_t, kv_bytes=kv_bytes,
                       max_new=max_new, out=[first], bucket=bucket,
                       events=[(self._now(), 1)])
        self._running[req.rid] = run
        # the prefill already produced token #1
        if run.max_new <= len(run.out):
            self._complete(run)
        return "admitted"

    def _sticky_decision(self, b: int, total: int, eff: float,
                         cache_len: int) -> Optional[Decision]:
        """Bucket affinity for structural mode: joining an already-compiled
        bucket whose keep-mask still fits the remaining budget batches with
        the requests resident there and skips both the policy rollout and a
        fresh compile. Without this, the drifting pool level mints a new
        bucket per admission and structural serving degenerates into
        per-request executables (the exact failure one-shot serving has)."""
        if self.cfg.mode != "structural" or self.cfg.admission != "strict":
            return None
        best = None
        for group in self.executor.groups():
            if (group.mask is None or group.cache_len != cache_len
                    or len(group.free_slots()) < b):
                continue
            peak = self.mm.peak_bytes(group.mask, b, total)
            if peak > eff:
                continue
            if not self.pool.can_alloc(
                    self.mm.state_bytes(group.mask, b, total)):
                continue
            # prefer the bucket keeping the most blocks (least over-pruned)
            kept = int(group.mask.sum())
            if best is None or kept > best[0]:
                best = (kept, group, peak)
        if best is None:
            return None
        _, group, peak = best
        return Decision(mask=group.mask.copy(), steps=0, peak_bytes=peak,
                        fits=True, latency_s=0.0, cached=True)

    # ------------------------------------------------------ chunked prefill
    def _advance_prefills(self) -> None:
        """Advance every in-flight chunked prefill by ONE chunk (at most
        ``cfg.max_prefill_tokens`` prompt tokens) — the interleave grain
        that bounds how long a long prompt can stall running decodes. A
        completing prefill seats its request (it joins decode next tick)
        and stamps its first-token event."""
        for rid in list(self._prefilling):
            pf = self._prefilling[rid]
            first = self.executor.prefill_step(pf.task)
            if first is None:
                continue
            del self._prefilling[rid]
            run = _Running(req=pf.req, decision=pf.decision, group=pf.group,
                           slots=pf.slots, admitted_t=pf.admitted_t,
                           kv_bytes=pf.kv_bytes, max_new=pf.max_new,
                           out=[first], bucket=pf.bucket,
                           events=[(self._now(), 1)])
            self._running[rid] = run
            if run.max_new <= len(run.out):
                self._complete(run)

    # --------------------------------------------------------------- decode
    def _launch_decode(self, decode_plan: Optional[List[str]],
                       backlog: bool = False) -> List[Tuple[Any, set]]:
        """Dispatch one fused horizon per occupied group named in the
        scheduler's decode plan, WITHOUT syncing. Returns the in-flight
        launches paired with the rids resident at launch time (the only
        requests this tick's tokens belong to). Plans are per-request but
        execution is per-group: a group steps if any of its residents are
        planned (the fused scan advances every occupant regardless — an
        unplanned co-resident's tokens are still folded back, since
        skipping them would discard real device work)."""
        launches: List[Tuple[Any, set]] = []
        if not self._running:
            return launches
        allowed = None if decode_plan is None else set(decode_plan)
        for group in self.executor.groups():
            if not group.occupied():
                continue
            runs = [run for run in self._running.values()
                    if run.group is group]
            if not runs or (allowed is not None
                            and not any(r.req.rid in allowed for r in runs)):
                continue
            # clamp the horizon to the group's largest remaining token
            # need, QUANTIZED up to a power of two: executables are
            # compiled per (batch width, horizon), and an exact clamp
            # would mint one per remaining-need value (timing-dependent —
            # steady state would never stop compiling). Pow2 bounds the
            # horizon set to {1, 2, 4, ...} while short tails still skip
            # most full-horizon compute; the overshoot is truncated at
            # fold-back.
            remaining = max((run.max_new - len(run.out) for run in runs),
                            default=1)
            horizon = min(self.cfg.decode_horizon,
                          _next_pow2(max(remaining, 1)))
            if backlog:
                # admission-stall clamp (bench triage): while requests
                # wait, a full horizon holds every completion — and the
                # slots/budget it would free — hostage until the group's
                # LONGEST resident retires it, so short-max_new traces
                # see queue delay grow with H. Clamp to the group's
                # soonest completion instead (pow2-quantized, same
                # bounded executable set): finished requests hand their
                # capacity to the queue at the earliest boundary. With an
                # empty queue the max-need horizon amortizes dispatch
                # exactly as before. Horizon size stays unobservable in
                # the token streams either way (truncated at fold-back).
                soonest = min((run.max_new - len(run.out) for run in runs),
                              default=1)
                horizon = min(horizon, _next_pow2(max(soonest, 1)))
            launches.append((self.executor.decode_launch(group, horizon),
                             {run.req.rid for run in runs}))
        return launches

    def _finish_decode(self, launches: List[Tuple[Any, set]]) -> None:
        """The tick's sync point: read back each launched horizon and fold
        its tokens into the requests that were resident at launch (a
        request admitted during the overlapped host phase gets nothing
        from this tick — its slot's rows are garbage). Completion is
        checked once at the horizon boundary; a request whose ``max_new``
        lands mid-horizon keeps only the tokens up to it — the trailing
        over-generated ones are truncated here, which is what makes
        horizon size unobservable in the results (bitwise-identical to
        decode_horizon=1)."""
        for launch, rids in launches:
            toks, _ = self.executor.decode_finish(launch)
            now = self._now()
            for rid in rids:
                run = self._running.get(rid)
                if run is None:
                    continue
                need = run.max_new - len(run.out)
                if need <= 0:
                    continue
                cols = toks[np.asarray(run.slots)]     # [b, horizon]
                n = min(need, launch.horizon)
                for h in range(n):
                    run.out.append(cols[:, h])
                run.events.append((now, n))
        self._decode_iters += 1
        used, phys = self.executor.kv_utilization()
        if phys > 0:
            self._frag_samples.append(1.0 - used / phys)
        done = [run for run in self._running.values()
                if len(run.out) >= run.max_new]
        # batch the device-side slot resets: one fused eviction per group
        # per macro-tick instead of one per completing request
        by_group: Dict[int, Tuple[Any, List[int]]] = {}
        for run in done:
            slots = by_group.setdefault(id(run.group), (run.group, []))[1]
            slots.extend(run.slots)
        for group, slots in by_group.values():
            group.evict(slots)
        for run in done:
            self._complete(run, evict=False)

    def _complete(self, run: _Running, *, evict: bool = True) -> None:
        if evict:
            run.group.evict(run.slots)
        self.pool.free(run.req.rid)
        now = self._now()
        d = run.decision
        # latency samples from the run's token-emission events: TTFT is
        # first token minus ARRIVAL (it includes the queue delay); each
        # later event covers one fused horizon and contributes its
        # per-token share n times, so long horizons don't undercount
        ttft = (run.events[0][0] - run.req.arrival_t if run.events
                else -1.0)
        if run.events:
            self._ttft_samples.append(ttft)
            prev = run.events[0][0]
            for t, n in run.events[1:]:
                self._itl_samples.extend([(t - prev) / max(n, 1)] * n)
                prev = t
        result = RequestResult(
            rid=run.req.rid, status="done",
            tokens=np.stack(run.out, axis=1),       # [b, generated]
            mask=d.mask, bucket=run.bucket,
            arrival_t=run.req.arrival_t, admitted_t=run.admitted_t,
            finished_t=now, queue_delay_s=run.admitted_t - run.req.arrival_t,
            decide_s=d.latency_s, fits=d.fits, cached_decision=d.cached,
            peak_bytes=d.peak_bytes, kv_bytes=run.kv_bytes, ttft_s=ttft)
        self._results.append(result)
        del self._running[run.req.rid]
        self.policy.feedback(result)

    # ---------------------------------------------------------------- stats
    def stats(self) -> Dict[str, Any]:
        out: Dict[str, Any] = dict(self.executor.stats())
        # per-request TTFT decomposition (queueing vs prefill) for the
        # most recent run: ttft_s − queue_delay_s is time from admission
        # to first token, i.e. the prefill share
        out["requests"] = {
            r.rid: {"queue_delay_s": r.queue_delay_s, "ttft_s": r.ttft_s,
                    "prefill_s": max(r.ttft_s - r.queue_delay_s, 0.0)}
            for r in self._results
            if r.status == "done" and r.ttft_s >= 0.0}
        return out
