"""Continuous-batching RAP engine — shared-budget serving of concurrent
requests (the production form of paper Algorithm 3).

``RAPServer`` replays requests one at a time, so each request sees a
*private* instantaneous budget and "runtime memory variation" is simulated.
The engine makes the contention real: many in-flight requests compete for
one device budget, and the policy's keep-mask decision is made against
whatever the *pool* has left.

Since the serving-API split (DESIGN.md §2) the engine is a thin
orchestration loop over four seams:

  * :class:`~repro.runtime.scheduler.Scheduler` — who is admitted next
    (FIFO / SJF / priority), emitting explicit ``SchedulerOutput`` plans;
  * :class:`~repro.core.policy.PruningPolicy` — what shape they run in:
    ``observe(PolicyState) → Decision`` against the remaining shared
    budget (the RL controller, any static baseline, or dense);
  * :class:`~repro.runtime.executor.ModelExecutor` — how the mask
    executes: slot groups, prefill, fused bucketed decode;
  * :class:`~repro.runtime.kv_pool.KVPool` — whether the bytes exist:
    page-granular admission against ``budget − resident params``. With a
    paged executor (``PagedExecutor``) the pool additionally OWNS the
    physical page arrays: admission charges the request's worst-case page
    count as a commitment, prefill writes into granted pages, each decoded
    token appends a page when it crosses a boundary (``KVPool.extend``),
    and completion frees the pages.

One iteration of :meth:`RAPEngine._tick` (the async macro-tick,
DESIGN.md §6 — device work is dispatched FIRST so host scheduling
overlaps the in-flight scans):

  1. **launch** — every occupied group in the scheduler's decode plan
     dispatches one fused horizon of up to ``EngineConfig.decode_horizon``
     tokens (DESIGN.md §5). JAX async dispatch returns token futures
     immediately; nothing syncs yet;
  2. **arrivals** — requests become visible at their trace timestamps
     (virtual clock; idle gaps are skipped, compute time is real) and
     enter the scheduler's waiting set;
  3. **admission** — the scheduler orders candidates; for each, the
     policy decides a keep-mask against the *remaining* shared budget and
     the request's analytical KV/state bytes are allocated from the pool.
     A deferral (no pages / no free slots) ends the admission loop, so
     the scheduler's ordering is never overtaken within a tick. ``force``
     mode (the one-shot compatibility path) admits regardless and records
     the overcommit. Prefill is monolithic by default; with
     ``EngineConfig.max_prefill_tokens > 0`` prompts are split into pow2
     chunks advanced one per tick, interleaved with running decodes;
  4. **finish** — the single device→host read-back folds each horizon's
     tokens into the requests that were resident at launch. Completion
     (``max_new`` today; an EOS-style stop condition, when one lands,
     would share the same boundary semantics) is checked once per
     horizon; tokens a request over-generated inside its final horizon
     are truncated, so results are bitwise-identical to H=1.

Completed requests free their pages and slots, unblocking the queue, and
are reported back to the policy via ``feedback()``.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

from repro.core import masks as masks_lib
from repro.core.controller import RAPController
from repro.core.policy import Decision, PolicyState, PruningPolicy
from repro.runtime.executor import (LocalExecutor, ModelExecutor, SlotGroup,
                                    chunk_widths)
from repro.runtime.latency import summarize as _lat_summarize
from repro.runtime.kv_pool import (KVPool, default_page_bytes,
                                   resolve_kv_dtype)
from repro.runtime.scheduler import (Scheduler, VictimCandidate,
                                     make_scheduler)

__all__ = ["EngineConfig", "EngineRequest", "RequestResult", "EngineReport",
           "RAPEngine", "enable_compile_cache"]

_MIGRATION_HINT = (
    "RAPEngine's constructor changed with the serving-API split: it now "
    "takes a PruningPolicy instead of a RAPController. Wrap your "
    "controller — RAPEngine(model, params, "
    "repro.core.policy.RLPolicy(controller), cfg) — or build any "
    "registered policy with repro.core.policy.make_policy(). Schedulers "
    "and executors are injectable via the scheduler=/executor= kwargs."
)


def _next_pow2(n: int) -> int:
    return 1 << max(int(n) - 1, 0).bit_length()


def _kv_byte_ratio(kv_dtype, mcfg) -> float:
    """Quantized-vs-model KV byte ratio for slot-cache admission.

    int8/fp8 slot caches store 1-byte elements plus one f32 scale per
    (token, kv-head) (``attention.kv_quant``), while the analytical memory
    model charges at the model's KV width — the ratio converts an
    Eq. (3)–(4) charge into the bytes the cache actually occupies."""
    _, _, quantized, _ = resolve_kv_dtype(kv_dtype)
    if not quantized:
        return 1.0
    from repro.core.memory import dtype_bytes
    dh = max(int(mcfg.dh), 1)
    return (dh * 1.0 + 4.0) / (dh * dtype_bytes(mcfg.dtype))


# -------------------------------------------- persistent compilation cache
# Process-wide hit/miss counters fed by JAX's monitoring events; the engine
# reports per-run deltas next to compile_events. compile_events counts
# TRACES (Python → jaxpr, paid either way); a cache hit means the expensive
# XLA compile behind a trace was served from disk.
_CACHE_EVENTS = {"hits": 0, "misses": 0}
_CACHE_LISTENER = {"registered": False}


def _on_jax_monitoring_event(event: str, **kw) -> None:
    if event == "/jax/compilation_cache/cache_hits":
        _CACHE_EVENTS["hits"] += 1
    elif event == "/jax/compilation_cache/cache_misses":
        _CACHE_EVENTS["misses"] += 1


def enable_compile_cache(cache_dir: str) -> None:
    """Root JAX's persistent compilation cache at ``cache_dir``.

    A second serve of the same config (same process or a fresh one)
    re-traces its executables but deserializes the XLA binaries from disk
    instead of recompiling — the recompile-dominated structural cold start
    becomes a warm start (DESIGN.md §9). Process-wide and idempotent; the
    floors are lowered so even sub-second compiles (smoke-sized models)
    populate the cache.
    """
    import jax
    cache_dir = str(cache_dir)
    changed = _CACHE_LISTENER.get("dir") != cache_dir
    jax.config.update("jax_compilation_cache_dir", cache_dir)
    jax.config.update("jax_persistent_cache_min_entry_size_bytes", -1)
    jax.config.update("jax_persistent_cache_min_compile_time_secs", 0.0)
    if changed:
        # JAX latches the cache-used decision at the process's FIRST
        # compile: a process that already compiled with caching off (any
        # engine built without compile_cache_dir) must reset the latch or
        # the new dir is silently ignored
        try:
            from jax._src import compilation_cache as _cc
            _cc.reset_cache()
        except (ImportError, AttributeError):   # private API moved
            pass
        _CACHE_LISTENER["dir"] = cache_dir
    if not _CACHE_LISTENER["registered"]:
        jax.monitoring.register_event_listener(_on_jax_monitoring_event)
        _CACHE_LISTENER["registered"] = True


# ------------------------------------------------------------------- config
@dataclasses.dataclass
class EngineConfig:
    mode: str = "masked"              # masked | structural
    max_new_tokens: int = 16
    max_active: int = 8               # cache slots per group (decode batch)
    max_len: int = 256                # slot cache length (prompt + generated)
    budget_bytes: float = 0.0         # TOTAL device budget (params + states)
    page_bytes: int = 0               # 0 → derived from the memory model
    tokens_per_page: int = 16
    kv_dtype: Any = None
    admission: str = "strict"         # strict (queue) | force (overcommit)
    # Admission quantizes the effective budget DOWN to this fraction of the
    # request's dense peak before calling the policy. The pool level drifts
    # continuously; without a quantum every admission sees a fresh budget,
    # the policy emits a fresh mask, and structural mode compiles a fresh
    # bucket — quantizing collapses steady-state admissions onto a handful
    # of memoized decisions/buckets. Safety is unaffected: the page
    # allocator, not the decision, enforces the byte budget.
    budget_quantum_frac: float = 0.05
    # "pow2": slot caches are minted per power-of-two length bucket (the
    # group key includes the bucket), so one long prompt mints a long-cache
    # group instead of invalidating every compiled short one, and short
    # requests keep decoding against short caches — the RAPServer shim's
    # setting (sequential serves, heterogeneous lengths). "max" (default):
    # one max_len-sized cache per group family — requests of every length
    # share one decode batch, which is what continuous batching is for;
    # splitting by length would fragment the fused decode step per bucket.
    len_buckets: str = "max"          # max | pow2
    # Decode batch buckets: the executor steps occupied slots in the
    # smallest bucket that holds them instead of always paying
    # max_active-wide compute. () disables (always full width).
    decode_buckets: Tuple[int, ...] = (1, 2, 4, 8)
    # Horizon decode (DESIGN.md §5): each engine macro-tick advances every
    # running request up to this many tokens through ONE fused on-device
    # loop per group, with completion checked at the horizon boundary and
    # over-generated tokens truncated (token streams are bitwise-identical
    # to decode_horizon=1). Clamped per tick to the largest remaining
    # token need in the group, so short tails don't pay full-horizon
    # compute — and, while requests are queued, to the group's SOONEST
    # completion, so a full horizon can't stall admission behind its
    # longest resident. 1 restores per-token ticks.
    decode_horizon: int = 8
    # Chunked prefill (DESIGN.md §6): 0 (default) prefills each prompt in
    # one monolithic pass; >0 caps the prompt tokens prefilled per engine
    # macro-tick — long prompts are split into power-of-two chunks
    # (largest-first, e.g. 13 → 8+4+1 under a cap of 8) interleaved with
    # the running requests' decode horizons, so a long prefill no longer
    # stalls every in-flight decode for its full length. Token streams are
    # bitwise-identical with chunking on or off. Backends without a
    # chunked path (heterogeneous layouts) fall back to monolithic.
    max_prefill_tokens: int = 0
    # Elastic budgets (DESIGN.md §11): when run() is given a budget_trace
    # and the budget shrinks below the bytes already reserved, the engine
    # preempts running victims (Scheduler.select_victims order), spilling
    # their KV pages to host and resuming them when the budget recovers.
    # False serves the trace for observability only: the budget still
    # gates NEW admissions, but running requests are never preempted.
    preemption_enabled: bool = True
    # Preemption overshoots the deficit by this fraction of the shrunken
    # KV budget, so the next admission/extension doesn't immediately
    # re-trigger a shock at the boundary. 0 frees exactly the deficit.
    spill_headroom_frac: float = 0.1
    # "scheduler" delegates victim order to Scheduler.select_victims
    # (SLO tiers + aging under PriorityScheduler); "arrival" preempts the
    # newest running request first (least sunk work, LIFO).
    victim_policy: str = "scheduler"
    # Structural bucket-shape quantization (DESIGN.md §9): snap every
    # decision mask onto a ladder of whole-layer keep-sets before a bucket
    # is minted, realizing the exact mask as 0/1 gates INSIDE the bucket
    # (bitwise-identical tokens), so an adaptive policy's stream of
    # distinct masks compiles a bounded executable family set instead of
    # one program per mask. none | layer | pow2 (masks.quantize_mask);
    # paged executors floor "none" at "layer".
    bucket_quant: str = "none"
    # Cap on live structural slot groups in the default LocalExecutor
    # (0 = unbounded): idle groups past the cap are evicted LRU, dropping
    # their prefill executables and — when they were the signature's last
    # group — the resident compacted param stack.
    max_structural_groups: int = 0
    # Non-empty: enable JAX's persistent compilation cache rooted here
    # (enable_compile_cache), so a second serve of the same config skips
    # XLA compilation. Per-run activity is reported as
    # EngineReport.compile_cache_hits / compile_cache_misses.
    compile_cache_dir: str = ""

    def __post_init__(self):
        if self.mode not in ("masked", "structural"):
            raise ValueError(f"unknown mode {self.mode!r}")
        if self.admission not in ("strict", "force"):
            raise ValueError(f"unknown admission {self.admission!r}")
        if self.len_buckets not in ("pow2", "max"):
            raise ValueError(f"unknown len_buckets {self.len_buckets!r} "
                             f"(expected 'pow2' or 'max')")
        if not (0.0 <= self.budget_quantum_frac <= 1.0):
            raise ValueError(
                f"budget_quantum_frac must be in [0, 1], got "
                f"{self.budget_quantum_frac!r} — it is a fraction of the "
                f"request's dense peak (0 disables admission quantization)")
        if self.max_active < 1:
            raise ValueError(
                f"max_active must be >= 1, got {self.max_active!r} — the "
                f"engine needs at least one cache slot to host a request")
        if self.max_len < 1:
            raise ValueError(
                f"max_len must be >= 1, got {self.max_len!r} — slot caches "
                f"must hold at least one token (prompt + generated)")
        if self.max_new_tokens < 0:
            raise ValueError(
                f"max_new_tokens must be >= 0, got {self.max_new_tokens!r}")
        if self.tokens_per_page < 1:
            raise ValueError(
                f"tokens_per_page must be >= 1, got "
                f"{self.tokens_per_page!r} — KV pool pages hold at least "
                f"one token of dense per-token state")
        if self.budget_bytes < 0:
            raise ValueError(
                f"budget_bytes must be >= 0, got {self.budget_bytes!r} "
                f"(0 means 'pass the budget per run() call')")
        if self.page_bytes < 0:
            raise ValueError(
                f"page_bytes must be >= 0, got {self.page_bytes!r} "
                f"(0 derives the page size from the memory model)")
        if any(int(b) < 1 for b in self.decode_buckets):
            raise ValueError(
                f"decode_buckets must be positive slot counts, got "
                f"{self.decode_buckets!r}")
        if self.decode_horizon < 1:
            raise ValueError(
                f"decode_horizon must be >= 1, got {self.decode_horizon!r} "
                f"— each macro-tick advances at least one token")
        if self.max_prefill_tokens < 0:
            raise ValueError(
                f"max_prefill_tokens must be >= 0, got "
                f"{self.max_prefill_tokens!r} (0 prefills prompts "
                f"monolithically; >0 caps prompt tokens prefilled per "
                f"engine tick)")
        if not isinstance(self.preemption_enabled, bool):
            raise ValueError(
                f"preemption_enabled must be a bool, got "
                f"{self.preemption_enabled!r} — it gates mid-serve KV "
                f"spill/resume when a budget_trace shrinks the budget "
                f"below the bytes already reserved")
        if not (0.0 <= self.spill_headroom_frac < 1.0):
            raise ValueError(
                f"spill_headroom_frac must be in [0, 1), got "
                f"{self.spill_headroom_frac!r} — the fraction of the "
                f"shrunken KV budget preemption frees beyond the deficit "
                f"(0 frees exactly the deficit)")
        if self.victim_policy not in ("scheduler", "arrival"):
            raise ValueError(
                f"unknown victim_policy {self.victim_policy!r} (expected "
                f"'scheduler' — Scheduler.select_victims's SLO-tier order "
                f"— or 'arrival' — newest running request first)")
        if self.bucket_quant not in ("none", "layer", "pow2"):
            raise ValueError(
                f"unknown bucket_quant {self.bucket_quant!r} (expected "
                f"'none' — one bucket per exact mask — 'layer' — "
                f"whole-layer buckets over the exact retained rows — or "
                f"'pow2' — keep-count rounded up to a power of two)")
        if self.max_structural_groups < 0:
            raise ValueError(
                f"max_structural_groups must be >= 0, got "
                f"{self.max_structural_groups!r} (0 disables the "
                f"structural group cap)")


@dataclasses.dataclass
class EngineRequest:
    rid: str                          # unique among in-flight requests
    prompt: np.ndarray                # int32 [b, S]
    arrival_t: float = 0.0
    max_new: Optional[int] = None     # generated tokens (≥1: prefill always
                                      # yields one); None → engine default
    priority: int = 0                 # PriorityScheduler rank (lower=sooner)


@dataclasses.dataclass
class RequestResult:
    rid: str
    status: str                       # done | rejected | cancelled
    tokens: Optional[np.ndarray]      # [b, generated]
    mask: Optional[np.ndarray]
    bucket: Tuple
    arrival_t: float
    admitted_t: float
    finished_t: float
    queue_delay_s: float
    decide_s: float
    fits: bool
    cached_decision: bool
    peak_bytes: float
    kv_bytes: float
    reason: str = ""
    # time to first token, measured from ARRIVAL (so it decomposes as
    # queue_delay_s + prefill time; -1.0 for rejected requests)
    ttft_s: float = -1.0


@dataclasses.dataclass
class EngineReport:
    results: List[RequestResult]
    wall_s: float                     # real compute wall time
    makespan_s: float                 # virtual: includes skipped arrival gaps
    generated_tokens: int
    tokens_per_s: float               # generated / makespan_s
    mean_queue_delay_s: float
    budget_fit_rate: float            # admitted requests whose peak fit
    rejected: int
    decode_iters: int                 # macro-ticks (horizons), not tokens
    compile_events: int
    pool: Dict[str, float]
    # persistent-compile-cache activity during the run (zeros unless
    # EngineConfig.compile_cache_dir enabled the cache): a hit means a
    # traced executable was deserialized from disk instead of recompiled,
    # so a warmed replay shows compile_events ≈ compile_cache_hits and
    # near-zero misses
    compile_cache_hits: int = 0
    compile_cache_misses: int = 0
    # wall time spent inside compiled-executable launches + read-backs
    # (prefill and decode horizons): wall_s − launch_s is the host-side
    # orchestration share the horizon decode exists to shrink
    launch_s: float = 0.0
    # measured physical KV fragmentation: mean over decode ticks of
    # 1 − used_bytes / physical_bytes from the executor's kv_utilization()
    # (0.0 when the backend does not track it)
    measured_frag: float = 0.0
    # latency percentiles (repro.runtime.latency.summarize dicts, seconds):
    # ttft pools per-request time-to-first-token (arrival → first token);
    # itl pools per-token inter-token latencies across every request's
    # decode stream (a fused H-token horizon contributes H samples of its
    # per-token share)
    ttft: Dict[str, float] = dataclasses.field(default_factory=dict)
    itl: Dict[str, float] = dataclasses.field(default_factory=dict)
    # elastic-budget counters (DESIGN.md §11): preemption events, requests
    # cancelled via cancel(), MB of KV spilled to host across the run
    preempted_count: int = 0
    cancelled: int = 0
    spilled_mb: float = 0.0
    # preempt→resume latency percentiles (summarize dict; one sample per
    # resume, on the virtual clock)
    resume_latency: Dict[str, float] = dataclasses.field(default_factory=dict)
    # ITL samples of requests that were preempted at least once, pooled
    # SEPARATELY from `itl` — a resume gap lands in the victim's stream as
    # one huge inter-token latency and would otherwise poison the p99 of
    # requests that were never touched
    itl_preempted: Dict[str, float] = dataclasses.field(default_factory=dict)
    # (virtual_t, budget_bytes) breakpoints the run actually applied —
    # scenario harnesses use these to window per-phase goodput
    budget_events: List[Tuple[float, float]] = \
        dataclasses.field(default_factory=list)

    def result(self, rid: str) -> RequestResult:
        for r in self.results:
            if r.rid == rid:
                return r
        raise KeyError(rid)


@dataclasses.dataclass
class _Running:
    req: EngineRequest
    decision: Decision
    group: SlotGroup
    slots: List[int]
    admitted_t: float
    kv_bytes: float
    max_new: int
    out: List[np.ndarray]            # per generated step: [b] tokens
    bucket: Tuple
    # token-emission events (virtual-clock time, tokens appended): the
    # first entry is the prefill's token #1 (TTFT anchor); each decode
    # horizon appends one entry covering its H tokens (ITL samples)
    events: List[Tuple[float, int]] = dataclasses.field(default_factory=list)
    # times this request was preempted (routes its ITL samples to the
    # report's itl_preempted pool instead of itl)
    preempt_count: int = 0
    # set by the force-resume liveness backstop: exempt from further
    # preemption so it drains instead of livelocking (a budget too small
    # for even ONE request would otherwise re-spill the resurrected
    # victim at the next tick start, before it ever decodes)
    pinned: bool = False


@dataclasses.dataclass
class _Prefilling:
    """A request admitted (pool charged, slots reserved) whose prompt is
    still being prefilled chunk-by-chunk across engine ticks."""
    req: EngineRequest
    decision: Decision
    group: SlotGroup
    slots: List[int]
    admitted_t: float
    kv_bytes: float
    max_new: int
    bucket: Tuple
    task: Any                        # executor _PrefillTask


@dataclasses.dataclass
class _Preempted:
    """A running request evicted under a budget shock: its KV pages live
    in the pool's host-side spill store, its non-KV device state (pos,
    last tokens, slot caches for the local path) in ``state``. Resuming
    re-grants pages, restores the state into free slots of an equivalent
    group, and the request decodes on, bitwise-identical to never having
    been preempted."""
    run: _Running
    state: Dict[str, Any]            # executor.spill_state() payload
    cache_len: int                   # group bucket to restore into
    preempted_t: float               # virtual clock (resume-latency anchor)


# ------------------------------------------------------------------- engine
class RAPEngine:
    """Thin orchestration loop: Scheduler × PruningPolicy × ModelExecutor
    × KVPool."""

    def __init__(self, model, params, policy: PruningPolicy = None,
                 cfg: EngineConfig = None, *,
                 scheduler: Optional[Scheduler] = None,
                 executor: Optional[ModelExecutor] = None, **legacy):
        if legacy:
            raise TypeError(
                f"RAPEngine got unexpected kwargs {sorted(legacy)}. "
                + _MIGRATION_HINT)
        if isinstance(policy, RAPController):
            raise TypeError(
                "RAPEngine received a RAPController where a PruningPolicy "
                "is expected. " + _MIGRATION_HINT)
        if policy is None or not isinstance(policy, PruningPolicy):
            raise TypeError(
                f"RAPEngine requires a PruningPolicy, got "
                f"{type(policy).__name__}. " + _MIGRATION_HINT)
        self.model = model
        self.mcfg = model.cfg
        if getattr(self.mcfg, "is_encoder_decoder", False):
            raise NotImplementedError("engine serves decoder-only models")
        self.params = params
        self.policy = policy
        # private copy: ensure_capacity mutates max_len/max_active, and a
        # caller-shared config would desync another engine's shape checks
        # from its actual cache sizes
        self.cfg = dataclasses.replace(cfg if cfg is not None
                                       else EngineConfig())
        if self.cfg.compile_cache_dir:
            enable_compile_cache(self.cfg.compile_cache_dir)
        self.mm = policy.mm
        self.scheduler = make_scheduler(scheduler)
        self.executor = executor if executor is not None else LocalExecutor(
            model, params, mode=self.cfg.mode, max_active=self.cfg.max_active,
            kv_dtype=self.cfg.kv_dtype,
            decode_buckets=self.cfg.decode_buckets,
            bucket_quant=self.cfg.bucket_quant,
            max_groups=self.cfg.max_structural_groups)
        self._paged = bool(getattr(self.executor, "paged", False))
        if self._paged:
            ex_mode = getattr(self.executor, "mode", self.cfg.mode)
            if ex_mode != self.cfg.mode:
                raise ValueError(
                    f"paged executor was built for mode={ex_mode!r} but "
                    f"EngineConfig.mode={self.cfg.mode!r}; construct "
                    f"PagedExecutor(..., mode={self.cfg.mode!r})")
            if self.cfg.admission != "strict":
                raise ValueError(
                    "a paged executor requires strict admission: overflow "
                    "pages have no physical backing to write KV into")
        # precision as a policy action: when the stack was built with a
        # canonical KV precision (cfg.kv_dtype or a quantized executor),
        # stamp it on the policy so every Decision carries it — admission
        # then charges quantized bytes and the pool's dtype check has a
        # request-side precision to validate. Launchers may override
        # policy.kv_dtype afterwards for per-run choices.
        kv_name = getattr(self.executor, "kv_dtype_name", None)
        if kv_name is None:
            kv_name, _, _, _ = resolve_kv_dtype(self.cfg.kv_dtype)
        if kv_name is not None and getattr(policy, "kv_dtype", None) is None:
            policy.kv_dtype = kv_name
        self._full_mask = masks_lib.full_mask(self.mcfg.n_layers)
        self.resident_param_bytes = self.mm.param_bytes(self._full_mask)
        self.pool: Optional[KVPool] = None
        # run state
        self._pending: List[EngineRequest] = []
        self._running: "Dict[str, _Running]" = {}
        self._prefilling: "Dict[str, _Prefilling]" = {}
        self._results: List[RequestResult] = []
        self._ttft_samples: List[float] = []
        self._itl_samples: List[float] = []
        self._decode_iters = 0
        self._compiles_at_run_start = 0
        self._cache_hits_at_run_start = 0
        self._cache_misses_at_run_start = 0
        self._t0 = 0.0
        self._skew = 0.0
        self._budget = self.cfg.budget_bytes
        self._frag_samples: List[float] = []
        # elastic-budget state (DESIGN.md §11)
        self._preempted: "Dict[str, _Preempted]" = {}
        self._budget_trace: Any = None
        self._run_budget = self.cfg.budget_bytes
        self._budget_events: List[Tuple[float, float]] = []
        self._resume_samples: List[float] = []
        self._itl_preempted_samples: List[float] = []
        self._preempted_count = 0
        self._spilled_bytes = 0.0
        self._stall_ticks = 0

    # ------------------------------------------------------------ capacity
    def ensure_capacity(self, batch: int, total_len: int) -> None:
        """Grow slot count / cache-length cap. Slot growth drops compiled
        groups (the slot axis changes); length growth is quantized to
        powers of two and — under pow2 length buckets — keeps every
        existing group valid (they own their own shorter caches)."""
        if total_len > self.cfg.max_len:
            self.cfg.max_len = _next_pow2(total_len)
            if self.cfg.len_buckets == "max":
                # legacy single-length groups are sized by cfg.max_len:
                # growth invalidates them
                self.executor.drop_groups()
        if batch > self.cfg.max_active:
            self.cfg.max_active = int(batch)
            self.executor.set_max_active(self.cfg.max_active)

    def _cache_len(self, total: int) -> int:
        """Cache length bucket hosting a (prompt+gen)-token request.

        pow2 buckets deliberately ignore cfg.max_len (admission already
        guaranteed total ≤ max_len): clamping to a non-power-of-two cap
        would remap the same request shape to a different bucket after
        capacity growth, re-introducing the recompile the buckets exist
        to prevent."""
        if self.cfg.len_buckets == "pow2":
            return max(_next_pow2(total), 16)
        return self.cfg.max_len

    # ---------------------------------------------------------------- time
    def _now(self) -> float:
        return (time.perf_counter() - self._t0) + self._skew

    # ---------------------------------------------------------------- pool
    def _make_pool(self, budget_bytes: float) -> KVPool:
        if self._paged:
            # physical page size is dictated by the model's KV geometry
            # (cfg.page_bytes would desync the ledger from the arrays)
            page = self.executor.page_phys_bytes(self.cfg.tokens_per_page)
        else:
            page = self.cfg.page_bytes or default_page_bytes(
                self.mm, self.cfg.tokens_per_page)
        cap = budget_bytes - self.resident_param_bytes
        if cap < page and self.cfg.admission == "strict":
            raise ValueError(
                f"budget {budget_bytes:.0f}B leaves no KV pool after "
                f"resident params ({self.resident_param_bytes:.0f}B)")
        return KVPool(max(cap, 0.0), page_bytes=page, mm=self.mm,
                      tokens_per_page=(self.cfg.tokens_per_page
                                       if self._paged else None))

    # ------------------------------------------------------------- serving
    def run(self, requests: List[EngineRequest], *,
            budget_bytes: Optional[float] = None,
            budget_trace: Any = None,
            on_tick: Any = None) -> EngineReport:
        """Serve a trace to completion and report aggregate stats.

        ``budget_trace`` makes the device budget time-varying (DESIGN.md
        §10): either a list of ``(t_seconds, budget_bytes)`` breakpoints —
        piecewise-constant on the run's VIRTUAL clock, applied at the
        start of the first tick at or after each breakpoint — or a
        callable ``now → budget_bytes`` evaluated once per tick (call-
        counting callables give deterministic shocks in tests, where tick
        wall time varies). The pool's physical arrays are sized once from
        the base budget — the trace modulates admission and triggers
        preemption; values above the base are clamped by pool capacity.

        ``on_tick(engine)`` is called once per tick during the host phase
        (decode scans already in flight), after launch and before
        arrivals — the seam fault-injection harnesses use to cancel
        requests mid-horizon deterministically.
        """
        budget = self.cfg.budget_bytes if budget_bytes is None else budget_bytes
        self.pool = self._make_pool(budget)
        if self._paged:
            self.executor.bind_pool(self.pool, self.cfg.max_len)
        self._budget = budget
        self._run_budget = budget
        if budget_trace is not None and not callable(budget_trace):
            budget_trace = sorted((float(t), float(v))
                                  for t, v in budget_trace)
        self._budget_trace = budget_trace
        self._budget_events = ([(0.0, float(budget))]
                               if budget_trace is not None else [])
        self._frag_samples: List[float] = []
        self._pending = sorted(requests, key=lambda r: r.arrival_t)
        self.scheduler.clear()
        self._running.clear()
        self._prefilling.clear()
        self._preempted.clear()
        self._results = []
        self._ttft_samples = []
        self._itl_samples = []
        self._resume_samples = []
        self._itl_preempted_samples = []
        self._preempted_count = 0
        self._spilled_bytes = 0.0
        self._stall_ticks = 0
        self._decode_iters = 0
        self._compiles_at_run_start = self.executor.compile_events
        self._cache_hits_at_run_start = _CACHE_EVENTS["hits"]
        self._cache_misses_at_run_start = _CACHE_EVENTS["misses"]
        self._launch_s_at_run_start = getattr(self.executor, "launch_s", 0.0)
        self._skew = 0.0
        self._t0 = time.perf_counter()
        self.executor.evict_all()             # previous run's occupants
        try:
            while (self._pending or len(self.scheduler) or self._running
                   or self._prefilling or self._preempted):
                self._tick(on_tick)
        except BaseException:
            # a run that raises mid-serve must not leak pool ledger
            # entries / spilled pages / seated slots into the next run()
            # on this engine (pinned by
            # tests/test_engine.py::test_run_exception_releases_pool)
            self._abort_cleanup()
            raise
        # makespan is on the VIRTUAL clock (skipped idle gaps included) —
        # the same clock request timestamps live on, so throughput is
        # comparable with any other replay of the same arrival process
        makespan = self._now()
        wall = time.perf_counter() - self._t0
        done = [r for r in self._results if r.status == "done"]
        gen = sum(r.tokens.size for r in done if r.tokens is not None)
        delays = [r.queue_delay_s for r in done]
        return EngineReport(
            results=self._results,
            wall_s=wall,
            makespan_s=makespan,
            generated_tokens=gen,
            tokens_per_s=gen / max(makespan, 1e-9),
            mean_queue_delay_s=float(np.mean(delays)) if delays else 0.0,
            budget_fit_rate=(float(np.mean([r.fits for r in done]))
                             if done else 0.0),
            rejected=sum(1 for r in self._results if r.status == "rejected"),
            decode_iters=self._decode_iters,
            compile_events=(self.executor.compile_events
                            - self._compiles_at_run_start),
            compile_cache_hits=(_CACHE_EVENTS["hits"]
                                - self._cache_hits_at_run_start),
            compile_cache_misses=(_CACHE_EVENTS["misses"]
                                  - self._cache_misses_at_run_start),
            pool=self.pool.stats(),
            launch_s=(getattr(self.executor, "launch_s", 0.0)
                      - self._launch_s_at_run_start),
            measured_frag=(float(np.mean(self._frag_samples))
                           if self._frag_samples else 0.0),
            ttft=_lat_summarize(self._ttft_samples),
            itl=_lat_summarize(self._itl_samples),
            preempted_count=self._preempted_count,
            cancelled=sum(1 for r in self._results
                          if r.status == "cancelled"),
            spilled_mb=self._spilled_bytes / 1e6,
            resume_latency=_lat_summarize(self._resume_samples),
            itl_preempted=_lat_summarize(self._itl_preempted_samples),
            budget_events=list(self._budget_events))

    # ------------------------------------------------------------ one tick
    def _tick(self, on_tick: Any = None) -> None:
        """One engine macro-tick, host work overlapped with device work:

          0. **budget** — re-evaluate the elastic budget on the virtual
             clock; if reserved bytes now exceed it, preempt victims
             (spill KV pages to host, free slots). This happens FIRST,
             before any launch, when no scan is in flight and the pool's
             page arrays are concrete — the only point in the tick where
             gathering page contents is race-free;
          1. **launch** — dispatch this tick's fused decode horizons (the
             scheduler's decode plan). JAX async dispatch returns the
             token futures immediately, so the scans run on device while…
          2. **host phase** — the on_tick hook, arrivals, resume of
             preempted requests (budget permitting), admission (policy
             decision, pool allocation, page granting), and one chunk of
             every in-flight chunked prefill all execute on the host with
             the scans still in flight (pinned by the transfer-guard
             overlap tests in tests/test_horizon.py);
          3. **finish** — the single device→host read-back folds the
             horizon's tokens into the running requests and completions
             are processed.

        A request admitted during the host phase joins decode from the
        NEXT tick — its slots were free padding (or reserved) when this
        tick's scan launched, so this tick's rows for them are garbage
        and are never read (the launch's captured occupancy pins this).
        The same captured-occupancy contract makes resume and mid-horizon
        cancellation safe: a restored request's slots and pages were free
        at launch, and a cancelled request simply vanishes from
        ``_running`` so fold-back skips it (over-generated horizon tokens
        are truncated exactly like a completion's)."""
        now = self._now()
        self._eval_budget(now)
        self._maybe_preempt(now)
        plan = self.scheduler.schedule(now, running=list(self._running))
        backlog = (len(self.scheduler) > 0
                   or bool(self._pending
                           and self._pending[0].arrival_t <= now))
        launches = self._launch_decode(plan.decode, backlog=backlog)
        # ---- host phase (device scans in flight from here to finish) ----
        if on_tick is not None:
            on_tick(self)
        while self._pending and self._pending[0].arrival_t <= now:
            req = self._pending.pop(0)
            if (req.rid in self.scheduler or req.rid in self._running
                    or req.rid in self._prefilling):
                self._reject(req, f"duplicate request id {req.rid!r} "
                                  f"(already in flight)")
                continue
            max_new = (self.cfg.max_new_tokens if req.max_new is None
                       else req.max_new)
            # total token cost: batch rows each hold prompt+decode tokens
            # (this is what scales the request's KV demand — SJF orders
            # by it)
            cost = req.prompt.shape[0] * (req.prompt.shape[1]
                                          + max(max_new, 1))
            self.scheduler.add(req, cost=cost)
        # resume preempted requests BEFORE admitting new ones: a victim
        # already holds its admission (and its partial output) — letting
        # the queue overtake it would turn one preemption into starvation
        self._try_resume()
        # admission plan: try candidates in the scheduler's order; a
        # deferral ends the loop so the order is never overtaken in-tick
        deferred = None
        for req in self.scheduler.schedule(now).admit:
            verdict = self._try_admit(req)
            if verdict == "defer":
                deferred = req
                break
            self.scheduler.remove(req.rid)
        # a deferral is "stuck" only if judged NOW, before this tick's
        # in-flight work lands: with nothing launched, running,
        # prefilling, or preempted, no completion or resume can ever free
        # the memory it waits on. (Work finishing later this tick frees
        # capacity — the deferred request simply retries next tick.)
        stuck = (deferred is not None and not launches
                 and not self._running and not self._prefilling
                 and not self._preempted)
        self._advance_prefills()
        # ---- finish: the tick's one sync point --------------------------
        if launches:
            self._finish_decode(launches)
        if self._running or self._prefilling:
            self._stall_ticks = 0
        else:
            self._idle_step(deferred, stuck)

    def _idle_step(self, deferred, stuck: bool) -> None:
        """Liveness with an idle engine (nothing running or prefilling):
        fast-forward the virtual clock to the next event that can change
        admissibility — a pending arrival or a budget-trace breakpoint —
        and backstop the cases where no such event exists (callable
        traces tick forward on evaluation; a trace that never recovers
        must not spin forever)."""
        now = self._now()
        nxt = self._next_breakpoint(now)
        if stuck:
            if nxt is not None:
                # the budget may recover at the next breakpoint: jump
                # there instead of rejecting the deferred head
                self._skew += max(nxt - now, 0.0) + 1e-9
            elif callable(self._budget_trace):
                # call-counting traces advance per evaluation: give the
                # shock a bounded number of idle ticks to recover before
                # declaring the deferral permanent
                self._stall_ticks += 1
                if self._stall_ticks > 256:
                    self.scheduler.remove(deferred.rid)
                    self._reject(deferred, "deferred with idle engine "
                                           "(budget trace never recovered)")
            else:
                # deferred head with an idle engine and no future budget
                # event: reject the scheduler's choice instead of
                # spinning (defensive; strict capacity misfits are
                # rejected in _try_admit already)
                self.scheduler.remove(deferred.rid)
                self._reject(deferred, "deferred with idle engine")
        elif deferred is None and self._pending and not self._preempted:
            # fast-forward the virtual clock across the idle gap (clamped
            # so a budget breakpoint inside the gap is not skipped over)
            tgt = self._pending[0].arrival_t
            if nxt is not None:
                tgt = min(tgt, nxt)
            self._skew += max(tgt - now, 0.0) + 1e-9
        elif self._preempted:
            if nxt is not None:
                tgt = nxt
                if self._pending:
                    tgt = min(tgt, self._pending[0].arrival_t)
                self._skew += max(tgt - now, 0.0) + 1e-9
            else:
                # no breakpoint will ever raise the budget again (constant
                # callable, or trace exhausted low): after a bounded spin,
                # force-resume — physical capacity checks only — so the
                # run drains instead of deadlocking
                self._stall_ticks += 1
                if self._stall_ticks > 8 and not self._force_resume():
                    raise RuntimeError(
                        "elastic-budget deadlock: preempted requests "
                        "cannot be restored even ignoring the budget "
                        "(pool capacity lost?)")

    # ----------------------------------------- elastic budget / preemption
    def _kv_budget(self) -> float:
        """KV-side share of the current elastic budget (params stay
        resident through a shock — shrinking below them just means zero
        KV headroom, not negative)."""
        return max(self._budget - self.resident_param_bytes, 0.0)

    def _eval_budget(self, now: float) -> None:
        """Re-evaluate the piecewise-constant budget on the virtual clock
        (list traces apply every breakpoint ≤ now; callables are invoked
        once per tick). Changes are recorded as (t, bytes) events."""
        tr = self._budget_trace
        if tr is None:
            return
        if callable(tr):
            b = float(tr(now))
        else:
            b = self._run_budget
            for t, v in tr:
                if t <= now + 1e-12:
                    b = v
                else:
                    break
        if b != self._budget:
            self._budget = b
            self._budget_events.append((now, b))

    def _next_breakpoint(self, now: float) -> Optional[float]:
        """First future breakpoint of a list trace (None for callables —
        they advance by being evaluated, and for exhausted traces)."""
        tr = self._budget_trace
        if tr is None or callable(tr):
            return None
        for t, _ in tr:
            if t > now + 1e-12:
                return t
        return None

    def _maybe_preempt(self, now: float) -> None:
        """Shed reserved bytes when the elastic budget shrank below them:
        spill victims (Scheduler.select_victims order) until reservations
        fit the shrunken budget minus headroom. Runs at tick START — no
        scan is in flight, so the pool's page arrays are concrete and
        gathering page contents races nothing. Only decoding requests are
        candidates; an in-flight chunked prefill finishes its prompt
        first and becomes preemptible the next tick."""
        if (not self.cfg.preemption_enabled or self._budget_trace is None
                or not self._running):
            return
        kv_budget = self._kv_budget()
        if self.pool.bytes_reserved <= kv_budget + 1e-6:
            return
        target = kv_budget * (1.0 - self.cfg.spill_headroom_frac)
        cands = [VictimCandidate(
                     rid=rid,
                     priority=getattr(run.req, "priority", 0),
                     arrival_t=run.req.arrival_t,
                     remaining_tokens=max(run.max_new - len(run.out), 0),
                     reserved_bytes=self.pool.request_reserved_bytes(rid))
                 for rid, run in self._running.items() if not run.pinned]
        if self.cfg.victim_policy == "arrival":
            order = sorted(cands, key=lambda c: -c.arrival_t)
        else:
            order = self.scheduler.select_victims(cands, now)
        for cand in order:
            if self.pool.bytes_reserved <= target + 1e-6:
                break
            self._preempt(self._running[cand.rid], now)

    def _preempt(self, run: _Running, now: float) -> None:
        """Evict one running request with its state: copy the non-KV
        device state out (executor seam), free its slots, spill its KV
        pages to the pool's host store, release its reservation."""
        rid = run.req.rid
        cache_len = run.group.cache_len
        state = self.executor.spill_state(run.group, run.slots)
        run.group.evict(run.slots)
        self._spilled_bytes += self.pool.spill(rid)
        del self._running[rid]
        run.preempt_count += 1
        self._preempted[rid] = _Preempted(run=run, state=state,
                                          cache_len=cache_len,
                                          preempted_t=now)
        self._preempted_count += 1

    def _try_resume(self) -> None:
        """Restore preempted requests that fit the recovered budget,
        most-important first (reverse of preemption order — victims were
        shed least-important first)."""
        if not self._preempted:
            return
        kv_budget = self._kv_budget()
        for rid in reversed(list(self._preempted)):
            self._resume_one(rid, kv_budget)

    def _resume_one(self, rid: str, kv_budget: float, *,
                    force: bool = False) -> bool:
        p = self._preempted[rid]
        if not force:
            need = self.pool.restore_reserved_bytes(rid)
            if self.pool.bytes_reserved + need > kv_budget + 1e-6:
                return False
        if not self.pool.can_restore(rid):
            return False
        b = len(p.run.slots)
        group = self.executor.group_for(p.run.decision.mask, p.cache_len)
        free = group.free_slots()
        if len(free) < b:
            return False
        rows = self.pool.restore(rid)
        slots = free[:b]
        self.executor.restore_state(group, slots, rid, p.state,
                                    p.run.decision.mask, rows)
        run = p.run
        run.group, run.slots = group, slots
        if force:
            run.pinned = True      # liveness: must drain, never re-spill
        del self._preempted[rid]
        self._running[rid] = run
        self._resume_samples.append(self._now() - p.preempted_t)
        self._stall_ticks = 0
        return True

    def _force_resume(self) -> bool:
        """Deadlock backstop: restore the most-important preempted
        request ignoring the elastic budget (physical page/slot capacity
        still checked — with an idle engine every page is free, so this
        succeeds unless the pool itself shrank). The resurrected run is
        PINNED — exempt from re-preemption — so it decodes to completion
        one victim at a time instead of livelocking through an endless
        spill/resume cycle when the shocked budget cannot host even one
        request; the overshoot is bounded by that single run."""
        for rid in reversed(list(self._preempted)):
            if self._resume_one(rid, float("inf"), force=True):
                return True
        return False

    # --------------------------------------------------------- cancellation
    def cancel(self, rid: str) -> bool:
        """Cancel a request at ANY lifecycle stage — pending, queued,
        prefilling, decoding mid-horizon, or preempted. Returns True if
        the request was found and cancelled; False for unknown, already
        finished, or already cancelled ids (idempotent — double-cancel
        and cancel racing a normal completion are both no-ops). Tokens a
        cancelled decode over-generated inside its in-flight horizon are
        truncated: fold-back skips rids no longer in the running set.
        Pages are freed via the pool's ``missing_ok`` seam, so the free
        cannot race a completion's."""
        for i, req in enumerate(self._pending):
            if req.rid == rid:
                self._pending.pop(i)
                self._record_cancelled(req)
                return True
        req = self.scheduler.peek(rid)
        if req is not None:
            self.scheduler.remove(rid)
            self._record_cancelled(req)
            return True
        pf = self._prefilling.pop(rid, None)
        if pf is not None:
            pf.group.evict(pf.slots)
            self.pool.free(rid, missing_ok=True)
            self._record_cancelled(pf.req, decision=pf.decision,
                                   admitted_t=pf.admitted_t,
                                   kv_bytes=pf.kv_bytes, bucket=pf.bucket)
            return True
        run = self._running.pop(rid, None)
        if run is not None:
            run.group.evict(run.slots)
            self.pool.free(rid, missing_ok=True)
            self._record_cancelled(run.req, decision=run.decision,
                                   admitted_t=run.admitted_t,
                                   kv_bytes=run.kv_bytes, bucket=run.bucket,
                                   out=run.out, events=run.events)
            return True
        p = self._preempted.pop(rid, None)
        if p is not None:
            self.pool.drop_spilled(rid, missing_ok=True)
            run = p.run
            self._record_cancelled(run.req, decision=run.decision,
                                   admitted_t=run.admitted_t,
                                   kv_bytes=run.kv_bytes, bucket=run.bucket,
                                   out=run.out, events=run.events)
            return True
        return False

    def _record_cancelled(self, req: EngineRequest, *, decision=None,
                          admitted_t: float = -1.0, kv_bytes: float = 0.0,
                          bucket: Tuple = (), out=None, events=None) -> None:
        now = self._now()
        d = decision
        tokens = np.stack(out, axis=1) if out else None
        ttft = (events[0][0] - req.arrival_t) if events else -1.0
        self._results.append(RequestResult(
            rid=req.rid, status="cancelled", tokens=tokens,
            mask=(d.mask if d is not None else None), bucket=bucket,
            arrival_t=req.arrival_t, admitted_t=admitted_t,
            finished_t=now,
            queue_delay_s=(admitted_t - req.arrival_t if admitted_t >= 0.0
                           else now - req.arrival_t),
            decide_s=(d.latency_s if d is not None else 0.0),
            fits=(d.fits if d is not None else False),
            cached_decision=(d.cached if d is not None else False),
            peak_bytes=(d.peak_bytes if d is not None else 0.0),
            kv_bytes=kv_bytes, reason="cancelled", ttft_s=ttft))

    # --------------------------------------------------------- fault safety
    def _abort_cleanup(self) -> None:
        """Release everything a raising run would otherwise leak into the
        next run() on this engine: pool ledger entries (live AND
        spilled), seated slots, and the queues. Idempotent via the pool's
        missing_ok seam."""
        if self.pool is not None:
            for rid in list(self.pool.live_requests()):
                self.pool.free(rid, missing_ok=True)
            for rid in list(self.pool.spilled_requests()):
                self.pool.drop_spilled(rid, missing_ok=True)
        try:
            self.executor.evict_all()
        except Exception:
            pass                      # executor may be mid-wreck already
        self._running.clear()
        self._prefilling.clear()
        self._preempted.clear()
        self.scheduler.clear()
        self._pending = []

    # ----------------------------------------------------------- admission
    def _reject(self, req: EngineRequest, reason: str) -> None:
        now = self._now()
        self._results.append(RequestResult(
            rid=req.rid, status="rejected", tokens=None, mask=None,
            bucket=(), arrival_t=req.arrival_t, admitted_t=-1.0,
            finished_t=now, queue_delay_s=now - req.arrival_t,
            decide_s=0.0, fits=False, cached_decision=False,
            peak_bytes=0.0, kv_bytes=0.0, reason=reason))

    def _try_admit(self, req: EngineRequest) -> str:
        """→ 'admitted' | 'defer' | 'rejected' (rejection recorded here)."""
        b, S = req.prompt.shape
        max_new = (self.cfg.max_new_tokens if req.max_new is None
                   else req.max_new)
        # prefill always yields one token, so the floor is 1 (a max_new=0
        # request is served as prefill-only next-token prediction)
        max_new = max(max_new, 1)
        total = S + max_new
        if req.rid in self._running or req.rid in self._prefilling:
            self._reject(req, f"duplicate request id {req.rid!r} "
                              f"(already in flight)")
            return "rejected"
        if total > self.cfg.max_len or b > self.cfg.max_active:
            if self.cfg.admission != "force":
                self._reject(req, f"shape (b={b}, prompt+gen={total}) "
                                  f"exceeds engine capacity "
                                  f"({self.cfg.max_active} slots × "
                                  f"{self.cfg.max_len})")
                return "rejected"
            if self._running:
                return "defer"   # growth drops live caches; wait for drain
            self.ensure_capacity(b, total)

        # keep-mask against the REMAINING shared budget (quantized down so
        # steady-state admissions hit the policy's memo table)
        eff = self._budget - self.pool.bytes_reserved
        quantum = self.cfg.budget_quantum_frac * self.mm.dense_peak(b, total)
        if quantum > 0 and self.cfg.admission == "strict":
            # (force mode is the one-shot compatibility path: budgets pass
            # through exactly so decisions match the historical contract)
            eff = np.floor(eff / quantum + 1e-9) * quantum
        cache_len = self._cache_len(total)
        d = self._sticky_decision(b, total, eff, cache_len)
        if d is None:
            d = self.policy.observe(PolicyState(
                batch=b, total_len=total, budget_bytes=eff,
                reserved_bytes=self.pool.bytes_reserved,
                capacity_bytes=self.pool.acct.capacity_bytes,
                n_running=len(self._running), now=self._now()))
        kv_bytes = self.mm.state_bytes(d.mask, b, total)
        if not self._paged:
            # slot-path admission charges QUANTIZED bytes: the analytical
            # model speaks model-width bytes, but an int8/fp8 slot cache
            # stores 1-byte elements (+ one f32 scale per token·head), so
            # a quantized request admits ~width× the sequence under the
            # same budget. (The paged path gets this for free: its pages
            # are physically narrower, so page counts already shrank, and
            # the pool's in_use_scale converts the analytical charge.)
            kv_bytes *= _kv_byte_ratio(d.kv_dtype, self.mcfg)
        if self._budget_trace is not None and self.cfg.admission == "strict":
            # elastic-budget gate: the pool's capacity was sized from the
            # BASE budget and cannot see a mid-run shrink, so admission
            # additionally checks the request's worst-case reservation
            # against the CURRENT budget — otherwise a shock would admit
            # into bytes the trace just took away and immediately preempt
            worst = (self.pool.pages_for_tokens(b, total)
                     * self.pool.page_bytes if self._paged
                     else self.pool.pages_needed(kv_bytes)
                     * self.pool.page_bytes)
            if self.pool.bytes_reserved + worst > self._kv_budget() + 1e-6:
                return "defer"
        force = self.cfg.admission == "force"
        if self._paged:
            # page-granular admission: the paged path physically stores
            # every layer's KV whatever the mask says (masked-mode gates
            # save compute, not memory), so the charge is the request's
            # worst-case PAGE commitment, not its analytical byte count —
            # the honest signal the policy's budget observation reflects
            if not self.pool.fits_capacity_tokens(b, total):
                self._reject(
                    req, f"{self.pool.pages_for_tokens(b, total)} pages "
                         f"({b}×{total} tokens) can never fit pool "
                         f"capacity of {self.pool.n_pages} pages")
                return "rejected"
            if not self.pool.can_alloc_tokens(b, total):
                return "defer"
        elif not force:
            if not self.pool.fits_capacity(kv_bytes):
                self._reject(req, f"state {kv_bytes:.0f}B can never fit "
                                  f"pool capacity "
                                  f"{self.pool.acct.capacity_bytes:.0f}B")
                return "rejected"
            if not self.pool.can_alloc(kv_bytes):
                return "defer"

        group = self.executor.group_for(d.mask, cache_len)
        free = group.free_slots()
        if len(free) < b:
            return "defer"
        slots = free[:b]
        # admission ends HERE: admitted_t (and so queue_delay_s) measures
        # time spent queued, not queueing + prefill — TTFT decomposes as
        # queue_delay_s + prefill time
        admitted_t = self._now()
        bucket = group.key if self.cfg.mode == "structural" else ()
        chunked = (self.cfg.max_prefill_tokens > 0 and S >= 1
                   and self.executor.supports_chunked_prefill(group))
        if self._paged:
            # grant pages backing the prompt now; commit the decode tail.
            # The ledger's in-use side stays analytical (the Eq. (3)–(4)
            # bytes) as a cross-check against the physical reservation.
            # Chunked prefill grants only the FIRST chunk's pages here —
            # each later chunk extends the allocation just before it runs
            # (the commitment above covers them, so the grants can't fail).
            if chunked:
                c1 = chunk_widths(S, self.cfg.max_prefill_tokens)[0]
                rate = kv_bytes / max(total, 1)
                self.pool.alloc_tokens(req.rid, b, c1, max_tokens=total,
                                       in_use_bytes=rate * c1,
                                       in_use_per_token=rate,
                                       kv_dtype=d.kv_dtype)
            else:
                prompt_bytes = self.mm.state_bytes(d.mask, b, S)
                rate = max(kv_bytes - prompt_bytes, 0.0) / max(total - S, 1)
                self.pool.alloc_tokens(req.rid, b, S, max_tokens=total,
                                       in_use_bytes=prompt_bytes,
                                       in_use_per_token=rate,
                                       kv_dtype=d.kv_dtype)
        else:
            self.pool.alloc(req.rid, kv_bytes, allow_overcommit=force)
        prompt = np.asarray(req.prompt, np.int32)
        if chunked:
            task = self.executor.prefill_begin(
                group, slots, req.rid, prompt, d.mask,
                max_chunk=self.cfg.max_prefill_tokens)
            self._prefilling[req.rid] = _Prefilling(
                req=req, decision=d, group=group, slots=slots,
                admitted_t=admitted_t, kv_bytes=kv_bytes, max_new=max_new,
                bucket=bucket, task=task)
            return "admitted"
        first = self.executor.prefill_into(group, slots, req.rid, prompt,
                                           d.mask)
        run = _Running(req=req, decision=d, group=group, slots=slots,
                       admitted_t=admitted_t, kv_bytes=kv_bytes,
                       max_new=max_new, out=[first], bucket=bucket,
                       events=[(self._now(), 1)])
        self._running[req.rid] = run
        # the prefill already produced token #1
        if run.max_new <= len(run.out):
            self._complete(run)
        return "admitted"

    def _sticky_decision(self, b: int, total: int, eff: float,
                         cache_len: int) -> Optional[Decision]:
        """Bucket affinity for structural mode: joining an already-compiled
        bucket whose keep-mask still fits the remaining budget batches with
        the requests resident there and skips both the policy rollout and a
        fresh compile. Without this, the drifting pool level mints a new
        bucket per admission and structural serving degenerates into
        per-request executables (the exact failure one-shot serving has)."""
        if self.cfg.mode != "structural" or self.cfg.admission != "strict":
            return None
        best = None
        for group in self.executor.groups():
            if group.mask is None or len(group.free_slots()) < b:
                continue
            # paged groups have no dense cache (pages grow per token), so
            # any bucket can host any admissible length — cache_len
            # affinity only applies to the slot-cache path
            if not self._paged and group.cache_len != cache_len:
                continue
            peak = self.mm.peak_bytes(group.mask, b, total)
            if peak > eff:
                continue
            if self._paged:
                if not self.pool.can_alloc_tokens(b, total):
                    continue
            elif not self.pool.can_alloc(
                    self.mm.state_bytes(group.mask, b, total)):
                continue
            # prefer the bucket keeping the most blocks (least over-pruned)
            kept = int(group.mask.sum())
            if best is None or kept > best[0]:
                best = (kept, group, peak)
        if best is None:
            return None
        _, group, peak = best
        return Decision(mask=group.mask.copy(), steps=0, peak_bytes=peak,
                        fits=True, latency_s=0.0, cached=True)

    # ------------------------------------------------------ chunked prefill
    def _advance_prefills(self) -> None:
        """Advance every in-flight chunked prefill by ONE chunk (at most
        ``cfg.max_prefill_tokens`` prompt tokens) — the interleave grain
        that bounds how long a long prompt can stall running decodes. A
        completing prefill seats its request (it joins decode next tick)
        and stamps its first-token event."""
        for rid in list(self._prefilling):
            pf = self._prefilling[rid]
            first = self.executor.prefill_step(pf.task)
            if first is None:
                continue
            del self._prefilling[rid]
            run = _Running(req=pf.req, decision=pf.decision, group=pf.group,
                           slots=pf.slots, admitted_t=pf.admitted_t,
                           kv_bytes=pf.kv_bytes, max_new=pf.max_new,
                           out=[first], bucket=pf.bucket,
                           events=[(self._now(), 1)])
            self._running[rid] = run
            if run.max_new <= len(run.out):
                self._complete(run)

    # --------------------------------------------------------------- decode
    def _launch_decode(self, decode_plan: Optional[List[str]],
                       backlog: bool = False) -> List[Tuple[Any, set]]:
        """Dispatch one fused horizon per occupied group named in the
        scheduler's decode plan, WITHOUT syncing. Returns the in-flight
        launches paired with the rids resident at launch time (the only
        requests this tick's tokens belong to). Plans are per-request but
        execution is per-group: a group steps if any of its residents are
        planned (the fused scan advances every occupant regardless — an
        unplanned co-resident's tokens are still folded back, since
        skipping them would discard real device work)."""
        launches: List[Tuple[Any, set]] = []
        if not self._running:
            return launches
        allowed = None if decode_plan is None else set(decode_plan)
        for group in self.executor.groups():
            if not group.occupied():
                continue
            runs = [run for run in self._running.values()
                    if run.group is group]
            if not runs or (allowed is not None
                            and not any(r.req.rid in allowed for r in runs)):
                continue
            # clamp the horizon to the group's largest remaining token
            # need, QUANTIZED up to a power of two: executables are
            # compiled per (batch width, horizon), and an exact clamp
            # would mint one per remaining-need value (timing-dependent —
            # steady state would never stop compiling). Pow2 bounds the
            # horizon set to {1, 2, 4, ...} while short tails still skip
            # most full-horizon compute; the overshoot is truncated at
            # fold-back.
            remaining = max((run.max_new - len(run.out) for run in runs),
                            default=1)
            horizon = min(self.cfg.decode_horizon,
                          _next_pow2(max(remaining, 1)))
            if backlog:
                # admission-stall clamp (bench triage): while requests
                # wait, a full horizon holds every completion — and the
                # slots/budget it would free — hostage until the group's
                # LONGEST resident retires it, so short-max_new traces
                # see queue delay grow with H. Clamp to the group's
                # soonest completion instead (pow2-quantized, same
                # bounded executable set): finished requests hand their
                # capacity to the queue at the earliest boundary. With an
                # empty queue the max-need horizon amortizes dispatch
                # exactly as before. Horizon size stays unobservable in
                # the token streams either way (truncated at fold-back).
                soonest = min((run.max_new - len(run.out) for run in runs),
                              default=1)
                horizon = min(horizon, _next_pow2(max(soonest, 1)))
            launches.append((self.executor.decode_launch(group, horizon),
                             {run.req.rid for run in runs}))
        return launches

    def _finish_decode(self, launches: List[Tuple[Any, set]]) -> None:
        """The tick's sync point: read back each launched horizon and fold
        its tokens into the requests that were resident at launch (a
        request admitted during the overlapped host phase gets nothing
        from this tick — its slot's rows are garbage). Completion is
        checked once at the horizon boundary; a request whose ``max_new``
        lands mid-horizon keeps only the tokens up to it — the trailing
        over-generated ones are truncated here, which is what makes
        horizon size unobservable in the results (bitwise-identical to
        decode_horizon=1)."""
        for launch, rids in launches:
            toks, _ = self.executor.decode_finish(launch)
            now = self._now()
            for rid in rids:
                run = self._running.get(rid)
                if run is None:
                    continue
                need = run.max_new - len(run.out)
                if need <= 0:
                    continue
                cols = toks[np.asarray(run.slots)]     # [b, horizon]
                n = min(need, launch.horizon)
                for h in range(n):
                    run.out.append(cols[:, h])
                run.events.append((now, n))
        self._decode_iters += 1
        used, phys = self.executor.kv_utilization()
        if phys > 0:
            self._frag_samples.append(1.0 - used / phys)
        done = [run for run in self._running.values()
                if len(run.out) >= run.max_new]
        # batch the device-side slot resets: one fused eviction per group
        # per macro-tick instead of one per completing request
        by_group: Dict[int, Tuple[Any, List[int]]] = {}
        for run in done:
            slots = by_group.setdefault(id(run.group), (run.group, []))[1]
            slots.extend(run.slots)
        for group, slots in by_group.values():
            group.evict(slots)
        for run in done:
            self._complete(run, evict=False)

    def _complete(self, run: _Running, *, evict: bool = True) -> None:
        if evict:
            run.group.evict(run.slots)
        self.pool.free(run.req.rid)
        now = self._now()
        d = run.decision
        # latency samples from the run's token-emission events: TTFT is
        # first token minus ARRIVAL (it includes the queue delay); each
        # later event covers one fused horizon and contributes its
        # per-token share n times, so long horizons don't undercount
        ttft = (run.events[0][0] - run.req.arrival_t if run.events
                else -1.0)
        if run.events:
            self._ttft_samples.append(ttft)
            # a preempted request's resume gap lands in its stream as one
            # huge inter-token latency: pool those samples separately so
            # untouched requests' ITL percentiles stay meaningful
            sink = (self._itl_preempted_samples if run.preempt_count > 0
                    else self._itl_samples)
            prev = run.events[0][0]
            for t, n in run.events[1:]:
                sink.extend([(t - prev) / max(n, 1)] * n)
                prev = t
        result = RequestResult(
            rid=run.req.rid, status="done",
            tokens=np.stack(run.out, axis=1),       # [b, generated]
            mask=d.mask, bucket=run.bucket,
            arrival_t=run.req.arrival_t, admitted_t=run.admitted_t,
            finished_t=now, queue_delay_s=run.admitted_t - run.req.arrival_t,
            decide_s=d.latency_s, fits=d.fits, cached_decision=d.cached,
            peak_bytes=d.peak_bytes, kv_bytes=run.kv_bytes, ttft_s=ttft)
        self._results.append(result)
        del self._running[run.req.rid]
        self.policy.feedback(result)

    # ---------------------------------------------------------------- stats
    def stats(self) -> Dict[str, Any]:
        out: Dict[str, Any] = dict(self.executor.stats())
        # per-request TTFT decomposition (queueing vs prefill) for the
        # most recent run: ttft_s − queue_delay_s is time from admission
        # to first token, i.e. the prefill share
        out["requests"] = {
            r.rid: {"queue_delay_s": r.queue_delay_s, "ttft_s": r.ttft_s,
                    "prefill_s": max(r.ttft_s - r.queue_delay_s, 0.0)}
            for r in self._results
            if r.status == "done" and r.ttft_s >= 0.0}
        return out
