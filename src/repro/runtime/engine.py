"""Continuous-batching RAP engine — shared-budget serving of concurrent
requests (the production form of paper Algorithm 3).

``RAPServer`` replays requests one at a time, so each request sees a
*private* instantaneous budget and "runtime memory variation" is simulated.
The engine makes the contention real: many in-flight requests compete for
one device budget, and the controller's keep-mask decision is made against
whatever the *pool* has left.

Architecture (one iteration of :meth:`RAPEngine._tick`):

  1. **arrivals** — requests become visible at their trace timestamps
     (virtual clock; idle gaps are skipped, compute time is real);
  2. **admission control** — FIFO head-of-line: for the oldest waiting
     request, ``RAPController.decide()`` runs against the *remaining*
     shared budget (total budget minus the pool's reserved bytes), then the
     request's analytical KV/state bytes are allocated from the
     :class:`~repro.runtime.kv_pool.KVPool`. If pages are short the request
     waits (strict mode) — admission never lets bytes-in-use exceed the
     budget. ``force`` mode (the one-shot compatibility path) admits
     regardless and records the overcommit;
  3. **prefill** — newly admitted requests prefill individually (shapes
     differ) and their caches are written into free *slots* of the group's
     shared slot-batched cache;
  4. **decode** — ALL running requests advance one token in a single fused
     ``decode_step`` per group: per-slot positions (int32 [B]) and
     per-slot gates ([L, B]) let one executable serve every resident
     keep-mask in ``masked`` mode; ``structural`` mode groups requests by
     bucket (retained-layout signature) with one compacted executable per
     bucket, vLLM-shape-bucket style.

Completed requests free their pages and slot, unblocking the queue.
"""
from __future__ import annotations

import collections
import dataclasses
import time
from typing import Any, Deque, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import masks as masks_lib
from repro.core.controller import Decision, RAPController
from repro.models import decoder
from repro.runtime.kv_pool import KVPool, default_page_bytes

__all__ = ["EngineConfig", "EngineRequest", "RequestResult", "EngineReport",
           "RAPEngine"]


# ------------------------------------------------------------------- config
@dataclasses.dataclass
class EngineConfig:
    mode: str = "masked"              # masked | structural
    max_new_tokens: int = 16
    max_active: int = 8               # cache slots per group (decode batch)
    max_len: int = 256                # slot cache length (prompt + generated)
    budget_bytes: float = 0.0         # TOTAL device budget (params + states)
    page_bytes: int = 0               # 0 → derived from the memory model
    tokens_per_page: int = 16
    kv_dtype: Any = None
    admission: str = "strict"         # strict (queue) | force (overcommit)
    # Admission quantizes the effective budget DOWN to this fraction of the
    # request's dense peak before calling decide(). The pool level drifts
    # continuously; without a quantum every admission sees a fresh budget,
    # the controller emits a fresh mask, and structural mode compiles a
    # fresh bucket — quantizing collapses steady-state admissions onto a
    # handful of memoized decisions/buckets. Safety is unaffected: the page
    # allocator, not the decision, enforces the byte budget.
    budget_quantum_frac: float = 0.05

    def __post_init__(self):
        if self.mode not in ("masked", "structural"):
            raise ValueError(f"unknown mode {self.mode!r}")
        if self.admission not in ("strict", "force"):
            raise ValueError(f"unknown admission {self.admission!r}")


@dataclasses.dataclass
class EngineRequest:
    rid: str                          # unique among in-flight requests
    prompt: np.ndarray                # int32 [b, S]
    arrival_t: float = 0.0
    max_new: Optional[int] = None     # generated tokens (≥1: prefill always
                                      # yields one); None → engine default


@dataclasses.dataclass
class RequestResult:
    rid: str
    status: str                       # done | rejected
    tokens: Optional[np.ndarray]      # [b, generated]
    mask: Optional[np.ndarray]
    bucket: Tuple
    arrival_t: float
    admitted_t: float
    finished_t: float
    queue_delay_s: float
    decide_s: float
    fits: bool
    cached_decision: bool
    peak_bytes: float
    kv_bytes: float
    reason: str = ""


@dataclasses.dataclass
class EngineReport:
    results: List[RequestResult]
    wall_s: float                     # real compute wall time
    makespan_s: float                 # virtual: includes skipped arrival gaps
    generated_tokens: int
    tokens_per_s: float               # generated / makespan_s
    mean_queue_delay_s: float
    budget_fit_rate: float            # admitted requests whose peak fit
    rejected: int
    decode_iters: int
    compile_events: int
    pool: Dict[str, float]

    def result(self, rid: str) -> RequestResult:
        for r in self.results:
            if r.rid == rid:
                return r
        raise KeyError(rid)


# ------------------------------------------------------------------ groups
class _Group:
    """One slot-batched executable family sharing a cache.

    masked mode: a single group over the full params with per-slot gates.
    structural mode: one group per bucket (compacted params, gates absorbed
    into structure)."""

    def __init__(self, key, params, layout, cfg_model, n_slots: int,
                 max_len: int, kv_dtype, gated: bool,
                 mask: Optional[np.ndarray] = None):
        self.key = key
        self.params = params
        self.layout = layout
        self.mask = mask              # the keep-mask that minted this bucket
        self.n_slots = n_slots
        self.max_len = max_len
        self.gated = gated
        self.occupants: List[Optional[str]] = [None] * n_slots
        self.cache = decoder.init_cache(cfg_model, n_slots, max_len,
                                        layout, kv_dtype)
        self.cache["pos"] = jnp.zeros((n_slots,), jnp.int32)
        self.tokens = jnp.zeros((n_slots, 1), jnp.int32)
        if gated:
            L = cfg_model.n_layers
            self._gates_np = np.ones((2, L, n_slots), np.float32)
            self._gates_dev = jnp.asarray(self._gates_np)
        cfg = cfg_model
        layout_c = layout

        if gated:
            @jax.jit
            def step(p, cache, tok, gm, gf):
                return decoder.decode_step(p, cfg, cache, tok,
                                           gates={"mixer": gm, "ffn": gf})
        else:
            @jax.jit
            def step(p, cache, tok):
                return decoder.decode_step(p, cfg, cache, tok,
                                           layout=layout_c)
        self._step = step
        self.compiled = False        # flips on first decode (trace+compile)

    # ----------------------------------------------------------- occupancy
    def free_slots(self) -> List[int]:
        return [i for i, o in enumerate(self.occupants) if o is None]

    def occupied(self) -> bool:
        return any(o is not None for o in self.occupants)

    def place(self, rid: str, slots: List[int], req_cache: dict,
              mask: Optional[np.ndarray], prompt_len: int) -> None:
        """Write a freshly prefilled request cache into ``slots``."""
        idx = jnp.asarray(slots, jnp.int32)
        cache = dict(self.cache)
        for k, v in cache.items():
            if k == "pos":
                cache[k] = v.at[idx].set(jnp.asarray(prompt_len, jnp.int32))
            else:
                cache[k] = jax.tree.map(
                    lambda big, small: big.at[:, idx].set(small), v,
                    req_cache[k])
        self.cache = cache
        for s in slots:
            self.occupants[s] = rid
        if self.gated and mask is not None:
            g = masks_lib.mask_to_gates(mask)
            for s in slots:
                self._gates_np[0, :, s] = np.asarray(g["mixer"])
                self._gates_np[1, :, s] = np.asarray(g["ffn"])
            self._gates_dev = jnp.asarray(self._gates_np)

    def set_tokens(self, slots: List[int], toks: np.ndarray) -> None:
        idx = jnp.asarray(slots, jnp.int32)
        self.tokens = self.tokens.at[idx, 0].set(
            jnp.asarray(toks, jnp.int32))

    def evict(self, slots: List[int]) -> None:
        for s in slots:
            self.occupants[s] = None

    # -------------------------------------------------------------- decode
    def decode_once(self) -> Tuple[np.ndarray, bool]:
        """Advance every slot one token; returns ([n_slots] next tokens,
        whether this call compiled a new executable)."""
        new = not self.compiled
        self.compiled = True
        if self.gated:
            logits, self.cache = self._step(self.params, self.cache,
                                            self.tokens, self._gates_dev[0],
                                            self._gates_dev[1])
        else:
            logits, self.cache = self._step(self.params, self.cache,
                                            self.tokens)
        nxt = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
        self.tokens = nxt[:, None]
        return np.asarray(nxt), new


@dataclasses.dataclass
class _Running:
    req: EngineRequest
    decision: Decision
    group_key: Any
    slots: List[int]
    admitted_t: float
    kv_bytes: float
    max_new: int
    out: List[np.ndarray]            # per generated step: [b] tokens
    bucket: Tuple


# ------------------------------------------------------------------- engine
class RAPEngine:
    """Continuous-batching serving engine with RAP admission control."""

    def __init__(self, model, params, controller: RAPController,
                 cfg: EngineConfig):
        self.model = model
        self.mcfg = model.cfg
        if getattr(self.mcfg, "is_encoder_decoder", False):
            raise NotImplementedError("engine serves decoder-only models")
        self.params = params
        self.controller = controller
        # private copy: ensure_capacity mutates max_len/max_active, and a
        # caller-shared config would desync another engine's shape checks
        # from its actual cache sizes
        self.cfg = dataclasses.replace(cfg)
        self.mm = controller.mm
        self._full_mask = masks_lib.full_mask(self.mcfg.n_layers)
        self.resident_param_bytes = self.mm.param_bytes(self._full_mask)
        self._groups: Dict[Any, _Group] = {}
        self._prefill_fns: Dict[Tuple, Any] = {}
        self.pool: Optional[KVPool] = None
        # run state
        self._pending: List[EngineRequest] = []
        self._waiting: Deque[EngineRequest] = collections.deque()
        self._running: "collections.OrderedDict[str, _Running]" = \
            collections.OrderedDict()
        self._results: List[RequestResult] = []
        self._decode_iters = 0
        self._compiles = 0
        self._t0 = 0.0
        self._skew = 0.0
        self._budget = cfg.budget_bytes

    # ------------------------------------------------------------ capacity
    def ensure_capacity(self, batch: int, total_len: int) -> None:
        """Grow slot count / cache length; drops compiled groups on change."""
        grew = False
        if total_len > self.cfg.max_len:
            self.cfg.max_len = int(total_len)
            grew = True
        if batch > self.cfg.max_active:
            self.cfg.max_active = int(batch)
            grew = True
        if grew:
            self._groups.clear()
            self._prefill_fns.clear()

    # ---------------------------------------------------------------- time
    def _now(self) -> float:
        return (time.perf_counter() - self._t0) + self._skew

    # ---------------------------------------------------------------- pool
    def _make_pool(self, budget_bytes: float) -> KVPool:
        page = self.cfg.page_bytes or default_page_bytes(
            self.mm, self.cfg.tokens_per_page)
        cap = budget_bytes - self.resident_param_bytes
        if cap < page and self.cfg.admission == "strict":
            raise ValueError(
                f"budget {budget_bytes:.0f}B leaves no KV pool after "
                f"resident params ({self.resident_param_bytes:.0f}B)")
        return KVPool(max(cap, 0.0), page_bytes=page, mm=self.mm)

    # ------------------------------------------------------------- serving
    def run(self, requests: List[EngineRequest], *,
            budget_bytes: Optional[float] = None) -> EngineReport:
        """Serve a trace to completion and report aggregate stats."""
        budget = self.cfg.budget_bytes if budget_bytes is None else budget_bytes
        self.pool = self._make_pool(budget)
        self._budget = budget
        self._pending = sorted(requests, key=lambda r: r.arrival_t)
        self._waiting.clear()
        self._running.clear()
        self._results = []
        self._decode_iters = 0
        self._compiles = 0
        self._skew = 0.0
        self._t0 = time.perf_counter()
        for g in self._groups.values():       # previous run's occupants
            g.evict([i for i in range(g.n_slots)])
        while self._pending or self._waiting or self._running:
            self._tick()
        # makespan is on the VIRTUAL clock (skipped idle gaps included) —
        # the same clock request timestamps live on, so throughput is
        # comparable with any other replay of the same arrival process
        makespan = self._now()
        wall = time.perf_counter() - self._t0
        done = [r for r in self._results if r.status == "done"]
        gen = sum(r.tokens.size for r in done if r.tokens is not None)
        delays = [r.queue_delay_s for r in done]
        return EngineReport(
            results=self._results,
            wall_s=wall,
            makespan_s=makespan,
            generated_tokens=gen,
            tokens_per_s=gen / max(makespan, 1e-9),
            mean_queue_delay_s=float(np.mean(delays)) if delays else 0.0,
            budget_fit_rate=(float(np.mean([r.fits for r in done]))
                             if done else 0.0),
            rejected=sum(1 for r in self._results if r.status == "rejected"),
            decode_iters=self._decode_iters,
            compile_events=self._compiles,
            pool=self.pool.stats())

    # ------------------------------------------------------------ one tick
    def _tick(self) -> None:
        now = self._now()
        while self._pending and self._pending[0].arrival_t <= now:
            self._waiting.append(self._pending.pop(0))
        # FIFO admission with head-of-line blocking (completion order stays
        # arrival order for equal decode lengths)
        while self._waiting:
            verdict = self._try_admit(self._waiting[0])
            if verdict == "defer":
                break
            self._waiting.popleft()
        if not self._running:
            if self._waiting:
                # deferred head with an idle engine: nothing will ever free
                # memory — reject instead of spinning (defensive; strict
                # capacity misfits are rejected in _try_admit already)
                self._reject(self._waiting.popleft(),
                             "deferred with idle engine")
            elif self._pending:
                # fast-forward the virtual clock across the idle gap
                self._skew += self._pending[0].arrival_t - self._now() + 1e-9
            return
        self._decode_all()

    # ----------------------------------------------------------- admission
    def _reject(self, req: EngineRequest, reason: str) -> None:
        now = self._now()
        self._results.append(RequestResult(
            rid=req.rid, status="rejected", tokens=None, mask=None,
            bucket=(), arrival_t=req.arrival_t, admitted_t=-1.0,
            finished_t=now, queue_delay_s=now - req.arrival_t,
            decide_s=0.0, fits=False, cached_decision=False,
            peak_bytes=0.0, kv_bytes=0.0, reason=reason))

    def _try_admit(self, req: EngineRequest) -> str:
        """→ 'admitted' | 'defer' | 'rejected' (rejection recorded here)."""
        b, S = req.prompt.shape
        max_new = (self.cfg.max_new_tokens if req.max_new is None
                   else req.max_new)
        # prefill always yields one token, so the floor is 1 (a max_new=0
        # request is served as prefill-only next-token prediction)
        max_new = max(max_new, 1)
        total = S + max_new
        if req.rid in self._running:
            self._reject(req, f"duplicate request id {req.rid!r} "
                              f"(already in flight)")
            return "rejected"
        if total > self.cfg.max_len or b > self.cfg.max_active:
            if self.cfg.admission != "force":
                self._reject(req, f"shape (b={b}, prompt+gen={total}) "
                                  f"exceeds engine capacity "
                                  f"({self.cfg.max_active} slots × "
                                  f"{self.cfg.max_len})")
                return "rejected"
            if self._running:
                return "defer"   # growth drops live caches; wait for drain
            self.ensure_capacity(b, total)

        # keep-mask against the REMAINING shared budget (quantized down so
        # steady-state admissions hit the controller's memo table)
        eff = self._budget - self.pool.bytes_reserved
        quantum = self.cfg.budget_quantum_frac * self.mm.dense_peak(b, total)
        if quantum > 0 and self.cfg.admission == "strict":
            # (force mode is the one-shot compatibility path: budgets pass
            # through exactly so decisions match the historical contract)
            eff = np.floor(eff / quantum + 1e-9) * quantum
        d = self._sticky_decision(b, total, eff)
        if d is None:
            d = self.controller.decide(b, total, eff)
        kv_bytes = self.mm.state_bytes(d.mask, b, total)
        force = self.cfg.admission == "force"
        if not force:
            if not self.pool.fits_capacity(kv_bytes):
                self._reject(req, f"state {kv_bytes:.0f}B can never fit "
                                  f"pool capacity "
                                  f"{self.pool.acct.capacity_bytes:.0f}B")
                return "rejected"
            if not self.pool.can_alloc(kv_bytes):
                return "defer"

        group = self._group_for(d.mask)
        free = group.free_slots()
        if len(free) < b:
            return "defer"
        slots = free[:b]
        self.pool.alloc(req.rid, kv_bytes, allow_overcommit=force)
        first = self._prefill_into(group, slots, req, d)
        bucket = group.key if self.cfg.mode == "structural" else ()
        run = _Running(req=req, decision=d, group_key=group.key, slots=slots,
                       admitted_t=self._now(), kv_bytes=kv_bytes,
                       max_new=max_new, out=[first], bucket=bucket)
        self._running[req.rid] = run
        # the prefill already produced token #1
        if run.max_new <= len(run.out):
            self._complete(run)
        return "admitted"

    def _sticky_decision(self, b: int, total: int,
                         eff: float) -> Optional[Decision]:
        """Bucket affinity for structural mode: joining an already-compiled
        bucket whose keep-mask still fits the remaining budget batches with
        the requests resident there and skips both the Q-rollout and a fresh
        compile. Without this, the drifting pool level mints a new bucket
        per admission and structural serving degenerates into per-request
        executables (the exact failure one-shot serving has)."""
        if self.cfg.mode != "structural" or self.cfg.admission != "strict":
            return None
        best = None
        for group in self._groups.values():
            if group.mask is None or len(group.free_slots()) < b:
                continue
            peak = self.mm.peak_bytes(group.mask, b, total)
            if peak > eff:
                continue
            if not self.pool.can_alloc(
                    self.mm.state_bytes(group.mask, b, total)):
                continue
            # prefer the bucket keeping the most blocks (least over-pruned)
            kept = int(group.mask.sum())
            if best is None or kept > best[0]:
                best = (kept, group, peak)
        if best is None:
            return None
        _, group, peak = best
        return Decision(mask=group.mask.copy(), steps=0, peak_bytes=peak,
                        fits=True, latency_s=0.0, cached=True)

    # ------------------------------------------------------------ executors
    def _group_for(self, mask: np.ndarray) -> _Group:
        if self.cfg.mode == "masked":
            key = "masked"
            if key not in self._groups:
                self._groups[key] = _Group(
                    key, self.params, None, self.mcfg, self.cfg.max_active,
                    self.cfg.max_len, self.cfg.kv_dtype, gated=True)
            return self._groups[key]
        key = masks_lib.bucket_key(self.mcfg, mask)
        if key not in self._groups:
            small, layout = masks_lib.compact_params(self.params, self.mcfg,
                                                     mask)
            self._groups[key] = _Group(
                key, small, layout, self.mcfg, self.cfg.max_active,
                self.cfg.max_len, self.cfg.kv_dtype, gated=False,
                mask=np.array(mask, copy=True))
        return self._groups[key]

    def _prefill_fn(self, group: _Group, b: int, S: int):
        key = (group.key, b, S)
        if key not in self._prefill_fns:
            cfg, max_len = self.mcfg, self.cfg.max_len
            kv_dtype, layout = self.cfg.kv_dtype, group.layout
            if group.gated:
                @jax.jit
                def fn(p, tokens, gm, gf):
                    return decoder.prefill(p, cfg, tokens, max_len,
                                           gates={"mixer": gm, "ffn": gf},
                                           kv_dtype=kv_dtype)
            else:
                @jax.jit
                def fn(p, tokens):
                    return decoder.prefill(p, cfg, tokens, max_len,
                                           layout=layout, kv_dtype=kv_dtype)
            self._prefill_fns[key] = fn
            self._compiles += 1
        return self._prefill_fns[key]

    def _prefill_into(self, group: _Group, slots: List[int],
                      req: EngineRequest, d: Decision) -> np.ndarray:
        """Prefill the request and seat it; returns token #1 per row [b]."""
        b, S = req.prompt.shape
        tokens = jnp.asarray(req.prompt, jnp.int32)
        fn = self._prefill_fn(group, b, S)
        if group.gated:
            g = masks_lib.mask_to_gates(d.mask)
            logits, cache = fn(self.params, tokens, g["mixer"], g["ffn"])
        else:
            logits, cache = fn(group.params, tokens)
        cache.pop("pos")
        group.place(req.rid, slots, cache, d.mask if group.gated else None, S)
        first = np.asarray(jnp.argmax(logits, axis=-1).astype(jnp.int32))
        group.set_tokens(slots, first)
        return first

    # --------------------------------------------------------------- decode
    def _decode_all(self) -> None:
        stepped = False
        for group in self._groups.values():
            if not group.occupied():
                continue
            nxt, compiled = group.decode_once()
            stepped = True
            if compiled:
                self._compiles += 1
            for run in list(self._running.values()):
                if run.group_key != group.key:
                    continue
                if len(run.out) >= run.max_new:
                    continue
                run.out.append(nxt[np.asarray(run.slots)])
        if stepped:
            self._decode_iters += 1
        for run in list(self._running.values()):
            if len(run.out) >= run.max_new:
                self._complete(run)

    def _complete(self, run: _Running) -> None:
        group = self._groups[run.group_key]
        group.evict(run.slots)
        self.pool.free(run.req.rid)
        now = self._now()
        d = run.decision
        self._results.append(RequestResult(
            rid=run.req.rid, status="done",
            tokens=np.stack(run.out, axis=1),       # [b, generated]
            mask=d.mask, bucket=run.bucket,
            arrival_t=run.req.arrival_t, admitted_t=run.admitted_t,
            finished_t=now, queue_delay_s=run.admitted_t - run.req.arrival_t,
            decide_s=d.latency_s, fits=d.fits, cached_decision=d.cached,
            peak_bytes=d.peak_bytes, kv_bytes=run.kv_bytes))
        del self._running[run.req.rid]

    # ---------------------------------------------------------------- stats
    def stats(self) -> Dict[str, int]:
        return {
            "groups": len(self._groups),
            "structural_buckets": sum(1 for k in self._groups
                                      if k != "masked"),
            "prefill_executables": len(self._prefill_fns),
            "masked_prefill_executables": sum(
                1 for k in self._prefill_fns if k[0] == "masked"),
            "compile_events": self._compiles,
        }
