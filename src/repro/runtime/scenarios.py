"""Fault-injection scenarios for the serving engine (DESIGN.md §11).

The engine's elastic-budget machinery (preemption, KV spill/resume,
cancellation) is only trustworthy if it survives adversarial traffic, so
this module packages the three scenario families the bench hard-gates:

 * **budget-shock staircases** — the device budget is cut mid-serve (the
   paper's "runtime memory variation", `core/workload.py`'s OU walk in
   its most hostile form) and later restored; the engine must keep
   completing requests during the shock and recover its warmed
   throughput afterwards;
 * **cancellation storms** — a large fraction of in-flight requests is
   cancelled at random lifecycle stages (queued, prefilling,
   mid-horizon, preempted); the pool must end with zero live rids and
   zero leaked pages;
 * **heavy-tailed prompt mixes** — lognormal prompt lengths stress
   admission and preemption with co-resident requests of very different
   KV footprints.

Budget traces come in two forms, matching ``RAPEngine.run``:

 * :class:`TickStaircase` is **call-counting**: it steps on each engine
   tick, not at wall-clock breakpoints, so tests and benches get a
   deterministic number of pre-shock ticks regardless of how long a tick
   takes on the machine running them;
 * :func:`staircase_trace` / :func:`workload_budget_trace` build
   ``(t, bytes)`` breakpoint lists on the virtual clock — the form the
   serve CLI uses, where wall-time realism matters more than tick-exact
   determinism.
"""
from __future__ import annotations

import math
from typing import Any, Dict, List, Optional, Sequence, Tuple

import numpy as np

__all__ = ["TickStaircase", "staircase_trace", "workload_budget_trace",
           "heavy_tailed_requests", "run_budget_shock",
           "run_cancellation_storm"]


class TickStaircase:
    """Piecewise-constant budget over engine TICKS: ``phases`` is a list
    of ``(n_ticks, frac)`` — the budget is ``base_bytes * frac`` for the
    next ``n_ticks`` evaluations, holding the last phase's value once the
    phases are exhausted. The engine evaluates callable traces exactly
    once per tick, which makes this deterministic where wall-clock
    breakpoints are not (tick duration varies across machines)."""

    def __init__(self, base_bytes: float,
                 phases: Sequence[Tuple[int, float]]):
        if not phases:
            raise ValueError("TickStaircase needs at least one phase")
        self.base_bytes = float(base_bytes)
        self.phases = [(int(n), float(f)) for n, f in phases]
        if any(n < 0 for n, _ in self.phases):
            raise ValueError(f"phase tick counts must be >= 0, got "
                             f"{self.phases!r}")
        self.calls = 0

    def __call__(self, now: float) -> float:
        self.calls += 1
        left = self.calls
        for n, frac in self.phases:
            if left <= n:
                return self.base_bytes * frac
            left -= n
        return self.base_bytes * self.phases[-1][1]


def staircase_trace(base_bytes: float, t_down: float, t_up: float,
                    frac: float = 0.5) -> List[Tuple[float, float]]:
    """Breakpoint-list form of a single budget shock on the virtual
    clock: full budget until ``t_down``, ``frac`` of it until ``t_up``,
    full again after."""
    if not t_down < t_up:
        raise ValueError(f"shock window must satisfy t_down < t_up, got "
                         f"[{t_down}, {t_up})")
    return [(0.0, float(base_bytes)),
            (float(t_down), float(base_bytes) * float(frac)),
            (float(t_up), float(base_bytes))]


def workload_budget_trace(workload_requests,
                          base_bytes: float) -> List[Tuple[float, float]]:
    """Derive a budget trace from ``core/workload.py`` requests: each
    request's ``budget_frac`` (the OU memory-availability walk sampled at
    its arrival) becomes a breakpoint scaling the base budget — the
    serving loop finally consumes the trace the workload module has
    always synthesized."""
    return [(float(r.t), float(base_bytes) * float(r.budget_frac))
            for r in workload_requests]


def heavy_tailed_requests(tokens: np.ndarray, n: int, *, seed: int = 0,
                          rate: float = 200.0, min_len: int = 8,
                          max_len: int = 64, sigma: float = 0.8,
                          max_new: int = 4) -> List[Any]:
    """Poisson arrivals with LOGNORMAL prompt lengths clipped to
    ``[min_len, max_len]`` — a heavy-tailed mix where a few long prompts
    co-reside with many short ones, the regime where victim selection and
    page-granular admission actually differ from the uniform traces.
    Prompt token ids are sliced from ``tokens`` (any [1, >=max_len] int
    array). Deterministic in ``seed``."""
    from repro.runtime.engine import EngineRequest
    rng = np.random.default_rng(seed)
    toks = np.asarray(tokens, np.int32)[:1]
    if toks.shape[1] < max_len:
        raise ValueError(f"token source holds {toks.shape[1]} tokens, "
                         f"need max_len={max_len}")
    med = math.sqrt(min_len * max_len)      # median in the middle (log scale)
    out = []
    t = 0.0
    for i in range(int(n)):
        t += float(rng.exponential(1.0 / rate))
        s = int(np.clip(rng.lognormal(math.log(med), sigma),
                        min_len, max_len))
        out.append(EngineRequest(rid=f"h{i}", prompt=toks[:, :s].copy(),
                                 arrival_t=t, max_new=max_new))
    return out


# ------------------------------------------------------------- scenarios
def _phase_stats(results, lo: float, hi: float) -> Dict[str, float]:
    """Completions whose finish lands in the virtual-clock window
    [lo, hi): count, generated tokens, tokens/s over the window, and
    ``slot_tok_per_s`` — tokens per second of request RESIDENCY
    (admission→finish, clipped to the window). The residency-normalized
    rate is what recovery gates compare: the raw window rate collapses
    at the drain tail when concurrency decays to one straggler, while
    per-residency throughput stays flat unless the engine actually got
    slower (leaked pages/slots stretch every residency)."""
    done = [r for r in results
            if r.status == "done" and lo <= r.finished_t < hi]
    toks = sum(r.tokens.size for r in done if r.tokens is not None)
    span = max(hi - lo, 1e-9)
    busy = sum(max(0.0, min(r.finished_t, hi) - max(r.admitted_t, lo))
               for r in done)
    return {"completed": float(len(done)), "tokens": float(toks),
            "tok_per_s": toks / span, "window_s": span,
            "slot_tok_per_s": toks / max(busy, 1e-9)}


def run_budget_shock(engine, requests, *, budget_bytes: float,
                     frac: float = 0.5, pre_ticks: Optional[int] = None,
                     shock_ticks: Optional[int] = None) -> Dict[str, Any]:
    """Serve ``requests`` under a tick-staircase budget shock: full
    budget for ``pre_ticks`` ticks, then a cut taking ``frac`` of the
    **KV headroom** away for ``shock_ticks``, full again until drain.
    When the windows are not given they are auto-sized to ~30%/30% of
    the workload's estimated drain ticks, so the budget recovers while
    requests are still outstanding — a fixed window silently degenerates
    (no post-recovery completions to gate on) whenever the workload
    drains inside it.
    The cut is applied to the budget's KV share (budget − resident
    params), not the total: params stay resident through a shock, and at
    small model scale a 50% *total* cut would zero the pool outright
    instead of halving it — the interesting regime is the one where the
    engine must shed *some* victims and keep serving the rest. Phase
    windows are recovered from the report's ``budget_events`` (virtual
    clock), so the per-phase stats line up with what the engine actually
    applied.

    The bench hard-gates on the returned dict: ``completed`` > 0 in both
    the shock and post phases (forward progress, no deadlock) and
    ``recovery_ratio`` — best-of-``replays`` full-budget replay tok/s
    AFTER the shocked run over the same measured BEFORE it — above its
    floor. Recovery is steady state vs steady state on the same warmed
    engine: in-run phase-window rates (kept as diagnostics under
    ``pre``/``shock``/``post``) are hopelessly biased at smoke scale,
    where a shock-narrowed decode width hits an XLA compile the warmup
    never saw and the drain tail runs below full concurrency. What the
    gate owns is leakage: pages/slots/accounting corruption surviving
    the shock shows up as a permanently slower engine."""
    if pre_ticks is None or shock_ticks is None:
        cfg = engine.cfg
        h = max(int(getattr(cfg, "decode_horizon", 1) or 1), 1)
        slots = max(int(getattr(cfg, "max_active", 1) or 1), 1)
        longest = max((r.max_new if r.max_new is not None
                       else cfg.max_new_tokens) for r in requests)
        # one prefill tick + the decode horizons, in slot-width waves
        per_req = 1 + math.ceil(max(longest, 1) / h)
        est = math.ceil(len(requests) / slots) * per_req
        if pre_ticks is None:
            # a low floor drops the shock onto the FIRST resident wave
            # (mid-decode, reservations at their peak) — land it later
            # and the wave has drained, so nothing is left to preempt
            pre_ticks = max(3, round(0.3 * est))
        if shock_ticks is None:
            shock_ticks = max(6, round(0.3 * est))
    params = float(getattr(engine, "resident_param_bytes", 0.0))
    kv_share = max(budget_bytes - params, 0.0)
    shock_frac_total = (params + (1.0 - frac) * kv_share) / budget_bytes
    trace = TickStaircase(budget_bytes,
                          [(pre_ticks, 1.0), (shock_ticks, shock_frac_total),
                           (0, 1.0)])
    replays = 3
    warmed_rate = max(engine.run(requests).tokens_per_s
                      for _ in range(replays))
    report = engine.run(requests, budget_bytes=budget_bytes,
                        budget_trace=trace)
    replay_rate = max(engine.run(requests).tokens_per_s
                      for _ in range(replays))
    # budget_events: (0, full) then one event per applied change; the
    # first drop below full opens the shock window, the return closes it
    t_down = t_up = None
    for t, b in report.budget_events[1:]:
        if t_down is None and b < budget_bytes:
            t_down = t
        elif t_down is not None and b >= budget_bytes:
            t_up = t
            break
    end = max(report.makespan_s, 1e-9)
    if t_down is None:                    # drained before the shock hit
        t_down = t_up = end
    elif t_up is None:                    # drained inside the shock
        t_up = end
    # the pre-shock window starts at the FIRST completion, not t=0:
    # cold-start compiles would otherwise depress the pre-shock rate and
    # flatter the recovery ratio (benches additionally warm up first)
    first_done = min((r.finished_t for r in report.results
                      if r.status == "done"), default=0.0)
    pre = _phase_stats(report.results, min(first_done, t_down), t_down)
    shock = _phase_stats(report.results, t_down, t_up)
    post = _phase_stats(report.results, t_up, end)
    return {
        "report": report,
        "shock_frac": float(frac),
        "t_down": float(t_down), "t_up": float(t_up),
        "pre": pre, "shock": shock, "post": post,
        "preempted_count": report.preempted_count,
        "spilled_mb": report.spilled_mb,
        "resume_p50_s": report.resume_latency.get("p50", 0.0),
        "warmed_tok_per_s": float(warmed_rate),
        "replay_tok_per_s": float(replay_rate),
        "recovery_ratio": (replay_rate / warmed_rate
                           if warmed_rate > 0 else 0.0),
        "deadlock": False,                # engine.run returned ⇒ it drained
    }


def run_cancellation_storm(engine, requests, *, cancel_frac: float = 0.25,
                           seed: int = 0, start_tick: int = 2,
                           budget_trace: Optional[Any] = None,
                           budget_bytes: Optional[float] = None
                           ) -> Dict[str, Any]:
    """Serve ``requests`` while cancelling at least ``cancel_frac`` of
    them from the on_tick hook — each victim drawn at whatever lifecycle
    stage it happens to occupy (queued, prefilling, mid-horizon decode,
    or preempted when a ``budget_trace`` is also applied), which is the
    point: the cancel path must be safe at every stage, concurrently
    with in-flight scans. Victim draws are deterministic in ``seed``;
    the asserted invariants (zero live rids, zero leaked pages) are
    timing-independent.

    Returns pool-ledger invariants the bench hard-gates."""
    rng = np.random.default_rng(seed)
    quota = int(math.ceil(cancel_frac * len(requests)))
    state = {"tick": 0, "cancelled": 0}

    def on_tick(eng):
        state["tick"] += 1
        if state["tick"] < start_tick or state["cancelled"] >= quota:
            return
        stages = ([r.rid for r in eng._pending]
                  + [rid for rid in eng._prefilling]
                  + [rid for rid in eng._running]
                  + [rid for rid in eng._preempted]
                  + [r.rid for r in
                     eng.scheduler.schedule(eng._now()).admit])
        if not stages:
            return
        # one victim per tick keeps every stage reachable across the
        # storm instead of emptying the engine in one burst
        rid = stages[int(rng.integers(0, len(stages)))]
        if eng.cancel(rid):
            state["cancelled"] += 1
        # double-cancel is part of the storm: must be a no-op
        assert eng.cancel(rid) is False

    report = engine.run(requests, budget_bytes=budget_bytes,
                        budget_trace=budget_trace, on_tick=on_tick)
    pool = engine.pool.stats()
    return {
        "report": report,
        "n_requests": len(requests),
        "cancelled": report.cancelled,
        "cancel_quota": quota,
        "done": sum(1 for r in report.results if r.status == "done"),
        "live_requests": pool["live_requests"],
        "spilled_requests": pool["spilled_requests"],
        "leaked_pages": pool["n_pages"] - pool["free_pages"],
        "preempted_count": report.preempted_count,
        "deadlock": False,
    }
