"""Fault-tolerant training runtime.

Production behaviours implemented (and exercised by tests on CPU):
  * checkpoint/restart — atomic step checkpoints (params + optimizer +
    data-cursor); on start the trainer resumes from the latest manifest,
    and the step-indexed data pipeline replays the exact batch sequence;
  * crash safety — any exception triggers a best-effort emergency save
    before re-raising, so at most one step of work is lost;
  * straggler mitigation — per-step wall-time EWMA; a step slower than
    ``straggler_factor ×`` EWMA increments a counter and fires the
    ``on_straggler`` hook (on a real cluster this feeds the coordinator
    that re-schedules the slow host; here it is observable behaviour
    under test);
  * elastic re-mesh — ``Trainer.remesh(new_mesh)`` re-jits the step and
    re-shards params/optimizer onto a different device count via
    device_put; combined with checkpoint restore this is the
    shrink/grow-the-job path;
  * async checkpointing — file I/O on a background thread, overlapping
    the next steps;
  * donated buffers — params/opt_state donate their slots, halving the
    peak update memory.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, Iterator, Optional

import jax
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.optim import adamw
from repro.parallel import batch_pspecs, param_pspecs, shardings_for
from repro.runtime import steps as steps_lib


@dataclasses.dataclass
class TrainerConfig:
    total_steps: int = 100
    ckpt_dir: Optional[str] = None
    ckpt_every: int = 50
    ckpt_keep: int = 3
    ckpt_async: bool = True
    log_every: int = 10
    remat: bool = True
    straggler_factor: float = 3.0
    ewma_alpha: float = 0.2
    seed: int = 0


class Trainer:
    def __init__(self, model, opt_cfg: adamw.AdamWConfig,
                 cfg: TrainerConfig, mesh=None,
                 on_straggler: Optional[Callable[[int, float], None]] = None,
                 on_log: Optional[Callable[[int, Dict], None]] = None):
        self.model = model
        self.opt_cfg = opt_cfg
        self.cfg = cfg
        self.mesh = mesh
        self.on_straggler = on_straggler
        self.on_log = on_log
        self.ckpt = (CheckpointManager(cfg.ckpt_dir, keep=cfg.ckpt_keep)
                     if cfg.ckpt_dir else None)
        self.step = 0
        self.params = None
        self.opt_state = None
        self._ewma = None
        self.straggler_events = []
        self._build()

    # ------------------------------------------------------------- plumbing
    def _build(self):
        fn = steps_lib.make_train_step(self.model, self.opt_cfg,
                                       remat=self.cfg.remat)
        if self.mesh is not None:
            from jax.sharding import NamedSharding, PartitionSpec as P
            params_shape = jax.eval_shape(
                lambda: self.model.init(jax.random.key(self.cfg.seed)))
            pspec = param_pspecs(params_shape, self.mesh)
            self._param_sh = shardings_for(pspec, self.mesh)
            # optimizer moments follow the parameter shardings; step scalar
            # is replicated
            self._opt_sh = adamw.AdamWState(
                step=NamedSharding(self.mesh, P()),
                mu=self._param_sh, nu=jax.tree.map(lambda s: s,
                                                   self._param_sh))
        else:
            self._param_sh = self._opt_sh = None
        self._step_fn = jax.jit(fn, donate_argnums=(0, 1))

    def init_state(self):
        self.params = self.model.init(jax.random.key(self.cfg.seed))
        self.opt_state = adamw.init(self.params)
        if self._param_sh is not None:
            self.params = jax.device_put(self.params, self._param_sh)
            self.opt_state = jax.device_put(self.opt_state, self._opt_sh)
        self.step = 0

    def maybe_restore(self) -> bool:
        """True if a checkpoint was restored."""
        if self.ckpt is None or self.ckpt.latest_step() is None:
            return False
        template = {
            "params": jax.eval_shape(
                lambda: self.model.init(jax.random.key(self.cfg.seed))),
            "opt": jax.eval_shape(
                lambda: adamw.init(self.model.init(
                    jax.random.key(self.cfg.seed)))),
        }
        shards = None
        if self._param_sh is not None:
            shards = {"params": self._param_sh, "opt": self._opt_sh}
        state, manifest = self.ckpt.restore(template, shardings=shards)
        self.params, self.opt_state = state["params"], state["opt"]
        self.step = manifest["step"]
        return True

    def save(self, blocking: Optional[bool] = None):
        if self.ckpt is None:
            return
        self.ckpt.save({"params": self.params, "opt": self.opt_state},
                       self.step,
                       blocking=(not self.cfg.ckpt_async
                                 if blocking is None else blocking))

    # ------------------------------------------------------------- elastic
    def remesh(self, new_mesh):
        """Elastic scaling: rebuild shardings + executable for a new mesh
        and migrate live state onto it."""
        self.mesh = new_mesh
        params, opt_state = self.params, self.opt_state
        self._build()
        if params is not None:
            host_p = jax.tree.map(np.asarray, params)
            host_o = jax.tree.map(np.asarray, opt_state)
            self.params = jax.device_put(host_p, self._param_sh)
            self.opt_state = jax.device_put(host_o, self._opt_sh)

    # ----------------------------------------------------------------- run
    def run(self, batches: Iterator[Dict], *,
            steps: Optional[int] = None) -> Dict[str, Any]:
        """Train until ``total_steps`` (or ``steps`` more), checkpointing and
        watching for stragglers. Returns summary metrics."""
        if self.params is None and not self.maybe_restore():
            self.init_state()
        target = (self.cfg.total_steps if steps is None
                  else self.step + steps)
        history = []
        try:
            while self.step < target:
                t0 = time.perf_counter()   # includes data fetch: input
                batch = next(batches)      # stalls are stragglers too
                self.params, self.opt_state, metrics = self._step_fn(
                    self.params, self.opt_state, batch)
                jax.block_until_ready(metrics["loss"])
                dt = time.perf_counter() - t0
                self.step += 1
                self._watch_straggler(dt)
                if self.step % self.cfg.log_every == 0 or self.step == target:
                    m = {k: float(v) for k, v in metrics.items()}
                    history.append({"step": self.step, "time_s": dt, **m})
                    if self.on_log:
                        self.on_log(self.step, m)
                if (self.ckpt is not None
                        and self.step % self.cfg.ckpt_every == 0):
                    self.save()
        except BaseException:
            if self.ckpt is not None and self.params is not None:
                try:
                    self.save(blocking=True)   # emergency checkpoint
                except Exception:
                    pass
            raise
        if self.ckpt is not None:
            self.save(blocking=True)
        return {"history": history, "final_step": self.step,
                "straggler_events": list(self.straggler_events)}

    def _watch_straggler(self, dt: float):
        if self._ewma is None:
            # first step is compile-dominated — sentinel, seed on the next
            self._ewma = -1.0
            return
        if self._ewma < 0:
            self._ewma = dt
            return
        if dt > self.cfg.straggler_factor * self._ewma:
            self.straggler_events.append((self.step, dt, self._ewma))
            if self.on_straggler:
                self.on_straggler(self.step, dt)
        a = self.cfg.ewma_alpha
        self._ewma = (1 - a) * self._ewma + a * dt
