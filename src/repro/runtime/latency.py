"""Pure latency-percentile helpers for serving measurement.

The async engine reports per-request TTFT (time to first token) and
inter-token latency as p50/p90/p99 summaries (DESIGN.md §12
"Measurement"); this module is the arithmetic behind them, kept free of
engine/JAX imports so the benchmark schema and the property tests
(``tests/test_latency.py``, hypothesis) can pin it in isolation.

Percentiles use the classic sorted-sample linear interpolation (numpy's
default "linear" method) and are total functions: an empty stream yields
zeros with ``count == 0`` rather than NaNs, so report plumbing never has
to special-case runs where nothing was measured (e.g. every request
rejected).
"""
from __future__ import annotations

import math
from typing import Dict, Iterable, Sequence, Tuple

__all__ = ["percentile", "summarize"]


def percentile(xs: Sequence[float], q: float) -> float:
    """q-th percentile (``0 <= q <= 100``) of ``xs`` by linear
    interpolation between order statistics. Empty input yields 0.0."""
    if not 0.0 <= q <= 100.0:
        raise ValueError(f"percentile q must be in [0, 100], got {q!r}")
    data = sorted(float(x) for x in xs)
    n = len(data)
    if n == 0:
        return 0.0
    if n == 1:
        return data[0]
    rank = (q / 100.0) * (n - 1)
    lo = math.floor(rank)
    hi = min(lo + 1, n - 1)
    frac = rank - lo
    return data[lo] + (data[hi] - data[lo]) * frac


def summarize(xs: Iterable[float],
              qs: Tuple[float, ...] = (50.0, 90.0, 99.0)) -> Dict[str, float]:
    """{"p50", "p90", "p99", ..., "mean", "count"} summary of a latency
    stream. Percentile keys follow ``qs`` (integral q renders as ``pN``).
    Empty streams summarize to all-zeros with ``count == 0``."""
    data = [float(x) for x in xs]
    out: Dict[str, float] = {}
    for q in qs:
        key = f"p{int(q)}" if float(q).is_integer() else f"p{q}"
        out[key] = percentile(data, q)
    out["mean"] = sum(data) / len(data) if data else 0.0
    out["count"] = float(len(data))
    return out
