"""RAP serving runtime — paper Algorithm 3 embedded in a batched server.

Per request the flow is the paper's online loop:
  ① observe (batch, seq_len, available-memory budget)
  ② RAPController.decide() → block keep-mask (masked-argmax over Q until
     the analytical peak fits)
  ③ execute pruned inference
  ④ report memory / quality stats

XLA adaptation of "execute pruned" (see DESIGN.md §2) — two modes:
  * ``masked``     — the mask becomes runtime 0/1 gate inputs to one shared
    executable: zero recompiles, instant policy switches, but no real
    memory savings (GSI scoring and latency-critical paths use this);
  * ``structural`` — parameter stacks are gathered along the layer axis
    into a genuinely smaller pytree + smaller KV cache, and the
    (prefill, decode) executables are cached per *bucket* (the retained
    layout signature). Uniform architectures collapse many masks into one
    bucket, so compiles amortize exactly like vLLM's shape buckets.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import masks as masks_lib
from repro.core.controller import RAPController
from repro.models import decoder


@dataclasses.dataclass
class ServeResult:
    tokens: np.ndarray           # [B, generated]
    mask: np.ndarray
    peak_bytes: float
    budget_bytes: float
    fits: bool
    decide_s: float
    infer_s: float
    bucket: Tuple
    compiled_new: bool


class RAPServer:
    def __init__(self, model, params, controller: RAPController, *,
                 mode: str = "structural", max_new_tokens: int = 16,
                 kv_dtype=None):
        assert mode in ("structural", "masked")
        self.model = model
        self.cfg = model.cfg
        self.params = params
        self.controller = controller
        self.mode = mode
        self.max_new = max_new_tokens
        self.kv_dtype = kv_dtype
        self._bucket_cache: Dict[Tuple, Dict[str, Any]] = {}
        self._masked_exec: Dict[Tuple[int, int], Dict[str, Any]] = {}

    # ------------------------------------------------------------ executors
    def _structural_entry(self, mask: np.ndarray, prompt_shape):
        key = (masks_lib.bucket_key(self.cfg, mask), prompt_shape)
        new = key not in self._bucket_cache
        if new:
            small, layout = masks_lib.compact_params(self.params, self.cfg,
                                                     mask)
            max_len = prompt_shape[1] + self.max_new
            cfg = self.cfg

            @jax.jit
            def prefill(p, tokens):
                return decoder.prefill(p, cfg, tokens, max_len,
                                       layout=layout, kv_dtype=self.kv_dtype)

            @jax.jit
            def decode(p, cache, tok):
                return decoder.decode_step(p, cfg, cache, tok, layout=layout)

            self._bucket_cache[key] = {
                "params": small, "prefill": prefill, "decode": decode,
            }
        return key, self._bucket_cache[key], new

    def _masked_entry(self, prompt_shape):
        key = prompt_shape
        new = key not in self._masked_exec
        if new:
            cfg = self.cfg
            max_len = prompt_shape[1] + self.max_new

            @jax.jit
            def prefill(p, tokens, gates):
                return decoder.prefill(p, cfg, tokens, max_len, gates=gates,
                                       kv_dtype=self.kv_dtype)

            @jax.jit
            def decode(p, cache, tok, gates):
                return decoder.decode_step(p, cfg, cache, tok, gates=gates)

            self._masked_exec[key] = {"prefill": prefill, "decode": decode}
        return key, self._masked_exec[key], new

    # --------------------------------------------------------------- serve
    def serve(self, prompt_tokens: np.ndarray, budget_bytes: float,
              *, greedy: bool = True) -> ServeResult:
        B, S = prompt_tokens.shape
        total_len = S + self.max_new
        d = self.controller.decide(B, total_len, budget_bytes)
        tokens = jnp.asarray(prompt_tokens, jnp.int32)

        t0 = time.perf_counter()
        if self.mode == "structural":
            key, entry, new = self._structural_entry(d.mask, (B, S))
            params = entry["params"]
            logits, cache = entry["prefill"](params, tokens)
            step_args = ()
        else:
            key, entry, new = self._masked_entry((B, S))
            params = self.params
            gates = masks_lib.mask_to_gates(d.mask)
            logits, cache = entry["prefill"](params, tokens, gates)
            step_args = (gates,)

        out = []
        tok = jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
        out.append(tok)
        for _ in range(self.max_new - 1):
            lg, cache = entry["decode"](params, cache, tok, *step_args)
            tok = jnp.argmax(lg[:, -1], axis=-1)[:, None].astype(jnp.int32)
            out.append(tok)
        gen = np.concatenate([np.asarray(t) for t in out], axis=1)
        infer_s = time.perf_counter() - t0

        return ServeResult(
            tokens=gen, mask=d.mask, peak_bytes=d.peak_bytes,
            budget_bytes=budget_bytes, fits=d.fits, decide_s=d.latency_s,
            infer_s=infer_s, bucket=key if self.mode == "structural" else (),
            compiled_new=new)

    def stats(self) -> Dict[str, int]:
        return {"structural_buckets": len(self._bucket_cache),
                "masked_executables": len(self._masked_exec)}
