"""RAP one-shot serving — compatibility wrapper over the batching engine.

Per request the flow is the paper's online loop:
  ① observe (batch, seq_len, available-memory budget)
  ② PruningPolicy.observe() → block keep-mask (the RL controller's
     masked-argmax over Q until the analytical peak fits, or any
     registered baseline policy)
  ③ execute pruned inference
  ④ report memory / quality stats

XLA adaptation of "execute pruned" (see DESIGN.md §8) — two modes:
  * ``masked``     — the mask becomes runtime 0/1 gate inputs to one shared
    executable: zero recompiles, instant policy switches, but no real
    memory savings (GSI scoring and latency-critical paths use this);
  * ``structural`` — parameter stacks are gathered along the layer axis
    into a genuinely smaller pytree + smaller KV cache, and the
    (prefill, decode) executables are cached per *bucket* (the retained
    layout signature). Uniform architectures collapse many masks into one
    bucket, so compiles amortize exactly like vLLM's shape buckets.

Since the continuous-batching refactor (DESIGN.md §10) this class is a thin
shim: each ``serve()`` call runs a single-request trace through
:class:`repro.runtime.engine.RAPEngine` in ``force``-admission mode, which
reproduces the historical contract exactly — one decision per request
against a private instantaneous budget, executed regardless of fit (the
engine records the overcommit instead of queueing). New code should talk to
the engine directly and share one pool across requests.

The historical shim tradeoff — one monotonically growing ``max_len`` whose
growth dropped every compiled group, leaving short serves paying an
arbitrary long cache length — is gone: the engine mints slot caches per
power-of-two length bucket, so a long prompt compiles its own long-cache
group and short serves keep their short ones.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Tuple

import numpy as np

from repro.core.controller import RAPController
from repro.core.policy import PruningPolicy
from repro.runtime.engine import EngineConfig, EngineRequest, RAPEngine

_MIGRATION_HINT = (
    "RAPServer's constructor changed with the serving-API split: it now "
    "takes a PruningPolicy instead of a RAPController. Wrap your "
    "controller — RAPServer(model, params, "
    "repro.core.policy.RLPolicy(controller), ...) — or build any "
    "registered policy with repro.core.policy.make_policy()."
)


@dataclasses.dataclass
class ServeResult:
    tokens: np.ndarray           # [B, generated]
    mask: np.ndarray
    peak_bytes: float
    budget_bytes: float
    fits: bool
    decide_s: float
    infer_s: float
    bucket: Tuple
    compiled_new: bool


class RAPServer:
    def __init__(self, model, params, policy: PruningPolicy = None, *,
                 mode: str = "structural", max_new_tokens: int = 16,
                 kv_dtype=None, **legacy):
        if legacy:
            raise TypeError(
                f"RAPServer got unexpected kwargs {sorted(legacy)}. "
                + _MIGRATION_HINT)
        if isinstance(policy, RAPController):
            raise TypeError(
                "RAPServer received a RAPController where a PruningPolicy "
                "is expected. " + _MIGRATION_HINT)
        if policy is None or not isinstance(policy, PruningPolicy):
            raise TypeError(
                f"RAPServer requires a PruningPolicy, got "
                f"{type(policy).__name__}. " + _MIGRATION_HINT)
        assert mode in ("structural", "masked")
        self.model = model
        self.cfg = model.cfg
        self.params = params
        self.policy = policy
        self.mode = mode
        self.max_new = max_new_tokens
        self.kv_dtype = kv_dtype
        self._engine = RAPEngine(model, params, policy, EngineConfig(
            mode=mode, max_new_tokens=max_new_tokens, max_active=1,
            max_len=max_new_tokens + 1, kv_dtype=kv_dtype,
            admission="force", len_buckets="pow2"))
        self._serial = 0

    # --------------------------------------------------------------- serve
    def serve(self, prompt_tokens: np.ndarray, budget_bytes: float,
              *, greedy: bool = True) -> ServeResult:
        B, S = prompt_tokens.shape
        self._engine.ensure_capacity(B, S + self.max_new)
        self._serial += 1
        req = EngineRequest(rid=f"serve-{self._serial}",
                            prompt=np.asarray(prompt_tokens, np.int32))
        report = self._engine.run([req], budget_bytes=budget_bytes)
        r = report.result(req.rid)
        return ServeResult(
            tokens=r.tokens, mask=r.mask, peak_bytes=r.peak_bytes,
            budget_bytes=budget_bytes, fits=r.fits, decide_s=r.decide_s,
            infer_s=max(report.wall_s - r.decide_s, 0.0),
            bucket=r.bucket, compiled_new=report.compile_events > 0)

    def stats(self) -> Dict[str, int]:
        st = self._engine.stats()
        return {"structural_buckets": st["structural_buckets"],
                "masked_executables": st["masked_prefill_executables"]}
