"""Pooled KV-cache allocator — the shared-budget half of continuous batching.

One-shot serving (``RAPServer``) charges each request against its *own*
instantaneous budget, so "runtime memory variation" is simulated. The engine
instead draws every request's dynamic state (KV cache / recurrent state /
conv buffers — the Eq. (3)–(4) ``state_bytes`` term) from ONE device pool:

  * the pool owns ``capacity_bytes`` split into fixed-size pages
    (vLLM-block style); an allocation takes ``ceil(bytes / page)`` pages
    from the free list and returns them on completion;
  * admission control asks ``can_alloc`` BEFORE the controller's keep-mask
    is executed, so requests queue instead of OOM-ing when the pool is hot;
  * a :class:`repro.core.memory.PoolAccounting` ledger tracks reserved
    (page-rounded) vs in-use (exact analytical) bytes, giving the
    fragmentation/occupancy stats the scheduler and benchmarks report.

The pool is an *accounting* allocator: JAX owns the physical buffers (the
engine's slot-batched caches), the pool decides who may occupy them. That
split keeps the allocator backend-agnostic — the same admission logic will
gate real paged attention once per-page gather lands (ROADMAP).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

from repro.core.memory import MemoryModel, PoolAccounting, PoolExhausted

__all__ = ["KVPool", "PageAllocation", "PoolExhausted", "default_page_bytes"]


def default_page_bytes(mm: MemoryModel, tokens_per_page: int = 16,
                       batch: int = 1) -> int:
    """Page size holding ``tokens_per_page`` tokens of dense per-token state
    (all layers kept). Models with only fixed-size state (pure SSM/RNN) have
    no per-token term; fall back to the fixed footprint so one page holds one
    request's recurrent state."""
    full = [True] * (2 * mm.n_layers)
    per_tok = mm.state_bytes(full, batch, 1) - mm.state_bytes(full, batch, 0)
    if per_tok <= 0:
        per_tok = max(mm.state_bytes(full, batch, 0), 1.0)
        return int(per_tok)
    return max(int(per_tok * tokens_per_page), 1)


@dataclasses.dataclass(frozen=True)
class PageAllocation:
    rid: str
    pages: tuple            # page ids granted (stable until freed)
    requested_bytes: float  # exact analytical state bytes
    page_bytes: int

    @property
    def reserved_bytes(self) -> float:
        return float(len(self.pages) * self.page_bytes)


class KVPool:
    """Slot/page-based KV-cache pool over a global byte budget."""

    def __init__(self, capacity_bytes: float, *, page_bytes: int,
                 mm: Optional[MemoryModel] = None):
        if page_bytes <= 0:
            raise ValueError("page_bytes must be positive")
        self.page_bytes = int(page_bytes)
        self.n_pages = max(int(capacity_bytes // self.page_bytes), 0)
        self.mm = mm
        # capacity is page-quantized: a partial tail page is unusable
        self.acct = PoolAccounting(
            capacity_bytes=float(self.n_pages * self.page_bytes))
        self._free: List[int] = list(range(self.n_pages))
        self._live: Dict[str, PageAllocation] = {}
        self._next_overflow_page = self.n_pages  # ids for overcommitted pages

    # ------------------------------------------------------------- queries
    def pages_needed(self, nbytes: float) -> int:
        nbytes = max(float(nbytes), 0.0)
        return max(int(-(-nbytes // self.page_bytes)), 1)  # ceil, min 1 page

    def can_alloc(self, nbytes: float) -> bool:
        return self.pages_needed(nbytes) <= len(self._free)

    def fits_capacity(self, nbytes: float) -> bool:
        """Could this request EVER fit (empty pool)?"""
        return self.pages_needed(nbytes) <= self.n_pages

    @property
    def free_pages(self) -> int:
        return len(self._free)

    @property
    def bytes_in_use(self) -> float:
        return self.acct.in_use_bytes

    @property
    def bytes_reserved(self) -> float:
        return self.acct.reserved_bytes

    @property
    def available_bytes(self) -> float:
        return float(len(self._free) * self.page_bytes)

    # ----------------------------------------------------------- lifecycle
    def alloc(self, rid: str, nbytes: float, *,
              allow_overcommit: bool = False) -> PageAllocation:
        if rid in self._live:
            raise ValueError(f"request {rid!r} already holds an allocation")
        need = self.pages_needed(nbytes)
        if need > len(self._free) and not allow_overcommit:
            raise PoolExhausted(
                f"request {rid!r} needs {need} pages "
                f"({nbytes:.0f}B), {len(self._free)} free "
                f"of {self.n_pages} total")
        pages = [self._free.pop() for _ in range(min(need, len(self._free)))]
        while len(pages) < need:  # overcommit: synthesize pages past capacity
            pages.append(self._next_overflow_page)
            self._next_overflow_page += 1
        alloc = PageAllocation(rid=rid, pages=tuple(pages),
                               requested_bytes=float(max(nbytes, 0.0)),
                               page_bytes=self.page_bytes)
        self.acct.reserve(alloc.reserved_bytes, alloc.requested_bytes,
                          allow_overcommit=allow_overcommit)
        self._live[rid] = alloc
        return alloc

    def free(self, rid: str) -> float:
        """Release a request's pages; returns the reserved bytes returned."""
        alloc = self._live.pop(rid)
        for p in alloc.pages:
            if p < self.n_pages:         # overflow pages evaporate
                self._free.append(p)
        self.acct.release(alloc.reserved_bytes, alloc.requested_bytes)
        return alloc.reserved_bytes

    def live_requests(self) -> List[str]:
        return list(self._live)

    # ---------------------------------------------------------------- stats
    def stats(self) -> Dict[str, float]:
        return {
            "capacity_bytes": self.acct.capacity_bytes,
            "page_bytes": float(self.page_bytes),
            "n_pages": float(self.n_pages),
            "free_pages": float(len(self._free)),
            "live_requests": float(len(self._live)),
            "reserved_bytes": self.acct.reserved_bytes,
            "in_use_bytes": self.acct.in_use_bytes,
            "peak_reserved_bytes": self.acct.peak_reserved_bytes,
            "peak_in_use_bytes": self.acct.peak_in_use_bytes,
            "occupancy": self.acct.occupancy(),
            "fragmentation": self.acct.fragmentation(),
            "overcommit_events": float(self.acct.overcommit_events),
        }
