"""Pooled KV-cache allocator — the shared-budget half of continuous batching.

One-shot serving (``RAPServer``) charges each request against its *own*
instantaneous budget, so "runtime memory variation" is simulated. The engine
instead draws every request's dynamic state (KV cache / recurrent state /
conv buffers — the Eq. (3)–(4) ``state_bytes`` term) from ONE device pool:

  * the pool owns ``capacity_bytes`` split into fixed-size pages
    (vLLM-block style); an allocation takes ``ceil(bytes / page)`` pages
    from the free list and returns them on completion;
  * admission control asks ``can_alloc`` BEFORE the controller's keep-mask
    is executed, so requests queue instead of OOM-ing when the pool is hot;
  * a :class:`repro.core.memory.PoolAccounting` ledger tracks reserved
    (page-rounded) vs in-use (exact analytical) bytes, giving the
    fragmentation/occupancy stats the scheduler and benchmarks report.

Two allocation styles share the one free list:

  * **byte allocations** (:meth:`alloc`) — the accounting-only contract the
    slot-batched ``LocalExecutor`` path uses: JAX owns the physical slot
    caches, the pool decides who may occupy them;
  * **token allocations** (:meth:`alloc_tokens` / :meth:`extend`) — the
    physically paged contract behind ``PagedExecutor``: the pool owns the
    page arrays themselves (:meth:`allocate_physical`; one K and one V pool
    per attention layer, allocated once at capacity), grants page ids whose
    contents the executor fills, and appends pages per decoded token.
    Admission reserves a **commitment** (the request's worst-case page
    count) up front, so a mid-decode :meth:`extend` can never fail in
    strict mode: ``free pages − outstanding commitments`` is what
    :meth:`can_alloc_tokens` admits against.

Do not mix the two styles on one pool instance: byte allocations check the
raw free list and can eat into pages the token path has committed.

Both styles can be **spilled** (:meth:`spill` / :meth:`restore`): a
preempted request's physical page contents (and, for quantized pools, its
per-page scale rows) are copied to a host-side store, its device pages
return to the free list, and its commitment + ledger charge are released —
so a shrinking budget can reclaim device memory without discarding work.
``restore`` re-grants pages with the identical per-row layout and writes
the host copies back bitwise, so a resumed request's decode stream matches
an unpreempted run exactly (greedy decode is deterministic). Byte-style
spills carry accounting only — the slot executor owns the cache contents
and spills them itself (``ModelExecutor.spill_state``).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

from repro.core.memory import MemoryModel, PoolAccounting, PoolExhausted

__all__ = ["KVPool", "PageAllocation", "TokenAllocation",
           "SpilledAllocation", "PoolExhausted", "default_page_bytes",
           "resolve_kv_dtype", "KV_DTYPE_NAMES"]

# user-facing kv-dtype names accepted by --kv-dtype and Decision.kv_dtype
KV_DTYPE_NAMES = ("fp32", "bf16", "int8", "fp8")


def resolve_kv_dtype(kv_dtype):
    """Normalize a user-facing KV dtype spec.

    Returns ``(name, storage_dtype, quantized, qmax)`` where ``name`` is the
    canonical string (or ``None`` for "use the model dtype"), ``storage_dtype``
    the jnp dtype pages are stored in (``None`` when deferring to the model
    dtype), ``quantized`` whether per-page scales are required, and ``qmax``
    the symmetric quantization ceiling (127 for int8, 448 for fp8-e4m3).
    fp8 is platform-gated: requested on a jax build without
    ``float8_e4m3fn`` it raises rather than silently mis-storing pages."""
    import jax.numpy as jnp
    if kv_dtype is None:
        return None, None, False, None
    if isinstance(kv_dtype, str):
        name = kv_dtype.lower()
    else:
        name = jnp.dtype(kv_dtype).name      # jnp/np dtype objects
    aliases = {"float32": "fp32", "bfloat16": "bf16",
               "float8_e4m3fn": "fp8", "auto": None}
    name = aliases.get(name, name)
    if name is None:
        return None, None, False, None
    if name == "fp32":
        return "fp32", jnp.float32, False, None
    if name == "bf16":
        return "bf16", jnp.bfloat16, False, None
    if name == "int8":
        return "int8", jnp.int8, True, 127.0
    if name == "fp8":
        fp8 = getattr(jnp, "float8_e4m3fn", None)
        if fp8 is None:
            raise ValueError(
                "kv_dtype 'fp8' requires jax.numpy.float8_e4m3fn, which "
                "this platform's jax build does not provide; use 'int8'")
        return "fp8", fp8, True, 448.0
    if not isinstance(kv_dtype, str):
        # any other explicit dtype object passes through unquantized
        # (fp16 etc.) — only the canonical names get scale pools
        return name, jnp.dtype(kv_dtype), False, None
    raise ValueError(
        f"unknown kv_dtype {kv_dtype!r}; expected one of {KV_DTYPE_NAMES}")


def default_page_bytes(mm: MemoryModel, tokens_per_page: int = 16,
                       batch: int = 1) -> int:
    """Page size holding ``tokens_per_page`` tokens of dense per-token state
    (all layers kept). Models with only fixed-size state (pure SSM/RNN) have
    no per-token term; fall back to the fixed footprint so one page holds one
    request's recurrent state."""
    full = [True] * (2 * mm.n_layers)
    per_tok = mm.state_bytes(full, batch, 1) - mm.state_bytes(full, batch, 0)
    if per_tok <= 0:
        per_tok = max(mm.state_bytes(full, batch, 0), 1.0)
        return int(per_tok)
    return max(int(per_tok * tokens_per_page), 1)


@dataclasses.dataclass(frozen=True)
class PageAllocation:
    rid: str
    pages: tuple            # page ids granted (stable until freed)
    requested_bytes: float  # exact analytical state bytes
    page_bytes: int

    @property
    def reserved_bytes(self) -> float:
        return float(len(self.pages) * self.page_bytes)


@dataclasses.dataclass
class TokenAllocation:
    """A physically paged allocation: per-row page id lists that grow one
    page at a time as decode appends tokens, bounded by an admission-time
    commitment (``max_tokens``)."""
    rid: str
    batch: int
    seq_tokens: int          # tokens with granted page backing, per row
    max_tokens: int          # admission commitment, per row
    rows: List[List[int]]    # [batch][n_row_pages] physical page ids
    page_bytes: int
    tokens_per_page: int
    in_use_bytes: float      # analytical bytes charged so far
    in_use_per_token: float  # analytical bytes per appended token (all rows)

    @property
    def held_pages(self) -> int:
        return sum(len(r) for r in self.rows)

    @property
    def committed_pages(self) -> int:
        per_row = -(-max(self.max_tokens, 1) // self.tokens_per_page)
        return self.batch * per_row

    @property
    def reserved_bytes(self) -> float:
        return float(self.held_pages * self.page_bytes)


@dataclasses.dataclass
class SpilledAllocation:
    """A preempted request's host-side allocation record.

    Token-style spills of a physical pool carry the page contents (and,
    for quantized pools, the scale rows) as host arrays; byte-style spills
    carry accounting only — the slot executor owns (and spills) the actual
    cache contents. ``restore`` rebuilds the allocation with the identical
    per-row page count and writes the host copies back bitwise."""
    rid: str
    kind: str                 # "tokens" | "bytes"
    batch: int
    seq_tokens: int
    max_tokens: int
    pages_per_row: int        # granted pages per row at spill time
    requested_bytes: float    # byte-kind ledger charge
    in_use_bytes: float
    in_use_per_token: float
    k_host: object = None     # [L, held_pages, pt, K, D] page contents
    v_host: object = None
    k_scales_host: object = None   # [L, held_pages, K] f32 (quantized only)
    v_scales_host: object = None


class KVPool:
    """Slot/page-based KV-cache pool over a global byte budget."""

    def __init__(self, capacity_bytes: float, *, page_bytes: int,
                 mm: Optional[MemoryModel] = None,
                 tokens_per_page: Optional[int] = None):
        if page_bytes <= 0:
            raise ValueError("page_bytes must be positive")
        if tokens_per_page is not None and tokens_per_page < 1:
            raise ValueError("tokens_per_page must be >= 1")
        self.page_bytes = int(page_bytes)
        self.n_pages = max(int(capacity_bytes // self.page_bytes), 0)
        self.mm = mm
        self.tokens_per_page = tokens_per_page
        # capacity is page-quantized: a partial tail page is unusable
        self.acct = PoolAccounting(
            capacity_bytes=float(self.n_pages * self.page_bytes))
        self._free: List[int] = list(range(self.n_pages))
        self._live: Dict[str, PageAllocation] = {}
        self._tok: Dict[str, TokenAllocation] = {}
        self._spilled: Dict[str, SpilledAllocation] = {}
        self.spilled_bytes_total = 0.0   # cumulative device bytes spilled
        self._next_overflow_page = self.n_pages  # ids for overcommitted pages
        self._committed_extra = 0   # Σ token allocs (committed − held) pages
        # physical page arrays (allocate_physical): [L, n_pages+1, pt, K, D]
        self.k_pages = None
        self.v_pages = None
        # quantized pools: canonical dtype name + per-page scales
        # ([L, n_pages+1, K] f32; row n_pages scales the scratch page)
        self.kv_dtype: Optional[str] = None
        self.k_scales = None
        self.v_scales = None

    # ---------------------------------------------------------- physical
    @property
    def scratch_page(self) -> int:
        """Extra physical page at index ``n_pages``: a write sink for padded
        decode-batch rows (never granted, never read under a valid mask)."""
        return self.n_pages

    def allocate_physical(self, *, n_layers: int, n_kv_heads: int,
                          head_dim: int, dtype, kv_dtype=None) -> None:
        """Materialize the page pools: one K and one V array per attention
        layer (stacked on a leading layer axis), sized once at capacity plus
        one scratch page. Requires ``tokens_per_page``.

        ``kv_dtype`` selects the storage precision: ``None`` keeps ``dtype``
        as-is; ``"fp32"``/``"bf16"`` override the width; ``"int8"``/``"fp8"``
        store quantized pages plus per-(page, kv-head) fp32 scale arrays
        ``[n_layers, n_pages+1, K]`` (one scale row per layer covers the
        scratch page too — padded decode rows requantize it harmlessly).
        The accounting ledger's ``in_use_scale`` is set so analytical
        (model-width) in-use charges land in *physical* bytes — mixed
        precision pools report true MB, not model-width fiction."""
        if self.tokens_per_page is None:
            raise ValueError("allocate_physical requires tokens_per_page")
        import jax.numpy as jnp
        name, store_dtype, quantized, _ = resolve_kv_dtype(kv_dtype)
        self.kv_dtype = name
        phys = store_dtype if store_dtype is not None else dtype
        shape = (n_layers, self.n_pages + 1, self.tokens_per_page,
                 n_kv_heads, head_dim)
        self.k_pages = jnp.zeros(shape, phys)
        self.v_pages = jnp.zeros(shape, phys)
        if quantized:
            sshape = (n_layers, self.n_pages + 1, n_kv_heads)
            self.k_scales = jnp.zeros(sshape, jnp.float32)
            self.v_scales = jnp.zeros(sshape, jnp.float32)
        else:
            self.k_scales = None
            self.v_scales = None
        # per-pool byte width (satellite of the quantized-pages change):
        # analytical ledger charges arrive in model-dtype bytes; physical
        # truth per token is page_bytes / tokens_per_page (scales included)
        model_tok = (2 * n_kv_heads * head_dim
                     * jnp.dtype(dtype).itemsize * n_layers)
        if model_tok > 0:
            self.acct.in_use_scale = (
                self.page_bytes / self.tokens_per_page) / model_tok

    # ------------------------------------------------------------- queries
    def pages_needed(self, nbytes: float) -> int:
        nbytes = max(float(nbytes), 0.0)
        return max(int(-(-nbytes // self.page_bytes)), 1)  # ceil, min 1 page

    def can_alloc(self, nbytes: float) -> bool:
        return self.pages_needed(nbytes) <= len(self._free)

    def fits_capacity(self, nbytes: float) -> bool:
        """Could this request EVER fit (empty pool)?"""
        return self.pages_needed(nbytes) <= self.n_pages

    def pages_per_row(self, n_tokens: int) -> int:
        if self.tokens_per_page is None:
            raise ValueError("token-granular API requires tokens_per_page")
        return -(-max(int(n_tokens), 1) // self.tokens_per_page)

    def pages_for_tokens(self, batch: int, n_tokens: int) -> int:
        return max(int(batch), 1) * self.pages_per_row(n_tokens)

    def can_alloc_tokens(self, batch: int, max_tokens: int) -> bool:
        """Admission check for the paged path: the request's *worst-case*
        page count must fit what is neither free-and-committed nor held."""
        need = self.pages_for_tokens(batch, max_tokens)
        return need <= len(self._free) - self._committed_extra

    def fits_capacity_tokens(self, batch: int, max_tokens: int) -> bool:
        return self.pages_for_tokens(batch, max_tokens) <= self.n_pages

    @property
    def free_pages(self) -> int:
        return len(self._free)

    @property
    def committed_pages(self) -> int:
        """Pages promised to live token allocations but not yet granted."""
        return self._committed_extra

    @property
    def bytes_in_use(self) -> float:
        return self.acct.in_use_bytes

    @property
    def bytes_reserved(self) -> float:
        return self.acct.reserved_bytes

    @property
    def available_bytes(self) -> float:
        return float(len(self._free) * self.page_bytes)

    # ----------------------------------------------------------- lifecycle
    def alloc(self, rid: str, nbytes: float, *,
              allow_overcommit: bool = False) -> PageAllocation:
        """Byte-granular (accounting-only) allocation.

        Under ``allow_overcommit`` the pool pops whatever real pages remain
        and *synthesizes* ids past capacity for the rest. Overflow ids are
        bookkeeping fictions: they have no physical backing, and when freed
        they evaporate rather than entering the free list — so a later
        ``free()`` of a different request can never backfill an allocation
        that overflowed; it stays overcommitted (and over-budget in the
        ledger) until itself freed. Pinned in
        ``tests/test_engine.py::test_pool_overflow_pages_never_backfilled``.
        """
        if rid in self._live or rid in self._tok or rid in self._spilled:
            raise ValueError(f"request {rid!r} already holds an allocation")
        need = self.pages_needed(nbytes)
        if not allow_overcommit:
            if need > len(self._free):
                raise PoolExhausted(
                    f"request {rid!r} needs {need} pages "
                    f"({nbytes:.0f}B), {len(self._free)} free "
                    f"of {self.n_pages} total")
            # ledger check BEFORE popping pages: another request's
            # overcommit can hold the ledger at/over capacity while real
            # pages sit free — raising after the pop would leak them
            if not self.acct.can_reserve(need * self.page_bytes):
                raise PoolExhausted(
                    f"request {rid!r} needs {need * self.page_bytes}B but "
                    f"the ledger has {self.acct.available_bytes:.0f}B "
                    f"headroom (an overcommitted allocation is holding the "
                    f"budget past capacity)")
        pages = [self._free.pop() for _ in range(min(need, len(self._free)))]
        while len(pages) < need:  # overcommit: synthesize pages past capacity
            pages.append(self._next_overflow_page)
            self._next_overflow_page += 1
        alloc = PageAllocation(rid=rid, pages=tuple(pages),
                               requested_bytes=float(max(nbytes, 0.0)),
                               page_bytes=self.page_bytes)
        self.acct.reserve(alloc.reserved_bytes, alloc.requested_bytes,
                          allow_overcommit=allow_overcommit)
        self._live[rid] = alloc
        return alloc

    def effective_kv_dtype(self) -> Optional[str]:
        """Canonical storage dtype name of the physical pools, or ``None``
        when unquantized pages simply mirror the model dtype."""
        if self.kv_dtype is not None:
            return self.kv_dtype
        if self.k_pages is not None:
            raw = str(self.k_pages.dtype)
            try:
                name, _, _, _ = resolve_kv_dtype(raw)
            except ValueError:
                return raw
            return name
        return None

    def check_kv_dtype(self, rid: str, kv_dtype) -> None:
        """Reject a request whose ``Decision.kv_dtype`` disagrees with the
        precision this pool's pages were allocated in. Writing model-width
        values into int8 pages (or vice versa) would silently mis-scale
        every page the request touches — fail loudly at admission instead."""
        if kv_dtype is None:
            return
        name, _, _, _ = resolve_kv_dtype(kv_dtype)
        if name is None:
            return
        pool_name = self.effective_kv_dtype()
        if name != (pool_name if pool_name is not None else name):
            raise ValueError(
                f"request {rid!r} asks for kv_dtype {name!r} but this pool "
                f"was allocated with kv_dtype {pool_name!r}; one pool holds "
                f"one precision — route the request to a matching pool or "
                f"re-allocate the pool")

    def alloc_tokens(self, rid: str, batch: int, n_tokens: int, *,
                     max_tokens: int, in_use_bytes: float = 0.0,
                     in_use_per_token: float = 0.0,
                     kv_dtype=None) -> TokenAllocation:
        """Token-granular physically paged allocation (strict only).

        Grants pages backing ``n_tokens`` per row now and *commits* up to
        ``max_tokens`` per row, so every later :meth:`extend` up to the
        commitment is guaranteed to find a free page. ``in_use_bytes`` is
        the analytical ledger charge for the granted tokens;
        ``in_use_per_token`` the charge per appended token (cross-check
        against the physical reservation). ``kv_dtype`` is the request's
        precision ask (``Decision.kv_dtype``): it must match the precision
        the physical pools were allocated in (:meth:`check_kv_dtype`)."""
        if rid in self._live or rid in self._tok or rid in self._spilled:
            raise ValueError(f"request {rid!r} already holds an allocation")
        self.check_kv_dtype(rid, kv_dtype)
        batch = max(int(batch), 1)
        n_tokens = max(int(n_tokens), 1)
        if max_tokens < n_tokens:
            raise ValueError(f"max_tokens {max_tokens} < n_tokens {n_tokens}")
        committed = self.pages_for_tokens(batch, max_tokens)
        if committed > len(self._free) - self._committed_extra:
            raise PoolExhausted(
                f"request {rid!r} commits {committed} pages "
                f"({batch}×{max_tokens} tokens), "
                f"{len(self._free) - self._committed_extra} admissible "
                f"({len(self._free)} free − {self._committed_extra} "
                f"committed) of {self.n_pages} total")
        per_row = self.pages_per_row(n_tokens)
        rows = [[self._free.pop() for _ in range(per_row)]
                for _ in range(batch)]
        alloc = TokenAllocation(
            rid=rid, batch=batch, seq_tokens=n_tokens, max_tokens=max_tokens,
            rows=rows, page_bytes=self.page_bytes,
            tokens_per_page=self.tokens_per_page,
            in_use_bytes=float(max(in_use_bytes, 0.0)),
            in_use_per_token=float(max(in_use_per_token, 0.0)))
        self._committed_extra += committed - alloc.held_pages
        self.acct.grow(alloc.reserved_bytes, alloc.in_use_bytes)
        self._tok[rid] = alloc
        return alloc

    def seq_tokens(self, rid: str) -> int:
        """Tokens per row with granted page backing for a live token
        allocation (the physical write frontier — positions beyond it have
        no page of their own)."""
        return self._tok_state(rid, "seq_tokens").seq_tokens

    def remaining_commitment(self, rid: str) -> int:
        """Tokens per row still extendable under ``rid``'s admission
        commitment (``max_tokens − seq_tokens``). The horizon decode path
        pre-grants ``min(H, remaining_commitment)`` tokens in ONE
        :meth:`extend` before launching a fused H-step loop — within the
        commitment that bulk extend can never fail in strict mode."""
        st = self._tok_state(rid, "remaining_commitment")
        return st.max_tokens - st.seq_tokens

    def _tok_state(self, rid: str, op: str) -> TokenAllocation:
        st = self._tok.get(rid)
        if st is None:
            raise ValueError(
                f"{op}({rid!r}): unknown request id; live token "
                f"allocations: {sorted(self._tok)}")
        return st

    def extend(self, rid: str, n_tokens: int = 1) -> List[List[int]]:
        """Append ``n_tokens`` decode tokens to ``rid``'s rows; returns the
        newly granted page ids per row (usually empty — a page boundary is
        crossed once every ``tokens_per_page`` tokens; a bulk horizon
        extend may grant several pages per row at once). Cannot exceed the
        admission commitment; within it, strict-mode extends never fail."""
        st = self._tok_state(rid, "extend")
        new_seq = st.seq_tokens + int(n_tokens)
        if new_seq > st.max_tokens:
            raise ValueError(
                f"extend({rid!r}) to {new_seq} tokens exceeds the admission "
                f"commitment of {st.max_tokens}")
        need_per_row = self.pages_per_row(new_seq)
        have_per_row = len(st.rows[0])
        granted: List[List[int]] = [[] for _ in st.rows]
        n_new = (need_per_row - have_per_row) * st.batch
        if n_new > 0:
            if n_new > len(self._free):
                raise PoolExhausted(
                    f"extend({rid!r}) needs {n_new} pages, "
                    f"{len(self._free)} free — commitment accounting was "
                    f"bypassed (byte allocs mixed onto a token pool?)")
            for i, row in enumerate(st.rows):
                for _ in range(need_per_row - have_per_row):
                    p = self._free.pop()
                    row.append(p)
                    granted[i].append(p)
            self._committed_extra -= n_new
        st.seq_tokens = new_seq
        delta_in_use = st.in_use_per_token * int(n_tokens)
        st.in_use_bytes += delta_in_use
        self.acct.grow(float(n_new * self.page_bytes), delta_in_use)
        return granted

    def row_pages(self, rid: str) -> List[List[int]]:
        """Current per-row page ids of a live token allocation."""
        st = self._tok_state(rid, "row_pages")
        return [list(r) for r in st.rows]

    def free(self, rid: str, *, missing_ok: bool = False) -> float:
        """Release a request's pages; returns the reserved bytes returned.

        Unknown ids raise a ``ValueError`` naming the id and the live set
        (a bare ``KeyError`` used to escape here). ``missing_ok=True`` makes
        the call idempotent — the engine's cancel path may race a normal
        completion, and double-freeing must not corrupt the free list."""
        if rid in self._tok:
            st = self._tok.pop(rid)
            for row in st.rows:
                self._free.extend(row)
            self._committed_extra -= st.committed_pages - st.held_pages
            self.acct.release(st.reserved_bytes, st.in_use_bytes)
            return st.reserved_bytes
        alloc = self._live.pop(rid, None)
        if alloc is None:
            if missing_ok:
                return 0.0
            raise ValueError(
                f"free({rid!r}): unknown request id; live allocations: "
                f"{sorted([*self._live, *self._tok])}")
        for p in alloc.pages:
            if p < self.n_pages:         # overflow pages evaporate
                self._free.append(p)
        self.acct.release(alloc.reserved_bytes, alloc.requested_bytes)
        return alloc.reserved_bytes

    def live_requests(self) -> List[str]:
        return [*self._live, *self._tok]

    def request_reserved_bytes(self, rid: str) -> float:
        """Device bytes currently reserved by ``rid`` — the bytes a
        preemption of it would free (0.0 for unknown or spilled ids)."""
        st = self._tok.get(rid)
        if st is not None:
            return st.reserved_bytes
        alloc = self._live.get(rid)
        return alloc.reserved_bytes if alloc is not None else 0.0

    # ------------------------------------------------------- spill / restore
    def _gather_pages(self, ids: List[int]):
        """Host copies of the physical pages (and scale rows) backing
        ``ids``. numpy round-trips of f32/bf16/int8/fp8 device arrays are
        exact, which is what makes spill→restore bitwise."""
        import numpy as np
        import jax.numpy as jnp
        idx = jnp.asarray(np.asarray(ids, np.int32))
        k = np.asarray(self.k_pages[:, idx])
        v = np.asarray(self.v_pages[:, idx])
        ks = vs = None
        if self.k_scales is not None:
            ks = np.asarray(self.k_scales[:, idx])
            vs = np.asarray(self.v_scales[:, idx])
        return k, v, ks, vs

    def spill(self, rid: str) -> float:
        """Preempt ``rid``: copy its physical page contents (plus
        quantization scale rows) to a host-side store, return its device
        pages to the free list, and release its commitment and ledger
        charge. Returns the reserved bytes released. Byte-style (slot
        executor) allocations release accounting only — the executor spills
        the cache contents itself. :meth:`restore` rebuilds the allocation
        bitwise; :meth:`drop_spilled` discards it (cancellation)."""
        st = self._tok.pop(rid, None)
        if st is not None:
            ids = [p for row in st.rows for p in row]
            k = v = ks = vs = None
            if self.k_pages is not None and ids:
                k, v, ks, vs = self._gather_pages(ids)
            self._free.extend(ids)
            self._committed_extra -= st.committed_pages - st.held_pages
            self.acct.release(st.reserved_bytes, st.in_use_bytes)
            self._spilled[rid] = SpilledAllocation(
                rid=rid, kind="tokens", batch=st.batch,
                seq_tokens=st.seq_tokens, max_tokens=st.max_tokens,
                pages_per_row=len(st.rows[0]), requested_bytes=0.0,
                in_use_bytes=st.in_use_bytes,
                in_use_per_token=st.in_use_per_token,
                k_host=k, v_host=v, k_scales_host=ks, v_scales_host=vs)
            self.spilled_bytes_total += st.reserved_bytes
            return st.reserved_bytes
        alloc = self._live.pop(rid, None)
        if alloc is None:
            raise ValueError(
                f"spill({rid!r}): unknown request id; live allocations: "
                f"{sorted([*self._live, *self._tok])}")
        for p in alloc.pages:
            if p < self.n_pages:         # overflow pages evaporate
                self._free.append(p)
        self.acct.release(alloc.reserved_bytes, alloc.requested_bytes)
        self._spilled[rid] = SpilledAllocation(
            rid=rid, kind="bytes", batch=0, seq_tokens=0, max_tokens=0,
            pages_per_row=0, requested_bytes=alloc.requested_bytes,
            in_use_bytes=0.0, in_use_per_token=0.0)
        self.spilled_bytes_total += alloc.reserved_bytes
        return alloc.reserved_bytes

    def _spilled_state(self, rid: str, op: str) -> SpilledAllocation:
        sp = self._spilled.get(rid)
        if sp is None:
            raise ValueError(
                f"{op}({rid!r}): unknown request id; spilled requests: "
                f"{sorted(self._spilled)}")
        return sp

    def restore_reserved_bytes(self, rid: str) -> float:
        """Worst-case device bytes a :meth:`restore` of ``rid`` re-takes
        (the admission commitment for token spills, the page-rounded
        request for byte spills) — what the engine's elastic-budget check
        must find headroom for before resuming."""
        sp = self._spilled_state(rid, "restore_reserved_bytes")
        if sp.kind == "bytes":
            return float(self.pages_needed(sp.requested_bytes)
                         * self.page_bytes)
        return float(self.pages_for_tokens(sp.batch, sp.max_tokens)
                     * self.page_bytes)

    def can_restore(self, rid: str) -> bool:
        """Whether the pool physically has the pages (and ledger headroom)
        to restore ``rid`` right now."""
        sp = self._spilled.get(rid)
        if sp is None:
            return False
        if sp.kind == "bytes":
            need = self.pages_needed(sp.requested_bytes)
            return (need <= len(self._free)
                    and self.acct.can_reserve(need * self.page_bytes))
        return (self.pages_for_tokens(sp.batch, sp.max_tokens)
                <= len(self._free) - self._committed_extra)

    def restore(self, rid: str) -> Optional[List[List[int]]]:
        """Re-admit a spilled request: re-grant pages with the identical
        per-row layout, write the host page copies (and scale rows) back
        bitwise, and re-take the admission commitment. Returns the new
        per-row page ids (None for byte-style spills). Raises
        :class:`PoolExhausted` when the pool cannot host it yet — the
        caller retries when capacity frees."""
        sp = self._spilled_state(rid, "restore")
        if sp.kind == "bytes":
            alloc_bytes = sp.requested_bytes
            del self._spilled[rid]
            try:
                self.alloc(rid, alloc_bytes)
            except Exception:
                self._spilled[rid] = sp      # stay restorable on failure
                raise
            return None
        committed = self.pages_for_tokens(sp.batch, sp.max_tokens)
        if committed > len(self._free) - self._committed_extra:
            raise PoolExhausted(
                f"restore({rid!r}) commits {committed} pages, "
                f"{len(self._free) - self._committed_extra} admissible "
                f"({len(self._free)} free − {self._committed_extra} "
                f"committed) of {self.n_pages} total")
        rows = [[self._free.pop() for _ in range(sp.pages_per_row)]
                for _ in range(sp.batch)]
        if self.k_pages is not None and sp.pages_per_row:
            import numpy as np
            import jax.numpy as jnp
            ids = [p for row in rows for p in row]
            idx = jnp.asarray(np.asarray(ids, np.int32))
            self.k_pages = self.k_pages.at[:, idx].set(
                jnp.asarray(sp.k_host))
            self.v_pages = self.v_pages.at[:, idx].set(
                jnp.asarray(sp.v_host))
            if self.k_scales is not None:
                self.k_scales = self.k_scales.at[:, idx].set(
                    jnp.asarray(sp.k_scales_host))
                self.v_scales = self.v_scales.at[:, idx].set(
                    jnp.asarray(sp.v_scales_host))
        st = TokenAllocation(
            rid=rid, batch=sp.batch, seq_tokens=sp.seq_tokens,
            max_tokens=sp.max_tokens, rows=rows, page_bytes=self.page_bytes,
            tokens_per_page=self.tokens_per_page,
            in_use_bytes=sp.in_use_bytes,
            in_use_per_token=sp.in_use_per_token)
        self._committed_extra += committed - st.held_pages
        self.acct.grow(st.reserved_bytes, st.in_use_bytes)
        self._tok[rid] = st
        del self._spilled[rid]
        return [list(r) for r in rows]

    def drop_spilled(self, rid: str, *, missing_ok: bool = False) -> bool:
        """Discard a spilled request's host copy (cancellation while
        preempted). Idempotent under ``missing_ok``, mirroring
        :meth:`free`."""
        if self._spilled.pop(rid, None) is None:
            if missing_ok:
                return False
            raise ValueError(
                f"drop_spilled({rid!r}): unknown request id; spilled "
                f"requests: {sorted(self._spilled)}")
        return True

    def spilled_requests(self) -> List[str]:
        return list(self._spilled)

    # ---------------------------------------------------------------- stats
    def stats(self) -> Dict[str, float]:
        return {
            "capacity_bytes": self.acct.capacity_bytes,
            "page_bytes": float(self.page_bytes),
            "n_pages": float(self.n_pages),
            "free_pages": float(len(self._free)),
            "committed_pages": float(self._committed_extra),
            "live_requests": float(len(self._live) + len(self._tok)),
            "reserved_bytes": self.acct.reserved_bytes,
            "in_use_bytes": self.acct.in_use_bytes,
            "peak_reserved_bytes": self.acct.peak_reserved_bytes,
            "peak_in_use_bytes": self.acct.peak_in_use_bytes,
            "occupancy": self.acct.occupancy(),
            "fragmentation": self.acct.fragmentation(),
            "overcommit_events": float(self.acct.overcommit_events),
            "in_use_scale": float(self.acct.in_use_scale),
            "spilled_requests": float(len(self._spilled)),
            "spilled_bytes_total": float(self.spilled_bytes_total),
        }
