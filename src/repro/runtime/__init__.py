from repro.runtime import latency, steps
from repro.runtime.engine import (EngineConfig, EngineReport, EngineRequest,
                                  RAPEngine, RequestResult)
from repro.runtime.executor import (LocalExecutor, ModelExecutor,
                                    PagedExecutor, PagedGroup,
                                    ShardedExecutor, ShardedSlotGroup,
                                    SlotGroup, chunk_widths)
from repro.runtime.kv_pool import (KVPool, PageAllocation, PoolExhausted,
                                   TokenAllocation)
from repro.runtime.scheduler import (SCHEDULERS, FIFOScheduler,
                                     PriorityScheduler, Scheduler,
                                     SchedulerOutput, SJFScheduler,
                                     make_scheduler)
from repro.runtime.server import RAPServer, ServeResult
from repro.runtime.trainer import Trainer, TrainerConfig

__all__ = ["steps", "latency", "Trainer", "TrainerConfig", "RAPServer",
           "ServeResult", "RAPEngine", "EngineConfig", "EngineRequest",
           "EngineReport", "RequestResult", "KVPool", "PageAllocation",
           "TokenAllocation", "PoolExhausted", "Scheduler",
           "SchedulerOutput", "FIFOScheduler", "SJFScheduler",
           "PriorityScheduler", "SCHEDULERS", "make_scheduler",
           "ModelExecutor", "LocalExecutor", "PagedExecutor", "PagedGroup",
           "ShardedExecutor", "ShardedSlotGroup", "SlotGroup",
           "chunk_widths"]
