from repro.runtime import latency, scenarios, steps
from repro.runtime.engine import (EngineConfig, EngineReport, EngineRequest,
                                  RAPEngine, RequestResult)
from repro.runtime.executor import (LocalExecutor, ModelExecutor,
                                    PagedExecutor, PagedGroup,
                                    ShardedExecutor, ShardedSlotGroup,
                                    SlotGroup, chunk_widths)
from repro.runtime.kv_pool import (KVPool, PageAllocation, PoolExhausted,
                                   SpilledAllocation, TokenAllocation)
from repro.runtime.scenarios import (TickStaircase, heavy_tailed_requests,
                                     run_budget_shock,
                                     run_cancellation_storm,
                                     staircase_trace, workload_budget_trace)
from repro.runtime.scheduler import (SCHEDULERS, FIFOScheduler,
                                     PriorityScheduler, Scheduler,
                                     SchedulerOutput, SJFScheduler,
                                     VictimCandidate, make_scheduler)
from repro.runtime.server import RAPServer, ServeResult
from repro.runtime.trainer import Trainer, TrainerConfig

__all__ = ["steps", "latency", "scenarios", "Trainer", "TrainerConfig",
           "RAPServer", "ServeResult", "RAPEngine", "EngineConfig",
           "EngineRequest", "EngineReport", "RequestResult", "KVPool",
           "PageAllocation", "TokenAllocation", "SpilledAllocation",
           "PoolExhausted", "Scheduler", "SchedulerOutput",
           "FIFOScheduler", "SJFScheduler", "PriorityScheduler",
           "VictimCandidate", "SCHEDULERS", "make_scheduler",
           "ModelExecutor", "LocalExecutor", "PagedExecutor", "PagedGroup",
           "ShardedExecutor", "ShardedSlotGroup", "SlotGroup",
           "chunk_widths", "TickStaircase", "staircase_trace",
           "workload_budget_trace", "heavy_tailed_requests",
           "run_budget_shock", "run_cancellation_storm"]
