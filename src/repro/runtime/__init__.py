from repro.runtime import steps
from repro.runtime.server import RAPServer
from repro.runtime.trainer import Trainer, TrainerConfig

__all__ = ["steps", "Trainer", "TrainerConfig", "RAPServer"]
