"""Pluggable request scheduling for the serving engine.

PR 1 baked FIFO admission with head-of-line blocking into
``RAPEngine._tick``. This module extracts the queue + ordering decision
behind a small protocol so admission *policy* is swappable without
touching the engine loop:

    Scheduler.add(request, cost=…)      requests enter the waiting set
    Scheduler.schedule(now, running=…) ──► SchedulerOutput(admit, decode)
    Scheduler.remove(rid)               admitted / rejected requests leave

A :class:`SchedulerOutput` carries two separate plans (the async-engine
split): ``admit`` is the **prefill plan** — waiting requests in admission
order — and ``decode`` the **decode plan** — which *running* requests
step this macro-tick (every scheduler here steps all of them; a
preemption/SLO-tier scheduler would return a subset).

The engine walks ``SchedulerOutput.admit`` in order, attempting admission
(policy decision → pool allocation → prefill) per candidate, and stops at
the first *deferral* (no pages / no free slots). Stopping preserves the
scheduler's ordering guarantee — a deferred candidate is never overtaken
within a tick — so FIFO keeps strict head-of-line semantics and SJF/
priority orders cannot starve the job they chose to run next.

Schedulers:
  * :class:`FIFOScheduler`     — arrival order (PR 1 behaviour);
  * :class:`SJFScheduler`      — shortest job first, by the request's
    total token cost (prompt + decode length), ties broken by arrival;
  * :class:`PriorityScheduler` — explicit ``EngineRequest.priority``
    (lower = sooner) with an **aging** term: priority improves linearly
    with waiting time (one level per ``aging_s`` seconds), so a
    low-priority request behind a steady high-priority stream is
    eventually ordered first instead of starving.
"""
from __future__ import annotations

import collections
import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

__all__ = ["Scheduler", "SchedulerOutput", "VictimCandidate",
           "FIFOScheduler", "SJFScheduler", "PriorityScheduler",
           "SCHEDULERS", "make_scheduler"]


@dataclasses.dataclass(frozen=True)
class VictimCandidate:
    """One *running* request offered to :meth:`Scheduler.select_victims`
    when the engine must shed reserved bytes under a shrinking budget."""
    rid: str
    priority: int             # EngineRequest.priority (lower = sooner)
    arrival_t: float
    remaining_tokens: int     # decode tokens still owed
    reserved_bytes: float     # device bytes a preemption would free


@dataclasses.dataclass
class SchedulerOutput:
    """Explicit per-tick plans: who prefills, who decodes."""
    admit: List                     # prefill plan: EngineRequests, in order
    n_waiting: int = 0
    # decode plan: rids of running requests to step this macro-tick. Every
    # built-in scheduler steps all of them; None means "caller passed no
    # running set" (legacy schedule(now) calls) and is treated as "all".
    decode: Optional[List[str]] = None


@dataclasses.dataclass
class _Entry:
    req: object                     # EngineRequest (duck-typed)
    cost: float                     # total tokens: prompt + decode budget
    seq: int                        # arrival tiebreak (insertion order)


class Scheduler:
    """Base: owns the waiting set; subclasses define the ordering key."""

    name = "base"

    def __init__(self):
        self._waiting: "collections.OrderedDict[str, _Entry]" = \
            collections.OrderedDict()
        self._seq = 0

    # ------------------------------------------------------------ lifecycle
    def add(self, req, *, cost: float = 0.0) -> None:
        if req.rid in self._waiting:
            raise ValueError(f"request {req.rid!r} already waiting")
        self._waiting[req.rid] = _Entry(req=req, cost=float(cost),
                                        seq=self._seq)
        self._seq += 1

    def remove(self, rid: str) -> None:
        self._waiting.pop(rid, None)

    def peek(self, rid: str):
        """The waiting EngineRequest for ``rid``, or None — the engine's
        cancellation path needs the request object to record the result."""
        entry = self._waiting.get(rid)
        return entry.req if entry is not None else None

    def clear(self) -> None:
        self._waiting.clear()

    def __len__(self) -> int:
        return len(self._waiting)

    def __contains__(self, rid: str) -> bool:
        return rid in self._waiting

    # ------------------------------------------------------------- ordering
    def _key(self, entry: _Entry, now: float) -> Tuple:
        raise NotImplementedError

    # ------------------------------------------------------------ victims
    def _victim_priority(self, cand: VictimCandidate, now: float) -> float:
        """Effective priority of a running request for victim selection
        (lower = more important = preempted LAST). The base schedulers
        have no priority notion, so every candidate ties at 0.0 and the
        tiebreaks below decide."""
        return 0.0

    def select_victims(self, cands: Sequence[VictimCandidate],
                       now: float) -> List[VictimCandidate]:
        """Order running requests for preemption under a budget shock:
        lowest effective priority first, then most remaining work (the
        request that would waste the least completed compute if evicted
        keeps running; the one furthest from done yields), then newest
        arrival. The engine preempts a prefix of this order until reserved
        bytes fit the shrunken budget."""
        return sorted(cands,
                      key=lambda c: (-self._victim_priority(c, now),
                                     -c.remaining_tokens, -c.arrival_t))

    def schedule(self, now: float,
                 running: Sequence[str] = ()) -> SchedulerOutput:
        """Order the waiting set into this tick's prefill plan; plan the
        decode step for every running request."""
        entries = sorted(self._waiting.values(),
                         key=lambda e: self._key(e, now))
        return SchedulerOutput(admit=[e.req for e in entries],
                               n_waiting=len(entries),
                               decode=list(running))


class FIFOScheduler(Scheduler):
    name = "fifo"

    def _key(self, entry: _Entry, now: float) -> Tuple:
        return (entry.seq,)

    def schedule(self, now: float,
                 running: Sequence[str] = ()) -> SchedulerOutput:
        # insertion order IS arrival order — skip the O(W log W) sort the
        # generic path pays per tick
        return SchedulerOutput(admit=[e.req for e in self._waiting.values()],
                               n_waiting=len(self._waiting),
                               decode=list(running))


class SJFScheduler(Scheduler):
    """Shortest job first — smallest total token cost (batch × (prompt +
    decode), the engine's `cost` at add()) next. Under memory pressure
    this admits the requests with the smallest KV demand first, trading
    FIFO fairness for queue-delay percentiles."""

    name = "sjf"

    def _key(self, entry: _Entry, now: float) -> Tuple:
        return (entry.cost, entry.seq)


class PriorityScheduler(Scheduler):
    """Explicit request priority (lower = sooner); FIFO within a level.

    The effective priority **ages**: it improves by one level per
    ``aging_s`` seconds of waiting (measured from the request's
    ``arrival_t`` on the engine's clock), so a steady stream of
    high-priority arrivals can delay a low-priority request only
    ``aging_s × Δpriority`` seconds before it sorts ahead of them —
    bounded starvation instead of indefinite deferral (pinned in
    ``tests/test_engine.py::test_priority_scheduler_aging_prevents_starvation``).
    Ties (same arrival time) keep the pure priority order unchanged.
    ``aging_s=float('inf')`` restores the unaged behaviour.
    """

    name = "priority"

    def __init__(self, aging_s: float = 10.0):
        super().__init__()
        if not aging_s > 0:
            raise ValueError(
                f"aging_s must be > 0 seconds per priority level, got "
                f"{aging_s!r} (use float('inf') to disable aging)")
        self.aging_s = float(aging_s)

    def _key(self, entry: _Entry, now: float) -> Tuple:
        prio = getattr(entry.req, "priority", 0)
        waited = max(now - getattr(entry.req, "arrival_t", 0.0), 0.0)
        aged = prio - (waited / self.aging_s if self.aging_s != float("inf")
                       else 0.0)
        return (aged, entry.seq)

    def _victim_priority(self, cand: VictimCandidate, now: float) -> float:
        """SLO-tier victim selection reuses the aging seam: a request's
        effective priority improves the longer it has been in the system,
        so an old low-tier request is not the automatic victim of every
        shock — the same bounded-starvation contract admission has."""
        waited = max(now - cand.arrival_t, 0.0)
        return cand.priority - (waited / self.aging_s
                                if self.aging_s != float("inf") else 0.0)


SCHEDULERS: Dict[str, type] = {
    "fifo": FIFOScheduler,
    "sjf": SJFScheduler,
    "priority": PriorityScheduler,
}


def make_scheduler(spec) -> Scheduler:
    """Accepts a Scheduler instance (passed through), a registered name,
    or None (FIFO — the PR 1 default)."""
    if spec is None:
        return FIFOScheduler()
    if isinstance(spec, Scheduler):
        return spec
    if isinstance(spec, str):
        if spec not in SCHEDULERS:
            raise KeyError(f"unknown scheduler {spec!r}; available: "
                           f"{', '.join(sorted(SCHEDULERS))}")
        return SCHEDULERS[spec]()
    raise TypeError(f"scheduler must be a name or Scheduler, got "
                    f"{type(spec).__name__}")
