"""Pluggable request scheduling for the serving engine.

PR 1 baked FIFO admission with head-of-line blocking into
``RAPEngine._tick``. This module extracts the queue + ordering decision
behind a small protocol so admission *policy* is swappable without
touching the engine loop:

    Scheduler.add(request, cost=…)      requests enter the waiting set
    Scheduler.schedule(now) ──► SchedulerOutput(admit=[…ordered…])
    Scheduler.remove(rid)               admitted / rejected requests leave

The engine walks ``SchedulerOutput.admit`` in order, attempting admission
(policy decision → pool allocation → prefill) per candidate, and stops at
the first *deferral* (no pages / no free slots). Stopping preserves the
scheduler's ordering guarantee — a deferred candidate is never overtaken
within a tick — so FIFO keeps strict head-of-line semantics and SJF/
priority orders cannot starve the job they chose to run next.

Schedulers:
  * :class:`FIFOScheduler`     — arrival order (PR 1 behaviour);
  * :class:`SJFScheduler`      — shortest job first, by the request's
    total token cost (prompt + decode length), ties broken by arrival;
  * :class:`PriorityScheduler` — explicit ``EngineRequest.priority``
    (lower = sooner), ties broken by arrival.
"""
from __future__ import annotations

import collections
import dataclasses
from typing import Dict, List, Tuple

__all__ = ["Scheduler", "SchedulerOutput", "FIFOScheduler", "SJFScheduler",
           "PriorityScheduler", "SCHEDULERS", "make_scheduler"]


@dataclasses.dataclass
class SchedulerOutput:
    """An explicit admission plan for one engine tick."""
    admit: List                     # EngineRequests, in admission order
    n_waiting: int = 0


@dataclasses.dataclass
class _Entry:
    req: object                     # EngineRequest (duck-typed)
    cost: float                     # total tokens: prompt + decode budget
    seq: int                        # arrival tiebreak (insertion order)


class Scheduler:
    """Base: owns the waiting set; subclasses define the ordering key."""

    name = "base"

    def __init__(self):
        self._waiting: "collections.OrderedDict[str, _Entry]" = \
            collections.OrderedDict()
        self._seq = 0

    # ------------------------------------------------------------ lifecycle
    def add(self, req, *, cost: float = 0.0) -> None:
        if req.rid in self._waiting:
            raise ValueError(f"request {req.rid!r} already waiting")
        self._waiting[req.rid] = _Entry(req=req, cost=float(cost),
                                        seq=self._seq)
        self._seq += 1

    def remove(self, rid: str) -> None:
        self._waiting.pop(rid, None)

    def clear(self) -> None:
        self._waiting.clear()

    def __len__(self) -> int:
        return len(self._waiting)

    def __contains__(self, rid: str) -> bool:
        return rid in self._waiting

    # ------------------------------------------------------------- ordering
    def _key(self, entry: _Entry) -> Tuple:
        raise NotImplementedError

    def schedule(self, now: float) -> SchedulerOutput:
        """Order the waiting set into this tick's admission plan."""
        entries = sorted(self._waiting.values(), key=self._key)
        return SchedulerOutput(admit=[e.req for e in entries],
                               n_waiting=len(entries))


class FIFOScheduler(Scheduler):
    name = "fifo"

    def _key(self, entry: _Entry) -> Tuple:
        return (entry.seq,)

    def schedule(self, now: float) -> SchedulerOutput:
        # insertion order IS arrival order — skip the O(W log W) sort the
        # generic path pays per tick
        return SchedulerOutput(admit=[e.req for e in self._waiting.values()],
                               n_waiting=len(self._waiting))


class SJFScheduler(Scheduler):
    """Shortest job first — smallest total token cost (batch × (prompt +
    decode), the engine's `cost` at add()) next. Under memory pressure
    this admits the requests with the smallest KV demand first, trading
    FIFO fairness for queue-delay percentiles."""

    name = "sjf"

    def _key(self, entry: _Entry) -> Tuple:
        return (entry.cost, entry.seq)


class PriorityScheduler(Scheduler):
    """Explicit request priority (lower = sooner); FIFO within a level."""

    name = "priority"

    def _key(self, entry: _Entry) -> Tuple:
        return (getattr(entry.req, "priority", 0), entry.seq)


SCHEDULERS: Dict[str, type] = {
    "fifo": FIFOScheduler,
    "sjf": SJFScheduler,
    "priority": PriorityScheduler,
}


def make_scheduler(spec) -> Scheduler:
    """Accepts a Scheduler instance (passed through), a registered name,
    or None (FIFO — the PR 1 default)."""
    if spec is None:
        return FIFOScheduler()
    if isinstance(spec, Scheduler):
        return spec
    if isinstance(spec, str):
        if spec not in SCHEDULERS:
            raise KeyError(f"unknown scheduler {spec!r}; available: "
                           f"{', '.join(sorted(SCHEDULERS))}")
        return SCHEDULERS[spec]()
    raise TypeError(f"scheduler must be a name or Scheduler, got "
                    f"{type(spec).__name__}")
