"""Fig. 10 analogue: reward-coefficient (α, β) sensitivity grid.

For each (α, β) a short policy training; reported metric = mean episode
reward of the trained policy plus the quality (Δppl) and memory (peak
fraction) of its decisions at a fixed request — showing the
accuracy-vs-memory ridge the paper tunes to (α=1.0, β=0.3).
"""
from __future__ import annotations

import numpy as np

from benchmarks import common
from repro.core import masks


def run() -> list:
    model, params, corpus = common.subject()
    mm = common.memory_model(model.cfg)
    evals = common.eval_batches(corpus, n_batches=2)
    bs, sql = common.EVAL_REQUEST
    budget = 0.7 * mm.dense_peak(bs, sql)
    dense_ppl = common.evaluate(model, params, evals)["ppl"]

    rows = []
    for alpha in (0.2, 0.6, 1.0):
        for beta in (0.1, 0.3, 0.5):
            ctl, tr = common.trained_controller(
                model, params, corpus, episodes=4, seed=0,
                alpha=alpha, beta=beta, tag=f"a{alpha}_b{beta}")
            d = ctl.decide(bs, sql, budget)
            g = masks.mask_to_gates(d.mask)
            m = common.evaluate(model, params, evals, gates=g)
            rows.append({
                "alpha": alpha, "beta": beta,
                "mean_reward": round(float(np.mean(tr.episode_rewards[-5:])),
                                     4),
                "ppl_ratio": round(m["ppl"] / dense_ppl, 3),
                "peak_frac": round(d.peak_bytes / mm.dense_peak(bs, sql), 3),
                "kept": int(d.mask.sum())})
    common.emit("fig10_alpha_beta", rows,
                header=["alpha", "beta", "mean_reward", "ppl_ratio",
                        "peak_frac", "kept"])
    return rows
