"""Benchmark harness: one module per paper table/figure + the roofline.

  PYTHONPATH=src python -m benchmarks.run [--only table1,fig9]

Each module prints a CSV block and writes experiments/bench/<name>.json.
"""
from __future__ import annotations

import argparse
import importlib
import sys
import time
import traceback

BENCHES = [
    ("table1", "benchmarks.table1_budgets",
     "Table 1/3 — methods at 80%/60% unified memory budgets"),
    ("table2", "benchmarks.table2_ablation",
     "Table 2 / Fig.8 — RAP vs RAP^-GSI vs RAP^-RL"),
    ("table4", "benchmarks.table4_prune_ratio",
     "Table 4 — weight-prune ratio needed per budget"),
    ("fig3", "benchmarks.fig3_memory_breakdown",
     "Fig. 3 — param- vs KV-dominated memory"),
    ("fig4", "benchmarks.fig4_block_sensitivity",
     "Fig. 4/12 — per-block sensitivity vs request length"),
    ("fig6", "benchmarks.fig6_gsi_vs_oneshot",
     "Fig. 6 — GSI vs one-shot block scores"),
    ("fig9", "benchmarks.fig9_seeds",
     "Fig. 9 — RL reward across seeds"),
    ("fig10", "benchmarks.fig10_alpha_beta",
     "Fig. 10 — α/β penalty sensitivity"),
    ("fig11", "benchmarks.fig11_overhead",
     "Fig. 11 — controller overhead"),
    ("roofline", "benchmarks.roofline",
     "§Roofline — 3 terms per arch × shape from the dry-run"),
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated subset, e.g. table1,fig9")
    args = ap.parse_args()
    want = set(args.only.split(",")) if args.only else None

    failures = []
    for name, module, desc in BENCHES:
        if want and name not in want:
            continue
        print(f"\n===== {name}: {desc} =====", flush=True)
        t0 = time.time()
        try:
            importlib.import_module(module).run()
            print(f"===== {name} done in {time.time()-t0:.1f}s =====",
                  flush=True)
        except Exception as e:
            failures.append(name)
            print(f"===== {name} FAILED: {type(e).__name__}: {e} =====")
            traceback.print_exc()
    if failures:
        print(f"\nFAILED: {failures}")
        sys.exit(1)
    print("\nall benchmarks complete")


if __name__ == "__main__":
    main()
