"""Table 4 analogue: weight-prune ratio needed to meet each memory budget.

The paper's point: pruning *ratio* is a misleading proxy — methods that can
shed KV cache (MHA blocks) meet a unified budget with far fewer parameter
removals than FFN-only schemes.
"""
from __future__ import annotations

from benchmarks import common
from repro.core import baselines, masks


def run() -> list:
    model, params, corpus = common.subject()
    mm = common.memory_model(model.cfg)
    calib = common.calib_batch(corpus)
    bs, sql = common.EVAL_REQUEST
    ctl, _ = common.trained_controller(model, params, corpus)

    rows = []
    for frac in (0.8, 0.6):
        budget = frac * mm.dense_peak(bs, sql)
        schemes = {
            "LLMPruner": baselines.llmpruner_mask(model, params, calib, mm,
                                                  bs, sql, budget),
            "ShortGPT": baselines.shortgpt_mask(model, params, calib, mm,
                                                bs, sql, budget),
            "MHA-Drop": baselines.mha_drop_mask(model, params, calib, mm,
                                                bs, sql, budget),
            "FFN-Skip": baselines.ffn_skip_mask(model, params, calib, mm,
                                                bs, sql, budget),
            "RAP": ctl.decide(bs, sql, budget).mask,
        }
        for name, mask in schemes.items():
            rows.append({
                "budget": frac, "scheme": name,
                "weight_prune_ratio":
                    round(1.0 - masks.mask_param_fraction(model.cfg, mask), 4),
                "fits": bool(mm.peak_bytes(mask, bs, sql) <= budget)})
        ratio = baselines.slicegpt_fit_ratio(model.cfg, mm, bs, sql, budget)
        rows.append({"budget": frac, "scheme": "SliceGPT",
                     "weight_prune_ratio": round(1.0 - ratio, 4),
                     "fits": True})

    common.emit("table4_prune_ratio", rows,
                header=["budget", "scheme", "weight_prune_ratio", "fits"])
    return rows
