"""Fig. 4/12 analogue: per-block Δlog-ppl of removing each MHA/FFN block,
at two request lengths — block importance is heterogeneous across depth and
shifts with sequence length."""
from __future__ import annotations

import numpy as np

from benchmarks import common
from repro.core import gsi


def run() -> list:
    model, params, corpus = common.subject()
    rows = []
    L = model.cfg.n_layers
    for seq in (64, 256):
        batch = common.calib_batch(corpus, n=4, seq=seq)
        scores = gsi.oneshot_rank(model, params, batch, chunk=16)
        base = float(gsi.make_ppl_fn(model, batch)(
            params, np.ones(2 * L, np.float32)))
        for b in range(2 * L):
            rows.append({"seq": seq,
                         "block": f"{'MHA' if b < L else 'FFN'}{b % L}",
                         "delta_log_ppl": round(float(scores[b]) - base, 4)})
    common.emit("fig4_block_sensitivity", rows,
                header=["seq", "block", "delta_log_ppl"])
    # heterogeneity check: spread across blocks ≫ 0
    d64 = [r["delta_log_ppl"] for r in rows if r["seq"] == 64]
    print(f"# spread(seq=64): max={max(d64):.3f} min={min(d64):.3f}")
    return rows
