"""Table 1/3 analogue: every method at equal unified memory budgets.

Protocol (paper §5.2): budget = frac × max(params + KV) of the dense model
at the evaluation request shape; each method prunes until it fits; we then
measure held-out perplexity and next-token accuracy. RAP uses the trained
DQN controller (GSI scores recomputed per removal); baselines are the
static schemes of §5.1.
"""
from __future__ import annotations

import numpy as np

from benchmarks import common
from repro.core import baselines, masks
from repro.models import registry

BUDGETS = (0.8, 0.6)


def run() -> list:
    model, params, corpus = common.subject()
    mm = common.memory_model(model.cfg)
    calib = common.calib_batch(corpus)
    evals = common.eval_batches(corpus)
    bs, sql = common.EVAL_REQUEST
    ctl, _ = common.trained_controller(model, params, corpus)

    rows = []
    dense = common.evaluate(model, params, evals)
    rows.append({"budget": 1.0, "scheme": "Dense", "ppl": dense["ppl"],
                 "acc": dense["acc"], "kept_blocks": 2 * model.cfg.n_layers,
                 "fits": True, "param_frac": 1.0})

    for frac in BUDGETS:
        budget = frac * mm.dense_peak(bs, sql)

        def eval_mask(name, mask):
            g = masks.mask_to_gates(mask)
            m = common.evaluate(model, params, evals, gates=g)
            rows.append({
                "budget": frac, "scheme": name, "ppl": m["ppl"],
                "acc": m["acc"], "kept_blocks": int(mask.sum()),
                "fits": bool(mm.peak_bytes(mask, bs, sql) <= budget),
                "param_frac": masks.mask_param_fraction(model.cfg, mask)})

        eval_mask("LLMPruner",
                  baselines.llmpruner_mask(model, params, calib, mm, bs, sql,
                                           budget))
        eval_mask("ShortGPT",
                  baselines.shortgpt_mask(model, params, calib, mm, bs, sql,
                                          budget))
        eval_mask("MHA-Drop",
                  baselines.mha_drop_mask(model, params, calib, mm, bs, sql,
                                          budget))
        eval_mask("FFN-Skip",
                  baselines.ffn_skip_mask(model, params, calib, mm, bs, sql,
                                          budget))
        # SliceGPT: width slicing → different params/cfg
        ratio = baselines.slicegpt_fit_ratio(model.cfg, mm, bs, sql, budget)
        p2, cfg2 = baselines.slicegpt_slice(model, params, ratio)
        m2 = registry.build(cfg2)
        sm = common.evaluate(m2, p2, evals)
        rows.append({"budget": frac, "scheme": "SliceGPT", "ppl": sm["ppl"],
                     "acc": sm["acc"], "kept_blocks": 2 * model.cfg.n_layers,
                     "fits": True, "param_frac": ratio})
        # RAP
        d = ctl.decide(bs, sql, budget)
        eval_mask("RAP", d.mask)

    common.emit("table1_budgets", rows,
                header=["budget", "scheme", "ppl", "acc", "kept_blocks",
                        "fits", "param_frac"])
    return rows
