"""Fig. 11 analogue: controller overhead vs the served model.

Paper: 18K-param controller vs 6.7B LLM (~3.7e5× reduction), policy step
0.5 s vs 52.7 s inference (<1%). Here: measured on the subject model and
extrapolated analytically to llama2-7b scale.
"""
from __future__ import annotations

import time

import jax
import numpy as np

from benchmarks import common
from repro.configs import get_config
from repro.core import dqn


def run() -> list:
    model, params, corpus = common.subject()
    mm = common.memory_model(model.cfg)
    ctl, tr = common.trained_controller(model, params, corpus)
    bs, sql = common.EVAL_REQUEST
    budget = 0.7 * mm.dense_peak(bs, sql)

    # controller decide latency (post-warmup)
    ctl.decide(bs, sql, budget)
    t0 = time.perf_counter()
    n = 5
    for _ in range(n):
        d = ctl.decide(bs, sql, budget)
    decide_s = (time.perf_counter() - t0) / n

    # one model inference (teacher-forced eval batch) for comparison
    evals = common.eval_batches(corpus, n_batches=1)
    common.evaluate(model, params, evals)
    t0 = time.perf_counter()
    common.evaluate(model, params, evals)
    infer_s = time.perf_counter() - t0

    n_model = sum(int(np.prod(l.shape)) for l in jax.tree.leaves(params))
    n_ctrl = dqn.n_params(ctl.q_params)
    llama = get_config("llama2-7b").total_params()
    # q-net for llama2-7b scale: state 2·32+4, actions 2·32+1, hidden 64
    n_ctrl_llama = dqn.n_params(dqn.init_qnet(jax.random.key(0), 68, 65, 64))

    rows = [{
        "quantity": "params", "controller": n_ctrl, "model": n_model,
        "ratio": round(n_model / n_ctrl, 1)},
        {"quantity": "params@llama2-7b", "controller": n_ctrl_llama,
         "model": llama, "ratio": round(llama / n_ctrl_llama, 1)},
        {"quantity": "latency_s", "controller": round(decide_s, 4),
         "model": round(infer_s, 4),
         "ratio": round(infer_s / max(decide_s, 1e-9), 2)},
    ]
    common.emit("fig11_overhead", rows,
                header=["quantity", "controller", "model", "ratio"])
    print(f"# paper: 18K vs 6.7B (3.7e5×); here @llama-scale: "
          f"{n_ctrl_llama} vs {llama} "
          f"({llama/n_ctrl_llama:.1e}×)")
    return rows
