"""Fig. 3 analogue: parameter- vs KV-dominated memory across request shapes.

Uses the full llama2-7b config (the paper's own subject) and Eq.(3)+(4):
shows the transition from parameter-dominated (small batch/seq) to
KV-dominated (large batch/seq) — including the paper's headline point that
(batch=16, seq=4k) KV (32 GB) dwarfs the 14 GB of parameters.
"""
from __future__ import annotations

from benchmarks import common
from repro.configs import get_config
from repro.core import masks, memory


def run() -> list:
    cfg = get_config("llama2-7b")
    mm = memory.build_memory_model(cfg)   # bf16 by config
    full = masks.full_mask(cfg.n_layers)
    rows = []
    for bs in (1, 4, 16, 64):
        for seq in (512, 2048, 4096, 16384):
            p = mm.param_bytes(full)
            k = mm.state_bytes(full, bs, seq)
            rows.append({"batch": bs, "seq": seq,
                         "param_gb": round(p / 2**30, 2),
                         "kv_gb": round(k / 2**30, 2),
                         "kv_frac": round(k / (p + k), 3)})
    common.emit("fig3_memory_breakdown", rows,
                header=["batch", "seq", "param_gb", "kv_gb", "kv_frac"])
    # paper's headline cell
    head = [r for r in rows if r["batch"] == 16 and r["seq"] == 4096][0]
    print(f"# llama2-7b @ bs=16 seq=4k: params {head['param_gb']}GB, "
          f"KV {head['kv_gb']}GB (paper: 14GB vs 32GB)")
    return rows
