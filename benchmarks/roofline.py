"""§Roofline: three-term table for every (arch × shape) from the dry-run."""
from __future__ import annotations

from benchmarks import common
from repro.roofline import full_table, render_table, analysis


def run() -> list:
    rows = full_table()
    live = [r for r in rows if not r.get("skipped")]
    print(render_table(rows))
    for r in live:
        print(f"# {r['arch']}×{r['shape']}: {analysis.suggestion(r)}")
    common.emit("roofline", rows,
                header=["arch", "shape", "compute_s", "memory_s",
                        "collective_s", "dominant", "roofline_frac",
                        "fit_gb"])
    if live:
        n_fit = sum(1 for r in live if r.get("fits_hbm"))
        print(f"# {len(live)} cells analyzed; {n_fit} fit 16GB/chip HBM")
    return rows
