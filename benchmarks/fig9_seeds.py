"""Fig. 9 analogue: DQN expected-reward curves across 3 random seeds —
training is robust to initialization."""
from __future__ import annotations

import numpy as np

from benchmarks import common


def run() -> list:
    model, params, corpus = common.subject()
    rows = []
    finals = []
    for seed in (0, 1, 2):
        _, tr = common.trained_controller(model, params, corpus,
                                          episodes=5, seed=seed,
                                          tag="fig9")
        r = np.asarray(tr.episode_rewards)
        # smoothed curve
        smooth = np.convolve(r, np.ones(5) / 5, mode="valid")
        for ep, v in enumerate(smooth):
            rows.append({"seed": seed, "episode": ep,
                         "reward_smoothed": round(float(v), 4)})
        finals.append(float(smooth[-1]))
    common.emit("fig9_seeds", rows,
                header=["seed", "episode", "reward_smoothed"])
    print(f"# final smoothed rewards per seed: "
          f"{[round(f, 3) for f in finals]} "
          f"(band width {max(finals)-min(finals):.3f})")
    return rows
