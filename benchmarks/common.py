"""Shared benchmark substrate: the RAP subject model + evaluation protocol.

The paper's experiments run Llama2-7B/Llama3-8B on WikiText2/PTB + seven
commonsense suites. Offline, the analogue (DESIGN.md §15) is:
  * subject model — same family (RMSNorm+SwiGLU+RoPE decoder, 8L/d256,
    ~13M params), trained in-repo on the synthetic Zipf-Markov corpus;
  * "WikiText2 ppl"  → held-out synthetic perplexity;
  * "commonsense acc" → next-token top-1 accuracy on held-out text (the
    downstream-quality proxy);
  * unified memory budget — Eq.(3)+(4) peak at an evaluation request shape
    chosen so KV cache dominates parameters (the paper's motivating regime).

Everything heavy (subject training, DQN policies) is cached under
``experiments/bench/`` so reruns are incremental.
"""
from __future__ import annotations

import json
import os
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.configs import get_config
from repro.configs.llama2_7b import RAP_SUBJECT
from repro.core import dqn, env as env_lib, memory
from repro.core.controller import RAPController
from repro.data import SyntheticCorpus, batch_iterator
from repro.models import registry
from repro.optim import adamw
from repro.runtime import Trainer, TrainerConfig

BENCH_DIR = "experiments/bench"
SUBJECT_STEPS = 300
EVAL_REQUEST = (8, 2048)     # (batch, seq): KV-dominated regime


def ensure_dirs():
    os.makedirs(BENCH_DIR, exist_ok=True)


def subject() -> Tuple:
    """(model, trained params, corpus). Trains once, cached on disk."""
    ensure_dirs()
    cfg = RAP_SUBJECT
    model = registry.build(cfg)
    corpus = SyntheticCorpus(cfg.vocab_size, seed=0)
    ckpt_dir = os.path.join(BENCH_DIR, "subject_ckpt")
    tr = Trainer(model, adamw.AdamWConfig(lr=1e-3, total_steps=SUBJECT_STEPS,
                                          warmup_steps=30),
                 TrainerConfig(total_steps=SUBJECT_STEPS, ckpt_dir=ckpt_dir,
                               ckpt_every=100, log_every=100,
                               remat=False, ckpt_async=False))
    if not tr.maybe_restore() or tr.step < SUBJECT_STEPS:
        start = tr.step
        print(f"[common] training subject model {start}→{SUBJECT_STEPS}")
        tr.run(batch_iterator(corpus, 16, 128, start=start))
    return model, tr.params, corpus


def calib_batch(corpus, n=4, seq=128) -> Dict:
    return {k: jnp.asarray(v) for k, v in
            corpus.batch(n, seq, split="calib").items()}


def eval_batches(corpus, n_batches=4, bs=8, seq=128):
    return [{k: jnp.asarray(v) for k, v in
             corpus.batch(bs, seq, split="eval", index=i).items()}
            for i in range(n_batches)]


def evaluate(model, params, batches, gates=None) -> Dict[str, float]:
    """Held-out perplexity + next-token top-1 accuracy (downstream proxy)."""
    tot_nll, tot_correct, tot_tok = 0.0, 0.0, 0
    for b in batches:
        lg = model.logits(params, b, gates=gates)
        lg, labels = lg[:, :-1], b["labels"][:, 1:]
        viota = jax.lax.broadcasted_iota(jnp.int32, (lg.shape[-1],), 0)
        lg = jnp.where(viota >= model.cfg.vocab_size, -1e30, lg)
        logz = jax.nn.logsumexp(lg, axis=-1)
        gold = jnp.sum(jnp.where(viota == labels[..., None], lg, 0.0), -1)
        tot_nll += float(jnp.sum(logz - gold))
        tot_correct += float(jnp.sum(jnp.argmax(lg, -1) == labels))
        tot_tok += labels.size
    return {"ppl": float(np.exp(tot_nll / tot_tok)),
            "acc": tot_correct / tot_tok}


def memory_model(cfg=None) -> memory.MemoryModel:
    return memory.build_memory_model(cfg or RAP_SUBJECT)


def trained_controller(model, params, corpus, *, episodes=6, seed=0,
                       alpha=1.0, beta=0.3, tag="default",
                       force=False) -> Tuple[RAPController, dqn.TrainResult]:
    """DQN policy for the subject model (cached per tag/seed)."""
    ensure_dirs()
    mm = memory_model(model.cfg)
    calib = calib_batch(corpus, n=2, seq=64)   # CPU time box
    cache = os.path.join(BENCH_DIR, f"qnet_{tag}_s{seed}")
    env_cfg = env_lib.EnvConfig(alpha=alpha, beta=beta)
    e = env_lib.PruneEnv(model, params, calib, mm, env_cfg, chunk=16)

    def sampler(rng):
        bs = int(2 ** rng.integers(0, 4))
        sql = int(rng.integers(4, 33)) * 64
        frac = float(rng.uniform(0.55, 0.9))
        return bs, sql, frac * mm.dense_peak(bs, sql)

    meta_p = cache + ".json"
    if os.path.exists(meta_p) and not force:
        with open(meta_p) as f:
            meta = json.load(f)
        qp = {k: jnp.asarray(np.asarray(v, np.float32))
              for k, v in meta["q_params"].items()}
        tr = dqn.TrainResult(qp, meta["rewards"], meta["fits"], [])
    else:
        print(f"[common] training DQN policy ({tag}, seed {seed}, "
              f"{episodes} eps)")
        tr = dqn.train(lambda: e, episodes=episodes, seed=seed,
                       cfg=dqn.DQNConfig(eps_decay_episodes=episodes * 2 // 3),
                       request_sampler=sampler)
        with open(meta_p, "w") as f:
            json.dump({"q_params": {k: np.asarray(v).tolist()
                                    for k, v in tr.q_params.items()},
                       "rewards": tr.episode_rewards,
                       "fits": tr.episode_fits}, f)
    ctl = RAPController(model, params, calib, mm, tr.q_params,
                        env_cfg=env_cfg, chunk=16)
    return ctl, tr


def emit(name: str, rows, header=None):
    """Write JSON + print CSV block for the harness."""
    ensure_dirs()
    with open(os.path.join(BENCH_DIR, name + ".json"), "w") as f:
        json.dump(rows, f, indent=1, default=float)
    if header:
        print(",".join(header))
    for r in rows:
        if isinstance(r, dict):
            print(",".join(str(r.get(h, "")) for h in (header or r)))
    print(flush=True)
