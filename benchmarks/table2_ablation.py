"""Table 2 / Fig. 8 analogue: component ablations.

RAP^-GSI — one-shot dense scores, no re-evaluation (static top-k drop);
RAP^-RL  — random block drops to the same budget (paper's Random-Drop);
RAP      — full system.
"""
from __future__ import annotations

import numpy as np

from benchmarks import common
from repro.core import baselines, masks

BUDGETS = (0.8, 0.6)


def run() -> list:
    model, params, corpus = common.subject()
    mm = common.memory_model(model.cfg)
    calib = common.calib_batch(corpus)
    evals = common.eval_batches(corpus)
    bs, sql = common.EVAL_REQUEST
    ctl, _ = common.trained_controller(model, params, corpus)

    rows = []
    for frac in BUDGETS:
        budget = frac * mm.dense_peak(bs, sql)

        def eval_mask(name, mask):
            g = masks.mask_to_gates(mask)
            m = common.evaluate(model, params, evals, gates=g)
            rows.append({"budget": frac, "scheme": name, "ppl": m["ppl"],
                         "acc": m["acc"], "kept_blocks": int(mask.sum())})

        # RAP^-RL: random drop (mean over 3 seeds)
        ppls, accs, kept = [], [], []
        for s in range(3):
            m = baselines.random_drop_mask(model, mm, bs, sql, budget, seed=s)
            g = masks.mask_to_gates(m)
            r = common.evaluate(model, params, evals, gates=g)
            ppls.append(r["ppl"]); accs.append(r["acc"]); kept.append(m.sum())
        rows.append({"budget": frac, "scheme": "RAP^-RL",
                     "ppl": float(np.mean(ppls)), "acc": float(np.mean(accs)),
                     "kept_blocks": int(np.mean(kept))})
        # RAP^-GSI: one-shot scores
        eval_mask("RAP^-GSI",
                  baselines.oneshot_ppl_mask(model, params, calib, mm, bs,
                                             sql, budget, chunk=16))
        # full RAP
        d = ctl.decide(bs, sql, budget)
        eval_mask("RAP", d.mask)

    common.emit("table2_ablation", rows,
                header=["budget", "scheme", "ppl", "acc", "kept_blocks"])
    return rows
