"""Engine vs one-shot serving throughput on a Poisson trace.

Replays the SAME ≥16-request Poisson arrival trace through:

  * **engine/slot** — continuous batching through ``RAPEngine`` +
    ``LocalExecutor``: one shared KV pool (admission-controlled),
    slot-batched decode over all running requests, under the chosen
    pruning policy and scheduler (per mode: masked | structural);
  * **engine/paged** — the same trace through ``PagedExecutor``
    (masked and structural modes): physically paged KV with per-request
    page tables, measuring what paging buys in *physical* internal
    fragmentation (``measured_frag``: 1 − tokens-written /
    cache-bytes-allocated, sampled per decode tick) at equal-or-better
    throughput. Structural rows run under ``--bucket-quant`` (DESIGN.md
    §9) so the compiled-executable set stays bounded, and the warmed
    structural/paged row at the top horizon is hard-gated ≥ its
    structural/slot counterpart;
  * **engine/sharded** — the same trace through ``ShardedExecutor``
    (masked mode): mesh-resident slot groups over a DP-majority host
    mesh (DESIGN.md §7). On a multi-device host the warmed sharded row
    must not be SLOWER than single-device local at equal batch — the
    horizon amortizes the collectives, and a regressive mesh would mean
    sharding costs more than it parallelizes. Gated below like the
    horizon gate, hard-failing on real accelerator meshes; fake
    host-platform CPU devices report the ratio loudly instead (threads
    on one socket measure the partition overhead without the silicon
    that pays for it);
  * **serial** — the historical one-shot path: ``RAPServer.serve()`` per
    request, each against its own instantaneous budget.

Each engine configuration is swept over the decode **horizon** H ∈
{1, 4, 8} (``EngineConfig.decode_horizon``, DESIGN.md §5): H tokens per
fused on-device loop with one device→host sync per horizon. Rows carry a
``host_ms_per_tok`` column — (wall time − time inside compiled launches
and read-backs) / generated tokens — isolating the host-side dispatch
overhead the horizon exists to shrink. After writing its document the
script FAILS (exit 1) if the warmed masked/paged row at the largest
swept horizon (H=8 vs H=1 by default) drops more than 10% of the
smallest's tok/s, or fails to beat its ``host_ms_per_tok``: amortized
dispatch is the point of the feature (tok/s at smoke scale on a small
host is compute-bound parity, and the backlog-aware clamp deliberately
trades a few % of top-horizon tok/s for lower queue delay), and a
silent regression here would invalidate the cross-PR trajectory.

Every engine row also reports request-level latency percentiles
(DESIGN.md §6): **TTFT** (arrival → first token, p50/p90/p99 ms) and
**ITL** (inter-token latency, per generated token). After the sweep an
**interference** section replays a decode-heavy trace three ways —
alone, with a long prompt injected mid-serve prefilled monolithically,
and with the same prompt prefilled in chunks
(``EngineConfig.max_prefill_tokens``) — and gates the async engine's
reason to exist: warmed decode p99 ITL under a concurrent chunked long
prefill must stay ≤ 3× the no-prefill baseline (exit 1 otherwise).

Reports aggregate tokens/sec, mean queue delay, budget-fit rate, and the
pool's reserved/in-use peaks, and writes a machine-readable
``experiments/bench/BENCH_engine.json`` (schema below) so the perf
trajectory is tracked across PRs. The pool-never-exceeds-budget invariant
is asserted in ``tests/test_engine.py``; this script is the measurement
rig.

  PYTHONPATH=src python benchmarks/bench_engine_throughput.py \
      --requests 16 --rate 50 --max-new 8 --policy rl --scheduler fifo
"""
from __future__ import annotations

import argparse
import json
import os
import time


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama2-7b")
    ap.add_argument("--layers", type=int, default=4)
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--rate", type=float, default=1000.0,
                    help="Poisson arrival rate (req/s). Keep the offered "
                         "load (rate × max_new tok/s) well above serving "
                         "capacity: throughput is tokens/makespan on the "
                         "arrival clock, so an undersaturated trace caps "
                         "both servers at the offered rate and the "
                         "comparison measures nothing")
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--pool-requests", type=float, default=2.5,
                    help="pool sized for this many concurrent dense requests")
    ap.add_argument("--modes", nargs="+",
                    default=["masked", "structural"])
    ap.add_argument("--horizons", nargs="+", type=int, default=[1, 4, 8],
                    help="decode_horizon sweep: tokens fused per engine "
                         "macro-tick (one compiled launch, one sync)")
    ap.add_argument("--policy", default="rl",
                    help="pruning policy (rl or any registered baseline)")
    ap.add_argument("--scheduler", default="fifo",
                    choices=("fifo", "sjf", "priority"))
    ap.add_argument("--min-tok-s", type=float, default=0.0,
                    help="absolute floor for the warmed masked/paged row "
                         "at the top horizon (0 disables); machine-"
                         "specific, so off by default — the committed "
                         "repo-root BENCH_engine.json is produced with "
                         "--min-tok-s 1500 to pin the PR 4 level")
    ap.add_argument("--kv-dtypes", nargs="*", default=["int8"],
                    help="quantized KV page precisions to sweep (int8/fp8) "
                         "in addition to the model-precision rows: one "
                         "masked slot + paged row each at the top horizon. "
                         "Pass no values to disable. The int8 paged row is "
                         "hard-gated: admitted tokens per MB of pool must "
                         "be ≥ 1.8× the model-precision paged row at equal "
                         "budget, and warmed tok/s ≥ 0.9× of it")
    ap.add_argument("--chunk", type=int, default=16,
                    help="max_prefill_tokens for the interference "
                         "section's chunked run (0 disables the section)")
    ap.add_argument("--no-scenarios", action="store_true",
                    help="skip the elastic-budget scenario section "
                         "(budget-shock staircase + cancellation storm on "
                         "the paged executor, DESIGN.md §11)")
    ap.add_argument("--bucket-quant", default="pow2",
                    choices=("none", "layer", "pow2"),
                    help="structural bucket-shape quantization ladder "
                         "(DESIGN.md §9). The bench defaults to pow2 — an "
                         "adaptive policy's mask stream must not compile "
                         "one executable per distinct mask on the timed "
                         "path")
    ap.add_argument("--compile-cache-dir", default="",
                    help="persistent XLA compilation cache directory "
                         "(DESIGN.md §9); empty disables. A second bench "
                         "invocation against the same dir re-traces but "
                         "loads executables from disk instead of "
                         "recompiling")
    ap.add_argument("--assert-cache-replay", action="store_true",
                    help="hard gate for warmed-replay CI: with "
                         "--compile-cache-dir pre-populated by an earlier "
                         "identical invocation, this process must hit the "
                         "disk cache (> 0 hits) and compile nearly "
                         "nothing new (≤ 2 misses) — exit 1 otherwise")
    ap.add_argument("--scenario-requests", type=int, default=12,
                    help="requests per scenario run (heavy-tailed "
                         "lognormal prompt mix)")
    ap.add_argument("--shock-frac", type=float, default=0.5,
                    help="fraction of the KV headroom removed mid-serve "
                         "by the budget-shock scenario")
    ap.add_argument("--cancel-frac", type=float, default=0.25,
                    help="fraction of requests cancelled at random "
                         "lifecycle stages by the storm scenario")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--repeats", type=int, default=3,
                    help="timed replays per warmed row; the best (highest "
                         "tok/s) is reported, so cross-row gates compare "
                         "configuration capability rather than host noise. "
                         "Ignored under --no-warmup (cold rows are "
                         "single-shot by design)")
    ap.add_argument("--no-warmup", action="store_true",
                    help="skip the compile warm-up replay (reports cold "
                         "numbers dominated by XLA compile latency)")
    ap.add_argument("--out", default="experiments/bench")
    args = ap.parse_args()

    import jax
    import numpy as np

    from repro.configs import get_smoke_config
    from repro.core import dqn, masks, memory
    from repro.core.controller import RAPController
    from repro.core.policy import make_policy
    from repro.core.workload import PoissonConfig, poisson_requests
    from repro.data import SyntheticCorpus
    from repro.launch.mesh import make_serve_mesh
    from repro.models import registry
    from repro.runtime import (EngineConfig, EngineRequest, PagedExecutor,
                               RAPEngine, RAPServer, ShardedExecutor)

    if args.compile_cache_dir:
        # enable BEFORE the first compile: JAX latches the cache-used
        # decision process-wide at first use (see enable_compile_cache)
        from repro.runtime.engine import enable_compile_cache
        enable_compile_cache(args.compile_cache_dir)

    cfg = get_smoke_config(args.arch).replace(n_layers=args.layers)
    model = registry.build(cfg)
    params = model.init(jax.random.key(args.seed))
    corpus = SyntheticCorpus(cfg.vocab_size, seed=args.seed)
    calib = {k: jax.numpy.asarray(v)
             for k, v in corpus.batch(2, 64, split="calib").items()}
    mm = memory.build_memory_model(cfg)
    def build_policy():
        if args.policy == "rl":
            qp = dqn.init_qnet(jax.random.key(args.seed),
                               2 * cfg.n_layers + 4,
                               2 * cfg.n_layers + 1, 32)
            controller = RAPController(model, params, calib, mm, qp)
            return make_policy("rl", controller=controller)
        return make_policy(args.policy, model=model, params=params,
                           calib=calib, mm=mm, seed=args.seed)

    policy = build_policy()

    # prompt lengths round to 16 — serving engines bucket shapes so compiles
    # amortize; finer granularity just measures XLA compile latency
    wl = PoissonConfig(seed=args.seed, n_requests=args.requests,
                       rate=args.rate, short_len=(16, 48),
                       long_len=(48, 96), round_len_to=16)
    trace = poisson_requests(wl)
    rng = np.random.default_rng(args.seed)
    prompts = [corpus.sample_tokens(rng, 1, r.seq_len) for r in trace]
    max_total = max(r.seq_len for r in trace) + args.max_new

    full = masks.full_mask(cfg.n_layers)
    state1 = mm.state_bytes(full, 1, max_total)
    budget = mm.param_bytes(full) + args.pool_requests * state1
    print(f"[bench] {len(trace)} requests, prompt lens "
          f"{min(r.seq_len for r in trace)}–{max(r.seq_len for r in trace)}, "
          f"budget {budget / 1e6:.2f} MB "
          f"(pool ≈ {args.pool_requests:.1f} dense requests), "
          f"policy={policy.name} scheduler={args.scheduler}")

    reqs = [EngineRequest(rid=f"q{i}", prompt=np.asarray(p, np.int32),
                          arrival_t=trace[i].t)
            for i, p in enumerate(prompts)]

    serve_mesh = make_serve_mesh(args.slots)

    def _ms_pcts(summary):
        # {"p50","p90","p99"} in milliseconds from an EngineReport latency
        # summary (seconds)
        return {k: round(summary.get(k, 0.0) * 1e3, 3)
                for k in ("p50", "p90", "p99")}

    def run_engine(mode, executor_kind, horizon, kv_dtype=None):
        executor = None
        if executor_kind == "paged":
            executor = PagedExecutor(model, params, mode=mode,
                                     max_active=args.slots,
                                     kv_dtype=kv_dtype,
                                     bucket_quant=args.bucket_quant)
        elif executor_kind == "sharded":
            executor = ShardedExecutor(model, serve_mesh, params=params,
                                       max_active=args.slots)
        engine = RAPEngine(model, params, policy, EngineConfig(
            mode=mode, max_new_tokens=args.max_new, max_active=args.slots,
            max_len=max_total, budget_bytes=budget, decode_horizon=horizon,
            kv_dtype=kv_dtype, bucket_quant=args.bucket_quant,
            compile_cache_dir=args.compile_cache_dir),
            scheduler=args.scheduler, executor=executor)
        if not args.no_warmup:      # steady-state: compiles amortize away
            for _ in range(5):
                if engine.run(reqs).compile_events == 0:
                    break
        # best-of-N timed replays: the timed run is ~100 ms on a warmed
        # engine, so repeats are nearly free, and every gate below compares
        # rows measured minutes apart — a single scheduler hiccup or stray
        # compile on a shared host would fail a gate that the configuration
        # actually clears. Cold runs (--no-warmup) stay single-shot: their
        # point is the compile-dominated first replay.
        rep = None
        for _ in range(1 if args.no_warmup else max(1, args.repeats)):
            r = engine.run(reqs)
            assert r.rejected == 0, "trace should fit the pool eventually"
            assert (r.pool["peak_reserved_bytes"]
                    <= r.pool["capacity_bytes"] + 1e-6)
            if rep is None or r.tokens_per_s > rep.tokens_per_s:
                rep = r
        # admitted-tokens-per-MB: KV tokens one MB of pool storage holds at
        # this row's precision — the capacity axis quantized pages buy.
        # Paged rows read the physical page geometry; slot rows derive it
        # from the analytical per-token KV bytes at the row's byte ratio.
        pool_obj = getattr(engine, "pool", None)
        if (pool_obj is not None and pool_obj.page_bytes
                and pool_obj.tokens_per_page):
            tok_per_mb = pool_obj.tokens_per_page * 1e6 / pool_obj.page_bytes
        else:
            from repro.runtime.engine import _kv_byte_ratio
            per_tok = (mm.state_bytes(full, 1, 1)
                       - mm.state_bytes(full, 1, 0))
            per_tok *= _kv_byte_ratio(kv_dtype, cfg)
            tok_per_mb = 1e6 / max(per_tok, 1e-9)
        return rep, tok_per_mb

    rows = []
    # slot executor per requested mode; paged rides along in masked mode
    # (the only mode it serves) so every bench run tracks the paged-vs-slot
    # fragmentation and throughput delta. Heterogeneous-mixer archs
    # (griffin/mamba) stay slot-only — PagedExecutor rejects them.
    from repro.models.decoder import default_layout
    layout = default_layout(cfg)
    paged_ok = (len(layout) > 0
                and all(s.mixer == "attn" and s.ffn == layout[0].ffn
                        for s in layout))
    run_matrix = [(m, "slot") for m in args.modes]
    if paged_ok:
        # paged rides along in every mode it serves (masked + structural)
        # so each bench run tracks the paged-vs-slot delta per mode
        run_matrix.extend((m, "paged") for m in args.modes
                          if m in ("masked", "structural"))
    elif "masked" in args.modes or "structural" in args.modes:
        print(f"[bench] skipping paged runs: {args.arch} is not a uniform "
              f"all-attention layout")
    if "masked" in args.modes:
        # sharded serves ANY layout in masked mode (gated groups); on a
        # single-device host this is the (1, 1) degenerate mesh and the
        # row measures the jit-with-shardings overhead floor
        run_matrix.append(("masked", "sharded"))
        print(f"[bench] sharded mesh: {dict(serve_mesh.shape)} over "
              f"{serve_mesh.size} of {len(jax.devices())} devices")
    serial_cache = {}
    runs = [(m, e, h, None) for m, e in run_matrix for h in args.horizons]
    # quantized rows: one slot + one paged row per requested precision at
    # the top horizon, same trace and budget — the per-MB capacity delta
    # and the fused-dequant throughput cost, measured against the
    # model-precision rows above
    h_top_kv = max(args.horizons)
    for kv in args.kv_dtypes:
        if "masked" in args.modes:
            runs.append(("masked", "slot", h_top_kv, kv))
            if paged_ok:
                runs.append(("masked", "paged", h_top_kv, kv))
    for mode, executor_kind, horizon, kv_dtype in runs:
        rep, tok_per_mb = run_engine(mode, executor_kind, horizon, kv_dtype)

        # ---- serial one-shot replay of the same trace (once per mode)
        def serial_replay(server):
            # one-shot serving is sequential: request i starts at
            # max(previous finish, its arrival) — same arrival process the
            # engine sees, so both report tokens / makespan
            t, tokens, fits = 0.0, 0, []
            for i, p in enumerate(prompts):
                per_req_budget = trace[i].budget_frac * mm.dense_peak(
                    1, trace[i].seq_len + args.max_new)
                t0 = time.perf_counter()
                r = server.serve(np.asarray(p, np.int32), per_req_budget)
                dur = time.perf_counter() - t0
                t = max(t, trace[i].t) + dur
                tokens += r.tokens.size
                fits.append(r.fits)
            return tokens / max(t, 1e-9), fits

        if mode not in serial_cache:
            server = RAPServer(model, params, policy, mode=mode,
                               max_new_tokens=args.max_new)
            if not args.no_warmup:
                serial_replay(server)
            serial_cache[mode] = serial_replay(server)
        serial_tps, serial_fits = serial_cache[mode]

        speedup = rep.tokens_per_s / max(serial_tps, 1e-9)
        # host-side share of serving: wall time not spent inside compiled
        # launches / read-backs, per generated token — the dispatch
        # overhead the horizon decode exists to amortize
        host_ms = ((rep.wall_s - rep.launch_s)
                   / max(rep.generated_tokens, 1) * 1e3)
        row = {
            "mode": mode,
            "executor": executor_kind,
            "decode_horizon": horizon,
            "kv_dtype": kv_dtype or "model",
            "kv_tok_per_mb": round(tok_per_mb, 1),
            "engine_tok_s": round(rep.tokens_per_s, 1),
            "serial_tok_s": round(serial_tps, 1),
            "speedup": round(speedup, 2),
            "queue_delay_ms": round(rep.mean_queue_delay_s * 1e3, 1),
            "fit_rate": round(rep.budget_fit_rate, 3),
            "decode_iters": rep.decode_iters,
            "compiles": rep.compile_events,
            "cache_hits": rep.compile_cache_hits,
            "cache_misses": rep.compile_cache_misses,
            "host_ms_per_tok": round(host_ms, 4),
            "pool_peak_mb": round(rep.pool["peak_reserved_bytes"] / 1e6, 3),
            "pool_frag": round(rep.pool["fragmentation"], 3),
            "measured_frag": round(rep.measured_frag, 3),
            # request-level latency percentiles (DESIGN.md §6): TTFT is
            # arrival → first token; ITL per generated decode token
            "ttft_ms": _ms_pcts(rep.ttft),
            "itl_ms": _ms_pcts(rep.itl),
        }
        rows.append(row)
        print(f"[bench] {mode:10s}/{executor_kind:5s} H={horizon} "
              f"kv={row['kv_dtype']:5s} "
              f"engine {row['engine_tok_s']:8.1f} tok/s  "
              f"serial {row['serial_tok_s']:8.1f} tok/s  "
              f"speedup ×{row['speedup']:.2f}  "
              f"host {row['host_ms_per_tok']:.3f} ms/tok  "
              f"ttft p50/p99 {row['ttft_ms']['p50']:.1f}/"
              f"{row['ttft_ms']['p99']:.1f} ms  "
              f"itl p99 {row['itl_ms']['p99']:.2f} ms  "
              f"measured-frag {row['measured_frag']:.3f}")
        if speedup <= 1.0:
            print(f"[bench] WARNING: engine did not beat serial in {mode}")

    by_exec = {(r["mode"], r["executor"], r["decode_horizon"],
                r["kv_dtype"]): r for r in rows}
    h_top = max(args.horizons)
    slot = by_exec.get(("masked", "slot", h_top, "model"))
    paged = by_exec.get(("masked", "paged", h_top, "model"))

    # ---- horizon sanity warning: H > 1 should never lose to H = 1 ------
    # (the fused loop exists to amortize dispatch; a slower bigger horizon
    # means macro-ticks are stalling something — admission, completions)
    h_min = min(args.horizons)
    for (m, e) in {(r["mode"], r["executor"]) for r in rows}:
        base = by_exec.get((m, e, h_min, "model"))
        if not base or h_min != 1:
            continue
        for h in args.horizons:
            r = by_exec.get((m, e, h, "model"))
            if r and h > 1 and r["engine_tok_s"] < base["engine_tok_s"]:
                print(f"[bench] WARNING: {m}/{e} H={h} "
                      f"({r['engine_tok_s']:.1f} tok/s) underperforms H=1 "
                      f"({base['engine_tok_s']:.1f} tok/s) — the horizon "
                      f"should amortize dispatch, not stall admission")
    if slot and paged:
        print(f"[bench] paged vs slot (masked, H={h_top}): "
              f"frag {paged['measured_frag']:.3f} vs "
              f"{slot['measured_frag']:.3f}, "
              f"tok/s {paged['engine_tok_s']:.1f} vs "
              f"{slot['engine_tok_s']:.1f} "
              f"(×{paged['engine_tok_s'] / max(slot['engine_tok_s'], 1e-9):.2f})")
        if paged["measured_frag"] >= slot["measured_frag"]:
            print("[bench] WARNING: paged fragmentation not below slot")
        if paged["engine_tok_s"] < 0.9 * slot["engine_tok_s"]:
            print("[bench] WARNING: paged throughput >10% below slot")

    # ---- interference: decode ITL under a concurrent long prefill ----
    # A decode-heavy trace (3 short requests generating 64 tokens each at
    # H=2) is replayed three ways: alone (baseline), with a long prompt
    # injected shortly after decode starts and prefilled monolithically,
    # and with the same prompt prefilled in `--chunk`-token slices
    # interleaved between decode launches. The chunked run is what the
    # async engine promises: the long prefill's host/device time is
    # amortized across macro-ticks instead of stalling the running
    # decodes for the whole prompt.
    interference = None
    if args.chunk > 0:
        i_short_new, i_long_len, i_horizon = 64, 96, 2
        i_max_len = 128
        i_budget = (mm.param_bytes(full)
                    + 4.5 * mm.state_bytes(full, 1, i_max_len))
        shorts = [EngineRequest(
            rid=f"d{i}", prompt=np.asarray(
                corpus.sample_tokens(rng, 1, 16), np.int32),
            arrival_t=0.0) for i in range(3)]
        long_req = EngineRequest(
            rid="long", prompt=np.asarray(
                corpus.sample_tokens(rng, 1, i_long_len), np.int32),
            arrival_t=0.01, max_new=2)

        def run_interference(reqs_i, chunk):
            engine = RAPEngine(model, params, policy, EngineConfig(
                mode="masked", max_new_tokens=i_short_new,
                max_active=args.slots, max_len=i_max_len,
                budget_bytes=i_budget, decode_horizon=i_horizon,
                max_prefill_tokens=chunk), scheduler=args.scheduler)
            if not args.no_warmup:
                for _ in range(5):
                    if engine.run(reqs_i).compile_events == 0:
                        break
            rep = engine.run(reqs_i)
            assert rep.rejected == 0
            return rep

        base_rep = run_interference(shorts, 0)
        mono_rep = run_interference(shorts + [long_req], 0)
        chunk_rep = run_interference(shorts + [long_req], args.chunk)
        interference = {
            "config": {"decode_requests": len(shorts),
                       "decode_new_tokens": i_short_new,
                       "long_prompt_len": i_long_len,
                       "decode_horizon": i_horizon,
                       "chunk": args.chunk},
            "baseline_itl_ms": _ms_pcts(base_rep.itl),
            "monolithic_itl_ms": _ms_pcts(mono_rep.itl),
            "chunked_itl_ms": _ms_pcts(chunk_rep.itl),
            "monolithic_ttft_ms": _ms_pcts(mono_rep.ttft),
            "chunked_ttft_ms": _ms_pcts(chunk_rep.ttft),
        }
        print(f"[bench] interference (decode p99 ITL): baseline "
              f"{interference['baseline_itl_ms']['p99']:.2f} ms, "
              f"+long monolithic "
              f"{interference['monolithic_itl_ms']['p99']:.2f} ms, "
              f"+long chunked({args.chunk}) "
              f"{interference['chunked_itl_ms']['p99']:.2f} ms")
    # ---- elastic-budget scenarios (DESIGN.md §11) --------------------
    # Fault-injection on the paged executor (slot fallback for non-
    # uniform layouts): a mid-serve budget-shock staircase (preemption +
    # KV spill/resume must keep completing requests and recover warmed
    # throughput) and a cancellation storm (≥ --cancel-frac of requests
    # cancelled at random lifecycle stages must leave zero live rids and
    # zero leaked pages). Both hard-gate after the doc is written.
    scenarios = None
    if not args.no_scenarios and "masked" in args.modes:
        from repro.runtime import (heavy_tailed_requests, run_budget_shock,
                                   run_cancellation_storm)
        sc_exec = "paged" if paged_ok else "slot"
        sc_max_new, sc_max_prompt = 4, 64
        sc_max_len = sc_max_prompt + sc_max_new
        sc_budget = (mm.param_bytes(full)
                     + args.pool_requests * mm.state_bytes(full, 1,
                                                           sc_max_len))
        tok_src = corpus.sample_tokens(rng, 1, sc_max_prompt)
        # fresh policy: the row sweep's policy memoized decisions stamped
        # with each row's kv_dtype, and a cached int8 decision replayed
        # against the scenarios' model-precision pool is a dtype mismatch
        sc_policy = build_policy()

        def sc_engine():
            executor = (PagedExecutor(model, params, max_active=args.slots)
                        if sc_exec == "paged" else None)
            return RAPEngine(model, params, sc_policy, EngineConfig(
                mode="masked", max_new_tokens=sc_max_new,
                max_active=args.slots, max_len=sc_max_len,
                budget_bytes=sc_budget, decode_horizon=2),
                scheduler=args.scheduler, executor=executor)

        def sc_reqs(seed):
            return heavy_tailed_requests(
                tok_src, args.scenario_requests, seed=seed,
                max_len=sc_max_prompt, max_new=sc_max_new)

        shock_eng = sc_engine()
        if not args.no_warmup:      # warm compiles so phase rates are real
            shock_eng.run(sc_reqs(args.seed))
        shock = run_budget_shock(shock_eng, sc_reqs(args.seed),
                                 budget_bytes=sc_budget,
                                 frac=args.shock_frac)
        shock_rep = shock.pop("report")
        storm = run_cancellation_storm(sc_engine(), sc_reqs(args.seed + 1),
                                       cancel_frac=args.cancel_frac,
                                       seed=args.seed)
        storm_rep = storm.pop("report")
        scenarios = {
            "executor": sc_exec,
            "budget_shock": {
                **{k: v for k, v in shock.items()},
                "itl_ms": _ms_pcts(shock_rep.itl),
                "itl_preempted_ms": _ms_pcts(shock_rep.itl_preempted),
                "itl_preempted_count": shock_rep.itl_preempted["count"],
            },
            "cancellation_storm": storm,
        }
        print(f"[bench] budget shock ({sc_exec}, −{args.shock_frac:.0%} KV "
              f"headroom): pre/shock/post "
              f"{shock['pre']['completed']:.0f}/"
              f"{shock['shock']['completed']:.0f}/"
              f"{shock['post']['completed']:.0f} done, replay "
              f"{shock['replay_tok_per_s']:.0f} vs warmed "
              f"{shock['warmed_tok_per_s']:.0f} tok/s (recovery "
              f"×{shock['recovery_ratio']:.2f}), preempted "
              f"{shock['preempted_count']}, spilled "
              f"{shock['spilled_mb']:.2f} MB, resume p50 "
              f"{shock['resume_p50_s'] * 1e3:.1f} ms")
        print(f"[bench] cancellation storm ({sc_exec}): "
              f"{storm['cancelled']}/{storm['n_requests']} cancelled "
              f"(quota {storm['cancel_quota']}), {storm['done']} done, "
              f"live {storm['live_requests']:.0f}, spilled "
              f"{storm['spilled_requests']:.0f}, leaked pages "
              f"{storm['leaked_pages']:.0f}")
    elif not args.no_scenarios:
        print("[bench] skipping scenarios (masked mode not in --modes)")

    os.makedirs(args.out, exist_ok=True)
    # per-PR perf trajectory: one machine-readable document with the run
    # configuration, so cross-PR comparisons know what was measured
    doc = {
        "schema": 8,        # v8: structural serving at speed (DESIGN.md §9)
                            # — the run matrix gains structural/paged rows
                            # (PagedExecutor now serves structural mode over
                            # per-bucket compacted layer stacks; the warmed
                            # structural/paged row at the top horizon is
                            # hard-gated ≥ its structural/slot counterpart);
                            # structural rows run under --bucket-quant
                            # (default pow2: bounded compiled-executable
                            # set); rows gain cache_hits/cache_misses from
                            # the persistent XLA compilation cache
                            # (--compile-cache-dir) and the document gains
                            # a "compile_cache" section;
                            # --assert-cache-replay hard-gates a warmed
                            # second invocation to near-zero recompiles.
                            # Config gains bucket_quant + compile_cache_dir.
                            # v7: elastic-budget scenarios (DESIGN.md §11) —
                            # the document gains a "scenarios" section:
                            # budget_shock (per-phase completion/tok-s under
                            # a mid-serve KV-headroom staircase cut, with
                            # preempted/spilled/resume-latency and separate
                            # preempted-request ITL percentiles) and
                            # cancellation_storm (pool-ledger invariants
                            # after cancelling ≥ --cancel-frac of requests
                            # at random lifecycle stages). Hard-gated:
                            # shock+post phases must complete > 0 requests,
                            # the full-budget replay after the shocked run
                            # ≥ 0.9× the pre-shock warmed tok/s, storm
                            # ends with zero live rids and zero leaked
                            # pages. Config gains scenario knobs.
                            # v6: quantized KV pages (DESIGN.md §4) — rows
                            # gain kv_dtype ("model"|int8|fp8) and
                            # kv_tok_per_mb (KV tokens one MB of pool
                            # holds at the row's precision); --kv-dtypes
                            # adds masked slot+paged quantized rows at the
                            # top horizon, int8 paged hard-gated ≥ 1.8×
                            # the model-precision row's kv_tok_per_mb and
                            # (warmed) ≥ 0.9× its tok/s; warmed rows are
                            # best-of---repeats timed replays; config gains
                            # kv_dtypes + repeats. v5: async engine latency
                            # (DESIGN.md §6) —
                            # rows gain ttft_ms/itl_ms {p50,p90,p99} and
                            # the document gains the "interference"
                            # section (decode ITL under a concurrent
                            # monolithic vs chunked long prefill). v4
                            # added sharded executor rows (mesh-resident
                            # slot groups, DESIGN.md §7) — executor gains
                            # "sharded" and config gains mesh (axis sizes)
                            # + devices. v3 added the horizon sweep
                            # (decode_horizon, host_ms_per_tok). v2 added
                            # executor (slot|paged) + measured_frag.
        "bench": "engine_throughput",
        "config": {
            "arch": args.arch, "layers": args.layers,
            "requests": args.requests, "rate": args.rate,
            "max_new": args.max_new, "slots": args.slots,
            "pool_requests": args.pool_requests, "policy": policy.name,
            "scheduler": args.scheduler, "seed": args.seed,
            "warmup": not args.no_warmup,
            "repeats": 1 if args.no_warmup else max(1, args.repeats),
            "horizons": list(args.horizons),
            "kv_dtypes": list(args.kv_dtypes),
            "mesh": {str(k): int(v) for k, v in serve_mesh.shape.items()},
            "devices": len(jax.devices()),
            "scenario_requests": args.scenario_requests,
            "shock_frac": args.shock_frac,
            "cancel_frac": args.cancel_frac,
            "bucket_quant": args.bucket_quant,
            "compile_cache_dir": args.compile_cache_dir,
        },
        "rows": rows,
        "interference": interference,
        "scenarios": scenarios,
    }
    if args.compile_cache_dir:
        from repro.runtime.engine import _CACHE_EVENTS
        doc["compile_cache"] = {"dir": args.compile_cache_dir,
                                "hits": _CACHE_EVENTS["hits"],
                                "misses": _CACHE_EVENTS["misses"]}
        print(f"[bench] compile cache: {doc['compile_cache']['hits']} disk "
              f"hits, {doc['compile_cache']['misses']} misses "
              f"({args.compile_cache_dir})")
    bench_out = os.path.join(args.out, "BENCH_engine.json")
    with open(bench_out, "w") as f:
        json.dump(doc, f, indent=1)
    # rows-only file kept for pre-split consumers of the old layout
    legacy_out = os.path.join(args.out, "engine_throughput.json")
    with open(legacy_out, "w") as f:
        json.dump(rows, f, indent=1)
    # CSV summary: scalar columns only (nested percentile dicts live in
    # the JSON document)
    hdr = [k for k in rows[0] if not isinstance(rows[0][k], dict)]
    print(",".join(hdr))
    for r in rows:
        print(",".join(str(r[h]) for h in hdr))
    print(f"[bench] wrote {bench_out}")

    # Horizon perf gate — AFTER the doc is written, so a failing run still
    # leaves its machine-readable rows behind for diagnosis. Compares the
    # sweep's endpoints, so custom --horizons stay gated too.
    h_lo, h_hi = min(args.horizons), max(args.horizons)
    lo = by_exec.get(("masked", "paged", h_lo, "model"))
    hi = by_exec.get(("masked", "paged", h_hi, "model"))
    if not (lo and hi) or h_lo == h_hi:
        print("[bench] skipping horizon gate (no masked/paged rows at two "
              "distinct horizons)")
    elif args.no_warmup:
        # cold runs measure per-run XLA compile latency (a bigger horizon
        # compiles a bigger scan), not serving throughput — gate only warmed
        print(f"[bench] skipping H={h_hi}>H={h_lo} gate (--no-warmup: "
              f"numbers are compile-dominated)")
    elif hi["engine_tok_s"] < 0.9 * lo["engine_tok_s"]:
        raise SystemExit(
            f"[bench] FAIL: masked/paged H={h_hi} "
            f"({hi['engine_tok_s']:.1f} tok/s) is more than 10% below "
            f"H={h_lo} ({lo['engine_tok_s']:.1f} tok/s) — the fused "
            f"horizon loop must not cost throughput; a regression "
            f"here invalidates the perf trajectory")
    elif hi["host_ms_per_tok"] >= lo["host_ms_per_tok"]:
        # tok/s at the two endpoints is compute-bound parity on a small
        # host — the horizon's own promise is amortized dispatch, which
        # host_ms_per_tok measures directly (the backlog-aware clamp also
        # deliberately trades a few % of H=8 tok/s for ~2× lower queue
        # delay, see EngineConfig.decode_horizon)
        raise SystemExit(
            f"[bench] FAIL: masked/paged H={h_hi} host overhead "
            f"({hi['host_ms_per_tok']:.3f} ms/tok) does not beat "
            f"H={h_lo} ({lo['host_ms_per_tok']:.3f} ms/tok) — the fused "
            f"horizon loop exists to amortize per-token dispatch")

    # Quantized-KV gate — the capacity claim int8 pages exist for: at
    # equal budget, the int8 paged pool must hold ≥ 1.8× the KV tokens per
    # MB of the model-precision pool (narrower elements minus the per-page
    # scale overhead), and (warmed) serve ≥ 0.9× its throughput — the
    # fused-dequant kernel must not give the capacity win back in tok/s.
    # The per-MB ratio is page geometry, not timing, so it gates cold
    # runs too.
    q8 = by_exec.get(("masked", "paged", h_top, "int8"))
    base8 = by_exec.get(("masked", "paged", h_top, "model"))
    if not (q8 and base8):
        print("[bench] skipping int8 gate (no masked/paged int8+model "
              "rows at the top horizon)")
    else:
        ratio_mb = q8["kv_tok_per_mb"] / max(base8["kv_tok_per_mb"], 1e-9)
        ratio_ts = (q8["engine_tok_s"]
                    / max(base8["engine_tok_s"], 1e-9))
        print(f"[bench] int8 vs model paged (masked, H={h_top}): "
              f"{q8['kv_tok_per_mb']:.0f} vs {base8['kv_tok_per_mb']:.0f} "
              f"tok/MB (×{ratio_mb:.2f}), tok/s ×{ratio_ts:.2f}")
        if ratio_mb < 1.8:
            raise SystemExit(
                f"[bench] FAIL: int8 paged admitted-tokens-per-MB is only "
                f"×{ratio_mb:.2f} the model-precision row (need ≥ 1.8×) — "
                f"quantized pages must buy real KV capacity at equal "
                f"budget")
        if args.no_warmup:
            print("[bench] skipping int8 throughput gate (--no-warmup: "
                  "numbers are compile-dominated)")
        elif ratio_ts < 0.9:
            raise SystemExit(
                f"[bench] FAIL: int8 paged throughput is ×{ratio_ts:.2f} "
                f"the model-precision row (need ≥ 0.9×) — the fused "
                f"dequant path must not give the capacity win back")

    # Structural-paged gate (DESIGN.md §9) — paged structural decode runs
    # per-bucket compacted stacks over the shared page pool; at the top
    # horizon the warmed paged row must not be slower than structural/slot
    # (same compacted compute, better packing). Hard gate: a regression
    # here means the structural paged path costs more than it serves.
    st_slot = by_exec.get(("structural", "slot", h_top, "model"))
    st_paged = by_exec.get(("structural", "paged", h_top, "model"))
    if not (st_slot and st_paged):
        print("[bench] skipping structural-paged gate (no structural "
              "slot+paged rows at the top horizon)")
    elif args.no_warmup:
        print("[bench] skipping structural-paged gate (--no-warmup: "
              "numbers are compile-dominated)")
    else:
        ratio = (st_paged["engine_tok_s"]
                 / max(st_slot["engine_tok_s"], 1e-9))
        print(f"[bench] structural paged vs slot (H={h_top}): "
              f"{st_paged['engine_tok_s']:.1f} vs "
              f"{st_slot['engine_tok_s']:.1f} tok/s (×{ratio:.2f})")
        # 5% band: the two warmed rows are typically within measurement
        # noise of each other (same compacted compute), and best-of-
        # --repeats can land either side of parity on a shared host
        if ratio < 0.95:
            raise SystemExit(
                f"[bench] FAIL: warmed structural/paged H={h_top} "
                f"({st_paged['engine_tok_s']:.1f} tok/s) is ×{ratio:.2f} "
                f"of structural/slot ({st_slot['engine_tok_s']:.1f} "
                f"tok/s, need ≥ 0.95×) — paged structural decode must "
                f"not cost throughput against the slot path it "
                f"generalizes")

    # Cache-replay gate (DESIGN.md §9, opt-in) — CI runs the bench twice
    # against the same --compile-cache-dir; the second invocation passes
    # --assert-cache-replay and must load its executables from disk: same
    # config ⇒ same traces ⇒ every compile should be a cache hit. A small
    # miss slack absorbs executables whose keys legitimately vary across
    # processes (e.g. autotuning); near-zero is the contract.
    if args.assert_cache_replay:
        if not args.compile_cache_dir:
            raise SystemExit("[bench] FAIL: --assert-cache-replay needs "
                             "--compile-cache-dir")
        from repro.runtime.engine import _CACHE_EVENTS
        hits, misses = _CACHE_EVENTS["hits"], _CACHE_EVENTS["misses"]
        if hits <= 0 or misses > 2:
            raise SystemExit(
                f"[bench] FAIL: warmed replay did not reuse the persistent "
                f"compile cache ({hits} hits, {misses} misses; need > 0 "
                f"hits and ≤ 2 misses) — a second identical invocation "
                f"must load executables from {args.compile_cache_dir}, "
                f"not recompile the serving set")
        print(f"[bench] cache replay gate passed: {hits} hits, "
              f"{misses} misses")

    # Absolute-throughput gate (opt-in, machine-specific): the warmed
    # masked/paged row at the top horizon must hold the floor the
    # previous PR's committed run established on the same machine.
    if args.min_tok_s > 0 and not args.no_warmup:
        anchor = by_exec.get(("masked", "paged", h_top, "model")) or \
            by_exec.get(("masked", "slot", h_top, "model"))
        if anchor and anchor["engine_tok_s"] < args.min_tok_s:
            raise SystemExit(
                f"[bench] FAIL: warmed masked/{anchor['executor']} "
                f"H={h_top} ({anchor['engine_tok_s']:.1f} tok/s) is below "
                f"the --min-tok-s floor ({args.min_tok_s:.0f} tok/s) — "
                f"throughput regressed against the committed trajectory")

    # Chunked-prefill interference gate — AFTER the doc write, like the
    # horizon gate. The async engine's latency contract: with a long
    # prompt prefilled in chunks interleaved between decode launches,
    # warmed decode p99 ITL must stay within 3× the no-prefill baseline.
    # Monolithic prefill is reported but not gated — stalling for the
    # whole prompt is exactly the behaviour chunking replaces. A 50 µs
    # floor keeps degenerate sub-tick baselines from making 3× meaningless.
    if interference is None:
        print("[bench] skipping interference gate (--chunk 0)")
    elif args.no_warmup:
        print("[bench] skipping interference gate (--no-warmup: numbers "
              "are compile-dominated)")
    else:
        base_p99 = interference["baseline_itl_ms"]["p99"]
        chunk_p99 = interference["chunked_itl_ms"]["p99"]
        limit = 3.0 * max(base_p99, 0.05)
        if chunk_p99 > limit:
            raise SystemExit(
                f"[bench] FAIL: decode p99 ITL under a concurrent chunked "
                f"long prefill ({chunk_p99:.2f} ms) exceeds 3× the "
                f"no-prefill baseline ({base_p99:.2f} ms) — chunked "
                f"prefill must bound decode latency interference; a "
                f"regression here invalidates the async-engine contract")

    # Sharded gate — on a multi-device host, the warmed sharded row at the
    # top horizon must not be slower than single-device local at equal
    # batch: the horizon pays the mesh's collectives once per H tokens, so
    # sharding must amortize, not regress. Enforced on real accelerator
    # meshes only: fake host-platform CPU "devices"
    # (XLA_FLAGS=--xla_force_host_platform_device_count) are threads on
    # one socket, so the partition/dispatch overhead they measure is real
    # but the parallel speedup that would pay for it is structurally
    # impossible — there, the ratio is reported loudly instead of failing.
    # Also skipped on one device (the (1, 1) mesh row only tracks the
    # jit-with-shardings overhead floor) and on cold runs.
    sh = by_exec.get(("masked", "sharded", h_hi, "model"))
    sl = by_exec.get(("masked", "slot", h_hi, "model"))
    if not (sh and sl):
        print("[bench] skipping sharded gate (no masked sharded+slot rows)")
    elif args.no_warmup:
        print("[bench] skipping sharded gate (--no-warmup: numbers are "
              "compile-dominated)")
    elif serve_mesh.size <= 1:
        print("[bench] skipping sharded gate (single-device mesh)")
    else:
        ratio = sh["engine_tok_s"] / max(sl["engine_tok_s"], 1e-9)
        print(f"[bench] sharded vs local (masked, H={h_hi}, "
              f"{serve_mesh.size}-device mesh): "
              f"{sh['engine_tok_s']:.1f} vs {sl['engine_tok_s']:.1f} tok/s "
              f"(×{ratio:.2f})")
        if sh["engine_tok_s"] >= sl["engine_tok_s"]:
            pass
        elif jax.default_backend() == "cpu":
            print(f"[bench] WARNING: sharded slower than local ×{ratio:.2f} "
                  f"— expected on fake host-platform CPU devices (shared "
                  f"socket); the gate hard-fails on real accelerator "
                  f"meshes")
        else:
            raise SystemExit(
                f"[bench] FAIL: masked/sharded H={h_hi} on a "
                f"{serve_mesh.size}-device mesh ({sh['engine_tok_s']:.1f} "
                f"tok/s) is slower than single-device local "
                f"({sl['engine_tok_s']:.1f} tok/s) at equal batch — "
                f"collectives must be amortized by the horizon, not "
                f"regressive; a regression here invalidates the sharded "
                f"serve path")

    # Scenario gates (DESIGN.md §11) — AFTER the doc write, like every
    # gate above: a failing run still leaves its rows behind. These are
    # the robustness contract the elastic-budget machinery ships under;
    # run_budget_shock / run_cancellation_storm returning at all already
    # proves no deadlock (the engine drained).
    if scenarios is not None:
        sh = scenarios["budget_shock"]
        stm = scenarios["cancellation_storm"]
        if sh["shock"]["completed"] <= 0 or sh["post"]["completed"] <= 0:
            raise SystemExit(
                f"[bench] FAIL: budget shock stalled completions "
                f"(shock {sh['shock']['completed']:.0f} done, post "
                f"{sh['post']['completed']:.0f} done) — the engine must "
                f"keep serving through a −{args.shock_frac:.0%} KV cut "
                f"and after recovery, not deadlock or starve")
        if sh["preempted_count"] > 0 and sh["itl_preempted_count"] <= 0:
            raise SystemExit(
                "[bench] FAIL: requests were preempted but no ITL samples "
                "landed in the preempted pool — resume gaps would pollute "
                "the untouched requests' percentiles")
        if args.no_warmup:
            print("[bench] skipping shock recovery gate (--no-warmup: "
                  "numbers are compile-dominated)")
        elif sh["recovery_ratio"] < 0.9:
            raise SystemExit(
                f"[bench] FAIL: the full-budget replay AFTER the shocked "
                f"run reached only ×{sh['recovery_ratio']:.2f} of the "
                f"pre-shock warmed rate ({sh['replay_tok_per_s']:.0f} vs "
                f"{sh['warmed_tok_per_s']:.0f} tok/s, need ≥ 0.9×) — "
                f"restoring the budget must restore goodput; pages or "
                f"slots are leaking across the shock")
        if (stm["live_requests"] or stm["spilled_requests"]
                or stm["leaked_pages"]):
            raise SystemExit(
                f"[bench] FAIL: cancellation storm leaked state — live "
                f"rids {stm['live_requests']:.0f}, spilled "
                f"{stm['spilled_requests']:.0f}, leaked pages "
                f"{stm['leaked_pages']:.0f} (all must be 0); the cancel "
                f"path must release every page at every lifecycle stage")
        if stm["cancelled"] < stm["cancel_quota"]:
            print(f"[bench] WARNING: storm cancelled {stm['cancelled']} < "
                  f"quota {stm['cancel_quota']} (trace drained before the "
                  f"storm met its quota — raise --scenario-requests)")


if __name__ == "__main__":
    main()
