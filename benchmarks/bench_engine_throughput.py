"""Engine vs one-shot serving throughput on a Poisson trace.

Replays the SAME ≥16-request Poisson arrival trace through:

  * **engine/slot** — continuous batching through ``RAPEngine`` +
    ``LocalExecutor``: one shared KV pool (admission-controlled),
    slot-batched decode over all running requests, under the chosen
    pruning policy and scheduler (per mode: masked | structural);
  * **engine/paged** — the same trace through ``PagedExecutor``
    (masked mode): physically paged KV with per-request page tables,
    measuring what paging buys in *physical* internal fragmentation
    (``measured_frag``: 1 − tokens-written / cache-bytes-allocated,
    sampled per decode tick) at equal-or-better throughput;
  * **serial** — the historical one-shot path: ``RAPServer.serve()`` per
    request, each against its own instantaneous budget.

Reports aggregate tokens/sec, mean queue delay, budget-fit rate, and the
pool's reserved/in-use peaks, and writes a machine-readable
``experiments/bench/BENCH_engine.json`` (schema below) so the perf
trajectory is tracked across PRs. The pool-never-exceeds-budget invariant
is asserted in ``tests/test_engine.py``; this script is the measurement
rig.

  PYTHONPATH=src python benchmarks/bench_engine_throughput.py \
      --requests 16 --rate 50 --max-new 8 --policy rl --scheduler fifo
"""
from __future__ import annotations

import argparse
import json
import os
import time


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama2-7b")
    ap.add_argument("--layers", type=int, default=4)
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--rate", type=float, default=1000.0,
                    help="Poisson arrival rate (req/s). Keep the offered "
                         "load (rate × max_new tok/s) well above serving "
                         "capacity: throughput is tokens/makespan on the "
                         "arrival clock, so an undersaturated trace caps "
                         "both servers at the offered rate and the "
                         "comparison measures nothing")
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--pool-requests", type=float, default=2.5,
                    help="pool sized for this many concurrent dense requests")
    ap.add_argument("--modes", nargs="+",
                    default=["masked", "structural"])
    ap.add_argument("--policy", default="rl",
                    help="pruning policy (rl or any registered baseline)")
    ap.add_argument("--scheduler", default="fifo",
                    choices=("fifo", "sjf", "priority"))
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--no-warmup", action="store_true",
                    help="skip the compile warm-up replay (reports cold "
                         "numbers dominated by XLA compile latency)")
    ap.add_argument("--out", default="experiments/bench")
    args = ap.parse_args()

    import jax
    import numpy as np

    from repro.configs import get_smoke_config
    from repro.core import dqn, masks, memory
    from repro.core.controller import RAPController
    from repro.core.policy import make_policy
    from repro.core.workload import PoissonConfig, poisson_requests
    from repro.data import SyntheticCorpus
    from repro.models import registry
    from repro.runtime import (EngineConfig, EngineRequest, PagedExecutor,
                               RAPEngine, RAPServer)

    cfg = get_smoke_config(args.arch).replace(n_layers=args.layers)
    model = registry.build(cfg)
    params = model.init(jax.random.key(args.seed))
    corpus = SyntheticCorpus(cfg.vocab_size, seed=args.seed)
    calib = {k: jax.numpy.asarray(v)
             for k, v in corpus.batch(2, 64, split="calib").items()}
    mm = memory.build_memory_model(cfg)
    if args.policy == "rl":
        qp = dqn.init_qnet(jax.random.key(args.seed), 2 * cfg.n_layers + 4,
                           2 * cfg.n_layers + 1, 32)
        controller = RAPController(model, params, calib, mm, qp)
        policy = make_policy("rl", controller=controller)
    else:
        policy = make_policy(args.policy, model=model, params=params,
                             calib=calib, mm=mm, seed=args.seed)

    # prompt lengths round to 16 — serving engines bucket shapes so compiles
    # amortize; finer granularity just measures XLA compile latency
    wl = PoissonConfig(seed=args.seed, n_requests=args.requests,
                       rate=args.rate, short_len=(16, 48),
                       long_len=(48, 96), round_len_to=16)
    trace = poisson_requests(wl)
    rng = np.random.default_rng(args.seed)
    prompts = [corpus.sample_tokens(rng, 1, r.seq_len) for r in trace]
    max_total = max(r.seq_len for r in trace) + args.max_new

    full = masks.full_mask(cfg.n_layers)
    state1 = mm.state_bytes(full, 1, max_total)
    budget = mm.param_bytes(full) + args.pool_requests * state1
    print(f"[bench] {len(trace)} requests, prompt lens "
          f"{min(r.seq_len for r in trace)}–{max(r.seq_len for r in trace)}, "
          f"budget {budget / 1e6:.2f} MB "
          f"(pool ≈ {args.pool_requests:.1f} dense requests), "
          f"policy={policy.name} scheduler={args.scheduler}")

    reqs = [EngineRequest(rid=f"q{i}", prompt=np.asarray(p, np.int32),
                          arrival_t=trace[i].t)
            for i, p in enumerate(prompts)]

    def run_engine(mode, executor_kind):
        executor = None
        if executor_kind == "paged":
            executor = PagedExecutor(model, params, max_active=args.slots)
        engine = RAPEngine(model, params, policy, EngineConfig(
            mode=mode, max_new_tokens=args.max_new, max_active=args.slots,
            max_len=max_total, budget_bytes=budget),
            scheduler=args.scheduler, executor=executor)
        if not args.no_warmup:      # steady-state: compiles amortize away
            for _ in range(5):
                if engine.run(reqs).compile_events == 0:
                    break
        rep = engine.run(reqs)
        assert rep.rejected == 0, "trace should fit the pool eventually"
        assert (rep.pool["peak_reserved_bytes"]
                <= rep.pool["capacity_bytes"] + 1e-6)
        return rep

    rows = []
    # slot executor per requested mode; paged rides along in masked mode
    # (the only mode it serves) so every bench run tracks the paged-vs-slot
    # fragmentation and throughput delta. Heterogeneous-mixer archs
    # (griffin/mamba) stay slot-only — PagedExecutor rejects them.
    from repro.models.decoder import default_layout
    layout = default_layout(cfg)
    paged_ok = (len(layout) > 0
                and all(s.mixer == "attn" and s.ffn == layout[0].ffn
                        for s in layout))
    run_matrix = [(m, "slot") for m in args.modes]
    if "masked" in args.modes and paged_ok:
        run_matrix.append(("masked", "paged"))
    elif "masked" in args.modes:
        print(f"[bench] skipping paged run: {args.arch} is not a uniform "
              f"all-attention layout")
    serial_cache = {}
    for mode, executor_kind in run_matrix:
        rep = run_engine(mode, executor_kind)

        # ---- serial one-shot replay of the same trace (once per mode)
        def serial_replay(server):
            # one-shot serving is sequential: request i starts at
            # max(previous finish, its arrival) — same arrival process the
            # engine sees, so both report tokens / makespan
            t, tokens, fits = 0.0, 0, []
            for i, p in enumerate(prompts):
                per_req_budget = trace[i].budget_frac * mm.dense_peak(
                    1, trace[i].seq_len + args.max_new)
                t0 = time.perf_counter()
                r = server.serve(np.asarray(p, np.int32), per_req_budget)
                dur = time.perf_counter() - t0
                t = max(t, trace[i].t) + dur
                tokens += r.tokens.size
                fits.append(r.fits)
            return tokens / max(t, 1e-9), fits

        if mode not in serial_cache:
            server = RAPServer(model, params, policy, mode=mode,
                               max_new_tokens=args.max_new)
            if not args.no_warmup:
                serial_replay(server)
            serial_cache[mode] = serial_replay(server)
        serial_tps, serial_fits = serial_cache[mode]

        speedup = rep.tokens_per_s / max(serial_tps, 1e-9)
        row = {
            "mode": mode,
            "executor": executor_kind,
            "engine_tok_s": round(rep.tokens_per_s, 1),
            "serial_tok_s": round(serial_tps, 1),
            "speedup": round(speedup, 2),
            "queue_delay_ms": round(rep.mean_queue_delay_s * 1e3, 1),
            "fit_rate": round(rep.budget_fit_rate, 3),
            "decode_iters": rep.decode_iters,
            "compiles": rep.compile_events,
            "pool_peak_mb": round(rep.pool["peak_reserved_bytes"] / 1e6, 3),
            "pool_frag": round(rep.pool["fragmentation"], 3),
            "measured_frag": round(rep.measured_frag, 3),
        }
        rows.append(row)
        print(f"[bench] {mode:10s}/{executor_kind:5s} "
              f"engine {row['engine_tok_s']:8.1f} tok/s  "
              f"serial {row['serial_tok_s']:8.1f} tok/s  "
              f"speedup ×{row['speedup']:.2f}  "
              f"queue {row['queue_delay_ms']:.1f} ms  "
              f"measured-frag {row['measured_frag']:.3f}")
        if speedup <= 1.0:
            print(f"[bench] WARNING: engine did not beat serial in {mode}")

    by_exec = {(r["mode"], r["executor"]): r for r in rows}
    slot, paged = by_exec.get(("masked", "slot")), by_exec.get(
        ("masked", "paged"))
    if slot and paged:
        print(f"[bench] paged vs slot (masked): "
              f"frag {paged['measured_frag']:.3f} vs "
              f"{slot['measured_frag']:.3f}, "
              f"tok/s {paged['engine_tok_s']:.1f} vs "
              f"{slot['engine_tok_s']:.1f} "
              f"(×{paged['engine_tok_s'] / max(slot['engine_tok_s'], 1e-9):.2f})")
        if paged["measured_frag"] >= slot["measured_frag"]:
            print("[bench] WARNING: paged fragmentation not below slot")
        if paged["engine_tok_s"] < 0.9 * slot["engine_tok_s"]:
            print("[bench] WARNING: paged throughput >10% below slot")

    os.makedirs(args.out, exist_ok=True)
    # per-PR perf trajectory: one machine-readable document with the run
    # configuration, so cross-PR comparisons know what was measured
    doc = {
        "schema": 2,        # v2: rows gained executor (slot|paged) +
                            # measured_frag (physical KV fragmentation)
        "bench": "engine_throughput",
        "config": {
            "arch": args.arch, "layers": args.layers,
            "requests": args.requests, "rate": args.rate,
            "max_new": args.max_new, "slots": args.slots,
            "pool_requests": args.pool_requests, "policy": policy.name,
            "scheduler": args.scheduler, "seed": args.seed,
            "warmup": not args.no_warmup,
        },
        "rows": rows,
    }
    bench_out = os.path.join(args.out, "BENCH_engine.json")
    with open(bench_out, "w") as f:
        json.dump(doc, f, indent=1)
    # rows-only file kept for pre-split consumers of the old layout
    legacy_out = os.path.join(args.out, "engine_throughput.json")
    with open(legacy_out, "w") as f:
        json.dump(rows, f, indent=1)
    hdr = list(rows[0])
    print(",".join(hdr))
    for r in rows:
        print(",".join(str(r[h]) for h in hdr))
    print(f"[bench] wrote {bench_out}")


if __name__ == "__main__":
    main()
