"""Fig. 6 analogue: GSI re-evaluated scores vs one-shot scores after
successive removals — one-shot misses inter-layer dependence."""
from __future__ import annotations

import numpy as np

from benchmarks import common
from repro.core import gsi


def run() -> list:
    model, params, corpus = common.subject()
    batch = common.calib_batch(corpus)
    L = model.cfg.n_layers
    oneshot = gsi.oneshot_rank(model, params, batch, chunk=16)
    res = gsi.gsi_rank(model, params, batch, max_removals=6, chunk=16)
    rows = []
    for step, snap in enumerate(res.score_snapshots):
        for b in range(2 * L):
            if np.isfinite(snap[b]):
                rows.append({"gsi_step": step,
                             "block": f"{'MHA' if b < L else 'FFN'}{b % L}",
                             "gsi_score": round(float(snap[b]), 4),
                             "oneshot_score": round(float(oneshot[b]), 4)})
    common.emit("fig6_gsi_vs_oneshot", rows,
                header=["gsi_step", "block", "gsi_score", "oneshot_score"])
    # divergence grows with removals
    last = [r for r in rows if r["gsi_step"] == len(res.score_snapshots) - 1]
    div = float(np.mean([abs(r["gsi_score"] - r["oneshot_score"])
                         for r in last]))
    print(f"# mean |GSI − one-shot| at step {len(res.score_snapshots)-1}: "
          f"{div:.4f}")
    return rows
