"""End-to-end training driver with full fault-tolerance plumbing.

  PYTHONPATH=src python examples/train_e2e.py --size small --steps 300
  PYTHONPATH=src python examples/train_e2e.py --size 100m  --steps 300  # real hw

``small`` (~13M params) trains in minutes on this CPU container; ``100m``
is the same family scaled to ~100M params — the intended shape on a real
accelerator. Demonstrates: step-indexed data pipeline, async checkpoints,
crash-resume (kill it mid-run and re-run the same command), straggler
logging, final held-out evaluation.
"""
import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.llama2_7b import RAP_SUBJECT
from repro.data import SyntheticCorpus, batch_iterator
from repro.models import registry
from repro.optim import adamw
from repro.runtime import Trainer, TrainerConfig

SIZES = {
    # ~13M — CPU-friendly
    "small": RAP_SUBJECT,
    # ~100M of the same family (24L × 512d), the few-hundred-step target
    "100m": RAP_SUBJECT.replace(name="subject-100m", n_layers=24,
                                d_model=512, n_heads=8, n_kv_heads=8,
                                head_dim=64, d_ff=1536, vocab_size=8192,
                                vocab_round_to=512),
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--size", choices=SIZES, default="small")
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt-dir", default="/tmp/rap_e2e_ckpt")
    args = ap.parse_args()

    cfg = SIZES[args.size]
    model = registry.build(cfg)
    n = cfg.total_params()
    print(f"model: {cfg.name}  ~{n/1e6:.1f}M params")
    corpus = SyntheticCorpus(cfg.vocab_size, seed=0)

    trainer = Trainer(
        model,
        adamw.AdamWConfig(lr=1e-3, total_steps=args.steps, warmup_steps=30),
        TrainerConfig(total_steps=args.steps, ckpt_dir=args.ckpt_dir,
                      ckpt_every=50, log_every=25),
        on_log=lambda s, m: print(f"step {s:5d}  loss {m['loss']:.4f}  "
                                  f"ppl {m['ppl']:8.2f}  lr {m['lr']:.2e}",
                                  flush=True),
        on_straggler=lambda s, dt: print(f"  !! straggler at step {s}: "
                                         f"{dt:.2f}s"))
    resumed = trainer.maybe_restore()
    if resumed:
        print(f"resuming from step {trainer.step}")
    batches = batch_iterator(corpus, args.batch, args.seq,
                             start=trainer.step)
    summary = trainer.run(batches)

    # held-out evaluation
    ev = {k: jnp.asarray(v) for k, v in corpus.batch(
        8, args.seq, split="eval").items()}
    loss, aux = model.loss(trainer.params, ev)
    print(f"\nfinal: step {summary['final_step']}  "
          f"held-out ppl {float(aux['ppl']):.2f}  "
          f"stragglers {len(summary['straggler_events'])}")


if __name__ == "__main__":
    main()
